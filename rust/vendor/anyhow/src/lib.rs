//! Minimal offline drop-in for the parts of the `anyhow` crate this
//! workspace uses: [`Result`], [`Error`], the [`Context`] extension
//! trait, and the `anyhow!` / `bail!` macros.
//!
//! The build environment has no network registry, so the real crates.io
//! dependency is replaced by this path dependency with the same crate
//! name. Semantics follow anyhow where the workspace relies on them:
//!
//! * `{e}` (plain `Display`) prints the outermost context frame only;
//! * `{e:#}` (alternate) prints the whole chain, colon-separated;
//! * `?` converts any `std::error::Error` into [`Error`], capturing its
//!   `source()` chain as additional frames;
//! * [`Context`] is implemented for `Result` (any convertible error,
//!   including [`Error`] itself) and for `Option`.
//!
//! Like real anyhow, [`Error`] deliberately does **not** implement
//! `std::error::Error` — that is what keeps the blanket `From` impl
//! coherent with the reflexive `impl From<T> for T`.

use std::fmt;

/// A lightweight context-carrying error: an ordered stack of
/// human-readable frames, outermost context first, root cause last.
pub struct Error {
    frames: Vec<String>,
}

impl Error {
    /// Construct from a printable message.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { frames: vec![m.to_string()] }
    }

    /// Wrap this error with an outer context frame.
    pub fn context(mut self, c: impl fmt::Display) -> Error {
        self.frames.insert(0, c.to_string());
        self
    }

    /// The frames, outermost context first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.frames.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.frames.join(": "))
        } else {
            f.write_str(self.frames.first().map(String::as_str).unwrap_or("error"))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.frames.first().map(String::as_str).unwrap_or("error"))?;
        if self.frames.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for frame in &self.frames[1..] {
                write!(f, "\n    {frame}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        let mut frames = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            frames.push(s.to_string());
            src = s.source();
        }
        Error { frames }
    }
}

/// `anyhow::Result<T>`: `std::result::Result` with [`Error`] defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(|| ...)` to
/// `Result` and `Option`.
pub trait Context<T>: Sized {
    /// Attach a context message, converting the error to [`Error`].
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    /// Attach a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => { $crate::Error::msg(format!($($arg)*)) };
}

/// Early-return an `Err` built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return ::core::result::Result::Err($crate::anyhow!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn display_prints_outermost_alternate_prints_chain() {
        let e: Error = Error::from(io_err()).context("loading config");
        assert_eq!(format!("{e}"), "loading config");
        assert_eq!(format!("{e:#}"), "loading config: missing thing");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<u32> {
            let n: u32 = "17".parse()?;
            Ok(n)
        }
        assert_eq!(inner().unwrap(), 17);
        fn failing() -> Result<u32> {
            let n: u32 = "x".parse()?;
            Ok(n)
        }
        assert!(failing().is_err());
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert!(format!("{e:#}").starts_with("outer: "));

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("want {}", 5)).unwrap_err();
        assert_eq!(format!("{e}"), "want 5");
        assert_eq!(Some(3).context("never used").unwrap(), 3);
    }

    #[test]
    fn macros_build_and_bail() {
        fn f(flag: bool) -> Result<u32> {
            if flag {
                bail!("flag was {flag}");
            }
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(format!("{}", f(true).unwrap_err()), "flag was true");
        let e = anyhow!("code {}", 7);
        assert_eq!(e.to_string(), "code 7");
    }

    #[test]
    fn chain_is_ordered_outermost_first() {
        let e = Error::msg("root").context("mid").context("top");
        let frames: Vec<&str> = e.chain().collect();
        assert_eq!(frames, vec!["top", "mid", "root"]);
        assert!(format!("{e:?}").contains("Caused by:"));
    }
}
