//! `cargo bench --bench fig2_thread_scaling` — regenerates Fig 2:
//! speedup of fine- over coarse-grained on the CPU model across
//! {1,2,4,8,16,32,48} threads at K = K_max, one row per graph — plus
//! the schedule-ablation sweep: coarse-grained K=3 under
//! static/dynamic/workaware/stealing at every thread count.

use ktruss::bench_harness::{figs, report, Workload};

fn main() {
    let w = Workload::from_env().expect("workload config");
    println!("{}", w.banner("Fig 2 (fine/coarse CPU speedup vs threads, K=Kmax)"));
    let f = figs::run_fig2(&w, |msg| eprintln!("  [{msg}]")).expect("fig2 run");
    let mut body = f.render();
    body.push_str("\n## schedule sweep (coarse, K=3, speedup over static)\n");
    let s = figs::run_fig2_schedules(&w, |msg| eprintln!("  [sched {msg}]")).expect("sched sweep");
    body.push_str(&s.render());
    body.push_str(&format!("\n[scale {}]\n", f.scale));
    report::emit("fig2_thread_scaling.txt", &body).expect("save report");
}
