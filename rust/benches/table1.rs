//! `cargo bench --bench table1` — regenerates the paper's Table I:
//! runtime (ms) and ME/s for CPU-C/CPU-F (48 simulated threads) and
//! GPU-C/GPU-F (simulated V100), K = 3, over the replica suite.
//!
//! Env: KTRUSS_SUITE (paper|small|name,name…), KTRUSS_SCALE (default
//! 0.15 — this container is one core; scale is printed with results).

use ktruss::bench_harness::{report, table1, Workload};

fn main() {
    let w = Workload::from_env().expect("workload config");
    println!("{}", w.banner("Table I (K=3)"));
    let t = table1::run(&w, 3, |msg| eprintln!("  [{msg}]")).expect("table1 run");
    let body = format!("{}\n[scale {}]\n", t.render(), t.scale);
    report::emit("table1.txt", &body).expect("save report");
}
