//! `cargo bench --bench ablations` — design-decision ablations
//! (DESIGN.md §7): zero-terminated CSR overhead, static vs dynamic
//! scheduling, ultra-fine task splitting, flat-index resolution.

use ktruss::bench_harness::{ablations, report, Workload};

fn main() {
    let w = Workload::from_env().expect("workload config");
    println!("{}", w.banner("Ablations"));
    let mut body = String::new();
    // family-diverse picks: hub-heavy, uniform, triangle-rich
    let names = ["as20000102", "roadNet-PA", "ca-GrQc", "soc-Epinions1"];
    for name in names {
        let Some(spec) = ktruss::gen::suite::by_name(name) else { continue };
        let g = w.load(spec).expect("load replica");
        body.push_str(&format!("## {name} (n={}, m={})\n", g.n(), g.nnz()));

        let zt = ablations::ablate_zeroterm(&g, 5);
        body.push_str(&format!(
            "1. zero-terminated vs bounds-carried support pass: {:.3} ms vs {:.3} ms ({:+.1}% overhead)\n",
            zt.zeroterm_ms,
            zt.bounds_ms,
            zt.overhead() * 100.0
        ));

        let sched = ablations::ablate_schedule(&g);
        body.push_str(&format!(
            "2. 48T support kernel: coarse-static {:.4} ms | coarse-dynamic {:.4} ms | fine-static {:.4} ms\n   \
             schedule axis: coarse-workaware {:.4} ms | coarse-stealing {:.4} ms | fine-workaware {:.4} ms\n",
            sched.coarse_static_s * 1e3,
            sched.coarse_dynamic_s * 1e3,
            sched.fine_static_s * 1e3,
            sched.coarse_workaware_s * 1e3,
            sched.coarse_stealing_s * 1e3,
            sched.fine_workaware_s * 1e3
        ));

        for seg in [16u32, 64, 256] {
            let uf = ablations::ablate_ultrafine(&g, seg);
            body.push_str(&format!(
                "3. GPU fine {:.4} ms vs ultra-fine(seg={seg}) {:.4} ms\n",
                uf.fine_s * 1e3,
                uf.ultra_s * 1e3
            ));
        }

        let fi = ablations::ablate_flat_index(&g, 5);
        body.push_str(&format!(
            "4. flat-index resolve: binary-search {:.2} ns/slot vs hinted {:.2} ns/slot\n",
            fi.binary_search_ns, fi.hinted_ns
        ));

        let ro = ablations::ablate_reorder(&g);
        body.push_str(&format!(
            "5. 48T coarse kernel vs vertex order: natural {:.4} ms | degree-sorted {:.4} ms | (fine natural {:.4} ms)\n\n",
            ro.natural_s * 1e3,
            ro.degree_sorted_s * 1e3,
            ro.fine_natural_s * 1e3
        ));
        eprintln!("  [{name} done]");
    }
    body.push_str(&format!("[scale {}]\n", w.scale));
    report::emit("ablations.txt", &body).expect("save report");
}
