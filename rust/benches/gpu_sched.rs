//! `cargo bench --bench gpu_sched` — regenerates the GPU
//! schedule × granularity sweep: static vs work-aware vs stealing warp
//! scheduling across coarse/fine/segment granularities on the skewed
//! RMAT and star hot-row workloads (the schedule-aware GPU machine
//! model's headline figure).

use ktruss::bench_harness::{figs, report};

fn main() {
    let seg_len = std::env::var("KTRUSS_SEG_LEN")
        .ok()
        .and_then(|s| s.parse::<u32>().ok())
        .unwrap_or(ktruss::algo::support::DEFAULT_SEGMENT_LEN);
    println!("# gpu-sched: schedule x granularity sweep (seg_len {seg_len})");
    let sweep = figs::run_gpu_schedule_sweep(seg_len, |msg| eprintln!("  [{msg}]"))
        .expect("gpu schedule sweep");
    report::emit("gpu_schedule_sweep.txt", &sweep.render()).expect("save report");
}
