//! `cargo bench --bench fig3_cpu_mes` — regenerates Fig 3: ME/s of the
//! coarse and fine implementations on the CPU model at 48 threads, for
//! K=3 (top panel) and K=K_max (bottom panel).

use ktruss::bench_harness::{figs, report, Workload};

fn main() {
    let w = Workload::from_env().expect("workload config");
    println!("{}", w.banner("Fig 3 (CPU 48T ME/s, coarse vs fine)"));
    let mut body = String::new();
    for use_kmax in [false, true] {
        let p = figs::run_mes_panel(&w, figs::PanelDevice::Cpu48, use_kmax, |msg| {
            eprintln!("  [{msg}]")
        })
        .expect("fig3 run");
        body.push_str(&p.render());
        body.push('\n');
    }
    body.push_str(&format!(
        "(paper Fig 3 geomeans at full scale: 1.48x for K=3, 1.26x for K=Kmax)\n[scale {}]\n",
        w.scale
    ));
    report::emit("fig3_cpu_mes.txt", &body).expect("save report");
}
