//! Serving throughput sweep: open-loop skewed job arrivals against the
//! sharded executor at several shard counts (same total worker budget).
//! Reports jobs/s, p50/p99 serving latency, deadline-miss rate and
//! steal counts per shard count.
//!
//! Env knobs: `KTRUSS_SERVE_JOBS`, `KTRUSS_SERVE_ARRIVAL_US`,
//! `KTRUSS_SERVE_WORKERS`, `KTRUSS_SERVE_SHARDS` (comma list).

use anyhow::Result;
use ktruss::bench_harness::{report, serve_bench};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> Result<()> {
    let default = serve_bench::ThroughputConfig::default();
    let shard_counts = match std::env::var("KTRUSS_SERVE_SHARDS") {
        Ok(v) => v
            .split(',')
            .filter_map(|s| s.trim().parse::<usize>().ok())
            .filter(|&s| s > 0)
            .collect(),
        Err(_) => default.shard_counts.clone(),
    };
    let cfg = serve_bench::ThroughputConfig {
        jobs: env_usize("KTRUSS_SERVE_JOBS", default.jobs),
        arrival_us: env_usize("KTRUSS_SERVE_ARRIVAL_US", default.arrival_us as usize) as u64,
        total_workers: env_usize("KTRUSS_SERVE_WORKERS", default.total_workers),
        shard_counts,
        ..default
    };
    let r = serve_bench::run(&cfg, |msg| eprintln!("  [{msg}]"))?;
    report::emit("serve_throughput.txt", &r.render())
}
