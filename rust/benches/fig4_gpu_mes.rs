//! `cargo bench --bench fig4_gpu_mes` — regenerates Fig 4: ME/s of the
//! coarse and fine implementations on the GPU (V100) model, for K=3 and
//! K=K_max.

use ktruss::bench_harness::{figs, report, Workload};

fn main() {
    let w = Workload::from_env().expect("workload config");
    println!("{}", w.banner("Fig 4 (GPU ME/s, coarse vs fine)"));
    let mut body = String::new();
    for use_kmax in [false, true] {
        let p = figs::run_mes_panel(&w, figs::PanelDevice::Gpu, use_kmax, |msg| {
            eprintln!("  [{msg}]")
        })
        .expect("fig4 run");
        body.push_str(&p.render());
        body.push('\n');
    }
    body.push_str(&format!(
        "(paper Fig 4 geomeans at full scale: 16.93x for K=3, 9.97x for K=Kmax)\n[scale {}]\n",
        w.scale
    ));
    report::emit("fig4_gpu_mes.txt", &body).expect("save report");
}
