//! `cargo bench --bench plan_ablation` — the plan-ablation sweep: the
//! auto planner against every fixed plan across the testkit fixture
//! families, evaluated twice (the planner's predicted scores and a full
//! convergence-loop replay through the CPU machine model).
//!
//! Panics — and the CI smoke job fails — unless the auto plan is
//! within 1.05x of the best fixed plan (predicted) on every fixture
//! AND strictly beats the `static/coarse/full` baseline (simulated,
//! end to end) on every skewed fixture. Prints `plan-ablation-ok` when
//! both hold.

use ktruss::bench_harness::{plan_ablation, report};

fn main() {
    let report_data = plan_ablation::run(48, 3, |msg| eprintln!("  [{msg}]")).expect("sweep");
    let text = report_data.render();
    println!("{text}");
    assert!(
        report_data.auto_within_margin(),
        "auto plan exceeded {}x of the best fixed plan",
        plan_ablation::AUTO_MARGIN
    );
    assert!(
        report_data.auto_beats_static_coarse(),
        "auto plan failed to beat static-coarse on a skewed fixture"
    );
    println!("plan-ablation-ok");
    report::emit("plan_ablation.txt", &text).expect("write report");
}
