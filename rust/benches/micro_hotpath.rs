//! `cargo bench --bench micro_hotpath` — real-wallclock microbenchmarks
//! of the L3 hot path on this host (these are *not* simulated):
//!
//! * support kernel, sequential (ns/merge-step — the calibration value)
//! * support kernel via the worker pool (1/2/4 threads)
//! * prune pass
//! * full K=3 and K_max runs on a mid-size replica
//! * a cascade-heavy workload comparing the incremental frontier driver
//!   against full recompute (exact merge-step totals — the CI smoke
//!   asserts the reduction and this bench panics if it regresses)
//!
//! Pass `cascade` as the first bench argument
//! (`cargo bench --bench micro_hotpath -- cascade`) to run only the
//! cascade comparison, or `bitmap` to run only the hybrid
//! bitmap-representation smoke (both are what CI does).
//!
//! The §Perf log in EXPERIMENTS.md tracks these numbers across
//! optimization iterations.

use ktruss::algo::bitmap::{compute_supports_hybrid_seq, hybrid_tasks};
use ktruss::algo::incremental::SupportMode;
use ktruss::algo::kmax;
use ktruss::algo::ktruss::{ktruss as run_ktruss, ktruss_mode};
use ktruss::algo::support::{
    compute_supports_seq, compute_supports_segmented_seq, Granularity, Mode,
};
use ktruss::bench_harness::report;
use ktruss::cost::trace::trace_supports;
use ktruss::graph::ZCsr;
use ktruss::par::{compute_supports_par, Pool, Schedule, ALL_SCHEDULES};
use ktruss::plan::Planner;
use ktruss::util::stats::mean;
use ktruss::util::timer::bench_ms;
use ktruss::util::Rng;

/// Cascade-heavy workload: the deterministic serial peel chain (one or
/// two frontier edges per round for ~d/2 rounds — the worst case for
/// full recompute) plus a skewed AS-topology RMAT for a realistic mix.
/// Reports exact merge-step totals per support mode and **panics**
/// unless, on the peel chain, the incremental driver converges in ≥ 4
/// iterations, produces the identical truss, and does ≥ 3x fewer total
/// merge-steps than full recompute with auto never exceeding full —
/// the invariants the CI smoke step enforces.
fn cascade_section() -> String {
    let mut body = String::new();
    let chain = ktruss::testkit::graphs::peel_chain(48);
    let rmat = ktruss::gen::rmat::rmat(
        6000,
        45_000,
        ktruss::gen::rmat::RmatParams::autonomous_system(),
        &mut Rng::new(0xCA5C),
    );
    for (name, g, k, enforce) in
        [("peel-chain", &chain, 4u32, true), ("rmat-as", &rmat, 5u32, false)]
    {
        let full = ktruss_mode(g, k, Mode::Fine, SupportMode::Full);
        let inc = ktruss_mode(g, k, Mode::Fine, SupportMode::Incremental);
        let auto = ktruss_mode(g, k, Mode::Fine, SupportMode::Auto);
        assert_eq!(full.truss, inc.truss, "{name}: trusses must be identical");
        assert_eq!(full.truss, auto.truss, "{name}: trusses must be identical");
        let (fs, is, as_) = (
            full.total_support_steps(),
            inc.total_support_steps(),
            auto.total_support_steps(),
        );
        let reduction = fs as f64 / is.max(1) as f64;
        body.push_str(&format!(
            "cascade[{name}] k={k}: iterations={} full_steps={fs} incremental_steps={is} \
             auto_steps={as_} reduction={reduction:.2}x\n",
            full.iterations,
        ));
        if enforce {
            assert!(
                full.iterations >= 4,
                "{name}: cascade workload must take >= 4 iterations, got {}",
                full.iterations
            );
            assert!(
                reduction >= 3.0,
                "{name}: incremental must reduce merge-steps >= 3x, got {reduction:.2}x"
            );
            assert!(
                as_ <= fs,
                "{name}: auto must never exceed full recompute ({as_} vs {fs})"
            );
        }
    }
    body.push_str("cascade-ok\n");
    body
}

/// Hybrid bitmap-representation smoke: on the hub fixtures the hybrid
/// candidate (bitmap hub rows + tail-side chunks) must strictly beat
/// the pure-merge candidates in **simulated** GPU makespan — hybrid <
/// fine on both fixtures, and hybrid < segment on the comb, whose hub
/// is a heavy *partner* row and therefore actually gets encoded — while
/// reproducing the merge supports bit for bit. Also asserts the planner
/// in auto mode never picks a plan worse than 1.05x the best fixed
/// candidate (the sticky margin guarantees ~1.031x). These are the
/// invariants the CI smoke step enforces.
fn bitmap_section() -> String {
    let mut body = String::new();
    let comb = ktruss::testkit::graphs::hub_divergence_comb(64, 256, 800);
    let star = ktruss::testkit::graphs::star_with_fringe(1200);
    let planner = Planner::gpu();
    for (name, g) in [("hub-comb", &comb), ("star-fringe", &star)] {
        let z = ZCsr::from_csr(g);
        let ex = planner.explain(g, 3);
        let len = ex.seg_len;

        // exactness: the representation switch must not move a single count
        let mut want = Vec::new();
        compute_supports_seq(&z, &mut want);
        let mut got = Vec::new();
        compute_supports_hybrid_seq(&z, len, &mut got);
        assert_eq!(got, want, "{name}: hybrid supports must equal merge supports");

        // best simulated makespan per granularity, over every schedule
        let best = |gran: Granularity| -> f64 {
            ALL_SCHEDULES
                .iter()
                .map(|&sched| planner.predict_pass_ms(&z, gran, sched))
                .fold(f64::INFINITY, f64::min)
        };
        let fine = best(Granularity::Fine);
        let seg = best(Granularity::Segment { len });
        let hyb = best(Granularity::Hybrid { len });
        let probes = hybrid_tasks(&z, len).probe.len();
        body.push_str(&format!(
            "bitmap[{name}] len={len} probe_tasks={probes} sim_ms: \
             fine={fine:.4} segment={seg:.4} hybrid={hyb:.4}\n"
        ));
        assert!(
            hyb < fine,
            "{name}: hybrid ({hyb:.4}) must beat fine ({fine:.4}) in simulated makespan"
        );
        if name == "hub-comb" {
            assert!(
                probes > 0,
                "{name}: the hub partner row must be bitmap-encoded"
            );
            assert!(
                hyb < seg,
                "{name}: hybrid ({hyb:.4}) must beat segment ({seg:.4}) in simulated makespan"
            );
        }

        // plan-auto never regresses vs the best fixed candidate
        for (dev, ex) in [("gpu", ex), ("cpu", Planner::new(8).explain(g, 3))] {
            let best_fixed = ex
                .candidates
                .iter()
                .map(|c| c.predicted_ms)
                .fold(f64::INFINITY, f64::min);
            let chosen = ex.candidates[ex.chosen].predicted_ms;
            assert!(
                chosen <= best_fixed * 1.05,
                "{name}/{dev}: auto plan {chosen:.4} regresses > 1.05x vs best fixed {best_fixed:.4}"
            );
        }

        // wallclock flavor (small fixtures — sanity, not scaling claims)
        let mut s = Vec::new();
        let t_merge = mean(&bench_ms(1, 5, || compute_supports_seq(&z, &mut s))).unwrap();
        let t_seg =
            mean(&bench_ms(1, 5, || compute_supports_segmented_seq(&z, len, &mut s))).unwrap();
        let t_hyb =
            mean(&bench_ms(1, 5, || compute_supports_hybrid_seq(&z, len, &mut s))).unwrap();
        body.push_str(&format!(
            "bitmap[{name}] wallclock ms: merge={t_merge:.4} segment={t_seg:.4} hybrid={t_hyb:.4}\n"
        ));
    }
    body.push_str("bitmap-ok\n");
    body
}

fn main() {
    let cascade_only = std::env::args().any(|a| a == "cascade");
    if cascade_only {
        let body = cascade_section();
        print!("{body}");
        report::emit("micro_cascade.txt", &body).expect("save report");
        return;
    }
    let bitmap_only = std::env::args().any(|a| a == "bitmap");
    if bitmap_only {
        let body = bitmap_section();
        print!("{body}");
        report::emit("micro_bitmap.txt", &body).expect("save report");
        return;
    }
    let mut body = String::new();
    let g = ktruss::gen::rmat::rmat(
        20_000,
        150_000,
        ktruss::gen::rmat::RmatParams::social(),
        &mut Rng::new(0xBEEF),
    );
    let z = ZCsr::from_csr(&g);
    let mut s = Vec::new();
    let tr = trace_supports(&z, &mut s);
    body.push_str(&format!(
        "workload: rmat-social n={} m={} steps/pass={}\n\n",
        g.n(),
        g.nnz(),
        tr.total_steps
    ));

    // 0. the original (bounds-checked, match-based) kernel — §Perf "before"
    let times = bench_ms(2, 8, || {
        ktruss::algo::support::compute_supports_seq_checked(&z, &mut s)
    });
    let ms_before = mean(&times).unwrap();
    body.push_str(&format!(
        "support_seq_checked:{:8.3} ms/pass  ({:.3} ns/step)   [pre-optimization kernel]\n",
        ms_before,
        ms_before * 1e6 / tr.total_steps as f64
    ));

    // 1. sequential support kernel (optimized)
    let times = bench_ms(2, 8, || compute_supports_seq(&z, &mut s));
    let ms = mean(&times).unwrap();
    body.push_str(&format!(
        "support_seq:        {:8.3} ms/pass  ({:.3} ns/step)   [{:+.1}% vs checked]\n",
        ms,
        ms * 1e6 / tr.total_steps as f64,
        (ms / ms_before - 1.0) * 100.0
    ));

    // 2. pool variants (this host has few cores; numbers are for
    //    contention sanity, not scaling claims)
    for threads in [1usize, 2, 4] {
        let pool = Pool::new(threads);
        for mode in [Mode::Coarse, Mode::Fine] {
            let times = bench_ms(1, 4, || {
                compute_supports_par(&z, &pool, mode, Schedule::Dynamic { chunk: 1024 })
            });
            body.push_str(&format!(
                "support_pool[{threads}t,{mode}]: {:8.3} ms/pass\n",
                mean(&times).unwrap()
            ));
        }
    }

    // 3. prune pass
    let mut z2 = z.clone();
    let mut s2 = vec![0u32; z2.slots()];
    let times = bench_ms(2, 8, || {
        // re-fill supports so prune has real work each trial
        compute_supports_seq(&z2, &mut s2);
        ktruss::algo::prune::prune(&mut z2, &mut s2, 3)
    });
    body.push_str(&format!(
        "support+prune:      {:8.3} ms/iter\n",
        mean(&times).unwrap()
    ));

    // 4. end-to-end
    let times = bench_ms(1, 3, || run_ktruss(&g, 3, Mode::Fine));
    body.push_str(&format!("ktruss_k3:          {:8.3} ms\n", mean(&times).unwrap()));
    let times = bench_ms(0, 1, || kmax::kmax(&g));
    body.push_str(&format!("kmax_full:          {:8.3} ms\n", mean(&times).unwrap()));

    // 5. cascade workload: incremental vs full merge-step totals
    body.push('\n');
    body.push_str(&cascade_section());

    // 6. hybrid bitmap representation on the hub fixtures
    body.push('\n');
    body.push_str(&bitmap_section());

    report::emit("micro_hotpath.txt", &body).expect("save report");
}
