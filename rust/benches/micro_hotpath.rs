//! `cargo bench --bench micro_hotpath` — real-wallclock microbenchmarks
//! of the L3 hot path on this host (these are *not* simulated):
//!
//! * support kernel, sequential (ns/merge-step — the calibration value)
//! * support kernel via the worker pool (1/2/4 threads)
//! * prune pass
//! * full K=3 and K_max runs on a mid-size replica
//!
//! The §Perf log in EXPERIMENTS.md tracks these numbers across
//! optimization iterations.

use ktruss::algo::kmax;
use ktruss::algo::ktruss::ktruss as run_ktruss;
use ktruss::algo::support::{compute_supports_seq, Mode};
use ktruss::bench_harness::report;
use ktruss::cost::trace::trace_supports;
use ktruss::graph::ZCsr;
use ktruss::par::{compute_supports_par, Pool, Schedule};
use ktruss::util::stats::mean;
use ktruss::util::timer::bench_ms;
use ktruss::util::Rng;

fn main() {
    let mut body = String::new();
    let g = ktruss::gen::rmat::rmat(
        20_000,
        150_000,
        ktruss::gen::rmat::RmatParams::social(),
        &mut Rng::new(0xBEEF),
    );
    let z = ZCsr::from_csr(&g);
    let mut s = Vec::new();
    let tr = trace_supports(&z, &mut s);
    body.push_str(&format!(
        "workload: rmat-social n={} m={} steps/pass={}\n\n",
        g.n(),
        g.nnz(),
        tr.total_steps
    ));

    // 0. the original (bounds-checked, match-based) kernel — §Perf "before"
    let times = bench_ms(2, 8, || {
        ktruss::algo::support::compute_supports_seq_checked(&z, &mut s)
    });
    let ms_before = mean(&times).unwrap();
    body.push_str(&format!(
        "support_seq_checked:{:8.3} ms/pass  ({:.3} ns/step)   [pre-optimization kernel]\n",
        ms_before,
        ms_before * 1e6 / tr.total_steps as f64
    ));

    // 1. sequential support kernel (optimized)
    let times = bench_ms(2, 8, || compute_supports_seq(&z, &mut s));
    let ms = mean(&times).unwrap();
    body.push_str(&format!(
        "support_seq:        {:8.3} ms/pass  ({:.3} ns/step)   [{:+.1}% vs checked]\n",
        ms,
        ms * 1e6 / tr.total_steps as f64,
        (ms / ms_before - 1.0) * 100.0
    ));

    // 2. pool variants (this host has few cores; numbers are for
    //    contention sanity, not scaling claims)
    for threads in [1usize, 2, 4] {
        let pool = Pool::new(threads);
        for mode in [Mode::Coarse, Mode::Fine] {
            let times = bench_ms(1, 4, || {
                compute_supports_par(&z, &pool, mode, Schedule::Dynamic { chunk: 1024 })
            });
            body.push_str(&format!(
                "support_pool[{threads}t,{mode}]: {:8.3} ms/pass\n",
                mean(&times).unwrap()
            ));
        }
    }

    // 3. prune pass
    let mut z2 = z.clone();
    let mut s2 = vec![0u32; z2.slots()];
    let times = bench_ms(2, 8, || {
        // re-fill supports so prune has real work each trial
        compute_supports_seq(&z2, &mut s2);
        ktruss::algo::prune::prune(&mut z2, &mut s2, 3)
    });
    body.push_str(&format!(
        "support+prune:      {:8.3} ms/iter\n",
        mean(&times).unwrap()
    ));

    // 4. end-to-end
    let times = bench_ms(1, 3, || run_ktruss(&g, 3, Mode::Fine));
    body.push_str(&format!("ktruss_k3:          {:8.3} ms\n", mean(&times).unwrap()));
    let times = bench_ms(0, 1, || kmax::kmax(&g));
    body.push_str(&format!("kmax_full:          {:8.3} ms\n", mean(&times).unwrap()));

    report::emit("micro_hotpath.txt", &body).expect("save report");
}
