//! `ktruss` — launcher for the fine-grained Eager K-truss stack.
//!
//! Subcommands:
//!   run        compute a k-truss on a graph (sparse or dense engine)
//!   kmax       find the largest non-empty k
//!   decompose  full truss decomposition (trussness histogram)
//!   generate   materialize a SNAP-replica graph to a file
//!   suite      list the replica suite with structural stats
//!   bench      regenerate a paper table/figure (table1|fig2|fig3|fig4|ablations),
//!              the GPU schedule sweep (gpu-sched), the lockstep-lane backend
//!              study (lane), the serving throughput workload (serve), or the
//!              streaming maintenance workload (stream)
//!   serve      start the sharded executor and run a mixed-priority job stream
//!   mutate     replay an edge-mutation script against a versioned resident
//!              graph (one planned Mutate job per batch, epochs advance per
//!              batch, final differential verify against a scratch recompute)
//!   metrics    Prometheus-style exposition snapshot after a short demo stream
//!   plan       print the planner's per-candidate predicted costs and the
//!              chosen ExecutionPlan ("explain" mode)
//!   sim        estimate one graph on the calibrated machine models across the
//!              schedule x granularity grid
//!   calibrate  measure the host's merge-step cost for the CPU model
//!   info       runtime/artifact environment report

use anyhow::{bail, Context, Result};
use ktruss::algo::incremental::SupportMode;
use ktruss::algo::support::{Granularity, Mode, DEFAULT_SEGMENT_LEN};
// NB: import the function under a distinct name — importing the
// `algo::ktruss` *module* here would shadow the `ktruss` crate name.
use ktruss::algo::ktruss::ktruss_mode as ktruss_seq_mode;
use ktruss::algo::stream::EdgeBatch;
use ktruss::algo::{decompose, kmax};
use ktruss::bench_harness::{
    ablations, chaos_bench, figs, lane_bench, plan_ablation, report, serve_bench, stream_bench,
    table1, Workload,
};
use ktruss::cli::Args;
use ktruss::coordinator::JobKind;
use ktruss::cost::persist;
use ktruss::gen::suite;
use ktruss::graph::{io, stats, Csr};
use ktruss::par::{ktruss_par_plan, Pool, Schedule};
use ktruss::plan::{PlanSpec, Planner};
use ktruss::serve::{CostModel, Executor, GraphStore, Priority, ServeConfig, SubmitOpts};
use ktruss::sim::{simulate_ktruss_mode, SimConfig, GPU_SCHEDULES};
use ktruss::util::fmt::{speedup, Table};
use ktruss::util::Timer;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "help" {
        print_help();
        return;
    }
    let cmd = argv[0].clone();
    let args = match Args::parse(argv.into_iter().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(2);
        }
    };
    let result = match cmd.as_str() {
        "run" => cmd_run(&args),
        "kmax" => cmd_kmax(&args),
        "decompose" => cmd_decompose(&args),
        "generate" => cmd_generate(&args),
        "suite" => cmd_suite(&args),
        "bench" => cmd_bench(&args),
        "serve" => cmd_serve(&args),
        "mutate" => cmd_mutate(&args),
        "metrics" => cmd_metrics(&args),
        "plan" => cmd_plan(&args),
        "sim" => cmd_sim(&args),
        "calibrate" => cmd_calibrate(&args),
        "info" => cmd_info(&args),
        other => {
            eprintln!("unknown command {other:?}\n");
            print_help();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "ktruss — fine-grained parallel Eager K-truss (HPEC'19 reproduction)\n\n\
         USAGE: ktruss <command> [flags]\n\n\
         COMMANDS\n\
           run        --graph <name|path> [--k 3] [--mode fine|coarse] [--par N] [--engine sparse|dense]\n\
                      [--device cpu|gpu] [--plan auto|<schedule>/<granularity>/<support>]\n\
                      [--granularity coarse|fine|segment[:len]|hybrid[:len]]\n\
                      [--schedule static|dynamic[:chunk]|workaware|stealing]\n\
                      [--support-mode full|incremental|auto]\n\
                      [--shards N] [--priority high|normal|low] [--deadline-ms D]\n\
                      [--trace-out spans.json|.jsonl]\n\
                      (pooled runs execute one cost-driven ExecutionPlan: --plan pins\n\
                      or frees all axes at once, the per-axis flags pin single axes,\n\
                      anything unpinned is chosen by the planner per graph;\n\
                      --device gpu scores on the GPU machine model and executes the\n\
                      plan on the lockstep-lane backend in-process;\n\
                      --shards > 1 serves the job through the sharded executor;\n\
                      --granularity segment runs the ultra-fine pooled kernel,\n\
                      hybrid adds bitmap-encoded hub partner rows + tail chunks)\n\
           kmax       --graph <name|path>\n\
           decompose  --graph <name|path>\n\
           generate   --graph <name> [--scale 1.0] [--out file.tsv] [--format tsv|bin]\n\
           suite      [--scale 0.15] [--stats]\n\
           bench      <table1|fig2|fig3|fig4|ablations> [--k 3] (env: KTRUSS_SUITE, KTRUSS_SCALE)\n\
           bench gpu-sched [--seg-len 64]  (GPU schedule x granularity sweep)\n\
           bench lane [--workers 4]  (lockstep-lane backend study: lane vs pool walls,\n\
                      fused vs separate frontier steps, calibrated model-vs-executed band)\n\
           bench plan [--threads 48] [--k 3]  (auto plan vs every fixed plan ablation)\n\
           bench serve [--jobs 120] [--arrival-us 300] [--workers 4] [--shard-counts 1,2,4]\n\
           bench stream [--depth 10] [--batches 12] [--k 4] [--workers 3] [--shards 1]\n\
                      [--trace-out spans.json]  (streaming maintenance: churn-chain replay\n\
                      with merge-step accounting vs from-scratch, then the same script served\n\
                      as planned Mutate jobs with pinned-epoch reads)\n\
           bench chaos [--jobs 48] [--heavy 6] [--heavy-n 700] [--arrival-us 400]\n\
                      [--workers 2] [--shards 2] [--seed 42] [--fault-seed 42] [--retry-max 3]\n\
                      (overload/recovery study under seeded fault injection: fault-free\n\
                      reference, then the same burst with admission control off vs on;\n\
                      verifies every job reaches one terminal outcome and done results\n\
                      match the reference bit-for-bit)\n\
           serve      [--jobs 32] [--shards 2] [--pool 4] [--plan <spec>] [--schedule <s>]\n\
                      [--priority <p>] [--support-mode full|incremental|auto]\n\
                      [--deadline-ms D] [--calibration file.tsv]\n\
                      [--max-queue N] [--shed] [--chaos SEED]\n\
                      [--trace-out spans.json|.jsonl]\n\
                      (demo job stream through the sharded executor; --pool is the TOTAL worker\n\
                      budget split across shards; unpinned plan axes are chosen per job at\n\
                      submit time; without --priority the stream mixes priority classes;\n\
                      --trace-out dumps the job -> pass span tree as Chrome trace JSON or\n\
                      JSONL, and the drift report prints per executed-plan regime;\n\
                      --max-queue bounds admission with backpressure, --shed turns on\n\
                      deadline-aware shedding + cancellation, --chaos injects seeded faults)\n\
           mutate     [--graph <name|path>] [--k 4] [--shards 1] [--pool 2] [--plan <spec>]\n\
                      [--mutations churn[:batches[:depth]] | \"+u:v,-u:v;…\"]\n\
                      [--trace-out spans.json|.jsonl]\n\
                      (batched edge mutations against a versioned resident graph: each batch\n\
                      is one planned Mutate job through the executor, serialized because\n\
                      batches are order-dependent; epochs advance per batch and the\n\
                      maintained truss is verified against a scratch recompute at the end;\n\
                      churn generates its own fixture graph + script, the inline form needs\n\
                      --graph and applies deletes before inserts within a batch)\n\
           metrics    [--jobs 12] [--shards 2] [--pool 4] [--calibration file.tsv]\n\
                      (Prometheus-style text exposition snapshot: runs a short demo stream\n\
                      and prints serving counters, latency buckets and plan-drift gauges;\n\
                      --calibration seeds the cost model and drift baselines first)\n\
           plan       [--graph <name|path>] [--k 3] [--par 48] [--device cpu|gpu] [--plan <spec>]\n\
                      (explain mode: per-candidate predicted costs and the chosen plan;\n\
                      without --graph, sweeps a demo set of generator families)\n\
           sim        --graph <name|path> [--k 3] [--granularity <g>|all]\n\
                      [--gpu-schedule static|work-aware|stealing|all] [--cpu-threads N]\n\
                      [--support-mode full|incremental|auto]\n\
                      (timing estimates on the calibrated V100 model; static is always\n\
                      included as the speedup baseline; --cpu-threads adds CPU rows;\n\
                      --support-mode replays the incremental driver's kernel launches)\n\
           calibrate\n\
           info\n\n\
         GRAPH SOURCES: a SNAP suite name (e.g. ca-GrQc, see `ktruss suite`) generates the\n\
         replica at --scale (default 0.15); a path loads a TSV edge list or .bin cache."
    )
}

/// Resolve `--graph` to a loaded CSR.
fn load_graph(args: &Args) -> Result<Csr> {
    let src = args
        .opt("graph")
        .context("--graph <suite-name|path> is required")?;
    if let Some(spec) = suite::by_name(&src) {
        let scale = args.get_as::<f64>("scale", 0.15)?;
        return suite::load(spec, scale);
    }
    let path = std::path::Path::new(&src);
    if !path.exists() {
        bail!("{src:?} is neither a suite graph nor a file (see `ktruss suite`)");
    }
    if src.ends_with(".bin") {
        io::read_binary_file(path)
    } else {
        io::read_edge_list_file(path)
    }
}

fn parse_mode(args: &Args) -> Result<Mode> {
    match args.get("mode", "fine").as_str() {
        "fine" => Ok(Mode::Fine),
        "coarse" => Ok(Mode::Coarse),
        other => bail!("--mode must be fine|coarse, got {other:?}"),
    }
}

/// Parse the plan-axis flags into one [`PlanSpec`]: `--plan` sets the
/// base spec, the per-axis flags (`--schedule`, `--granularity`,
/// `--support-mode`) pin single axes on top of it.
fn parse_plan_spec(args: &Args) -> Result<PlanSpec> {
    let mut spec: PlanSpec = match args.opt("plan") {
        Some(s) => s.parse().map_err(|e| anyhow::anyhow!("--plan: {e}"))?,
        None => PlanSpec::auto(),
    };
    if let Some(s) = args.opt("schedule") {
        spec.schedule = Some(s.parse::<Schedule>().map_err(|e| anyhow::anyhow!("--schedule: {e}"))?);
    }
    if let Some(s) = args.opt("granularity") {
        spec.granularity =
            Some(s.parse::<Granularity>().map_err(|e| anyhow::anyhow!("--granularity: {e}"))?);
    }
    if let Some(s) = args.opt("support-mode") {
        spec.support =
            Some(s.parse::<SupportMode>().map_err(|e| anyhow::anyhow!("--support-mode: {e}"))?);
    }
    Ok(spec)
}

fn cmd_run(args: &Args) -> Result<()> {
    let g = load_graph(args)?;
    let k = args.get_as::<u32>("k", 3)?;
    let mode_flag = args.opt("mode");
    let mode = parse_mode(args)?;
    let mut spec = parse_plan_spec(args)?;
    // an explicit --mode is a granularity pin (unless --granularity or
    // --plan already pinned one) — the historical coarse/fine selector
    // must keep steering the pooled path, not be silently out-planned
    if spec.granularity.is_none() && mode_flag.is_some() {
        spec.granularity = Some(mode.into());
    }
    let par = args.get_as::<usize>("par", 1)?;
    let engine_flag = args.opt("engine");
    let engine = engine_flag.clone().unwrap_or_else(|| "sparse".to_string());
    let gpu_device = match args.get("device", "cpu").as_str() {
        "cpu" => false,
        "gpu" => true,
        other => bail!("--device must be cpu|gpu, got {other:?}"),
    };
    let shards = args.get_as::<usize>("shards", 1)?;
    let priority: Priority = args
        .get("priority", "normal")
        .parse()
        .map_err(|e| anyhow::anyhow!("--priority: {e}"))?;
    let deadline_ms = args.get_as::<u64>("deadline-ms", 0)?;
    let trace_out = args.opt("trace-out");
    args.reject_unknown()?;
    let seg_requested = matches!(
        spec.granularity,
        Some(Granularity::Segment { .. }) | Some(Granularity::Hybrid { .. })
    );
    if seg_requested {
        if shards > 1 {
            bail!("segment/hybrid granularity runs the pooled sparse kernel; drop --shards");
        }
        if engine == "dense" {
            bail!("segment/hybrid granularity requires --engine sparse");
        }
    }
    if gpu_device {
        // the lane backend executes in-process under a GPU-scored plan
        if engine == "dense" {
            bail!("--device gpu runs the lockstep-lane sparse backend; drop --engine dense");
        }
        if shards > 1 {
            bail!("--device gpu runs in-process (no executor routing); drop --shards");
        }
    }
    if shards > 1 {
        // serve the single job through the sharded executor (exercises
        // admission, submit-time planning and the serving metrics)
        if engine_flag.is_some() {
            eprintln!("note: --engine is ignored with --shards; the executor routes per job");
        }
        println!("graph: {}", stats::stats(&g));
        let ex = Executor::start(
            ServeConfig { shards, plan: spec, ..Default::default() }.with_total_workers(par),
        );
        let t = Timer::start();
        let ticket = ex.submit_with(
            Arc::new(g),
            JobKind::Ktruss { k, mode },
            SubmitOpts {
                priority,
                deadline: (deadline_ms > 0).then(|| Duration::from_millis(deadline_ms)),
                degrade_store: None,
            },
        );
        let r = ticket.wait();
        let wall = t.elapsed_ms();
        let plan = r
            .plan
            .map(|p| p.to_string())
            .unwrap_or_else(|| "none".to_string());
        match r.output.map_err(|e| anyhow::anyhow!("{e}"))? {
            ktruss::coordinator::JobOutput::Ktruss { truss_edges, iterations, .. } => {
                println!(
                    "{k}-truss: {truss_edges} edges survive, {iterations} iterations, \
                     {wall:.3} ms [{} via {shards}-shard executor, plan={plan}, priority={priority}]",
                    r.engine
                );
            }
            other => bail!("unexpected output {other:?}"),
        }
        println!("metrics: {}", ex.metrics.render());
        if let Some(path) = &trace_out {
            let spans = ex.obs.spans.snapshot();
            ktruss::obs::export::write_trace(std::path::Path::new(path), &spans)?;
            println!("trace: wrote {} job span(s) to {path}", spans.len());
            let drift = ex.obs.drift.render();
            if !drift.is_empty() {
                println!("{drift}");
            }
        }
        ex.shutdown();
        return Ok(());
    }
    if spec.schedule.is_some() && (engine != "sparse" || par <= 1) && !seg_requested && !gpu_device
    {
        eprintln!(
            "note: --schedule only affects the sparse pool engine; add --par <N> (N > 1) to use it"
        );
    }
    println!("graph: {}", stats::stats(&g));
    // executed plan + per-iteration stats captured for --trace-out
    // (the dense engine reports no per-pass stats: empty span tree)
    let mut span_plan: Option<ktruss::plan::ExecutionPlan> = None;
    let mut span_stats: Vec<ktruss::algo::ktruss::IterationStat> = Vec::new();
    let t = Timer::start();
    let (edges, iterations, engine_used) = match engine.as_str() {
        "dense" => {
            let eng = ktruss::runtime::DenseEngine::new()?;
            let (truss, iters) = eng.ktruss(&g, k)?;
            (truss.nnz(), iters, "dense-xla (AOT jax/Pallas via PJRT)".to_string())
        }
        "sparse" if par > 1 || seg_requested || gpu_device => {
            // pooled path: one cost-driven plan (pinned axes honored,
            // the rest chosen by the planner for this graph). With
            // --device gpu the planner scores on the GPU machine model
            // and ktruss_par_plan dispatches to the lockstep-lane
            // backend (crate::exec::lane).
            let pool = Pool::new(par.max(1));
            let planner =
                if gpu_device { Planner::gpu() } else { Planner::new(pool.workers()) };
            let plan = planner.with_spec(spec).choose(&g, k);
            let r = ktruss_par_plan(&g, k, &pool, &plan);
            span_plan = Some(plan);
            let backend = if gpu_device {
                format!("lane backend (lockstep warps over {} workers, plan={plan})", pool.workers())
            } else {
                format!("sparse-cpu (pool, plan={plan})")
            };
            let out = (r.truss.nnz(), r.iterations, backend);
            span_stats = r.stats;
            out
        }
        "sparse" => {
            // sequential reference path: no schedule axis to plan; the
            // support mode (pinned or the auto default) still applies
            let support = spec.support.unwrap_or(SupportMode::Auto);
            let seq_mode = spec.granularity.and_then(|gr| gr.mode()).unwrap_or(mode);
            let r = ktruss_seq_mode(&g, k, seq_mode, support);
            let inc_iters = r.stats.iter().filter(|s| s.incremental).count();
            let out = (
                r.truss.nnz(),
                r.iterations,
                format!(
                    "sparse-cpu (sequential, support={support}, {inc_iters} incremental iterations, {} total steps)",
                    r.total_support_steps()
                ),
            );
            span_stats = r.stats;
            out
        }
        other => bail!("--engine must be sparse|dense, got {other:?}"),
    };
    let wall_ms = t.elapsed_ms();
    println!(
        "{k}-truss: {edges} edges survive ({} removed), {iterations} iterations, {wall_ms:.3} ms [{engine_used}, mode={mode}]",
        g.nnz() - edges,
    );
    if let Some(path) = &trace_out {
        let span = local_job_span(&g, "ktruss", span_plan, wall_ms, &span_stats);
        ktruss::obs::export::write_trace(std::path::Path::new(path), &[span])?;
        println!("trace: wrote 1 job span to {path}");
    }
    Ok(())
}

/// A [`JobSpan`](ktruss::obs::span::JobSpan) for a CLI-local (not
/// executor-served) run: no admission segment, so the queue wait and
/// the cost-model prediction fields stay zero; the pass tree still
/// carries the drivers' exact per-iteration steps.
fn local_job_span(
    g: &Csr,
    kind: &str,
    plan: Option<ktruss::plan::ExecutionPlan>,
    wall_ms: f64,
    stats: &[ktruss::algo::ktruss::IterationStat],
) -> ktruss::obs::span::JobSpan {
    let passes = ktruss::obs::span::passes_from_stats(stats);
    ktruss::obs::span::JobSpan {
        id: 0,
        kind: kind.to_string(),
        n: g.n(),
        m: g.nnz(),
        shard: 0,
        schedule: plan.map(|p| p.schedule.to_string()).unwrap_or_else(|| "-".to_string()),
        granularity: plan.map(|p| p.granularity.to_string()).unwrap_or_else(|| "-".to_string()),
        support: plan.map(|p| p.support.to_string()).unwrap_or_else(|| "-".to_string()),
        device: plan.map(|p| p.device.to_string()).unwrap_or_else(|| "-".to_string()),
        est_steps: 0,
        total_steps: passes.iter().map(|p| p.steps).sum(),
        predicted_ms: 0.0,
        planned_pass_ms: None,
        queue_ms: 0.0,
        exec_ms: wall_ms,
        serve_ms: wall_ms,
        deadline_ms: None,
        deadline_missed: false,
        start_us: 0,
        ok: true,
        outcome: "done".to_string(),
        passes,
    }
}

/// `plan`: print the planner's per-candidate predicted costs and the
/// chosen `ExecutionPlan` — for one `--graph`, or for a demo sweep of
/// generator families when no graph is given.
fn cmd_plan(args: &Args) -> Result<()> {
    let k = args.get_as::<u32>("k", 3)?;
    let threads = args.get_as::<usize>("par", 48)?;
    let device = args.get("device", "cpu");
    let spec = parse_plan_spec(args)?;
    let planner = match device.as_str() {
        "cpu" => Planner::new(threads),
        "gpu" => Planner::gpu(),
        other => bail!("--device must be cpu|gpu, got {other:?}"),
    }
    .with_spec(spec);
    let has_graph = args.opt("graph").is_some();
    // consume --scale even when no graph is given (load_graph reads it)
    let _ = args.get_as::<f64>("scale", 0.15)?;
    if has_graph {
        let g = load_graph(args)?;
        args.reject_unknown()?;
        println!("graph: {}", stats::stats(&g));
        println!("{}", planner.explain(&g, k).render());
        return Ok(());
    }
    args.reject_unknown()?;
    // demo sweep: one explain table per generator family, so the
    // structural flip (coarse on flat, fine/segment + cost-aware
    // schedules on hubs) is visible side by side
    let mut rng = ktruss::util::Rng::new(7);
    let demos: Vec<(&str, Csr)> = vec![
        (
            "rmat-social",
            ktruss::gen::rmat::rmat(2000, 12_000, ktruss::gen::rmat::RmatParams::social(), &mut rng),
        ),
        (
            "rmat-as-hub",
            ktruss::gen::rmat::rmat(
                3000,
                15_000,
                ktruss::gen::rmat::RmatParams::autonomous_system(),
                &mut rng,
            ),
        ),
        ("road-grid", ktruss::gen::grid::road(3000, 5800, 0.05, &mut rng)),
        ("star-fringe", ktruss::testkit::graphs::star_with_fringe(1200)),
        ("hub-comb", ktruss::testkit::graphs::hub_divergence_comb(64, 256, 800)),
    ];
    println!(
        "# plan: per-candidate predicted costs over {} generator families (k={k}, {device} model, {threads} threads)",
        demos.len()
    );
    for (name, g) in &demos {
        println!("## {name}: {}", stats::stats(g));
        println!("{}", planner.explain(g, k).render());
    }
    Ok(())
}

fn cmd_kmax(args: &Args) -> Result<()> {
    let g = load_graph(args)?;
    args.reject_unknown()?;
    println!("graph: {}", stats::stats(&g));
    let t = Timer::start();
    let r = kmax::kmax(&g);
    println!(
        "kmax = {} ({} edges in the {}-truss), {} total iterations, {:.3} ms",
        r.kmax,
        r.truss.nnz(),
        r.kmax,
        r.total_iterations,
        t.elapsed_ms()
    );
    Ok(())
}

fn cmd_decompose(args: &Args) -> Result<()> {
    let g = load_graph(args)?;
    args.reject_unknown()?;
    let t = Timer::start();
    let d = decompose::decompose(&g);
    println!("kmax = {}, {:.3} ms", d.kmax, t.elapsed_ms());
    println!("trussness histogram (k: edges with trussness exactly k):");
    for (k, count) in d.histogram() {
        println!("  {k:>4}: {count}");
    }
    Ok(())
}

fn cmd_generate(args: &Args) -> Result<()> {
    let name = args.opt("graph").context("--graph <suite-name> required")?;
    let spec = suite::by_name(&name).with_context(|| format!("unknown suite graph {name:?}"))?;
    let scale = args.get_as::<f64>("scale", 1.0)?;
    let out = args.get("out", &format!("{name}.tsv"));
    let format = args.get("format", if out.ends_with(".bin") { "bin" } else { "tsv" });
    args.reject_unknown()?;
    let t = Timer::start();
    let g = suite::generate(spec, scale);
    match format.as_str() {
        "tsv" => io::write_edge_list(&g, std::fs::File::create(&out)?)?,
        "bin" => io::write_binary_file(&g, &out)?,
        other => bail!("--format must be tsv|bin, got {other:?}"),
    }
    println!(
        "wrote {out}: {} ({} family, scale {scale}, {:.1} ms)",
        stats::stats(&g),
        format_args!("{:?}", spec.family),
        t.elapsed_ms()
    );
    Ok(())
}

fn cmd_suite(args: &Args) -> Result<()> {
    let show_stats = args.has("stats");
    let scale = args.get_as::<f64>("scale", 0.15)?;
    args.reject_unknown()?;
    println!("{} Table-I replica graphs (paper sizes; generated at --scale):", suite::SUITE.len());
    for spec in suite::SUITE {
        if show_stats {
            let g = suite::load(spec, scale)?;
            println!("  {:22} {:?}: {}", spec.name, spec.family, stats::stats(&g));
        } else {
            println!(
                "  {:22} {:?}: |V|={} |E|={}",
                spec.name, spec.family, spec.vertices, spec.edges
            );
        }
    }
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    let which = args
        .positional
        .first()
        .context(
            "bench needs a target: table1|fig2|fig3|fig4|ablations|gpu-sched|lane|serve|stream|chaos|plan",
        )?
        .clone();
    if which == "lane" {
        let workers = args.get_as::<usize>("workers", 4)?;
        args.reject_unknown()?;
        println!("# lane: lockstep-lane backend study ({workers} workers)");
        let r = lane_bench::run(workers, |msg| eprintln!("  [{msg}]"))?;
        let rendered = r.render();
        report::emit("lane_backend.txt", &rendered)?;
        if let Err(e) = r.verify() {
            anyhow::bail!("lane invariant violated: {e}");
        }
        return Ok(());
    }
    if which == "serve" {
        return cmd_bench_serve(args);
    }
    if which == "chaos" {
        return cmd_bench_chaos(args);
    }
    if which == "stream" {
        return cmd_bench_stream(args);
    }
    if which == "plan" {
        // the plan ablation generates its own fixture families (skewed
        // + flat); the replica suite is not involved
        let threads = args.get_as::<usize>("threads", 48)?;
        let k = args.get_as::<u32>("k", 3)?;
        args.reject_unknown()?;
        println!("# plan: auto plan vs every fixed plan (CPU model, {threads} threads, k={k})");
        let r = plan_ablation::run(threads, k, |msg| eprintln!("  [{msg}]"))?;
        if !r.auto_within_margin() || !r.auto_beats_static_coarse() {
            eprintln!("warning: plan-ablation invariants failed (see report)");
        }
        return report::emit("plan_ablation.txt", &r.render());
    }
    if which == "gpu-sched" {
        // the sweep generates its own adversarial graphs (skewed RMAT +
        // star hot-row); the replica suite is not involved
        let seg_len = args.get_as::<u32>("seg-len", DEFAULT_SEGMENT_LEN)?;
        args.reject_unknown()?;
        println!("# gpu-sched: GPU schedule x granularity sweep (seg_len {seg_len})");
        let sweep = figs::run_gpu_schedule_sweep(seg_len, |msg| eprintln!("  [{msg}]"))?;
        return report::emit("gpu_schedule_sweep.txt", &sweep.render());
    }
    let k = args.get_as::<u32>("k", 3)?;
    args.reject_unknown()?;
    let w = Workload::from_env()?;
    println!("{}", w.banner(&which));
    match which.as_str() {
        "table1" => {
            let t = table1::run(&w, k, |msg| eprintln!("  [{msg}]"))?;
            report::emit("table1.txt", &t.render())?;
        }
        "fig2" => {
            let f = figs::run_fig2(&w, |msg| eprintln!("  [{msg}]"))?;
            report::emit("fig2_thread_scaling.txt", &f.render())?;
        }
        "fig3" | "fig4" => {
            let dev = if which == "fig3" { figs::PanelDevice::Cpu48 } else { figs::PanelDevice::Gpu };
            let mut out = String::new();
            for use_kmax in [false, true] {
                let p = figs::run_mes_panel(&w, dev, use_kmax, |msg| eprintln!("  [{msg}]"))?;
                out.push_str(&p.render());
                out.push('\n');
            }
            report::emit(&format!("{which}_mes.txt"), &out)?;
        }
        "ablations" => {
            let out = run_ablations(&w)?;
            report::emit("ablations.txt", &out)?;
        }
        other => bail!("unknown bench target {other:?}"),
    }
    Ok(())
}

/// The serving throughput workload (no replica suite involved: the job
/// stream is generated directly, see `bench_harness::serve_bench`).
fn cmd_bench_serve(args: &Args) -> Result<()> {
    let default = serve_bench::ThroughputConfig::default();
    let shard_counts: Vec<usize> = args
        .get("shard-counts", "1,2,4")
        .split(',')
        .map(|s| {
            s.trim()
                .parse::<usize>()
                .map_err(|_| anyhow::anyhow!("--shard-counts: bad entry {s:?}"))
        })
        .collect::<Result<_>>()?;
    let cfg = serve_bench::ThroughputConfig {
        jobs: args.get_as::<usize>("jobs", default.jobs)?,
        arrival_us: args.get_as::<u64>("arrival-us", default.arrival_us)?,
        total_workers: args.get_as::<usize>("workers", default.total_workers)?,
        shard_counts,
        deadline_ms: args.get_as::<u64>("deadline-ms", default.deadline_ms)?,
        seed: args.get_as::<u64>("seed", default.seed)?,
    };
    args.reject_unknown()?;
    println!(
        "# serve: {} jobs, shard counts {:?}, {} total workers",
        cfg.jobs, cfg.shard_counts, cfg.total_workers
    );
    let r = serve_bench::run(&cfg, |msg| eprintln!("  [{msg}]"))?;
    report::emit("serve_throughput.txt", &r.render())
}

/// The chaos overload/recovery study (seeded fault injection over a
/// head-of-line burst, shedding off vs on; see
/// `bench_harness::chaos_bench`).
fn cmd_bench_chaos(args: &Args) -> Result<()> {
    let default = chaos_bench::ChaosConfig::default();
    let cfg = chaos_bench::ChaosConfig {
        jobs: args.get_as::<usize>("jobs", default.jobs)?,
        heavy: args.get_as::<usize>("heavy", default.heavy)?,
        heavy_n: args.get_as::<usize>("heavy-n", default.heavy_n)?,
        arrival_us: args.get_as::<u64>("arrival-us", default.arrival_us)?,
        total_workers: args.get_as::<usize>("workers", default.total_workers)?,
        shards: args.get_as::<usize>("shards", default.shards)?,
        seed: args.get_as::<u64>("seed", default.seed)?,
        faults: ktruss::serve::FaultPlan {
            seed: args.get_as::<u64>("fault-seed", default.faults.seed)?,
            ..default.faults
        },
        retry_max: args.get_as::<u32>("retry-max", default.retry_max)?,
    };
    args.reject_unknown()?;
    println!(
        "# chaos: {} stream jobs + {} heavy head-of-line jobs, {} shard(s), seeded faults",
        cfg.jobs, cfg.heavy, cfg.shards
    );
    let r = chaos_bench::run(&cfg, |msg| eprintln!("  [{msg}]"))?;
    let rendered = r.render();
    report::emit("chaos_recovery.txt", &rendered)?;
    if let Err(e) = r.verify() {
        anyhow::bail!("chaos invariant violated: {e}");
    }
    Ok(())
}

/// The streaming maintenance workload (churn-chain differential replay
/// with merge-step accounting, then the executor-served epoch run; see
/// `bench_harness::stream_bench`).
fn cmd_bench_stream(args: &Args) -> Result<()> {
    let default = stream_bench::StreamConfig::default();
    let cfg = stream_bench::StreamConfig {
        depth: args.get_as::<usize>("depth", default.depth)?,
        batches: args.get_as::<usize>("batches", default.batches)?,
        k: args.get_as::<u32>("k", default.k)?,
        shards: args.get_as::<usize>("shards", default.shards)?,
        total_workers: args.get_as::<usize>("workers", default.total_workers)?,
        trace_out: args.opt("trace-out"),
    };
    args.reject_unknown()?;
    println!(
        "# stream: {} churn batches over peel_chain({}), k={}, {} worker(s)",
        cfg.batches, cfg.depth, cfg.k, cfg.total_workers
    );
    let r = stream_bench::run(&cfg, |msg| eprintln!("  [{msg}]"))?;
    report::emit("stream_maintenance.txt", &r.render())
}

/// Parse an inline mutation script: batches separated by `;`, ops by
/// `,`; each op is `+u:v` (insert) or `-u:v` (delete).
fn parse_mutation_script(src: &str) -> Result<Vec<EdgeBatch>> {
    let mut script = Vec::new();
    for part in src.split(';') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let mut batch = EdgeBatch::default();
        for op in part.split(',') {
            let op = op.trim();
            let rest = op
                .strip_prefix('+')
                .or_else(|| op.strip_prefix('-'))
                .with_context(|| format!("mutation op {op:?} must start with + or -"))?;
            let (u, v) = rest
                .split_once(':')
                .with_context(|| format!("mutation op {op:?} must be +u:v or -u:v"))?;
            let edge = (
                u.trim().parse::<u32>().with_context(|| format!("bad vertex in {op:?}"))?,
                v.trim().parse::<u32>().with_context(|| format!("bad vertex in {op:?}"))?,
            );
            if op.starts_with('+') {
                batch.insert.push(edge);
            } else {
                batch.delete.push(edge);
            }
        }
        script.push(batch);
    }
    if script.is_empty() {
        bail!("--mutations script is empty");
    }
    Ok(script)
}

/// `mutate`: replay an edge-mutation script against a versioned
/// resident [`GraphStore`] through the sharded executor — one planned
/// `Mutate` job per batch, strictly serialized (batches are
/// order-dependent), with a final differential verify against a
/// from-scratch recompute.
fn cmd_mutate(args: &Args) -> Result<()> {
    let k = args.get_as::<u32>("k", 4)?;
    let shards = args.get_as::<usize>("shards", 1)?.max(1);
    let pool = args.get_as::<usize>("pool", 2)?;
    let spec = parse_plan_spec(args)?;
    let mutations = args.get("mutations", "churn");
    let trace_out = args.opt("trace-out");
    let (g, script) = if let Some(rest) = mutations.strip_prefix("churn") {
        // churn[:batches[:depth]] — the deterministic fixture script
        let mut batches = 8usize;
        let mut depth = 8usize;
        if let Some(params) = rest.strip_prefix(':') {
            let mut it = params.split(':');
            if let Some(b) = it.next() {
                batches = b
                    .parse()
                    .map_err(|_| anyhow::anyhow!("--mutations churn: bad batches {b:?}"))?;
            }
            if let Some(d) = it.next() {
                depth = d
                    .parse()
                    .map_err(|_| anyhow::anyhow!("--mutations churn: bad depth {d:?}"))?;
            }
        } else if !rest.is_empty() {
            bail!("--mutations must be churn[:batches[:depth]] or an inline +u:v,-u:v;… script");
        }
        if depth < 4 {
            bail!("--mutations churn needs depth >= 4");
        }
        if args.opt("graph").is_some() {
            eprintln!("note: --mutations churn generates its own graph; --graph is ignored");
        }
        ktruss::testkit::graphs::churn_chain(depth, batches)
    } else {
        (load_graph(args)?, parse_mutation_script(&mutations)?)
    };
    args.reject_unknown()?;
    println!("graph: {}", stats::stats(&g));
    let store = Arc::new(GraphStore::new(&g, k));
    println!(
        "store: epoch 0, k={k}, {} truss edges; applying {} batch(es)…",
        store.pin().truss.nnz(),
        script.len()
    );
    let ex = Executor::start(
        ServeConfig { shards, plan: spec, enable_dense: false, ..Default::default() }
            .with_total_workers(pool),
    );
    let t = Timer::start();
    for (i, batch) in script.iter().enumerate() {
        let pinned = store.pin();
        let ticket = ex.submit(
            pinned.graph.clone(),
            JobKind::Mutate { store: Arc::clone(&store), batch: Arc::new(batch.clone()) },
        );
        // serialize: the next batch may depend on this one's edges
        let r = ticket.wait();
        let plan = r.plan.map(|p| p.to_string()).unwrap_or_else(|| "none".to_string());
        match r.output.map_err(|e| anyhow::anyhow!("batch {i}: {e}"))? {
            ktruss::coordinator::JobOutput::Mutate {
                epoch,
                inserted,
                deleted,
                rejected,
                recomputed,
                truss_edges,
            } => {
                println!(
                    "batch {i}: epoch {epoch}, +{inserted}/-{deleted} (rejected {rejected}), \
                     truss {truss_edges} edges [{}, plan={plan}]",
                    if recomputed { "reconverged" } else { "fast-path" }
                );
            }
            other => bail!("unexpected output {other:?}"),
        }
    }
    let wall = t.elapsed_ms();
    let snap = store.pin();
    let scratch = ktruss_seq_mode(&snap.graph, k, Mode::Fine, SupportMode::Full);
    if *snap.truss != scratch.truss {
        bail!("maintained truss diverged from the from-scratch recompute");
    }
    println!(
        "verify: maintained {k}-truss matches scratch recompute ({} edges @ epoch {}), \
         {wall:.2} ms total",
        scratch.truss.nnz(),
        snap.epoch
    );
    println!("metrics: {}", ex.metrics.render());
    if let Some(path) = &trace_out {
        let spans = ex.obs.spans.snapshot();
        ktruss::obs::export::write_trace(std::path::Path::new(path), &spans)?;
        println!("trace: wrote {} job span(s) to {path}", spans.len());
    }
    ex.shutdown();
    Ok(())
}

fn run_ablations(w: &Workload) -> Result<String> {
    let mut out = String::new();
    // use up to three family-diverse graphs from the workload
    let picks: Vec<_> = w.specs.iter().take(3).collect();
    for spec in picks {
        let g = w.load(spec)?;
        out.push_str(&format!("## {} (n={}, m={})\n", spec.name, g.n(), g.nnz()));
        let zt = ablations::ablate_zeroterm(&g, 5);
        out.push_str(&format!(
            "zero-terminated vs bounds-carried: {:.3} ms vs {:.3} ms ({:+.1}% overhead)\n",
            zt.zeroterm_ms,
            zt.bounds_ms,
            zt.overhead() * 100.0
        ));
        let sched = ablations::ablate_schedule(&g);
        out.push_str(&format!(
            "48T support kernel: coarse-static {:.3} ms, coarse-dynamic {:.3} ms, fine-static {:.3} ms\n",
            sched.coarse_static_s * 1e3,
            sched.coarse_dynamic_s * 1e3,
            sched.fine_static_s * 1e3
        ));
        out.push_str(&format!(
            "schedule axis: coarse-workaware {:.3} ms, coarse-stealing {:.3} ms, fine-workaware {:.3} ms\n",
            sched.coarse_workaware_s * 1e3,
            sched.coarse_stealing_s * 1e3,
            sched.fine_workaware_s * 1e3
        ));
        let uf = ablations::ablate_ultrafine(&g, 64);
        out.push_str(&format!(
            "GPU fine vs ultra-fine(seg=64): {:.3} ms vs {:.3} ms\n",
            uf.fine_s * 1e3,
            uf.ultra_s * 1e3
        ));
        let fi = ablations::ablate_flat_index(&g, 5);
        out.push_str(&format!(
            "flat-index resolve: binary-search {:.2} ns/slot, hinted {:.2} ns/slot\n\n",
            fi.binary_search_ns, fi.hinted_ns
        ));
    }
    Ok(out)
}

fn cmd_serve(args: &Args) -> Result<()> {
    let jobs = args.get_as::<usize>("jobs", 32)?;
    let shards = args.get_as::<usize>("shards", 2)?.max(1);
    // --pool is the TOTAL worker budget, split evenly across shards
    let pool = args.get_as::<usize>("pool", 4)?;
    // unpinned plan axes are chosen per job by the submit-time planner
    let spec = parse_plan_spec(args)?;
    // no --priority flag ⇒ the demo stream mixes priority classes
    let fixed_priority: Option<Priority> = match args.opt("priority") {
        Some(p) => Some(p.parse().map_err(|e| anyhow::anyhow!("--priority: {e}"))?),
        None => None,
    };
    let deadline_ms = args.get_as::<u64>("deadline-ms", 0)?;
    // robustness knobs: bounded admission backlog, shedding/deadline
    // enforcement, and a mild deterministic chaos plan for demos
    let max_queue = args.get_as::<usize>("max-queue", 0)?;
    let shed = args.has("shed");
    let chaos: Option<ktruss::serve::FaultPlan> = args
        .opt("chaos")
        .map(|s| -> Result<ktruss::serve::FaultPlan> {
            let seed: u64 = s.parse().map_err(|e| anyhow::anyhow!("--chaos <seed>: {e}"))?;
            Ok(ktruss::serve::FaultPlan {
                seed,
                exec_panic_every: 7,
                transient: true,
                stall_every: 11,
                stall_ms: 5,
                ..ktruss::serve::FaultPlan::default()
            })
        })
        .transpose()?;
    let calibration = args.opt("calibration");
    let trace_out = args.opt("trace-out");
    args.reject_unknown()?;

    // seed the cost model from persisted traces when available (the
    // loaded history is kept and merged back on save)
    let prior_records = match &calibration {
        Some(path) if std::path::Path::new(path).exists() => {
            let records = persist::load(std::path::Path::new(path))?;
            println!("calibration: seeded from {} records in {path}", records.len());
            records
        }
        _ => Vec::new(),
    };
    let model = if prior_records.is_empty() {
        CostModel::new()
    } else {
        CostModel::from_records(&prior_records)
    };
    // --pool is the exact TOTAL budget; with_total_workers spreads the
    // remainder over the first shards
    let serve_cfg =
        ServeConfig { shards, plan: spec, max_queue, shed, faults: chaos, ..Default::default() }
            .with_total_workers(pool);
    let (wps, extra) = (serve_cfg.workers_per_shard, serve_cfg.workers_remainder);
    let ex = Executor::start_with_model(serve_cfg, model);
    println!(
        "executor up (shards={shards}, workers/shard={wps}+{extra}, plan={spec}, schedule={}); submitting {jobs} mixed jobs…",
        spec.schedule
            .map(|s| s.to_string())
            .unwrap_or_else(|| "auto".to_string())
    );
    let mut rng = ktruss::util::Rng::new(1);
    let mut tickets = Vec::new();
    let t = Timer::start();
    for i in 0..jobs {
        let n = rng.range(50, 400);
        let m = rng.range(n, 3 * n);
        let g = Arc::new(ktruss::gen::erdos_renyi::gnm(n, m.min(n * (n - 1) / 2), &mut rng));
        let kind = match i % 4 {
            0 => JobKind::Ktruss { k: 3, mode: Mode::Fine },
            1 => JobKind::Ktruss { k: 4, mode: Mode::Coarse },
            2 => JobKind::Triangles,
            _ => JobKind::Kmax,
        };
        let priority = fixed_priority.unwrap_or(match i % 4 {
            0 => Priority::High,
            3 => Priority::Low,
            _ => Priority::Normal,
        });
        let deadline = (deadline_ms > 0).then(|| Duration::from_millis(deadline_ms));
        let opts = SubmitOpts { priority, deadline, degrade_store: None };
        match ex.try_submit_with(g, kind, opts) {
            Ok(t) => tickets.push(t),
            // backpressure is a normal overload response, not an error
            Err(e) => println!("job refused at admission: {e}"),
        }
    }
    let submitted = tickets.len();
    let mut outcomes: std::collections::BTreeMap<String, usize> =
        std::collections::BTreeMap::new();
    for ticket in tickets {
        let r = ticket.wait();
        *outcomes.entry(r.outcome.to_string()).or_insert(0) += 1;
        // with shedding/enforcement on, non-Done outcomes carry an Err
        // output by design; only a failed *execution* is a hard error
        if r.outcome == ktruss::coordinator::JobOutcome::Done {
            if let Err(e) = &r.output {
                bail!("job {} failed: {e}", r.id);
            }
        }
    }
    let total_ms = t.elapsed_ms();
    let outcome_list =
        outcomes.iter().map(|(o, c)| format!("{c} {o}")).collect::<Vec<_>>().join(", ");
    println!("all {submitted} submitted jobs reached a terminal outcome in {total_ms:.1} ms ({outcome_list})");
    println!("metrics: {}", ex.metrics.render());
    println!("{}", ex.metrics.render_shards());
    if let (Some(p50), Some(p99)) = (ex.metrics.quantile(0.50), ex.metrics.quantile(0.99)) {
        println!("serving latency: p50 {p50:.3} ms, p99 {p99:.3} ms");
    }
    println!(
        "cost model: {:.2} ns/step over {} observations",
        ex.cost_model.ns_per_step(),
        ex.cost_model.samples()
    );
    // drift accounting: per-plan-regime predicted-vs-actual report
    let drift = ex.obs.drift.render();
    if !drift.is_empty() {
        println!("{drift}");
        let flagged = ex.obs.drift.flagged(1.5, 3);
        if flagged.is_empty() {
            println!("drift: all plan regimes within the 1.5x calibration band");
        } else {
            println!(
                "drift: {} plan regime(s) outside the 1.5x calibration band: {}",
                flagged.len(),
                flagged.iter().map(|r| r.plan.clone()).collect::<Vec<_>>().join(", ")
            );
        }
    }
    if let Some(path) = &trace_out {
        let spans = ex.obs.spans.snapshot();
        ktruss::obs::export::write_trace(std::path::Path::new(path), &spans)?;
        println!("trace: wrote {} job span(s) to {path}", spans.len());
    }
    if let Some(path) = calibration {
        // append this run's observations to the loaded history,
        // keeping the freshest records when over the cap
        let mut records = prior_records;
        records.extend(ex.cost_model.records());
        if records.len() > ktruss::serve::cost_model::RECORD_CAP {
            let drop = records.len() - ktruss::serve::cost_model::RECORD_CAP;
            records.drain(..drop);
        }
        persist::save(std::path::Path::new(&path), &records)?;
        println!("calibration: saved {} records to {path}", records.len());
    }
    ex.shutdown();
    Ok(())
}

/// `metrics`: run a short demo job stream through the sharded executor
/// and print the Prometheus-style text exposition of the serving
/// counters plus the plan-drift gauges ([`ktruss::obs::prom`]). With
/// `--calibration`, the cost model (and the drift baselines, via the
/// records' plan provenance) are seeded from the persisted traces
/// before the stream runs.
fn cmd_metrics(args: &Args) -> Result<()> {
    let jobs = args.get_as::<usize>("jobs", 12)?;
    let shards = args.get_as::<usize>("shards", 2)?.max(1);
    let pool = args.get_as::<usize>("pool", 4)?;
    let calibration = args.opt("calibration");
    args.reject_unknown()?;
    let model = match &calibration {
        Some(path) if std::path::Path::new(path).exists() => {
            CostModel::from_records(&persist::load(std::path::Path::new(path))?)
        }
        _ => CostModel::new(),
    };
    let ex = Executor::start_with_model(
        ServeConfig { shards, ..Default::default() }.with_total_workers(pool),
        model,
    );
    let mut rng = ktruss::util::Rng::new(5);
    let mut tickets = Vec::new();
    for i in 0..jobs {
        let n = rng.range(60, 300);
        let m = rng.range(n, 3 * n);
        let g = Arc::new(ktruss::gen::erdos_renyi::gnm(n, m.min(n * (n - 1) / 2), &mut rng));
        let kind = if i % 3 == 2 {
            JobKind::Triangles
        } else {
            JobKind::Ktruss { k: 3, mode: Mode::Fine }
        };
        tickets.push(ex.submit(g, kind));
    }
    for ticket in tickets {
        let r = ticket.wait();
        if let Err(e) = &r.output {
            bail!("job {} failed: {e}", r.id);
        }
    }
    print!("{}", ktruss::obs::prom::render(&ex.metrics, Some(&ex.obs.drift)));
    ex.shutdown();
    Ok(())
}

/// `sim`: timing estimates for one graph on the calibrated machine
/// models, across the schedule × granularity grid. Static is always in
/// the config set — it is the speedup baseline of every other schedule
/// at the same granularity/device.
fn cmd_sim(args: &Args) -> Result<()> {
    let g = load_graph(args)?;
    let k = args.get_as::<u32>("k", 3)?;
    let gran_flag = args.get("granularity", "all");
    // "all" replays the trace-distinguishable granularities; hybrid is
    // accepted explicitly (`--granularity hybrid[:len]`) but charged
    // like segment by the trace-replay models — the planner's static
    // enumeration (`ktruss plan`) is where the representation choice
    // shows a distinct cost
    let grans: Vec<Granularity> = if gran_flag == "all" {
        vec![
            Granularity::Coarse,
            Granularity::Fine,
            Granularity::Segment { len: DEFAULT_SEGMENT_LEN },
        ]
    } else {
        vec![gran_flag
            .parse()
            .map_err(|e| anyhow::anyhow!("--granularity: {e}"))?]
    };
    let sched_flag = args.get("gpu-schedule", "all");
    let scheds: Vec<Schedule> = if sched_flag == "all" {
        GPU_SCHEDULES.to_vec()
    } else {
        let s: Schedule = sched_flag
            .parse()
            .map_err(|e| anyhow::anyhow!("--gpu-schedule: {e}"))?;
        if s == Schedule::Static {
            vec![s]
        } else {
            vec![Schedule::Static, s]
        }
    };
    let cpu_threads = args.get_as::<usize>("cpu-threads", 0)?;
    let support: SupportMode = args
        .get("support-mode", "full")
        .parse()
        .map_err(|e| anyhow::anyhow!("--support-mode: {e}"))?;
    args.reject_unknown()?;
    println!("graph: {}", stats::stats(&g));
    // one block of configs per granularity (and per device), static
    // first so every row's baseline is the block head
    let mut configs: Vec<SimConfig> = Vec::new();
    let mut baseline: Vec<usize> = Vec::new();
    for &gran in &grans {
        let b = configs.len();
        for &sched in &scheds {
            configs.push(SimConfig::gpu_gran(gran, sched));
            baseline.push(b);
        }
        if cpu_threads > 0 {
            let b = configs.len();
            for &sched in &scheds {
                configs.push(SimConfig::cpu_gran(cpu_threads, gran, sched));
                baseline.push(b);
            }
        }
    }
    let t = Timer::start();
    let res = simulate_ktruss_mode(&g, k, &configs, support);
    let wall = t.elapsed_ms();
    let mut table = Table::new(vec!["config", "time ms", "ME/s", "vs static"]);
    for (i, r) in res.iter().enumerate() {
        table.row(vec![
            r.label.clone(),
            format!("{:.3}", r.time_ms()),
            format!("{:.3}", r.me_per_s),
            speedup(res[baseline[i]].seconds / r.seconds),
        ]);
    }
    println!("{}", table.render());
    println!(
        "k={k}, support={support}, {} convergence iterations; replay took {wall:.1} ms host time",
        res.first().map(|r| r.iterations).unwrap_or(0)
    );
    println!("(vs static = speedup over the static schedule at the same granularity/device)");
    Ok(())
}

fn cmd_calibrate(args: &Args) -> Result<()> {
    args.reject_unknown()?;
    let c = ktruss::sim::calibrate::calibrate_step_ns();
    println!(
        "merge-step cost: {:.3} ns/step ({} steps in {:.2} ms)",
        c.step_ns, c.steps, c.wall_ms
    );
    println!("(CPU model default is 1.4 ns; export KTRUSS_STEP_NS to override in benches)");
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    args.reject_unknown()?;
    println!("ktruss {} — three-layer rust+jax+pallas stack", env!("CARGO_PKG_VERSION"));
    match ktruss::runtime::Runtime::global() {
        Ok(rt) => println!(
            "PJRT runtime: platform={} devices={}",
            rt.platform(),
            rt.device_count()
        ),
        Err(e) => println!("PJRT runtime unavailable: {e:#}"),
    }
    match ktruss::runtime::artifacts::artifacts_dir() {
        Ok(dir) => {
            println!("artifacts: {}", dir.display());
            for e in ktruss::runtime::artifacts::list_entries(&dir)? {
                println!("  {} (n={})", e.name, e.n);
            }
        }
        Err(e) => println!("artifacts: {e:#}"),
    }
    println!("host parallelism: {}", std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1));
    Ok(())
}
