//! Unified execution planning — one cost-driven decision for every
//! axis the stack exposes.
//!
//! The paper's core claim is that the *right* task decomposition and
//! schedule depend on the graph's shape: coarse row tasks for flat
//! degree distributions, fine/segment tasks plus work-aware or stealing
//! schedules for hub-skewed ones (PKT and the GPU dynamic
//! load-balancing survey both report the same flip). The repo exposes
//! every axis — [`Schedule`], [`Granularity`],
//! [`SupportMode`] — but until this module they were decided in four
//! disconnected places. The planner replaces those scattered heuristics
//! with one subsystem:
//!
//! 1. read the graph's static per-task cost bounds off the
//!    zero-terminated CSR ([`balance::estimate_costs`] — the same
//!    bounds the work-aware binner uses);
//! 2. auto-tune a segment length from the quantiles of that per-task
//!    cost distribution ([`auto_segment_len`]);
//! 3. score every (schedule × granularity) candidate through the
//!    **existing machine models** — the CPU makespan model
//!    ([`crate::sim::cpu::makespan_ns`]) or the GPU warp/slot model
//!    ([`crate::sim::gpu::estimate_tasks_sched`]) — at the machine's
//!    calibrated per-task overheads;
//! 4. pick a support-maintenance mode from the serving cost model's
//!    per-label ns/step EWMAs when both profiles have been observed
//!    ([`crate::serve::cost_model::CostModel`]), falling back to the
//!    degree-skew heuristic;
//! 5. return one [`ExecutionPlan`] that is carried end to end: the
//!    serving layer computes it once at admission, the queue transports
//!    it, the worker executes it, and the drivers
//!    ([`crate::par::ktruss_par_plan`]) consume every field including
//!    the auto-crossover fraction.
//!
//! Candidate selection is deliberately *sticky*: the planner takes the
//! **earliest** (simplest — the grid enumerates granularity-major,
//! simplest first) candidate whose predicted cost is within
//! [`PLAN_SWITCH_MARGIN`] of the global best. Static estimates are
//! upper bounds with different slack per granularity, so near-ties are
//! noise — the planner switches away from the simple plan only on a
//! clear, shape-driven win (hub rows, clustered hot regions), which is
//! exactly when the paper says the choice matters. The margin is
//! applied against the global minimum, never against a running
//! incumbent, so the decision depends only on the candidate costs —
//! not on the order the scan happened to visit them (see
//! [`select_sticky`'s regression test](self)).

use crate::algo::incremental::{SupportMode, DEFAULT_CROSSOVER_FRAC};
use crate::algo::support::{Granularity, Mode, DEFAULT_SEGMENT_LEN};
use crate::coordinator::job::JobKind;
use crate::graph::{Csr, Vid, ZCsr};
use crate::par::balance::{self, Costs};
use crate::par::Schedule;
use crate::serve::cost_model::{job_label, CostModel};
use crate::sim::machine::{CpuMachine, GpuMachine};
use crate::util::fmt::Table;
use std::sync::Arc;

/// Jobs below this many edges skip candidate scoring entirely: binning,
/// frontier bookkeeping and planning itself all dominate the kernel at
/// this size, so the plan is pinned to the cheapest execution
/// (static schedule, coarse tasks, full recompute). Same threshold the
/// retired per-job heuristics used.
pub const TINY_JOB_NNZ: usize = 2048;

/// Degree-skew threshold (max upper-triangular row length over the
/// mean) above which the support-mode fallback heuristic expects a
/// deep, fringe-peeling cascade and picks
/// [`SupportMode::Incremental`] outright.
pub const HUB_SKEW: f64 = 8.0;

/// A candidate qualifies for selection only when
/// `candidate × PLAN_SWITCH_MARGIN ≤ best` over all scored candidates —
/// the planner's stickiness toward simpler plans (see the module docs);
/// the earliest qualifying candidate wins. Kept tight enough that the
/// chosen plan is always within 5% of the best-scored candidate (the
/// plan-ablation CI bound).
pub const PLAN_SWITCH_MARGIN: f64 = 0.97;

/// Bounds of the auto-tuned segment length (see [`auto_segment_len`]).
pub const MIN_AUTO_SEGMENT_LEN: u32 = 16;
/// Upper bound of the auto-tuned segment length.
pub const MAX_AUTO_SEGMENT_LEN: u32 = 256;

/// Minimum calibration samples **per label** before the planner trusts
/// the cost model's `ktruss+full` vs `ktruss+incremental` comparison
/// over the degree-skew fallback. One-off observations are dominated by
/// which graph shapes happened to run under each label (tiny jobs are
/// the only Full plans under an all-auto spec), so a single sample per
/// side would make the comparison systematically biased.
pub const MIN_SUPPORT_SAMPLES: u64 = 3;

/// The one decision object the whole stack consumes: how a fixed-k
/// truss job executes, on every axis at once.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ExecutionPlan {
    /// How tasks map to workers ([`crate::par::Pool`] schedule).
    pub schedule: Schedule,
    /// How the support pass is cut into tasks.
    pub granularity: Granularity,
    /// How supports are maintained across iterations.
    pub support: SupportMode,
    /// The [`SupportMode::Auto`] crossover fraction: the frontier
    /// update runs only when its estimated work is at most this
    /// fraction of the full-pass proxy.
    pub crossover: f64,
    /// The device whose machine model scored this plan — and, since
    /// the lane backend landed ([`crate::exec::lane`]), the backend
    /// that executes it: [`PlanDevice::Gpu`] plans run the
    /// lockstep-lane execution, [`PlanDevice::Cpu`] plans the thread
    /// pool. Not part of the `schedule/granularity/support` display
    /// grammar; drift/provenance keys carry it as a fourth axis.
    pub device: PlanDevice,
}

impl ExecutionPlan {
    /// A plan with explicit axes at the default crossover fraction,
    /// scored and executed on the CPU pool (the planner stamps its own
    /// device onto every plan it returns).
    pub fn fixed(schedule: Schedule, granularity: Granularity, support: SupportMode) -> ExecutionPlan {
        ExecutionPlan {
            schedule,
            granularity,
            support,
            crossover: DEFAULT_CROSSOVER_FRAC,
            device: PlanDevice::Cpu,
        }
    }

    /// The coarse/fine [`Mode`] this plan's granularity maps onto
    /// ([`Mode::Fine`] for the segment split, which subdivides fine
    /// tasks and reports as fine).
    pub fn mode(&self) -> Mode {
        self.granularity.mode().unwrap_or(Mode::Fine)
    }
}

impl std::fmt::Display for ExecutionPlan {
    /// `schedule/granularity/support` — the same grammar
    /// [`PlanSpec`] parses, so a printed plan is a valid `--plan`
    /// argument.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}/{}/{}", self.schedule, self.granularity, self.support)
    }
}

/// A partially-pinned plan: `None` axes are chosen by the planner,
/// `Some` axes are fixed. This is what configuration carries — the CLI
/// `--plan` grammar, `ServeConfig::plan`, and the per-axis override
/// flags all produce one of these.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PlanSpec {
    /// Pinned schedule, or `None` to let the planner score it.
    pub schedule: Option<Schedule>,
    /// Pinned granularity, or `None` to let the planner score it.
    pub granularity: Option<Granularity>,
    /// Pinned support mode, or `None` to let the planner pick it.
    pub support: Option<SupportMode>,
    /// Pinned crossover fraction, or `None` for the default.
    pub crossover: Option<f64>,
}

impl PlanSpec {
    /// The all-auto spec (every axis chosen by the planner).
    pub fn auto() -> PlanSpec {
        PlanSpec::default()
    }

    /// Whether any axis is pinned.
    pub fn is_auto(&self) -> bool {
        self.schedule.is_none()
            && self.granularity.is_none()
            && self.support.is_none()
            && self.crossover.is_none()
    }

    /// The fully-fixed plan this spec describes, when every execution
    /// axis is pinned (the crossover falls back to its default).
    pub fn fixed(&self) -> Option<ExecutionPlan> {
        Some(ExecutionPlan {
            schedule: self.schedule?,
            granularity: self.granularity?,
            support: self.support?,
            crossover: self.crossover.unwrap_or(DEFAULT_CROSSOVER_FRAC),
            device: PlanDevice::Cpu,
        })
    }

    /// Overlay the pinned axes of this spec onto a chosen plan. The
    /// device is not a spec axis — it always survives from the base
    /// plan (the planner that scored it).
    pub fn apply(&self, base: ExecutionPlan) -> ExecutionPlan {
        ExecutionPlan {
            schedule: self.schedule.unwrap_or(base.schedule),
            granularity: self.granularity.unwrap_or(base.granularity),
            support: self.support.unwrap_or(base.support),
            crossover: self.crossover.unwrap_or(base.crossover),
            device: base.device,
        }
    }
}

impl std::fmt::Display for PlanSpec {
    /// `auto` when nothing is pinned, otherwise
    /// `sched-or-auto/gran-or-auto/support-or-any` (unpinned schedule
    /// and granularity render as `auto`, unpinned support as `any` —
    /// `auto` in the support slot means the pinned
    /// [`SupportMode::Auto`]; the crossover pin has no surface syntax
    /// and is not rendered).
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.is_auto() {
            return write!(f, "auto");
        }
        let part = |x: Option<String>, free: &str| x.unwrap_or_else(|| free.to_string());
        write!(
            f,
            "{}/{}/{}",
            part(self.schedule.map(|s| s.to_string()), "auto"),
            part(self.granularity.map(|g| g.to_string()), "auto"),
            part(self.support.map(|m| m.to_string()), "any"),
        )
    }
}

impl std::str::FromStr for PlanSpec {
    type Err = String;

    /// Parse the CLI `--plan` grammar: `auto` (all axes planned), or
    /// `<schedule>/<granularity>/<support>` — e.g.
    /// `stealing/fine/incremental`, `auto/segment:64/any`. The schedule
    /// and granularity parts accept `auto`/`any` to leave the axis to
    /// the planner; the support part accepts only `any` for that
    /// (because `auto` already names the per-round
    /// [`SupportMode::Auto`] crossover driver, which this pins).
    fn from_str(s: &str) -> Result<PlanSpec, String> {
        if s == "auto" {
            return Ok(PlanSpec::auto());
        }
        let parts: Vec<&str> = s.split('/').collect();
        if parts.len() != 3 {
            return Err(format!(
                "plan spec {s:?} must be `auto` or `<schedule>/<granularity>/<support>` \
                 (axis values, with `auto`/`any` leaving an axis to the planner)"
            ));
        }
        let axis = |p: &str| -> Option<&str> { (p != "auto" && p != "any").then_some(p) };
        Ok(PlanSpec {
            schedule: axis(parts[0]).map(|p| p.parse()).transpose()?,
            granularity: axis(parts[1]).map(|p| p.parse()).transpose()?,
            support: (parts[2] != "any").then(|| parts[2].parse()).transpose()?,
            crossover: None,
        })
    }
}

/// The device the plan's candidates are scored for — and executed on:
/// [`PlanDevice::Gpu`] plans dispatch to the lockstep-lane backend
/// ([`crate::exec::lane`]), [`PlanDevice::Cpu`] plans to the thread
/// pool drivers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanDevice {
    /// The CPU pool model at the planner's thread count.
    Cpu,
    /// The V100 warp/slot model ([`crate::sim::gpu`]).
    Gpu,
}

impl std::fmt::Display for PlanDevice {
    /// `cpu` / `gpu` — the device axis of drift and provenance keys.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            PlanDevice::Cpu => "cpu",
            PlanDevice::Gpu => "gpu",
        })
    }
}

impl std::str::FromStr for PlanDevice {
    type Err = String;

    /// Parse `cpu` / `gpu` (the CLI `--device` values).
    fn from_str(s: &str) -> Result<PlanDevice, String> {
        match s {
            "cpu" => Ok(PlanDevice::Cpu),
            "gpu" => Ok(PlanDevice::Gpu),
            other => Err(format!("unknown device {other:?} (expected cpu or gpu)")),
        }
    }
}

/// Auto-tune the segment length from a per-task cost distribution
/// (quantile-based): the median of the non-trivial costs, clamped
/// between [`MIN_AUTO_SEGMENT_LEN`] and [`MAX_AUTO_SEGMENT_LEN`].
///
/// The rationale: a segment of median-task length splits every hub-
/// sized task into many *typical*-sized pieces (bounding the longest
/// task — and on the GPU the serial tail — by the bulk of the
/// distribution) while leaving that bulk unsplit (cost ≤ len ⇒ one
/// segment), so the per-segment overhead stays proportional to the
/// skew it removes. Works on either cost source [`Costs`] carries —
/// the static estimates at admission time or a measured trace.
pub fn auto_segment_len(costs: &Costs) -> u32 {
    let mut v: Vec<u64> = costs.per_task.iter().copied().filter(|&c| c > 1).collect();
    if v.is_empty() {
        return DEFAULT_SEGMENT_LEN.clamp(MIN_AUTO_SEGMENT_LEN, MAX_AUTO_SEGMENT_LEN);
    }
    v.sort_unstable();
    let p50 = v[(v.len() - 1) / 2];
    (p50.min(MAX_AUTO_SEGMENT_LEN as u64) as u32).max(MIN_AUTO_SEGMENT_LEN)
}

/// One scored candidate of a planning decision.
#[derive(Clone, Debug)]
pub struct PlanCandidate {
    /// The candidate plan (all candidates share the chosen support mode
    /// and crossover; they differ on schedule × granularity).
    pub plan: ExecutionPlan,
    /// Predicted cost of one support pass under this candidate, in
    /// milliseconds of the scoring device's machine model.
    pub predicted_ms: f64,
}

/// The full record of one planning decision — every candidate with its
/// predicted cost, and which one won ("explain" mode).
#[derive(Clone, Debug)]
pub struct PlanExplanation {
    /// Requested k (recorded for provenance; the static scoring is
    /// k-independent).
    pub k: u32,
    /// Scored candidates, in enumeration order (granularity-major,
    /// schedule-minor).
    pub candidates: Vec<PlanCandidate>,
    /// Index of the chosen candidate.
    pub chosen: usize,
    /// Auto-tuned segment length used by the segment candidates.
    pub seg_len: u32,
    /// Degree-skew proxy (max upper-triangular row length / mean).
    pub skew: f64,
    /// Whether the tiny-job shortcut fired (no scoring ran).
    pub tiny: bool,
}

impl PlanExplanation {
    /// The winning plan.
    pub fn plan(&self) -> ExecutionPlan {
        self.candidates[self.chosen].plan
    }

    /// Predicted cost of the winning plan, ms.
    pub fn predicted_ms(&self) -> f64 {
        self.candidates[self.chosen].predicted_ms
    }

    /// The minimum predicted cost over all candidates (the best fixed
    /// plan's cost — the plan-ablation bound compares the winner
    /// against this).
    pub fn best_ms(&self) -> f64 {
        self.candidates
            .iter()
            .map(|c| c.predicted_ms)
            .fold(f64::INFINITY, f64::min)
    }

    /// Look up the candidate at one (schedule, granularity) grid point.
    pub fn candidate(&self, schedule: Schedule, gran: Granularity) -> Option<&PlanCandidate> {
        self.candidates
            .iter()
            .find(|c| c.plan.schedule == schedule && c.plan.granularity == gran)
    }

    /// Render the per-candidate predicted costs as an aligned table
    /// with the winner marked (what `ktruss plan` prints).
    pub fn render(&self) -> String {
        let mut table = Table::new(vec!["candidate plan", "predicted ms", ""]);
        for (i, c) in self.candidates.iter().enumerate() {
            table.row(vec![
                c.plan.to_string(),
                format!("{:.4}", c.predicted_ms),
                if i == self.chosen { "<- chosen".to_string() } else { String::new() },
            ]);
        }
        let mut out = table.render();
        out.push_str(&format!(
            "chosen: {} (skew {:.1}, seg-len {}{})\n",
            self.plan(),
            self.skew,
            self.seg_len,
            if self.tiny { ", tiny-job shortcut" } else { "" }
        ));
        out
    }
}

/// The planner: device, thread budget, pinned axes, and (optionally)
/// the serving cost model whose per-label ns/step EWMAs refine the
/// support-mode choice. Construction is cheap; [`Planner::choose`] does
/// `O(m log m)` work on the job's graph — comparable to the submit-time
/// cost estimate it sits next to.
#[derive(Clone)]
pub struct Planner {
    /// Worker threads the job's pool will run (CPU scoring width).
    pub threads: usize,
    /// Device whose machine model scores the candidates.
    pub device: PlanDevice,
    /// Pinned axes (candidate enumeration is restricted to them).
    pub spec: PlanSpec,
    model: Option<Arc<CostModel>>,
}

impl Planner {
    /// A CPU planner for a pool of `threads` workers, nothing pinned.
    pub fn new(threads: usize) -> Planner {
        Planner {
            threads: threads.max(1),
            device: PlanDevice::Cpu,
            spec: PlanSpec::auto(),
            model: None,
        }
    }

    /// A GPU planner (scores through the V100 warp/slot model).
    pub fn gpu() -> Planner {
        Planner {
            threads: 1,
            device: PlanDevice::Gpu,
            spec: PlanSpec::auto(),
            model: None,
        }
    }

    /// Pin axes (builder style).
    pub fn with_spec(mut self, spec: PlanSpec) -> Planner {
        self.spec = spec;
        self
    }

    /// Attach the serving cost model so the support-mode choice can use
    /// its calibrated per-label ns/step EWMAs.
    pub fn with_model(mut self, model: Arc<CostModel>) -> Planner {
        self.model = Some(model);
        self
    }

    /// Choose one plan for graph `g` at threshold `k`. Fully-pinned
    /// specs return immediately; otherwise the candidates are scored
    /// (see [`Planner::explain`]).
    pub fn choose(&self, g: &Csr, k: u32) -> ExecutionPlan {
        self.choose_scored(g, k).0
    }

    /// [`Planner::choose`] plus the winning candidate's predicted cost
    /// of one support pass, in ms of the scoring device's machine model
    /// (`None` when a fully-pinned spec short-circuited scoring). The
    /// serving executor carries this through the admission queue so the
    /// drift accounting can join the planner's prediction against the
    /// measured spans ([`crate::obs::drift`]).
    pub fn choose_scored(&self, g: &Csr, k: u32) -> (ExecutionPlan, Option<f64>) {
        if let Some(mut plan) = self.spec.fixed() {
            plan.device = self.device;
            return (plan, None);
        }
        let ex = self.explain(g, k);
        (ex.plan(), Some(ex.predicted_ms()))
    }

    /// Score every candidate and return the full decision record.
    pub fn explain(&self, g: &Csr, k: u32) -> PlanExplanation {
        let crossover = self.spec.crossover.unwrap_or(DEFAULT_CROSSOVER_FRAC);
        let n = g.n();
        let live: Vec<u32> = (0..n).map(|i| g.row(i).len() as u32).collect();
        let mean = if n == 0 { 0.0 } else { g.nnz() as f64 / n as f64 };
        let max = live.iter().copied().max().unwrap_or(0) as f64;
        let skew = if mean > 0.0 { max / mean } else { 0.0 };
        // tiny jobs: scoring (and every non-trivial plan) costs more
        // than it saves — pin the cheapest execution
        if g.nnz() < TINY_JOB_NNZ {
            let mut plan = self
                .spec
                .apply(ExecutionPlan::fixed(Schedule::Static, Granularity::Coarse, SupportMode::Full));
            plan.device = self.device;
            // a rough serial-cost figure in the scoring device's own
            // units, so the single row stays comparable to non-tiny
            // explanations from the same planner
            let step_ns = match self.device {
                PlanDevice::Cpu => CpuMachine::skylake_8160(self.threads).step_ns,
                PlanDevice::Gpu => GpuMachine::v100().serial_step_s() * 1e9,
            };
            let predicted_ms = g.nnz() as f64 * 4.0 * step_ns / 1e6;
            return PlanExplanation {
                k,
                candidates: vec![PlanCandidate { plan, predicted_ms }],
                chosen: 0,
                seg_len: DEFAULT_SEGMENT_LEN,
                skew,
                tiny: true,
            };
        }
        // score straight off the canonical CSR — no scratch
        // zero-terminated working copy at admission time (a fresh
        // zero-terminated row is its CSR row plus one terminator slot,
        // so the Csr-native estimates are entry-identical)
        let view = GraphView::Csr(g);
        let fine_costs = Costs { per_task: balance::estimate_costs_csr(g, Mode::Fine) };
        let fine_est: &[u64] = &fine_costs.per_task;
        let total_est: u64 = fine_est.iter().sum();
        let support = self.pick_support(g, total_est, skew);
        let seg_len = match self.spec.granularity {
            Some(Granularity::Segment { len }) | Some(Granularity::Hybrid { len }) => len,
            _ => auto_segment_len(&fine_costs),
        };
        let grans: Vec<Granularity> = match self.spec.granularity {
            Some(gran) => vec![gran],
            None => vec![
                Granularity::Coarse,
                Granularity::Fine,
                Granularity::Segment { len: seg_len },
                Granularity::Hybrid { len: seg_len },
            ],
        };
        let scheds: Vec<Schedule> = match self.spec.schedule {
            Some(s) => vec![s],
            None => vec![
                Schedule::Static,
                Schedule::Dynamic { chunk: 256 },
                Schedule::WorkAware,
                Schedule::Stealing,
            ],
        };
        let mut candidates = Vec::with_capacity(grans.len() * scheds.len());
        for &gran in &grans {
            let task_costs = self.task_costs(&view, fine_est, gran);
            for &sched in &scheds {
                let predicted_ms = self.score(&task_costs, total_est, sched);
                candidates.push(PlanCandidate {
                    plan: ExecutionPlan {
                        schedule: sched,
                        granularity: gran,
                        support,
                        crossover,
                        device: self.device,
                    },
                    predicted_ms,
                });
            }
        }
        let chosen = select_sticky(&candidates);
        PlanExplanation { k, candidates, chosen, seg_len, skew, tiny: false }
    }

    /// Per-task costs of one support pass at `gran`, in the scoring
    /// device's units (ns for CPU, steps for GPU), machine-model
    /// overheads included — exactly the per-task shaping
    /// [`crate::sim::cpu`] / [`crate::sim::gpu`] apply to traces, fed
    /// with the static bounds available at admission time. Reads only
    /// the row view, so it scores identically off the canonical
    /// [`Csr`] or a zero-terminated working copy.
    fn task_costs(&self, view: &GraphView<'_>, fine_est: &[u64], gran: Granularity) -> Vec<f64> {
        match self.device {
            PlanDevice::Cpu => {
                let m = CpuMachine::skylake_8160(self.threads);
                match gran {
                    Granularity::Coarse => view
                        .coarse_costs()
                        .iter()
                        .enumerate()
                        .map(|(i, &st)| {
                            m.coarse_task_ns
                                + view.row(i).len() as f64 * m.entry_ns
                                + st as f64 * m.step_ns
                        })
                        .collect(),
                    Granularity::Fine => fine_est
                        .iter()
                        .map(|&st| m.fine_task_ns + st as f64 * m.step_ns)
                        .collect(),
                    Granularity::Segment { len } => {
                        split_segments(fine_est, len)
                            .map(|st| m.segment_task_ns() + st as f64 * m.step_ns)
                            .collect()
                    }
                    Granularity::Hybrid { len } => {
                        let (merge, probe) = hybrid_pieces(view, fine_est, len);
                        merge
                            .into_iter()
                            .map(|st| m.segment_task_ns() + st as f64 * m.step_ns)
                            .chain(
                                probe
                                    .into_iter()
                                    .map(|st| m.bitmap_task_ns() + st as f64 * m.step_ns),
                            )
                            .collect()
                    }
                }
            }
            PlanDevice::Gpu => {
                let m = GpuMachine::v100();
                match gran {
                    Granularity::Coarse => view
                        .coarse_costs()
                        .iter()
                        .map(|&st| st as f64 + m.coarse_task_steps)
                        .collect(),
                    Granularity::Fine => fine_est
                        .iter()
                        .map(|&st| st as f64 + m.fine_task_steps)
                        .collect(),
                    Granularity::Segment { len } => split_segments(fine_est, len)
                        .map(|st| st as f64 + m.segment_task_steps())
                        .collect(),
                    Granularity::Hybrid { len } => {
                        let (merge, probe) = hybrid_pieces(view, fine_est, len);
                        merge
                            .into_iter()
                            .map(|st| st as f64 + m.segment_task_steps())
                            .chain(probe.into_iter().map(|st| st as f64 + m.bitmap_task_steps()))
                            .collect()
                    }
                }
            }
        }
    }

    /// The per-task cost vector (in the scoring device's units — ns for
    /// CPU, steps for GPU) of one support pass at `gran`, from the
    /// static bounds alone: exactly what the candidate scoring feeds
    /// the machine models. Public so the benches (plan ablation, the
    /// `bitmap` hot-path section) can compare fixed granularities
    /// through the same shaping the planner uses.
    pub fn static_task_costs(&self, z: &ZCsr, gran: Granularity) -> Vec<f64> {
        let fine_est = balance::estimate_costs(z, Mode::Fine);
        self.task_costs(&GraphView::Zero(z), &fine_est, gran)
    }

    /// [`Planner::static_task_costs`] straight off the canonical
    /// [`Csr`] — the admission-time shaping [`Planner::explain`] uses,
    /// which allocates no scratch zero-terminated working copy.
    pub fn static_task_costs_csr(&self, g: &Csr, gran: Granularity) -> Vec<f64> {
        let fine_est = balance::estimate_costs_csr(g, Mode::Fine);
        self.task_costs(&GraphView::Csr(g), &fine_est, gran)
    }

    /// Predicted cost (ms) of one support pass at a fixed
    /// (granularity, schedule) point, through the device's machine
    /// model — the single-candidate form of [`Planner::explain`].
    pub fn predict_pass_ms(&self, z: &ZCsr, gran: Granularity, schedule: Schedule) -> f64 {
        let costs = self.static_task_costs(z, gran);
        let total_est = balance::estimate_costs_sum(z, Mode::Fine);
        self.score(&costs, total_est, schedule)
    }

    /// Predicted cost (ms) of one support pass from its per-task costs
    /// under `schedule`, through the device's machine model.
    fn score(&self, task_costs: &[f64], total_est: u64, schedule: Schedule) -> f64 {
        match self.device {
            PlanDevice::Cpu => {
                let m = CpuMachine::skylake_8160(self.threads);
                let compute_ns =
                    crate::sim::cpu::makespan_ns(task_costs, m.threads, schedule);
                let bytes = total_est as f64 * 8.0 + task_costs.len() as f64 * 24.0;
                let bw_ns = bytes / m.mem_bw_gbs;
                compute_ns.max(bw_ns) / 1e6 + m.fork_join_us / 1e3
            }
            PlanDevice::Gpu => {
                let m = GpuMachine::v100();
                crate::sim::gpu::estimate_tasks_sched(&m, task_costs, total_est as f64, schedule)
                    .total_s()
                    * 1e3
            }
        }
    }

    /// The support-mode axis: pinned value, else the calibrated
    /// comparison when the cost model has seen both truss profiles,
    /// else the degree-skew fallback ([`HUB_SKEW`]).
    fn pick_support(&self, g: &Csr, total_est: u64, skew: f64) -> SupportMode {
        if let Some(s) = self.spec.support {
            return s;
        }
        if let Some(model) = &self.model {
            let probe = JobKind::Ktruss { k: 3, mode: Mode::Fine };
            let full_label = job_label(&probe, Some(SupportMode::Full));
            let inc_label = job_label(&probe, Some(SupportMode::Incremental));
            if model.samples_for(&full_label) >= MIN_SUPPORT_SAMPLES
                && model.samples_for(&inc_label) >= MIN_SUPPORT_SAMPLES
            {
                // job-level step profiles mirroring
                // `cost_model::estimate_steps_mode`'s truss multipliers
                let full_est = total_est.saturating_mul(3);
                let inc_est = total_est.saturating_add(g.nnz() as u64);
                return if model.predict_ms_for(&inc_label, inc_est)
                    < model.predict_ms_for(&full_label, full_est)
                {
                    SupportMode::Incremental
                } else {
                    SupportMode::Auto
                };
            }
        }
        if skew >= HUB_SKEW {
            SupportMode::Incremental
        } else {
            SupportMode::Auto
        }
    }
}

/// Order-independent sticky selection: the earliest (simplest — the
/// grid enumerates granularity-major, simplest first) candidate whose
/// predicted cost is within [`PLAN_SWITCH_MARGIN`] of the global best
/// (`cost × PLAN_SWITCH_MARGIN ≤ best`). The previous incumbent-scan
/// compared each candidate against a *running* incumbent, so the chosen
/// cost depended on the order the minimum was approached (an
/// intermediate candidate could reset the margin base and make the scan
/// skip — or land on — a candidate it otherwise wouldn't); comparing
/// against the global minimum makes the decision a pure function of the
/// cost multiset plus the fixed grid order.
fn select_sticky(candidates: &[PlanCandidate]) -> usize {
    let best = candidates
        .iter()
        .map(|c| c.predicted_ms)
        .fold(f64::INFINITY, f64::min);
    candidates
        .iter()
        .position(|c| c.predicted_ms * PLAN_SWITCH_MARGIN <= best)
        .unwrap_or(0)
}

/// Split each estimated task cost into `ceil(cost/len)` pieces of ≤
/// `len` steps — the modeled segment decomposition (the static-estimate
/// analogue of [`Costs::from_trace_rows`]'s segment arm).
fn split_segments(fine_est: &[u64], len: u32) -> impl Iterator<Item = u64> + '_ {
    let len = len.max(1) as u64;
    fine_est.iter().flat_map(move |&st| {
        let pieces = st.div_ceil(len).max(1);
        (0..pieces).map(move |i| {
            if i + 1 == pieces {
                st - i * len
            } else {
                len
            }
        })
    })
}

/// The two graph layouts the planner scores from, behind one row view.
/// At admission time the candidate scoring reads the canonical [`Csr`]
/// directly — a fresh zero-terminated row is exactly its CSR row plus
/// one terminator slot, so no scratch working copy is built (the
/// retired `ZCsr::from_csr` admission-time allocation). The bench
/// paths that score a mid-computation layout go through the
/// [`ZCsr`] arm instead.
enum GraphView<'a> {
    /// Canonical adjacency: every row fully live, one terminator slot
    /// of padding per row in the fine task-index space.
    Csr(&'a Csr),
    /// A zero-terminated working copy (possibly pruned, with
    /// tombstone padding beyond each row's live prefix).
    Zero(&'a ZCsr),
}

impl GraphView<'_> {
    fn n(&self) -> usize {
        match self {
            GraphView::Csr(g) => g.n(),
            GraphView::Zero(z) => z.n(),
        }
    }

    /// The row's live prefix (the whole row for the CSR arm).
    fn row(&self, i: usize) -> &[Vid] {
        match self {
            GraphView::Csr(g) => g.row(i),
            GraphView::Zero(z) => z.row_live(i),
        }
    }

    /// Dead slots after row `i`'s live prefix in the fine task-index
    /// space (the terminator for a fresh row; terminator plus
    /// tombstones for a pruned one).
    fn pad(&self, i: usize) -> usize {
        match self {
            GraphView::Csr(_) => 1,
            GraphView::Zero(z) => {
                let (start, end) = z.row_span(i);
                end - start - z.row_live(i).len()
            }
        }
    }

    /// [`balance::estimate_costs`] at [`Mode::Coarse`] for this view.
    fn coarse_costs(&self) -> Vec<u64> {
        match self {
            GraphView::Csr(g) => balance::estimate_costs_csr(g, Mode::Coarse),
            GraphView::Zero(z) => balance::estimate_costs(z, Mode::Coarse),
        }
    }
}

/// The modeled task pieces of one hybrid support pass at `len`:
/// `(merge-side pieces, probe-side pieces)`, both in steps.
///
/// Slots whose partner row the [`crate::algo::bitmap::BitmapIndex`]
/// selection would encode contribute tail-side probe chunks —
/// `ceil(tail/len)` pieces of at most `len` steps, which is *exact*
/// (one uniform probe per tail entry,
/// [`crate::algo::bitmap::BitmapTask::estimated_steps`]). Every other
/// slot (merge-represented partner, empty tail, terminator/tombstone)
/// stays on the merge side and is split with the **same** ≤`len`
/// upper-bound decomposition the segment candidate uses
/// ([`split_segments`] of the fine estimates). Keeping the merge side
/// on the segment candidate's bound convention makes the
/// hybrid-vs-segment comparison measure exactly the representation
/// switch on the encoded rows, not a change of accounting slack
/// between candidates.
///
/// The selection predicate is evaluated arithmetically (`live ≥
/// threshold`, bitmap words ≤ live — the same mirror
/// [`balance::hybrid_trace_pieces`] uses), so scoring builds no
/// bitmap index and allocates nothing graph-sized beyond the flags.
fn hybrid_pieces(view: &GraphView<'_>, fine_est: &[u64], len: u32) -> (Vec<u64>, Vec<u64>) {
    let n = view.n();
    let thr = len.max(1) as usize;
    let l = len.max(1) as u64;
    // mirror of the `BitmapIndex::build` selection: long enough to
    // qualify, and dense enough that the bitmap words don't exceed
    // the live count
    let encoded: Vec<bool> = (0..n)
        .map(|i| {
            let row = view.row(i);
            let lk = row.len();
            lk >= thr && {
                let words = ((row[lk - 1] as usize - row[0] as usize) >> 6) + 1;
                words <= lk
            }
        })
        .collect();
    let mut is_probe = vec![false; fine_est.len()];
    let mut probe = Vec::new();
    let mut start = 0usize;
    for i in 0..n {
        let row = view.row(i);
        let li = row.len();
        for off in 0..li {
            let tail = (li - off - 1) as u64;
            if tail == 0 {
                continue;
            }
            if !encoded[row[off] as usize] {
                continue;
            }
            is_probe[start + off] = true;
            let pieces = tail.div_ceil(l);
            for j in 0..pieces {
                probe.push(if j + 1 == pieces { tail - j * l } else { l });
            }
        }
        start += li + view.pad(i);
    }
    let merge_est: Vec<u64> = fine_est
        .iter()
        .zip(&is_probe)
        .filter(|&(_, &ip)| !ip)
        .map(|(&st, _)| st)
        .collect();
    (split_segments(&merge_est, len).collect(), probe)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn plan_spec_grammar_roundtrips() {
        assert_eq!("auto".parse::<PlanSpec>().unwrap(), PlanSpec::auto());
        assert_eq!(PlanSpec::auto().to_string(), "auto");
        let spec: PlanSpec = "stealing/segment:32/incremental".parse().unwrap();
        assert_eq!(spec.schedule, Some(Schedule::Stealing));
        assert_eq!(spec.granularity, Some(Granularity::Segment { len: 32 }));
        assert_eq!(spec.support, Some(SupportMode::Incremental));
        assert_eq!(spec.to_string(), "stealing/segment:32/incremental");
        let back: PlanSpec = spec.to_string().parse().unwrap();
        assert_eq!(back, spec);
        // partial pins keep the unpinned axes free ("any" in the
        // support slot — "auto" there pins SupportMode::Auto)
        let partial: PlanSpec = "auto/fine/any".parse().unwrap();
        assert_eq!(partial.schedule, None);
        assert_eq!(partial.granularity, Some(Granularity::Fine));
        assert_eq!(partial.support, None);
        assert_eq!(partial.to_string(), "auto/fine/any");
        let pinned_auto: PlanSpec = "auto/fine/auto".parse().unwrap();
        assert_eq!(pinned_auto.support, Some(SupportMode::Auto));
        // a fully-pinned spec fixes a plan; a partial one does not
        assert!(spec.fixed().is_some());
        assert!(partial.fixed().is_none());
        // errors: wrong arity and bad axis values
        assert!("static/fine".parse::<PlanSpec>().is_err());
        assert!("bogus/fine/auto".parse::<PlanSpec>().is_err());
        assert!("static/bogus/auto".parse::<PlanSpec>().is_err());
        assert!("static/fine/bogus".parse::<PlanSpec>().is_err());
    }

    #[test]
    fn plan_display_is_a_valid_spec() {
        let plan = ExecutionPlan::fixed(
            Schedule::WorkAware,
            Granularity::Segment { len: 48 },
            SupportMode::Auto,
        );
        let spec: PlanSpec = plan.to_string().parse().unwrap();
        assert_eq!(spec.fixed().unwrap(), plan);
        assert_eq!(plan.mode(), Mode::Fine);
        assert_eq!(
            ExecutionPlan::fixed(Schedule::Static, Granularity::Coarse, SupportMode::Full).mode(),
            Mode::Coarse
        );
    }

    #[test]
    fn spec_apply_overlays_only_pinned_axes() {
        let base = ExecutionPlan::fixed(Schedule::Static, Granularity::Coarse, SupportMode::Full);
        let spec: PlanSpec = "auto/fine/any".parse().unwrap();
        let out = spec.apply(base);
        assert_eq!(out.schedule, Schedule::Static);
        assert_eq!(out.granularity, Granularity::Fine);
        assert_eq!(out.support, SupportMode::Full);
    }

    #[test]
    fn auto_segment_len_follows_the_distribution() {
        // uniform small costs: clamped to the floor
        let small = Costs { per_task: vec![2; 100] };
        assert_eq!(auto_segment_len(&small), MIN_AUTO_SEGMENT_LEN);
        // median-100 distribution lands at 100
        let mid = Costs { per_task: vec![100; 51].into_iter().chain(vec![2; 50]).collect() };
        assert_eq!(auto_segment_len(&mid), 100);
        // giant costs: clamped to the ceiling
        let big = Costs { per_task: vec![100_000; 10] };
        assert_eq!(auto_segment_len(&big), MAX_AUTO_SEGMENT_LEN);
        // all-trivial falls back to the fixed default
        let trivial = Costs { per_task: vec![1; 10] };
        assert_eq!(
            auto_segment_len(&trivial),
            DEFAULT_SEGMENT_LEN.clamp(MIN_AUTO_SEGMENT_LEN, MAX_AUTO_SEGMENT_LEN)
        );
    }

    #[test]
    fn tiny_jobs_take_the_shortcut() {
        let g = crate::testkit::graphs::diamond();
        let ex = Planner::new(4).explain(&g, 3);
        assert!(ex.tiny);
        assert_eq!(ex.candidates.len(), 1);
        let plan = ex.plan();
        assert_eq!(plan.schedule, Schedule::Static);
        assert_eq!(plan.granularity, Granularity::Coarse);
        assert_eq!(plan.support, SupportMode::Full);
        // pinned axes still win on the shortcut path
        let spec: PlanSpec = "stealing/fine/auto".parse().unwrap();
        let pinned = Planner::new(4).with_spec(spec).choose(&g, 3);
        assert_eq!(pinned.schedule, Schedule::Stealing);
        assert_eq!(pinned.granularity, Granularity::Fine);
    }

    #[test]
    fn hub_graphs_get_fine_or_segment_and_a_cost_aware_schedule() {
        let planner = Planner::new(48);
        for (name, g) in [
            ("comb", crate::testkit::graphs::hub_divergence_comb(64, 256, 800)),
            ("star", crate::testkit::graphs::star_with_fringe(1200)),
        ] {
            let ex = planner.explain(&g, 3);
            assert!(!ex.tiny, "{name}");
            let plan = ex.plan();
            assert_ne!(plan.granularity, Granularity::Coarse, "{name}: {plan}");
            // the skew heuristic marks both hub fixtures incremental
            assert_eq!(plan.support, SupportMode::Incremental, "{name}: {plan}");
            // chosen plan is within the switch margin of the best
            assert!(
                ex.predicted_ms() <= ex.best_ms() / PLAN_SWITCH_MARGIN + 1e-12,
                "{name}: chosen {} vs best {}",
                ex.predicted_ms(),
                ex.best_ms()
            );
        }
        // at merge granularity the comb's clustered hot region defeats
        // static contiguous blocks outright (pinned to segment: the
        // hybrid representation is allowed to flatten the imbalance
        // itself, in which case a static schedule is no longer wrong)
        let comb = crate::testkit::graphs::hub_divergence_comb(64, 256, 800);
        let seg: PlanSpec = "auto/segment/any".parse().unwrap();
        let plan = planner.clone().with_spec(seg).choose(&comb, 3);
        assert_ne!(plan.schedule, Schedule::Static, "{plan}");
    }

    #[test]
    fn flat_grids_stay_coarse() {
        // near-uniform road lattice, dense enough (m/n ≈ 1.9) that the
        // coarse row task amortizes its fixed overhead: every candidate
        // is within a few percent, and the planner's stickiness keeps
        // the simple coarse plan — the paper's roadNet null effect
        let g = crate::gen::grid::road(3000, 5800, 0.05, &mut Rng::new(6));
        let ex = Planner::new(48).explain(&g, 3);
        assert!(!ex.tiny);
        let plan = ex.plan();
        assert_eq!(plan.granularity, Granularity::Coarse, "{plan}");
        // near-uniform work: no cascade regime, auto support
        assert_eq!(plan.support, SupportMode::Auto, "{plan}");
    }

    #[test]
    fn gpu_planner_splits_the_divergent_hot_slots() {
        // the comb concentrates its cost in a few ~800-step slots: on
        // the GPU the serial-tail term dominates fine's estimate, and
        // only the segment split shrinks the longest task
        let g = crate::testkit::graphs::hub_divergence_comb(64, 256, 800);
        let ex = Planner::gpu().explain(&g, 3);
        let plan = ex.plan();
        assert!(
            matches!(
                plan.granularity,
                Granularity::Segment { .. } | Granularity::Hybrid { .. }
            ),
            "{plan}"
        );
        let fine_best = ex
            .candidates
            .iter()
            .filter(|c| c.plan.granularity == Granularity::Fine)
            .map(|c| c.predicted_ms)
            .fold(f64::INFINITY, f64::min);
        assert!(ex.predicted_ms() < fine_best, "segment must beat fine on the tail");
    }

    #[test]
    fn pinned_axes_restrict_the_candidate_grid() {
        let g = crate::testkit::graphs::hub_divergence_comb(32, 128, 400);
        let spec: PlanSpec = "workaware/auto/auto".parse().unwrap();
        let ex = Planner::new(8).with_spec(spec).explain(&g, 3);
        assert!(ex.candidates.iter().all(|c| c.plan.schedule == Schedule::WorkAware));
        assert_eq!(ex.candidates.len(), 4); // one per granularity
        let full: PlanSpec = "static/coarse/full".parse().unwrap();
        let plan = Planner::new(8).with_spec(full).choose(&g, 3);
        assert_eq!(
            plan,
            ExecutionPlan::fixed(Schedule::Static, Granularity::Coarse, SupportMode::Full)
        );
    }

    #[test]
    fn calibrated_model_refines_the_support_choice() {
        use crate::coordinator::job::JobKind;
        // mild skew (< HUB_SKEW) so the fallback would say Auto
        let g = crate::gen::erdos_renyi::gnm(300, 2500, &mut Rng::new(9));
        let probe = JobKind::Ktruss { k: 3, mode: Mode::Fine };
        let full_label = job_label(&probe, Some(SupportMode::Full));
        let inc_label = job_label(&probe, Some(SupportMode::Incremental));
        let feed = |model: &CostModel, full_ms: f64, inc_ms: f64| {
            for _ in 0..MIN_SUPPORT_SAMPLES {
                model.observe_labeled(&full_label, 10, 20, 1000, full_ms);
                model.observe_labeled(&inc_label, 10, 20, 1000, inc_ms);
            }
        };
        // incremental observed much cheaper per step -> Incremental
        let model = Arc::new(CostModel::new());
        feed(&model, 0.10, 0.001);
        let plan = Planner::new(8).with_model(Arc::clone(&model)).choose(&g, 4);
        assert_eq!(plan.support, SupportMode::Incremental);
        // incremental observed much *more* expensive -> stay Auto
        let model = Arc::new(CostModel::new());
        feed(&model, 0.001, 0.10);
        let plan = Planner::new(8).with_model(Arc::clone(&model)).choose(&g, 4);
        assert_eq!(plan.support, SupportMode::Auto);
        // below the sample floor the calibration is ignored entirely
        // (the mild-skew fallback says Auto even with a cheap-looking
        // incremental label)
        let model = Arc::new(CostModel::new());
        model.observe_labeled(&full_label, 10, 20, 1000, 0.10);
        model.observe_labeled(&inc_label, 10, 20, 1000, 0.001);
        let plan = Planner::new(8).with_model(model).choose(&g, 4);
        assert_eq!(plan.support, SupportMode::Auto);
    }

    #[test]
    fn explanation_renders_candidates_and_winner() {
        let g = crate::testkit::graphs::star_with_fringe(1200);
        let ex = Planner::new(48).explain(&g, 3);
        let text = ex.render();
        assert!(text.contains("predicted ms"), "{text}");
        assert!(text.contains("<- chosen"), "{text}");
        assert!(text.contains("chosen: "), "{text}");
        // every candidate line is itself a parseable plan spec
        for c in &ex.candidates {
            let spec: PlanSpec = c.plan.to_string().parse().unwrap();
            assert_eq!(spec.fixed().unwrap(), c.plan);
            assert!(c.predicted_ms.is_finite() && c.predicted_ms > 0.0);
        }
        // the grid lookup finds the static-coarse baseline
        assert!(ex.candidate(Schedule::Static, Granularity::Coarse).is_some());
    }

    #[test]
    fn sticky_selection_is_order_independent() {
        let cand = |costs: &[f64]| -> Vec<PlanCandidate> {
            costs
                .iter()
                .map(|&predicted_ms| PlanCandidate {
                    plan: ExecutionPlan::fixed(
                        Schedule::Static,
                        Granularity::Coarse,
                        SupportMode::Full,
                    ),
                    predicted_ms,
                })
                .collect()
        };
        // regression for the incumbent-scan bug: on these two orderings
        // of the same cost multiset the old loop chose cost 4.7 for the
        // first and cost 4.6 for the second (the incumbent drifted to a
        // different margin base). The order-independent rule picks the
        // earliest candidate within the margin of the global best
        // (4.6 / 0.97 ≈ 4.742) — cost 4.7 — in both.
        let a = cand(&[5.0, 4.7, 4.8, 4.6]);
        let b = cand(&[5.0, 4.8, 4.7, 4.6]);
        assert_eq!(a[select_sticky(&a)].predicted_ms, 4.7);
        assert_eq!(b[select_sticky(&b)].predicted_ms, 4.7);
        // general contract on a drifting chain: within margin of best,
        // and no earlier candidate qualifies
        for costs in [
            vec![5.0, 4.8, 4.7, 4.6],
            vec![4.6, 4.7, 4.8, 5.0],
            vec![10.0, 9.71, 9.42],
            vec![1.0],
            vec![2.0, 2.0, 2.0],
        ] {
            let c = cand(&costs);
            let i = select_sticky(&c);
            let best = costs.iter().copied().fold(f64::INFINITY, f64::min);
            assert!(costs[i] * PLAN_SWITCH_MARGIN <= best, "{costs:?}");
            for (j, &cost) in costs.iter().enumerate().take(i) {
                assert!(cost * PLAN_SWITCH_MARGIN > best, "{costs:?} at {j}");
            }
        }
        // an exact tie keeps the earliest (simplest) candidate
        let tie = cand(&[2.0, 2.0, 2.0]);
        assert_eq!(select_sticky(&tie), 0);
    }

    #[test]
    fn hybrid_candidate_wins_the_comb_partner_rows() {
        // the comb's hub is a heavy *partner* row: the segment split
        // fans every heavy slot into ceil(live(hub)/len) partner-side
        // tasks, the bitmap representation into ceil(tail/len)
        // tail-side chunks — a task-count collapse both machine models
        // must see
        let g = crate::testkit::graphs::hub_divergence_comb(64, 256, 800);
        let ex = Planner::gpu().explain(&g, 3);
        let best = |gran: Granularity| -> f64 {
            ex.candidates
                .iter()
                .filter(|c| c.plan.granularity == gran)
                .map(|c| c.predicted_ms)
                .fold(f64::INFINITY, f64::min)
        };
        let hybrid = best(Granularity::Hybrid { len: ex.seg_len });
        assert!(hybrid.is_finite());
        assert!(
            hybrid < best(Granularity::Segment { len: ex.seg_len }),
            "hybrid {} vs segment {}",
            hybrid,
            best(Granularity::Segment { len: ex.seg_len })
        );
        assert!(hybrid < best(Granularity::Fine), "hybrid {} vs fine {}", hybrid, best(Granularity::Fine));
        // CPU model, same schedule point: the probe side strictly
        // shrinks total modeled work, so equal-work binning must win
        let cpu = Planner::new(48).explain(&g, 3);
        let at = |gran: Granularity| {
            cpu.candidate(Schedule::WorkAware, gran).expect("grid point").predicted_ms
        };
        assert!(
            at(Granularity::Hybrid { len: cpu.seg_len })
                < at(Granularity::Segment { len: cpu.seg_len })
        );
    }

    #[test]
    fn hybrid_candidate_degenerates_to_segment_off_the_hubs() {
        // a flat graph encodes no rows (every live length is below the
        // auto threshold), so the hybrid candidate's modeled cost list
        // must equal the segment candidate's exactly
        let g = crate::gen::grid::road(800, 1500, 0.05, &mut Rng::new(11));
        let z = crate::graph::ZCsr::from_csr(&g);
        let planner = Planner::new(8);
        let len = MIN_AUTO_SEGMENT_LEN;
        let seg = planner.static_task_costs(&z, Granularity::Segment { len });
        let hyb = planner.static_task_costs(&z, Granularity::Hybrid { len });
        assert_eq!(seg, hyb);
        for sched in [Schedule::Static, Schedule::WorkAware] {
            let s = planner.predict_pass_ms(&z, Granularity::Segment { len }, sched);
            let h = planner.predict_pass_ms(&z, Granularity::Hybrid { len }, sched);
            assert_eq!(s, h, "{sched}");
        }
    }

    #[test]
    fn split_segments_preserves_totals_and_bounds() {
        let est = [1u64, 5, 64, 200, 0];
        let pieces: Vec<u64> = split_segments(&est, 64).collect();
        assert!(pieces.iter().all(|&p| p <= 64));
        assert_eq!(pieces.iter().sum::<u64>(), est.iter().sum::<u64>());
        // a zero-cost entry still yields one (empty) task
        assert_eq!(split_segments(&[0], 8).count(), 1);
        assert_eq!(split_segments(&[200], 64).count(), 4);
    }

    #[test]
    fn planner_stamps_its_device_on_every_path() {
        assert_eq!(PlanDevice::Cpu.to_string(), "cpu");
        assert_eq!("gpu".parse::<PlanDevice>().unwrap(), PlanDevice::Gpu);
        assert!("tpu".parse::<PlanDevice>().is_err());
        // tiny shortcut, fixed spec, and scored grid all carry the
        // planner's device (the dispatch key the executing backends key
        // on), for both planners
        let tiny = crate::testkit::graphs::diamond();
        let comb = crate::testkit::graphs::hub_divergence_comb(48, 128, 400);
        let full: PlanSpec = "static/coarse/full".parse().unwrap();
        for (planner, device) in [
            (Planner::new(8), PlanDevice::Cpu),
            (Planner::gpu(), PlanDevice::Gpu),
        ] {
            assert_eq!(planner.choose(&tiny, 3).device, device);
            assert_eq!(planner.clone().with_spec(full).choose(&comb, 3).device, device);
            let ex = planner.explain(&comb, 3);
            assert!(ex.candidates.iter().all(|c| c.plan.device == device));
        }
        // the device never enters the printed plan grammar
        let plan = Planner::gpu().choose(&comb, 3);
        let spec: PlanSpec = plan.to_string().parse().unwrap();
        assert_eq!(spec.apply(plan), plan);
    }

    #[test]
    fn csr_native_scoring_matches_the_working_copy_path() {
        // satellite: admission-time scoring reads the canonical CSR —
        // the shaped task costs must equal the ZCsr path entry for
        // entry, for every granularity on both device models
        let fixtures = [
            crate::testkit::graphs::hub_divergence_comb(48, 128, 400),
            crate::testkit::graphs::peel_chain(24),
            crate::testkit::graphs::star_with_fringe(600),
        ];
        for g in &fixtures {
            let z = ZCsr::from_csr(g);
            for planner in [Planner::new(8), Planner::gpu()] {
                for gran in [
                    Granularity::Coarse,
                    Granularity::Fine,
                    Granularity::Segment { len: 32 },
                    Granularity::Hybrid { len: 32 },
                ] {
                    assert_eq!(
                        planner.static_task_costs_csr(g, gran),
                        planner.static_task_costs(&z, gran),
                        "{gran}"
                    );
                }
            }
        }
    }
}
