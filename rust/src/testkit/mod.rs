//! Mini property-testing kit. The offline crate set has no `proptest`,
//! so we ship the 10% of it the invariant tests need: seeded generation
//! of random inputs, a case loop with failure reporting, and greedy
//! input shrinking for graphs.

pub mod prop;
pub mod graphs;

pub use prop::{forall, Config};
