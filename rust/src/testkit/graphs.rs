//! Random-graph strategies and tiny fixture graphs with known truss
//! structure, shared by the property tests.

use crate::gen::rmat::{rmat, RmatParams};
use crate::graph::builder::from_sorted_unique;
use crate::graph::{Csr, Vid};
use crate::util::Rng;

/// Draw a small random graph from a mixed family (the families stress
/// different code paths: skew, tails, triangle density, no triangles).
pub fn arbitrary_graph(rng: &mut Rng) -> Csr {
    let n = rng.range(4, 200);
    let max_m = n * (n - 1) / 2;
    let m = rng.range(1, (4 * n).min(max_m) + 1);
    match rng.below(4) {
        0 => crate::gen::erdos_renyi::gnm(n, m, rng),
        1 => rmat(n.max(8), m, RmatParams::social(), rng),
        2 => rmat(n.max(8), m, RmatParams::autonomous_system(), rng),
        _ => crate::gen::community::communities(n.max(8), m, 12, rng),
    }
}

/// K_n clique.
pub fn clique(n: usize) -> Csr {
    let mut edges: Vec<(Vid, Vid)> = Vec::new();
    for u in 0..n as Vid {
        for v in (u + 1)..n as Vid {
            edges.push((u, v));
        }
    }
    from_sorted_unique(n, &edges)
}

/// Path graph 0-1-…-n-1 (triangle-free).
pub fn path(n: usize) -> Csr {
    let edges: Vec<(Vid, Vid)> = (0..n as Vid - 1).map(|u| (u, u + 1)).collect();
    from_sorted_unique(n, &edges)
}

/// The "diamond": two triangles sharing edge (0,2).
pub fn diamond() -> Csr {
    from_sorted_unique(4, &[(0, 1), (0, 2), (0, 3), (1, 2), (2, 3)])
}

/// Hub-and-fringe "hot row" graph: vertex 0 connects to every leaf and
/// consecutive leaves are chained, so every edge sits in a triangle
/// `(0, i, i+1)` while all of the merge work concentrates in row 0 —
/// the adversarial workload for coarse-grained scheduling.
pub fn star_with_fringe(leaves: usize) -> Csr {
    let mut edges: Vec<(Vid, Vid)> = Vec::new();
    for v in 1..=leaves as Vid {
        edges.push((0, v));
    }
    for v in 1..leaves as Vid {
        edges.push((v, v + 1));
    }
    edges.sort_unstable();
    from_sorted_unique(leaves + 1, &edges)
}

/// Hub-divergence "comb": the adversarial workload for *static GPU
/// scheduling at fine granularity*. `heavy` low-id rows each hold one
/// expensive nonzero — an edge to the hub, whose ~`span`-step merge
/// dwarfs the row's 31 trivial leaf edges — so every 32-slot warp in
/// the low-id region carries exactly one hot lane (maximal intra-warp
/// divergence), the hot warps are clustered at the front of the flat
/// index space (static contiguous waves pile them onto few
/// schedulers), and no single task is large enough for the serial tail
/// to mask the imbalance. `filler` rows of leaf-only edges pad the warp
/// count far past the scheduler-slot count.
pub fn hub_divergence_comb(heavy: usize, filler: usize, span: usize) -> Csr {
    let hub = (heavy + filler) as Vid;
    let far = hub + span as Vid; // last vertex of the hub's range
    let leaves: Vec<Vid> = (1..=30).map(|j| far + j).collect();
    let mut edges: Vec<(Vid, Vid)> = Vec::new();
    for i in 0..heavy as Vid {
        edges.push((i, hub));
        edges.push((i, far));
        for &l in &leaves {
            edges.push((i, l));
        }
    }
    for f in heavy as Vid..hub {
        for &l in &leaves {
            edges.push((f, l));
        }
    }
    for j in 1..=span as Vid {
        edges.push((hub, hub + j));
    }
    edges.sort_unstable();
    from_sorted_unique(far as usize + 31, &edges)
}

/// Deterministic **deep-cascade** fixture for the incremental support
/// driver: at k = 4 the peel front travels one gap-1 chain edge per
/// round from each end, so convergence takes ~`d/2` iterations with a
/// frontier of one or two edges each — the regime where recomputing
/// `S = AᵀA ∘ A` from scratch every round is maximally wasteful.
///
/// Structure: chain `x_0..x_d` with gap-1 edges `(x_j, x_{j+1})` and
/// gap-2 edges `(x_j, x_{j+2})`; every gap-2 edge is additionally the
/// diagonal of a private K4 `{x_j, x_{j+2}, r_j, s_j}` (support 2 from
/// the clique — stable at k = 4 forever). An interior gap-1 edge sits
/// in exactly the two strip triangles `(x_{j-1}, x_j, x_{j+1})` and
/// `(x_j, x_{j+1}, x_{j+2})` — support exactly 2, alive but with zero
/// slack — while the two end edges have support 1 and die in round
/// one. Each death destroys one strip triangle and drops the next
/// gap-1 edge to support 1: a strictly serial peel. The K4s and gap-2
/// edges survive as the final truss.
pub fn peel_chain(d: usize) -> Csr {
    assert!(d >= 4, "peel_chain needs a chain of at least 4 edges");
    let base = (d + 1) as Vid;
    let mut edges: Vec<(Vid, Vid)> = Vec::new();
    for j in 0..d as Vid {
        edges.push((j, j + 1));
    }
    for j in 0..(d as Vid - 1) {
        edges.push((j, j + 2));
        let r = base + 2 * j;
        let s = base + 2 * j + 1;
        edges.push((j, r));
        edges.push((j, s));
        edges.push((j + 2, r));
        edges.push((j + 2, s));
        edges.push((r, s));
    }
    edges.sort_unstable();
    from_sorted_unique(base as usize + 2 * (d - 1), &edges)
}

/// Deterministic **churn** fixture for the streaming maintenance path:
/// [`peel_chain`]`(d)` plus a mutation script of `batches` single-edge
/// batches that alternately delete and re-insert a K4 top edge
/// `(r_j, s_j)`, cycling through the blocks. At k = 4 every batch flips
/// the maintained truss: deleting `(r_j, s_j)` drops the four K4 spokes
/// to support 1 and the cascade takes the block's gap-2 diagonal with
/// them (−6 truss edges); re-inserting restores all six. Both
/// directions defeat the sound fast path (the delete removes truss
/// edges, the insert lands with support ≥ k − 2), so every batch
/// exercises the re-convergence tail — the fixture the streaming bench
/// and the serve-layer epoch tests replay.
pub fn churn_chain(d: usize, batches: usize) -> (Csr, Vec<crate::algo::stream::EdgeBatch>) {
    let g = peel_chain(d);
    let base = (d + 1) as Vid;
    let blocks = (d - 1) as Vid;
    let script = (0..batches)
        .map(|b| {
            let j = ((b / 2) as Vid) % blocks;
            let (r, s) = (base + 2 * j, base + 2 * j + 1);
            if b % 2 == 0 {
                crate::algo::stream::EdgeBatch::deletes(vec![(r, s)])
            } else {
                crate::algo::stream::EdgeBatch::inserts(vec![(r, s)])
            }
        })
        .collect();
    (g, script)
}

/// K5 with a pendant path — kmax 5, path trussness 2.
pub fn clique_with_tail() -> Csr {
    let mut edges: Vec<(Vid, Vid)> = Vec::new();
    for u in 0..5 as Vid {
        for v in (u + 1)..5 {
            edges.push((u, v));
        }
    }
    edges.extend([(4, 5), (5, 6)]);
    from_sorted_unique(7, &edges)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::validate;

    #[test]
    fn fixtures_are_valid() {
        for g in [clique(5), path(6), diamond(), clique_with_tail(), star_with_fringe(20)] {
            assert!(validate::check(&g).is_ok());
        }
        assert_eq!(clique(5).nnz(), 10);
        assert_eq!(path(6).nnz(), 5);
        assert_eq!(star_with_fringe(20).nnz(), 20 + 19);
    }

    #[test]
    fn comb_has_one_hot_slot_per_heavy_row() {
        let g = hub_divergence_comb(50, 100, 200);
        assert!(validate::check(&g).is_ok());
        assert_eq!(g.nnz(), 50 * 32 + 100 * 30 + 200);
        let z = crate::graph::ZCsr::from_csr(&g);
        let mut s = Vec::new();
        let tr = crate::cost::trace::trace_supports(&z, &mut s);
        // the hub-edge slot of each heavy row costs ~span steps, every
        // other slot of the row is trivial
        for i in 0..50 {
            let (start, _) = z.row_span(i);
            assert_eq!(tr.fine_steps[start], 200, "row {i} hub slot");
            assert!(tr.fine_steps[start + 1..start + 32].iter().all(|&st| st <= 1));
        }
    }

    #[test]
    fn peel_chain_cascades_serially() {
        let d = 12;
        let g = peel_chain(d);
        assert!(validate::check(&g).is_ok());
        // d gap-1 + (d-1) gap-2 + 5 per K4 helper
        assert_eq!(g.nnz(), d + (d - 1) * 6);
        let r = crate::algo::ktruss::ktruss(&g, 4, crate::algo::support::Mode::Fine);
        // the two fronts peel ~one edge per round each until they meet
        assert!(
            r.iterations >= d / 2,
            "expected a deep cascade, got {} iterations",
            r.iterations
        );
        // exactly the gap-1 chain dies; K4s and gap-2 diagonals survive
        assert_eq!(r.truss.nnz(), g.nnz() - d);
        // stable at k=3 (everything sits in at least one triangle)
        let r3 = crate::algo::ktruss::ktruss(&g, 3, crate::algo::support::Mode::Fine);
        assert_eq!(r3.truss.nnz(), g.nnz());
        assert_eq!(r3.iterations, 1);
    }

    #[test]
    fn churn_chain_truss_flips_every_batch() {
        let d = 8;
        let (g, script) = churn_chain(d, 6);
        assert_eq!(script.len(), 6);
        let full = g.nnz() - d; // the k=4 truss of the intact chain
        let mut st = crate::algo::stream::StreamState::new(&g, 4);
        assert_eq!(st.truss().nnz(), full);
        for (b, batch) in script.iter().enumerate() {
            let out = st.apply(batch);
            assert!(out.recomputed, "batch {b} must defeat the fast path");
            let want = if b % 2 == 0 { full - 6 } else { full };
            assert_eq!(out.truss_edges, want, "batch {b}");
            assert_eq!(st.truss().nnz(), want, "batch {b}");
        }
        // the script ends on an insert batch: the graph round-trips
        assert_eq!(st.graph(), &g);
    }

    #[test]
    fn arbitrary_graphs_are_valid() {
        let mut rng = Rng::new(42);
        for _ in 0..20 {
            let g = arbitrary_graph(&mut rng);
            assert!(validate::check(&g).is_ok());
            assert!(g.nnz() >= 1);
        }
    }
}
