//! Random-graph strategies and tiny fixture graphs with known truss
//! structure, shared by the property tests.

use crate::gen::rmat::{rmat, RmatParams};
use crate::graph::builder::from_sorted_unique;
use crate::graph::{Csr, Vid};
use crate::util::Rng;

/// Draw a small random graph from a mixed family (the families stress
/// different code paths: skew, tails, triangle density, no triangles).
pub fn arbitrary_graph(rng: &mut Rng) -> Csr {
    let n = rng.range(4, 200);
    let max_m = n * (n - 1) / 2;
    let m = rng.range(1, (4 * n).min(max_m) + 1);
    match rng.below(4) {
        0 => crate::gen::erdos_renyi::gnm(n, m, rng),
        1 => rmat(n.max(8), m, RmatParams::social(), rng),
        2 => rmat(n.max(8), m, RmatParams::autonomous_system(), rng),
        _ => crate::gen::community::communities(n.max(8), m, 12, rng),
    }
}

/// K_n clique.
pub fn clique(n: usize) -> Csr {
    let mut edges: Vec<(Vid, Vid)> = Vec::new();
    for u in 0..n as Vid {
        for v in (u + 1)..n as Vid {
            edges.push((u, v));
        }
    }
    from_sorted_unique(n, &edges)
}

/// Path graph 0-1-…-n-1 (triangle-free).
pub fn path(n: usize) -> Csr {
    let edges: Vec<(Vid, Vid)> = (0..n as Vid - 1).map(|u| (u, u + 1)).collect();
    from_sorted_unique(n, &edges)
}

/// The "diamond": two triangles sharing edge (0,2).
pub fn diamond() -> Csr {
    from_sorted_unique(4, &[(0, 1), (0, 2), (0, 3), (1, 2), (2, 3)])
}

/// K5 with a pendant path — kmax 5, path trussness 2.
pub fn clique_with_tail() -> Csr {
    let mut edges: Vec<(Vid, Vid)> = Vec::new();
    for u in 0..5 as Vid {
        for v in (u + 1)..5 {
            edges.push((u, v));
        }
    }
    edges.extend([(4, 5), (5, 6)]);
    from_sorted_unique(7, &edges)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::validate;

    #[test]
    fn fixtures_are_valid() {
        for g in [clique(5), path(6), diamond(), clique_with_tail()] {
            assert!(validate::check(&g).is_ok());
        }
        assert_eq!(clique(5).nnz(), 10);
        assert_eq!(path(6).nnz(), 5);
    }

    #[test]
    fn arbitrary_graphs_are_valid() {
        let mut rng = Rng::new(42);
        for _ in 0..20 {
            let g = arbitrary_graph(&mut rng);
            assert!(validate::check(&g).is_ok());
            assert!(g.nnz() >= 1);
        }
    }
}
