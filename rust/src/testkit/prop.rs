//! `forall`: run a property over many seeded random inputs and report
//! the first failing seed with its input.

use crate::util::Rng;

const DEFAULT_SEED: u64 = 0x5EED_0475;

/// Property-run configuration.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    /// Number of random cases.
    pub cases: usize,
    /// Base seed (per-case seeds derive from it). Fixed default keeps CI
    /// deterministic; set `KTRUSS_PROP_SEED` to explore new inputs.
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        let seed = std::env::var("KTRUSS_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(DEFAULT_SEED);
        Config { cases: 32, seed }
    }
}

impl Config {
    /// Default config with an explicit case count.
    pub fn cases(n: usize) -> Config {
        Config { cases: n, ..Default::default() }
    }
}

/// Run `prop` over `cfg.cases` inputs drawn by `generate`. Panics with
/// the failing case seed and debug repr on the first failure, so a
/// failure is reproducible by seeding `generate` with that value.
pub fn forall<T: std::fmt::Debug>(
    cfg: Config,
    mut generate: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut meta = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let case_seed = meta.next_u64();
        let mut rng = Rng::new(case_seed);
        let input = generate(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed at case {case} (seed {case_seed:#x}):\n  {msg}\n  input: {input:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        forall(
            Config::cases(10),
            |rng| rng.below(100),
            |&x| if x < 100 { Ok(()) } else { Err(format!("{x} >= 100")) },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        forall(
            Config::cases(50),
            |rng| rng.below(10),
            |&x| if x < 5 { Ok(()) } else { Err("too big".into()) },
        );
    }

    #[test]
    fn deterministic_across_runs() {
        let mut seen_a = Vec::new();
        forall(Config { cases: 5, seed: 7 }, |rng| rng.next_u64(), |&x| {
            seen_a.push(x);
            Ok(())
        });
        let mut seen_b = Vec::new();
        forall(Config { cases: 5, seed: 7 }, |rng| rng.next_u64(), |&x| {
            seen_b.push(x);
            Ok(())
        });
        assert_eq!(seen_a, seen_b);
    }
}
