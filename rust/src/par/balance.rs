//! Work-aware load balancing for the support/prune kernels — the
//! schedule-level complement to the paper's task-granularity argument.
//!
//! The paper (§III-A) shows that coarse-grained Eager K-truss is limited
//! by the *distribution* of per-task cost, not by available parallelism,
//! and fixes it by shrinking the task (one task per nonzero). This
//! module attacks the same imbalance along the orthogonal axis the
//! related work explores: keep the task definition, change the
//! *schedule*. Each piece maps to a published technique:
//!
//! * **Cost estimation** ([`estimate_costs`]) — per-task work bounds
//!   read directly off the zero-terminated CSR: a fine task's merge
//!   over slot `p` of row `i` with partner row `κ` executes at most
//!   `tail(i, p) + live(κ)` steps (each step advances one of the two
//!   pointers), and a coarse task is the sum over its row's live slots.
//!   This is the static analogue of the exact per-task traces
//!   [`crate::cost::trace`] measures.
//! * **Scan-based binning** ([`scan_bins`], [`Schedule::WorkAware`]) —
//!   the Hornet K-truss `ScanBased`/`BinarySearch` load-balancing
//!   idiom (SNIPPETS.md Snippet 1): prefix-sum the estimated costs,
//!   then binary-search the `w·total/W` quantiles so each of the `W`
//!   workers receives one contiguous chunk of approximately equal
//!   *work* (not equal *count*). Guaranteed: every chunk's work is at
//!   most `total/W + max_single_cost`.
//! * **Work stealing** ([`run_stealing`], [`Schedule::Stealing`]) —
//!   the dynamic strategy of "Dynamic Load Balancing Strategies for
//!   Graph Applications on GPUs" (PAPERS.md): workers own chunk deques
//!   (seeded by the same scan binning, several chunks per worker) and
//!   steal from a victim's tail when their own deque drains. Cost
//!   *estimation errors* — the one thing static binning cannot fix —
//!   are absorbed at runtime. The implementation never blocks (a
//!   worker exits after one full empty sweep, and tasks never spawn
//!   new work), so there is no lost-wakeup or deadlock state by
//!   construction; the `integration_balance` stress test exercises the
//!   many-threads-few-tasks corner.
//!
//! [`Schedule::WorkAware`]: super::pool::Schedule::WorkAware
//! [`Schedule::Stealing`]: super::pool::Schedule::Stealing

use crate::algo::support::{Granularity, Mode};
use crate::graph::{Csr, ZCsr};
use std::collections::VecDeque;
use std::sync::Mutex;

/// How many scan-binned chunks each worker's deque is seeded with under
/// [`Schedule::Stealing`](super::pool::Schedule::Stealing). More chunks
/// → finer stealing granularity but more queue traffic; 4 matches the
/// over-decomposition factor the GPU load-balancing literature uses.
pub const STEAL_CHUNKS_PER_WORKER: usize = 4;

/// Estimated cost (in merge steps, ≥ 1) of every task of one support
/// pass: one entry per **row** for [`Mode::Coarse`], one entry per
/// **slot** for [`Mode::Fine`]. Terminator/tombstone slots cost 1 (the
/// terminator check itself).
pub fn estimate_costs(z: &ZCsr, mode: Mode) -> Vec<u64> {
    let n = z.n();
    let col = z.col();
    // live entries per row (rows are kept compacted by prune)
    let live: Vec<u32> = (0..n).map(|i| z.row_live(i).len() as u32).collect();
    match mode {
        Mode::Coarse => (0..n)
            .map(|i| {
                let (start, _) = z.row_span(i);
                let li = live[i] as usize;
                let mut cost = 1u64;
                for off in 0..li {
                    let kappa = col[start + off] as usize;
                    let tail = (li - off - 1) as u64;
                    cost += 1 + tail + live[kappa] as u64;
                }
                cost
            })
            .collect(),
        Mode::Fine => {
            let mut costs = vec![1u64; z.slots()];
            for i in 0..n {
                let (start, _) = z.row_span(i);
                let li = live[i] as usize;
                for off in 0..li {
                    let kappa = col[start + off] as usize;
                    let tail = (li - off - 1) as u64;
                    costs[start + off] = 1 + tail + live[kappa] as u64;
                }
            }
            costs
        }
    }
}

/// [`estimate_costs`] straight off the canonical [`Csr`] — the
/// admission-time variant the planner scores with, so choosing a plan
/// allocates no scratch zero-terminated working copy. A fresh
/// zero-terminated row is exactly its CSR row followed by one
/// terminator slot, so the output is entry-for-entry identical to
/// `estimate_costs(&ZCsr::from_csr(g), mode)`: the fine vector carries
/// each row's live costs followed by one cost-1 terminator entry.
pub fn estimate_costs_csr(g: &Csr, mode: Mode) -> Vec<u64> {
    let n = g.n();
    match mode {
        Mode::Coarse => (0..n)
            .map(|i| {
                let row = g.row(i);
                let li = row.len();
                let mut cost = 1u64;
                for (off, &kappa) in row.iter().enumerate() {
                    let tail = (li - off - 1) as u64;
                    cost += 1 + tail + g.row(kappa as usize).len() as u64;
                }
                cost
            })
            .collect(),
        Mode::Fine => {
            let mut costs = Vec::with_capacity(g.nnz() + n);
            for i in 0..n {
                let row = g.row(i);
                let li = row.len();
                for (off, &kappa) in row.iter().enumerate() {
                    let tail = (li - off - 1) as u64;
                    costs.push(1 + tail + g.row(kappa as usize).len() as u64);
                }
                // the row's terminator slot
                costs.push(1);
            }
            costs
        }
    }
}

/// Sum of [`estimate_costs`] without materializing the per-task vector
/// — the allocation-free variant the sequential convergence drivers use
/// for their per-round auto-crossover check (they need only the total,
/// never the per-task breakdown; the ROADMAP's "sum-only estimate
/// variants" follow-up). Exactly equals
/// `estimate_costs(z, mode).iter().sum()`.
pub fn estimate_costs_sum(z: &ZCsr, mode: Mode) -> u64 {
    let n = z.n();
    let col = z.col();
    let live: Vec<u32> = (0..n).map(|i| z.row_live(i).len() as u32).collect();
    let mut total = 0u64;
    match mode {
        Mode::Coarse => {
            for i in 0..n {
                let (start, _) = z.row_span(i);
                let li = live[i] as usize;
                total += 1;
                for off in 0..li {
                    let kappa = col[start + off] as usize;
                    let tail = (li - off - 1) as u64;
                    total += 1 + tail + live[kappa] as u64;
                }
            }
        }
        Mode::Fine => {
            // every slot costs at least 1 (terminators/tombstones), live
            // slots cost 1 + tail + partner instead
            total = z.slots() as u64;
            for i in 0..n {
                let (start, _) = z.row_span(i);
                let li = live[i] as usize;
                for off in 0..li {
                    let kappa = col[start + off] as usize;
                    let tail = (li - off - 1) as u64;
                    total += tail + live[kappa] as u64;
                }
            }
        }
    }
    total
}

/// A per-task cost vector for one support/prune pass, tagged by how it
/// was obtained. Two sources:
///
/// * [`Costs::estimate`] — the static upper bounds of
///   [`estimate_costs`] (all the binner has before the first pass);
/// * [`Costs::from_trace`] — *measured* per-slot merge steps from the
///   previous pass (either the in-situ measurement `ktruss_par`
///   records, or a [`crate::cost::trace::SupportTrace`] from the replay
///   driver). As pruning skews rows away from the static bounds, the
///   measured costs keep the scan bins tight — the ROADMAP's
///   "feed measured traces back into the work-aware binner" item.
///
/// Slots that died since the measurement (terminators/tombstones) are
/// masked to cost 1; surviving entries may have shifted within their
/// row under prune-compaction, so fine-grained trace costs are a
/// per-row-faithful approximation rather than exact per-slot truth —
/// which is all scan binning needs.
#[derive(Clone, Debug)]
pub struct Costs {
    /// One entry per task (row for [`Mode::Coarse`], slot for
    /// [`Mode::Fine`]), every entry ≥ 1.
    pub per_task: Vec<u64>,
}

impl Costs {
    /// Static upper bounds read off the current working form.
    pub fn estimate(z: &ZCsr, mode: Mode) -> Costs {
        Costs { per_task: estimate_costs(z, mode) }
    }

    /// Measured per-slot merge steps from the previous pass
    /// (`fine_steps.len() == z.slots()`), masked against the *current*
    /// working form `z` (post-prune) and aggregated to `mode`'s task
    /// granularity.
    pub fn from_trace(fine_steps: &[u32], z: &ZCsr, mode: Mode) -> Costs {
        assert_eq!(fine_steps.len(), z.slots(), "one measured step count per slot");
        let col = z.col();
        let per_task = match mode {
            Mode::Fine => (0..z.slots())
                .map(|p| if col[p] == 0 { 1 } else { (fine_steps[p] as u64).max(1) })
                .collect(),
            Mode::Coarse => (0..z.n())
                .map(|i| {
                    let (start, end) = z.row_span(i);
                    let mut cost = 1u64;
                    for p in start..end {
                        if col[p] == 0 {
                            break;
                        }
                        cost += (fine_steps[p] as u64).max(1);
                    }
                    cost
                })
                .collect(),
        };
        Costs { per_task }
    }

    /// Per-task base merge steps derived from a measured trace using
    /// only the row layout — **the one shared derivation both timing
    /// models consume** ([`crate::sim::cpu`] and [`crate::sim::gpu`]
    /// both call this, so their task-cost views cannot drift; each
    /// model then adds its own per-task overhead constants on top).
    ///
    /// `fine_steps` holds the traced merge steps per slot (0 for
    /// terminators/tombstones, exactly what
    /// [`crate::cost::trace::SupportTrace`] records) and `row_ptr` the
    /// zero-terminated row layout at the time of the pass. Tasks:
    ///
    /// * [`Granularity::Coarse`] — one task per row: `1 + Σ` of its
    ///   slots' steps (the `+1` keeps the ≥ 1 invariant for empty rows);
    /// * [`Granularity::Fine`] — one task per slot: `max(steps, 1)`;
    /// * [`Granularity::Segment`] — each *worked* slot's steps split
    ///   into `ceil(steps/len)` tasks of ≤ `len` steps (the modeled
    ///   analogue of the real kernel's partner-row segments, which
    ///   bound each segment's merge by its length plus the in-range
    ///   tail). Zero-step slots produce **no** tasks, mirroring
    ///   [`crate::algo::support::segment_tasks`], which enumerates
    ///   nothing for terminators/tombstones and trivially empty merges;
    /// * [`Granularity::Hybrid`] — same ≤ `len` split: both of the
    ///   hybrid pass's task kinds (tail-side probe chunks and
    ///   partner-side merge segments) are ≤ `len`-bounded, so the
    ///   trace-shape view is the same piecewise decomposition. (The
    ///   *planner* scores hybrid from its real task enumeration — see
    ///   [`crate::plan`] — since a merge trace cannot reveal which
    ///   pieces become uniform probes.)
    pub fn from_trace_rows(fine_steps: &[u32], row_ptr: &[u32], gran: Granularity) -> Costs {
        let slots = *row_ptr.last().expect("row_ptr is never empty") as usize;
        assert_eq!(fine_steps.len(), slots, "one traced step count per slot");
        let per_task = match gran {
            Granularity::Coarse => (0..row_ptr.len() - 1)
                .map(|i| {
                    let (s, e) = (row_ptr[i] as usize, row_ptr[i + 1] as usize);
                    1 + fine_steps[s..e].iter().map(|&x| x as u64).sum::<u64>()
                })
                .collect(),
            Granularity::Fine => fine_steps.iter().map(|&st| (st as u64).max(1)).collect(),
            Granularity::Segment { len } | Granularity::Hybrid { len } => {
                let len = len.max(1);
                let mut tasks = Vec::with_capacity(fine_steps.len());
                for &st in fine_steps {
                    let mut left = st;
                    while left > 0 {
                        let seg = left.min(len);
                        tasks.push(seg as u64);
                        left -= seg;
                    }
                }
                tasks
            }
        };
        Costs { per_task }
    }

    /// Per-task base steps of one **incremental frontier pass**
    /// ([`crate::algo::incremental`]), derived from the traced
    /// per-frontier-task step counts — the frontier analogue of
    /// [`Costs::from_trace_rows`], and likewise the one shared
    /// derivation both timing models consume. `task_steps[i]` is the
    /// exact steps of frontier task `i` and `task_rows[i]` the row of
    /// its dying edge (ascending, as `mark_frontier` emits). Tasks:
    ///
    /// * [`Granularity::Coarse`] — one task per frontier *row*: `1 + Σ`
    ///   of its dying edges' steps (the row-grouped enumeration
    ///   `decrement_frontier_par_gran` runs);
    /// * [`Granularity::Fine`] — one task per dying edge:
    ///   `max(steps, 1)`;
    /// * [`Granularity::Segment`] / [`Granularity::Hybrid`] — each
    ///   task's steps split into `ceil(steps/len)` pieces of ≤ `len`
    ///   steps (zero-step tasks still contribute one unit task — the
    ///   enumeration itself runs even when it finds no triangle). The
    ///   frontier walk is representation-agnostic, so hybrid shares the
    ///   segment decomposition.
    pub fn from_frontier(task_steps: &[u32], task_rows: &[u32], gran: Granularity) -> Costs {
        assert_eq!(task_steps.len(), task_rows.len(), "one row per frontier task");
        let per_task = match gran {
            Granularity::Fine => task_steps.iter().map(|&st| (st as u64).max(1)).collect(),
            Granularity::Coarse => {
                let mut tasks: Vec<u64> = Vec::new();
                let mut i = 0usize;
                while i < task_steps.len() {
                    let row = task_rows[i];
                    let mut cost = 1u64;
                    while i < task_steps.len() && task_rows[i] == row {
                        cost += task_steps[i] as u64;
                        i += 1;
                    }
                    tasks.push(cost);
                }
                tasks
            }
            Granularity::Segment { len } | Granularity::Hybrid { len } => {
                let len = len.max(1);
                let mut tasks = Vec::with_capacity(task_steps.len());
                for &st in task_steps {
                    if st == 0 {
                        tasks.push(1);
                        continue;
                    }
                    let mut left = st;
                    while left > 0 {
                        let seg = left.min(len);
                        tasks.push(seg as u64);
                        left -= seg;
                    }
                }
                tasks
            }
        };
        Costs { per_task }
    }

    /// Number of tasks covered.
    pub fn len(&self) -> usize {
        self.per_task.len()
    }

    /// Whether the pass has no tasks at all.
    pub fn is_empty(&self) -> bool {
        self.per_task.is_empty()
    }
}

/// Trace-shape decomposition of one **hybrid** support pass into its
/// two task kinds: `(merge_pieces, probe_pieces)` in steps.
///
/// [`Costs::from_trace_rows`] charges [`Granularity::Hybrid`] like
/// [`Granularity::Segment`] because a merge trace alone cannot reveal
/// which slots the hybrid pass turns into uniform bitmap probes. Given
/// the pass's *column array* as well, the representation selection of
/// [`crate::algo::bitmap::BitmapIndex::build`] can be mirrored exactly
/// from the trace arrays:
///
/// * a partner row `κ` is bitmap-encoded iff its live length reaches
///   `len` and its dense encoding passes the density guard
///   (`words ≤ live`, with `words` read off the row's first/last live
///   column values — the same arithmetic `RowBitmap::encode` performs);
/// * a live slot with a non-empty tail and an encoded partner becomes
///   tail-side **probe chunks** of ≤ `len` entries, each costing
///   *exactly* its chunk length (the kernels execute one uniform probe
///   per entry — see [`crate::algo::bitmap::BitmapTask::estimated_steps`]);
/// * every other slot keeps the segment decomposition of its traced
///   merge steps (≤ `len`-step pieces), as in [`Costs::from_trace_rows`].
///
/// The timing models price the two kinds with different per-task
/// overheads (probe chunks are branch-free word lookups), which is what
/// lets the simulators see the representation win the planner's static
/// enumeration already scores.
pub fn hybrid_trace_pieces(
    fine_steps: &[u32],
    row_ptr: &[u32],
    col: &[u32],
    live_per_row: &[u32],
    len: u32,
) -> (Vec<u64>, Vec<u64>) {
    let slots = *row_ptr.last().expect("row_ptr is never empty") as usize;
    assert_eq!(fine_steps.len(), slots, "one traced step count per slot");
    assert_eq!(col.len(), slots, "one column value per slot");
    let n = row_ptr.len() - 1;
    assert_eq!(live_per_row.len(), n, "one live count per row");
    let len = len.max(1);
    let threshold = len as usize;
    // mirror BitmapIndex::build's selection: live ≥ threshold plus the
    // words ≤ live density guard over the row-local value universe
    let encoded: Vec<bool> = (0..n)
        .map(|kappa| {
            let lk = live_per_row[kappa] as usize;
            if lk < threshold || lk == 0 {
                return false;
            }
            let r0 = row_ptr[kappa] as usize;
            let (first, last) = (col[r0], col[r0 + lk - 1]);
            let words = ((last.saturating_sub(first)) as usize >> 6) + 1;
            words <= lk
        })
        .collect();
    let mut merge = Vec::new();
    let mut probe = Vec::new();
    for i in 0..n {
        let start = row_ptr[i] as usize;
        let li = live_per_row[i] as usize;
        for off in 0..li {
            let p = start + off;
            let kappa = col[p] as usize;
            let tail_len = li - off - 1;
            if tail_len > 0 && encoded[kappa] {
                // tail-side probe chunks: cost is exactly the chunk
                // length, the shape hybrid_tasks enumerates
                let mut left = tail_len as u32;
                while left > 0 {
                    let c = left.min(len);
                    probe.push(c as u64);
                    left -= c;
                }
            } else {
                // merge-representation partner: the traced steps split
                // into ≤ len pieces, as in Costs::from_trace_rows
                let mut left = fine_steps[p];
                while left > 0 {
                    let seg = left.min(len);
                    merge.push(seg as u64);
                    left -= seg;
                }
            }
        }
    }
    (merge, probe)
}

/// Scan-based binning: pack `costs.len()` tasks into `bins` contiguous
/// half-open ranges of approximately equal total cost, via prefix sums
/// and quantile binary search. The ranges partition `0..costs.len()`
/// exactly (some may be empty), in order.
///
/// Balance guarantee: every bin's work ≤ `total/bins + max(costs)`
/// (the quantile boundary can overshoot by at most one task).
pub fn scan_bins(costs: &[u64], bins: usize) -> Vec<(usize, usize)> {
    let n = costs.len();
    let bins = bins.max(1);
    let mut prefix: Vec<u64> = Vec::with_capacity(n + 1);
    prefix.push(0);
    let mut acc = 0u64;
    for &c in costs {
        acc = acc.saturating_add(c);
        prefix.push(acc);
    }
    let total = acc;
    let mut out = Vec::with_capacity(bins);
    let mut lo = 0usize;
    for w in 1..=bins {
        let hi = if w == bins {
            n
        } else {
            let target = ((total as u128) * (w as u128) / (bins as u128)) as u64;
            // first index whose prefix reaches the quantile — the
            // boundary task lands in the *current* bin, so a single
            // giant task is isolated rather than pushed downstream
            prefix.partition_point(|&x| x < target).clamp(lo, n)
        };
        out.push((lo, hi));
        lo = hi;
    }
    out
}

/// Split `0..n` into `chunks` contiguous ranges of approximately equal
/// *count* (the cost-oblivious fallback when no estimate is available).
pub fn even_chunks(n: usize, chunks: usize) -> Vec<(usize, usize)> {
    let chunks = chunks.max(1).min(n.max(1));
    (0..chunks).map(|c| (n * c / chunks, n * (c + 1) / chunks)).collect()
}

/// Execute `chunks` on `workers` threads with work stealing, invoking
/// `run_chunk(worker, lo, hi)` once per chunk. Chunks are dealt
/// round-robin into per-worker deques; a worker pops its own deque from
/// the front and steals from a victim's back when empty. Workers never
/// block: one full empty sweep means global completion (chunks cannot
/// spawn chunks), so the worker exits.
pub fn run_stealing_chunks(
    workers: usize,
    chunks: Vec<(usize, usize)>,
    run_chunk: impl Fn(usize, usize, usize) + Sync,
) {
    let workers = workers.max(1);
    if workers == 1 {
        for (lo, hi) in chunks {
            run_chunk(0, lo, hi);
        }
        return;
    }
    let queues: Vec<Mutex<VecDeque<(usize, usize)>>> =
        (0..workers).map(|_| Mutex::new(VecDeque::new())).collect();
    for (idx, (lo, hi)) in chunks.into_iter().enumerate() {
        if lo < hi {
            queues[idx % workers].lock().unwrap().push_back((lo, hi));
        }
    }
    std::thread::scope(|scope| {
        for w in 0..workers {
            let run_chunk = &run_chunk;
            let queues = &queues;
            scope.spawn(move || loop {
                let own = queues[w].lock().unwrap().pop_front();
                let (lo, hi) = match own {
                    Some(c) => c,
                    None => {
                        let mut stolen = None;
                        for off in 1..workers {
                            let victim = (w + off) % workers;
                            if let Some(c) = queues[victim].lock().unwrap().pop_back() {
                                stolen = Some(c);
                                break;
                            }
                        }
                        match stolen {
                            Some(c) => c,
                            None => break, // all deques empty — done
                        }
                    }
                };
                run_chunk(w, lo, hi);
            });
        }
    });
}

/// Per-index convenience over [`run_stealing_chunks`]: `f(worker, i)`
/// for every index covered by `chunks`, each exactly once.
pub fn run_stealing(
    workers: usize,
    chunks: Vec<(usize, usize)>,
    f: impl Fn(usize, usize) + Sync,
) {
    run_stealing_chunks(workers, chunks, |w, lo, hi| {
        for i in lo..hi {
            f(w, i);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::from_sorted_unique;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn csr_native_estimates_match_the_fresh_working_copy() {
        let fixtures = [
            crate::testkit::graphs::hub_divergence_comb(48, 128, 400),
            crate::testkit::graphs::peel_chain(24),
            crate::testkit::graphs::star_with_fringe(40),
            crate::testkit::graphs::diamond(),
        ];
        for g in &fixtures {
            let z = crate::graph::ZCsr::from_csr(g);
            for mode in [Mode::Coarse, Mode::Fine] {
                assert_eq!(
                    estimate_costs_csr(g, mode),
                    estimate_costs(&z, mode),
                    "Csr-native {mode} estimates must be entry-identical to the ZCsr bounds"
                );
            }
        }
    }

    #[test]
    fn hybrid_trace_pieces_mirror_the_real_task_enumeration() {
        // hub graph: the bitmap selection must fire for the hub rows,
        // and the probe pieces must reproduce hybrid_tasks' exact
        // per-chunk probe counts
        let g = crate::testkit::graphs::hub_divergence_comb(64, 256, 800);
        let z = crate::graph::ZCsr::from_csr(&g);
        let mut s = Vec::new();
        let tr = crate::cost::trace::trace_supports(&z, &mut s);
        let len = 32u32;
        let (merge, probe) =
            hybrid_trace_pieces(&tr.fine_steps, z.row_ptr(), z.col(), &tr.live_per_row, len);
        let ht = crate::algo::bitmap::hybrid_tasks(&z, len);
        // probe chunks are exact: same count, same total probe steps
        assert_eq!(probe.len(), ht.probe.len());
        let want_probe: u64 = ht
            .probe
            .iter()
            .map(crate::algo::bitmap::BitmapTask::estimated_steps)
            .sum();
        assert_eq!(probe.iter().sum::<u64>(), want_probe);
        assert!(!probe.is_empty(), "hub rows must select the bitmap representation");
        // merge pieces decompose the remaining traced steps into ≤ len
        // chunks; their total is the trace total minus the slots that
        // went to probes
        assert!(merge.iter().all(|&c| c >= 1 && c <= len as u64));
        assert!(probe.iter().all(|&c| c >= 1 && c <= len as u64));
        assert!(merge.iter().sum::<u64>() <= tr.total_steps);
        // no-hub fixture: nothing reaches the threshold, so the split
        // degenerates to the segment decomposition
        let g2 = from_sorted_unique(4, &[(0, 1), (0, 2), (0, 3), (1, 2), (2, 3)]);
        let z2 = crate::graph::ZCsr::from_csr(&g2);
        let mut s2 = Vec::new();
        let tr2 = crate::cost::trace::trace_supports(&z2, &mut s2);
        let (m2, p2) =
            hybrid_trace_pieces(&tr2.fine_steps, z2.row_ptr(), z2.col(), &tr2.live_per_row, 64);
        assert!(p2.is_empty());
        let seg = Costs::from_trace_rows(
            &tr2.fine_steps,
            z2.row_ptr(),
            Granularity::Segment { len: 64 },
        );
        assert_eq!(m2, seg.per_task);
    }

    #[test]
    fn scan_bins_partition_exactly() {
        let costs: Vec<u64> = (0..97).map(|i| (i % 7) + 1).collect();
        for bins in [1usize, 2, 3, 8, 97, 200] {
            let b = scan_bins(&costs, bins);
            assert_eq!(b.len(), bins.max(1));
            assert_eq!(b[0].0, 0);
            assert_eq!(b[b.len() - 1].1, costs.len());
            for w in b.windows(2) {
                assert_eq!(w[0].1, w[1].0, "bins must be contiguous");
                assert!(w[0].0 <= w[0].1);
            }
        }
    }

    #[test]
    fn scan_bins_balance_bound() {
        // heavily skewed costs: one giant task among many small ones
        let mut costs = vec![2u64; 500];
        costs[137] = 10_000;
        let bins = 8;
        let b = scan_bins(&costs, bins);
        let total: u64 = costs.iter().sum();
        let max_cost = *costs.iter().max().unwrap();
        for &(lo, hi) in &b {
            let work: u64 = costs[lo..hi].iter().sum();
            assert!(
                work <= total / bins as u64 + max_cost + 1,
                "bin [{lo},{hi}) work {work} exceeds bound"
            );
        }
    }

    #[test]
    fn scan_bins_uniform_costs_are_even_blocks() {
        let costs = vec![3u64; 64];
        let b = scan_bins(&costs, 4);
        assert_eq!(b, vec![(0, 16), (16, 32), (32, 48), (48, 64)]);
    }

    #[test]
    fn scan_bins_empty_costs() {
        assert_eq!(scan_bins(&[], 4), vec![(0, 0); 4]);
    }

    #[test]
    fn even_chunks_cover() {
        for (n, k) in [(10usize, 3usize), (0, 4), (5, 9), (100, 1)] {
            let c = even_chunks(n, k);
            let covered: usize = c.iter().map(|(lo, hi)| hi - lo).sum();
            assert_eq!(covered, n, "n={n} k={k}");
            if let Some(&(lo, _)) = c.first() {
                assert_eq!(lo, 0);
            }
            if let Some(&(_, hi)) = c.last() {
                assert_eq!(hi, n);
            }
        }
    }

    #[test]
    fn stealing_covers_every_index_once() {
        let n = 1000;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        let chunks = even_chunks(n, 13);
        run_stealing(4, chunks, |_, i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn stealing_more_workers_than_chunks_terminates() {
        // the many-threads-few-tasks corner: most workers find every
        // deque empty and must exit after one sweep
        let n = 3;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        run_stealing(16, vec![(0, 1), (1, 2), (2, 3)], |_, i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn stealing_no_chunks_is_noop() {
        run_stealing(8, Vec::new(), |_, _| panic!("no work exists"));
    }

    #[test]
    fn estimate_costs_shapes_and_bounds() {
        // diamond: row0 [1,2,3,0] row1 [2,0] row2 [3,0] row3 [0]
        let g = from_sorted_unique(4, &[(0, 1), (0, 2), (0, 3), (1, 2), (2, 3)]);
        let z = crate::graph::ZCsr::from_csr(&g);
        let fine = estimate_costs(&z, Mode::Fine);
        assert_eq!(fine.len(), z.slots());
        assert!(fine.iter().all(|&c| c >= 1));
        let coarse = estimate_costs(&z, Mode::Coarse);
        assert_eq!(coarse.len(), z.n());
        // the coarse estimate dominates the exact trace (upper bound)
        let mut s = Vec::new();
        let tr = crate::cost::trace::trace_supports(&z, &mut s);
        for i in 0..z.n() {
            assert!(
                coarse[i] >= tr.row_steps(z.row_ptr(), i),
                "row {i}: estimate {} below actual {}",
                coarse[i],
                tr.row_steps(z.row_ptr(), i)
            );
        }
    }

    #[test]
    fn estimate_costs_sum_matches_vector_sum() {
        let graphs = [
            from_sorted_unique(4, &[(0, 1), (0, 2), (0, 3), (1, 2), (2, 3)]),
            crate::gen::rmat::rmat(
                200,
                1500,
                crate::gen::rmat::RmatParams::autonomous_system(),
                &mut crate::util::Rng::new(3),
            ),
            crate::graph::Csr::empty(5),
        ];
        for g in &graphs {
            let mut z = crate::graph::ZCsr::from_csr(g);
            for mode in [Mode::Coarse, Mode::Fine] {
                let want: u64 = estimate_costs(&z, mode).iter().sum();
                assert_eq!(estimate_costs_sum(&z, mode), want, "{mode}");
            }
            // and after a prune-style mutation (tombstoned tail)
            if z.slots() > 2 {
                let (start, end) = z.row_span(0);
                for p in start..end {
                    z.col_mut()[p] = 0;
                }
                for mode in [Mode::Coarse, Mode::Fine] {
                    let want: u64 = estimate_costs(&z, mode).iter().sum();
                    assert_eq!(estimate_costs_sum(&z, mode), want, "pruned {mode}");
                }
            }
        }
    }

    #[test]
    fn costs_from_trace_match_measured_steps_on_fresh_graph() {
        let g = from_sorted_unique(4, &[(0, 1), (0, 2), (0, 3), (1, 2), (2, 3)]);
        let z = crate::graph::ZCsr::from_csr(&g);
        let mut s = Vec::new();
        let tr = crate::cost::trace::trace_supports(&z, &mut s);
        let fine = Costs::from_trace(&tr.fine_steps, &z, Mode::Fine);
        assert_eq!(fine.len(), z.slots());
        for (p, &c) in fine.per_task.iter().enumerate() {
            assert_eq!(c, (tr.fine_steps[p] as u64).max(1), "slot {p}");
            assert!(c >= 1);
        }
        let coarse = Costs::from_trace(&tr.fine_steps, &z, Mode::Coarse);
        assert_eq!(coarse.len(), z.n());
        for i in 0..z.n() {
            // row cost = 1 (overhead) + sum of max(step, 1) over live slots
            let (start, _) = z.row_span(i);
            let want: u64 = 1 + z
                .row_live(i)
                .iter()
                .enumerate()
                .map(|(off, _)| (tr.fine_steps[start + off] as u64).max(1))
                .sum::<u64>();
            assert_eq!(coarse.per_task[i], want, "row {i}");
        }
    }

    #[test]
    fn costs_from_trace_mask_dead_slots() {
        // kill row 0 entirely: its slots must cost 1 regardless of the
        // (stale) measured steps
        let g = from_sorted_unique(4, &[(0, 1), (0, 2), (0, 3), (1, 2), (2, 3)]);
        let mut z = crate::graph::ZCsr::from_csr(&g);
        let stale = vec![50u32; z.slots()];
        let (start, end) = z.row_span(0);
        for p in start..end {
            z.col_mut()[p] = 0;
        }
        let fine = Costs::from_trace(&stale, &z, Mode::Fine);
        for p in start..end {
            assert_eq!(fine.per_task[p], 1, "dead slot {p}");
        }
        let coarse = Costs::from_trace(&stale, &z, Mode::Coarse);
        assert_eq!(coarse.per_task[0], 1, "dead row");
        assert!(coarse.per_task[1] > 1, "live row keeps measured cost");
    }

    #[test]
    fn costs_from_trace_rows_all_granularities() {
        let g = from_sorted_unique(4, &[(0, 1), (0, 2), (0, 3), (1, 2), (2, 3)]);
        let z = crate::graph::ZCsr::from_csr(&g);
        let mut s = Vec::new();
        let tr = crate::cost::trace::trace_supports(&z, &mut s);
        // fine: max(steps, 1) per slot
        let fine = Costs::from_trace_rows(&tr.fine_steps, z.row_ptr(), Granularity::Fine);
        assert_eq!(fine.len(), z.slots());
        for (p, &c) in fine.per_task.iter().enumerate() {
            assert_eq!(c, (tr.fine_steps[p] as u64).max(1), "slot {p}");
        }
        // coarse: 1 + row sum, and totals line up with the tracer
        let coarse = Costs::from_trace_rows(&tr.fine_steps, z.row_ptr(), Granularity::Coarse);
        assert_eq!(coarse.len(), z.n());
        for i in 0..z.n() {
            assert_eq!(coarse.per_task[i], 1 + tr.row_steps(z.row_ptr(), i), "row {i}");
        }
        // segment: pieces are ≤ len, every piece ≥ 1, the split
        // preserves the total traced steps exactly, and zero-step slots
        // (terminators, tombstones, empty merges) contribute no tasks —
        // just like the real segment kernel's task enumeration
        for len in [1u32, 2, 64] {
            let seg =
                Costs::from_trace_rows(&tr.fine_steps, z.row_ptr(), Granularity::Segment { len });
            assert!(seg.per_task.iter().all(|&c| c >= 1 && c <= len.max(1) as u64));
            assert_eq!(seg.per_task.iter().sum::<u64>(), tr.total_steps, "len={len}");
            let want_tasks: usize = tr
                .fine_steps
                .iter()
                .map(|&st| (st as usize).div_ceil(len as usize))
                .sum();
            assert_eq!(seg.len(), want_tasks, "len={len}");
        }
    }

    #[test]
    fn costs_from_frontier_all_granularities() {
        let task_steps = [5u32, 0, 3, 7, 2];
        let task_rows = [0u32, 0, 2, 2, 5];
        let fine = Costs::from_frontier(&task_steps, &task_rows, Granularity::Fine);
        assert_eq!(fine.per_task, vec![5, 1, 3, 7, 2]);
        let coarse = Costs::from_frontier(&task_steps, &task_rows, Granularity::Coarse);
        assert_eq!(coarse.per_task, vec![1 + 5, 1 + 3 + 7, 1 + 2]);
        let seg = Costs::from_frontier(&task_steps, &task_rows, Granularity::Segment { len: 3 });
        assert_eq!(seg.per_task, vec![3, 2, 1, 3, 3, 1, 2]);
        assert!(Costs::from_frontier(&[], &[], Granularity::Coarse).is_empty());
    }

    #[test]
    fn costs_estimate_wraps_estimate_costs() {
        let g = from_sorted_unique(4, &[(0, 1), (0, 2), (0, 3), (1, 2), (2, 3)]);
        let z = crate::graph::ZCsr::from_csr(&g);
        for mode in [Mode::Coarse, Mode::Fine] {
            let c = Costs::estimate(&z, mode);
            assert_eq!(c.per_task, estimate_costs(&z, mode));
            assert!(!c.is_empty());
        }
    }

    #[test]
    fn fine_estimates_upper_bound_actual_steps() {
        let g = crate::gen::rmat::rmat(
            300,
            2000,
            crate::gen::rmat::RmatParams::social(),
            &mut crate::util::Rng::new(11),
        );
        let z = crate::graph::ZCsr::from_csr(&g);
        let mut s = Vec::new();
        let tr = crate::cost::trace::trace_supports(&z, &mut s);
        let est = estimate_costs(&z, Mode::Fine);
        for (p, (&e, &actual)) in est.iter().zip(tr.fine_steps.iter()).enumerate() {
            assert!(e >= actual as u64, "slot {p}: estimate {e} < actual {actual}");
        }
    }
}
