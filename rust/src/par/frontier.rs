//! Concurrent realization of the incremental frontier update
//! ([`crate::algo::incremental`]) on the worker pool.
//!
//! The pruned-edge frontier is exactly the task-skew regime the paper's
//! load-balancing machinery targets: a handful of dying edges whose
//! triangle enumerations range from one compare (a pendant edge) to a
//! hub-row merge thousands of steps long. The work-aware schedules
//! therefore bin the **frontier**, not the whole graph: per-task upper
//! bounds from [`crate::algo::incremental::frontier_costs`] flow
//! through the same scan binner / stealing deques the full support
//! pass uses ([`crate::par::balance`]), so `WorkAware` and `Stealing`
//! schedules see equal-work chunks of dying edges.
//!
//! Support decrements are relaxed atomic `fetch_sub`s — concurrent
//! frontier tasks may hit the same surviving leg, and decrements are
//! pure commutative counters read only after the pass, mirroring the
//! full kernel's atomic increments.

use super::parallel_support::{counter_total, worker_counters};
use super::pool::{Pool, Schedule};
use crate::algo::incremental::{frontier_task_atomic, increment_task_atomic, Frontier, InNbrs};
use crate::algo::prune::PruneOutcome;
use crate::algo::support::Granularity;
use crate::graph::ZCsr;
use crate::util::bitset::BitSet;
use std::sync::atomic::{AtomicU32, AtomicUsize, Ordering};

/// Whether `schedule` wants per-task cost estimates (same predicate the
/// support pass uses).
fn needs_costs(schedule: Schedule) -> bool {
    matches!(schedule, Schedule::WorkAware | Schedule::Stealing)
}

/// Run the frontier update concurrently: one task per dying edge,
/// atomic decrements into `s`. Work-aware schedules bin the per-task
/// cost estimates (`costs`, one entry per frontier task — computed
/// internally when `None`). Returns the exact total steps executed.
pub fn decrement_frontier_par(
    z: &ZCsr,
    pool: &Pool,
    f: &Frontier,
    in_nbrs: &InNbrs,
    schedule: Schedule,
    s: &[AtomicU32],
    costs: Option<&[u64]>,
) -> u64 {
    assert_eq!(s.len(), z.slots());
    let tasks = &f.tasks;
    let totals = worker_counters(pool);
    let body = |w: usize, ti: usize| {
        let steps = frontier_task_atomic(z, s, f, in_nbrs, tasks[ti]);
        totals[w].0.fetch_add(steps, Ordering::Relaxed);
    };
    if needs_costs(schedule) {
        let computed: Vec<u64>;
        let cost_vec: &[u64] = match costs {
            Some(c) => c,
            None => {
                computed = crate::algo::incremental::frontier_costs(z, f, in_nbrs);
                &computed
            }
        };
        assert_eq!(cost_vec.len(), tasks.len(), "one cost per frontier task");
        pool.parallel_for_costed(tasks.len(), cost_vec, schedule, body);
    } else {
        pool.parallel_for(tasks.len(), schedule, body);
    }
    counter_total(&totals)
}

/// [`decrement_frontier_par`] at an explicit [`Granularity`]:
/// `Coarse` groups the frontier tasks of one row into a single pool
/// task (the row-task analogue — a row whose edges die together is
/// enumerated by one worker); `Fine` and `Segment` run one pool task
/// per dying edge — a frontier task is already the fine decomposition,
/// and each one's enumeration is bounded by the dying edge's own
/// neighborhood, so the partner-row segment split degenerates to it
/// (the simulators model the segment split of frontier costs
/// explicitly; see [`crate::par::balance::Costs::from_frontier`]).
///
/// `costs` are optional precomputed per-frontier-task estimates (the
/// auto drivers already computed them for the crossover — reused here,
/// aggregated per row group for `Coarse`).
#[allow(clippy::too_many_arguments)]
pub fn decrement_frontier_par_gran(
    z: &ZCsr,
    pool: &Pool,
    f: &Frontier,
    in_nbrs: &InNbrs,
    gran: Granularity,
    schedule: Schedule,
    s: &[AtomicU32],
    costs: Option<&[u64]>,
) -> u64 {
    if !matches!(gran, Granularity::Coarse) {
        return decrement_frontier_par(z, pool, f, in_nbrs, schedule, s, costs);
    }
    // group consecutive tasks by row (mark_frontier emits ascending
    // slot order, so a row's tasks are contiguous)
    let mut groups: Vec<(usize, usize)> = Vec::new();
    let mut start = 0usize;
    for i in 1..=f.tasks.len() {
        if i == f.tasks.len() || f.tasks[i].row != f.tasks[start].row {
            groups.push((start, i));
            start = i;
        }
    }
    let totals = worker_counters(pool);
    let body = |w: usize, gi: usize| {
        let (lo, hi) = groups[gi];
        let mut steps = 0u64;
        for t in &f.tasks[lo..hi] {
            steps += frontier_task_atomic(z, s, f, in_nbrs, *t);
        }
        totals[w].0.fetch_add(steps, Ordering::Relaxed);
    };
    if needs_costs(schedule) {
        let computed: Vec<u64>;
        let per_task: &[u64] = match costs {
            Some(c) => c,
            None => {
                computed = crate::algo::incremental::frontier_costs(z, f, in_nbrs);
                &computed
            }
        };
        assert_eq!(per_task.len(), f.tasks.len(), "one cost per frontier task");
        let group_costs: Vec<u64> = groups
            .iter()
            .map(|&(lo, hi)| per_task[lo..hi].iter().sum::<u64>().max(1))
            .collect();
        pool.parallel_for_costed(groups.len(), &group_costs, schedule, body);
    } else {
        pool.parallel_for(groups.len(), schedule, body);
    }
    counter_total(&totals)
}

/// Run the insertion update concurrently: one task per inserted edge
/// on the *post-insertion* working form, atomic increments into `s`
/// ([`crate::algo::incremental::increment_task_atomic`]). Scheduling is
/// identical to [`decrement_frontier_par`] — the inserted-edge frontier
/// has the same task skew as the dying-edge frontier, and the same
/// per-task cost bounds apply. Returns the exact total steps executed.
pub fn increment_frontier_par(
    z: &ZCsr,
    pool: &Pool,
    f: &Frontier,
    in_nbrs: &InNbrs,
    schedule: Schedule,
    s: &[AtomicU32],
    costs: Option<&[u64]>,
) -> u64 {
    assert_eq!(s.len(), z.slots());
    let tasks = &f.tasks;
    let totals = worker_counters(pool);
    let body = |w: usize, ti: usize| {
        let steps = increment_task_atomic(z, s, f, in_nbrs, tasks[ti]);
        totals[w].0.fetch_add(steps, Ordering::Relaxed);
    };
    if needs_costs(schedule) {
        let computed: Vec<u64>;
        let cost_vec: &[u64] = match costs {
            Some(c) => c,
            None => {
                computed = crate::algo::incremental::frontier_costs(z, f, in_nbrs);
                &computed
            }
        };
        assert_eq!(cost_vec.len(), tasks.len(), "one cost per frontier task");
        pool.parallel_for_costed(tasks.len(), cost_vec, schedule, body);
    } else {
        pool.parallel_for(tasks.len(), schedule, body);
    }
    counter_total(&totals)
}

/// [`increment_frontier_par`] at an explicit [`Granularity`], mirroring
/// [`decrement_frontier_par_gran`]: `Coarse` groups the contiguous
/// tasks of one row into a single pool task; every other granularity
/// runs one pool task per inserted edge (an insertion task is already
/// the fine decomposition).
#[allow(clippy::too_many_arguments)]
pub fn increment_frontier_par_gran(
    z: &ZCsr,
    pool: &Pool,
    f: &Frontier,
    in_nbrs: &InNbrs,
    gran: Granularity,
    schedule: Schedule,
    s: &[AtomicU32],
    costs: Option<&[u64]>,
) -> u64 {
    if !matches!(gran, Granularity::Coarse) {
        return increment_frontier_par(z, pool, f, in_nbrs, schedule, s, costs);
    }
    // group consecutive tasks by row (frontier_from_marked emits
    // ascending slot order, so a row's tasks are contiguous)
    let mut groups: Vec<(usize, usize)> = Vec::new();
    let mut start = 0usize;
    for i in 1..=f.tasks.len() {
        if i == f.tasks.len() || f.tasks[i].row != f.tasks[start].row {
            groups.push((start, i));
            start = i;
        }
    }
    let totals = worker_counters(pool);
    let body = |w: usize, gi: usize| {
        let (lo, hi) = groups[gi];
        let mut steps = 0u64;
        for t in &f.tasks[lo..hi] {
            steps += increment_task_atomic(z, s, f, in_nbrs, *t);
        }
        totals[w].0.fetch_add(steps, Ordering::Relaxed);
    };
    if needs_costs(schedule) {
        let computed: Vec<u64>;
        let per_task: &[u64] = match costs {
            Some(c) => c,
            None => {
                computed = crate::algo::incremental::frontier_costs(z, f, in_nbrs);
                &computed
            }
        };
        assert_eq!(per_task.len(), f.tasks.len(), "one cost per frontier task");
        let group_costs: Vec<u64> = groups
            .iter()
            .map(|&(lo, hi)| per_task[lo..hi].iter().sum::<u64>().max(1))
            .collect();
        pool.parallel_for_costed(groups.len(), &group_costs, schedule, body);
    } else {
        pool.parallel_for(groups.len(), schedule, body);
    }
    counter_total(&totals)
}

/// Concurrent support-preserving compaction: drop the dying slots of
/// every row, moving each survivor's support along with its column.
/// Rows are disjoint slot ranges, so a parallel-for over rows with raw
/// pointer partitioning is safe (the same argument as `prune_par`);
/// `s` is the atomic support array the frontier pass just updated,
/// accessed with relaxed loads/stores (the pass has completed — the
/// pool's scope join is the synchronization point).
pub fn compact_preserving_par(
    z: &mut ZCsr,
    s: &[AtomicU32],
    dying: &BitSet,
    pool: &Pool,
    schedule: Schedule,
) -> PruneOutcome {
    assert_eq!(s.len(), z.slots());
    assert_eq!(dying.len(), z.slots());
    let removed = AtomicUsize::new(0);
    let remaining = AtomicUsize::new(0);
    let n = z.n();
    let row_ptr: Vec<(usize, usize)> = (0..n).map(|i| z.row_span(i)).collect();
    let col_ptr = SendPtr(z.col_mut().as_mut_ptr());
    let body = |_w: usize, i: usize| {
        let (start, end) = row_ptr[i];
        // SAFETY: rows are disjoint slot ranges; each i touches only
        // [start, end) of the column array.
        let col = unsafe { std::slice::from_raw_parts_mut(col_ptr.get().add(start), end - start) };
        let sup = &s[start..end];
        let mut write = 0usize;
        let mut local_removed = 0usize;
        for p in 0..col.len() {
            let c = col[p];
            if c == 0 {
                break;
            }
            if dying.get(start + p) {
                local_removed += 1;
            } else {
                col[write] = c;
                let v = sup[p].load(Ordering::Relaxed);
                sup[write].store(v, Ordering::Relaxed);
                write += 1;
            }
        }
        for slot in col.iter_mut().skip(write) {
            *slot = 0;
        }
        for sp in sup.iter().skip(write) {
            sp.store(0, Ordering::Relaxed);
        }
        removed.fetch_add(local_removed, Ordering::Relaxed);
        remaining.fetch_add(write, Ordering::Relaxed);
    };
    if needs_costs(schedule) {
        let costs: Vec<u64> = row_ptr.iter().map(|&(lo, hi)| (hi - lo) as u64).collect();
        pool.parallel_for_costed(n, &costs, schedule, body);
    } else {
        pool.parallel_for(n, schedule, body);
    }
    PruneOutcome { removed: removed.into_inner(), remaining: remaining.into_inner() }
}

/// Pointer wrapper asserting cross-thread use is safe because the
/// parallel-for partitions rows disjointly.
struct SendPtr<T>(*mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}
impl<T> SendPtr<T> {
    fn get(&self) -> *mut T {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::incremental::{compact_preserving, decrement_frontier_seq, mark_frontier};
    use crate::algo::support::compute_supports_seq;
    use crate::par::pool::ALL_SCHEDULES;

    fn working(g: &crate::graph::Csr) -> (ZCsr, Vec<u32>) {
        let z = ZCsr::from_csr(g);
        let mut s = Vec::new();
        compute_supports_seq(&z, &mut s);
        (z, s)
    }

    #[test]
    fn par_frontier_matches_seq_all_schedules() {
        let g = crate::gen::rmat::rmat(
            300,
            2200,
            crate::gen::rmat::RmatParams::autonomous_system(),
            &mut crate::util::Rng::new(31),
        );
        let (z, s0) = working(&g);
        let in_nbrs = InNbrs::build(&z);
        let pool = Pool::new(4);
        for k in [4u32, 5] {
            let f = mark_frontier(&z, &s0, k);
            let mut s_seq = s0.clone();
            let want_steps = decrement_frontier_seq(&z, &mut s_seq, &f, &in_nbrs);
            for sched in ALL_SCHEDULES {
                let s_at: Vec<AtomicU32> =
                    s0.iter().map(|&x| AtomicU32::new(x)).collect();
                let steps =
                    decrement_frontier_par(&z, &pool, &f, &in_nbrs, sched, &s_at, None);
                let got: Vec<u32> =
                    s_at.iter().map(|x| x.load(Ordering::Relaxed)).collect();
                assert_eq!(got, s_seq, "k={k} {sched:?}");
                assert_eq!(steps, want_steps, "k={k} {sched:?}");
            }
            // the coarse (row-grouped) enumeration agrees too
            for gran in
                [Granularity::Coarse, Granularity::Fine, Granularity::Segment { len: 8 }]
            {
                let s_at: Vec<AtomicU32> =
                    s0.iter().map(|&x| AtomicU32::new(x)).collect();
                let steps = decrement_frontier_par_gran(
                    &z,
                    &pool,
                    &f,
                    &in_nbrs,
                    gran,
                    Schedule::WorkAware,
                    &s_at,
                    None,
                );
                let got: Vec<u32> =
                    s_at.iter().map(|x| x.load(Ordering::Relaxed)).collect();
                assert_eq!(got, s_seq, "k={k} {gran}");
                assert_eq!(steps, want_steps, "k={k} {gran}");
            }
        }
    }

    #[test]
    fn par_increment_matches_seq_all_schedules() {
        // seq<->par parity of the insertion pass needs no insertion
        // semantics: any mark set drives the same enumeration, so
        // reuse the threshold scan's marks as the "inserted" slots
        let g = crate::gen::rmat::rmat(
            280,
            2000,
            crate::gen::rmat::RmatParams::social(),
            &mut crate::util::Rng::new(23),
        );
        let (z, s0) = working(&g);
        let in_nbrs = InNbrs::build(&z);
        let pool = Pool::new(4);
        for k in [4u32, 5] {
            let f = mark_frontier(&z, &s0, k);
            let mut s_seq = s0.clone();
            let want_steps =
                crate::algo::incremental::increment_frontier_seq(&z, &mut s_seq, &f, &in_nbrs);
            for sched in ALL_SCHEDULES {
                let s_at: Vec<AtomicU32> =
                    s0.iter().map(|&x| AtomicU32::new(x)).collect();
                let steps =
                    increment_frontier_par(&z, &pool, &f, &in_nbrs, sched, &s_at, None);
                let got: Vec<u32> =
                    s_at.iter().map(|x| x.load(Ordering::Relaxed)).collect();
                assert_eq!(got, s_seq, "k={k} {sched:?}");
                assert_eq!(steps, want_steps, "k={k} {sched:?}");
            }
            for gran in [
                Granularity::Coarse,
                Granularity::Fine,
                Granularity::Segment { len: 8 },
                Granularity::Hybrid { len: 8 },
            ] {
                let s_at: Vec<AtomicU32> =
                    s0.iter().map(|&x| AtomicU32::new(x)).collect();
                let steps = increment_frontier_par_gran(
                    &z,
                    &pool,
                    &f,
                    &in_nbrs,
                    gran,
                    Schedule::WorkAware,
                    &s_at,
                    None,
                );
                let got: Vec<u32> =
                    s_at.iter().map(|x| x.load(Ordering::Relaxed)).collect();
                assert_eq!(got, s_seq, "k={k} {gran}");
                assert_eq!(steps, want_steps, "k={k} {gran}");
            }
        }
    }

    #[test]
    fn par_compaction_matches_seq() {
        let g = crate::gen::erdos_renyi::gnm(200, 1400, &mut crate::util::Rng::new(6));
        let (z0, s0) = working(&g);
        let in_nbrs = InNbrs::build(&z0);
        let f = mark_frontier(&z0, &s0, 4);
        // sequential reference
        let mut z_seq = z0.clone();
        let mut s_seq = s0.clone();
        decrement_frontier_seq(&z_seq, &mut s_seq, &f, &in_nbrs);
        let want = compact_preserving(&mut z_seq, &mut s_seq, &f.dying);
        let pool = Pool::new(3);
        for sched in ALL_SCHEDULES {
            let mut z_par = z0.clone();
            let s_at: Vec<AtomicU32> = s0.iter().map(|&x| AtomicU32::new(x)).collect();
            decrement_frontier_par(&z_par, &pool, &f, &in_nbrs, sched, &s_at, None);
            let got = compact_preserving_par(&mut z_par, &s_at, &f.dying, &pool, sched);
            assert_eq!(got, want, "{sched:?}");
            assert_eq!(z_par, z_seq, "{sched:?}");
            let s_got: Vec<u32> = s_at.iter().map(|x| x.load(Ordering::Relaxed)).collect();
            assert_eq!(s_got, s_seq, "{sched:?}");
            assert!(crate::graph::validate::check_zcsr(&z_par).is_ok(), "{sched:?}");
        }
    }

    #[test]
    fn empty_frontier_par_is_noop() {
        let g = crate::graph::builder::from_sorted_unique(3, &[(0, 1), (0, 2), (1, 2)]);
        let (z, s0) = working(&g);
        let in_nbrs = InNbrs::build(&z);
        let f = mark_frontier(&z, &s0, 3);
        assert!(f.is_empty());
        let pool = Pool::new(4);
        let s_at: Vec<AtomicU32> = s0.iter().map(|&x| AtomicU32::new(x)).collect();
        for sched in ALL_SCHEDULES {
            let steps = decrement_frontier_par(&z, &pool, &f, &in_nbrs, sched, &s_at, None);
            assert_eq!(steps, 0, "{sched:?}");
        }
    }
}
