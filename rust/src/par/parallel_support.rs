//! Concurrent `computeSupports` on the real worker pool — the rust
//! analogue of the paper's Kokkos Listing 1, in both granularities.
//!
//! The support array is `AtomicU32` (the paper's `Atomic` memory trait):
//! fine-grained tasks racing on shared `S₂₂` rows is the whole point,
//! and relaxed fetch-adds are sufficient because supports are pure
//! commutative counters read only after the pass completes.
//!
//! The work-aware schedules ([`Schedule::WorkAware`],
//! [`Schedule::Stealing`]) feed per-task cost estimates from
//! [`super::balance::estimate_costs`] into the pool; the cost-oblivious
//! schedules run the plain parallel-for.

use super::balance::{self, Costs};
use super::frontier;
use super::pool::{PassControl, Pool, Schedule};
use crate::algo::bitmap::{self, eager_update_bitmap_atomic};
use crate::algo::incremental::{self, InNbrs, SupportMode};
use crate::algo::support::{
    eager_update_atomic, eager_update_segment_atomic, segment_tasks, Granularity, Mode,
};
use crate::graph::ZCsr;
use crate::plan::ExecutionPlan;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};

/// Run one support pass concurrently; returns the plain support array.
pub fn compute_supports_par(z: &ZCsr, pool: &Pool, mode: Mode, schedule: Schedule) -> Vec<u32> {
    let s: Vec<AtomicU32> = (0..z.slots()).map(|_| AtomicU32::new(0)).collect();
    compute_supports_into(z, pool, mode, schedule, &s);
    s.into_iter().map(|x| x.into_inner()).collect()
}

/// Whether `schedule` wants per-task cost estimates.
fn needs_costs(schedule: Schedule) -> bool {
    matches!(schedule, Schedule::WorkAware | Schedule::Stealing)
}

/// Cache-line-padded per-worker step counter: each worker's accumulator
/// owns its own 64B line, so the hot kernel's step accounting never
/// false-shares a line between cores (a plain `Vec<AtomicU64>` packs
/// eight counters per line and would ping-pong it on every task).
#[repr(align(64))]
pub(crate) struct PaddedCounter(pub(crate) AtomicU64);

/// One zeroed counter per pool worker.
pub(crate) fn worker_counters(pool: &Pool) -> Vec<PaddedCounter> {
    (0..pool.workers()).map(|_| PaddedCounter(AtomicU64::new(0))).collect()
}

/// Sum the per-worker counters after the pass joined.
pub(crate) fn counter_total(counters: &[PaddedCounter]) -> u64 {
    counters.iter().map(|c| c.0.load(Ordering::Relaxed)).sum()
}

/// Run one support pass into an existing (zeroed) atomic array.
/// Work-aware schedules bin on the static cost estimates. Returns the
/// exact total merge steps of the pass.
pub fn compute_supports_into(
    z: &ZCsr,
    pool: &Pool,
    mode: Mode,
    schedule: Schedule,
    s: &[AtomicU32],
) -> u64 {
    compute_supports_costed(z, pool, mode, schedule, s, None, None)
}

/// Run one support pass into an existing (zeroed) atomic array, with
/// explicit control over the work-aware binner's cost source and with
/// optional in-situ cost measurement.
///
/// * `costs` — per-task costs for the binner ([`Costs::estimate`] or
///   [`Costs::from_trace`]); `None` computes the static estimate
///   internally (only when `schedule` needs costs at all).
/// * `measured` — when `Some`, every slot's exact merge-step count is
///   recorded (`measured.len() == z.slots()`; terminator/tombstone
///   slots record 0). One relaxed store per slot — cheap relative to
///   the merge itself, and it turns the *next* pass's binning from
///   upper bounds into ground truth (see [`ktruss_par`]).
///
/// Returns the exact total merge steps of the pass (accumulated in
/// per-worker counters, so the hot loop pays no shared-counter
/// contention).
pub fn compute_supports_costed(
    z: &ZCsr,
    pool: &Pool,
    mode: Mode,
    schedule: Schedule,
    s: &[AtomicU32],
    costs: Option<&Costs>,
    measured: Option<&[AtomicU32]>,
) -> u64 {
    assert_eq!(s.len(), z.slots());
    if let Some(m) = measured {
        assert_eq!(m.len(), z.slots(), "one measured-step cell per slot");
    }
    let totals = worker_counters(pool);
    let col = z.col();
    // resolve the binner's cost vector (work-aware schedules only)
    let owned_costs: Option<Costs> = if needs_costs(schedule) && costs.is_none() {
        Some(Costs::estimate(z, mode))
    } else {
        None
    };
    let cost_vec: Option<&[u64]> = if needs_costs(schedule) {
        costs.or(owned_costs.as_ref()).map(|c| c.per_task.as_slice())
    } else {
        None
    };
    match mode {
        Mode::Coarse => {
            // one task per row (paper Algorithm 2): the task walks all
            // live entries of a₁₂ᵀ
            let task = |w: usize, i: usize| {
                let (start, end) = z.row_span(i);
                let mut row_steps = 0u64;
                for p in start..end {
                    let kappa = col[p];
                    if kappa == 0 {
                        break;
                    }
                    let (r0, _) = z.row_span(kappa as usize);
                    let steps = eager_update_atomic(col, s, p, r0);
                    row_steps += steps;
                    if let Some(m) = measured {
                        m[p].store(steps.min(u32::MAX as u64) as u32, Ordering::Relaxed);
                    }
                }
                totals[w].0.fetch_add(row_steps, Ordering::Relaxed);
            };
            match cost_vec {
                Some(c) => {
                    assert_eq!(c.len(), z.n(), "coarse costs are per row");
                    pool.parallel_for_costed(z.n(), c, schedule, task);
                }
                None => pool.parallel_for(z.n(), schedule, task),
            }
        }
        Mode::Fine => {
            // one task per slot (paper Algorithm 3 / Listing 1): a flat
            // range over the zero-terminated nonzero array; terminator
            // and tombstone slots are trivial no-ops, exactly as in the
            // paper's flat RangePolicy formulation
            let task = |w: usize, p: usize| {
                let kappa = col[p];
                if kappa == 0 {
                    if let Some(m) = measured {
                        m[p].store(0, Ordering::Relaxed);
                    }
                    return;
                }
                let (r0, _) = z.row_span(kappa as usize);
                let steps = eager_update_atomic(col, s, p, r0);
                totals[w].0.fetch_add(steps, Ordering::Relaxed);
                if let Some(m) = measured {
                    m[p].store(steps.min(u32::MAX as u64) as u32, Ordering::Relaxed);
                }
            };
            match cost_vec {
                Some(c) => {
                    assert_eq!(c.len(), z.slots(), "fine costs are per slot");
                    pool.parallel_for_costed(z.slots(), c, schedule, task);
                }
                None => pool.parallel_for(z.slots(), schedule, task),
            }
        }
    }
    counter_total(&totals)
}

/// Run one **segment-split** support pass into an existing (zeroed)
/// atomic array — the ultra-fine granularity: one task per ≤`len`-entry
/// partner-row segment of each fine task ([`segment_tasks`]). Segment
/// tasks of the same fine task race on the same support slot, so the
/// accumulation is atomic throughout. Work-aware schedules scan-bin the
/// per-segment cost estimates ([`crate::algo::support::SegTask::estimated_steps`])
/// into equal-work chunks; segments are already near-uniform, so this
/// mainly absorbs the variable in-range tail work. Returns the exact
/// total merge steps of the pass.
pub fn compute_supports_segmented(
    z: &ZCsr,
    pool: &Pool,
    len: u32,
    schedule: Schedule,
    s: &[AtomicU32],
) -> u64 {
    assert_eq!(s.len(), z.slots());
    let tasks = segment_tasks(z, len);
    let col = z.col();
    let totals = worker_counters(pool);
    let body = |w: usize, ti: usize| {
        let steps = eager_update_segment_atomic(col, s, &tasks[ti]);
        totals[w].0.fetch_add(steps, Ordering::Relaxed);
    };
    if needs_costs(schedule) {
        let costs: Vec<u64> = tasks.iter().map(|t| t.estimated_steps()).collect();
        pool.parallel_for_costed(tasks.len(), &costs, schedule, body);
    } else {
        pool.parallel_for(tasks.len(), schedule, body);
    }
    counter_total(&totals)
}

/// Run one **hybrid** support pass into an existing (zeroed) atomic
/// array ([`Granularity::Hybrid`]): the mixed task list of
/// [`bitmap::hybrid_tasks`] — partner-side merge segments plus
/// tail-side bitmap probe chunks — executed as one combined index space
/// (merge tasks first, then probe tasks) under any schedule. Work-aware
/// schedules scan-bin the per-task estimates
/// ([`HybridTasks::estimated_steps`](bitmap::HybridTasks::estimated_steps));
/// probe-chunk estimates are *exact*, so the bins are tight on the
/// bitmap side by construction. Returns the exact total executed steps
/// of the pass.
pub fn compute_supports_hybrid(
    z: &ZCsr,
    pool: &Pool,
    len: u32,
    schedule: Schedule,
    s: &[AtomicU32],
) -> u64 {
    let ht = bitmap::hybrid_tasks(z, len);
    compute_supports_hybrid_tasks(z, pool, &ht, schedule, s)
}

/// [`compute_supports_hybrid`] against an **existing** task list: the
/// entry the convergence drivers use to reuse one
/// [`bitmap::HybridTasks`] (and its [`bitmap::BitmapIndex`]) across
/// iterations, refreshed by frontier-driven invalidation
/// ([`bitmap::HybridTasks::refresh`]) instead of rebuilt per pass.
/// `ht` must describe the current working form of `z` (either freshly
/// built or refreshed with every row whose live entries changed).
pub fn compute_supports_hybrid_tasks(
    z: &ZCsr,
    pool: &Pool,
    ht: &bitmap::HybridTasks,
    schedule: Schedule,
    s: &[AtomicU32],
) -> u64 {
    assert_eq!(s.len(), z.slots());
    let col = z.col();
    let totals = worker_counters(pool);
    let n_merge = ht.merge.len();
    let body = |w: usize, ti: usize| {
        let steps = if ti < n_merge {
            eager_update_segment_atomic(col, s, &ht.merge[ti])
        } else {
            let t = &ht.probe[ti - n_merge];
            let kappa = col[t.p as usize] as usize;
            let bm = ht.index.row(kappa).expect("probe task against unencoded row");
            eager_update_bitmap_atomic(col, s, bm, t)
        };
        totals[w].0.fetch_add(steps, Ordering::Relaxed);
    };
    if needs_costs(schedule) {
        let costs = ht.estimated_steps();
        pool.parallel_for_costed(ht.len(), &costs, schedule, body);
    } else {
        pool.parallel_for(ht.len(), schedule, body);
    }
    counter_total(&totals)
}

/// Run one support pass at any [`Granularity`]; returns the plain
/// support array. Coarse/fine dispatch to [`compute_supports_par`], the
/// segment split to [`compute_supports_segmented`], the hybrid
/// representation to [`compute_supports_hybrid`]. All granularities
/// produce identical supports (verified by the segment and hybrid
/// property tests).
pub fn compute_supports_gran(
    z: &ZCsr,
    pool: &Pool,
    gran: Granularity,
    schedule: Schedule,
) -> Vec<u32> {
    match gran {
        Granularity::Coarse => compute_supports_par(z, pool, Mode::Coarse, schedule),
        Granularity::Fine => compute_supports_par(z, pool, Mode::Fine, schedule),
        Granularity::Segment { len } => {
            let s: Vec<AtomicU32> = (0..z.slots()).map(|_| AtomicU32::new(0)).collect();
            compute_supports_segmented(z, pool, len, schedule, &s);
            s.into_iter().map(|x| x.into_inner()).collect()
        }
        Granularity::Hybrid { len } => {
            let s: Vec<AtomicU32> = (0..z.slots()).map(|_| AtomicU32::new(0)).collect();
            compute_supports_hybrid(z, pool, len, schedule, &s);
            s.into_iter().map(|x| x.into_inner()).collect()
        }
    }
}

/// Concurrent prune: each row is compacted independently (rows never
/// share slots), so a plain parallel-for over rows with interior
/// mutability via raw pointer partitioning is safe. Work-aware
/// schedules bin rows by slot count (compaction cost is linear in the
/// row's slot span).
pub fn prune_par(
    z: &mut ZCsr,
    s: &mut [u32],
    k: u32,
    pool: &Pool,
    schedule: Schedule,
) -> crate::algo::prune::PruneOutcome {
    use std::sync::atomic::AtomicUsize;
    assert_eq!(s.len(), z.slots());
    let threshold = k.saturating_sub(2);
    let removed = AtomicUsize::new(0);
    let remaining = AtomicUsize::new(0);
    let n = z.n();
    let row_ptr: Vec<(usize, usize)> = (0..n).map(|i| z.row_span(i)).collect();
    let col_ptr = SendPtr(z.col_mut().as_mut_ptr());
    let s_ptr = SendPtr(s.as_mut_ptr());
    let body = |_w: usize, i: usize| {
        let (start, end) = row_ptr[i];
        // SAFETY: rows are disjoint slot ranges; each i touches only
        // [start, end) of both arrays.
        let col = unsafe { std::slice::from_raw_parts_mut(col_ptr.get().add(start), end - start) };
        let sup = unsafe { std::slice::from_raw_parts_mut(s_ptr.get().add(start), end - start) };
        let mut write = 0usize;
        let mut local_removed = 0usize;
        for p in 0..col.len() {
            let c = col[p];
            if c == 0 {
                break;
            }
            if sup[p] >= threshold {
                col[write] = c;
                write += 1;
            } else {
                local_removed += 1;
            }
        }
        for slot in col.iter_mut().skip(write) {
            *slot = 0;
        }
        for sp in sup.iter_mut() {
            *sp = 0;
        }
        removed.fetch_add(local_removed, Ordering::Relaxed);
        remaining.fetch_add(write, Ordering::Relaxed);
    };
    if needs_costs(schedule) {
        let costs: Vec<u64> = row_ptr.iter().map(|&(lo, hi)| (hi - lo) as u64).collect();
        pool.parallel_for_costed(n, &costs, schedule, body);
    } else {
        pool.parallel_for(n, schedule, body);
    }
    crate::algo::prune::PruneOutcome {
        removed: removed.into_inner(),
        remaining: remaining.into_inner(),
    }
}

/// Pointer wrapper that asserts cross-thread use is safe because the
/// parallel-for partitions rows disjointly.
struct SendPtr<T>(*mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}
impl<T> SendPtr<T> {
    /// Accessor (rather than field capture) so edition-2021 closures
    /// capture the `Sync` wrapper, not the raw pointer field.
    fn get(&self) -> *mut T {
        self.0
    }
}

/// Full concurrent k-truss (support + prune until convergence) under
/// the default [`SupportMode::Auto`] driver — the production entry
/// point used by the coordinator's CPU engine.
///
/// ```
/// use ktruss::algo::support::Mode;
/// use ktruss::graph::builder::from_sorted_unique;
/// use ktruss::par::{ktruss_par, Pool, Schedule};
///
/// // diamond: triangles {0,1,2} and {0,2,3} — every edge survives k=3
/// let g = from_sorted_unique(4, &[(0, 1), (0, 2), (0, 3), (1, 2), (2, 3)]);
/// let r = ktruss_par(&g, 3, &Pool::new(2), Mode::Fine, Schedule::WorkAware);
/// assert_eq!(r.truss.nnz(), 5);
/// ```
pub fn ktruss_par(
    g: &crate::graph::Csr,
    k: u32,
    pool: &Pool,
    mode: Mode,
    schedule: Schedule,
) -> crate::algo::ktruss::KtrussResult {
    ktruss_par_mode(g, k, pool, mode, schedule, SupportMode::Auto)
}

/// The plan-driven concurrent k-truss: one [`ExecutionPlan`] carries
/// every execution axis — granularity, schedule, support mode and the
/// auto-crossover fraction — end to end. This is the entry the
/// coordinator worker runs a job's submit-time plan through; the
/// mode/gran entries below are thin wrappers that build a plan at the
/// default crossover.
pub fn ktruss_par_plan(
    g: &crate::graph::Csr,
    k: u32,
    pool: &Pool,
    plan: &ExecutionPlan,
) -> crate::algo::ktruss::KtrussResult {
    ktruss_par_plan_ctl(g, k, pool, plan, PassControl::default()).0
}

/// [`ktruss_par_plan`] with pass-boundary control: the serving layer's
/// cancellable entry point. The driver consults `ctl` after every
/// completed pass (once the frontier shows more work remains) and, when
/// the token reports cancelled, stops **between** passes — every pass
/// that ran has its exact [`IterationStat`](crate::algo::ktruss::IterationStat)
/// recorded, so a cancelled job's span tree still sums pass steps to
/// its total.
///
/// Returns the (possibly partial) result plus `true` when the run was
/// cut short by cancellation; `false` means it converged normally and
/// the result is the exact k-truss.
pub fn ktruss_par_plan_ctl(
    g: &crate::graph::Csr,
    k: u32,
    pool: &Pool,
    plan: &ExecutionPlan,
    ctl: PassControl<'_>,
) -> (crate::algo::ktruss::KtrussResult, bool) {
    // device dispatch: Gpu plans execute on the lane-lockstep backend
    // (same pool, GPU execution shape — see [`crate::exec::lane`]);
    // results are bit-identical across backends at every plan point
    if plan.device == crate::plan::PlanDevice::Gpu {
        return crate::exec::lane::ktruss_lane_ctl(g, k, pool, plan, ctl);
    }
    ktruss_par_gran_crossover(
        g,
        k,
        pool,
        plan.granularity,
        plan.schedule,
        plan.support,
        plan.crossover,
        ctl,
    )
}

/// [`ktruss_par`] with an explicit support-maintenance mode.
///
/// Full recomputes run a *calibrated* pass under the work-aware
/// schedules: the first bins on the static upper bounds, every later
/// one on the **measured** per-slot merge steps of the previous full
/// pass ([`Costs::from_trace`], masked against the current working
/// form). Incremental iterations instead run the parallel frontier
/// pass ([`frontier::decrement_frontier_par`]): the binner receives
/// per-frontier-task cost estimates, so the work-aware schedules bin
/// the *frontier*, not the whole graph — and the same estimate total
/// drives the [`SupportMode::Auto`] crossover back to a full recompute
/// when the frontier is too large to be worth it.
pub fn ktruss_par_mode(
    g: &crate::graph::Csr,
    k: u32,
    pool: &Pool,
    mode: Mode,
    schedule: Schedule,
    support: SupportMode,
) -> crate::algo::ktruss::KtrussResult {
    ktruss_par_mode_crossover(
        g,
        k,
        pool,
        mode,
        schedule,
        support,
        incremental::DEFAULT_CROSSOVER_FRAC,
        PassControl::default(),
    )
    .0
}

/// [`ktruss_par_mode`] with the plan-supplied auto-crossover fraction
/// and pass-boundary control; returns `(result, cancelled)`.
#[allow(clippy::too_many_arguments)]
fn ktruss_par_mode_crossover(
    g: &crate::graph::Csr,
    k: u32,
    pool: &Pool,
    mode: Mode,
    schedule: Schedule,
    support: SupportMode,
    crossover: f64,
    ctl: PassControl<'_>,
) -> (crate::algo::ktruss::KtrussResult, bool) {
    let mut z = ZCsr::from_csr(g);
    let s_atomic: Vec<AtomicU32> = (0..z.slots()).map(|_| AtomicU32::new(0)).collect();
    let mut s_plain = vec![0u32; z.slots()];
    // measure per-slot steps only when a work-aware schedule will
    // consume them at the next full pass
    let measure = needs_costs(schedule);
    let measured: Vec<AtomicU32> = if measure {
        (0..z.slots()).map(|_| AtomicU32::new(0)).collect()
    } else {
        Vec::new()
    };
    let measured_opt = if measure { Some(measured.as_slice()) } else { None };
    let mut measured_snap: Vec<u32> = Vec::new();
    let use_inc = support.allows_incremental();
    let mut iterations = 0usize;
    let mut stats = Vec::new();
    // live-edge counter maintained from the prune/compaction outcomes
    // (one initial O(slots) scan, no per-round rescan)
    let mut live = z.live_edges();
    let mut cancelled = false;
    if live == 0 {
        return (
            crate::algo::ktruss::KtrussResult { truss: z.to_csr(), iterations, stats, k, mode },
            false,
        );
    }
    let in_nbrs: Option<InNbrs> = if use_inc { Some(InNbrs::build(&z)) } else { None };
    // tasks offered to the pool pre-split: rows for coarse, live edges
    // for fine (frontier passes offer the frontier)
    let full_tasks = |live: usize| match mode {
        Mode::Coarse => z.n(),
        Mode::Fine => live,
    };
    // initial full pass (statically binned)
    let mut pass_timer = crate::util::Timer::start();
    let mut pass_steps = compute_supports_costed(
        &z, pool, mode, schedule, &s_atomic, None, measured_opt,
    );
    let mut pass_wall_ms = pass_timer.elapsed_ms();
    let mut pass_tasks = full_tasks(live);
    let mut pass_incremental = false;
    let mut last_full_steps = pass_steps;
    if measure {
        measured_snap.extend(measured.iter().map(|a| a.load(Ordering::Relaxed)));
    }
    loop {
        if live == 0 {
            break;
        }
        let f = incremental::mark_frontier_with(&z, k, |p| {
            s_atomic[p].load(Ordering::Relaxed)
        });
        iterations += 1;
        stats.push(crate::algo::ktruss::IterationStat {
            live_edges: live,
            removed: f.len(),
            support_steps: pass_steps,
            incremental: pass_incremental,
            wall_ms: pass_wall_ms,
            tasks: pass_tasks,
        });
        if f.is_empty() {
            break;
        }
        // pass boundary: fault-injection hook + cooperative cancel —
        // the completed pass above is already recorded, so a cancelled
        // run's stats still sum to the executed step total
        if ctl.pass_boundary(iterations - 1) {
            cancelled = true;
            break;
        }
        // decide how to bring S up to date for the shrunken graph (the
        // shared per-round decision at the plan's crossover fraction;
        // only a work-aware schedule needs the per-task estimates back
        // for its binner — other schedules run the sum-only check)
        let (go_incremental, frontier_cost_vec) = incremental::decide_incremental(
            &z,
            &f,
            in_nbrs.as_ref(),
            support,
            last_full_steps,
            crossover,
            needs_costs(schedule),
        );
        if go_incremental {
            let nbrs = in_nbrs.as_ref().expect("incremental mode builds the index");
            pass_tasks = f.len();
            pass_timer.restart();
            pass_steps = frontier::decrement_frontier_par(
                &z,
                pool,
                &f,
                nbrs,
                schedule,
                &s_atomic,
                frontier_cost_vec.as_deref(),
            );
            pass_wall_ms = pass_timer.elapsed_ms();
            pass_incremental = true;
            live = frontier::compact_preserving_par(&mut z, &s_atomic, &f.dying, pool, schedule)
                .remaining;
        } else {
            // classic path: drain the atomic supports, prune (resetting
            // them), recompute with trace-calibrated binning
            for (d, a) in s_plain.iter_mut().zip(s_atomic.iter()) {
                *d = a.swap(0, Ordering::Relaxed);
            }
            live = prune_par(&mut z, &mut s_plain, k, pool, schedule).remaining;
            if live == 0 {
                pass_steps = 0;
                pass_incremental = false;
                pass_wall_ms = 0.0;
                pass_tasks = 0;
            } else {
                // feed the measured previous full pass into the binner,
                // masked against the just-pruned working form (row_ptr
                // is stable under compaction, so slots stay row-aligned)
                let costs = (measure && !measured_snap.is_empty())
                    .then(|| Costs::from_trace(&measured_snap, &z, mode));
                pass_timer.restart();
                pass_steps = compute_supports_costed(
                    &z, pool, mode, schedule, &s_atomic, costs.as_ref(), measured_opt,
                );
                pass_wall_ms = pass_timer.elapsed_ms();
                pass_tasks = full_tasks(live);
                pass_incremental = false;
                last_full_steps = pass_steps;
                if measure {
                    measured_snap.clear();
                    measured_snap.extend(measured.iter().map(|a| a.load(Ordering::Relaxed)));
                }
            }
        }
    }
    (crate::algo::ktruss::KtrussResult { truss: z.to_csr(), iterations, stats, k, mode }, cancelled)
}

/// Full concurrent k-truss at any [`Granularity`] under the default
/// [`SupportMode::Auto`] driver. See [`ktruss_par_gran_mode`].
pub fn ktruss_par_gran(
    g: &crate::graph::Csr,
    k: u32,
    pool: &Pool,
    gran: Granularity,
    schedule: Schedule,
) -> crate::algo::ktruss::KtrussResult {
    ktruss_par_gran_mode(g, k, pool, gran, schedule, SupportMode::Auto)
}

/// Full concurrent k-truss at any [`Granularity`] with an explicit
/// support-maintenance mode. Coarse/fine delegate to
/// [`ktruss_par_mode`]; the segment split and the hybrid
/// representation run their own convergence loop whose **full** passes
/// use [`compute_supports_segmented`] / [`compute_supports_hybrid`]
/// (task lists — and, for hybrid, row representations — re-derived
/// from the compacted working form each iteration) and whose
/// **incremental** iterations run the frontier pass at the matching
/// granularity ([`frontier::decrement_frontier_par_gran`]).
///
/// The returned [`crate::algo::ktruss::KtrussResult`] records
/// [`Mode::Fine`] for segment and hybrid runs — both are sub-divisions
/// of fine tasks and produce identical results at every granularity.
pub fn ktruss_par_gran_mode(
    g: &crate::graph::Csr,
    k: u32,
    pool: &Pool,
    gran: Granularity,
    schedule: Schedule,
    support: SupportMode,
) -> crate::algo::ktruss::KtrussResult {
    ktruss_par_gran_crossover(
        g,
        k,
        pool,
        gran,
        schedule,
        support,
        incremental::DEFAULT_CROSSOVER_FRAC,
        PassControl::default(),
    )
    .0
}

/// [`ktruss_par_gran_mode`] with the plan-supplied auto-crossover
/// fraction and pass-boundary control — the shared engine behind
/// [`ktruss_par_plan`] / [`ktruss_par_plan_ctl`]; returns
/// `(result, cancelled)`.
#[allow(clippy::too_many_arguments)]
fn ktruss_par_gran_crossover(
    g: &crate::graph::Csr,
    k: u32,
    pool: &Pool,
    gran: Granularity,
    schedule: Schedule,
    support: SupportMode,
    crossover: f64,
    ctl: PassControl<'_>,
) -> (crate::algo::ktruss::KtrussResult, bool) {
    let (len, hybrid) = match gran {
        Granularity::Coarse => {
            return ktruss_par_mode_crossover(
                g,
                k,
                pool,
                Mode::Coarse,
                schedule,
                support,
                crossover,
                ctl,
            )
        }
        Granularity::Fine => {
            return ktruss_par_mode_crossover(
                g,
                k,
                pool,
                Mode::Fine,
                schedule,
                support,
                crossover,
                ctl,
            )
        }
        Granularity::Segment { len } => (len, false),
        Granularity::Hybrid { len } => (len, true),
    };
    // full passes re-enumerate segment tasks from the compacted
    // working form each iteration; the hybrid path instead keeps ONE
    // task list (and bitmap index) alive across iterations, refreshed
    // by frontier-driven invalidation — `pending_rows` accumulates the
    // rows whose dying slots were removed since the last full pass,
    // and `run_full_gran` re-encodes exactly those before executing
    // ([`bitmap::HybridTasks::refresh`]; prune/compaction is row-local,
    // so untouched rows' encodings and representations are unchanged)
    let mut ht: Option<bitmap::HybridTasks> = None;
    let mut pending_rows: Vec<u32> = Vec::new();
    let mut z = ZCsr::from_csr(g);
    let s_atomic: Vec<AtomicU32> = (0..z.slots()).map(|_| AtomicU32::new(0)).collect();
    let mut s_plain = vec![0u32; z.slots()];
    let use_inc = support.allows_incremental();
    let mut iterations = 0usize;
    let mut stats = Vec::new();
    // live-edge counter maintained from the prune/compaction outcomes
    let mut live = z.live_edges();
    let mut cancelled = false;
    if live == 0 {
        return (
            crate::algo::ktruss::KtrussResult {
                truss: z.to_csr(),
                iterations,
                stats,
                k,
                mode: Mode::Fine,
            },
            false,
        );
    }
    let in_nbrs: Option<InNbrs> = if use_inc { Some(InNbrs::build(&z)) } else { None };
    let mut pass_timer = crate::util::Timer::start();
    let mut pass_steps = run_full_gran(
        &z, pool, len, hybrid, schedule, &s_atomic, &mut ht, &mut pending_rows,
    );
    let mut pass_wall_ms = pass_timer.elapsed_ms();
    // tasks pre-split: segment/hybrid subdivide fine (per-edge) tasks,
    // so the offered count before splitting is the live-edge count
    let mut pass_tasks = live;
    let mut pass_incremental = false;
    let mut last_full_steps = pass_steps;
    loop {
        if live == 0 {
            break;
        }
        let f = incremental::mark_frontier_with(&z, k, |p| {
            s_atomic[p].load(Ordering::Relaxed)
        });
        iterations += 1;
        stats.push(crate::algo::ktruss::IterationStat {
            live_edges: live,
            removed: f.len(),
            support_steps: pass_steps,
            incremental: pass_incremental,
            wall_ms: pass_wall_ms,
            tasks: pass_tasks,
        });
        if f.is_empty() {
            break;
        }
        // pass boundary: fault-injection hook + cooperative cancel
        if ctl.pass_boundary(iterations - 1) {
            cancelled = true;
            break;
        }
        // both branches below remove exactly this round's dying slots;
        // the rows owning them are the ones whose hybrid encodings go
        // stale (tasks emit ascending slot order, so rows arrive
        // grouped — consecutive dedup suffices)
        if hybrid {
            let mut last = u32::MAX;
            for t in &f.tasks {
                if t.row != last {
                    pending_rows.push(t.row);
                    last = t.row;
                }
            }
        }
        let (go_incremental, frontier_cost_vec) = incremental::decide_incremental(
            &z,
            &f,
            in_nbrs.as_ref(),
            support,
            last_full_steps,
            crossover,
            needs_costs(schedule),
        );
        if go_incremental {
            let nbrs = in_nbrs.as_ref().expect("incremental mode builds the index");
            pass_tasks = f.len();
            pass_timer.restart();
            pass_steps = frontier::decrement_frontier_par_gran(
                &z,
                pool,
                &f,
                nbrs,
                gran,
                schedule,
                &s_atomic,
                frontier_cost_vec.as_deref(),
            );
            pass_wall_ms = pass_timer.elapsed_ms();
            pass_incremental = true;
            live = frontier::compact_preserving_par(&mut z, &s_atomic, &f.dying, pool, schedule)
                .remaining;
        } else {
            for (d, a) in s_plain.iter_mut().zip(s_atomic.iter()) {
                *d = a.swap(0, Ordering::Relaxed);
            }
            live = prune_par(&mut z, &mut s_plain, k, pool, schedule).remaining;
            if live == 0 {
                pass_steps = 0;
                pass_incremental = false;
                pass_wall_ms = 0.0;
                pass_tasks = 0;
            } else {
                pass_timer.restart();
                pass_steps = run_full_gran(
                    &z, pool, len, hybrid, schedule, &s_atomic, &mut ht, &mut pending_rows,
                );
                pass_wall_ms = pass_timer.elapsed_ms();
                pass_tasks = live;
                pass_incremental = false;
                last_full_steps = pass_steps;
            }
        }
    }
    (
        crate::algo::ktruss::KtrussResult {
            truss: z.to_csr(),
            iterations,
            stats,
            k,
            mode: Mode::Fine,
        },
        cancelled,
    )
}

/// One full pass of the segment/hybrid convergence driver. Segment
/// passes re-enumerate their task list (cheap — no index to build);
/// hybrid passes maintain `ht` across iterations: built once, then
/// [`bitmap::HybridTasks::refresh`]ed with the rows accumulated in
/// `pending` (cleared here) instead of rebuilt from scratch.
#[allow(clippy::too_many_arguments)]
fn run_full_gran(
    z: &ZCsr,
    pool: &Pool,
    len: u32,
    hybrid: bool,
    schedule: Schedule,
    s: &[AtomicU32],
    ht: &mut Option<bitmap::HybridTasks>,
    pending: &mut Vec<u32>,
) -> u64 {
    if hybrid {
        match ht {
            Some(t) => t.refresh(z, len, pending),
            None => *ht = Some(bitmap::hybrid_tasks(z, len)),
        }
        pending.clear();
        let t = ht.as_ref().expect("hybrid task list just built");
        compute_supports_hybrid_tasks(z, pool, t, schedule, s)
    } else {
        compute_supports_segmented(z, pool, len, schedule, s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::ktruss::ktruss;
    use crate::algo::support::compute_supports_seq;
    use crate::par::pool::ALL_SCHEDULES;

    fn random_graph(seed: u64) -> crate::graph::Csr {
        crate::gen::rmat::rmat(
            300,
            2200,
            crate::gen::rmat::RmatParams::social(),
            &mut crate::util::Rng::new(seed),
        )
    }

    #[test]
    fn par_supports_match_seq_all_modes_and_schedules() {
        let g = random_graph(1);
        let z = ZCsr::from_csr(&g);
        let mut want = Vec::new();
        compute_supports_seq(&z, &mut want);
        let pool = Pool::new(4);
        for mode in [Mode::Coarse, Mode::Fine] {
            for sched in ALL_SCHEDULES {
                let got = compute_supports_par(&z, &pool, mode, sched);
                assert_eq!(got, want, "{mode} {sched:?}");
            }
        }
    }

    #[test]
    fn par_ktruss_matches_seq() {
        let g = random_graph(2);
        let pool = Pool::new(4);
        for k in [3u32, 5] {
            let seq = ktruss(&g, k, Mode::Fine);
            // WorkAware and Stealing exercise the measured-cost
            // feedback loop (trace-calibrated bins after iteration 0)
            for mode in [Mode::Coarse, Mode::Fine] {
                for sched in
                    [Schedule::Dynamic { chunk: 64 }, Schedule::WorkAware, Schedule::Stealing]
                {
                    let par = ktruss_par(&g, k, &pool, mode, sched);
                    assert_eq!(par.truss, seq.truss, "k={k} {mode} {sched:?}");
                    assert_eq!(par.iterations, seq.iterations, "k={k} {mode} {sched:?}");
                }
            }
        }
    }

    #[test]
    fn segmented_par_supports_match_seq_all_schedules() {
        let g = random_graph(21);
        let z = ZCsr::from_csr(&g);
        let mut want = Vec::new();
        compute_supports_seq(&z, &mut want);
        let pool = Pool::new(4);
        for len in [1u32, 7, 64] {
            for sched in ALL_SCHEDULES {
                let got =
                    compute_supports_gran(&z, &pool, Granularity::Segment { len }, sched);
                assert_eq!(got, want, "len={len} {sched:?}");
            }
        }
        // and the gran dispatcher's coarse/fine paths agree too
        for gran in [Granularity::Coarse, Granularity::Fine] {
            let got = compute_supports_gran(&z, &pool, gran, Schedule::WorkAware);
            assert_eq!(got, want, "{gran}");
        }
    }

    #[test]
    fn hybrid_par_supports_match_seq_all_schedules() {
        // include a hub-partner-heavy fixture so the bitmap side really
        // executes, not just the merge fallback
        let comb = crate::testkit::graphs::hub_divergence_comb(12, 20, 90);
        for g in [&random_graph(23), &comb] {
            let z = ZCsr::from_csr(g);
            let mut want = Vec::new();
            compute_supports_seq(&z, &mut want);
            let pool = Pool::new(4);
            for len in [1u32, 7, 64] {
                for sched in ALL_SCHEDULES {
                    let got = compute_supports_gran(&z, &pool, Granularity::Hybrid { len }, sched);
                    assert_eq!(got, want, "len={len} {sched:?}");
                }
            }
        }
    }

    #[test]
    fn hybrid_pass_total_steps_match_seq_hybrid() {
        let g = crate::testkit::graphs::hub_divergence_comb(10, 15, 70);
        let z = ZCsr::from_csr(&g);
        let mut s_seq = Vec::new();
        let want = crate::algo::bitmap::compute_supports_hybrid_seq(&z, 16, &mut s_seq);
        let pool = Pool::new(4);
        for sched in ALL_SCHEDULES {
            let s: Vec<AtomicU32> = (0..z.slots()).map(|_| AtomicU32::new(0)).collect();
            let total = compute_supports_hybrid(&z, &pool, 16, sched, &s);
            assert_eq!(total, want, "{sched:?}");
        }
    }

    #[test]
    fn ktruss_par_hybrid_matches_seq() {
        let g = random_graph(24);
        let pool = Pool::new(4);
        for k in [3u32, 5] {
            let seq = ktruss(&g, k, Mode::Fine);
            for len in [2u32, 64] {
                for sched in [Schedule::Static, Schedule::WorkAware, Schedule::Stealing] {
                    let par = ktruss_par_gran(&g, k, &pool, Granularity::Hybrid { len }, sched);
                    assert_eq!(par.truss, seq.truss, "k={k} len={len} {sched:?}");
                    assert_eq!(par.iterations, seq.iterations, "k={k} len={len} {sched:?}");
                }
            }
        }
    }

    #[test]
    fn ktruss_par_gran_matches_seq() {
        let g = random_graph(22);
        let pool = Pool::new(4);
        for k in [3u32, 5] {
            let seq = ktruss(&g, k, Mode::Fine);
            for len in [2u32, 64] {
                for sched in [Schedule::Static, Schedule::WorkAware, Schedule::Stealing] {
                    let par =
                        ktruss_par_gran(&g, k, &pool, Granularity::Segment { len }, sched);
                    assert_eq!(par.truss, seq.truss, "k={k} len={len} {sched:?}");
                    assert_eq!(par.iterations, seq.iterations, "k={k} len={len} {sched:?}");
                }
            }
            // coarse/fine delegation path
            let par = ktruss_par_gran(&g, k, &pool, Granularity::Coarse, Schedule::WorkAware);
            assert_eq!(par.truss, seq.truss, "k={k} coarse delegation");
        }
    }

    #[test]
    fn par_mode_drivers_match_seq_exactly() {
        // truss, iterations AND exact per-iteration support steps must
        // agree between the sequential and pooled drivers in every
        // support mode (the crossover sees identical inputs, so even
        // auto's per-round decisions coincide)
        let g = random_graph(33);
        let pool = Pool::new(4);
        for support in [SupportMode::Full, SupportMode::Incremental, SupportMode::Auto] {
            for k in [3u32, 5] {
                let seq = crate::algo::ktruss::ktruss_mode(&g, k, Mode::Fine, support);
                for sched in [Schedule::Static, Schedule::WorkAware, Schedule::Stealing] {
                    let par = ktruss_par_mode(&g, k, &pool, Mode::Fine, sched, support);
                    assert_eq!(par.truss, seq.truss, "k={k} {support} {sched:?}");
                    assert_eq!(par.iterations, seq.iterations, "k={k} {support} {sched:?}");
                    let seq_steps: Vec<u64> =
                        seq.stats.iter().map(|s| s.support_steps).collect();
                    let par_steps: Vec<u64> =
                        par.stats.iter().map(|s| s.support_steps).collect();
                    assert_eq!(par_steps, seq_steps, "k={k} {support} {sched:?}");
                    let seq_inc: Vec<bool> = seq.stats.iter().map(|s| s.incremental).collect();
                    let par_inc: Vec<bool> = par.stats.iter().map(|s| s.incremental).collect();
                    assert_eq!(par_inc, seq_inc, "k={k} {support} {sched:?}");
                }
            }
        }
    }

    #[test]
    fn segment_mode_driver_matches_seq() {
        let g = random_graph(34);
        let pool = Pool::new(3);
        for support in [SupportMode::Full, SupportMode::Incremental, SupportMode::Auto] {
            for k in [3u32, 5] {
                let seq = ktruss(&g, k, Mode::Fine);
                let par = ktruss_par_gran_mode(
                    &g,
                    k,
                    &pool,
                    Granularity::Segment { len: 16 },
                    Schedule::WorkAware,
                    support,
                );
                assert_eq!(par.truss, seq.truss, "k={k} {support}");
                assert_eq!(par.iterations, seq.iterations, "k={k} {support}");
            }
        }
    }

    #[test]
    fn cancelled_driver_stops_between_passes_with_exact_stats() {
        use crate::par::pool::CancelToken;
        // peel_chain converges over many rounds, so a pre-cancelled
        // token must cut the run short after the first recorded pass
        let g = crate::testkit::graphs::peel_chain(24);
        let pool = Pool::new(2);
        let plan = crate::plan::Planner::new(2).choose(&g, 3);
        let full = ktruss_par_plan(&g, 3, &pool, &plan);
        assert!(full.iterations > 2, "fixture must need several passes");
        for gran in [
            Granularity::Coarse,
            Granularity::Fine,
            Granularity::Segment { len: 8 },
            Granularity::Hybrid { len: 8 },
        ] {
            let mut p = plan;
            p.granularity = gran;
            let token = CancelToken::new();
            token.cancel();
            let ctl = PassControl { cancel: Some(&token), on_pass: None };
            let (r, cancelled) = ktruss_par_plan_ctl(&g, 3, &pool, &p, ctl);
            assert!(cancelled, "{gran}: pre-cancelled token must stop the run");
            assert!(
                r.iterations < full.iterations,
                "{gran}: cancelled run must not converge ({} vs {})",
                r.iterations,
                full.iterations
            );
            // every executed pass is recorded: stats len == iterations
            // and the per-pass steps are the run's exact total
            assert_eq!(r.stats.len(), r.iterations, "{gran}");
            assert_eq!(
                r.stats.iter().map(|s| s.support_steps).sum::<u64>(),
                r.total_support_steps(),
                "{gran}"
            );
        }
        // an uncancelled token changes nothing, including step parity
        let token = CancelToken::new();
        let ctl = PassControl { cancel: Some(&token), on_pass: None };
        let (r, cancelled) = ktruss_par_plan_ctl(&g, 3, &pool, &plan, ctl);
        assert!(!cancelled);
        assert_eq!(r.truss, full.truss);
        assert_eq!(r.iterations, full.iterations);
    }

    #[test]
    fn pass_hook_fires_at_every_boundary() {
        use std::sync::atomic::AtomicUsize;
        let g = crate::testkit::graphs::peel_chain(16);
        let pool = Pool::new(2);
        let plan = crate::plan::Planner::new(2).choose(&g, 3);
        let fired = AtomicUsize::new(0);
        let hook = |_iter: usize| {
            fired.fetch_add(1, Ordering::Relaxed);
        };
        let ctl = PassControl { cancel: None, on_pass: Some(&hook) };
        let (r, cancelled) = ktruss_par_plan_ctl(&g, 3, &pool, &plan, ctl);
        assert!(!cancelled);
        // the hook fires between passes: every pass except the final
        // (empty-frontier) one has a boundary after it
        assert_eq!(fired.load(Ordering::Relaxed), r.iterations - 1);
    }

    #[test]
    fn costed_pass_returns_exact_total_steps() {
        let g = random_graph(35);
        let z = ZCsr::from_csr(&g);
        let mut s_trace = Vec::new();
        let tr = crate::cost::trace::trace_supports(&z, &mut s_trace);
        let pool = Pool::new(4);
        for mode in [Mode::Coarse, Mode::Fine] {
            for sched in [Schedule::Static, Schedule::WorkAware, Schedule::Stealing] {
                let s: Vec<AtomicU32> = (0..z.slots()).map(|_| AtomicU32::new(0)).collect();
                let total = compute_supports_costed(&z, &pool, mode, sched, &s, None, None);
                assert_eq!(total, tr.total_steps, "{mode} {sched:?}");
            }
        }
        // the segmented pass counts its own (bounded-merge) steps: they
        // must match the sequential segmented kernel's total exactly
        let mut s_seg = Vec::new();
        let want_seg =
            crate::algo::support::compute_supports_segmented_seq(&z, 16, &mut s_seg);
        let s: Vec<AtomicU32> = (0..z.slots()).map(|_| AtomicU32::new(0)).collect();
        let total = compute_supports_segmented(&z, &pool, 16, Schedule::WorkAware, &s);
        assert_eq!(total, want_seg, "segment");
    }

    #[test]
    fn ktruss_par_gran_empty_graph() {
        let pool = Pool::new(3);
        let empty = crate::graph::Csr::empty(5);
        let r =
            ktruss_par_gran(&empty, 3, &pool, Granularity::Segment { len: 4 }, Schedule::WorkAware);
        assert_eq!(r.truss.nnz(), 0);
        assert_eq!(r.iterations, 0);
    }

    #[test]
    fn costed_pass_measures_exact_trace_steps() {
        // the in-situ measurement of the parallel pass must agree with
        // the sequential tracer slot for slot
        let g = random_graph(9);
        let z = ZCsr::from_csr(&g);
        let mut s_trace = Vec::new();
        let tr = crate::cost::trace::trace_supports(&z, &mut s_trace);
        let pool = Pool::new(4);
        for (mode, sched) in [
            (Mode::Fine, Schedule::WorkAware),
            (Mode::Coarse, Schedule::Stealing),
            (Mode::Fine, Schedule::Dynamic { chunk: 32 }),
        ] {
            let s: Vec<AtomicU32> = (0..z.slots()).map(|_| AtomicU32::new(0)).collect();
            let measured: Vec<AtomicU32> = (0..z.slots()).map(|_| AtomicU32::new(0)).collect();
            compute_supports_costed(&z, &pool, mode, sched, &s, None, Some(&measured));
            let got: Vec<u32> = s.iter().map(|x| x.load(Ordering::Relaxed)).collect();
            assert_eq!(got, s_trace, "{mode} {sched:?}: supports");
            for (p, (m, want)) in measured.iter().zip(tr.fine_steps.iter()).enumerate() {
                assert_eq!(m.load(Ordering::Relaxed), *want, "{mode} {sched:?}: slot {p}");
            }
        }
    }

    #[test]
    fn costed_pass_accepts_external_cost_vectors() {
        // binning on externally supplied (even deliberately wrong)
        // costs must never change the computed supports, only the
        // partitioning
        let g = random_graph(10);
        let z = ZCsr::from_csr(&g);
        let mut want = Vec::new();
        compute_supports_seq(&z, &mut want);
        let pool = Pool::new(3);
        for mode in [Mode::Coarse, Mode::Fine] {
            let n_tasks = match mode {
                Mode::Coarse => z.n(),
                Mode::Fine => z.slots(),
            };
            let skewed = Costs { per_task: (0..n_tasks).map(|i| (i as u64 % 17) + 1).collect() };
            for sched in [Schedule::WorkAware, Schedule::Stealing] {
                let s: Vec<AtomicU32> = (0..z.slots()).map(|_| AtomicU32::new(0)).collect();
                compute_supports_costed(&z, &pool, mode, sched, &s, Some(&skewed), None);
                let got: Vec<u32> = s.iter().map(|x| x.load(Ordering::Relaxed)).collect();
                assert_eq!(got, want, "{mode} {sched:?}");
            }
        }
    }

    #[test]
    fn prune_par_matches_seq() {
        let g = random_graph(3);
        let z0 = ZCsr::from_csr(&g);
        let mut s0 = Vec::new();
        compute_supports_seq(&z0, &mut s0);
        let mut z1 = z0.clone();
        let mut s1 = s0.clone();
        let a = crate::algo::prune::prune(&mut z1, &mut s1, 4);
        let pool = Pool::new(3);
        for sched in ALL_SCHEDULES {
            let mut z2 = z0.clone();
            let mut s2 = s0.clone();
            let b = prune_par(&mut z2, &mut s2, 4, &pool, sched);
            assert_eq!(a, b, "{sched:?}");
            assert_eq!(z1, z2, "{sched:?}");
            assert_eq!(s1, s2, "{sched:?}");
        }
    }

    #[test]
    fn prune_par_empty_graph() {
        let g = crate::graph::Csr::empty(0);
        let pool = Pool::new(4);
        for sched in ALL_SCHEDULES {
            let mut z = ZCsr::from_csr(&g);
            let mut s: Vec<u32> = vec![0; z.slots()];
            let out = prune_par(&mut z, &mut s, 3, &pool, sched);
            assert_eq!(out.removed, 0, "{sched:?}");
            assert_eq!(out.remaining, 0, "{sched:?}");
        }
        // vertices but no edges: every row is just its terminator
        let g = crate::graph::Csr::empty(5);
        for sched in ALL_SCHEDULES {
            let mut z = ZCsr::from_csr(&g);
            let mut s: Vec<u32> = vec![0; z.slots()];
            let out = prune_par(&mut z, &mut s, 3, &pool, sched);
            assert_eq!((out.removed, out.remaining), (0, 0), "{sched:?}");
            assert!(crate::graph::validate::check_zcsr(&z).is_ok(), "{sched:?}");
        }
    }

    #[test]
    fn prune_par_all_edges_die_in_one_pass() {
        // a path has zero support everywhere: k=3 kills every edge at once
        let g = crate::testkit::graphs::path(12);
        let pool = Pool::new(3);
        for sched in ALL_SCHEDULES {
            let mut z = ZCsr::from_csr(&g);
            let mut s = Vec::new();
            compute_supports_seq(&z, &mut s);
            let out = prune_par(&mut z, &mut s, 3, &pool, sched);
            assert_eq!(out.removed, g.nnz(), "{sched:?}");
            assert_eq!(out.remaining, 0, "{sched:?}");
            assert_eq!(z.live_edges(), 0, "{sched:?}");
            assert!(s.iter().all(|&x| x == 0), "{sched:?}: supports reset");
            assert!(crate::graph::validate::check_zcsr(&z).is_ok(), "{sched:?}");
        }
    }

    #[test]
    fn prune_par_row_of_only_tombstones() {
        // craft a working form whose row 0 is entirely tombstones (a
        // prior pass killed the whole row): prune must leave it alone
        // and still compact the healthy rows correctly
        let g = crate::graph::builder::from_sorted_unique(
            4,
            &[(0, 1), (0, 2), (0, 3), (1, 2), (2, 3)],
        );
        let pool = Pool::new(4);
        for sched in ALL_SCHEDULES {
            let mut z = ZCsr::from_csr(&g);
            let (start, end) = z.row_span(0);
            for p in start..end {
                z.col_mut()[p] = 0;
            }
            let mut s = vec![5u32; z.slots()];
            let out = prune_par(&mut z, &mut s, 3, &pool, sched);
            assert_eq!(out.removed, 0, "{sched:?}");
            assert_eq!(out.remaining, 2, "{sched:?}"); // (1,2) and (2,3) survive
            assert_eq!(z.row_live(0), &[] as &[u32], "{sched:?}");
            assert!(s.iter().all(|&x| x == 0), "{sched:?}");
            assert!(crate::graph::validate::check_zcsr(&z).is_ok(), "{sched:?}");
        }
    }
}
