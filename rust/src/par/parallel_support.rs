//! Concurrent `computeSupports` on the real worker pool — the rust
//! analogue of the paper's Kokkos Listing 1, in both granularities.
//!
//! The support array is `AtomicU32` (the paper's `Atomic` memory trait):
//! fine-grained tasks racing on shared `S₂₂` rows is the whole point,
//! and relaxed fetch-adds are sufficient because supports are pure
//! commutative counters read only after the pass completes.

use super::pool::{Pool, Schedule};
use crate::algo::support::{eager_update_atomic, Mode};
use crate::graph::ZCsr;
use std::sync::atomic::{AtomicU32, Ordering};

/// Run one support pass concurrently; returns the plain support array.
pub fn compute_supports_par(z: &ZCsr, pool: &Pool, mode: Mode, schedule: Schedule) -> Vec<u32> {
    let s: Vec<AtomicU32> = (0..z.slots()).map(|_| AtomicU32::new(0)).collect();
    compute_supports_into(z, pool, mode, schedule, &s);
    s.into_iter().map(|x| x.into_inner()).collect()
}

/// Run one support pass into an existing (zeroed) atomic array.
pub fn compute_supports_into(
    z: &ZCsr,
    pool: &Pool,
    mode: Mode,
    schedule: Schedule,
    s: &[AtomicU32],
) {
    assert_eq!(s.len(), z.slots());
    let col = z.col();
    match mode {
        Mode::Coarse => {
            // one task per row (paper Algorithm 2): the task walks all
            // live entries of a₁₂ᵀ
            pool.parallel_for(z.n(), schedule, |_, i| {
                let (start, end) = z.row_span(i);
                for p in start..end {
                    let kappa = col[p];
                    if kappa == 0 {
                        break;
                    }
                    let (r0, _) = z.row_span(kappa as usize);
                    eager_update_atomic(col, s, p, r0);
                }
            });
        }
        Mode::Fine => {
            // one task per slot (paper Algorithm 3 / Listing 1): a flat
            // range over the zero-terminated nonzero array; terminator
            // and tombstone slots are trivial no-ops, exactly as in the
            // paper's flat RangePolicy formulation
            pool.parallel_for(z.slots(), schedule, |_, p| {
                let kappa = col[p];
                if kappa == 0 {
                    return;
                }
                let (r0, _) = z.row_span(kappa as usize);
                eager_update_atomic(col, s, p, r0);
            });
        }
    }
}

/// Concurrent prune: each row is compacted independently (rows never
/// share slots), so a plain parallel-for over rows with interior
/// mutability via raw pointer partitioning is safe.
pub fn prune_par(z: &mut ZCsr, s: &mut [u32], k: u32, pool: &Pool) -> crate::algo::prune::PruneOutcome {
    use std::sync::atomic::AtomicUsize;
    let threshold = k.saturating_sub(2);
    let removed = AtomicUsize::new(0);
    let remaining = AtomicUsize::new(0);
    let n = z.n();
    let row_ptr: Vec<(usize, usize)> = (0..n).map(|i| z.row_span(i)).collect();
    let col_ptr = SendPtr(z.col_mut().as_mut_ptr());
    let s_ptr = SendPtr(s.as_mut_ptr());
    pool.parallel_for(n, Schedule::Static, |_, i| {
        let (start, end) = row_ptr[i];
        // SAFETY: rows are disjoint slot ranges; each i touches only
        // [start, end) of both arrays.
        let col = unsafe { std::slice::from_raw_parts_mut(col_ptr.get().add(start), end - start) };
        let sup = unsafe { std::slice::from_raw_parts_mut(s_ptr.get().add(start), end - start) };
        let mut write = 0usize;
        let mut local_removed = 0usize;
        for p in 0..col.len() {
            let c = col[p];
            if c == 0 {
                break;
            }
            if sup[p] >= threshold {
                col[write] = c;
                write += 1;
            } else {
                local_removed += 1;
            }
        }
        for slot in col.iter_mut().skip(write) {
            *slot = 0;
        }
        for sp in sup.iter_mut() {
            *sp = 0;
        }
        removed.fetch_add(local_removed, Ordering::Relaxed);
        remaining.fetch_add(write, Ordering::Relaxed);
    });
    crate::algo::prune::PruneOutcome {
        removed: removed.into_inner(),
        remaining: remaining.into_inner(),
    }
}

/// Pointer wrapper that asserts cross-thread use is safe because the
/// parallel-for partitions rows disjointly.
struct SendPtr<T>(*mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}
impl<T> SendPtr<T> {
    /// Accessor (rather than field capture) so edition-2021 closures
    /// capture the `Sync` wrapper, not the raw pointer field.
    fn get(&self) -> *mut T {
        self.0
    }
}

/// Full concurrent k-truss (support + prune until convergence) — the
/// production entry point used by the coordinator's CPU engine.
pub fn ktruss_par(
    g: &crate::graph::Csr,
    k: u32,
    pool: &Pool,
    mode: Mode,
    schedule: Schedule,
) -> crate::algo::ktruss::KtrussResult {
    let mut z = ZCsr::from_csr(g);
    let s_atomic: Vec<AtomicU32> = (0..z.slots()).map(|_| AtomicU32::new(0)).collect();
    let mut s_plain = vec![0u32; z.slots()];
    let mut iterations = 0usize;
    let mut stats = Vec::new();
    loop {
        let live = z.live_edges();
        if live == 0 {
            break;
        }
        compute_supports_into(&z, pool, mode, schedule, &s_atomic);
        for (d, a) in s_plain.iter_mut().zip(s_atomic.iter()) {
            *d = a.swap(0, Ordering::Relaxed);
        }
        let support_steps = s_plain.iter().map(|&x| x as u64).sum::<u64>() + live as u64;
        let out = prune_par(&mut z, &mut s_plain, k, pool);
        iterations += 1;
        stats.push(crate::algo::ktruss::IterationStat {
            live_edges: live,
            removed: out.removed,
            support_steps,
        });
        if out.removed == 0 {
            break;
        }
    }
    crate::algo::ktruss::KtrussResult { truss: z.to_csr(), iterations, stats, k, mode }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::ktruss::ktruss;
    use crate::algo::support::compute_supports_seq;

    fn random_graph(seed: u64) -> crate::graph::Csr {
        crate::gen::rmat::rmat(
            300,
            2200,
            crate::gen::rmat::RmatParams::social(),
            &mut crate::util::Rng::new(seed),
        )
    }

    #[test]
    fn par_supports_match_seq_all_modes_and_schedules() {
        let g = random_graph(1);
        let z = ZCsr::from_csr(&g);
        let mut want = Vec::new();
        compute_supports_seq(&z, &mut want);
        let pool = Pool::new(4);
        for mode in [Mode::Coarse, Mode::Fine] {
            for sched in [Schedule::Static, Schedule::Dynamic { chunk: 16 }] {
                let got = compute_supports_par(&z, &pool, mode, sched);
                assert_eq!(got, want, "{mode} {sched:?}");
            }
        }
    }

    #[test]
    fn par_ktruss_matches_seq() {
        let g = random_graph(2);
        let pool = Pool::new(4);
        for k in [3u32, 5] {
            let seq = ktruss(&g, k, Mode::Fine);
            for mode in [Mode::Coarse, Mode::Fine] {
                let par = ktruss_par(&g, k, &pool, mode, Schedule::Dynamic { chunk: 64 });
                assert_eq!(par.truss, seq.truss, "k={k} {mode}");
                assert_eq!(par.iterations, seq.iterations, "k={k} {mode}");
            }
        }
    }

    #[test]
    fn prune_par_matches_seq() {
        let g = random_graph(3);
        let mut z1 = ZCsr::from_csr(&g);
        let mut z2 = z1.clone();
        let mut s1 = Vec::new();
        compute_supports_seq(&z1, &mut s1);
        let mut s2 = s1.clone();
        let pool = Pool::new(3);
        let a = crate::algo::prune::prune(&mut z1, &mut s1, 4);
        let b = prune_par(&mut z2, &mut s2, 4, &pool);
        assert_eq!(a, b);
        assert_eq!(z1, z2);
        assert_eq!(s1, s2);
    }
}
