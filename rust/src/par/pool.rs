//! A scoped worker pool with Kokkos-`RangePolicy`-style scheduling.
//!
//! This is the *real* concurrent execution path (atomics and all); it
//! validates that the eager update kernel is safe under concurrency.
//! Timing on this container is meaningless for the paper's experiments
//! (1 hardware core) — the calibrated models in [`crate::sim`] produce
//! the 48-thread/GPU timing instead (DESIGN.md §2).
//!
//! Beyond the paper's `Static`/`Dynamic` pair, the pool executes the
//! two work-aware schedules from [`super::balance`]: scan-binned
//! equal-work chunks (`WorkAware`) and chunk deques with work stealing
//! (`Stealing`). Cost estimates flow in through
//! [`Pool::parallel_for_costed`]; without estimates the work-aware
//! schedules degrade to their cost-oblivious equivalents.

use super::balance;
use std::sync::atomic::{AtomicUsize, Ordering};

/// How a 1-D iteration range is divided among workers, mirroring the
/// schedules Kokkos'/OpenMP's `RangePolicy` offers plus the two
/// work-aware strategies from the load-balancing literature
/// (see [`super::balance`] for the technique-to-paper mapping).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Schedule {
    /// Contiguous equal-count blocks, one per worker (OpenMP default,
    /// and what the paper's flat RangePolicy compiles to on CPU).
    Static,
    /// Workers grab fixed-size chunks from a shared counter.
    Dynamic { chunk: usize },
    /// Scan-binned contiguous chunks of approximately equal estimated
    /// *work*, one per worker (Hornet `ScanBased`/`BinarySearch`
    /// idiom). Falls back to `Static` when no cost estimate is
    /// available.
    WorkAware,
    /// Per-worker chunk deques (seeded by scan binning) with work
    /// stealing from victims' tails.
    Stealing,
}

impl std::fmt::Display for Schedule {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Schedule::Static => write!(f, "static"),
            Schedule::Dynamic { chunk } => write!(f, "dynamic:{chunk}"),
            Schedule::WorkAware => write!(f, "workaware"),
            Schedule::Stealing => write!(f, "stealing"),
        }
    }
}

impl std::str::FromStr for Schedule {
    type Err = String;

    /// Parse `static`, `dynamic`, `dynamic:<chunk>`, `workaware`,
    /// `stealing` (the CLI `--schedule` grammar).
    fn from_str(s: &str) -> Result<Schedule, String> {
        match s {
            "static" => Ok(Schedule::Static),
            "dynamic" => Ok(Schedule::Dynamic { chunk: 256 }),
            "workaware" | "work-aware" => Ok(Schedule::WorkAware),
            "stealing" | "steal" => Ok(Schedule::Stealing),
            other => other
                .strip_prefix("dynamic:")
                .and_then(|c| c.parse::<usize>().ok())
                .filter(|&c| c > 0)
                .map(|chunk| Schedule::Dynamic { chunk })
                .ok_or_else(|| {
                    format!(
                        "unknown schedule {other:?} (expected static|dynamic[:chunk]|workaware|stealing)"
                    )
                }),
        }
    }
}

/// A fixed-width worker pool. Threads are spawned per call via
/// `std::thread::scope` — simple, safe, and cheap relative to the
/// kernels we run (ms-scale tasks).
#[derive(Clone, Copy, Debug)]
pub struct Pool {
    workers: usize,
}

impl Pool {
    /// A pool with `workers` threads (≥ 1).
    pub fn new(workers: usize) -> Pool {
        Pool { workers: workers.max(1) }
    }

    /// Pool sized to available hardware parallelism.
    pub fn host() -> Pool {
        Pool::new(
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
        )
    }

    /// Worker threads this pool runs.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Parallel-for over `0..n`: every index is passed to `f` exactly
    /// once; `worker` is the executing worker's id.
    pub fn parallel_for(&self, n: usize, schedule: Schedule, f: impl Fn(usize, usize) + Sync) {
        if n == 0 {
            return;
        }
        if self.workers == 1 {
            for i in 0..n {
                f(0, i);
            }
            return;
        }
        match schedule {
            // WorkAware without cost estimates degenerates to uniform
            // costs, whose scan bins are exactly the static blocks.
            Schedule::Static | Schedule::WorkAware => {
                std::thread::scope(|scope| {
                    for w in 0..self.workers {
                        let f = &f;
                        // contiguous block [lo, hi) for worker w
                        let lo = n * w / self.workers;
                        let hi = n * (w + 1) / self.workers;
                        scope.spawn(move || {
                            for i in lo..hi {
                                f(w, i);
                            }
                        });
                    }
                });
            }
            Schedule::Dynamic { chunk } => {
                let chunk = chunk.max(1);
                let next = AtomicUsize::new(0);
                std::thread::scope(|scope| {
                    for w in 0..self.workers {
                        let f = &f;
                        let next = &next;
                        scope.spawn(move || loop {
                            let lo = next.fetch_add(chunk, Ordering::Relaxed);
                            if lo >= n {
                                break;
                            }
                            for i in lo..(lo + chunk).min(n) {
                                f(w, i);
                            }
                        });
                    }
                });
            }
            Schedule::Stealing => {
                let chunks =
                    balance::even_chunks(n, self.workers * balance::STEAL_CHUNKS_PER_WORKER);
                balance::run_stealing(self.workers, chunks, |w, i| f(w, i));
            }
        }
    }

    /// Parallel-for with per-task cost estimates (`costs.len() == n`).
    /// `WorkAware` scan-bins the costs into one equal-work chunk per
    /// worker; `Stealing` seeds the deques with equal-work chunks.
    /// Cost-oblivious schedules ignore `costs`.
    pub fn parallel_for_costed(
        &self,
        n: usize,
        costs: &[u64],
        schedule: Schedule,
        f: impl Fn(usize, usize) + Sync,
    ) {
        assert_eq!(costs.len(), n, "one cost per task required");
        if n == 0 {
            return;
        }
        if self.workers == 1 {
            for i in 0..n {
                f(0, i);
            }
            return;
        }
        match schedule {
            Schedule::WorkAware => {
                let bins = balance::scan_bins(costs, self.workers);
                std::thread::scope(|scope| {
                    for (w, &(lo, hi)) in bins.iter().enumerate() {
                        let f = &f;
                        scope.spawn(move || {
                            for i in lo..hi {
                                f(w, i);
                            }
                        });
                    }
                });
            }
            Schedule::Stealing => {
                let chunks = balance::scan_bins(
                    costs,
                    self.workers * balance::STEAL_CHUNKS_PER_WORKER,
                );
                balance::run_stealing(self.workers, chunks, |w, i| f(w, i));
            }
            other => self.parallel_for(n, other, f),
        }
    }

    /// Parallel map-reduce: apply `f` to each index, combine with `merge`.
    pub fn parallel_reduce<T: Send>(
        &self,
        n: usize,
        schedule: Schedule,
        identity: impl Fn() -> T + Sync,
        f: impl Fn(usize, &mut T) + Sync,
        merge: impl Fn(T, T) -> T,
    ) -> T {
        if self.workers == 1 || n == 0 {
            let mut acc = identity();
            for i in 0..n {
                f(i, &mut acc);
            }
            return acc;
        }
        let partials = std::sync::Mutex::new(Vec::with_capacity(self.workers));
        match schedule {
            Schedule::Static | Schedule::WorkAware => {
                std::thread::scope(|scope| {
                    for w in 0..self.workers {
                        let f = &f;
                        let identity = &identity;
                        let partials = &partials;
                        let lo = n * w / self.workers;
                        let hi = n * (w + 1) / self.workers;
                        scope.spawn(move || {
                            let mut acc = identity();
                            for i in lo..hi {
                                f(i, &mut acc);
                            }
                            partials.lock().unwrap().push(acc);
                        });
                    }
                });
            }
            Schedule::Dynamic { chunk } => {
                let chunk = chunk.max(1);
                let next = AtomicUsize::new(0);
                std::thread::scope(|scope| {
                    for _ in 0..self.workers {
                        let f = &f;
                        let identity = &identity;
                        let partials = &partials;
                        let next = &next;
                        scope.spawn(move || {
                            let mut acc = identity();
                            loop {
                                let lo = next.fetch_add(chunk, Ordering::Relaxed);
                                if lo >= n {
                                    break;
                                }
                                for i in lo..(lo + chunk).min(n) {
                                    f(i, &mut acc);
                                }
                            }
                            partials.lock().unwrap().push(acc);
                        });
                    }
                });
            }
            Schedule::Stealing => {
                let chunks =
                    balance::even_chunks(n, self.workers * balance::STEAL_CHUNKS_PER_WORKER);
                // accumulate per chunk (chunks are coarse, so the
                // per-chunk lock is off the hot path)
                balance::run_stealing_chunks(self.workers, chunks, |_w, lo, hi| {
                    let mut acc = identity();
                    for i in lo..hi {
                        f(i, &mut acc);
                    }
                    partials.lock().unwrap().push(acc);
                });
            }
        }
        partials
            .into_inner()
            .unwrap()
            .into_iter()
            .fold(identity(), merge)
    }
}

/// Every schedule variant, for exhaustive test sweeps.
pub const ALL_SCHEDULES: [Schedule; 4] = [
    Schedule::Static,
    Schedule::Dynamic { chunk: 16 },
    Schedule::WorkAware,
    Schedule::Stealing,
];

/// A cooperative cancellation token: an explicit cancel flag plus an
/// optional wall-clock deadline, checked by the convergence drivers at
/// pass boundaries. Cloning shares the flag (`Arc`), so the serving
/// layer can cancel a running job from outside the worker thread.
///
/// Cancellation is *cooperative and pass-granular* by design: a pass
/// that has started runs to completion (its exact step counts stay
/// accounted), and the driver stops before starting the next one —
/// which is what keeps a cancelled job's span tree satisfying the
/// pass-steps-sum-to-total invariant.
#[derive(Clone, Debug, Default)]
pub struct CancelToken {
    flag: std::sync::Arc<std::sync::atomic::AtomicBool>,
    deadline: Option<std::time::Instant>,
}

impl CancelToken {
    /// A token that only cancels when [`CancelToken::cancel`] is called.
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    /// A token that additionally reports cancelled once `deadline` has
    /// passed.
    pub fn with_deadline(deadline: std::time::Instant) -> CancelToken {
        CancelToken { flag: Default::default(), deadline: Some(deadline) }
    }

    /// Request cancellation (visible to every clone of this token).
    pub fn cancel(&self) {
        self.flag.store(true, Ordering::Release);
    }

    /// Whether the token has been cancelled or its deadline has passed.
    pub fn is_cancelled(&self) -> bool {
        self.flag.load(Ordering::Acquire)
            || self.deadline.is_some_and(|d| std::time::Instant::now() >= d)
    }
}

/// Pass-boundary control threaded through the convergence drivers:
/// an optional [`CancelToken`] plus an optional per-pass hook (used by
/// the fault-injection harness to stall at genuine pass boundaries).
/// The hook receives the 0-based index of the pass that just finished.
#[derive(Clone, Copy, Default)]
pub struct PassControl<'a> {
    /// Checked after every completed pass; when cancelled the driver
    /// returns early with the passes it has already run.
    pub cancel: Option<&'a CancelToken>,
    /// Invoked after every completed pass (fault-injection stalls).
    pub on_pass: Option<&'a (dyn Fn(usize) + Sync)>,
}

impl PassControl<'_> {
    /// Run the per-pass hook (if any) for completed pass `iter`, then
    /// report whether the driver should stop before the next pass.
    pub fn pass_boundary(&self, iter: usize) -> bool {
        if let Some(hook) = self.on_pass {
            hook(iter);
        }
        self.cancel.is_some_and(|c| c.is_cancelled())
    }
}

impl std::fmt::Debug for PassControl<'_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PassControl")
            .field("cancel", &self.cancel)
            .field("on_pass", &self.on_pass.is_some())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, AtomicUsize};

    #[test]
    fn covers_every_index_static() {
        let pool = Pool::new(4);
        let hits: Vec<AtomicUsize> = (0..100).map(|_| AtomicUsize::new(0)).collect();
        pool.parallel_for(100, Schedule::Static, |_, i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn covers_every_index_dynamic() {
        let pool = Pool::new(3);
        let hits: Vec<AtomicUsize> = (0..97).map(|_| AtomicUsize::new(0)).collect();
        pool.parallel_for(97, Schedule::Dynamic { chunk: 5 }, |_, i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn covers_every_index_all_schedules() {
        for sched in ALL_SCHEDULES {
            let pool = Pool::new(4);
            let hits: Vec<AtomicUsize> = (0..251).map(|_| AtomicUsize::new(0)).collect();
            pool.parallel_for(251, sched, |_, i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "{sched:?}"
            );
        }
    }

    #[test]
    fn costed_covers_every_index_all_schedules() {
        // skewed costs so the scan bins are genuinely uneven in count
        let n = 300usize;
        let costs: Vec<u64> = (0..n).map(|i| if i % 50 == 0 { 1000 } else { 1 }).collect();
        for sched in ALL_SCHEDULES {
            let pool = Pool::new(4);
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            pool.parallel_for_costed(n, &costs, sched, |_, i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "{sched:?}"
            );
        }
    }

    #[test]
    fn single_worker_sequential() {
        let pool = Pool::new(1);
        let sum = AtomicU64::new(0);
        pool.parallel_for(10, Schedule::Static, |w, i| {
            assert_eq!(w, 0);
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 45);
    }

    #[test]
    fn empty_range_is_noop() {
        for sched in ALL_SCHEDULES {
            Pool::new(4).parallel_for(0, sched, |_, _| panic!("should not run"));
            Pool::new(4).parallel_for_costed(0, &[], sched, |_, _| panic!("should not run"));
        }
    }

    #[test]
    fn reduce_sums_correctly() {
        let pool = Pool::new(4);
        for sched in ALL_SCHEDULES {
            let total = pool.parallel_reduce(
                1000,
                sched,
                || 0u64,
                |i, acc| *acc += i as u64,
                |a, b| a + b,
            );
            assert_eq!(total, 499_500, "{sched:?}");
        }
    }

    #[test]
    fn cancel_token_flag_and_deadline() {
        let t = CancelToken::new();
        assert!(!t.is_cancelled());
        let shared = t.clone();
        shared.cancel();
        assert!(t.is_cancelled(), "cancel must be visible through clones");

        let expired =
            CancelToken::with_deadline(std::time::Instant::now() - std::time::Duration::from_millis(1));
        assert!(expired.is_cancelled());
        let future =
            CancelToken::with_deadline(std::time::Instant::now() + std::time::Duration::from_secs(600));
        assert!(!future.is_cancelled());
    }

    #[test]
    fn pass_control_runs_hook_then_reports_cancel() {
        let seen = AtomicUsize::new(0);
        let hook = |iter: usize| {
            seen.store(iter + 1, Ordering::Relaxed);
        };
        let token = CancelToken::new();
        let ctl = PassControl { cancel: Some(&token), on_pass: Some(&hook) };
        assert!(!ctl.pass_boundary(3));
        assert_eq!(seen.load(Ordering::Relaxed), 4);
        token.cancel();
        assert!(ctl.pass_boundary(4));
        // the hook still runs on the cancelling boundary
        assert_eq!(seen.load(Ordering::Relaxed), 5);
        assert!(!PassControl::default().pass_boundary(0));
    }

    #[test]
    fn schedule_display_roundtrips_through_fromstr() {
        for sched in [
            Schedule::Static,
            Schedule::Dynamic { chunk: 64 },
            Schedule::WorkAware,
            Schedule::Stealing,
        ] {
            let s = sched.to_string();
            let back: Schedule = s.parse().unwrap_or_else(|e| panic!("{s}: {e}"));
            assert_eq!(back, sched, "{s}");
        }
        assert_eq!("dynamic".parse::<Schedule>().unwrap(), Schedule::Dynamic { chunk: 256 });
        assert!("nope".parse::<Schedule>().is_err());
        assert!("dynamic:0".parse::<Schedule>().is_err());
        assert!("dynamic:x".parse::<Schedule>().is_err());
    }
}
