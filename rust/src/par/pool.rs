//! A scoped worker pool with Kokkos-`RangePolicy`-style scheduling.
//!
//! This is the *real* concurrent execution path (atomics and all); it
//! validates that the eager update kernel is safe under concurrency.
//! Timing on this container is meaningless for the paper's experiments
//! (1 hardware core) — the calibrated models in [`crate::sim`] produce
//! the 48-thread/GPU timing instead (DESIGN.md §2).

use std::sync::atomic::{AtomicUsize, Ordering};

/// How a 1-D iteration range is divided among workers, mirroring the
/// schedules Kokkos'/OpenMP's `RangePolicy` offers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Schedule {
    /// Contiguous equal-count blocks, one per worker (OpenMP default,
    /// and what the paper's flat RangePolicy compiles to on CPU).
    Static,
    /// Workers grab fixed-size chunks from a shared counter.
    Dynamic { chunk: usize },
}

/// A fixed-width worker pool. Threads are spawned per call via
/// `std::thread::scope` — simple, safe, and cheap relative to the
/// kernels we run (ms-scale tasks).
#[derive(Clone, Copy, Debug)]
pub struct Pool {
    workers: usize,
}

impl Pool {
    /// A pool with `workers` threads (≥ 1).
    pub fn new(workers: usize) -> Pool {
        Pool { workers: workers.max(1) }
    }

    /// Pool sized to available hardware parallelism.
    pub fn host() -> Pool {
        Pool::new(
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
        )
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Parallel-for over `0..n`: every index is passed to `f` exactly
    /// once; `worker` is the executing worker's id.
    pub fn parallel_for(&self, n: usize, schedule: Schedule, f: impl Fn(usize, usize) + Sync) {
        if n == 0 {
            return;
        }
        if self.workers == 1 {
            for i in 0..n {
                f(0, i);
            }
            return;
        }
        match schedule {
            Schedule::Static => {
                std::thread::scope(|scope| {
                    for w in 0..self.workers {
                        let f = &f;
                        // contiguous block [lo, hi) for worker w
                        let lo = n * w / self.workers;
                        let hi = n * (w + 1) / self.workers;
                        scope.spawn(move || {
                            for i in lo..hi {
                                f(w, i);
                            }
                        });
                    }
                });
            }
            Schedule::Dynamic { chunk } => {
                let chunk = chunk.max(1);
                let next = AtomicUsize::new(0);
                std::thread::scope(|scope| {
                    for w in 0..self.workers {
                        let f = &f;
                        let next = &next;
                        scope.spawn(move || loop {
                            let lo = next.fetch_add(chunk, Ordering::Relaxed);
                            if lo >= n {
                                break;
                            }
                            for i in lo..(lo + chunk).min(n) {
                                f(w, i);
                            }
                        });
                    }
                });
            }
        }
    }

    /// Parallel map-reduce: apply `f` to each index, combine with `merge`.
    pub fn parallel_reduce<T: Send>(
        &self,
        n: usize,
        schedule: Schedule,
        identity: impl Fn() -> T + Sync,
        f: impl Fn(usize, &mut T) + Sync,
        merge: impl Fn(T, T) -> T,
    ) -> T {
        if self.workers == 1 || n == 0 {
            let mut acc = identity();
            for i in 0..n {
                f(i, &mut acc);
            }
            return acc;
        }
        let partials = std::sync::Mutex::new(Vec::with_capacity(self.workers));
        match schedule {
            Schedule::Static => {
                std::thread::scope(|scope| {
                    for w in 0..self.workers {
                        let f = &f;
                        let identity = &identity;
                        let partials = &partials;
                        let lo = n * w / self.workers;
                        let hi = n * (w + 1) / self.workers;
                        scope.spawn(move || {
                            let mut acc = identity();
                            for i in lo..hi {
                                f(i, &mut acc);
                            }
                            partials.lock().unwrap().push(acc);
                        });
                    }
                });
            }
            Schedule::Dynamic { chunk } => {
                let chunk = chunk.max(1);
                let next = AtomicUsize::new(0);
                std::thread::scope(|scope| {
                    for _ in 0..self.workers {
                        let f = &f;
                        let identity = &identity;
                        let partials = &partials;
                        let next = &next;
                        scope.spawn(move || {
                            let mut acc = identity();
                            loop {
                                let lo = next.fetch_add(chunk, Ordering::Relaxed);
                                if lo >= n {
                                    break;
                                }
                                for i in lo..(lo + chunk).min(n) {
                                    f(i, &mut acc);
                                }
                            }
                            partials.lock().unwrap().push(acc);
                        });
                    }
                });
            }
        }
        partials
            .into_inner()
            .unwrap()
            .into_iter()
            .fold(identity(), merge)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, AtomicUsize};

    #[test]
    fn covers_every_index_static() {
        let pool = Pool::new(4);
        let hits: Vec<AtomicUsize> = (0..100).map(|_| AtomicUsize::new(0)).collect();
        pool.parallel_for(100, Schedule::Static, |_, i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn covers_every_index_dynamic() {
        let pool = Pool::new(3);
        let hits: Vec<AtomicUsize> = (0..97).map(|_| AtomicUsize::new(0)).collect();
        pool.parallel_for(97, Schedule::Dynamic { chunk: 5 }, |_, i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn single_worker_sequential() {
        let pool = Pool::new(1);
        let sum = AtomicU64::new(0);
        pool.parallel_for(10, Schedule::Static, |w, i| {
            assert_eq!(w, 0);
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 45);
    }

    #[test]
    fn empty_range_is_noop() {
        Pool::new(4).parallel_for(0, Schedule::Static, |_, _| panic!("should not run"));
    }

    #[test]
    fn reduce_sums_correctly() {
        let pool = Pool::new(4);
        for sched in [Schedule::Static, Schedule::Dynamic { chunk: 7 }] {
            let total = pool.parallel_reduce(
                1000,
                sched,
                || 0u64,
                |i, acc| *acc += i as u64,
                |a, b| a + b,
            );
            assert_eq!(total, 499_500, "{sched:?}");
        }
    }
}
