//! A scoped worker pool with Kokkos-`RangePolicy`-style scheduling.
//!
//! This is the *real* concurrent execution path (atomics and all); it
//! validates that the eager update kernel is safe under concurrency.
//! Timing on this container is meaningless for the paper's experiments
//! (1 hardware core) — the calibrated models in [`crate::sim`] produce
//! the 48-thread/GPU timing instead (DESIGN.md §2).
//!
//! Beyond the paper's `Static`/`Dynamic` pair, the pool executes the
//! two work-aware schedules from [`super::balance`]: scan-binned
//! equal-work chunks (`WorkAware`) and chunk deques with work stealing
//! (`Stealing`). Cost estimates flow in through
//! [`Pool::parallel_for_costed`]; without estimates the work-aware
//! schedules degrade to their cost-oblivious equivalents.

use super::balance;
use std::sync::atomic::{AtomicUsize, Ordering};

/// How a 1-D iteration range is divided among workers, mirroring the
/// schedules Kokkos'/OpenMP's `RangePolicy` offers plus the two
/// work-aware strategies from the load-balancing literature
/// (see [`super::balance`] for the technique-to-paper mapping).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Schedule {
    /// Contiguous equal-count blocks, one per worker (OpenMP default,
    /// and what the paper's flat RangePolicy compiles to on CPU).
    Static,
    /// Workers grab fixed-size chunks from a shared counter.
    Dynamic { chunk: usize },
    /// Scan-binned contiguous chunks of approximately equal estimated
    /// *work*, one per worker (Hornet `ScanBased`/`BinarySearch`
    /// idiom). Falls back to `Static` when no cost estimate is
    /// available.
    WorkAware,
    /// Per-worker chunk deques (seeded by scan binning) with work
    /// stealing from victims' tails.
    Stealing,
}

impl std::fmt::Display for Schedule {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Schedule::Static => write!(f, "static"),
            Schedule::Dynamic { chunk } => write!(f, "dynamic:{chunk}"),
            Schedule::WorkAware => write!(f, "workaware"),
            Schedule::Stealing => write!(f, "stealing"),
        }
    }
}

impl std::str::FromStr for Schedule {
    type Err = String;

    /// Parse `static`, `dynamic`, `dynamic:<chunk>`, `workaware`,
    /// `stealing` (the CLI `--schedule` grammar).
    fn from_str(s: &str) -> Result<Schedule, String> {
        match s {
            "static" => Ok(Schedule::Static),
            "dynamic" => Ok(Schedule::Dynamic { chunk: 256 }),
            "workaware" | "work-aware" => Ok(Schedule::WorkAware),
            "stealing" | "steal" => Ok(Schedule::Stealing),
            other => other
                .strip_prefix("dynamic:")
                .and_then(|c| c.parse::<usize>().ok())
                .filter(|&c| c > 0)
                .map(|chunk| Schedule::Dynamic { chunk })
                .ok_or_else(|| {
                    format!(
                        "unknown schedule {other:?} (expected static|dynamic[:chunk]|workaware|stealing)"
                    )
                }),
        }
    }
}

/// A fixed-width worker pool. Threads are spawned per call via
/// `std::thread::scope` — simple, safe, and cheap relative to the
/// kernels we run (ms-scale tasks).
#[derive(Clone, Copy, Debug)]
pub struct Pool {
    workers: usize,
}

impl Pool {
    /// A pool with `workers` threads (≥ 1).
    pub fn new(workers: usize) -> Pool {
        Pool { workers: workers.max(1) }
    }

    /// Pool sized to available hardware parallelism.
    pub fn host() -> Pool {
        Pool::new(
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
        )
    }

    /// Worker threads this pool runs.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Parallel-for over `0..n`: every index is passed to `f` exactly
    /// once; `worker` is the executing worker's id.
    pub fn parallel_for(&self, n: usize, schedule: Schedule, f: impl Fn(usize, usize) + Sync) {
        if n == 0 {
            return;
        }
        if self.workers == 1 {
            for i in 0..n {
                f(0, i);
            }
            return;
        }
        match schedule {
            // WorkAware without cost estimates degenerates to uniform
            // costs, whose scan bins are exactly the static blocks.
            Schedule::Static | Schedule::WorkAware => {
                std::thread::scope(|scope| {
                    for w in 0..self.workers {
                        let f = &f;
                        // contiguous block [lo, hi) for worker w
                        let lo = n * w / self.workers;
                        let hi = n * (w + 1) / self.workers;
                        scope.spawn(move || {
                            for i in lo..hi {
                                f(w, i);
                            }
                        });
                    }
                });
            }
            Schedule::Dynamic { chunk } => {
                let chunk = chunk.max(1);
                let next = AtomicUsize::new(0);
                std::thread::scope(|scope| {
                    for w in 0..self.workers {
                        let f = &f;
                        let next = &next;
                        scope.spawn(move || loop {
                            let lo = next.fetch_add(chunk, Ordering::Relaxed);
                            if lo >= n {
                                break;
                            }
                            for i in lo..(lo + chunk).min(n) {
                                f(w, i);
                            }
                        });
                    }
                });
            }
            Schedule::Stealing => {
                let chunks =
                    balance::even_chunks(n, self.workers * balance::STEAL_CHUNKS_PER_WORKER);
                balance::run_stealing(self.workers, chunks, |w, i| f(w, i));
            }
        }
    }

    /// Parallel-for with per-task cost estimates (`costs.len() == n`).
    /// `WorkAware` scan-bins the costs into one equal-work chunk per
    /// worker; `Stealing` seeds the deques with equal-work chunks.
    /// Cost-oblivious schedules ignore `costs`.
    pub fn parallel_for_costed(
        &self,
        n: usize,
        costs: &[u64],
        schedule: Schedule,
        f: impl Fn(usize, usize) + Sync,
    ) {
        assert_eq!(costs.len(), n, "one cost per task required");
        if n == 0 {
            return;
        }
        if self.workers == 1 {
            for i in 0..n {
                f(0, i);
            }
            return;
        }
        match schedule {
            Schedule::WorkAware => {
                let bins = balance::scan_bins(costs, self.workers);
                std::thread::scope(|scope| {
                    for (w, &(lo, hi)) in bins.iter().enumerate() {
                        let f = &f;
                        scope.spawn(move || {
                            for i in lo..hi {
                                f(w, i);
                            }
                        });
                    }
                });
            }
            Schedule::Stealing => {
                let chunks = balance::scan_bins(
                    costs,
                    self.workers * balance::STEAL_CHUNKS_PER_WORKER,
                );
                balance::run_stealing(self.workers, chunks, |w, i| f(w, i));
            }
            other => self.parallel_for(n, other, f),
        }
    }

    /// Parallel map-reduce: apply `f` to each index, combine with `merge`.
    pub fn parallel_reduce<T: Send>(
        &self,
        n: usize,
        schedule: Schedule,
        identity: impl Fn() -> T + Sync,
        f: impl Fn(usize, &mut T) + Sync,
        merge: impl Fn(T, T) -> T,
    ) -> T {
        if self.workers == 1 || n == 0 {
            let mut acc = identity();
            for i in 0..n {
                f(i, &mut acc);
            }
            return acc;
        }
        let partials = std::sync::Mutex::new(Vec::with_capacity(self.workers));
        match schedule {
            Schedule::Static | Schedule::WorkAware => {
                std::thread::scope(|scope| {
                    for w in 0..self.workers {
                        let f = &f;
                        let identity = &identity;
                        let partials = &partials;
                        let lo = n * w / self.workers;
                        let hi = n * (w + 1) / self.workers;
                        scope.spawn(move || {
                            let mut acc = identity();
                            for i in lo..hi {
                                f(i, &mut acc);
                            }
                            partials.lock().unwrap().push(acc);
                        });
                    }
                });
            }
            Schedule::Dynamic { chunk } => {
                let chunk = chunk.max(1);
                let next = AtomicUsize::new(0);
                std::thread::scope(|scope| {
                    for _ in 0..self.workers {
                        let f = &f;
                        let identity = &identity;
                        let partials = &partials;
                        let next = &next;
                        scope.spawn(move || {
                            let mut acc = identity();
                            loop {
                                let lo = next.fetch_add(chunk, Ordering::Relaxed);
                                if lo >= n {
                                    break;
                                }
                                for i in lo..(lo + chunk).min(n) {
                                    f(i, &mut acc);
                                }
                            }
                            partials.lock().unwrap().push(acc);
                        });
                    }
                });
            }
            Schedule::Stealing => {
                let chunks =
                    balance::even_chunks(n, self.workers * balance::STEAL_CHUNKS_PER_WORKER);
                // accumulate per chunk (chunks are coarse, so the
                // per-chunk lock is off the hot path)
                balance::run_stealing_chunks(self.workers, chunks, |_w, lo, hi| {
                    let mut acc = identity();
                    for i in lo..hi {
                        f(i, &mut acc);
                    }
                    partials.lock().unwrap().push(acc);
                });
            }
        }
        partials
            .into_inner()
            .unwrap()
            .into_iter()
            .fold(identity(), merge)
    }
}

/// Every schedule variant, for exhaustive test sweeps.
pub const ALL_SCHEDULES: [Schedule; 4] = [
    Schedule::Static,
    Schedule::Dynamic { chunk: 16 },
    Schedule::WorkAware,
    Schedule::Stealing,
];

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, AtomicUsize};

    #[test]
    fn covers_every_index_static() {
        let pool = Pool::new(4);
        let hits: Vec<AtomicUsize> = (0..100).map(|_| AtomicUsize::new(0)).collect();
        pool.parallel_for(100, Schedule::Static, |_, i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn covers_every_index_dynamic() {
        let pool = Pool::new(3);
        let hits: Vec<AtomicUsize> = (0..97).map(|_| AtomicUsize::new(0)).collect();
        pool.parallel_for(97, Schedule::Dynamic { chunk: 5 }, |_, i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn covers_every_index_all_schedules() {
        for sched in ALL_SCHEDULES {
            let pool = Pool::new(4);
            let hits: Vec<AtomicUsize> = (0..251).map(|_| AtomicUsize::new(0)).collect();
            pool.parallel_for(251, sched, |_, i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "{sched:?}"
            );
        }
    }

    #[test]
    fn costed_covers_every_index_all_schedules() {
        // skewed costs so the scan bins are genuinely uneven in count
        let n = 300usize;
        let costs: Vec<u64> = (0..n).map(|i| if i % 50 == 0 { 1000 } else { 1 }).collect();
        for sched in ALL_SCHEDULES {
            let pool = Pool::new(4);
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            pool.parallel_for_costed(n, &costs, sched, |_, i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "{sched:?}"
            );
        }
    }

    #[test]
    fn single_worker_sequential() {
        let pool = Pool::new(1);
        let sum = AtomicU64::new(0);
        pool.parallel_for(10, Schedule::Static, |w, i| {
            assert_eq!(w, 0);
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 45);
    }

    #[test]
    fn empty_range_is_noop() {
        for sched in ALL_SCHEDULES {
            Pool::new(4).parallel_for(0, sched, |_, _| panic!("should not run"));
            Pool::new(4).parallel_for_costed(0, &[], sched, |_, _| panic!("should not run"));
        }
    }

    #[test]
    fn reduce_sums_correctly() {
        let pool = Pool::new(4);
        for sched in ALL_SCHEDULES {
            let total = pool.parallel_reduce(
                1000,
                sched,
                || 0u64,
                |i, acc| *acc += i as u64,
                |a, b| a + b,
            );
            assert_eq!(total, 499_500, "{sched:?}");
        }
    }

    #[test]
    fn schedule_display_roundtrips_through_fromstr() {
        for sched in [
            Schedule::Static,
            Schedule::Dynamic { chunk: 64 },
            Schedule::WorkAware,
            Schedule::Stealing,
        ] {
            let s = sched.to_string();
            let back: Schedule = s.parse().unwrap_or_else(|e| panic!("{s}: {e}"));
            assert_eq!(back, sched, "{s}");
        }
        assert_eq!("dynamic".parse::<Schedule>().unwrap(), Schedule::Dynamic { chunk: 256 });
        assert!("nope".parse::<Schedule>().is_err());
        assert!("dynamic:0".parse::<Schedule>().is_err());
        assert!("dynamic:x".parse::<Schedule>().is_err());
    }
}
