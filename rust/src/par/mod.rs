//! Kokkos-style parallel substrate: a scoped worker pool with
//! static/dynamic range scheduling, work-aware scan-binned and
//! work-stealing schedules (see [`balance`]), and the concurrent
//! (atomic) realizations of the support and prune kernels.

pub mod balance;
pub mod parallel_support;
pub mod pool;

pub use balance::{estimate_costs, scan_bins, Costs};
pub use parallel_support::{compute_supports_par, ktruss_par, prune_par};
pub use pool::{Pool, Schedule, ALL_SCHEDULES};
