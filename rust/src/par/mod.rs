//! Kokkos-style parallel substrate: a scoped worker pool with
//! static/dynamic range scheduling, and the concurrent (atomic)
//! realizations of the support and prune kernels.

pub mod parallel_support;
pub mod pool;

pub use parallel_support::{compute_supports_par, ktruss_par, prune_par};
pub use pool::{Pool, Schedule};
