//! **L2 — pool & balance.** Kokkos-style parallel substrate: a scoped
//! worker pool with static/dynamic range scheduling, work-aware
//! scan-binned and work-stealing schedules (see [`balance`]), and the
//! concurrent (atomic) realizations of the support and prune kernels
//! at every granularity (coarse rows, fine nonzeros, partner-row
//! segments). This layer owns load balancing at *task* granularity:
//! given the tasks [`crate::algo`] defines, distribute them across the
//! pool so no worker starves behind a hub row. The incremental support
//! driver's frontier pass ([`frontier`]) runs here too, binning the
//! pruned-edge frontier instead of the whole graph.

pub mod balance;
pub mod frontier;
pub mod parallel_support;
pub mod pool;

pub use balance::{estimate_costs, scan_bins, Costs};
pub use frontier::{
    compact_preserving_par, decrement_frontier_par, decrement_frontier_par_gran,
    increment_frontier_par, increment_frontier_par_gran,
};
pub use parallel_support::{
    compute_supports_gran, compute_supports_hybrid, compute_supports_hybrid_tasks,
    compute_supports_par, compute_supports_segmented, ktruss_par, ktruss_par_gran,
    ktruss_par_gran_mode, ktruss_par_mode, ktruss_par_plan, ktruss_par_plan_ctl, prune_par,
};
pub use pool::{CancelToken, PassControl, Pool, Schedule, ALL_SCHEDULES};
