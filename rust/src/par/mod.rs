//! **L2 — pool & balance.** Kokkos-style parallel substrate: a scoped
//! worker pool with static/dynamic range scheduling, work-aware
//! scan-binned and work-stealing schedules (see [`balance`]), and the
//! concurrent (atomic) realizations of the support and prune kernels
//! at every granularity (coarse rows, fine nonzeros, partner-row
//! segments). This layer owns load balancing at *task* granularity:
//! given the tasks [`crate::algo`] defines, distribute them across the
//! pool so no worker starves behind a hub row.

pub mod balance;
pub mod parallel_support;
pub mod pool;

pub use balance::{estimate_costs, scan_bins, Costs};
pub use parallel_support::{
    compute_supports_gran, compute_supports_par, compute_supports_segmented, ktruss_par,
    ktruss_par_gran, prune_par,
};
pub use pool::{Pool, Schedule, ALL_SCHEDULES};
