//! Per-task cost tracing for the support kernel.
//!
//! The paper's load-imbalance argument (§III-A) is entirely about the
//! *distribution of task costs*: a coarse task's cost is the total merge
//! work of its row, a fine task's cost is the merge work of one nonzero.
//! The tracer records the exact merge-step count of every fine task
//! (slot); coarse task costs are derived by summing a row's slots.
//! These distributions — not wallclock on this 1-core container — drive
//! the calibrated CPU/GPU timing models in [`crate::sim`].

use crate::algo::support::eager_update_seq;
use crate::graph::ZCsr;
use crate::util::stats::Summary;

/// The measured cost of one support pass.
#[derive(Clone, Debug)]
pub struct SupportTrace {
    /// Merge steps per slot (0 for terminators/tombstones). Length ==
    /// `z.slots()` at the time of the pass.
    pub fine_steps: Vec<u32>,
    /// Live entries per row at the time of the pass (fine tasks that do
    /// real work; terminator checks are modeled as overhead-only tasks).
    pub live_per_row: Vec<u32>,
    /// Σ fine_steps.
    pub total_steps: u64,
}

impl SupportTrace {
    /// Coarse task cost for row `i` in merge steps (excluding per-entry
    /// overhead, which the machine model adds).
    pub fn row_steps(&self, row_ptr: &[u32], i: usize) -> u64 {
        let (s, e) = (row_ptr[i] as usize, row_ptr[i + 1] as usize);
        self.fine_steps[s..e].iter().map(|&x| x as u64).sum()
    }

    /// All coarse task costs.
    pub fn all_row_steps(&self, row_ptr: &[u32]) -> Vec<u64> {
        (0..row_ptr.len() - 1).map(|i| self.row_steps(row_ptr, i)).collect()
    }

    /// Distribution summary of coarse task costs — the imbalance the
    /// paper's Fig. 1 illustrates.
    pub fn coarse_summary(&self, row_ptr: &[u32]) -> Option<Summary> {
        let xs: Vec<f64> = self.all_row_steps(row_ptr).iter().map(|&x| x as f64).collect();
        Summary::of(&xs)
    }

    /// Distribution summary of fine task costs.
    pub fn fine_summary(&self) -> Option<Summary> {
        let xs: Vec<f64> = self.fine_steps.iter().map(|&x| x as f64).collect();
        Summary::of(&xs)
    }
}

/// Run one support pass sequentially, filling `s` with supports and
/// returning the per-slot cost trace.
pub fn trace_supports(z: &ZCsr, s: &mut Vec<u32>) -> SupportTrace {
    let mut trace = SupportTrace {
        fine_steps: Vec::new(),
        live_per_row: Vec::new(),
        total_steps: 0,
    };
    trace_supports_into(z, s, &mut trace);
    trace
}

/// Buffer-reusing variant (§Perf: the replay driver calls this once per
/// iteration; reusing the two big vectors removes the dominant
/// allocation from multi-iteration bench runs).
pub fn trace_supports_into(z: &ZCsr, s: &mut Vec<u32>, trace: &mut SupportTrace) {
    s.clear();
    s.resize(z.slots(), 0);
    trace.fine_steps.clear();
    trace.fine_steps.resize(z.slots(), 0);
    trace.live_per_row.clear();
    trace.live_per_row.resize(z.n(), 0);
    let mut total: u64 = 0;
    let col = z.col();
    for i in 0..z.n() {
        let (start, end) = z.row_span(i);
        for p in start..end {
            let kappa = col[p];
            if kappa == 0 {
                break;
            }
            trace.live_per_row[i] += 1;
            let (r0, _) = z.row_span(kappa as usize);
            let steps = eager_update_seq(col, s, p, r0);
            trace.fine_steps[p] = steps.min(u32::MAX as u64) as u32;
            total += steps;
        }
    }
    trace.total_steps = total;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::support::compute_supports_seq;
    use crate::graph::builder::from_sorted_unique;

    #[test]
    fn trace_matches_untraced_supports() {
        let g = crate::gen::rmat::rmat(
            250,
            1800,
            crate::gen::rmat::RmatParams::social(),
            &mut crate::util::Rng::new(15),
        );
        let z = ZCsr::from_csr(&g);
        let mut s1 = Vec::new();
        let mut s2 = Vec::new();
        compute_supports_seq(&z, &mut s1);
        let tr = trace_supports(&z, &mut s2);
        assert_eq!(s1, s2);
        assert_eq!(tr.fine_steps.len(), z.slots());
        assert!(tr.total_steps > 0);
    }

    #[test]
    fn row_steps_sum_to_total() {
        let g = from_sorted_unique(4, &[(0, 1), (0, 2), (0, 3), (1, 2), (2, 3)]);
        let z = ZCsr::from_csr(&g);
        let mut s = Vec::new();
        let tr = trace_supports(&z, &mut s);
        let rows = tr.all_row_steps(z.row_ptr());
        assert_eq!(rows.iter().sum::<u64>(), tr.total_steps);
        assert_eq!(tr.row_steps(z.row_ptr(), 0), 2);
        assert_eq!(tr.row_steps(z.row_ptr(), 3), 0);
    }

    #[test]
    fn coarse_costs_more_skewed_than_fine_on_powerlaw() {
        let g = crate::gen::rmat::rmat(
            2000,
            12_000,
            crate::gen::rmat::RmatParams::autonomous_system(),
            &mut crate::util::Rng::new(99),
        );
        let z = ZCsr::from_csr(&g);
        let mut s = Vec::new();
        let tr = trace_supports(&z, &mut s);
        let coarse = tr.coarse_summary(z.row_ptr()).unwrap();
        let fine = tr.fine_summary().unwrap();
        // the paper's whole premise: row-level imbalance (max/mean) far
        // exceeds nonzero-level imbalance
        assert!(
            coarse.imbalance() > 2.0 * fine.imbalance(),
            "coarse {} fine {}",
            coarse.imbalance(),
            fine.imbalance()
        );
    }

    #[test]
    fn live_per_row_counts() {
        let g = from_sorted_unique(4, &[(0, 1), (0, 2), (0, 3), (1, 2), (2, 3)]);
        let z = ZCsr::from_csr(&g);
        let mut s = Vec::new();
        let tr = trace_supports(&z, &mut s);
        assert_eq!(tr.live_per_row, vec![3, 1, 1, 0]);
    }
}
