//! Persistence for measured job traces — the calibration feedback loop
//! of the serving cost model.
//!
//! Each record pairs a job's *static* cost estimate (merge steps read
//! off the graph, see `serve::cost_model`) with the *measured* wall
//! time of executing it. Replaying these records re-seeds the cost
//! model's ns-per-step calibration at startup, so batch packing starts
//! from observed hardware behaviour instead of the built-in default —
//! the job-level analogue of feeding `cost::replay` traces back into
//! the work-aware binner.
//!
//! Format: line-oriented TSV (`kind n m est_steps wall_ms`), `#`-prefix
//! comments. Hand-rolled because the offline crate set has no serde.

use anyhow::{Context, Result};
use std::path::Path;

/// One measured execution of a served job.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceRecord {
    /// Job kind label (`ktruss`, `kmax`, `decompose`, `triangles`).
    pub kind: String,
    /// Vertices of the job's graph.
    pub n: usize,
    /// Edges of the job's graph.
    pub m: usize,
    /// The cost model's static estimate at admission time.
    pub est_steps: u64,
    /// Measured execution wall time (excluding queueing).
    pub wall_ms: f64,
}

/// Write `records` to `path` (atomically enough for calibration data:
/// full rewrite, no partial appends).
pub fn save(path: &Path, records: &[TraceRecord]) -> Result<()> {
    let mut out = String::from("# ktruss serve calibration: kind n m est_steps wall_ms\n");
    for r in records {
        out.push_str(&format!(
            "{}\t{}\t{}\t{}\t{:.6}\n",
            r.kind, r.n, r.m, r.est_steps, r.wall_ms
        ));
    }
    std::fs::write(path, out).with_context(|| format!("write trace file {}", path.display()))
}

/// Load records from `path`. Unparseable lines are an error (the file
/// is machine-written); comment and blank lines are skipped.
pub fn load(path: &Path) -> Result<Vec<TraceRecord>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("read trace file {}", path.display()))?;
    let mut out = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let fields: Vec<&str> = line.split_whitespace().collect();
        if fields.len() != 5 {
            anyhow::bail!(
                "{}:{}: expected 5 fields, got {}",
                path.display(),
                lineno + 1,
                fields.len()
            );
        }
        let at = |what: &str| format!("{}:{}: bad {what}", path.display(), lineno + 1);
        let rec = TraceRecord {
            kind: fields[0].to_string(),
            n: fields[1].parse().with_context(|| at("n"))?,
            m: fields[2].parse().with_context(|| at("m"))?,
            est_steps: fields[3].parse().with_context(|| at("est_steps"))?,
            wall_ms: fields[4].parse().with_context(|| at("wall_ms"))?,
        };
        out.push(rec);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(name)
    }

    #[test]
    fn save_load_roundtrip() {
        let path = tmp("ktruss-persist-roundtrip.tsv");
        let records = vec![
            TraceRecord { kind: "ktruss".into(), n: 100, m: 400, est_steps: 9000, wall_ms: 1.25 },
            TraceRecord { kind: "kmax".into(), n: 50, m: 80, est_steps: 700, wall_ms: 0.5 },
        ];
        save(&path, &records).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back, records);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn load_skips_comments_and_rejects_garbage() {
        let path = tmp("ktruss-persist-garbage.tsv");
        std::fs::write(&path, "# header\n\nktruss\t10\t20\t30\t0.5\n").unwrap();
        let recs = load(&path).unwrap();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].est_steps, 30);

        std::fs::write(&path, "ktruss\t10\t20\n").unwrap();
        assert!(load(&path).is_err());
        std::fs::write(&path, "ktruss\tx\t20\t30\t0.5\n").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn load_missing_file_is_an_error() {
        assert!(load(&tmp("ktruss-persist-definitely-missing.tsv")).is_err());
    }
}
