//! Persistence for measured job traces — the calibration feedback loop
//! of the serving cost model.
//!
//! Each record pairs a job's *static* cost estimate (merge steps read
//! off the graph, see `serve::cost_model`) with the *measured* wall
//! time of executing it. Replaying these records re-seeds the cost
//! model's ns-per-step calibration at startup, so batch packing starts
//! from observed hardware behaviour instead of the built-in default —
//! the job-level analogue of feeding `cost::replay` traces back into
//! the work-aware binner.
//!
//! Format: line-oriented TSV
//! (`kind n m est_steps wall_ms schedule granularity support device`),
//! `#`-prefix comments. The four plan-provenance columns record the
//! executed plan axes (`-` when the job ran unplanned, and for records
//! written before the columns existed — the loader accepts the legacy
//! 5-field and 8-field rows). The `device` column carries the executed
//! backend (`cpu`/`gpu`) so drift baselines seeded from these records
//! never fold lane-backend walls into the CPU regimes. Hand-rolled
//! because the offline crate set has no serde.

use anyhow::{Context, Result};
use std::path::Path;

/// The provenance placeholder for an axis the record does not carry
/// (unplanned jobs, legacy records).
pub const NO_PROVENANCE: &str = "-";

/// One measured execution of a served job.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceRecord {
    /// Job kind label (`ktruss`, `kmax`, `decompose`, `triangles`,
    /// optionally suffixed `+<support>` by the serving calibration).
    pub kind: String,
    /// Vertices of the job's graph.
    pub n: usize,
    /// Edges of the job's graph.
    pub m: usize,
    /// The cost model's static estimate at admission time.
    pub est_steps: u64,
    /// Measured execution wall time (excluding queueing).
    pub wall_ms: f64,
    /// Executed schedule axis ([`NO_PROVENANCE`] when unplanned).
    pub schedule: String,
    /// Executed granularity axis ([`NO_PROVENANCE`] when unplanned).
    pub granularity: String,
    /// Executed support-mode axis ([`NO_PROVENANCE`] when unplanned).
    pub support: String,
    /// Executed device axis (`cpu`/`gpu`; [`NO_PROVENANCE`] when
    /// unplanned or loaded from a pre-device record).
    pub device: String,
}

impl TraceRecord {
    /// A record without plan provenance (every axis
    /// [`NO_PROVENANCE`]) — what non-truss kinds and legacy rows carry.
    pub fn unplanned(
        kind: String,
        n: usize,
        m: usize,
        est_steps: u64,
        wall_ms: f64,
    ) -> TraceRecord {
        TraceRecord {
            kind,
            n,
            m,
            est_steps,
            wall_ms,
            schedule: NO_PROVENANCE.to_string(),
            granularity: NO_PROVENANCE.to_string(),
            support: NO_PROVENANCE.to_string(),
            device: NO_PROVENANCE.to_string(),
        }
    }

    /// Whether the record carries any executed plan axis.
    pub fn has_provenance(&self) -> bool {
        self.schedule != NO_PROVENANCE
            || self.granularity != NO_PROVENANCE
            || self.support != NO_PROVENANCE
            || self.device != NO_PROVENANCE
    }
}

/// Write `records` to `path` (atomically enough for calibration data:
/// full rewrite, no partial appends).
pub fn save(path: &Path, records: &[TraceRecord]) -> Result<()> {
    let mut out = String::from(
        "# ktruss serve calibration: kind n m est_steps wall_ms schedule granularity support device\n",
    );
    for r in records {
        out.push_str(&format!(
            "{}\t{}\t{}\t{}\t{:.6}\t{}\t{}\t{}\t{}\n",
            r.kind,
            r.n,
            r.m,
            r.est_steps,
            r.wall_ms,
            r.schedule,
            r.granularity,
            r.support,
            r.device
        ));
    }
    std::fs::write(path, out).with_context(|| format!("write trace file {}", path.display()))
}

/// Load records from `path`. Unparseable lines are an error (the file
/// is machine-written); comment and blank lines are skipped. Accepts
/// the current 9-field rows, the pre-device 8-field rows (which load
/// with a [`NO_PROVENANCE`] device axis), and the legacy 5-field rows
/// (which load with every plan axis [`NO_PROVENANCE`]).
pub fn load(path: &Path) -> Result<Vec<TraceRecord>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("read trace file {}", path.display()))?;
    let mut out = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let fields: Vec<&str> = line.split_whitespace().collect();
        if fields.len() != 5 && fields.len() != 8 && fields.len() != 9 {
            anyhow::bail!(
                "{}:{}: expected 5 (legacy), 8 (pre-device) or 9 fields, got {}",
                path.display(),
                lineno + 1,
                fields.len()
            );
        }
        let at = |what: &str| format!("{}:{}: bad {what}", path.display(), lineno + 1);
        let prov = |i: usize| {
            fields.get(i).map(|s| s.to_string()).unwrap_or_else(|| NO_PROVENANCE.to_string())
        };
        let rec = TraceRecord {
            kind: fields[0].to_string(),
            n: fields[1].parse().with_context(|| at("n"))?,
            m: fields[2].parse().with_context(|| at("m"))?,
            est_steps: fields[3].parse().with_context(|| at("est_steps"))?,
            wall_ms: fields[4].parse().with_context(|| at("wall_ms"))?,
            schedule: prov(5),
            granularity: prov(6),
            support: prov(7),
            device: prov(8),
        };
        out.push(rec);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(name)
    }

    #[test]
    fn save_load_roundtrip() {
        let path = tmp("ktruss-persist-roundtrip.tsv");
        let mut planned = TraceRecord::unplanned("ktruss+full".into(), 100, 400, 9000, 1.25);
        planned.schedule = "dynamic".into();
        planned.granularity = "hybrid".into();
        planned.support = "full".into();
        planned.device = "gpu".into();
        let records =
            vec![planned, TraceRecord::unplanned("kmax".into(), 50, 80, 700, 0.5)];
        save(&path, &records).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back, records);
        assert!(back[0].has_provenance());
        assert!(!back[1].has_provenance());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn load_accepts_legacy_five_field_rows() {
        let path = tmp("ktruss-persist-legacy.tsv");
        std::fs::write(&path, "# old header\nktruss\t10\t20\t30\t0.5\n").unwrap();
        let recs = load(&path).unwrap();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0], TraceRecord::unplanned("ktruss".into(), 10, 20, 30, 0.5));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn load_skips_comments_and_rejects_garbage() {
        let path = tmp("ktruss-persist-garbage.tsv");
        std::fs::write(&path, "# header\n\nktruss\t10\t20\t30\t0.5\tdynamic\tfine\tfull\n")
            .unwrap();
        let recs = load(&path).unwrap();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].est_steps, 30);
        assert_eq!(recs[0].granularity, "fine");
        assert_eq!(recs[0].device, NO_PROVENANCE, "pre-device rows default the device axis");

        std::fs::write(&path, "ktruss\t10\t20\t30\t0.5\tdynamic\tfine\tfull\tgpu\n").unwrap();
        let recs = load(&path).unwrap();
        assert_eq!(recs[0].device, "gpu");

        std::fs::write(&path, "ktruss\t10\t20\n").unwrap();
        assert!(load(&path).is_err());
        std::fs::write(&path, "ktruss\t10\t20\t30\t0.5\tdynamic\tfine\n").unwrap();
        assert!(load(&path).is_err());
        std::fs::write(&path, "ktruss\tx\t20\t30\t0.5\n").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn load_missing_file_is_an_error() {
        assert!(load(&tmp("ktruss-persist-definitely-missing.tsv")).is_err());
    }
}
