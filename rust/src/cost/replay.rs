//! Replay the K-truss convergence loop while exposing per-iteration
//! cost traces to an observer. One replay serves every simulated device
//! and granularity at once, because all of them execute the same kernel
//! over the same per-iteration task set — only the schedule differs.
//!
//! Two replay drivers: [`replay_ktruss`] traces the classic
//! full-recompute loop, [`replay_ktruss_mode`] traces the
//! support-maintenance driver of [`crate::algo::incremental`] — its
//! observer receives a [`PassObservation`] per iteration, either a full
//! pass trace or the frontier task set (dying edges with exact per-task
//! steps), mirroring the real drivers' per-round crossover decisions.

use super::trace::SupportTrace;
use crate::algo::incremental::{self, InNbrs, SupportMode};
use crate::algo::prune::prune;
use crate::graph::{Csr, ZCsr};

/// What the observer sees each iteration (before the next prune has
/// destroyed the state).
pub struct IterObservation<'a> {
    /// 0-based iteration number within the current convergence loop.
    pub iter: usize,
    /// Live edges when the support pass ran.
    pub live_edges: usize,
    /// The support pass cost trace.
    pub trace: &'a SupportTrace,
    /// Row layout at the time of the pass (terminator slots included).
    pub row_ptr: &'a [u32],
    /// Column array at the time of the pass (0 = terminator slot) —
    /// what the hybrid pricing split reads to decide which partner rows
    /// are bitmap-encoded ([`crate::par::balance::hybrid_trace_pieces`]).
    pub col: &'a [u32],
    /// Slots in the working array.
    pub slots: usize,
    /// Vertices.
    pub n: usize,
    /// Edges removed by the prune that followed the pass.
    pub removed: usize,
}

/// Replay the k-truss loop on `g`, invoking `obs` once per iteration.
/// Returns (iterations, surviving edges).
pub fn replay_ktruss(
    g: &Csr,
    k: u32,
    mut obs: impl FnMut(&IterObservation),
) -> (usize, usize) {
    let mut z = ZCsr::from_csr(g);
    let mut s: Vec<u32> = Vec::new();
    replay_loop(&mut z, &mut s, k, 0, &mut obs)
}

/// Replay the incremental K_max peeling (paper's K=K_max setting: the
/// *total* time to discover K_max is what the experiment measures).
/// Returns (kmax, total iterations).
pub fn replay_kmax(g: &Csr, mut obs: impl FnMut(u32, &IterObservation)) -> (u32, usize) {
    if g.nnz() == 0 {
        return (0, 0);
    }
    let mut z = ZCsr::from_csr(g);
    let mut s: Vec<u32> = Vec::new();
    let mut kmax = 2u32;
    let mut total_iters = 0usize;
    let mut k = 3u32;
    loop {
        let (iters, remaining) =
            replay_loop(&mut z, &mut s, k, 0, &mut |o: &IterObservation| obs(k, o));
        total_iters += iters;
        if remaining == 0 {
            break;
        }
        kmax = k;
        k += 1;
    }
    (kmax, total_iters)
}

/// What the observer of [`replay_ktruss_mode`] sees each iteration: the
/// pass that produced the iteration's supports was either a full
/// recompute or an incremental frontier update.
pub enum PassObservation<'a> {
    /// A full support pass ran; same payload as [`replay_ktruss`].
    Full(IterObservation<'a>),
    /// The incremental frontier update ran.
    Frontier(FrontierIterObservation<'a>),
}

/// Frontier-pass payload of [`PassObservation::Frontier`].
pub struct FrontierIterObservation<'a> {
    /// 0-based iteration number within the current convergence loop.
    pub iter: usize,
    /// Live edges when the frontier was marked.
    pub live_edges: usize,
    /// Exact steps of each frontier task (one dying edge each).
    pub task_steps: &'a [u32],
    /// Row of each frontier task's dying edge (ascending — feeds the
    /// granularity grouping of [`crate::par::balance::Costs::from_frontier`]).
    pub task_rows: &'a [u32],
    /// Σ `task_steps`.
    pub total_steps: u64,
    /// Slots in the working array.
    pub slots: usize,
    /// Vertices.
    pub n: usize,
    /// Edges removed by the compaction that followed the update.
    pub removed: usize,
}

/// Replay the support-maintenance driver
/// ([`crate::algo::ktruss::run_to_convergence_mode`], cold) on `g`,
/// invoking `obs` once per iteration with the pass that produced that
/// iteration's supports. Makes the same per-round full-vs-frontier
/// decisions as the real driver **at the default crossover fraction**
/// ([`incremental::DEFAULT_CROSSOVER_FRAC`] — what every plan runs
/// unless its `crossover` field was overridden programmatically), so
/// the simulators price exactly the kernel launches production would
/// issue. Returns (iterations, surviving edges).
pub fn replay_ktruss_mode(
    g: &Csr,
    k: u32,
    support: SupportMode,
    mut obs: impl FnMut(&PassObservation),
) -> (usize, usize) {
    let mut z = ZCsr::from_csr(g);
    let mut s: Vec<u32> = Vec::new();
    let mut iters = 0usize;
    if z.live_edges() == 0 {
        return (0, 0);
    }
    let use_inc = support.allows_incremental();
    let in_nbrs: Option<InNbrs> = if use_inc { Some(InNbrs::build(&z)) } else { None };
    let mut trace = SupportTrace {
        fine_steps: Vec::new(),
        live_per_row: Vec::new(),
        total_steps: 0,
    };
    // the pass that produced the current supports: full trace, or the
    // frontier task steps/rows
    super::trace::trace_supports_into(&z, &mut s, &mut trace);
    let mut pass_full = true;
    let mut frontier_steps: Vec<u32> = Vec::new();
    let mut frontier_rows: Vec<u32> = Vec::new();
    let mut last_full_steps = trace.total_steps;
    // live-edge counter maintained from the prune/compaction outcomes
    // (one initial O(slots) scan, no per-round rescan)
    let mut live = z.live_edges();
    loop {
        if live == 0 {
            break;
        }
        let f = incremental::mark_frontier(&z, &s, k);
        let removed = f.len();
        if pass_full {
            obs(&PassObservation::Full(IterObservation {
                iter: iters,
                live_edges: live,
                trace: &trace,
                row_ptr: z.row_ptr(),
                col: z.col(),
                slots: z.slots(),
                n: z.n(),
                removed,
            }));
        } else {
            obs(&PassObservation::Frontier(FrontierIterObservation {
                iter: iters,
                live_edges: live,
                task_steps: &frontier_steps,
                task_rows: &frontier_rows,
                total_steps: frontier_steps.iter().map(|&x| x as u64).sum(),
                slots: z.slots(),
                n: z.n(),
                removed,
            }));
        }
        iters += 1;
        if f.is_empty() {
            break;
        }
        let (go_incremental, _) = incremental::decide_incremental(
            &z,
            &f,
            in_nbrs.as_ref(),
            support,
            last_full_steps,
            incremental::DEFAULT_CROSSOVER_FRAC,
            false,
        );
        if go_incremental {
            let nbrs = in_nbrs.as_ref().expect("incremental mode builds the index");
            let (_, per_task) = incremental::decrement_frontier_traced(&z, &mut s, &f, nbrs);
            frontier_steps = per_task;
            frontier_rows = f.tasks.iter().map(|t| t.row).collect();
            pass_full = false;
            live = incremental::compact_preserving(&mut z, &mut s, &f.dying).remaining;
        } else {
            live = prune(&mut z, &mut s, k).remaining;
            if live == 0 {
                break;
            }
            super::trace::trace_supports_into(&z, &mut s, &mut trace);
            pass_full = true;
            last_full_steps = trace.total_steps;
        }
    }
    (iters, live)
}

fn replay_loop(
    z: &mut ZCsr,
    s: &mut Vec<u32>,
    k: u32,
    iter_base: usize,
    obs: &mut impl FnMut(&IterObservation),
) -> (usize, usize) {
    let mut iters = 0usize;
    // §Perf: reuse the trace buffers across iterations — the row layout
    // (row_ptr) is immutable under prune-compaction, so it needs no
    // per-iteration snapshot either.
    let mut trace = super::trace::SupportTrace {
        fine_steps: Vec::new(),
        live_per_row: Vec::new(),
        total_steps: 0,
    };
    // the observer fires after the prune has compacted the columns, so
    // the pass-time column array is snapshotted into a reused buffer
    let mut col_snap: Vec<u32> = Vec::new();
    // live-edge counter maintained from the prune outcomes (one initial
    // O(slots) scan per convergence loop, no per-round rescan)
    let mut live = z.live_edges();
    loop {
        if live == 0 {
            break;
        }
        super::trace::trace_supports_into(z, s, &mut trace);
        col_snap.clear();
        col_snap.extend_from_slice(z.col());
        let out = prune(z, s, k);
        obs(&IterObservation {
            iter: iter_base + iters,
            live_edges: live,
            trace: &trace,
            row_ptr: z.row_ptr(),
            col: &col_snap,
            slots: trace.fine_steps.len(),
            n: z.n(),
            removed: out.removed,
        });
        iters += 1;
        live = out.remaining;
        if out.removed == 0 {
            break;
        }
    }
    (iters, live)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::ktruss::{ktruss, Mode};
    use crate::graph::builder::from_sorted_unique;

    #[test]
    fn replay_iterations_match_driver() {
        let g = crate::gen::community::communities(200, 1000, 15, &mut crate::util::Rng::new(8));
        let direct = ktruss(&g, 4, Mode::Fine);
        let mut seen = 0usize;
        let (iters, remaining) = replay_ktruss(&g, 4, |o| {
            assert_eq!(o.iter, seen);
            seen += 1;
            assert!(o.live_edges > 0);
        });
        assert_eq!(iters, direct.iterations);
        assert_eq!(remaining, direct.truss.nnz());
        assert_eq!(seen, iters);
    }

    #[test]
    fn replay_exposes_shrinking_work() {
        // triangle + long tail: tail edges die over multiple iterations
        let g = from_sorted_unique(
            7,
            &[(0, 1), (0, 2), (1, 2), (2, 3), (3, 4), (4, 5), (5, 6)],
        );
        let mut lives = Vec::new();
        replay_ktruss(&g, 3, |o| lives.push(o.live_edges));
        assert!(lives.len() >= 2);
        for w in lives.windows(2) {
            assert!(w[1] < w[0], "live edges must shrink: {lives:?}");
        }
    }

    #[test]
    fn replay_kmax_matches_kmax_module() {
        let g = crate::gen::community::communities(150, 800, 15, &mut crate::util::Rng::new(9));
        let want = crate::algo::kmax::kmax(&g);
        let mut iters_seen = 0usize;
        let (kmax, total) = replay_kmax(&g, |_, _| iters_seen += 1);
        assert_eq!(kmax, want.kmax);
        assert_eq!(total, want.total_iterations);
        assert_eq!(iters_seen, total);
    }

    #[test]
    fn replay_mode_matches_driver_stats() {
        use crate::algo::incremental::SupportMode;
        use crate::algo::ktruss::ktruss_mode;
        let g = crate::gen::rmat::rmat(
            300,
            2200,
            crate::gen::rmat::RmatParams::autonomous_system(),
            &mut crate::util::Rng::new(44),
        );
        for support in [SupportMode::Full, SupportMode::Incremental, SupportMode::Auto] {
            for k in [4u32, 5] {
                let r = ktruss_mode(&g, k, Mode::Fine, support);
                let mut steps: Vec<u64> = Vec::new();
                let mut kinds: Vec<bool> = Vec::new();
                let (iters, remaining) = replay_ktruss_mode(&g, k, support, |o| match o {
                    PassObservation::Full(f) => {
                        steps.push(f.trace.total_steps);
                        kinds.push(false);
                    }
                    PassObservation::Frontier(f) => {
                        steps.push(f.total_steps);
                        kinds.push(true);
                        assert_eq!(f.task_steps.len(), f.task_rows.len());
                    }
                });
                assert_eq!(iters, r.iterations, "{support} k={k}");
                assert_eq!(remaining, r.truss.nnz(), "{support} k={k}");
                let want_steps: Vec<u64> =
                    r.stats.iter().map(|s| s.support_steps).collect();
                let want_kinds: Vec<bool> = r.stats.iter().map(|s| s.incremental).collect();
                assert_eq!(steps, want_steps, "{support} k={k}");
                assert_eq!(kinds, want_kinds, "{support} k={k}");
            }
        }
    }

    #[test]
    fn observation_layout_is_consistent() {
        let g = from_sorted_unique(4, &[(0, 1), (0, 2), (0, 3), (1, 2), (2, 3)]);
        replay_ktruss(&g, 3, |o| {
            assert_eq!(o.row_ptr.len(), o.n + 1);
            assert_eq!(*o.row_ptr.last().unwrap() as usize, o.slots);
            assert_eq!(o.trace.fine_steps.len(), o.slots);
            assert_eq!(o.col.len(), o.slots);
        });
    }
}
