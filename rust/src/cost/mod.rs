//! Task-cost measurement: exact per-task work traces of the support
//! kernel, a replay driver that exposes them iteration by iteration,
//! and persistence for measured job traces (the serving cost model's
//! calibration feedback). These feed the device timing models in
//! [`crate::sim`] and the batch scheduler in [`crate::serve`].

pub mod persist;
pub mod replay;
pub mod trace;

pub use persist::TraceRecord;
pub use replay::{
    replay_kmax, replay_ktruss, replay_ktruss_mode, FrontierIterObservation, IterObservation,
    PassObservation,
};
pub use trace::{trace_supports, SupportTrace};
