//! Task-cost measurement: exact per-task work traces of the support
//! kernel and a replay driver that exposes them iteration by iteration.
//! These feed the device timing models in [`crate::sim`].

pub mod replay;
pub mod trace;

pub use replay::{replay_kmax, replay_ktruss, IterObservation};
pub use trace::{trace_supports, SupportTrace};
