//! PJRT client bridge — **stubbed** in the offline build.
//!
//! The original implementation wrapped the `xla` FFI crate (PJRT CPU
//! client, HLO-text compilation, literal transfer). That crate links
//! against `libxla_extension`, which this build environment does not
//! ship, so the bridge is replaced by an API-compatible stub that
//! reports the runtime as unavailable. Every consumer already treats
//! dense-path failure as a soft condition:
//!
//! * the coordinator's [`crate::coordinator::worker::Worker`] falls
//!   back to the sparse pool when a dense execution errors,
//! * `ktruss info` prints the unavailability reason,
//! * the dense integration tests probe one execution and skip when the
//!   runtime cannot actually run artifacts.
//!
//! Restoring the real bridge is a drop-in: reintroduce the `xla`
//! dependency and replace the bodies below (the shapes of
//! [`Runtime::load_hlo_text`] and [`Executable::run_f32`] match what
//! the dense engine needs).
//!
//! Until then, GPU-device plans are not stranded: the lockstep-lane
//! backend ([`crate::exec::lane`]) executes `PlanDevice::Gpu` plans
//! in-process — warps as lockstep lanes with divergence masking,
//! merge-path intra-warp assignment, persistent-block stealing — so
//! `run --device gpu` exercises the GPU execution shape (and the
//! model-vs-executed calibration loop) without `libxla_extension`.
//! A revived PJRT bridge would slot in as a second executing device
//! behind the same plan dispatch.

use anyhow::{bail, Result};
use std::path::Path;

/// Why every entry point of the stub fails.
const UNAVAILABLE: &str =
    "PJRT runtime unavailable: built without the xla bridge (offline crate set)";

/// Process-wide PJRT client handle (stub: cannot be constructed).
pub struct Runtime {
    _private: (),
}

impl Runtime {
    /// Get (or create) the process-wide runtime. Always errors in the
    /// stubbed build.
    pub fn global() -> Result<&'static Runtime> {
        bail!("{UNAVAILABLE}")
    }

    /// Backend platform name.
    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    /// Devices the backend exposes (always 0 in the stubbed build).
    pub fn device_count(&self) -> usize {
        0
    }

    /// Load an HLO-text artifact and compile it to an executable.
    /// Always errors in the stubbed build.
    pub fn load_hlo_text(&'static self, path: impl AsRef<Path>) -> Result<Executable> {
        bail!("{UNAVAILABLE} (cannot compile {})", path.as_ref().display())
    }
}

/// A dense f32 tensor handed to an executable (row-major data + dims).
pub struct Tensor {
    /// Row-major element data.
    pub data: Vec<f32>,
    /// Dimension sizes, outermost first.
    pub dims: Vec<usize>,
}

impl Tensor {
    /// An `n × n` row-major matrix.
    pub fn matrix(data: Vec<f32>, n: usize) -> Tensor {
        debug_assert_eq!(data.len(), n * n);
        Tensor { data, dims: vec![n, n] }
    }

    /// A scalar.
    pub fn scalar(x: f32) -> Tensor {
        Tensor { data: vec![x], dims: Vec::new() }
    }
}

/// A compiled artifact bound to the global runtime (stub: unreachable,
/// since [`Runtime::load_hlo_text`] never succeeds).
pub struct Executable {
    _private: (),
}

impl Executable {
    /// Execute with tensor inputs; returns the flattened f32 output
    /// tuple elements. Always errors in the stubbed build.
    pub fn run_f32(&self, _args: &[Tensor]) -> Result<Vec<Vec<f32>>> {
        bail!("{UNAVAILABLE}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn global_runtime_reports_unavailable() {
        let err = Runtime::global().err().expect("stub must error");
        assert!(err.to_string().contains("unavailable"), "{err:#}");
    }

    #[test]
    fn tensor_constructors_shape() {
        let m = Tensor::matrix(vec![0.0; 9], 3);
        assert_eq!(m.dims, vec![3, 3]);
        let s = Tensor::scalar(2.5);
        assert_eq!(s.data, vec![2.5]);
        assert!(s.dims.is_empty());
    }
}
