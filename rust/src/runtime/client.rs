//! PJRT client wrapper: load AOT-compiled HLO text artifacts and execute
//! them from the rust request path. Adapted from the working pattern in
//! /opt/xla-example/load_hlo (see README there for the interchange
//! gotchas — HLO *text*, not serialized protos).

use anyhow::{Context, Result};
use once_cell::sync::OnceCell;
use std::path::Path;
use std::sync::Mutex;

/// Process-wide PJRT CPU client. PJRT clients are expensive to create
/// and internally thread-safe; executions are serialized with a mutex
/// because the 0.1.6 crate does not declare `PjRtLoadedExecutable` Sync.
pub struct Runtime {
    client: xla::PjRtClient,
    exec_lock: Mutex<()>,
}

static RUNTIME: OnceCell<Runtime> = OnceCell::new();

// SAFETY: the underlying PJRT CPU client is thread-safe; all mutation
// through the wrapper goes through `exec_lock`.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Runtime {
    /// Get (or create) the process-wide runtime.
    pub fn global() -> Result<&'static Runtime> {
        RUNTIME.get_or_try_init(|| {
            let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
            Ok(Runtime { client, exec_lock: Mutex::new(()) })
        })
    }

    /// Backend platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load an HLO-text artifact and compile it to an executable.
    /// (`&'static self` because `Runtime::global()` is the only way to
    /// obtain a runtime and executables outlive call sites.)
    pub fn load_hlo_text(&'static self, path: impl AsRef<Path>) -> Result<Executable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {}", path.display()))?;
        Ok(Executable { exe, runtime: self })
    }
}

/// A compiled artifact bound to the global runtime.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    runtime: &'static Runtime,
}

// SAFETY: executions are serialized through the runtime's exec_lock.
unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

impl Executable {
    /// Execute with literal inputs; returns the output tuple elements.
    /// (aot.py lowers with `return_tuple=True`, so the single output is
    /// always a tuple — possibly a 1-tuple.)
    pub fn run(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let _guard = self.runtime.exec_lock.lock().unwrap();
        let result = self.exe.execute::<xla::Literal>(args)?[0][0]
            .to_literal_sync()
            .context("fetch result literal")?;
        result.to_tuple().context("decompose output tuple")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn global_runtime_initializes() {
        let rt = Runtime::global().expect("runtime");
        assert_eq!(rt.platform().to_lowercase(), "cpu");
        assert!(rt.device_count() >= 1);
    }
}
