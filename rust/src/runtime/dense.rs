//! The dense linear-algebraic K-truss path: execute the AOT-compiled
//! L2/L1 artifacts (jax + Pallas, lowered at build time) from rust.
//!
//! This is (a) the TPU-shaped realization of the paper's fine-grained
//! insight (uniform-cost MXU tiles — see DESIGN.md §Hardware-Adaptation)
//! and (b) an end-to-end independent oracle for the sparse path: same
//! K-truss, computed by a different algorithm in a different language
//! through a different runtime.

use super::artifacts::{artifacts_dir, list_entries, pick_entry};
use super::client::{Executable, Runtime, Tensor};
use crate::graph::{builder, Csr, Vid};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::sync::Mutex;

/// Dense-path engine: caches compiled executables per entry point.
pub struct DenseEngine {
    entries: Vec<super::artifacts::ArtifactEntry>,
    compiled: Mutex<HashMap<String, &'static Executable>>,
}

impl DenseEngine {
    /// Discover artifacts and create an engine.
    pub fn new() -> Result<DenseEngine> {
        let dir = artifacts_dir()?;
        let entries = list_entries(&dir)?;
        if entries.is_empty() {
            bail!("no artifacts in {} — run `make artifacts`", dir.display());
        }
        Ok(DenseEngine { entries, compiled: Mutex::new(HashMap::new()) })
    }

    /// Largest dense block size available.
    pub fn max_n(&self) -> usize {
        self.entries.iter().map(|e| e.n).max().unwrap_or(0)
    }

    fn executable(&self, kind: &str, need: usize) -> Result<&'static Executable> {
        let entry = pick_entry(&self.entries, kind, need)
            .with_context(|| format!("no '{kind}' artifact"))?
            .clone();
        let mut cache = self.compiled.lock().unwrap();
        if let Some(exe) = cache.get(&entry.name) {
            return Ok(exe);
        }
        let exe = Runtime::global()?.load_hlo_text(&entry.path)?;
        // executables live for the process; leak to get a &'static we
        // can hand out without self-referential lifetimes
        let exe: &'static Executable = Box::leak(Box::new(exe));
        cache.insert(entry.name.clone(), exe);
        Ok(exe)
    }

    /// Compute per-edge supports of `g` via the dense AOT path.
    /// Returns supports in row-major live-edge order (matching
    /// `Csr::edges()`), or an error if the graph exceeds every block.
    pub fn supports(&self, g: &Csr) -> Result<Vec<u32>> {
        let n = g.n();
        if n > self.max_n() {
            bail!("graph n={n} exceeds dense block limit {}", self.max_n());
        }
        let exe = self.executable("support", n)?;
        let block = pick_entry(&self.entries, "support", n).unwrap().n;
        let a = to_dense_symmetric(g, block);
        let out = exe.run_f32(&[Tensor::matrix(a, block)])?;
        let s = &out[0];
        Ok(g.edges()
            .map(|(u, v)| s[u as usize * block + v as usize] as u32)
            .collect())
    }

    /// Full dense K-truss: iterate the AOT `ktruss_step` executable
    /// until `removed == 0` (the convergence loop lives here, in rust).
    /// Returns (truss subgraph, iterations).
    pub fn ktruss(&self, g: &Csr, k: u32) -> Result<(Csr, usize)> {
        let n = g.n();
        if n > self.max_n() {
            bail!("graph n={n} exceeds dense block limit {}", self.max_n());
        }
        let exe = self.executable("ktruss_step", n)?;
        let block = pick_entry(&self.entries, "ktruss_step", n).unwrap().n;
        let mut a = to_dense_symmetric(g, block);
        let mut iterations = 0usize;
        loop {
            let threshold = Tensor::scalar(k.saturating_sub(2) as f32);
            let mut out = exe.run_f32(&[Tensor::matrix(a, block), threshold])?;
            let removed: f32 = out[1][0];
            a = out.swap_remove(0);
            iterations += 1;
            if removed == 0.0 {
                break;
            }
            if iterations > 4 * block {
                bail!("dense ktruss failed to converge after {iterations} iterations");
            }
        }
        Ok((from_dense_symmetric(&a, block, n), iterations))
    }
}

/// Pack the upper-triangular CSR into a symmetric dense 0/1 block of
/// size `block × block` (row-major f32, zero-padded).
pub fn to_dense_symmetric(g: &Csr, block: usize) -> Vec<f32> {
    assert!(g.n() <= block);
    let mut a = vec![0.0f32; block * block];
    for (u, v) in g.edges() {
        a[u as usize * block + v as usize] = 1.0;
        a[v as usize * block + u as usize] = 1.0;
    }
    a
}

/// Extract the strictly-upper-triangular edges of a symmetric dense
/// block back into a CSR on `n` vertices.
pub fn from_dense_symmetric(a: &[f32], block: usize, n: usize) -> Csr {
    let mut edges: Vec<(Vid, Vid)> = Vec::new();
    for u in 0..n {
        for v in (u + 1)..n {
            if a[u * block + v] != 0.0 {
                edges.push((u as Vid, v as Vid));
            }
        }
    }
    builder::from_sorted_unique(n, &edges)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::from_sorted_unique;

    #[test]
    fn dense_roundtrip() {
        let g = from_sorted_unique(5, &[(0, 1), (0, 4), (2, 3)]);
        let a = to_dense_symmetric(&g, 8);
        assert_eq!(a[0 * 8 + 1], 1.0);
        assert_eq!(a[1 * 8 + 0], 1.0);
        assert_eq!(from_dense_symmetric(&a, 8, 5), g);
    }

    // Engine tests requiring built artifacts live in
    // rust/tests/integration_runtime.rs so `cargo test --lib` stays
    // independent of `make artifacts`.
}
