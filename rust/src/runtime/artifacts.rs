//! Artifact discovery: find the `artifacts/` directory produced by
//! `make artifacts` and enumerate the exported entry points.

use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// An exported AOT entry point.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArtifactEntry {
    /// Entry name, e.g. `ktruss_step_256`.
    pub name: String,
    /// Entry kind: `support` or `ktruss_step`.
    pub kind: String,
    /// Dense block size n (matrix is n×n).
    pub n: usize,
    /// Absolute path of the `.hlo.txt` file.
    pub path: PathBuf,
}

/// Locate the artifacts directory: `$KTRUSS_ARTIFACTS`, else
/// `./artifacts`, else walking up from the executable (so `cargo test`
/// from any cwd inside the repo finds it).
pub fn artifacts_dir() -> Result<PathBuf> {
    if let Some(dir) = std::env::var_os("KTRUSS_ARTIFACTS") {
        let p = PathBuf::from(dir);
        if p.is_dir() {
            return Ok(p);
        }
        bail!("KTRUSS_ARTIFACTS={} is not a directory", p.display());
    }
    let mut cur = std::env::current_dir().context("cwd")?;
    loop {
        let cand = cur.join("artifacts");
        if cand.join("manifest.json").is_file() {
            return Ok(cand);
        }
        if !cur.pop() {
            bail!(
                "artifacts/ not found (run `make artifacts` or set KTRUSS_ARTIFACTS)"
            );
        }
    }
}

/// Enumerate `<kind>_<n>.hlo.txt` entries in a directory.
pub fn list_entries(dir: &Path) -> Result<Vec<ArtifactEntry>> {
    let mut out = Vec::new();
    for entry in std::fs::read_dir(dir).with_context(|| format!("read {}", dir.display()))? {
        let path = entry?.path();
        let Some(fname) = path.file_name().and_then(|s| s.to_str()) else { continue };
        let Some(stem) = fname.strip_suffix(".hlo.txt") else { continue };
        // name pattern: {kind}_{n}
        let Some((kind, n_str)) = stem.rsplit_once('_') else { continue };
        let Ok(n) = n_str.parse::<usize>() else { continue };
        out.push(ArtifactEntry {
            name: stem.to_string(),
            kind: kind.to_string(),
            n,
            path: path.clone(),
        });
    }
    out.sort_by(|a, b| a.name.cmp(&b.name));
    Ok(out)
}

/// Find the smallest exported block size ≥ `need` for `kind`, falling
/// back to the largest available.
pub fn pick_entry<'a>(
    entries: &'a [ArtifactEntry],
    kind: &str,
    need: usize,
) -> Option<&'a ArtifactEntry> {
    let mut of_kind: Vec<&ArtifactEntry> = entries.iter().filter(|e| e.kind == kind).collect();
    of_kind.sort_by_key(|e| e.n);
    of_kind
        .iter()
        .find(|e| e.n >= need)
        .copied()
        .or_else(|| of_kind.last().copied())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_entry_names() {
        let dir = std::env::temp_dir().join(format!("ktruss-art-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("support_128.hlo.txt"), "x").unwrap();
        std::fs::write(dir.join("ktruss_step_256.hlo.txt"), "x").unwrap();
        std::fs::write(dir.join("manifest.json"), "{}").unwrap();
        std::fs::write(dir.join("README"), "x").unwrap();
        let entries = list_entries(&dir).unwrap();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].kind, "ktruss_step");
        assert_eq!(entries[0].n, 256);
        assert_eq!(entries[1].kind, "support");
        assert_eq!(entries[1].n, 128);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn pick_prefers_smallest_sufficient() {
        let mk = |kind: &str, n: usize| ArtifactEntry {
            name: format!("{kind}_{n}"),
            kind: kind.into(),
            n,
            path: PathBuf::new(),
        };
        let entries = vec![mk("support", 128), mk("support", 256)];
        assert_eq!(pick_entry(&entries, "support", 100).unwrap().n, 128);
        assert_eq!(pick_entry(&entries, "support", 129).unwrap().n, 256);
        // too big: falls back to largest
        assert_eq!(pick_entry(&entries, "support", 1000).unwrap().n, 256);
        assert!(pick_entry(&entries, "nope", 1).is_none());
    }

    #[test]
    fn artifacts_dir_resolves_in_repo() {
        // the repo has artifacts/ built by `make artifacts`
        if let Ok(dir) = artifacts_dir() {
            assert!(dir.join("manifest.json").is_file());
        }
    }
}
