//! PJRT runtime: loads the HLO-text artifacts that `make artifacts`
//! produced from the jax/Pallas layers and executes them on the CPU
//! PJRT client — python is never on this path.
//!
//! In the offline build the PJRT client itself is a stub (see
//! [`client`]); the dense engine then degrades gracefully to the
//! sparse path everywhere it is consumed.

pub mod artifacts;
pub mod client;
pub mod dense;

pub use client::{Executable, Runtime};
pub use dense::DenseEngine;
