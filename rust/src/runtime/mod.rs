//! PJRT runtime: loads the HLO-text artifacts that `make artifacts`
//! produced from the jax/Pallas layers and executes them on the CPU
//! PJRT client — python is never on this path.

pub mod artifacts;
pub mod client;
pub mod dense;

pub use client::{Executable, Runtime};
pub use dense::DenseEngine;
