//! # ktruss — fine-grained parallel Eager K-truss
//!
//! A production-shaped reproduction of *"Exploration of Fine-Grained
//! Parallelism for Load Balancing Eager K-truss on GPU and CPU"*
//! (Blanco, Low, Kim — IEEE HPEC 2019), built as a three-layer
//! rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordinator: graph substrate, the coarse-
//!   and fine-grained Eager K-truss kernels, a Kokkos-style parallel
//!   policy layer, calibrated CPU/GPU timing simulators (the paper's
//!   48-thread Skylake and V100 testbeds are simulated; see DESIGN.md
//!   §2), a PJRT runtime for the AOT-compiled dense path, and a serving
//!   coordinator that batches and routes K-truss jobs.
//! * **L2 (python/compile/model.py)** — the dense blocked linear-
//!   algebraic formulation `S = (AᵀA) ∘ A` in JAX, AOT-lowered to HLO
//!   text at build time.
//! * **L1 (python/compile/kernels/)** — the Pallas tile kernel for the
//!   support computation, validated against a pure-jnp oracle.
//!
//! Python never runs on the request path: `make artifacts` lowers the
//! model once and the rust binary executes the HLO via PJRT.
//!
//! Quickstart (compile-checked; `no_run` because doctest binaries do
//! not inherit the rpath to libxla_extension's bundled libstdc++):
//!
//! ```no_run
//! use ktruss::graph::builder::from_sorted_unique;
//! use ktruss::algo::ktruss::{ktruss, Mode};
//!
//! // diamond: triangles {0,1,2} and {0,2,3}
//! let g = from_sorted_unique(4, &[(0,1),(0,2),(0,3),(1,2),(2,3)]);
//! let res = ktruss(&g, 3, Mode::Fine);
//! assert_eq!(res.truss.nnz(), 5); // every edge is in a triangle
//! ```

#![warn(missing_docs)]

pub mod algo;
pub mod bench_harness;
pub mod cli;
pub mod coordinator;
pub mod cost;
pub mod exec;
pub mod gen;
pub mod graph;
pub mod obs;
pub mod par;
pub mod plan;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod testkit;
pub mod util;
