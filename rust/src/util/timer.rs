//! Wall-clock timing helpers for the real (non-simulated) measurement
//! paths: single-thread calibration runs and the §Perf micro-benchmarks.

use std::time::{Duration, Instant};

/// A simple scoped stopwatch.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start the stopwatch now.
    pub fn start() -> Timer {
        Timer { start: Instant::now() }
    }

    /// Time since start.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Time since start, in milliseconds.
    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e3
    }

    /// Read the elapsed time and restart the stopwatch.
    pub fn restart(&mut self) -> Duration {
        let e = self.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Run `f` once and return (result, elapsed ms).
pub fn time_ms<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.elapsed_ms())
}

/// Repeat `f` `trials` times (after `warmup` unmeasured runs) and return
/// the per-trial milliseconds. The paper reports the mean of 10 trials.
pub fn bench_ms<T>(warmup: usize, trials: usize, mut f: impl FnMut() -> T) -> Vec<f64> {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    (0..trials)
        .map(|_| {
            let t = Timer::start();
            std::hint::black_box(f());
            t.elapsed_ms()
        })
        .collect()
}

/// Millions of edges processed per second — the paper's metric
/// (edges = nnz of the upper-triangular matrix; time in milliseconds).
pub fn me_per_s(edges: usize, time_ms: f64) -> f64 {
    if time_ms <= 0.0 {
        return f64::INFINITY;
    }
    edges as f64 / 1.0e6 / (time_ms / 1.0e3)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_advances() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(t.elapsed_ms() >= 1.0);
    }

    #[test]
    fn time_ms_returns_value() {
        let (v, ms) = time_ms(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(ms >= 0.0);
    }

    #[test]
    fn bench_collects_trials() {
        let xs = bench_ms(1, 5, || 1 + 1);
        assert_eq!(xs.len(), 5);
    }

    #[test]
    fn me_per_s_known() {
        // 1M edges in 1000 ms = 1 ME/s
        assert!((me_per_s(1_000_000, 1000.0) - 1.0).abs() < 1e-12);
        // paper row: ca-GrQc 14.5k edges, 1.051ms -> 13.8 ME/s
        let v = me_per_s(14_484, 1.051);
        assert!((v - 13.78).abs() < 0.1, "{v}");
    }
}
