//! Human-readable formatting helpers for CLI/bench reports: counts with
//! k/M suffixes (matching the paper's "Vertices (k)" column style),
//! fixed-width tables, and simple markdown emission.

/// Format a count the way Table I does: `5.2k`, `3774.8k`, plain below 1000.
pub fn count_k(n: usize) -> String {
    if n < 1000 {
        format!("{n}")
    } else {
        format!("{:.1}k", n as f64 / 1000.0)
    }
}

/// Format milliseconds with 3 decimal places (Table I style).
pub fn ms(x: f64) -> String {
    format!("{x:.3}")
}

/// Format ME/s with 3 decimal places (Table I style).
pub fn mes(x: f64) -> String {
    format!("{x:.3}")
}

/// Format a speedup with 2 decimals and an `x` suffix.
pub fn speedup(x: f64) -> String {
    format!("{x:.2}x")
}

/// A minimal fixed-column text table builder for bench reports.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// A table with the given column headers and no rows.
    pub fn new<S: Into<String>>(header: Vec<S>) -> Table {
        Table { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    /// Append one row (must match the header width).
    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    /// Rows appended so far.
    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Render as an aligned plain-text table.
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        let fmt_row = |cells: &[String], w: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>width$}", c, width = w[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header, &w));
        out.push('\n');
        out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * (w.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &w));
            out.push('\n');
        }
        out
    }

    /// Render as GitHub-flavoured markdown (for EXPERIMENTS.md).
    pub fn render_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("| {} |\n", self.header.join(" | ")));
        out.push_str(&format!(
            "|{}\n",
            self.header.iter().map(|_| "---|").collect::<String>()
        ));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        out
    }

    /// Render as CSV.
    pub fn render_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.header.join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn count_k_style() {
        assert_eq!(count_k(999), "999");
        assert_eq!(count_k(5242), "5.2k");
        assert_eq!(count_k(3_774_768), "3774.8k");
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(vec!["graph", "ms"]);
        t.row(vec!["ca-GrQc", "1.051"]);
        t.row(vec!["p2p-Gnutella08", "0.230"]);
        let s = t.render();
        assert!(s.contains("ca-GrQc"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    fn markdown_and_csv() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["1", "2"]);
        assert_eq!(t.render_csv(), "a,b\n1,2\n");
        assert!(t.render_markdown().starts_with("| a | b |\n|---|---|\n"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_checked() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["1"]);
    }
}
