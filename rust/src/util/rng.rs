//! Deterministic pseudo-random number generation.
//!
//! The offline build has no `rand` crate, so we ship a small, well-known
//! generator family: SplitMix64 for seeding and xoshiro256** for the
//! stream. Determinism matters here — every synthetic SNAP-replica graph
//! and every simulator run must be reproducible from a seed recorded in
//! EXPERIMENTS.md.

/// SplitMix64: used to expand a single `u64` seed into generator state.
/// Passes BigCrush when used as a stream; here it only seeds xoshiro.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** — fast, high-quality 64-bit PRNG (Blackman & Vigna).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        // All-zero state is the one invalid state; splitmix of any seed
        // cannot produce four zeros, but guard anyway.
        if s == [0, 0, 0, 0] {
            Rng { s: [1, 2, 3, 4] }
        } else {
            Rng { s }
        }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform `u32`.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform float in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)` via Lemire's multiply-shift
    /// (slight modulo bias is acceptable for graph generation; bound ≪ 2^64).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Sample from a geometric-ish heavy tail: returns a value in
    /// `[0, n)` with Zipf-like skew `alpha` (used by preferential
    /// attachment approximations). alpha=0 is uniform.
    pub fn zipf_index(&mut self, n: usize, alpha: f64) -> usize {
        if n <= 1 {
            return 0;
        }
        if alpha <= 0.0 {
            return self.below(n as u64) as usize;
        }
        // Inverse-CDF of a truncated Pareto over [1, n+1).
        let u = self.next_f64();
        let one_minus = 1.0 - alpha;
        let idx = if (one_minus).abs() < 1e-9 {
            // alpha == 1: logarithmic
            ((n as f64).powf(u) - 1.0).floor()
        } else {
            let max_cdf = ((n + 1) as f64).powf(one_minus) - 1.0;
            ((1.0 + u * max_cdf).powf(1.0 / one_minus) - 1.0).floor()
        };
        (idx as usize).min(n - 1)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Split off an independent child generator (seeded from this stream).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 3);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(9);
        for bound in [1u64, 2, 3, 10, 1000, u32::MAX as u64] {
            for _ in 0..200 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn below_is_roughly_uniform() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn zipf_is_skewed_toward_zero() {
        let mut r = Rng::new(13);
        let mut low = 0usize;
        const N: usize = 1000;
        for _ in 0..10_000 {
            if r.zipf_index(N, 1.2) < N / 10 {
                low += 1;
            }
        }
        // Heavily skewed: far more than the uniform 10% in the first decile.
        assert!(low > 4_000, "low-decile mass {low}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn split_streams_are_independent() {
        let mut parent = Rng::new(23);
        let mut c1 = parent.split();
        let mut c2 = parent.split();
        let same = (0..100).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 3);
    }
}
