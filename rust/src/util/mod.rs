//! Small shared utilities: deterministic RNG, statistics, timers,
//! bitsets, and report formatting. Everything here is dependency-free.

pub mod bitset;
pub mod fmt;
pub mod rng;
pub mod stats;
pub mod timer;

pub use bitset::BitSet;
pub use rng::Rng;
pub use timer::Timer;
