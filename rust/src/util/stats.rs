//! Small statistics helpers used by the bench harness and simulators:
//! geometric mean (the paper reports geomean speedups), percentiles,
//! histogram summaries of task-size distributions.

/// Geometric mean of strictly positive samples. Returns `None` when the
/// input is empty or contains a non-positive value.
pub fn geomean(xs: &[f64]) -> Option<f64> {
    if xs.is_empty() {
        return None;
    }
    let mut acc = 0.0f64;
    for &x in xs {
        if x <= 0.0 || !x.is_finite() {
            return None;
        }
        acc += x.ln();
    }
    Some((acc / xs.len() as f64).exp())
}

/// Arithmetic mean; `None` on empty input.
pub fn mean(xs: &[f64]) -> Option<f64> {
    if xs.is_empty() {
        None
    } else {
        Some(xs.iter().sum::<f64>() / xs.len() as f64)
    }
}

/// Population standard deviation; `None` on empty input.
pub fn stddev(xs: &[f64]) -> Option<f64> {
    let m = mean(xs)?;
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64;
    Some(var.sqrt())
}

/// p-th percentile (0..=100) by linear interpolation on sorted data.
pub fn percentile(xs: &[f64], p: f64) -> Option<f64> {
    if xs.is_empty() || !(0.0..=100.0).contains(&p) {
        return None;
    }
    let mut sorted: Vec<f64> = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        Some(sorted[lo])
    } else {
        let frac = rank - lo as f64;
        Some(sorted[lo] * (1.0 - frac) + sorted[hi] * frac)
    }
}

/// Coefficient of variation (stddev / mean) — the imbalance proxy used in
/// task-distribution reports.
pub fn cv(xs: &[f64]) -> Option<f64> {
    let m = mean(xs)?;
    if m == 0.0 {
        return None;
    }
    Some(stddev(xs)? / m)
}

/// Summary of a sample of task sizes / timings.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    /// Sample size.
    pub n: usize,
    /// Smallest sample.
    pub min: f64,
    /// Largest sample.
    pub max: f64,
    /// Arithmetic mean.
    pub mean: f64,
    /// Population standard deviation.
    pub stddev: f64,
    /// Median.
    pub p50: f64,
    /// 95th percentile.
    pub p95: f64,
    /// 99th percentile.
    pub p99: f64,
}

impl Summary {
    /// Summarize a sample (`None` when empty).
    pub fn of(xs: &[f64]) -> Option<Summary> {
        if xs.is_empty() {
            return None;
        }
        Some(Summary {
            n: xs.len(),
            min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
            max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
            mean: mean(xs)?,
            stddev: stddev(xs)?,
            p50: percentile(xs, 50.0)?,
            p95: percentile(xs, 95.0)?,
            p99: percentile(xs, 99.0)?,
        })
    }

    /// max/mean — the classic load-imbalance factor over per-worker loads.
    pub fn imbalance(&self) -> f64 {
        if self.mean == 0.0 {
            1.0
        } else {
            self.max / self.mean
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_basic() {
        let g = geomean(&[1.0, 4.0]).unwrap();
        assert!((g - 2.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), None);
        assert_eq!(geomean(&[1.0, 0.0]), None);
        assert_eq!(geomean(&[1.0, -2.0]), None);
    }

    #[test]
    fn geomean_matches_paper_style_speedups() {
        // geomean of reciprocal pair is 1.0
        let g = geomean(&[2.0, 0.5]).unwrap();
        assert!((g - 1.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_endpoints() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), Some(1.0));
        assert_eq!(percentile(&xs, 100.0), Some(5.0));
        assert_eq!(percentile(&xs, 50.0), Some(3.0));
        assert_eq!(percentile(&xs, 101.0), None);
        assert_eq!(percentile(&[], 50.0), None);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert_eq!(percentile(&xs, 25.0), Some(2.5));
    }

    #[test]
    fn summary_and_imbalance() {
        let s = Summary::of(&[1.0, 1.0, 1.0, 5.0]).unwrap();
        assert_eq!(s.n, 4);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert!((s.imbalance() - 2.5).abs() < 1e-12);
        assert!(Summary::of(&[]).is_none());
    }

    #[test]
    fn cv_zero_for_constant() {
        assert!(cv(&[3.0, 3.0, 3.0]).unwrap() < 1e-12);
    }

    #[test]
    fn stddev_known_value() {
        // population stddev of [2,4,4,4,5,5,7,9] is 2
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((stddev(&xs).unwrap() - 2.0).abs() < 1e-12);
    }
}
