//! Fixed-size bitset over `u64` words. Used by graph validation, the
//! naive reference algorithm's adjacency tests, and generator dedup.

#[derive(Clone, Debug, PartialEq, Eq)]
/// Fixed-size bitset (bit `i` of `words[i/64]`).
pub struct BitSet {
    words: Vec<u64>,
    len: usize,
}

impl BitSet {
    /// Create a bitset holding `len` bits, all clear.
    pub fn new(len: usize) -> BitSet {
        BitSet { words: vec![0; len.div_ceil(64)], len }
    }

    /// Bits the set holds.
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether the set holds zero bits.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Set bit `i`.
    #[inline]
    pub fn set(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i >> 6] |= 1u64 << (i & 63);
    }

    /// Clear bit `i`.
    #[inline]
    pub fn clear(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i >> 6] &= !(1u64 << (i & 63));
    }

    /// Read bit `i`.
    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i >> 6] >> (i & 63)) & 1 == 1
    }

    /// Set bit `i`, returning whether it was previously clear.
    #[inline]
    pub fn insert(&mut self, i: usize) -> bool {
        let was = self.get(i);
        self.set(i);
        !was
    }

    /// Clear all bits.
    pub fn reset(&mut self) {
        self.words.fill(0);
    }

    /// Number of set bits.
    pub fn count(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Iterate over indices of set bits in ascending order.
    pub fn iter_ones(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &w)| {
            let mut bits = w;
            std::iter::from_fn(move || {
                if bits == 0 {
                    None
                } else {
                    let b = bits.trailing_zeros() as usize;
                    bits &= bits - 1;
                    Some(wi * 64 + b)
                }
            })
        })
    }

    /// Population count of the intersection with another bitset of the
    /// same length (used for dense triangle counting checks).
    pub fn intersect_count(&self, other: &BitSet) -> usize {
        debug_assert_eq!(self.len, other.len);
        self.words
            .iter()
            .zip(other.words.iter())
            .map(|(a, b)| (a & b).count_ones() as usize)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_clear() {
        let mut b = BitSet::new(130);
        assert!(!b.get(0));
        b.set(0);
        b.set(64);
        b.set(129);
        assert!(b.get(0) && b.get(64) && b.get(129));
        assert!(!b.get(1) && !b.get(63) && !b.get(128));
        b.clear(64);
        assert!(!b.get(64));
        assert_eq!(b.count(), 2);
    }

    #[test]
    fn insert_reports_novelty() {
        let mut b = BitSet::new(10);
        assert!(b.insert(3));
        assert!(!b.insert(3));
    }

    #[test]
    fn iter_ones_ascending() {
        let mut b = BitSet::new(200);
        for i in [0, 5, 63, 64, 65, 127, 128, 199] {
            b.set(i);
        }
        let ones: Vec<usize> = b.iter_ones().collect();
        assert_eq!(ones, vec![0, 5, 63, 64, 65, 127, 128, 199]);
    }

    #[test]
    fn intersect_count_works() {
        let mut a = BitSet::new(100);
        let mut b = BitSet::new(100);
        for i in 0..50 {
            a.set(i);
        }
        for i in 25..75 {
            b.set(i);
        }
        assert_eq!(a.intersect_count(&b), 25);
    }

    #[test]
    fn reset_clears_everything() {
        let mut b = BitSet::new(77);
        for i in 0..77 {
            b.set(i);
        }
        b.reset();
        assert_eq!(b.count(), 0);
    }
}
