//! Deterministic fault injection for the serving executor.
//!
//! A [`FaultPlan`] is plain seeded data: for each named injection site
//! it says how often (one in `every` jobs) the fault fires. Whether a
//! given job faults is a pure hash of `(seed, site, job id)` — no
//! clocks, no global RNG — so a chaos run is exactly reproducible:
//! the same seed and workload fault the same jobs in the same places,
//! and a recovered run can be diffed bit-for-bit against a fault-free
//! reference.
//!
//! # Sites
//!
//! | site          | where it fires                                   | models                         |
//! |---------------|--------------------------------------------------|--------------------------------|
//! | `exec_panic`  | inside the per-job `catch_unwind` on a shard     | a job-triggered worker panic   |
//! | `shard_crash` | between dequeue and execution, *outside* the     | a whole-shard crash with a job |
//! |               | per-job isolation (kills the shard body)         | in flight                      |
//! | `stall`       | at a convergence pass boundary (the pass hook)   | a slow pass / lock convoy      |
//!
//! Queue-burst overload is a *workload*-side fault: `bench chaos`
//! produces it by submitting bursts, so it needs no injector site.
//!
//! The [`FaultInjector`] wraps a plan with fired-counters so a harness
//! can assert that faults actually triggered. `exec_panic` and
//! `shard_crash` respect the job's attempt number: a shard crash fires
//! only on attempt 0 (so the requeued job makes progress instead of
//! crash-looping the shard), and a *transient* exec panic likewise
//! fires only on attempt 0 (so the retry succeeds). A non-transient
//! exec panic fires on every attempt, driving the job into the
//! poison-job registry.

use crate::util::rng::splitmix64;
use std::sync::atomic::{AtomicU64, Ordering};

/// Seeded, deterministic description of which jobs fault where.
///
/// All fields are plain `Copy` data so the plan can ride inside
/// [`ServeConfig`](crate::serve::ServeConfig) without giving up
/// `Copy`. A rate of `0` disables that site.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// Seed mixed into every fire decision.
    pub seed: u64,
    /// Fire an `exec_panic` on roughly one in this many jobs
    /// (deterministic per job id; `0` = never).
    pub exec_panic_every: u32,
    /// When `true`, injected exec panics fire only on a job's first
    /// attempt, so the executor's retry succeeds. When `false` they
    /// fire on every attempt, exhausting the retry budget and
    /// exercising quarantine.
    pub transient: bool,
    /// Crash the whole shard body (outside the per-job isolation) on
    /// roughly one in this many jobs (`0` = never). Always fires only
    /// on attempt 0 so the respawned shard can finish the requeue.
    pub shard_crash_every: u32,
    /// Stall at convergence pass boundaries for roughly one in this
    /// many jobs (`0` = never).
    pub stall_every: u32,
    /// Stall duration per pass boundary, in milliseconds.
    pub stall_ms: u64,
}

impl FaultPlan {
    /// A plan that injects nothing (identical to `Default`).
    pub fn disabled() -> FaultPlan {
        FaultPlan::default()
    }

    /// Whether any site has a non-zero rate.
    pub fn is_active(&self) -> bool {
        self.exec_panic_every > 0 || self.shard_crash_every > 0 || self.stall_every > 0
    }

    /// Pure fire decision: hash `(seed, site, job)` and fire one time
    /// in `every`.
    fn fires(&self, every: u32, site: u64, job: u64) -> bool {
        if every == 0 {
            return false;
        }
        let mut state = self
            .seed
            .wrapping_add(site.wrapping_mul(0x9E37_79B9_7F4A_7C15))
            .wrapping_add(job.wrapping_mul(0xBF58_476D_1CE4_E5B9));
        splitmix64(&mut state) % every as u64 == 0
    }

    /// Whether this job's `attempt`-th execution should panic at the
    /// exec site.
    pub fn exec_panic_fires(&self, job: u64, attempt: u32) -> bool {
        if self.transient && attempt > 0 {
            return false;
        }
        self.fires(self.exec_panic_every, 1, job)
    }

    /// Whether popping this job (attempt `attempt`) should crash the
    /// whole shard body. Fires only on attempt 0.
    pub fn shard_crash_fires(&self, job: u64, attempt: u32) -> bool {
        attempt == 0 && self.fires(self.shard_crash_every, 2, job)
    }

    /// Whether this job's convergence passes should stall at each
    /// boundary.
    pub fn stall_fires(&self, job: u64) -> bool {
        self.fires(self.stall_every, 3, job)
    }
}

/// A [`FaultPlan`] plus fired-counters, shared by every shard of one
/// executor. The counters let a chaos harness assert that the plan
/// actually injected something (a chaos run where nothing fired proves
/// nothing).
#[derive(Debug, Default)]
pub struct FaultInjector {
    plan: FaultPlan,
    /// Exec-site panics fired so far.
    pub exec_panics: AtomicU64,
    /// Shard-body crashes fired so far.
    pub shard_crashes: AtomicU64,
    /// Pass-boundary stalls fired so far.
    pub stalls: AtomicU64,
}

impl FaultInjector {
    /// Wrap a plan with zeroed counters.
    pub fn new(plan: FaultPlan) -> FaultInjector {
        FaultInjector {
            plan,
            exec_panics: AtomicU64::new(0),
            shard_crashes: AtomicU64::new(0),
            stalls: AtomicU64::new(0),
        }
    }

    /// The wrapped plan.
    pub fn plan(&self) -> FaultPlan {
        self.plan
    }

    /// Exec site: panic if the plan says this job attempt faults.
    pub fn maybe_panic_exec(&self, job: u64, attempt: u32) {
        if self.plan.exec_panic_fires(job, attempt) {
            self.exec_panics.fetch_add(1, Ordering::Relaxed);
            panic!("injected exec panic (job {job}, attempt {attempt})");
        }
    }

    /// Shard-crash site: panic (outside the per-job isolation) if the
    /// plan says this pop faults.
    pub fn maybe_crash_shard(&self, job: u64, attempt: u32) {
        if self.plan.shard_crash_fires(job, attempt) {
            self.shard_crashes.fetch_add(1, Ordering::Relaxed);
            panic!("injected shard crash (job {job})");
        }
    }

    /// Stall site: sleep `stall_ms` if the plan says this job's passes
    /// stall. Called from the pass-boundary hook, so a stalled job
    /// with a deadline token crosses its deadline and cancels
    /// cooperatively.
    pub fn maybe_stall(&self, job: u64) {
        if self.plan.stall_fires(job) {
            self.stalls.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(std::time::Duration::from_millis(self.plan.stall_ms));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decisions_are_deterministic_and_seeded() {
        let plan = FaultPlan { seed: 42, exec_panic_every: 3, ..FaultPlan::default() };
        let fired: Vec<bool> = (0..64).map(|j| plan.exec_panic_fires(j, 0)).collect();
        let again: Vec<bool> = (0..64).map(|j| plan.exec_panic_fires(j, 0)).collect();
        assert_eq!(fired, again, "same seed must fire the same jobs");
        assert!(fired.iter().any(|&f| f), "a 1-in-3 rate over 64 jobs must fire");
        assert!(fired.iter().any(|&f| !f), "a 1-in-3 rate must not fire everything");
        let other = FaultPlan { seed: 43, exec_panic_every: 3, ..FaultPlan::default() };
        let shifted: Vec<bool> = (0..64).map(|j| other.exec_panic_fires(j, 0)).collect();
        assert_ne!(fired, shifted, "a different seed must fault different jobs");
    }

    #[test]
    fn sites_are_independent() {
        let plan = FaultPlan {
            seed: 7,
            exec_panic_every: 2,
            shard_crash_every: 2,
            stall_every: 2,
            ..FaultPlan::default()
        };
        let exec: Vec<bool> = (0..64).map(|j| plan.exec_panic_fires(j, 0)).collect();
        let crash: Vec<bool> = (0..64).map(|j| plan.shard_crash_fires(j, 0)).collect();
        let stall: Vec<bool> = (0..64).map(|j| plan.stall_fires(j)).collect();
        assert_ne!(exec, crash, "sites must hash independently");
        assert_ne!(exec, stall, "sites must hash independently");
    }

    #[test]
    fn transient_panics_spare_retries_and_crashes_fire_once() {
        let transient =
            FaultPlan { seed: 1, exec_panic_every: 1, transient: true, ..FaultPlan::default() };
        assert!(transient.exec_panic_fires(5, 0));
        assert!(!transient.exec_panic_fires(5, 1), "transient faults spare the retry");
        let persistent =
            FaultPlan { seed: 1, exec_panic_every: 1, transient: false, ..FaultPlan::default() };
        assert!(persistent.exec_panic_fires(5, 0));
        assert!(persistent.exec_panic_fires(5, 1), "persistent faults hit every attempt");
        let crash = FaultPlan { seed: 1, shard_crash_every: 1, ..FaultPlan::default() };
        assert!(crash.shard_crash_fires(5, 0));
        assert!(!crash.shard_crash_fires(5, 1), "a requeued job must not re-crash its shard");
    }

    #[test]
    fn disabled_plan_never_fires() {
        let plan = FaultPlan::disabled();
        assert!(!plan.is_active());
        for j in 0..32 {
            assert!(!plan.exec_panic_fires(j, 0));
            assert!(!plan.shard_crash_fires(j, 0));
            assert!(!plan.stall_fires(j));
        }
    }

    #[test]
    fn injector_counts_fired_faults() {
        let inj =
            FaultInjector::new(FaultPlan { seed: 9, exec_panic_every: 1, ..FaultPlan::default() });
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            inj.maybe_panic_exec(0, 0);
        }));
        assert!(caught.is_err(), "a 1-in-1 rate must panic");
        assert_eq!(inj.exec_panics.load(Ordering::Relaxed), 1);
        assert_eq!(inj.shard_crashes.load(Ordering::Relaxed), 0);
        inj.maybe_stall(0); // stall site disabled: no-op, no count
        assert_eq!(inj.stalls.load(Ordering::Relaxed), 0);
    }
}
