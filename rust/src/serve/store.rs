//! Versioned resident graphs: a [`GraphStore`] owns one maintained
//! [`StreamState`] and publishes an epoch-stamped **immutable**
//! [`EpochSnapshot`] after every applied batch.
//!
//! Readers pin an epoch by cloning the current snapshot `Arc` — a
//! query admitted against epoch `N` keeps computing on `N`'s graph
//! while the writer applies epoch `N + 1` (copy-on-compact: the
//! mutation rebuilds the working form and publishes fresh `Csr`s; the
//! pinned snapshot is never touched). A retired epoch stays readable
//! exactly as long as someone holds its `Arc` and is dropped the
//! moment the last reference goes — there is no epoch list to garbage
//! collect.
//!
//! One writer at a time: [`GraphStore::apply`] serializes mutations
//! behind a mutex. Batches are order-dependent (a delete of an edge an
//! earlier batch inserted must see it), so concurrent submitters must
//! impose their own order — the serving layer does this by waiting on
//! each `Mutate` job before submitting the next.
//!
//! **Publication is atomic (build-then-swap).** A batch is staged on a
//! clone of the maintained state and the store swaps to it only after
//! the whole pipeline succeeds — a `Mutate` job that panics or is
//! cancelled partway leaves the published epoch, every pinned
//! snapshot, and the maintained supports exactly as they were. The
//! store's own mutex is recovered from poisoning for the same reason:
//! a panicking holder cannot have left half-applied state behind.

use crate::algo::stream::{BatchOutcome, EdgeBatch, StreamState};
use crate::graph::Csr;
use crate::par::{PassControl, Pool};
use crate::plan::ExecutionPlan;
use std::sync::{Arc, Mutex, MutexGuard};

/// One immutable epoch of the resident graph: the full graph and its
/// maintained k-truss as of the batch that published it.
#[derive(Clone, Debug)]
pub struct EpochSnapshot {
    /// Epoch counter (0 = the initial load; +1 per applied batch).
    pub epoch: u64,
    /// The graph at this epoch.
    pub graph: Arc<Csr>,
    /// The maintained k-truss at this epoch.
    pub truss: Arc<Csr>,
}

struct StoreInner {
    state: StreamState,
    current: Arc<EpochSnapshot>,
}

/// The epoch-versioned resident graph (see the module docs).
pub struct GraphStore {
    k: u32,
    inner: Mutex<StoreInner>,
}

impl GraphStore {
    /// Load `g` as epoch 0, deriving initial supports and k-truss.
    pub fn new(g: &Csr, k: u32) -> GraphStore {
        let state = StreamState::new(g, k);
        let current = Arc::new(EpochSnapshot {
            epoch: 0,
            graph: Arc::new(state.graph().clone()),
            truss: Arc::new(state.truss().clone()),
        });
        GraphStore { k, inner: Mutex::new(StoreInner { state, current }) }
    }

    /// The fixed truss order this store maintains.
    pub fn k(&self) -> u32 {
        self.k
    }

    /// Lock the writer state, recovering from poisoning: a panic in a
    /// past `publish` happened while mutating a **staged clone**, so
    /// the guarded state is still the last successfully published
    /// epoch — cascading the poison would turn one faulted batch into
    /// a dead store.
    fn lock(&self) -> MutexGuard<'_, StoreInner> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// The current epoch number.
    pub fn epoch(&self) -> u64 {
        self.lock().current.epoch
    }

    /// Pin the current epoch: the returned snapshot stays valid (and
    /// immutable) for as long as the caller holds it, regardless of
    /// later batches.
    pub fn pin(&self) -> Arc<EpochSnapshot> {
        self.lock().current.clone()
    }

    /// Apply one batch sequentially and publish the next epoch.
    /// Returns the new snapshot and the batch outcome.
    pub fn apply(&self, batch: &EdgeBatch) -> (Arc<EpochSnapshot>, BatchOutcome) {
        self.publish(batch, None, PassControl::default())
            .expect("uncancelled publish always yields an epoch")
    }

    /// [`apply`](GraphStore::apply) with the frontier passes on the
    /// pool under `plan` — the executor's path for planned
    /// `Mutate` jobs.
    pub fn apply_par(
        &self,
        batch: &EdgeBatch,
        pool: &Pool,
        plan: &ExecutionPlan,
    ) -> (Arc<EpochSnapshot>, BatchOutcome) {
        self.publish(batch, Some((pool, plan)), PassControl::default())
            .expect("uncancelled publish always yields an epoch")
    }

    /// [`apply_par`](GraphStore::apply_par) with cooperative
    /// cancellation. Returns `None` — publishing **nothing** — when
    /// the batch was cut short at a stage boundary; the staged partial
    /// work is discarded and the current epoch is unchanged, so a
    /// cancelled `Mutate` job can simply be resubmitted.
    pub fn apply_par_ctl(
        &self,
        batch: &EdgeBatch,
        pool: &Pool,
        plan: &ExecutionPlan,
        ctl: PassControl<'_>,
    ) -> Option<(Arc<EpochSnapshot>, BatchOutcome)> {
        self.publish(batch, Some((pool, plan)), ctl)
    }

    fn publish(
        &self,
        batch: &EdgeBatch,
        par: Option<(&Pool, &ExecutionPlan)>,
        ctl: PassControl<'_>,
    ) -> Option<(Arc<EpochSnapshot>, BatchOutcome)> {
        let mut inner = self.lock();
        // build-then-swap: stage the batch on a clone of the
        // maintained state so a panic or a cooperative cancel mid-
        // pipeline unwinds without touching the published epoch
        let mut staged = inner.state.clone();
        let (out, cancelled) = match par {
            Some((pool, plan)) => staged.apply_par_ctl(batch, pool, plan, ctl),
            None => (staged.apply(batch), false),
        };
        if cancelled {
            return None;
        }
        let snap = Arc::new(EpochSnapshot {
            epoch: inner.current.epoch + 1,
            graph: Arc::new(staged.graph().clone()),
            truss: Arc::new(staged.truss().clone()),
        });
        inner.state = staged;
        inner.current = snap.clone();
        Some((snap, out))
    }
}

impl std::fmt::Debug for GraphStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let inner = self.lock();
        f.debug_struct("GraphStore")
            .field("k", &self.k)
            .field("epoch", &inner.current.epoch)
            .field("edges", &inner.current.graph.nnz())
            .field("truss_edges", &inner.current.truss.nnz())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::incremental::SupportMode;
    use crate::algo::ktruss::ktruss_mode;
    use crate::algo::support::Mode;
    use crate::testkit::graphs::peel_chain;

    #[test]
    fn pinned_epoch_survives_concurrent_apply() {
        let g = peel_chain(8);
        let store = Arc::new(GraphStore::new(&g, 4));
        let pinned = store.pin();
        let expect = ktruss_mode(&pinned.graph, 4, Mode::Fine, SupportMode::Full);
        let writer = {
            let store = store.clone();
            // delete block 0's K4 top edge (r, s) = (9, 10) while the
            // reader below is mid-computation on the pinned epoch
            std::thread::spawn(move || {
                let (snap, out) = store.apply(&EdgeBatch::deletes(vec![(9, 10)]));
                (snap.epoch, out.deleted)
            })
        };
        let got = ktruss_mode(&pinned.graph, 4, Mode::Fine, SupportMode::Incremental);
        let (epoch, deleted) = writer.join().unwrap();
        assert_eq!(epoch, 1);
        assert_eq!(deleted, 1);
        assert_eq!(got.truss, expect.truss, "pinned read must match a single-threaded run");
        assert_eq!(pinned.epoch, 0);
        assert_eq!(pinned.graph.nnz(), g.nnz(), "pinned snapshot must stay immutable");
        assert_eq!(store.epoch(), 1);
        assert!(store.pin().graph.nnz() < g.nnz());
    }

    #[test]
    fn faulted_batch_leaves_pinned_epoch_and_refcounts_intact() {
        use crate::algo::support::Granularity;
        use crate::par::{PassControl, Pool, Schedule};
        use crate::plan::ExecutionPlan;
        let g = peel_chain(8);
        let store = Arc::new(GraphStore::new(&g, 4));
        let pinned = store.pin();
        let weak_graph = Arc::downgrade(&pinned.graph);
        let pool = Pool::new(2);
        let plan = ExecutionPlan::fixed(Schedule::Static, Granularity::Fine, SupportMode::Full);
        // injected fault: the delete pass completes (stage 0 passed),
        // then the batch dies mid-pipeline at the next stage boundary
        let hook = |stage: usize| {
            if stage >= 1 {
                panic!("injected fault at stage {stage}");
            }
        };
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            store.apply_par_ctl(
                &EdgeBatch::deletes(vec![(9, 10)]),
                &pool,
                &plan,
                PassControl { cancel: None, on_pass: Some(&hook) },
            )
        }));
        assert!(res.is_err(), "the injected panic must surface to the caller");
        // nothing published: same epoch, same graph, pinned snapshot intact
        assert_eq!(store.epoch(), 0, "a faulted batch must not publish an epoch");
        let now = store.pin();
        assert_eq!(now.epoch, 0);
        assert_eq!(now.graph.nnz(), g.nnz(), "half-applied state must not leak");
        assert_eq!(pinned.graph.nnz(), g.nnz());
        // the store keeps serving after the fault: the poisoned mutex
        // is recovered and the retried batch publishes normally
        let (snap, out) = store.apply(&EdgeBatch::deletes(vec![(9, 10)]));
        assert_eq!(snap.epoch, 1);
        assert_eq!(out.deleted, 1);
        // refcounts: epoch 0 is retired and freed once unpinned —
        // the faulted attempt left no stray references behind
        drop(pinned);
        drop(now);
        assert!(weak_graph.upgrade().is_none(), "retired epoch 0 graph must be freed");
    }

    #[test]
    fn cancelled_batch_publishes_nothing() {
        use crate::algo::support::Granularity;
        use crate::par::{CancelToken, PassControl, Pool, Schedule};
        use crate::plan::ExecutionPlan;
        let g = peel_chain(6);
        let store = GraphStore::new(&g, 4);
        let pool = Pool::new(2);
        let plan = ExecutionPlan::fixed(Schedule::Static, Granularity::Fine, SupportMode::Full);
        let tok = CancelToken::new();
        tok.cancel();
        let res = store.apply_par_ctl(
            &EdgeBatch::deletes(vec![(7, 8)]),
            &pool,
            &plan,
            PassControl { cancel: Some(&tok), on_pass: None },
        );
        assert!(res.is_none(), "a cancelled batch must publish nothing");
        assert_eq!(store.epoch(), 0);
        assert_eq!(store.pin().graph.nnz(), g.nnz());
        // resubmitting the identical batch uncancelled succeeds
        let (snap, out) = store.apply(&EdgeBatch::deletes(vec![(7, 8)]));
        assert_eq!(snap.epoch, 1);
        assert_eq!(out.deleted, 1);
    }

    #[test]
    fn retired_epochs_are_dropped_once_unreferenced() {
        let g = peel_chain(6);
        let store = GraphStore::new(&g, 4);
        let pinned = store.pin();
        let weak_snap = Arc::downgrade(&pinned);
        let weak_graph = Arc::downgrade(&pinned.graph);
        store.apply(&EdgeBatch::deletes(vec![(7, 8)]));
        // the retired epoch stays readable while pinned…
        assert!(weak_snap.upgrade().is_some());
        assert_eq!(pinned.epoch, 0);
        drop(pinned);
        // …and is dropped the moment the last reference goes
        assert!(weak_snap.upgrade().is_none(), "retired epoch must be freed");
        assert!(weak_graph.upgrade().is_none(), "retired graph must be freed");
        assert_eq!(store.epoch(), 1);
    }

    #[test]
    fn executor_applies_while_pinned_readers_run() {
        use crate::coordinator::{JobKind, JobOutput};
        use crate::serve::{Executor, ServeConfig};
        let (g, script) = crate::testkit::graphs::churn_chain(8, 4);
        let store = Arc::new(GraphStore::new(&g, 4));
        let ex = Executor::start(
            ServeConfig { shards: 1, enable_dense: false, ..Default::default() }
                .with_total_workers(3),
        );
        for (i, batch) in script.iter().enumerate() {
            // pin the pre-batch epoch and serve a read against it
            // while the mutation runs on the executor
            let pinned = store.pin();
            let read = ex.submit(pinned.graph.clone(), JobKind::Ktruss { k: 4, mode: Mode::Fine });
            let ticket = ex.submit(
                pinned.graph.clone(),
                JobKind::Mutate { store: store.clone(), batch: Arc::new(batch.clone()) },
            );
            // serialize mutations: batches are order-dependent, so the
            // next one is submitted only after this one completes
            let r = ticket.wait();
            assert!(r.plan.is_some(), "mutate jobs are planned");
            match r.output.expect("mutate job succeeds") {
                JobOutput::Mutate { epoch, recomputed, .. } => {
                    assert_eq!(epoch, (i + 1) as u64, "batch {i}");
                    assert!(recomputed, "every churn batch flips the truss");
                }
                other => panic!("unexpected output {other:?}"),
            }
            let rr = read.wait();
            match rr.output.expect("read job succeeds") {
                JobOutput::Ktruss { truss_edges, .. } => {
                    let want = ktruss_mode(&pinned.graph, 4, Mode::Fine, SupportMode::Full);
                    assert_eq!(truss_edges, want.truss.nnz(), "batch {i}: pinned read diverged");
                }
                other => panic!("unexpected output {other:?}"),
            }
        }
        let spans = ex.obs.spans.snapshot();
        let mutate_spans: Vec<_> = spans.iter().filter(|s| s.kind == "mutate").collect();
        assert_eq!(mutate_spans.len(), script.len());
        assert!(mutate_spans.iter().all(|s| s.plan_string() != "-/-/-/-"));
        ex.shutdown();
        assert_eq!(store.epoch(), script.len() as u64);
    }
}
