//! Versioned resident graphs: a [`GraphStore`] owns one maintained
//! [`StreamState`] and publishes an epoch-stamped **immutable**
//! [`EpochSnapshot`] after every applied batch.
//!
//! Readers pin an epoch by cloning the current snapshot `Arc` — a
//! query admitted against epoch `N` keeps computing on `N`'s graph
//! while the writer applies epoch `N + 1` (copy-on-compact: the
//! mutation rebuilds the working form and publishes fresh `Csr`s; the
//! pinned snapshot is never touched). A retired epoch stays readable
//! exactly as long as someone holds its `Arc` and is dropped the
//! moment the last reference goes — there is no epoch list to garbage
//! collect.
//!
//! One writer at a time: [`GraphStore::apply`] serializes mutations
//! behind a mutex. Batches are order-dependent (a delete of an edge an
//! earlier batch inserted must see it), so concurrent submitters must
//! impose their own order — the serving layer does this by waiting on
//! each `Mutate` job before submitting the next.

use crate::algo::stream::{BatchOutcome, EdgeBatch, StreamState};
use crate::graph::Csr;
use crate::par::Pool;
use crate::plan::ExecutionPlan;
use std::sync::{Arc, Mutex};

/// One immutable epoch of the resident graph: the full graph and its
/// maintained k-truss as of the batch that published it.
#[derive(Clone, Debug)]
pub struct EpochSnapshot {
    /// Epoch counter (0 = the initial load; +1 per applied batch).
    pub epoch: u64,
    /// The graph at this epoch.
    pub graph: Arc<Csr>,
    /// The maintained k-truss at this epoch.
    pub truss: Arc<Csr>,
}

struct StoreInner {
    state: StreamState,
    current: Arc<EpochSnapshot>,
}

/// The epoch-versioned resident graph (see the module docs).
pub struct GraphStore {
    k: u32,
    inner: Mutex<StoreInner>,
}

impl GraphStore {
    /// Load `g` as epoch 0, deriving initial supports and k-truss.
    pub fn new(g: &Csr, k: u32) -> GraphStore {
        let state = StreamState::new(g, k);
        let current = Arc::new(EpochSnapshot {
            epoch: 0,
            graph: Arc::new(state.graph().clone()),
            truss: Arc::new(state.truss().clone()),
        });
        GraphStore { k, inner: Mutex::new(StoreInner { state, current }) }
    }

    /// The fixed truss order this store maintains.
    pub fn k(&self) -> u32 {
        self.k
    }

    /// The current epoch number.
    pub fn epoch(&self) -> u64 {
        self.inner.lock().unwrap().current.epoch
    }

    /// Pin the current epoch: the returned snapshot stays valid (and
    /// immutable) for as long as the caller holds it, regardless of
    /// later batches.
    pub fn pin(&self) -> Arc<EpochSnapshot> {
        self.inner.lock().unwrap().current.clone()
    }

    /// Apply one batch sequentially and publish the next epoch.
    /// Returns the new snapshot and the batch outcome.
    pub fn apply(&self, batch: &EdgeBatch) -> (Arc<EpochSnapshot>, BatchOutcome) {
        self.publish(batch, None)
    }

    /// [`apply`](GraphStore::apply) with the frontier passes on the
    /// pool under `plan` — the executor's path for planned
    /// `Mutate` jobs.
    pub fn apply_par(
        &self,
        batch: &EdgeBatch,
        pool: &Pool,
        plan: &ExecutionPlan,
    ) -> (Arc<EpochSnapshot>, BatchOutcome) {
        self.publish(batch, Some((pool, plan)))
    }

    fn publish(
        &self,
        batch: &EdgeBatch,
        par: Option<(&Pool, &ExecutionPlan)>,
    ) -> (Arc<EpochSnapshot>, BatchOutcome) {
        let mut inner = self.inner.lock().unwrap();
        let out = match par {
            Some((pool, plan)) => inner.state.apply_par(batch, pool, plan),
            None => inner.state.apply(batch),
        };
        let snap = Arc::new(EpochSnapshot {
            epoch: inner.current.epoch + 1,
            graph: Arc::new(inner.state.graph().clone()),
            truss: Arc::new(inner.state.truss().clone()),
        });
        inner.current = snap.clone();
        (snap, out)
    }
}

impl std::fmt::Debug for GraphStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let inner = self.inner.lock().unwrap();
        f.debug_struct("GraphStore")
            .field("k", &self.k)
            .field("epoch", &inner.current.epoch)
            .field("edges", &inner.current.graph.nnz())
            .field("truss_edges", &inner.current.truss.nnz())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::incremental::SupportMode;
    use crate::algo::ktruss::ktruss_mode;
    use crate::algo::support::Mode;
    use crate::testkit::graphs::peel_chain;

    #[test]
    fn pinned_epoch_survives_concurrent_apply() {
        let g = peel_chain(8);
        let store = Arc::new(GraphStore::new(&g, 4));
        let pinned = store.pin();
        let expect = ktruss_mode(&pinned.graph, 4, Mode::Fine, SupportMode::Full);
        let writer = {
            let store = store.clone();
            // delete block 0's K4 top edge (r, s) = (9, 10) while the
            // reader below is mid-computation on the pinned epoch
            std::thread::spawn(move || {
                let (snap, out) = store.apply(&EdgeBatch::deletes(vec![(9, 10)]));
                (snap.epoch, out.deleted)
            })
        };
        let got = ktruss_mode(&pinned.graph, 4, Mode::Fine, SupportMode::Incremental);
        let (epoch, deleted) = writer.join().unwrap();
        assert_eq!(epoch, 1);
        assert_eq!(deleted, 1);
        assert_eq!(got.truss, expect.truss, "pinned read must match a single-threaded run");
        assert_eq!(pinned.epoch, 0);
        assert_eq!(pinned.graph.nnz(), g.nnz(), "pinned snapshot must stay immutable");
        assert_eq!(store.epoch(), 1);
        assert!(store.pin().graph.nnz() < g.nnz());
    }

    #[test]
    fn retired_epochs_are_dropped_once_unreferenced() {
        let g = peel_chain(6);
        let store = GraphStore::new(&g, 4);
        let pinned = store.pin();
        let weak_snap = Arc::downgrade(&pinned);
        let weak_graph = Arc::downgrade(&pinned.graph);
        store.apply(&EdgeBatch::deletes(vec![(7, 8)]));
        // the retired epoch stays readable while pinned…
        assert!(weak_snap.upgrade().is_some());
        assert_eq!(pinned.epoch, 0);
        drop(pinned);
        // …and is dropped the moment the last reference goes
        assert!(weak_snap.upgrade().is_none(), "retired epoch must be freed");
        assert!(weak_graph.upgrade().is_none(), "retired graph must be freed");
        assert_eq!(store.epoch(), 1);
    }

    #[test]
    fn executor_applies_while_pinned_readers_run() {
        use crate::coordinator::{JobKind, JobOutput};
        use crate::serve::{Executor, ServeConfig};
        let (g, script) = crate::testkit::graphs::churn_chain(8, 4);
        let store = Arc::new(GraphStore::new(&g, 4));
        let ex = Executor::start(
            ServeConfig { shards: 1, enable_dense: false, ..Default::default() }
                .with_total_workers(3),
        );
        for (i, batch) in script.iter().enumerate() {
            // pin the pre-batch epoch and serve a read against it
            // while the mutation runs on the executor
            let pinned = store.pin();
            let read = ex.submit(pinned.graph.clone(), JobKind::Ktruss { k: 4, mode: Mode::Fine });
            let ticket = ex.submit(
                pinned.graph.clone(),
                JobKind::Mutate { store: store.clone(), batch: Arc::new(batch.clone()) },
            );
            // serialize mutations: batches are order-dependent, so the
            // next one is submitted only after this one completes
            let r = ticket.wait();
            assert!(r.plan.is_some(), "mutate jobs are planned");
            match r.output.expect("mutate job succeeds") {
                JobOutput::Mutate { epoch, recomputed, .. } => {
                    assert_eq!(epoch, (i + 1) as u64, "batch {i}");
                    assert!(recomputed, "every churn batch flips the truss");
                }
                other => panic!("unexpected output {other:?}"),
            }
            let rr = read.wait();
            match rr.output.expect("read job succeeds") {
                JobOutput::Ktruss { truss_edges, .. } => {
                    let want = ktruss_mode(&pinned.graph, 4, Mode::Fine, SupportMode::Full);
                    assert_eq!(truss_edges, want.truss.nnz(), "batch {i}: pinned read diverged");
                }
                other => panic!("unexpected output {other:?}"),
            }
        }
        let spans = ex.obs.spans.snapshot();
        let mutate_spans: Vec<_> = spans.iter().filter(|s| s.kind == "mutate").collect();
        assert_eq!(mutate_spans.len(), script.len());
        assert!(mutate_spans.iter().all(|s| s.plan_string() != "-/-/-"));
        ex.shutdown();
        assert_eq!(store.epoch(), script.len() as u64);
    }
}
