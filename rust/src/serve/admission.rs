//! Plan-aware admission control: decide at submit time whether a job
//! is enqueued, rejected with backpressure, or shed/degraded.
//!
//! The decision is a pure function ([`AdmissionPolicy::decide`]) of
//! the queue depth and the *planner's* cost prediction — the same
//! `choose_scored` estimate the dispatcher packs batches with. That is
//! the point: the serving layer refuses work it already knows it
//! cannot finish in time, instead of discovering the miss after
//! burning a shard on it.
//!
//! * **Backpressure**: with a bound configured
//!   (`ServeConfig::max_queue > 0`), a submission that finds the
//!   admitted-but-not-executing backlog at the bound is rejected with
//!   [`SubmitError::QueueFull`] — the caller sees the overload
//!   immediately instead of growing an unbounded queue.
//! * **Shed / degrade**: with shedding enabled (`ServeConfig::shed`),
//!   a [`Priority::Low`] job whose estimated wait plus predicted
//!   execution wall already exceeds its deadline budget is not
//!   enqueued. If the submission carries a
//!   [`GraphStore`](crate::serve::GraphStore) to degrade to, the
//!   executor answers from the store's current (possibly stale) epoch;
//!   otherwise the job is shed outright. Either way the ticket
//!   resolves immediately with a terminal
//!   [`JobOutcome`](crate::coordinator::JobOutcome).
//!
//! High- and normal-priority jobs are never shed at admission; they
//! are what shedding protects.

use super::queue::Priority;
use std::time::Duration;

/// Why a submission was refused outright (no ticket was issued).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Admission backpressure: the admitted-but-not-executing backlog
    /// is at the configured bound.
    QueueFull {
        /// The configured `max_queue` bound that was hit.
        max_queue: usize,
    },
    /// The executor has shut down.
    Down,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull { max_queue } => {
                write!(f, "admission queue full (bound {max_queue})")
            }
            SubmitError::Down => write!(f, "executor is down"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Inputs to one admission decision, gathered at submit time.
#[derive(Clone, Copy, Debug)]
pub struct AdmissionInput {
    /// The job's priority class.
    pub priority: Priority,
    /// The job's soft-deadline budget (`None` = best-effort, never
    /// shed).
    pub deadline: Option<Duration>,
    /// The cost model's predicted execution wall for the chosen plan,
    /// in ms.
    pub predicted_ms: f64,
    /// Estimated wait before this job would start executing, in ms
    /// (queued steps ahead of it through the ns/step calibration,
    /// spread across shards).
    pub wait_ms: f64,
    /// Jobs admitted but not yet executing (central queue plus shard
    /// queues).
    pub queue_depth: usize,
}

/// What admission decided for one submission.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmissionDecision {
    /// Enqueue normally.
    Admit,
    /// Refuse with [`SubmitError::QueueFull`]: the backlog is at the
    /// bound.
    Reject,
    /// Do not run: answer from a stale epoch if the submission carries
    /// a degrade store, else shed. The ticket resolves immediately.
    Degrade,
}

/// The admission knobs, lifted off
/// [`ServeConfig`](crate::serve::ServeConfig).
#[derive(Clone, Copy, Debug, Default)]
pub struct AdmissionPolicy {
    /// Backlog bound (`0` = unbounded, never reject).
    pub max_queue: usize,
    /// Shed/degrade Low jobs whose planned cost blows their deadline.
    pub shed: bool,
}

impl AdmissionPolicy {
    /// Decide one submission. Pure: same input, same decision.
    pub fn decide(&self, input: &AdmissionInput) -> AdmissionDecision {
        if self.max_queue > 0 && input.queue_depth >= self.max_queue {
            return AdmissionDecision::Reject;
        }
        if self.shed && input.priority == Priority::Low {
            if let Some(deadline) = input.deadline {
                let budget_ms = deadline.as_secs_f64() * 1e3;
                if input.wait_ms + input.predicted_ms > budget_ms {
                    return AdmissionDecision::Degrade;
                }
            }
        }
        AdmissionDecision::Admit
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn input() -> AdmissionInput {
        AdmissionInput {
            priority: Priority::Low,
            deadline: Some(Duration::from_millis(10)),
            predicted_ms: 2.0,
            wait_ms: 1.0,
            queue_depth: 0,
        }
    }

    #[test]
    fn unbounded_best_effort_policy_admits_everything() {
        let policy = AdmissionPolicy { max_queue: 0, shed: false };
        let over = AdmissionInput { queue_depth: 10_000, predicted_ms: 1e9, ..input() };
        assert_eq!(policy.decide(&over), AdmissionDecision::Admit);
    }

    #[test]
    fn full_queue_rejects_regardless_of_priority() {
        let policy = AdmissionPolicy { max_queue: 4, shed: true };
        for priority in [Priority::Low, Priority::Normal, Priority::High] {
            let at_bound = AdmissionInput { priority, queue_depth: 4, ..input() };
            assert_eq!(policy.decide(&at_bound), AdmissionDecision::Reject);
        }
        let below = AdmissionInput { queue_depth: 3, predicted_ms: 0.1, wait_ms: 0.0, ..input() };
        assert_eq!(policy.decide(&below), AdmissionDecision::Admit);
    }

    #[test]
    fn low_jobs_blowing_their_deadline_degrade() {
        let policy = AdmissionPolicy { max_queue: 0, shed: true };
        // wait 8ms + predicted 5ms > 10ms budget
        let doomed = AdmissionInput { predicted_ms: 5.0, wait_ms: 8.0, ..input() };
        assert_eq!(policy.decide(&doomed), AdmissionDecision::Degrade);
        // the same cost with headroom is admitted
        let fits = AdmissionInput { predicted_ms: 5.0, wait_ms: 1.0, ..input() };
        assert_eq!(policy.decide(&fits), AdmissionDecision::Admit);
    }

    #[test]
    fn only_low_priority_with_a_deadline_is_shed() {
        let policy = AdmissionPolicy { max_queue: 0, shed: true };
        let doomed = AdmissionInput { predicted_ms: 1e6, wait_ms: 1e6, ..input() };
        assert_eq!(policy.decide(&doomed), AdmissionDecision::Degrade);
        for priority in [Priority::Normal, Priority::High] {
            let protected = AdmissionInput { priority, ..doomed };
            assert_eq!(policy.decide(&protected), AdmissionDecision::Admit);
        }
        let best_effort = AdmissionInput { deadline: None, ..doomed };
        assert_eq!(policy.decide(&best_effort), AdmissionDecision::Admit);
    }

    #[test]
    fn shedding_off_never_degrades() {
        let policy = AdmissionPolicy { max_queue: 0, shed: false };
        let doomed = AdmissionInput { predicted_ms: 1e6, wait_ms: 1e6, ..input() };
        assert_eq!(policy.decide(&doomed), AdmissionDecision::Admit);
    }

    #[test]
    fn submit_error_displays() {
        assert_eq!(
            SubmitError::QueueFull { max_queue: 8 }.to_string(),
            "admission queue full (bound 8)"
        );
        assert_eq!(SubmitError::Down.to_string(), "executor is down");
    }
}
