//! The sharded serving executor: a central priority/EDF admission
//! queue, a dispatcher that packs batches across shards by estimated
//! cost, and N shard threads (each owning a worker pool) with
//! cross-shard stealing of queued jobs.
//!
//! This is the paper's fine-grained load-balancing argument re-applied
//! one level up. A batch of heterogeneous jobs is a coarse task set
//! with exactly the skew pathology of §III-A: one decomposition job can
//! dwarf a hundred triangle counts. So the dispatcher treats jobs like
//! the support pass treats rows — estimate per-task cost
//! ([`super::cost_model`]), pack the batch into equal-*work* (not
//! equal-count) shard assignments (the private `pack_batch`), and absorb
//! estimation error at runtime by letting a drained shard steal the
//! globally most urgent queued job (the Hornet bin-and-steal idiom at
//! job granularity; stealing the *most* urgent job is the job-level
//! twist — the idle thief executes it immediately, so the steal can
//! only pull urgent work forward).
//!
//! # Fault tolerance
//!
//! The executor survives its own workload (see `docs/ARCHITECTURE.md`,
//! "Failure model"):
//!
//! * **Admission control** ([`super::admission`]): a bounded backlog
//!   rejects with [`SubmitError::QueueFull`]; with shedding enabled,
//!   Low jobs whose planned cost already blows their deadline resolve
//!   immediately — degraded to a stale [`GraphStore`] epoch when the
//!   submission carries one, shed outright otherwise.
//! * **Panic isolation**: each execution runs under `catch_unwind`. A
//!   panicking job is retried with backoff up to
//!   [`ServeConfig::retry_max`] times, then its shape fingerprint is
//!   quarantined (the poison-job registry) — the shard itself keeps
//!   serving. A panic *outside* the per-job isolation kills only the
//!   shard body: a supervisor respawns it and requeues the in-flight
//!   admission from the shard's stash, so the job is never lost.
//! * **Deadline enforcement**: with shedding enabled, admitted jobs
//!   carry a deadline-armed [`CancelToken`] and stop cooperatively at
//!   the next convergence pass boundary once the deadline passes
//!   ([`JobOutcome::Cancelled`]).
//! * **Lock hygiene**: every mutex/condvar acquisition recovers from
//!   poisoning explicitly (`lock_recover`) — a panicking thread must
//!   not take down submitters or `Drop`.

use super::admission::{AdmissionDecision, AdmissionInput, AdmissionPolicy, SubmitError};
use super::cost_model::{estimate_steps_mode, job_label, kind_label, CostModel};
use super::faults::{FaultInjector, FaultPlan};
use super::queue::{Admission, Priority, ServeQueue};
use super::store::GraphStore;
use crate::algo::incremental::SupportMode;
use crate::coordinator::job::{
    Engine, JobId, JobKind, JobOutcome, JobOutput, JobRequest, JobResult,
};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::router::{route_costed, RouterConfig};
use crate::coordinator::worker::Worker;
use crate::graph::Csr;
use crate::par::{CancelToken, PassControl, Pool};
use crate::plan::{ExecutionPlan, PlanSpec, Planner};
use crate::runtime::DenseEngine;
use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Lock a mutex, recovering from poisoning. Every structure guarded in
/// this module stays consistent across a panic (queues and stashes are
/// mutated by single push/pop/take operations), so the poison flag
/// carries no information we act on — and ignoring it is what keeps a
/// panicked shard from cascading into every submitter and into
/// `Executor::drop`.
fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// Configuration of the sharded executor.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Worker shards; each owns a `par::Pool` and executes one job at a
    /// time (intra-job parallelism comes from the pool).
    pub shards: usize,
    /// Pool width per shard.
    pub workers_per_shard: usize,
    /// The first `workers_remainder` shards get one extra pool worker —
    /// lets a total worker budget that does not divide evenly across
    /// shards be honored exactly (`total = shards * workers_per_shard +
    /// workers_remainder`).
    pub workers_remainder: usize,
    /// Route to the dense engine only when a job's estimated work is at
    /// or below this many merge steps (`u64::MAX` = shape-only
    /// routing); see [`crate::coordinator::router::route_costed`].
    pub dense_step_ceiling: u64,
    /// Max jobs the dispatcher packs per batch.
    pub max_batch: usize,
    /// How long the dispatcher waits to fill a batch.
    pub batch_window: Duration,
    /// Try to construct the dense engine per shard (requires artifacts).
    pub enable_dense: bool,
    /// Execution-plan pinning for sparse truss jobs: pinned axes are
    /// fixed for every job, unpinned axes are chosen per job by the
    /// submit-time [`Planner`] (which also picks the job's
    /// cost-estimate profile). [`PlanSpec::auto`] = plan everything.
    pub plan: PlanSpec,
    /// Allow drained shards to steal queued jobs from loaded shards.
    pub steal: bool,
    /// Admission backlog bound: a submission that finds this many jobs
    /// admitted but not yet executing is rejected with
    /// [`SubmitError::QueueFull`]. `0` = unbounded, never reject.
    pub max_queue: usize,
    /// Enable shedding and deadline *enforcement*: Low jobs whose
    /// planned cost blows their deadline resolve at admission
    /// (degraded or shed), and admitted jobs cancel cooperatively at
    /// the first pass boundary past their deadline. Off by default:
    /// deadlines are soft (misses are counted, jobs still complete).
    pub shed: bool,
    /// Panic retry budget per job shape: an execution that panics is
    /// requeued (with backoff) while its fingerprint's panic count
    /// stays at or below this, then quarantined.
    pub retry_max: u32,
    /// Deterministic fault injection for chaos tests and `bench chaos`;
    /// `None` (or a plan with every rate 0) injects nothing.
    pub faults: Option<FaultPlan>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            shards: 2,
            workers_per_shard: 2,
            workers_remainder: 0,
            dense_step_ceiling: u64::MAX,
            max_batch: 16,
            batch_window: Duration::from_millis(2),
            enable_dense: true,
            plan: PlanSpec::auto(),
            steal: true,
            max_queue: 0,
            shed: false,
            retry_max: 2,
            faults: None,
        }
    }
}

impl ServeConfig {
    /// Split a TOTAL worker budget across this config's shards exactly:
    /// every shard gets `total / shards` workers and the first
    /// `total % shards` shards one extra (minimum 1 worker per shard,
    /// which is the only case where the budget can be exceeded).
    pub fn with_total_workers(mut self, total: usize) -> ServeConfig {
        let shards = self.shards.max(1);
        self.workers_per_shard = (total / shards).max(1);
        self.workers_remainder = if total / shards == 0 { 0 } else { total % shards };
        self
    }
}

/// Per-job submission options.
#[derive(Clone)]
pub struct SubmitOpts {
    /// Urgency class of the job.
    pub priority: Priority,
    /// Soft deadline relative to submission. Misses are counted in the
    /// metrics; with [`ServeConfig::shed`] the deadline is additionally
    /// *enforced* (admission shedding for Low jobs, cooperative
    /// cancellation for admitted ones).
    pub deadline: Option<Duration>,
    /// Stale-epoch degrade target: when admission sheds this job and
    /// the store can answer it (a k-truss job whose `k` matches the
    /// store's), the ticket resolves [`JobOutcome::Degraded`] from the
    /// store's current — possibly stale — epoch instead of failing.
    pub degrade_store: Option<Arc<GraphStore>>,
}

impl Default for SubmitOpts {
    fn default() -> Self {
        SubmitOpts { priority: Priority::Normal, deadline: None, degrade_store: None }
    }
}

impl std::fmt::Debug for SubmitOpts {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SubmitOpts")
            .field("priority", &self.priority)
            .field("deadline", &self.deadline)
            .field("degrade_store", &self.degrade_store.is_some())
            .finish()
    }
}

/// Ticket for a submitted job.
pub struct Ticket {
    /// Id assigned to the submitted job.
    pub id: JobId,
    rx: Receiver<JobResult>,
}

impl Ticket {
    /// Block until the result arrives.
    pub fn wait(self) -> JobResult {
        self.rx.recv().expect("executor dropped without reply")
    }

    /// Non-blocking poll.
    pub fn try_get(&self) -> Option<JobResult> {
        self.rx.try_recv().ok()
    }
}

/// Central admission queue state (guarded by one mutex, signalled on
/// every submit and on shutdown).
struct AdmState {
    queue: ServeQueue,
    shutdown: bool,
}

struct AdmissionShared {
    state: Mutex<AdmState>,
    cv: Condvar,
}

/// Per-shard run queues plus the dispatch-complete flag, all under one
/// mutex: stealing needs an atomic view of every queue anyway, and the
/// queues hold jobs (not tasks) so the lock is far off the hot path.
struct ShardQueues {
    queues: Vec<ServeQueue>,
    dispatch_done: bool,
}

struct ShardShared {
    state: Mutex<ShardQueues>,
    work_cv: Condvar,
    /// Estimated steps of the job each shard is currently executing
    /// (0 = idle). Lets the dispatcher's packing baseline see a shard
    /// blocked on a heavy job as loaded even when its queue is empty.
    inflight: Vec<AtomicU64>,
    /// The admission each shard is currently executing (a clone; the
    /// graph is an `Arc`). A shard-body panic unwinds past the job —
    /// the supervisor takes the stash and requeues it, so the crash
    /// loses nothing. Separate from `state` so neither ever needs the
    /// other while held by the supervisor.
    stash: Vec<Mutex<Option<Admission>>>,
    /// Poison-job registry: shape fingerprint → panics observed. A
    /// fingerprint whose count exceeds the retry budget is quarantined
    /// on sight; a successful completion clears its entry
    /// (self-healing after transient faults).
    poison: Mutex<HashMap<u64, u32>>,
}

/// The sharded executor handle. Dropping it drains queued jobs and
/// shuts the shards down.
///
/// ```
/// use std::sync::Arc;
/// use ktruss::coordinator::JobKind;
/// use ktruss::graph::builder::from_sorted_unique;
/// use ktruss::serve::{Executor, ServeConfig};
///
/// let ex = Executor::start(ServeConfig { shards: 2, ..Default::default() });
/// let g = Arc::new(from_sorted_unique(3, &[(0, 1), (0, 2), (1, 2)]));
/// let ticket = ex.submit(g, JobKind::Triangles);
/// let result = ticket.wait();
/// assert!(result.output.is_ok());
/// ex.shutdown();
/// ```
pub struct Executor {
    cfg: ServeConfig,
    adm: Arc<AdmissionShared>,
    shards: Arc<ShardShared>,
    next_id: AtomicU64,
    /// Latency quantiles, per-shard counters, deadline and robustness
    /// accounting.
    pub metrics: Arc<Metrics>,
    /// The ns/step-calibrated per-job cost model (refined by every
    /// completion).
    pub cost_model: Arc<CostModel>,
    /// Observability hub: the job → pass span log every shard appends
    /// to at completion, plus the per-plan-regime drift tracker joining
    /// admission-time predictions against measured walls
    /// ([`crate::obs`]).
    pub obs: Arc<crate::obs::ObsHub>,
    /// The fault injector shared by every shard when the config carries
    /// an active [`FaultPlan`] (`None` in production). Public so a
    /// chaos harness can assert its fired-counters.
    pub faults: Option<Arc<FaultInjector>>,
    /// The submit-time planner: plans each sparse truss job exactly
    /// once at admission (schedule × granularity × support ×
    /// crossover), informed by the cost model's per-label calibration.
    planner: Planner,
    dispatcher: Mutex<Option<std::thread::JoinHandle<()>>>,
    shard_handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl Executor {
    /// Start with a fresh (uncalibrated) cost model.
    pub fn start(cfg: ServeConfig) -> Executor {
        Executor::start_with_model(cfg, CostModel::new())
    }

    /// Start with a pre-seeded cost model (e.g. loaded from persisted
    /// trace records, see [`crate::cost::persist`]).
    pub fn start_with_model(cfg: ServeConfig, model: CostModel) -> Executor {
        // normalize degenerate knobs: 0 shards is meaningless and a
        // 0-size batch would make the dispatcher spin without ever
        // draining the queue (and hang shutdown)
        let cfg = ServeConfig { shards: cfg.shards.max(1), max_batch: cfg.max_batch.max(1), ..cfg };
        let metrics = Arc::new(Metrics::with_shards(cfg.shards));
        let cost_model = Arc::new(model);
        let obs = Arc::new(crate::obs::ObsHub::new());
        // a pre-seeded model's retained records may carry executed-plan
        // provenance: replay them so drift baselines survive restarts
        obs.drift.seed(&cost_model.records(), &cost_model);
        // plan against the base shard pool width (the remainder shards'
        // one extra worker is noise at planning granularity)
        let planner = Planner::new(cfg.workers_per_shard.max(1))
            .with_spec(cfg.plan)
            .with_model(Arc::clone(&cost_model));
        let adm = Arc::new(AdmissionShared {
            state: Mutex::new(AdmState { queue: ServeQueue::new(), shutdown: false }),
            cv: Condvar::new(),
        });
        let shards = Arc::new(ShardShared {
            state: Mutex::new(ShardQueues {
                queues: (0..cfg.shards).map(|_| ServeQueue::new()).collect(),
                dispatch_done: false,
            }),
            work_cv: Condvar::new(),
            inflight: (0..cfg.shards).map(|_| AtomicU64::new(0)).collect(),
            stash: (0..cfg.shards).map(|_| Mutex::new(None)).collect(),
            poison: Mutex::new(HashMap::new()),
        });
        let faults = cfg.faults.filter(|p| p.is_active()).map(|p| Arc::new(FaultInjector::new(p)));
        let mut shard_handles = Vec::with_capacity(cfg.shards);
        for me in 0..cfg.shards {
            let shards = Arc::clone(&shards);
            let metrics = Arc::clone(&metrics);
            let cost_model = Arc::clone(&cost_model);
            let obs = Arc::clone(&obs);
            let faults = faults.clone();
            let handle = std::thread::Builder::new()
                .name(format!("ktruss-shard-{me}"))
                .spawn(move || {
                    shard_supervisor(me, cfg, &shards, &metrics, &cost_model, &obs, faults.as_ref())
                })
                .expect("spawn shard");
            shard_handles.push(handle);
        }
        let dispatcher = {
            let adm = Arc::clone(&adm);
            let shards = Arc::clone(&shards);
            let metrics = Arc::clone(&metrics);
            std::thread::Builder::new()
                .name("ktruss-dispatch".into())
                .spawn(move || dispatch_loop(cfg, &adm, &shards, &metrics))
                .expect("spawn dispatcher")
        };
        Executor {
            cfg,
            adm,
            shards,
            next_id: AtomicU64::new(1),
            metrics,
            cost_model,
            obs,
            faults,
            planner,
            dispatcher: Mutex::new(Some(dispatcher)),
            shard_handles: Mutex::new(shard_handles),
        }
    }

    /// The (normalized) configuration the executor started with.
    pub fn config(&self) -> ServeConfig {
        self.cfg
    }

    /// Submit at normal priority, no deadline.
    pub fn submit(&self, graph: Arc<Csr>, kind: JobKind) -> Ticket {
        self.submit_with(graph, kind, SubmitOpts::default())
    }

    /// Submit with explicit priority and soft deadline, panicking on
    /// refusal (see [`Executor::try_submit_with`] for the fallible
    /// form — with admission control configured, prefer it).
    pub fn submit_with(&self, graph: Arc<Csr>, kind: JobKind, opts: SubmitOpts) -> Ticket {
        match self.try_submit_with(graph, kind, opts) {
            Ok(t) => t,
            // panic only with every executor lock released — panicking
            // with the admission mutex held would poison it and turn
            // the Executor's Drop (which locks it again) into a double
            // panic / abort
            Err(e) => panic!("{e}"),
        }
    }

    /// Submit with explicit priority and soft deadline. For sparse
    /// truss jobs the [`ExecutionPlan`] is computed **here, exactly
    /// once** — the plan rides the admission queue to the executing
    /// worker, and the cost estimate uses the plan's support profile,
    /// so the submit-time estimate and the execution agree by
    /// construction.
    ///
    /// The same plan drives admission control: with a backlog at the
    /// configured bound the submission is refused
    /// ([`SubmitError::QueueFull`]); with shedding enabled, a Low job
    /// whose estimated wait plus predicted wall blows its deadline
    /// resolves immediately — [`JobOutcome::Degraded`] from
    /// [`SubmitOpts::degrade_store`]'s current epoch when it can
    /// answer, [`JobOutcome::Shed`] otherwise. Mutations are never
    /// shed or degraded (dropping a write silently would corrupt the
    /// submitter's epoch ordering); backpressure still applies.
    pub fn try_submit_with(
        &self,
        graph: Arc<Csr>,
        kind: JobKind,
        opts: SubmitOpts,
    ) -> Result<Ticket, SubmitError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (rtx, rrx) = channel();
        let (plan, planned_pass_ms): (Option<ExecutionPlan>, Option<f64>) = match kind {
            JobKind::Ktruss { k, .. } => {
                let (p, scored) = self.planner.choose_scored(&graph, k);
                (Some(p), scored)
            }
            JobKind::Mutate { ref store, .. } => {
                let (p, scored) = self.planner.choose_scored(&graph, store.k());
                (Some(p), scored)
            }
            _ => (None, None),
        };
        let support = plan.map(|p| p.support).unwrap_or(SupportMode::Full);
        let est_steps = estimate_steps_mode(&graph, &kind, support);
        // predict under the same label the completion will calibrate
        // under, so drift accounting compares like with like
        let predicted_ms = self
            .cost_model
            .predict_ms_for(&job_label(&kind, plan.map(|p| p.support)), est_steps);
        // admission decision: backlog depth and queued work are read
        // without holding both locks at once (the numbers are
        // advisory — racing submitters may briefly overshoot a bound
        // by one, never grow it unbounded)
        let (queue_depth, queued_steps) = {
            let mut depth = 0usize;
            let mut steps = 0u64;
            {
                let st = lock_recover(&self.shards.state);
                for (w, q) in st.queues.iter().enumerate() {
                    depth += q.len();
                    steps += q.queued_steps() + self.shards.inflight[w].load(Ordering::Relaxed);
                }
            }
            let st = lock_recover(&self.adm.state);
            depth += st.queue.len();
            steps += st.queue.queued_steps();
            (depth, steps)
        };
        let wait_ms =
            queued_steps as f64 * self.cost_model.ns_per_step() / 1e6 / self.cfg.shards as f64;
        let policy = AdmissionPolicy { max_queue: self.cfg.max_queue, shed: self.cfg.shed };
        let input = AdmissionInput {
            priority: opts.priority,
            // mutations are never shed/degraded: hide the deadline
            // from the shed rule (backpressure still sees the depth)
            deadline: match kind {
                JobKind::Mutate { .. } => None,
                _ => opts.deadline,
            },
            predicted_ms,
            wait_ms,
            queue_depth,
        };
        match policy.decide(&input) {
            AdmissionDecision::Reject => {
                self.metrics.record_queue_rejected();
                return Err(SubmitError::QueueFull { max_queue: self.cfg.max_queue });
            }
            AdmissionDecision::Degrade => {
                // resolve the ticket immediately: from the degrade
                // store's current (possibly stale) epoch when it can
                // answer this job, else shed outright
                let stale: Option<JobOutput> = opts.degrade_store.as_ref().and_then(|store| {
                    match kind {
                        JobKind::Ktruss { k, .. } if k == store.k() => {
                            let snap = store.pin();
                            Some(JobOutput::Ktruss {
                                truss_edges: snap.truss.nnz(),
                                iterations: 0,
                                edges: snap.truss.edges().collect(),
                            })
                        }
                        _ => None,
                    }
                });
                let (outcome, output) = match stale {
                    Some(out) => (JobOutcome::Degraded, Ok(out)),
                    None => (
                        JobOutcome::Shed,
                        Err(format!(
                            "shed at admission: predicted {predicted_ms:.3}ms \
                             (after ~{wait_ms:.3}ms queue wait) cannot meet the deadline"
                        )),
                    ),
                };
                self.metrics.record_submit();
                match outcome {
                    JobOutcome::Degraded => self.metrics.record_degraded(),
                    _ => self.metrics.record_shed(),
                }
                let span = crate::obs::span::JobSpan {
                    id,
                    kind: kind_label(&kind).to_string(),
                    n: graph.n(),
                    m: graph.nnz(),
                    shard: 0,
                    schedule: plan.map(|p| p.schedule.to_string()).unwrap_or_else(|| "-".into()),
                    granularity: plan
                        .map(|p| p.granularity.to_string())
                        .unwrap_or_else(|| "-".into()),
                    support: plan.map(|p| p.support.to_string()).unwrap_or_else(|| "-".into()),
                    device: plan.map(|p| p.device.to_string()).unwrap_or_else(|| "-".into()),
                    est_steps,
                    total_steps: 0,
                    predicted_ms,
                    planned_pass_ms,
                    queue_ms: 0.0,
                    exec_ms: 0.0,
                    serve_ms: 0.0,
                    deadline_ms: opts.deadline.map(|d| d.as_secs_f64() * 1e3),
                    deadline_missed: false,
                    start_us: self.obs.spans.now_us(),
                    ok: output.is_ok(),
                    outcome: outcome.to_string(),
                    passes: vec![],
                };
                self.obs.spans.record(span);
                let _ = rtx.send(JobResult {
                    id,
                    engine: Engine::SparseCpu,
                    plan,
                    schedule: plan.map(|p| p.schedule),
                    support: plan.map(|p| p.support),
                    wall_ms: 0.0,
                    passes: vec![],
                    outcome,
                    output,
                });
                return Ok(Ticket { id, rx: rrx });
            }
            AdmissionDecision::Admit => {}
        }
        let fingerprint = job_fingerprint(&kind, &graph);
        let now = Instant::now();
        let adm = Admission {
            req: JobRequest { id, graph, kind },
            priority: opts.priority,
            deadline: opts.deadline.map(|d| now + d),
            submitted: now,
            est_steps,
            plan,
            predicted_ms,
            planned_pass_ms,
            attempts: 0,
            fingerprint,
            reply: rtx,
        };
        let down = {
            let mut st = lock_recover(&self.adm.state);
            if st.shutdown {
                true
            } else {
                st.queue.push(adm);
                false
            }
        };
        if down {
            return Err(SubmitError::Down);
        }
        self.metrics.record_submit();
        self.adm.cv.notify_all();
        Ok(Ticket { id, rx: rrx })
    }

    /// Graceful shutdown: queued jobs are still dispatched and executed
    /// before the shards exit. Also triggered by `Drop`. Idempotent.
    pub fn shutdown(&self) {
        {
            let mut st = lock_recover(&self.adm.state);
            st.shutdown = true;
        }
        self.adm.cv.notify_all();
        if let Some(h) = lock_recover(&self.dispatcher).take() {
            let _ = h.join();
        }
        for h in lock_recover(&self.shard_handles).drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Executor {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Shape fingerprint keying the poison-job registry: jobs that look
/// the same (kind, k, graph size) share a retry budget, so a
/// persistently panicking workload is quarantined as a class instead
/// of burning the budget once per identical submission.
fn job_fingerprint(kind: &JobKind, graph: &Csr) -> u64 {
    let mut state = (graph.n() as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ graph.nnz() as u64;
    for &b in kind_label(kind).as_bytes() {
        state = state.wrapping_mul(0x0100_0000_01B3).wrapping_add(b as u64);
    }
    if let JobKind::Ktruss { k, .. } = kind {
        state = state.wrapping_add(u64::from(*k));
    }
    crate::util::rng::splitmix64(&mut state)
}

/// Dispatcher: drain the admission queue in batches (the queue is
/// already priority/EDF-sorted), pack each batch into equal
/// estimated-work shard assignments, and hand them to the shards.
fn dispatch_loop(
    cfg: ServeConfig,
    adm: &AdmissionShared,
    shards: &ShardShared,
    metrics: &Metrics,
) {
    loop {
        let batch = {
            let mut st = lock_recover(&adm.state);
            while st.queue.is_empty() && !st.shutdown {
                st = adm.cv.wait(st).unwrap_or_else(|p| p.into_inner());
            }
            if st.queue.is_empty() && st.shutdown {
                break;
            }
            // accumulate up to max_batch within the window (skipped
            // when shutting down: drain as fast as possible)
            let deadline = Instant::now() + cfg.batch_window;
            while st.queue.len() < cfg.max_batch && !st.shutdown {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, _) = adm
                    .cv
                    .wait_timeout(st, deadline - now)
                    .unwrap_or_else(|p| p.into_inner());
                st = guard;
            }
            st.queue.take_front(cfg.max_batch)
        };
        if batch.is_empty() {
            continue;
        }
        // Pack the batch into approximately equal-work shard
        // assignments. Placement decides only *where* a job runs; each
        // shard's queue re-sorts by urgency, so it never changes *when*
        // a job runs relative to its queue peers.
        let costs: Vec<u64> = batch.iter().map(|a| a.est_steps).collect();
        {
            let mut st = lock_recover(&shards.state);
            // baseline = queued work + the job each shard is executing
            // right now, so a shard blocked on a heavy job with an
            // empty queue does not look idle
            let baseline: Vec<u64> = st
                .queues
                .iter()
                .enumerate()
                .map(|(w, q)| q.queued_steps() + shards.inflight[w].load(Ordering::Relaxed))
                .collect();
            let assignment = pack_batch(&costs, &baseline);
            for (a, &w) in batch.into_iter().zip(assignment.iter()) {
                st.queues[w].push(a);
            }
            for w in 0..st.queues.len() {
                metrics.set_queue_depth(w, st.queues[w].len() as u64);
            }
        }
        shards.work_cv.notify_all();
    }
    {
        let mut st = lock_recover(&shards.state);
        st.dispatch_done = true;
    }
    shards.work_cv.notify_all();
}

/// Equal-work batch packing: walk the urgency-ordered batch and place
/// each job on the currently least-loaded shard (existing queue
/// backlog plus work assigned earlier in this batch) — the job-level
/// analogue of the support pass's equal-work binning, with the
/// prefix-sum quantile search replaced by a running argmin. The
/// quantile form was deliberately **not** reused here: contiguous bins
/// over an urgency-sorted batch hand each shard one contiguous urgency
/// band (every High job on shard 0, every Low on shard N−1), so the
/// most urgent work would serialize on a single shard. Greedy
/// least-loaded keeps shard work equal to within one job (the classic
/// LPT bound) while striping each urgency class across shards.
///
/// Returns one shard index per batch entry. `baseline[w]` is shard
/// `w`'s already-queued estimated work.
fn pack_batch(costs: &[u64], baseline: &[u64]) -> Vec<usize> {
    let mut load = baseline.to_vec();
    let mut assignment = Vec::with_capacity(costs.len());
    for &c in costs {
        let mut best = 0usize;
        for (w, &l) in load.iter().enumerate() {
            if l < load[best] {
                best = w;
            }
        }
        load[best] += c.max(1);
        assignment.push(best);
    }
    assignment
}

/// Shard supervisor: run the shard body under `catch_unwind` and
/// respawn it in place when it panics past the per-job isolation (the
/// injected `shard_crash` site, or a real bug outside the exec
/// `catch_unwind`). The crashed body's in-flight admission — stashed
/// at pop time — is requeued with its attempt count bumped, so a
/// shard crash delays a job instead of losing it.
fn shard_supervisor(
    me: usize,
    cfg: ServeConfig,
    shards: &ShardShared,
    metrics: &Metrics,
    cost_model: &CostModel,
    obs: &crate::obs::ObsHub,
    faults: Option<&Arc<FaultInjector>>,
) {
    loop {
        let body = catch_unwind(AssertUnwindSafe(|| {
            shard_body(me, cfg, shards, metrics, cost_model, obs, faults)
        }));
        match body {
            Ok(()) => return,
            Err(_) => {
                metrics.record_respawn(me);
                shards.inflight[me].store(0, Ordering::Relaxed);
                let stashed = lock_recover(&shards.stash[me]).take();
                if let Some(mut adm) = stashed {
                    adm.attempts += 1;
                    {
                        let mut st = lock_recover(&shards.state);
                        st.queues[me].push(adm);
                        metrics.set_queue_depth(me, st.queues[me].len() as u64);
                    }
                    shards.work_cv.notify_all();
                }
            }
        }
    }
}

/// Deliver a terminal result for a job that never executed (shed at
/// the shard for quarantine, or cancelled before start): record a
/// zero-execution span and send the synthetic [`JobResult`].
fn reply_without_exec(
    me: usize,
    adm: &Admission,
    outcome: JobOutcome,
    output: Result<JobOutput, String>,
    obs: &crate::obs::ObsHub,
) {
    let elapsed_ms = adm.submitted.elapsed().as_secs_f64() * 1e3;
    let span = crate::obs::span::JobSpan {
        id: adm.req.id,
        kind: kind_label(&adm.req.kind).to_string(),
        n: adm.req.graph.n(),
        m: adm.req.graph.nnz(),
        shard: me,
        schedule: adm.plan.map(|p| p.schedule.to_string()).unwrap_or_else(|| "-".into()),
        granularity: adm.plan.map(|p| p.granularity.to_string()).unwrap_or_else(|| "-".into()),
        support: adm.plan.map(|p| p.support.to_string()).unwrap_or_else(|| "-".into()),
        device: adm.plan.map(|p| p.device.to_string()).unwrap_or_else(|| "-".into()),
        est_steps: adm.est_steps,
        total_steps: 0,
        predicted_ms: adm.predicted_ms,
        planned_pass_ms: adm.planned_pass_ms,
        queue_ms: elapsed_ms,
        exec_ms: 0.0,
        serve_ms: elapsed_ms,
        deadline_ms: adm
            .deadline
            .map(|d| d.saturating_duration_since(adm.submitted).as_secs_f64() * 1e3),
        deadline_missed: adm.deadline.is_some_and(|d| Instant::now() > d),
        start_us: obs.spans.now_us(),
        ok: output.is_ok(),
        outcome: outcome.to_string(),
        passes: vec![],
    };
    obs.spans.record(span);
    let _ = adm.reply.send(JobResult {
        id: adm.req.id,
        engine: Engine::SparseCpu,
        plan: adm.plan,
        schedule: adm.plan.map(|p| p.schedule),
        support: adm.plan.map(|p| p.support),
        wall_ms: 0.0,
        passes: vec![],
        outcome,
        output,
    });
}

/// One shard body: pop the most urgent job from the own queue, steal
/// the globally most urgent queued job from the other shards when
/// drained, execute under per-job panic isolation (retry → quarantine
/// on panic), account, record the job span, reply. Exits when dispatch
/// is complete and every queue is empty. Runs under
/// [`shard_supervisor`]'s respawn loop.
fn shard_body(
    me: usize,
    cfg: ServeConfig,
    shards: &ShardShared,
    metrics: &Metrics,
    cost_model: &CostModel,
    obs: &crate::obs::ObsHub,
    faults: Option<&Arc<FaultInjector>>,
) {
    let dense = if cfg.enable_dense { DenseEngine::new().ok() } else { None };
    let router_cfg = dense
        .as_ref()
        .map(|d| RouterConfig::new(d.max_n()).with_step_ceiling(cfg.dense_step_ceiling))
        .unwrap_or_else(RouterConfig::disabled);
    let width = cfg.workers_per_shard + usize::from(me < cfg.workers_remainder);
    let worker = Worker::with_spec(Pool::new(width), dense, cfg.plan);
    loop {
        let adm = {
            let mut st = lock_recover(&shards.state);
            loop {
                if let Some(a) = st.queues[me].pop_front() {
                    // publish in-flight work inside the critical
                    // section: the dispatcher must never observe an
                    // empty queue AND a zero inflight for a shard that
                    // just took a heavy job — and the stash must hold
                    // the job before anything after the pop can panic
                    shards.inflight[me].store(a.est_steps.max(1), Ordering::Relaxed);
                    metrics.set_queue_depth(me, st.queues[me].len() as u64);
                    *lock_recover(&shards.stash[me]) = Some(a.clone());
                    break Some(a);
                }
                if cfg.steal {
                    // steal the globally most urgent queued job: this
                    // shard is idle and executes it immediately, so
                    // the steal strictly advances the most urgent
                    // waiting work, wherever estimation error or a
                    // long-running job stranded it
                    let mut victim: Option<usize> = None;
                    let mut best: Option<super::queue::UrgencyKey> = None;
                    for (i, q) in st.queues.iter().enumerate() {
                        if i == me {
                            continue;
                        }
                        if let Some(front) = q.peek_front() {
                            let key = front.key();
                            let more_urgent = match best {
                                None => true,
                                Some(b) => key < b,
                            };
                            if more_urgent {
                                best = Some(key);
                                victim = Some(i);
                            }
                        }
                    }
                    if let Some(v) = victim {
                        if let Some(a) = st.queues[v].pop_front() {
                            shards.inflight[me].store(a.est_steps.max(1), Ordering::Relaxed);
                            metrics.record_steal(me);
                            metrics.set_queue_depth(v, st.queues[v].len() as u64);
                            *lock_recover(&shards.stash[me]) = Some(a.clone());
                            break Some(a);
                        }
                    }
                }
                if st.dispatch_done && st.queues.iter().all(|q| q.is_empty()) {
                    break None;
                }
                // timeout bounds the window between a dispatch-done
                // store and this shard's re-check
                let (guard, _) = shards
                    .work_cv
                    .wait_timeout(st, Duration::from_millis(20))
                    .unwrap_or_else(|p| p.into_inner());
                st = guard;
            }
        };
        let Some(adm) = adm else {
            return;
        };
        // fault site `shard_crash`: panics here unwind past the job,
        // out of shard_body — the supervisor respawns and requeues
        if let Some(inj) = faults {
            inj.maybe_crash_shard(adm.req.id, adm.attempts);
        }
        // poison pre-check: a fingerprint past its retry budget is
        // quarantined on sight, before burning a pool on it again
        let poison_count =
            lock_recover(&shards.poison).get(&adm.fingerprint).copied().unwrap_or(0);
        if poison_count > cfg.retry_max {
            shards.inflight[me].store(0, Ordering::Relaxed);
            lock_recover(&shards.stash[me]).take();
            metrics.record_quarantined();
            metrics.record_shard_done(me);
            reply_without_exec(
                me,
                &adm,
                JobOutcome::Quarantined,
                Err(format!(
                    "quarantined: shape panicked {poison_count} times (retry budget {})",
                    cfg.retry_max
                )),
                obs,
            );
            continue;
        }
        // deadline enforcement, pre-execution: a job whose deadline
        // already passed in the queue is not worth starting
        if cfg.shed && adm.deadline.is_some_and(|d| Instant::now() >= d) {
            shards.inflight[me].store(0, Ordering::Relaxed);
            lock_recover(&shards.stash[me]).take();
            metrics.record_cancelled(me);
            metrics.record_deadline_miss(me);
            metrics.record_shard_done(me);
            reply_without_exec(
                me,
                &adm,
                JobOutcome::Cancelled,
                Err("cancelled before start: deadline passed in queue".to_string()),
                obs,
            );
            continue;
        }
        let queue_ms = adm.submitted.elapsed().as_secs_f64() * 1e3;
        let start_us = obs.spans.now_us();
        let engine = route_costed(&router_cfg, &adm.req, adm.est_steps);
        // deadline enforcement, in-flight: arm a deadline token so the
        // convergence loop cancels cooperatively at a pass boundary
        let cancel = if cfg.shed { adm.deadline.map(CancelToken::with_deadline) } else { None };
        let job_id = adm.req.id;
        // fault site `stall`: ride the pass-boundary hook
        let stall_hook = faults.map(|inj| {
            let inj = Arc::clone(inj);
            move |_iter: usize| inj.maybe_stall(job_id)
        });
        let ctl = PassControl {
            cancel: cancel.as_ref(),
            on_pass: stall_hook.as_ref().map(|h| h as &(dyn Fn(usize) + Sync)),
        };
        // run under the submit-time plan (the worker never replans),
        // panic-isolated: a panicking job must not take the shard down
        let exec = catch_unwind(AssertUnwindSafe(|| {
            // fault site `exec_panic`: inside the per-job isolation
            if let Some(inj) = faults {
                inj.maybe_panic_exec(job_id, adm.attempts);
            }
            worker.execute_planned_ctl(&adm.req, engine, adm.plan, ctl)
        }));
        shards.inflight[me].store(0, Ordering::Relaxed);
        lock_recover(&shards.stash[me]).take();
        let result = match exec {
            Ok(result) => result,
            Err(_) => {
                // panic isolated: bump the shape's poison count, then
                // retry with backoff or quarantine
                let count = {
                    let mut poison = lock_recover(&shards.poison);
                    let c = poison.entry(adm.fingerprint).or_insert(0);
                    *c += 1;
                    *c
                };
                if count <= cfg.retry_max {
                    metrics.record_retry();
                    // exponential backoff, capped at 16ms: transient
                    // faults (a racing mutation, an allocator hiccup)
                    // deserve a beat before the retry
                    std::thread::sleep(Duration::from_millis(1u64 << (count - 1).min(4)));
                    let mut requeued = adm;
                    requeued.attempts += 1;
                    {
                        let mut st = lock_recover(&shards.state);
                        st.queues[me].push(requeued);
                        metrics.set_queue_depth(me, st.queues[me].len() as u64);
                    }
                    shards.work_cv.notify_all();
                } else {
                    metrics.record_quarantined();
                    metrics.record_shard_done(me);
                    reply_without_exec(
                        me,
                        &adm,
                        JobOutcome::Quarantined,
                        Err(format!(
                            "quarantined: shape panicked {count} times (retry budget {})",
                            cfg.retry_max
                        )),
                        obs,
                    );
                }
                continue;
            }
        };
        if result.output.is_ok() {
            // self-healing: a completed shape is no longer poisoned
            lock_recover(&shards.poison).remove(&adm.fingerprint);
        }
        // metrics record the *serving* latency (queueing + execution);
        // JobResult::wall_ms stays execution-only
        let serve_ms = adm.submitted.elapsed().as_secs_f64() * 1e3;
        let ok = result.output.is_ok();
        let cancelled = result.outcome == JobOutcome::Cancelled;
        if cancelled {
            metrics.record_cancelled(me);
        } else {
            metrics.record_done(result.engine, serve_ms, ok);
        }
        metrics.record_shard_done(me);
        let deadline_missed = adm.deadline.is_some_and(|d| Instant::now() > d);
        if deadline_missed {
            metrics.record_deadline_miss(me);
        }
        if ok {
            let label = job_label(&adm.req.kind, result.support);
            let (n, nnz) = (adm.req.graph.n(), adm.req.graph.nnz());
            // calibrate under the label of what actually ran: truss
            // jobs carry their support-mode provenance, so incremental
            // and full iteration profiles stay in separate EWMAs —
            // planned jobs additionally retain the executed plan axes
            // in their trace record (drift baselines across restarts)
            match &result.plan {
                Some(p) => cost_model
                    .observe_planned(&label, n, nnz, adm.est_steps, result.wall_ms, p),
                None => {
                    cost_model.observe_labeled(&label, n, nnz, adm.est_steps, result.wall_ms)
                }
            }
        }
        let span = crate::obs::span::JobSpan {
            id: adm.req.id,
            kind: kind_label(&adm.req.kind).to_string(),
            n: adm.req.graph.n(),
            m: adm.req.graph.nnz(),
            shard: me,
            schedule: result
                .plan
                .map(|p| p.schedule.to_string())
                .unwrap_or_else(|| "-".to_string()),
            granularity: result
                .plan
                .map(|p| p.granularity.to_string())
                .unwrap_or_else(|| "-".to_string()),
            support: result
                .plan
                .map(|p| p.support.to_string())
                .unwrap_or_else(|| "-".to_string()),
            device: result
                .plan
                .map(|p| p.device.to_string())
                .unwrap_or_else(|| "-".to_string()),
            est_steps: adm.est_steps,
            total_steps: result.passes.iter().map(|p| p.steps).sum(),
            predicted_ms: adm.predicted_ms,
            planned_pass_ms: adm.planned_pass_ms,
            queue_ms,
            exec_ms: result.wall_ms,
            serve_ms,
            deadline_ms: adm
                .deadline
                .map(|d| d.saturating_duration_since(adm.submitted).as_secs_f64() * 1e3),
            deadline_missed,
            start_us,
            ok,
            outcome: result.outcome.to_string(),
            passes: result.passes.clone(),
        };
        // drift joins the admission-time prediction against the
        // measured execution wall, keyed by the executed plan regime
        if ok && result.plan.is_some() {
            obs.drift.observe(&span.plan_string(), adm.predicted_ms, result.wall_ms);
        }
        obs.spans.record(span);
        let _ = adm.reply.send(result);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::support::Mode;
    use crate::graph::builder::from_sorted_unique;

    fn cfg(shards: usize, workers: usize) -> ServeConfig {
        ServeConfig {
            shards,
            workers_per_shard: workers,
            enable_dense: false,
            ..Default::default()
        }
    }

    #[test]
    fn single_shard_roundtrip() {
        let ex = Executor::start(cfg(1, 2));
        let g = Arc::new(from_sorted_unique(4, &[(0, 1), (0, 2), (0, 3), (1, 2), (2, 3)]));
        let t = ex.submit(Arc::clone(&g), JobKind::Ktruss { k: 3, mode: Mode::Fine });
        match t.wait().output.unwrap() {
            JobOutput::Ktruss { truss_edges, .. } => assert_eq!(truss_edges, 5),
            other => panic!("{other:?}"),
        }
        ex.shutdown();
    }

    #[test]
    fn multi_shard_mixed_jobs_all_complete() {
        let ex = Executor::start(cfg(3, 1));
        let g = Arc::new(crate::gen::erdos_renyi::gnm(120, 500, &mut crate::util::Rng::new(2)));
        let want_tri = crate::algo::triangle::count_triangles(&g);
        let tickets: Vec<Ticket> = (0..12)
            .map(|i| {
                let kind = match i % 3 {
                    0 => JobKind::Triangles,
                    1 => JobKind::Ktruss { k: 3, mode: Mode::Fine },
                    _ => JobKind::Kmax,
                };
                ex.submit(Arc::clone(&g), kind)
            })
            .collect();
        for t in tickets {
            let r = t.wait();
            if let JobOutput::Triangles { count } = r.output.unwrap() {
                assert_eq!(count, want_tri);
            }
        }
        let (done, failed, _) = ex.metrics.summary();
        assert_eq!((done, failed), (12, 0));
        // every executed job is attributed to exactly one shard
        let per_shard: u64 =
            ex.metrics.shards().iter().map(|s| s.jobs.load(Ordering::Relaxed)).sum();
        assert_eq!(per_shard, 12);
        ex.shutdown();
    }

    #[test]
    fn shutdown_executes_already_queued_jobs() {
        let ex = Executor::start(cfg(2, 1));
        let g = Arc::new(from_sorted_unique(3, &[(0, 1), (1, 2)]));
        let tickets: Vec<Ticket> =
            (0..6).map(|_| ex.submit(Arc::clone(&g), JobKind::Triangles)).collect();
        ex.shutdown(); // must drain, not drop
        for t in tickets {
            assert!(t.wait().output.is_ok());
        }
    }

    #[test]
    fn shutdown_is_idempotent() {
        let ex = Executor::start(cfg(2, 1));
        ex.shutdown();
        ex.shutdown();
    }

    #[test]
    fn ids_are_unique_and_monotone() {
        let ex = Executor::start(cfg(2, 1));
        let g = Arc::new(from_sorted_unique(3, &[(0, 1), (1, 2)]));
        let t1 = ex.submit(Arc::clone(&g), JobKind::Triangles);
        let t2 = ex.submit(Arc::clone(&g), JobKind::Triangles);
        assert!(t2.id > t1.id);
        t1.wait();
        t2.wait();
        ex.shutdown();
    }

    #[test]
    fn pack_batch_stripes_urgency_and_balances_work() {
        // equal-cost jobs (urgency-sorted: the first half is the High
        // class) must stripe across shards, not band onto shard 0
        let assignment = pack_batch(&[5, 5, 5, 5], &[0, 0]);
        assert_eq!(assignment, vec![0, 1, 0, 1]);
        // a heavy head job occupies one shard; the tail shares the rest
        let assignment = pack_batch(&[100, 1, 1, 1], &[0, 0]);
        assert_eq!(assignment[0], 0);
        assert!(assignment[1..].iter().all(|&w| w == 1));
        // existing backlog steers new work to the idle shard
        let assignment = pack_batch(&[3, 3], &[50, 0]);
        assert_eq!(assignment, vec![1, 1]);
        // load stays equal to within one job on skewed input
        let costs = [9u64, 7, 5, 4, 3, 2, 2, 1];
        let assignment = pack_batch(&costs, &[0, 0, 0]);
        let mut load = [0u64; 3];
        for (i, &w) in assignment.iter().enumerate() {
            load[w] += costs[i];
        }
        let max = *load.iter().max().unwrap();
        let min = *load.iter().min().unwrap();
        assert!(max - min <= 9, "loads {load:?}");
    }

    #[test]
    fn uneven_worker_budget_is_fully_distributed() {
        // 5 total workers over 2 shards: shard 0 gets 3, shard 1 gets 2
        let ex = Executor::start(ServeConfig {
            workers_per_shard: 2,
            workers_remainder: 1,
            ..cfg(2, 2)
        });
        let g = Arc::new(crate::gen::erdos_renyi::gnm(150, 700, &mut crate::util::Rng::new(8)));
        let want = crate::algo::triangle::count_triangles(&g);
        let tickets: Vec<Ticket> =
            (0..6).map(|_| ex.submit(Arc::clone(&g), JobKind::Triangles)).collect();
        for t in tickets {
            match t.wait().output.unwrap() {
                JobOutput::Triangles { count } => assert_eq!(count, want),
                other => panic!("{other:?}"),
            }
        }
        ex.shutdown();
    }

    #[test]
    fn submit_time_plan_is_carried_to_the_result() {
        let ex = Executor::start(cfg(1, 2));
        let g = Arc::new(crate::gen::rmat::rmat(
            600,
            4000,
            crate::gen::rmat::RmatParams::social(),
            &mut crate::util::Rng::new(17),
        ));
        let r = ex
            .submit(Arc::clone(&g), JobKind::Ktruss { k: 3, mode: Mode::Fine })
            .wait();
        let plan = r.plan.expect("truss jobs carry their submit-time plan");
        assert_eq!(r.schedule, Some(plan.schedule));
        assert_eq!(r.support, Some(plan.support));
        // non-truss kinds are not planned
        let r = ex.submit(g, JobKind::Triangles).wait();
        assert!(r.plan.is_none());
        ex.shutdown();
    }

    #[test]
    fn pinned_plan_spec_applies_to_every_job() {
        let spec: PlanSpec = "stealing/fine/auto".parse().unwrap();
        let ex = Executor::start(ServeConfig { plan: spec, ..cfg(2, 1) });
        let g = Arc::new(crate::gen::erdos_renyi::gnm(150, 700, &mut crate::util::Rng::new(9)));
        for _ in 0..3 {
            let r = ex
                .submit(Arc::clone(&g), JobKind::Ktruss { k: 3, mode: Mode::Fine })
                .wait();
            let plan = r.plan.unwrap();
            assert_eq!(plan.schedule, crate::par::Schedule::Stealing);
            assert_eq!(plan.granularity, crate::algo::support::Granularity::Fine);
            assert!(r.output.is_ok());
        }
        ex.shutdown();
    }

    #[test]
    fn job_spans_carry_exact_steps_and_predictions() {
        let ex = Executor::start(cfg(1, 2));
        let g = Arc::new(crate::gen::rmat::rmat(
            400,
            2500,
            crate::gen::rmat::RmatParams::social(),
            &mut crate::util::Rng::new(23),
        ));
        let r = ex
            .submit(Arc::clone(&g), JobKind::Ktruss { k: 3, mode: Mode::Fine })
            .wait();
        assert!(r.output.is_ok());
        let t = ex.submit(Arc::clone(&g), JobKind::Triangles).wait();
        assert!(t.output.is_ok());
        let spans = ex.obs.spans.snapshot();
        assert_eq!(spans.len(), 2);
        let truss = spans.iter().find(|s| s.kind == "ktruss").unwrap();
        // span step totals are exact: the pass spans sum to the job's
        // total, which equals the result's own measured step count
        assert!(!truss.passes.is_empty());
        assert_eq!(
            truss.passes.iter().map(|p| p.steps).sum::<u64>(),
            truss.total_steps
        );
        assert!(truss.total_steps > 0);
        let plan = r.plan.unwrap();
        assert_eq!(truss.plan_string(), format!("{}/{}", plan.device, plan));
        assert!(truss.predicted_ms > 0.0);
        assert!(truss.planned_pass_ms.is_some());
        assert!(truss.exec_ms >= 0.0 && truss.serve_ms >= truss.exec_ms);
        assert!(truss.ok);
        // unplanned kinds record a span too, with placeholder axes
        let tri = spans.iter().find(|s| s.kind == "triangles").unwrap();
        assert_eq!(tri.plan_string(), "-/-/-/-");
        assert!(tri.passes.is_empty());
        assert!(tri.planned_pass_ms.is_none());
        // the planned job fed the drift tracker under its plan regime
        let drift = ex.obs.drift.snapshot();
        assert_eq!(drift.len(), 1);
        assert_eq!(drift[0].plan, truss.plan_string());
        assert_eq!(drift[0].samples, 1);
        ex.shutdown();
    }

    #[test]
    fn seeded_model_with_provenance_seeds_drift_baselines() {
        let donor = Executor::start(cfg(1, 1));
        let g = Arc::new(crate::gen::erdos_renyi::gnm(120, 600, &mut crate::util::Rng::new(5)));
        donor
            .submit(Arc::clone(&g), JobKind::Ktruss { k: 3, mode: Mode::Fine })
            .wait();
        let records = donor.cost_model.records();
        donor.shutdown();
        assert!(records.iter().any(|r| r.has_provenance()));
        let ex = Executor::start_with_model(cfg(1, 1), CostModel::from_records(&records));
        assert!(
            !ex.obs.drift.snapshot().is_empty(),
            "drift baselines must survive a restart via persisted provenance"
        );
        ex.shutdown();
    }

    #[test]
    fn cost_model_learns_from_served_jobs() {
        let ex = Executor::start(cfg(1, 1));
        let g = Arc::new(crate::gen::erdos_renyi::gnm(100, 300, &mut crate::util::Rng::new(4)));
        for _ in 0..3 {
            ex.submit(Arc::clone(&g), JobKind::Triangles).wait();
        }
        assert!(ex.cost_model.samples() >= 3);
        assert!(ex.cost_model.ns_per_step() > 0.0);
        assert!(!ex.cost_model.records().is_empty());
        ex.shutdown();
    }

    // ---- fault tolerance ------------------------------------------

    #[test]
    fn submit_after_shutdown_panics_but_drop_stays_clean() {
        let ex = Executor::start(cfg(1, 1));
        ex.shutdown();
        let g = Arc::new(from_sorted_unique(3, &[(0, 1), (1, 2)]));
        let caught = catch_unwind(AssertUnwindSafe(|| {
            ex.submit(Arc::clone(&g), JobKind::Triangles);
        }));
        assert!(caught.is_err(), "submitting to a down executor must panic");
        // the panic fired with every executor lock released: shutdown
        // (and Drop) re-take the admission lock without a double panic
        ex.shutdown();
        drop(ex);
    }

    #[test]
    fn try_submit_reports_down_as_an_error() {
        let ex = Executor::start(cfg(1, 1));
        ex.shutdown();
        let g = Arc::new(from_sorted_unique(3, &[(0, 1), (1, 2)]));
        let err = ex.try_submit_with(g, JobKind::Triangles, SubmitOpts::default()).unwrap_err();
        assert_eq!(err, SubmitError::Down);
    }

    #[test]
    fn bounded_queue_rejects_overload_with_backpressure() {
        let ex = Executor::start(ServeConfig { max_queue: 2, steal: false, ..cfg(1, 1) });
        let g = Arc::new(crate::gen::erdos_renyi::gnm(300, 2000, &mut crate::util::Rng::new(3)));
        let mut accepted = Vec::new();
        let mut rejected = None;
        for _ in 0..50 {
            match ex.try_submit_with(Arc::clone(&g), JobKind::Decompose, SubmitOpts::default()) {
                Ok(t) => accepted.push(t),
                Err(e) => {
                    rejected = Some(e);
                    break;
                }
            }
        }
        let rejected =
            rejected.expect("50 heavy submits against a backlog bound of 2 must hit backpressure");
        assert_eq!(rejected, SubmitError::QueueFull { max_queue: 2 });
        assert!(ex.metrics.queue_rejected.load(Ordering::Relaxed) >= 1);
        // accepted jobs are unaffected by the rejection
        for t in accepted {
            assert!(t.wait().output.is_ok());
        }
        ex.shutdown();
    }

    #[test]
    fn doomed_low_jobs_are_shed_at_admission() {
        let ex = Executor::start(ServeConfig { shed: true, ..cfg(1, 1) });
        let g = Arc::new(crate::gen::erdos_renyi::gnm(200, 1200, &mut crate::util::Rng::new(7)));
        let doomed = SubmitOpts {
            priority: Priority::Low,
            deadline: Some(Duration::ZERO),
            degrade_store: None,
        };
        let r = ex
            .submit_with(Arc::clone(&g), JobKind::Ktruss { k: 3, mode: Mode::Fine }, doomed)
            .wait();
        assert_eq!(r.outcome, JobOutcome::Shed);
        assert!(r.output.is_err());
        assert_eq!(ex.metrics.shed.load(Ordering::Relaxed), 1);
        let span = ex.obs.spans.snapshot().into_iter().find(|s| s.id == r.id).unwrap();
        assert_eq!(span.outcome, "shed");
        assert_eq!(span.total_steps, 0);
        // a High job with the same impossible deadline is protected
        // from shedding and still runs
        let protected = SubmitOpts {
            priority: Priority::High,
            deadline: Some(Duration::from_secs(600)),
            degrade_store: None,
        };
        let r = ex.submit_with(g, JobKind::Ktruss { k: 3, mode: Mode::Fine }, protected).wait();
        assert_eq!(r.outcome, JobOutcome::Done);
        assert!(r.output.is_ok());
        ex.shutdown();
    }

    #[test]
    fn doomed_low_jobs_degrade_to_a_stale_epoch_when_a_store_is_supplied() {
        let g = Arc::new(crate::gen::erdos_renyi::gnm(150, 900, &mut crate::util::Rng::new(11)));
        let store = Arc::new(GraphStore::new(&g, 3));
        let expected = store.pin().truss.nnz();
        let ex = Executor::start(ServeConfig { shed: true, ..cfg(1, 1) });
        let opts = SubmitOpts {
            priority: Priority::Low,
            deadline: Some(Duration::ZERO),
            degrade_store: Some(Arc::clone(&store)),
        };
        let r = ex
            .submit_with(Arc::clone(&g), JobKind::Ktruss { k: 3, mode: Mode::Fine }, opts.clone())
            .wait();
        assert_eq!(r.outcome, JobOutcome::Degraded);
        match r.output.unwrap() {
            JobOutput::Ktruss { truss_edges, iterations, .. } => {
                assert_eq!(truss_edges, expected);
                assert_eq!(iterations, 0, "a degraded answer computes nothing");
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(ex.metrics.degraded.load(Ordering::Relaxed), 1);
        // the store cannot answer a different k: the job sheds instead
        let r = ex.submit_with(g, JobKind::Ktruss { k: 5, mode: Mode::Fine }, opts).wait();
        assert_eq!(r.outcome, JobOutcome::Shed);
        assert_eq!(ex.metrics.shed.load(Ordering::Relaxed), 1);
        ex.shutdown();
    }

    #[test]
    fn injected_panics_are_isolated_retried_and_healed() {
        let faults =
            FaultPlan { seed: 5, exec_panic_every: 1, transient: true, ..FaultPlan::default() };
        let ex = Executor::start(ServeConfig { faults: Some(faults), retry_max: 2, ..cfg(2, 1) });
        // distinct graphs → distinct fingerprints, so concurrent
        // panics never pool into one shape's retry budget
        let tickets: Vec<Ticket> = (0..6)
            .map(|i| {
                let g = Arc::new(crate::gen::erdos_renyi::gnm(
                    60 + i * 10,
                    150 + i * 30,
                    &mut crate::util::Rng::new(i as u64 + 1),
                ));
                ex.submit(g, JobKind::Ktruss { k: 3, mode: Mode::Fine })
            })
            .collect();
        for t in tickets {
            let r = t.wait();
            assert_eq!(r.outcome, JobOutcome::Done);
            assert!(r.output.is_ok());
        }
        // every job panicked once (1-in-1 transient plan), retried
        // once, healed; the shards themselves never went down
        assert_eq!(ex.metrics.retries.load(Ordering::Relaxed), 6);
        assert_eq!(ex.metrics.quarantined.load(Ordering::Relaxed), 0);
        let inj = ex.faults.as_ref().expect("active plan builds an injector");
        assert_eq!(inj.exec_panics.load(Ordering::Relaxed), 6);
        assert_eq!(ex.metrics.respawns(), 0);
        ex.shutdown();
    }

    #[test]
    fn persistent_panics_quarantine_the_job_shape() {
        let faults =
            FaultPlan { seed: 3, exec_panic_every: 1, transient: false, ..FaultPlan::default() };
        let ex = Executor::start(ServeConfig { faults: Some(faults), retry_max: 1, ..cfg(1, 1) });
        let g = Arc::new(crate::gen::erdos_renyi::gnm(80, 200, &mut crate::util::Rng::new(6)));
        let r = ex.submit(Arc::clone(&g), JobKind::Ktruss { k: 3, mode: Mode::Fine }).wait();
        assert_eq!(r.outcome, JobOutcome::Quarantined);
        assert!(r.output.is_err());
        assert_eq!(ex.metrics.retries.load(Ordering::Relaxed), 1);
        assert_eq!(ex.metrics.quarantined.load(Ordering::Relaxed), 1);
        let panics_after_first =
            ex.faults.as_ref().unwrap().exec_panics.load(Ordering::Relaxed);
        // the shape is now poisoned: a resubmission quarantines at the
        // pre-check, without executing (no further injected panics)
        let r = ex.submit(g, JobKind::Ktruss { k: 3, mode: Mode::Fine }).wait();
        assert_eq!(r.outcome, JobOutcome::Quarantined);
        assert_eq!(
            ex.faults.as_ref().unwrap().exec_panics.load(Ordering::Relaxed),
            panics_after_first
        );
        assert_eq!(ex.metrics.quarantined.load(Ordering::Relaxed), 2);
        ex.shutdown();
    }

    #[test]
    fn shard_crashes_respawn_and_requeue_the_inflight_job() {
        let faults = FaultPlan { seed: 2, shard_crash_every: 1, ..FaultPlan::default() };
        let ex = Executor::start(ServeConfig { faults: Some(faults), ..cfg(1, 1) });
        let g = Arc::new(from_sorted_unique(4, &[(0, 1), (0, 2), (1, 2), (2, 3)]));
        let want = crate::algo::triangle::count_triangles(&g);
        let tickets: Vec<Ticket> =
            (0..3).map(|_| ex.submit(Arc::clone(&g), JobKind::Triangles)).collect();
        for t in tickets {
            let r = t.wait();
            assert_eq!(r.outcome, JobOutcome::Done);
            match r.output.unwrap() {
                JobOutput::Triangles { count } => assert_eq!(count, want),
                other => panic!("{other:?}"),
            }
        }
        // every pop crashed the shard once; the supervisor respawned
        // it and requeued the stashed job, which then ran (the crash
        // site spares requeued attempts)
        assert_eq!(ex.metrics.respawns(), 3);
        assert_eq!(ex.faults.as_ref().unwrap().shard_crashes.load(Ordering::Relaxed), 3);
        let (done, failed, _) = ex.metrics.summary();
        assert_eq!((done, failed), (3, 0));
        ex.shutdown();
    }

    #[test]
    fn stalled_jobs_cancel_at_a_pass_boundary_under_deadline_enforcement() {
        let faults = FaultPlan { seed: 1, stall_every: 1, stall_ms: 150, ..FaultPlan::default() };
        let ex = Executor::start(ServeConfig { shed: true, faults: Some(faults), ..cfg(1, 2) });
        // peel_chain converges over many passes, so the injected stall
        // at a pass boundary pushes the job past its deadline mid-run
        let g = Arc::new(crate::testkit::graphs::peel_chain(24));
        let opts = SubmitOpts {
            priority: Priority::Normal,
            deadline: Some(Duration::from_millis(100)),
            degrade_store: None,
        };
        let r = ex.submit_with(g, JobKind::Ktruss { k: 3, mode: Mode::Fine }, opts).wait();
        assert_eq!(r.outcome, JobOutcome::Cancelled);
        assert!(r.output.is_err());
        assert!(ex.metrics.cancelled.load(Ordering::Relaxed) >= 1);
        assert!(ex.faults.as_ref().unwrap().stalls.load(Ordering::Relaxed) >= 1);
        let span = ex.obs.spans.snapshot().into_iter().find(|s| s.id == r.id).unwrap();
        assert_eq!(span.outcome, "cancelled");
        // a mid-run cancel has completed passes, and the span invariant
        // (pass steps sum to the total) holds for them
        assert!(!span.passes.is_empty(), "cancellation fired mid-run, not before start");
        assert_eq!(span.passes.iter().map(|p| p.steps).sum::<u64>(), span.total_steps);
        ex.shutdown();
    }
}
