//! Priority-aware admission queue for the serving executor.
//!
//! Jobs are ordered by (priority class, earliest soft deadline,
//! submission order): strict priority between classes, EDF within a
//! class, FIFO among jobs of the same class without deadlines. The same
//! queue type backs both the central admission queue and the per-shard
//! run queues, so a shard always executes its most urgent queued job —
//! and an idle thief steals the victim's most urgent job too, which
//! only ever makes that job finish *earlier* than the victim would
//! have managed (the thief runs it immediately; the victim is busy).

use crate::coordinator::job::{JobRequest, JobResult};
use crate::plan::ExecutionPlan;
use std::sync::mpsc::Sender;
use std::time::Instant;

/// Job priority class. Smaller is more urgent (the derived `Ord`
/// follows declaration order).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    /// Latency-sensitive interactive work.
    High,
    /// Default class.
    Normal,
    /// Batch / best-effort work.
    Low,
}

impl std::fmt::Display for Priority {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Priority::High => write!(f, "high"),
            Priority::Normal => write!(f, "normal"),
            Priority::Low => write!(f, "low"),
        }
    }
}

impl std::str::FromStr for Priority {
    type Err = String;

    fn from_str(s: &str) -> Result<Priority, String> {
        match s {
            "high" => Ok(Priority::High),
            "normal" => Ok(Priority::Normal),
            "low" => Ok(Priority::Low),
            other => Err(format!("unknown priority {other:?} (expected high|normal|low)")),
        }
    }
}

/// An admitted job: the request plus its serving envelope (priority,
/// soft deadline, admission timestamp, cost-model estimate, and the
/// reply channel the result is delivered on).
///
/// `Clone` exists for fault tolerance: a shard stashes a clone of the
/// admission it is executing so its supervisor can requeue the job if
/// the shard body panics (cheap — the graph is an `Arc`).
#[derive(Clone)]
pub struct Admission {
    /// The job itself (graph, kind, id).
    pub req: JobRequest,
    /// Urgency class (strict priority between classes).
    pub priority: Priority,
    /// Absolute soft deadline; `None` = best-effort. Misses are counted,
    /// never enforced (the job still runs to completion).
    pub deadline: Option<Instant>,
    /// When the job was admitted (end-to-end latency baseline).
    pub submitted: Instant,
    /// Estimated work in abstract merge steps (see `serve::cost_model`).
    pub est_steps: u64,
    /// The submit-time [`ExecutionPlan`] for sparse truss jobs (`None`
    /// for kinds the planner does not steer). Computed exactly once at
    /// admission and carried to the executing worker, so the per-job
    /// graph scan and candidate scoring are never repeated.
    pub plan: Option<ExecutionPlan>,
    /// The cost model's predicted wall time at admission, in ms
    /// (per-label calibration over `est_steps`) — joined against the
    /// measured wall at completion by the drift accounting
    /// ([`crate::obs::drift`]).
    pub predicted_ms: f64,
    /// The planner's scored per-pass prediction for the chosen plan, in
    /// machine-model ms (`None` when the plan was pinned or the kind is
    /// unplanned). Recorded on the job span for trace inspection.
    pub planned_pass_ms: Option<f64>,
    /// Execution attempts so far: 0 on first dispatch, incremented each
    /// time the job is requeued after a panic (retry) or a shard-body
    /// crash. Bounds the retry loop and lets transient fault injection
    /// spare the retry.
    pub attempts: u32,
    /// Shape fingerprint (kind label, graph size, estimate) keying the
    /// poison-job registry: jobs that repeatedly panic quarantine
    /// every future submission with the same fingerprint.
    pub fingerprint: u64,
    /// Channel the result is delivered on.
    pub reply: Sender<JobResult>,
}

/// The total urgency order: priority class, then deadline-holders
/// (EDF) before best-effort, then admission order. Smaller = more
/// urgent; unique per job (the id component breaks every tie).
pub(crate) type UrgencyKey = (Priority, bool, Instant, u64);

impl Admission {
    /// This job's [`UrgencyKey`] (`JobRequest::id` is assigned
    /// monotonically at submission).
    pub(crate) fn key(&self) -> UrgencyKey {
        (
            self.priority,
            self.deadline.is_none(),
            self.deadline.unwrap_or(self.submitted),
            self.req.id,
        )
    }
}

/// A queue of admissions kept sorted most-urgent-first.
///
/// Insertion is a binary search plus a shift (O(n)) — deliberately
/// simple: serving queues are tens of jobs deep, and the sorted layout
/// gives the most urgent job in O(1) (`pop_front`, also what a thief
/// takes when stealing from another shard's queue).
#[derive(Default)]
pub struct ServeQueue {
    items: Vec<Admission>,
}

impl ServeQueue {
    /// An empty queue.
    pub fn new() -> ServeQueue {
        ServeQueue { items: Vec::new() }
    }

    /// Queued jobs.
    pub fn len(&self) -> usize {
        self.items.len()
    }

    /// Whether no jobs are queued.
    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Total estimated work queued, in merge steps (the stealing victim
    /// heuristic: steal from the shard with the most queued *work*, not
    /// the most queued *jobs* — the paper's count-vs-cost distinction).
    pub fn queued_steps(&self) -> u64 {
        self.items.iter().map(|a| a.est_steps).sum()
    }

    /// Insert in priority order (stable: ties go behind existing items).
    pub fn push(&mut self, a: Admission) {
        let key = a.key();
        let pos = self.items.partition_point(|x| x.key() <= key);
        self.items.insert(pos, a);
    }

    /// The most urgent queued job, if any (not removed).
    pub fn peek_front(&self) -> Option<&Admission> {
        self.items.first()
    }

    /// Remove and return the most urgent job.
    pub fn pop_front(&mut self) -> Option<Admission> {
        if self.items.is_empty() {
            None
        } else {
            Some(self.items.remove(0))
        }
    }

    /// Drain up to `k` jobs from the front (one dispatch batch), most
    /// urgent first.
    pub fn take_front(&mut self, k: usize) -> Vec<Admission> {
        let k = k.min(self.items.len());
        self.items.drain(..k).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::support::Mode;
    use crate::coordinator::job::JobKind;
    use crate::graph::builder::from_sorted_unique;
    use std::sync::mpsc::channel;
    use std::sync::Arc;
    use std::time::Duration;

    fn adm(id: u64, priority: Priority, deadline_ms: Option<u64>) -> Admission {
        let g = Arc::new(from_sorted_unique(3, &[(0, 1), (1, 2)]));
        // the receiver side is dropped: queue tests never deliver results
        let (tx, _rx) = channel();
        let now = Instant::now();
        Admission {
            req: JobRequest { id, graph: g, kind: JobKind::Ktruss { k: 3, mode: Mode::Fine } },
            priority,
            deadline: deadline_ms.map(|ms| now + Duration::from_millis(ms)),
            submitted: now,
            est_steps: 1,
            plan: None,
            predicted_ms: 0.0,
            planned_pass_ms: None,
            attempts: 0,
            fingerprint: 0,
            reply: tx,
        }
    }

    fn ids(q: &mut ServeQueue) -> Vec<u64> {
        let mut out = Vec::new();
        while let Some(a) = q.pop_front() {
            out.push(a.req.id);
        }
        out
    }

    #[test]
    fn priority_classes_are_strict() {
        let mut q = ServeQueue::new();
        q.push(adm(1, Priority::Low, None));
        q.push(adm(2, Priority::High, None));
        q.push(adm(3, Priority::Normal, None));
        q.push(adm(4, Priority::High, None));
        assert_eq!(ids(&mut q), vec![2, 4, 3, 1]);
    }

    #[test]
    fn edf_within_class_and_deadlines_before_best_effort() {
        let mut q = ServeQueue::new();
        q.push(adm(1, Priority::Normal, None));
        q.push(adm(2, Priority::Normal, Some(500)));
        q.push(adm(3, Priority::Normal, Some(100)));
        q.push(adm(4, Priority::Normal, None));
        // earliest deadline first, then FIFO among no-deadline jobs
        assert_eq!(ids(&mut q), vec![3, 2, 1, 4]);
    }

    #[test]
    fn deadline_never_outranks_class() {
        let mut q = ServeQueue::new();
        q.push(adm(1, Priority::Low, Some(1)));
        q.push(adm(2, Priority::Normal, None));
        assert_eq!(ids(&mut q), vec![2, 1]);
    }

    #[test]
    fn pop_front_takes_most_urgent_until_empty() {
        let mut q = ServeQueue::new();
        q.push(adm(1, Priority::High, None));
        q.push(adm(2, Priority::Low, None));
        q.push(adm(3, Priority::Normal, None));
        assert_eq!(q.pop_front().unwrap().req.id, 1);
        assert_eq!(q.pop_front().unwrap().req.id, 3);
        assert_eq!(q.pop_front().unwrap().req.id, 2);
        assert!(q.pop_front().is_none());
    }

    #[test]
    fn take_front_is_bounded_and_ordered() {
        let mut q = ServeQueue::new();
        for id in 0..5 {
            q.push(adm(id, Priority::Normal, None));
        }
        let batch = q.take_front(3);
        assert_eq!(batch.iter().map(|a| a.req.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(q.len(), 2);
        assert_eq!(q.take_front(10).len(), 2);
        assert!(q.is_empty());
    }

    #[test]
    fn queued_steps_sums_estimates() {
        let mut q = ServeQueue::new();
        let mut a = adm(1, Priority::Normal, None);
        a.est_steps = 10;
        let mut b = adm(2, Priority::Normal, None);
        b.est_steps = 32;
        q.push(a);
        q.push(b);
        assert_eq!(q.queued_steps(), 42);
    }
}
