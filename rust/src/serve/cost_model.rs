//! Per-job runtime estimation for batch packing — GraphBLAST-style
//! cost-model routing applied at job granularity.
//!
//! Two halves:
//!
//! * **Static estimate** ([`estimate_steps`]): an upper-bound merge-step
//!   count read directly off the CSR, the job-level aggregate of the
//!   per-task bounds `par::balance::estimate_costs` computes for the
//!   support pass (a row's live entries each merge their tail with the
//!   partner row), scaled by a per-kind iteration factor. Units are
//!   abstract "steps" — only *ratios* matter for the executor's
//!   equal-work batch packing.
//! * **Calibration** ([`CostModel`]): an EWMA of observed ns-per-step
//!   from completed jobs, optionally seeded from persisted
//!   [`cost::persist`](crate::cost::persist) trace records of prior
//!   runs. This converts steps into predicted milliseconds for
//!   deadline-aware decisions, and tightens as the service runs — the
//!   job-level analogue of feeding measured `cost::replay` traces back
//!   into the work-aware binner.

use crate::coordinator::job::JobKind;
use crate::cost::persist::TraceRecord;
use crate::graph::Csr;
use std::collections::VecDeque;
use std::sync::Mutex;

/// Conservative default until the first observation lands (observed
/// per-estimated-step wall cost is well below the raw merge-step cost
/// because estimates are upper bounds).
pub const DEFAULT_NS_PER_STEP: f64 = 10.0;

/// EWMA smoothing factor for ns-per-step observations.
const EWMA_ALPHA: f64 = 0.2;

/// Retain at most this many trace records for persistence (a ring:
/// once full, the oldest observation is dropped for each new one, so
/// the retained window is always the freshest). Shared with the CLI's
/// calibration-file merge so persisted history obeys the same cap.
pub const RECORD_CAP: usize = 4096;

/// Short label for a job kind (trace record key).
pub fn kind_label(kind: &JobKind) -> &'static str {
    match kind {
        JobKind::Ktruss { .. } => "ktruss",
        JobKind::Kmax => "kmax",
        JobKind::Decompose => "decompose",
        JobKind::Triangles => "triangles",
    }
}

/// Static upper-bound work estimate for one job, in merge steps.
///
/// Per support pass: row `i` with `lᵢ` live entries costs
/// `lᵢ + lᵢ(lᵢ−1)/2 + Σ_{κ∈row i} l_κ` (per-entry overhead + tail
/// merges + partner-row merges). The per-kind multiplier folds in how
/// many passes the algorithm typically drives (K_max and decomposition
/// re-run the convergence loop per k).
pub fn estimate_steps(g: &Csr, kind: &JobKind) -> u64 {
    let n = g.n();
    let live: Vec<u32> = (0..n).map(|i| g.row(i).len() as u32).collect();
    let mut merge: u64 = 0;
    for i in 0..n {
        let li = live[i] as u64;
        merge += li + li * li.saturating_sub(1) / 2;
        for &kappa in g.row(i) {
            merge += live[kappa as usize] as u64;
        }
    }
    let mult: u64 = match kind {
        JobKind::Triangles => 1,
        JobKind::Ktruss { .. } => 3,
        JobKind::Kmax => 8,
        JobKind::Decompose => 12,
    };
    merge.saturating_mul(mult).max(1)
}

struct ModelState {
    ns_per_step: f64,
    samples: u64,
    records: VecDeque<TraceRecord>,
}

/// Thread-safe replay-calibrated cost model shared by the executor's
/// shards (each completed job refines the estimate-to-wall mapping).
pub struct CostModel {
    state: Mutex<ModelState>,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel::new()
    }
}

impl CostModel {
    /// A fresh model at the default ns/step prior, no observations.
    pub fn new() -> CostModel {
        CostModel {
            state: Mutex::new(ModelState {
                ns_per_step: DEFAULT_NS_PER_STEP,
                samples: 0,
                records: VecDeque::new(),
            }),
        }
    }

    /// Seed the calibration from persisted trace records (replayed in
    /// order through the same EWMA the live path uses).
    pub fn from_records(records: &[TraceRecord]) -> CostModel {
        let model = CostModel::new();
        {
            let mut st = model.state.lock().unwrap();
            for r in records {
                update(&mut st, r.est_steps, r.wall_ms);
            }
        }
        model
    }

    /// Record one completed job: refine ns-per-step and retain the
    /// trace record for persistence (freshest [`RECORD_CAP`] kept).
    pub fn observe(&self, kind: &JobKind, n: usize, m: usize, est_steps: u64, wall_ms: f64) {
        let mut st = self.state.lock().unwrap();
        update(&mut st, est_steps, wall_ms);
        if st.records.len() == RECORD_CAP {
            st.records.pop_front();
        }
        st.records.push_back(TraceRecord {
            kind: kind_label(kind).to_string(),
            n,
            m,
            est_steps,
            wall_ms,
        });
    }

    /// Current calibrated cost of one estimated step, in nanoseconds.
    pub fn ns_per_step(&self) -> f64 {
        self.state.lock().unwrap().ns_per_step
    }

    /// Observations folded into the calibration so far.
    pub fn samples(&self) -> u64 {
        self.state.lock().unwrap().samples
    }

    /// Predicted wall time for a job with the given static estimate.
    pub fn predict_ms(&self, est_steps: u64) -> f64 {
        est_steps as f64 * self.ns_per_step() / 1e6
    }

    /// Snapshot of retained trace records, oldest first (for
    /// [`crate::cost::persist`]).
    pub fn records(&self) -> Vec<TraceRecord> {
        self.state.lock().unwrap().records.iter().cloned().collect()
    }
}

fn update(st: &mut ModelState, est_steps: u64, wall_ms: f64) {
    if est_steps == 0 || !wall_ms.is_finite() || wall_ms < 0.0 {
        return;
    }
    let observed = wall_ms * 1e6 / est_steps as f64;
    st.ns_per_step = if st.samples == 0 {
        observed
    } else {
        EWMA_ALPHA * observed + (1.0 - EWMA_ALPHA) * st.ns_per_step
    };
    st.samples += 1;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::support::Mode;
    use crate::graph::builder::from_sorted_unique;

    #[test]
    fn estimate_grows_with_size_and_kind() {
        let mut rng = crate::util::Rng::new(7);
        let small = crate::gen::erdos_renyi::gnm(50, 150, &mut rng);
        let big = crate::gen::erdos_renyi::gnm(500, 3000, &mut rng);
        let kt = JobKind::Ktruss { k: 3, mode: Mode::Fine };
        assert!(estimate_steps(&big, &kt) > estimate_steps(&small, &kt));
        // kind multipliers: triangles < ktruss < kmax < decompose
        assert!(estimate_steps(&small, &JobKind::Triangles) < estimate_steps(&small, &kt));
        assert!(estimate_steps(&small, &kt) < estimate_steps(&small, &JobKind::Kmax));
        assert!(
            estimate_steps(&small, &JobKind::Kmax) < estimate_steps(&small, &JobKind::Decompose)
        );
    }

    #[test]
    fn estimate_is_positive_even_for_empty_graphs() {
        let g = crate::graph::Csr::empty(0);
        assert_eq!(estimate_steps(&g, &JobKind::Triangles), 1);
    }

    #[test]
    fn estimate_dominates_measured_support_steps() {
        // the job estimate must upper-bound one measured support pass
        // (it folds in ≥1 pass plus per-entry overhead)
        let g = crate::gen::rmat::rmat(
            200,
            1500,
            crate::gen::rmat::RmatParams::social(),
            &mut crate::util::Rng::new(3),
        );
        let z = crate::graph::ZCsr::from_csr(&g);
        let mut s = Vec::new();
        let tr = crate::cost::trace::trace_supports(&z, &mut s);
        let est = estimate_steps(&g, &JobKind::Triangles);
        assert!(est >= tr.total_steps, "estimate {est} < measured {}", tr.total_steps);
    }

    #[test]
    fn observe_calibrates_ns_per_step() {
        let m = CostModel::new();
        assert_eq!(m.samples(), 0);
        let kind = JobKind::Triangles;
        // 1000 steps in 0.01 ms = 10 ns/step exactly
        m.observe(&kind, 10, 20, 1000, 0.01);
        assert!((m.ns_per_step() - 10.0).abs() < 1e-9);
        assert_eq!(m.samples(), 1);
        // EWMA pulls toward new observations
        m.observe(&kind, 10, 20, 1000, 0.1); // 100 ns/step
        assert!(m.ns_per_step() > 10.0 && m.ns_per_step() < 100.0);
        assert!((m.predict_ms(1_000_000) - m.ns_per_step()).abs() < 1e-9);
        // degenerate observations are ignored
        m.observe(&kind, 10, 20, 0, 1.0);
        m.observe(&kind, 10, 20, 100, f64::NAN);
        assert_eq!(m.samples(), 2);
    }

    #[test]
    fn record_cap_is_a_ring_keeping_the_freshest() {
        let m = CostModel::new();
        for i in 0..RECORD_CAP + 10 {
            m.observe(&JobKind::Triangles, i, i, 100, 0.001);
        }
        let records = m.records();
        assert_eq!(records.len(), RECORD_CAP);
        assert_eq!(records.first().unwrap().n, 10, "oldest 10 evicted");
        assert_eq!(records.last().unwrap().n, RECORD_CAP + 9);
    }

    #[test]
    fn records_roundtrip_through_from_records() {
        let m = CostModel::new();
        let g = from_sorted_unique(3, &[(0, 1), (1, 2)]);
        let est = estimate_steps(&g, &JobKind::Kmax);
        m.observe(&JobKind::Kmax, g.n(), g.nnz(), est, 0.5);
        m.observe(&JobKind::Kmax, g.n(), g.nnz(), est, 0.6);
        let records = m.records();
        assert_eq!(records.len(), 2);
        assert_eq!(records[0].kind, "kmax");
        let seeded = CostModel::from_records(&records);
        assert_eq!(seeded.samples(), 2);
        assert!((seeded.ns_per_step() - m.ns_per_step()).abs() < 1e-9);
    }
}
