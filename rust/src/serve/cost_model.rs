//! Per-job runtime estimation for batch packing — GraphBLAST-style
//! cost-model routing applied at job granularity.
//!
//! Two halves:
//!
//! * **Static estimate** ([`estimate_steps`]): an upper-bound merge-step
//!   count read directly off the CSR, the job-level aggregate of the
//!   per-task bounds `par::balance::estimate_costs` computes for the
//!   support pass (a row's live entries each merge their tail with the
//!   partner row), scaled by a per-kind iteration factor. Units are
//!   abstract "steps" — only *ratios* matter for the executor's
//!   equal-work batch packing.
//! * **Calibration** ([`CostModel`]): EWMAs of observed ns-per-step
//!   from completed jobs — one **per job label** (kind, and for truss
//!   jobs the support mode that actually ran: an incremental iteration
//!   profile has a very different ns-per-estimated-step than a full
//!   recompute, and the two must not pollute one shared estimate) plus
//!   a global fallback for labels with no samples yet. Optionally
//!   seeded from persisted [`cost::persist`](crate::cost::persist)
//!   trace records of prior runs (records carry the label). This
//!   converts steps into predicted milliseconds for deadline-aware
//!   decisions, and tightens as the service runs — the job-level
//!   analogue of feeding measured `cost::replay` traces back into the
//!   work-aware binner.

use crate::algo::incremental::SupportMode;
use crate::coordinator::job::JobKind;
use crate::cost::persist::TraceRecord;
use crate::graph::Csr;
use std::collections::{HashMap, VecDeque};
use std::sync::Mutex;

/// Conservative default until the first observation lands (observed
/// per-estimated-step wall cost is well below the raw merge-step cost
/// because estimates are upper bounds).
pub const DEFAULT_NS_PER_STEP: f64 = 10.0;

/// EWMA smoothing factor for ns-per-step observations.
const EWMA_ALPHA: f64 = 0.2;

/// Retain at most this many trace records for persistence (a ring:
/// once full, the oldest observation is dropped for each new one, so
/// the retained window is always the freshest). Shared with the CLI's
/// calibration-file merge so persisted history obeys the same cap.
pub const RECORD_CAP: usize = 4096;

/// Short label for a job kind (trace record key).
pub fn kind_label(kind: &JobKind) -> &'static str {
    match kind {
        JobKind::Ktruss { .. } => "ktruss",
        JobKind::Kmax => "kmax",
        JobKind::Decompose => "decompose",
        JobKind::Triangles => "triangles",
        JobKind::Mutate { .. } => "mutate",
    }
}

/// Calibration label for a completed job: the kind label, suffixed with
/// the support mode the truss driver actually ran under (recorded in
/// [`crate::coordinator::job::JobResult::support`]). Distinct labels
/// keep incremental and full iteration profiles in separate EWMAs.
pub fn job_label(kind: &JobKind, support: Option<SupportMode>) -> String {
    match support {
        Some(mode) => format!("{}+{mode}", kind_label(kind)),
        None => kind_label(kind).to_string(),
    }
}

/// Static upper-bound work estimate for one job, in merge steps.
///
/// Per support pass: row `i` with `lᵢ` live entries costs
/// `lᵢ + lᵢ(lᵢ−1)/2 + Σ_{κ∈row i} l_κ` (per-entry overhead + tail
/// merges + partner-row merges). The per-kind multiplier folds in how
/// many passes the algorithm typically drives (K_max and decomposition
/// re-run the convergence loop per k).
pub fn estimate_steps(g: &Csr, kind: &JobKind) -> u64 {
    estimate_steps_mode(g, kind, SupportMode::Full)
}

/// [`estimate_steps`] under an explicit support-maintenance profile.
/// `support` only affects the fixed-k truss (the one kind whose driver
/// the serving policy actually selects): incremental/auto pays one full
/// pass plus frontier-sized updates, so its multiplier collapses to a
/// single pass plus an `O(nnz)` frontier term. K_max and decomposition
/// *always* chain k-levels warm through the incremental driver, so
/// their multipliers are fixed (and lower than the pre-incremental
/// 8x/12x) regardless of `support` — a submit-time override must not
/// move their estimates when it cannot move their execution.
pub fn estimate_steps_mode(g: &Csr, kind: &JobKind, support: SupportMode) -> u64 {
    let n = g.n();
    let live: Vec<u32> = (0..n).map(|i| g.row(i).len() as u32).collect();
    let mut merge: u64 = 0;
    for i in 0..n {
        let li = live[i] as u64;
        merge += li + li * li.saturating_sub(1) / 2;
        for &kappa in g.row(i) {
            merge += live[kappa as usize] as u64;
        }
    }
    let est = match kind {
        JobKind::Triangles => merge,
        JobKind::Ktruss { .. } if support.allows_incremental() => {
            merge.saturating_add(g.nnz() as u64)
        }
        JobKind::Ktruss { .. } => merge.saturating_mul(3),
        JobKind::Kmax => merge.saturating_mul(4),
        JobKind::Decompose => merge.saturating_mul(6),
        // a mutation touches a frontier sized by the batch: roughly the
        // average row's merge work per touched edge, with a 3x slack
        // for the re-admission / re-convergence tail
        JobKind::Mutate { batch, .. } => {
            let touched = (batch.insert.len() + batch.delete.len()).max(1) as u64;
            (merge / (g.nnz().max(1) as u64))
                .saturating_mul(touched)
                .saturating_mul(3)
        }
    };
    est.max(1)
}

/// One exponentially-weighted ns-per-step estimate.
#[derive(Clone, Copy)]
struct Ewma {
    ns_per_step: f64,
    samples: u64,
}

impl Ewma {
    fn new() -> Ewma {
        Ewma { ns_per_step: DEFAULT_NS_PER_STEP, samples: 0 }
    }

    fn fold(&mut self, observed: f64) {
        self.ns_per_step = if self.samples == 0 {
            observed
        } else {
            EWMA_ALPHA * observed + (1.0 - EWMA_ALPHA) * self.ns_per_step
        };
        self.samples += 1;
    }
}

struct ModelState {
    /// Fallback over every observation (labels with no samples yet
    /// predict through this).
    global: Ewma,
    /// One EWMA per job label ([`job_label`]).
    per_label: HashMap<String, Ewma>,
    records: VecDeque<TraceRecord>,
}

/// Thread-safe replay-calibrated cost model shared by the executor's
/// shards (each completed job refines the estimate-to-wall mapping).
pub struct CostModel {
    state: Mutex<ModelState>,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel::new()
    }
}

impl CostModel {
    /// A fresh model at the default ns/step prior, no observations.
    pub fn new() -> CostModel {
        CostModel {
            state: Mutex::new(ModelState {
                global: Ewma::new(),
                per_label: HashMap::new(),
                records: VecDeque::new(),
            }),
        }
    }

    /// Seed the calibration from persisted trace records (replayed in
    /// order through the same per-label EWMAs the live path uses —
    /// records carry the label in their `kind` field).
    pub fn from_records(records: &[TraceRecord]) -> CostModel {
        let model = CostModel::new();
        {
            let mut st = model.state.lock().unwrap();
            for r in records {
                update(&mut st, &r.kind, r.est_steps, r.wall_ms);
            }
        }
        model
    }

    /// Record one completed job under its kind label (no support-mode
    /// provenance). Prefer [`CostModel::observe_labeled`] when the
    /// executed support mode is known.
    pub fn observe(&self, kind: &JobKind, n: usize, m: usize, est_steps: u64, wall_ms: f64) {
        self.observe_labeled(kind_label(kind), n, m, est_steps, wall_ms);
    }

    /// Record one completed job under an explicit calibration label
    /// (see [`job_label`]): refine that label's EWMA plus the global
    /// fallback, and retain the trace record for persistence (freshest
    /// [`RECORD_CAP`] kept). The retained record carries no plan
    /// provenance — prefer [`CostModel::observe_planned`] when the
    /// executed plan is known, so persisted calibration can seed
    /// per-plan drift baselines across restarts.
    pub fn observe_labeled(
        &self,
        label: &str,
        n: usize,
        m: usize,
        est_steps: u64,
        wall_ms: f64,
    ) {
        self.record(TraceRecord::unplanned(label.to_string(), n, m, est_steps, wall_ms));
    }

    /// [`CostModel::observe_labeled`] with executed-plan provenance:
    /// the retained trace record carries the plan's schedule,
    /// granularity, support, and device axes, so a persisted
    /// calibration file can re-seed both the per-label EWMAs *and* the
    /// per-plan drift baselines
    /// ([`crate::obs::drift::DriftTracker::seed`]) at startup without
    /// folding lane-backend walls into CPU regimes.
    pub fn observe_planned(
        &self,
        label: &str,
        n: usize,
        m: usize,
        est_steps: u64,
        wall_ms: f64,
        plan: &crate::plan::ExecutionPlan,
    ) {
        let mut rec = TraceRecord::unplanned(label.to_string(), n, m, est_steps, wall_ms);
        rec.schedule = plan.schedule.to_string();
        rec.granularity = plan.granularity.to_string();
        rec.support = plan.support.to_string();
        rec.device = plan.device.to_string();
        self.record(rec);
    }

    fn record(&self, rec: TraceRecord) {
        let mut st = self.state.lock().unwrap();
        update(&mut st, &rec.kind, rec.est_steps, rec.wall_ms);
        if st.records.len() == RECORD_CAP {
            st.records.pop_front();
        }
        st.records.push_back(rec);
    }

    /// Globally calibrated cost of one estimated step, in nanoseconds.
    pub fn ns_per_step(&self) -> f64 {
        self.state.lock().unwrap().global.ns_per_step
    }

    /// Calibrated ns/step for one job label, falling back to the global
    /// estimate until the label has samples of its own.
    pub fn ns_per_step_for(&self, label: &str) -> f64 {
        let st = self.state.lock().unwrap();
        match st.per_label.get(label) {
            Some(e) if e.samples > 0 => e.ns_per_step,
            _ => st.global.ns_per_step,
        }
    }

    /// Observations folded into the calibration so far (all labels).
    pub fn samples(&self) -> u64 {
        self.state.lock().unwrap().global.samples
    }

    /// Observations folded into one label's EWMA.
    pub fn samples_for(&self, label: &str) -> u64 {
        self.state
            .lock()
            .unwrap()
            .per_label
            .get(label)
            .map(|e| e.samples)
            .unwrap_or(0)
    }

    /// Predicted wall time for a job with the given static estimate
    /// (global calibration).
    pub fn predict_ms(&self, est_steps: u64) -> f64 {
        est_steps as f64 * self.ns_per_step() / 1e6
    }

    /// Predicted wall time under one label's calibration.
    pub fn predict_ms_for(&self, label: &str, est_steps: u64) -> f64 {
        est_steps as f64 * self.ns_per_step_for(label) / 1e6
    }

    /// Snapshot of retained trace records, oldest first (for
    /// [`crate::cost::persist`]).
    pub fn records(&self) -> Vec<TraceRecord> {
        self.state.lock().unwrap().records.iter().cloned().collect()
    }
}

fn update(st: &mut ModelState, label: &str, est_steps: u64, wall_ms: f64) {
    if est_steps == 0 || !wall_ms.is_finite() || wall_ms < 0.0 {
        return;
    }
    let observed = wall_ms * 1e6 / est_steps as f64;
    st.global.fold(observed);
    st.per_label
        .entry(label.to_string())
        .or_insert_with(Ewma::new)
        .fold(observed);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::support::Mode;
    use crate::graph::builder::from_sorted_unique;

    #[test]
    fn estimate_grows_with_size_and_kind() {
        let mut rng = crate::util::Rng::new(7);
        let small = crate::gen::erdos_renyi::gnm(50, 150, &mut rng);
        let big = crate::gen::erdos_renyi::gnm(500, 3000, &mut rng);
        let kt = JobKind::Ktruss { k: 3, mode: Mode::Fine };
        assert!(estimate_steps(&big, &kt) > estimate_steps(&small, &kt));
        // kind multipliers: triangles < ktruss < kmax < decompose
        assert!(estimate_steps(&small, &JobKind::Triangles) < estimate_steps(&small, &kt));
        assert!(estimate_steps(&small, &kt) < estimate_steps(&small, &JobKind::Kmax));
        assert!(
            estimate_steps(&small, &JobKind::Kmax) < estimate_steps(&small, &JobKind::Decompose)
        );
    }

    #[test]
    fn estimate_is_positive_even_for_empty_graphs() {
        let g = crate::graph::Csr::empty(0);
        assert_eq!(estimate_steps(&g, &JobKind::Triangles), 1);
    }

    #[test]
    fn estimate_dominates_measured_support_steps() {
        // the job estimate must upper-bound one measured support pass
        // (it folds in ≥1 pass plus per-entry overhead)
        let g = crate::gen::rmat::rmat(
            200,
            1500,
            crate::gen::rmat::RmatParams::social(),
            &mut crate::util::Rng::new(3),
        );
        let z = crate::graph::ZCsr::from_csr(&g);
        let mut s = Vec::new();
        let tr = crate::cost::trace::trace_supports(&z, &mut s);
        let est = estimate_steps(&g, &JobKind::Triangles);
        assert!(est >= tr.total_steps, "estimate {est} < measured {}", tr.total_steps);
    }

    #[test]
    fn observe_calibrates_ns_per_step() {
        let m = CostModel::new();
        assert_eq!(m.samples(), 0);
        let kind = JobKind::Triangles;
        // 1000 steps in 0.01 ms = 10 ns/step exactly
        m.observe(&kind, 10, 20, 1000, 0.01);
        assert!((m.ns_per_step() - 10.0).abs() < 1e-9);
        assert_eq!(m.samples(), 1);
        // EWMA pulls toward new observations
        m.observe(&kind, 10, 20, 1000, 0.1); // 100 ns/step
        assert!(m.ns_per_step() > 10.0 && m.ns_per_step() < 100.0);
        assert!((m.predict_ms(1_000_000) - m.ns_per_step()).abs() < 1e-9);
        // degenerate observations are ignored
        m.observe(&kind, 10, 20, 0, 1.0);
        m.observe(&kind, 10, 20, 100, f64::NAN);
        assert_eq!(m.samples(), 2);
    }

    #[test]
    fn per_label_calibration_is_isolated() {
        let m = CostModel::new();
        let kind = JobKind::Ktruss { k: 3, mode: Mode::Fine };
        let full = job_label(&kind, Some(SupportMode::Full));
        let inc = job_label(&kind, Some(SupportMode::Incremental));
        assert_eq!(full, "ktruss+full");
        assert_eq!(inc, "ktruss+incremental");
        // full iterations: 10 ns/step; incremental: 1 ns/step
        m.observe_labeled(&full, 10, 20, 1000, 0.01);
        m.observe_labeled(&inc, 10, 20, 1000, 0.001);
        assert!((m.ns_per_step_for(&full) - 10.0).abs() < 1e-9);
        assert!((m.ns_per_step_for(&inc) - 1.0).abs() < 1e-9);
        assert_eq!(m.samples_for(&full), 1);
        assert_eq!(m.samples_for(&inc), 1);
        // the global fallback blends both; unseen labels use it
        assert_eq!(m.samples(), 2);
        assert!((m.ns_per_step_for("kmax") - m.ns_per_step()).abs() < 1e-9);
        assert!(
            (m.predict_ms_for(&inc, 1_000_000) - m.ns_per_step_for(&inc)).abs() < 1e-9
        );
        // per-label estimates survive a persist roundtrip
        let seeded = CostModel::from_records(&m.records());
        assert!((seeded.ns_per_step_for(&inc) - 1.0).abs() < 1e-9);
        assert!((seeded.ns_per_step_for(&full) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn incremental_estimate_is_leaner_for_truss_jobs() {
        let g = crate::gen::erdos_renyi::gnm(200, 1200, &mut crate::util::Rng::new(11));
        let kt = JobKind::Ktruss { k: 4, mode: Mode::Fine };
        let full = estimate_steps_mode(&g, &kt, SupportMode::Full);
        let inc = estimate_steps_mode(&g, &kt, SupportMode::Incremental);
        let auto = estimate_steps_mode(&g, &kt, SupportMode::Auto);
        assert!(inc < full, "inc {inc} vs full {full}");
        assert_eq!(inc, auto);
        // and the incremental profile still upper-bounds one real pass
        let z = crate::graph::ZCsr::from_csr(&g);
        let mut s = Vec::new();
        let tr = crate::cost::trace::trace_supports(&z, &mut s);
        assert!(inc >= tr.total_steps);
        // kinds the support policy cannot steer are mode-invariant: an
        // override must not move an estimate it cannot move in execution
        for kind in [JobKind::Triangles, JobKind::Kmax, JobKind::Decompose] {
            assert_eq!(
                estimate_steps_mode(&g, &kind, SupportMode::Incremental),
                estimate_steps(&g, &kind),
                "{}",
                kind_label(&kind)
            );
        }
    }

    #[test]
    fn observe_planned_retains_plan_provenance() {
        let m = CostModel::new();
        let plan = crate::plan::ExecutionPlan {
            schedule: crate::par::Schedule::WorkAware,
            granularity: crate::algo::support::Granularity::Fine,
            support: SupportMode::Full,
            crossover: 0.25,
            device: crate::plan::PlanDevice::Cpu,
        };
        m.observe_planned("ktruss+full", 10, 20, 1000, 0.01, &plan);
        m.observe_labeled("kmax", 10, 20, 500, 0.02);
        let records = m.records();
        assert_eq!(records.len(), 2);
        assert!(records[0].has_provenance());
        assert_eq!(records[0].schedule, plan.schedule.to_string());
        assert_eq!(records[0].granularity, plan.granularity.to_string());
        assert_eq!(records[0].support, plan.support.to_string());
        assert_eq!(records[0].device, "cpu");
        assert!(!records[1].has_provenance());
        // provenance does not perturb the calibration itself
        assert!((m.ns_per_step_for("ktruss+full") - 10.0).abs() < 1e-9);
    }

    #[test]
    fn record_cap_is_a_ring_keeping_the_freshest() {
        let m = CostModel::new();
        for i in 0..RECORD_CAP + 10 {
            m.observe(&JobKind::Triangles, i, i, 100, 0.001);
        }
        let records = m.records();
        assert_eq!(records.len(), RECORD_CAP);
        assert_eq!(records.first().unwrap().n, 10, "oldest 10 evicted");
        assert_eq!(records.last().unwrap().n, RECORD_CAP + 9);
    }

    #[test]
    fn records_roundtrip_through_from_records() {
        let m = CostModel::new();
        let g = from_sorted_unique(3, &[(0, 1), (1, 2)]);
        let est = estimate_steps(&g, &JobKind::Kmax);
        m.observe(&JobKind::Kmax, g.n(), g.nnz(), est, 0.5);
        m.observe(&JobKind::Kmax, g.n(), g.nnz(), est, 0.6);
        let records = m.records();
        assert_eq!(records.len(), 2);
        assert_eq!(records[0].kind, "kmax");
        let seeded = CostModel::from_records(&records);
        assert_eq!(seeded.samples(), 2);
        assert!((seeded.ns_per_step() - m.ns_per_step()).abs() < 1e-9);
    }
}
