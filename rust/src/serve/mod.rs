//! L3 serving scale-out: a sharded, priority-aware executor with
//! replay-calibrated batch scheduling.
//!
//! # Architecture (bottom-up)
//!
//! * **L1 — kernels** ([`crate::algo`]): the eager update merge, the
//!   support/prune passes, in coarse and fine granularity.
//! * **L2 — pool & balance** ([`crate::par`]): the Kokkos-style worker
//!   pool and the work-aware schedules (scan-binned chunks, stealing
//!   deques) that balance *tasks within one job*.
//! * **L3 — serve** (this module): balance *jobs within a batch* with
//!   the same machinery one level up:
//!
//!   | within one job (L2)               | across jobs (L3)                     |
//!   |-----------------------------------|--------------------------------------|
//!   | per-row/slot cost bounds          | per-job estimate ([`cost_model`])    |
//!   | `scan_bins` over rows             | least-loaded equal-work packing      |
//!   | chunk deques + stealing           | shard queues + job stealing          |
//!   | measured trace feedback           | ns/step calibration from completions |
//!
//! # Shape
//!
//! [`Executor::start`] spawns N shard threads (each owning a
//! [`crate::par::Pool`] and an optional dense engine) plus one
//! dispatcher. [`Executor::submit_with`] admits a job with a
//! [`Priority`] class and an optional soft deadline into the central
//! [`ServeQueue`] (strict priority between classes, EDF within one,
//! FIFO otherwise). The dispatcher drains the queue in batches, packs
//! each batch across shards by equal estimated work (least-loaded
//! greedy over the cost-model estimates, so urgency classes stripe
//! across shards instead of banding onto one), and drained shards
//! steal the globally most urgent queued job (the idle thief executes
//! it immediately, pulling urgent work forward).
//! Completions refine the [`CostModel`]'s
//! ns-per-step calibration, which can be persisted and re-loaded via
//! [`crate::cost::persist`].
//!
//! The single-pool [`crate::coordinator::Coordinator`] API survives as
//! a thin facade over a one-shard executor.
//!
//! # Failure model
//!
//! The executor is fault-tolerant by construction (see
//! `docs/ARCHITECTURE.md`, "Failure model"): admission can reject
//! (bounded queue, [`SubmitError::QueueFull`]) or shed/degrade
//! ([`admission`]) using the planner's cost prediction; shard bodies
//! run panic-isolated and self-heal (respawn + in-flight requeue, with
//! a poison-job registry and bounded retries); deadline enforcement
//! cancels past-deadline jobs cooperatively at pass boundaries; and a
//! deterministic [`faults`] harness injects panics, stalls, and crashes
//! for the `bench chaos` overload/recovery study. Every admitted job
//! reaches exactly one terminal
//! [`JobOutcome`](crate::coordinator::JobOutcome).

pub mod admission;
pub mod cost_model;
pub mod executor;
pub mod faults;
pub mod queue;
pub mod store;

pub use admission::{AdmissionDecision, AdmissionInput, AdmissionPolicy, SubmitError};
pub use cost_model::{estimate_steps, estimate_steps_mode, job_label, kind_label, CostModel};
pub use executor::{Executor, ServeConfig, SubmitOpts, Ticket};
pub use faults::{FaultInjector, FaultPlan};
pub use queue::{Admission, Priority, ServeQueue};
pub use store::{EpochSnapshot, GraphStore};
