//! Minimal argument parser for the `ktruss` launcher (no clap in the
//! offline crate set). Supports `--flag value`, `--flag=value`, boolean
//! `--flag`, and positional arguments.

use anyhow::{bail, Result};
use std::collections::HashMap;

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// Positional (non-flag) arguments in order of appearance.
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
    /// flags consumed so far (for unknown-flag reporting)
    used: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from raw argv (excluding the program name and subcommand).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    args.flags.insert(stripped.to_string(), v);
                } else {
                    args.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    /// String flag with default.
    pub fn get(&self, key: &str, default: &str) -> String {
        self.used.borrow_mut().push(key.to_string());
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Optional string flag.
    pub fn opt(&self, key: &str) -> Option<String> {
        self.used.borrow_mut().push(key.to_string());
        self.flags.get(key).cloned()
    }

    /// Typed flag with default.
    pub fn get_as<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        self.used.borrow_mut().push(key.to_string());
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key}: cannot parse {v:?}")),
        }
    }

    /// Boolean flag (present or `--flag true`).
    pub fn has(&self, key: &str) -> bool {
        self.used.borrow_mut().push(key.to_string());
        matches!(self.flags.get(key).map(String::as_str), Some("true") | Some("1") | Some("yes"))
    }

    /// Error on flags nobody consumed (catches typos).
    pub fn reject_unknown(&self) -> Result<()> {
        let used = self.used.borrow();
        for k in self.flags.keys() {
            if !used.iter().any(|u| u == k) {
                bail!("unknown flag --{k}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_flag_styles() {
        // NB: a bare boolean flag directly before a positional would
        // consume it as a value (documented grammar limitation), so
        // boolean flags go last or use `--flag=true`.
        let a = Args::parse(argv("--k 4 --mode=fine pos1 --verbose")).unwrap();
        assert_eq!(a.get("k", "3"), "4");
        assert_eq!(a.get("mode", "coarse"), "fine");
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
        assert!(a.reject_unknown().is_ok());
    }

    #[test]
    fn typed_parsing_and_defaults() {
        let a = Args::parse(argv("--k 7")).unwrap();
        assert_eq!(a.get_as::<u32>("k", 3).unwrap(), 7);
        assert_eq!(a.get_as::<u32>("missing", 9).unwrap(), 9);
        assert!(Args::parse(argv("--k x")).unwrap().get_as::<u32>("k", 3).is_err());
    }

    #[test]
    fn unknown_flags_rejected() {
        let a = Args::parse(argv("--k 4 --tpyo 1")).unwrap();
        let _ = a.get("k", "3");
        assert!(a.reject_unknown().is_err());
    }
}
