//! Job types the coordinator serves.

use crate::algo::incremental::SupportMode;
use crate::algo::support::Mode;
use crate::graph::{Csr, Vid};
use crate::par::Schedule;
use crate::plan::ExecutionPlan;
use std::sync::Arc;

/// Unique job id assigned at submission.
pub type JobId = u64;

/// What to compute.
#[derive(Clone, Debug)]
pub enum JobKind {
    /// Fixed-k K-truss.
    Ktruss { k: u32, mode: Mode },
    /// Largest non-empty k.
    Kmax,
    /// Full truss decomposition (trussness per edge).
    Decompose,
    /// Triangle count.
    Triangles,
    /// Apply one edge-mutation batch to a versioned resident graph
    /// ([`crate::serve::store::GraphStore`]), publishing the next
    /// epoch. The accompanying request graph is the pinned pre-batch
    /// snapshot (it sizes the cost estimate and the job span); the
    /// mutation itself runs against the store. Batches are
    /// order-dependent — submitters serialize them by waiting on each
    /// `Mutate` ticket before submitting the next.
    Mutate {
        /// The store to mutate.
        store: Arc<crate::serve::store::GraphStore>,
        /// The batch to apply.
        batch: Arc<crate::algo::stream::EdgeBatch>,
    },
}

/// A submitted request.
#[derive(Clone, Debug)]
pub struct JobRequest {
    /// Monotonically assigned job id.
    pub id: JobId,
    /// Input graph (shared, never copied per job).
    pub graph: Arc<Csr>,
    /// What to compute.
    pub kind: JobKind,
}

/// Which engine executed a job (routing provenance).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    /// Sparse zero-terminated CSR path on the worker pool.
    SparseCpu,
    /// Dense AOT (jax/Pallas via PJRT) path — small graphs only.
    DenseXla,
}

impl std::fmt::Display for Engine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Engine::SparseCpu => write!(f, "sparse-cpu"),
            Engine::DenseXla => write!(f, "dense-xla"),
        }
    }
}

/// Result payload per job kind.
#[derive(Clone, Debug)]
pub enum JobOutput {
    /// Fixed-k truss: surviving edge count, iterations, edge list.
    Ktruss {
        /// Edges surviving in the k-truss.
        truss_edges: usize,
        /// Convergence iterations.
        iterations: usize,
        /// The surviving edges themselves.
        edges: Vec<(Vid, Vid)>,
    },
    /// K_max discovery: the largest non-empty k and its truss size.
    Kmax {
        /// Largest k with a non-empty truss.
        kmax: u32,
        /// Edges of the K_max-truss.
        truss_edges: usize,
    },
    /// Full decomposition: kmax plus the trussness histogram.
    Decompose {
        /// Largest k with a non-empty truss.
        kmax: u32,
        /// (k, edges with trussness exactly k) pairs.
        histogram: Vec<(u32, usize)>,
    },
    /// Triangle count of the whole graph.
    Triangles {
        /// Total triangles.
        count: u64,
    },
    /// Applied mutation batch: the published epoch and what the batch
    /// did (see [`crate::algo::stream::BatchOutcome`]).
    Mutate {
        /// Epoch published by this batch.
        epoch: u64,
        /// Edges inserted after normalization.
        inserted: usize,
        /// Edges deleted after normalization.
        deleted: usize,
        /// Submitted mutations rejected by normalization.
        rejected: usize,
        /// Whether the truss was re-derived (vs the sound fast path).
        recomputed: bool,
        /// Edges in the maintained k-truss after the batch.
        truss_edges: usize,
    },
}

/// Terminal disposition of a submitted job. Every admission the
/// executor accepts (or sheds) reaches **exactly one** of these — the
/// chaos invariant the fault-injection harness asserts: no job is
/// lost, none is reported twice.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobOutcome {
    /// Executed to completion (the `output` carries the payload, or an
    /// execution error).
    Done,
    /// Rejected at admission: the planned cost blows the deadline (or
    /// the queue is saturated) and no degraded answer was available.
    Shed,
    /// Answered at admission from a stale epoch of the degrade store
    /// instead of computing fresh.
    Degraded,
    /// Stopped cooperatively at a pass boundary after its deadline
    /// passed (deadline enforcement; partial work is discarded).
    Cancelled,
    /// Refused by the poison-job registry after exhausting its panic
    /// retry budget.
    Quarantined,
}

impl std::fmt::Display for JobOutcome {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JobOutcome::Done => write!(f, "done"),
            JobOutcome::Shed => write!(f, "shed"),
            JobOutcome::Degraded => write!(f, "degraded"),
            JobOutcome::Cancelled => write!(f, "cancelled"),
            JobOutcome::Quarantined => write!(f, "quarantined"),
        }
    }
}

/// Completed job envelope.
#[derive(Clone, Debug)]
pub struct JobResult {
    /// Id of the completed job.
    pub id: JobId,
    /// Engine that executed it (routing provenance).
    pub engine: Engine,
    /// The full [`ExecutionPlan`] the sparse fixed-k truss engine ran
    /// under — for jobs served through the executor this is the
    /// submit-time plan, carried unchanged through the admission queue.
    /// `None` for dense executions (the AOT path has no plan axes) and
    /// for job kinds whose sparse path is sequential (kmax, decompose,
    /// triangles).
    pub plan: Option<ExecutionPlan>,
    /// The plan's schedule axis, mirrored flat for convenience (always
    /// `plan.map(|p| p.schedule)`).
    pub schedule: Option<Schedule>,
    /// The plan's support axis, mirrored flat (always
    /// `plan.map(|p| p.support)`) — the calibration label the serving
    /// cost model keys on ([`crate::serve::cost_model::job_label`]).
    pub support: Option<SupportMode>,
    /// Execution wall time (excluding queueing), ms.
    pub wall_ms: f64,
    /// Per-iteration pass spans of the sparse truss convergence loop
    /// (exact measured steps + wall per pass; empty for dense
    /// executions and for kinds whose driver reports no per-pass
    /// stats). Sum of the spans' `steps` equals
    /// [`KtrussResult::total_support_steps`](crate::algo::ktruss::KtrussResult::total_support_steps)
    /// for fixed-k truss jobs.
    pub passes: Vec<crate::obs::span::PassSpan>,
    /// Terminal disposition (see [`JobOutcome`]). `Done` for every job
    /// that executed — including ones whose `output` is an `Err` — and
    /// a degraded/terminated variant for jobs the serving layer shed,
    /// degraded, cancelled or quarantined instead of running to
    /// completion.
    pub outcome: JobOutcome,
    /// Ok(output) or the error message (no anyhow across channels).
    pub output: Result<JobOutput, String>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_display() {
        assert_eq!(Engine::SparseCpu.to_string(), "sparse-cpu");
        assert_eq!(Engine::DenseXla.to_string(), "dense-xla");
    }

    #[test]
    fn outcome_display() {
        assert_eq!(JobOutcome::Done.to_string(), "done");
        assert_eq!(JobOutcome::Shed.to_string(), "shed");
        assert_eq!(JobOutcome::Degraded.to_string(), "degraded");
        assert_eq!(JobOutcome::Cancelled.to_string(), "cancelled");
        assert_eq!(JobOutcome::Quarantined.to_string(), "quarantined");
    }
}
