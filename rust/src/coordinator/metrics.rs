//! Lock-free service metrics: per-engine job counts and a coarse
//! log₂-bucketed latency histogram, suitable for scraping from the CLI.

use std::sync::atomic::{AtomicU64, Ordering};

const BUCKETS: usize = 24; // 2^0 .. 2^23 microseconds (~8.4 s)

/// Aggregated coordinator metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    pub sparse_jobs: AtomicU64,
    pub dense_jobs: AtomicU64,
    latency_us: [AtomicU64; BUCKETS],
    latency_sum_us: AtomicU64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_submit(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_done(&self, engine: crate::coordinator::job::Engine, wall_ms: f64, ok: bool) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        if !ok {
            self.failed.fetch_add(1, Ordering::Relaxed);
        }
        match engine {
            crate::coordinator::job::Engine::SparseCpu => {
                self.sparse_jobs.fetch_add(1, Ordering::Relaxed)
            }
            crate::coordinator::job::Engine::DenseXla => {
                self.dense_jobs.fetch_add(1, Ordering::Relaxed)
            }
        };
        let us = (wall_ms * 1e3).max(0.0) as u64;
        let bucket = (64 - us.max(1).leading_zeros() as usize - 1).min(BUCKETS - 1);
        self.latency_us[bucket].fetch_add(1, Ordering::Relaxed);
        self.latency_sum_us.fetch_add(us, Ordering::Relaxed);
    }

    /// (completed, failed, mean latency ms).
    pub fn summary(&self) -> (u64, u64, f64) {
        let done = self.completed.load(Ordering::Relaxed);
        let failed = self.failed.load(Ordering::Relaxed);
        let mean_ms = if done == 0 {
            0.0
        } else {
            self.latency_sum_us.load(Ordering::Relaxed) as f64 / done as f64 / 1e3
        };
        (done, failed, mean_ms)
    }

    /// Latency histogram as (bucket_floor_us, count), non-empty buckets.
    pub fn latency_histogram(&self) -> Vec<(u64, u64)> {
        self.latency_us
            .iter()
            .enumerate()
            .filter_map(|(b, c)| {
                let count = c.load(Ordering::Relaxed);
                (count > 0).then_some((1u64 << b, count))
            })
            .collect()
    }

    /// Render a one-line scrape.
    pub fn render(&self) -> String {
        let (done, failed, mean) = self.summary();
        format!(
            "submitted={} completed={} failed={} sparse={} dense={} mean_latency_ms={:.3}",
            self.submitted.load(Ordering::Relaxed),
            done,
            failed,
            self.sparse_jobs.load(Ordering::Relaxed),
            self.dense_jobs.load(Ordering::Relaxed),
            mean
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::Engine;

    #[test]
    fn records_and_summarizes() {
        let m = Metrics::new();
        m.record_submit();
        m.record_submit();
        m.record_done(Engine::SparseCpu, 1.0, true);
        m.record_done(Engine::DenseXla, 3.0, false);
        let (done, failed, mean) = m.summary();
        assert_eq!(done, 2);
        assert_eq!(failed, 1);
        assert!((mean - 2.0).abs() < 0.01, "{mean}");
        assert_eq!(m.latency_histogram().iter().map(|&(_, c)| c).sum::<u64>(), 2);
        assert!(m.render().contains("completed=2"));
    }

    #[test]
    fn histogram_buckets_log2() {
        let m = Metrics::new();
        m.record_done(Engine::SparseCpu, 0.001, true); // 1us -> bucket 0
        m.record_done(Engine::SparseCpu, 1.0, true); // 1000us -> bucket 9
        let h = m.latency_histogram();
        assert_eq!(h.len(), 2);
        assert_eq!(h[0].0, 1);
        assert_eq!(h[1].0, 512);
    }
}
