//! Lock-free service metrics: per-engine job counts, a coarse
//! log₂-bucketed latency histogram with quantile extraction, and
//! per-shard serving gauges (jobs, steals, queue depth, deadline
//! misses), suitable for scraping from the CLI.

use std::sync::atomic::{AtomicU64, Ordering};

const BUCKETS: usize = 24; // 2^0 .. 2^23 microseconds (~8.4 s)

/// Serving counters for one executor shard.
#[derive(Debug, Default)]
pub struct ShardMetrics {
    /// Jobs this shard executed (including stolen ones).
    pub jobs: AtomicU64,
    /// Jobs this shard stole from another shard's queue.
    pub stolen: AtomicU64,
    /// Completions past their soft deadline.
    pub deadline_miss: AtomicU64,
    /// Current queued jobs (gauge, set by the dispatcher/shard).
    pub queue_depth: AtomicU64,
    /// Jobs this shard cancelled at a pass boundary (deadline
    /// enforcement).
    pub cancelled: AtomicU64,
    /// Times this shard's worker body was respawned after a panic.
    pub respawns: AtomicU64,
}

/// Aggregated coordinator metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Jobs submitted.
    pub submitted: AtomicU64,
    /// Jobs completed (ok or failed).
    pub completed: AtomicU64,
    /// Jobs that completed with an error.
    pub failed: AtomicU64,
    /// Jobs the sparse CPU engine executed.
    pub sparse_jobs: AtomicU64,
    /// Jobs the dense XLA engine executed.
    pub dense_jobs: AtomicU64,
    /// Jobs shed at admission (planned cost blew the deadline, no
    /// degraded answer available).
    pub shed: AtomicU64,
    /// Jobs answered at admission from a stale epoch of the degrade
    /// store.
    pub degraded: AtomicU64,
    /// Jobs cancelled at a pass boundary (deadline enforcement).
    pub cancelled: AtomicU64,
    /// Jobs refused by the poison-job registry after exhausting their
    /// panic retry budget.
    pub quarantined: AtomicU64,
    /// Panic-retry requeues (each failed attempt that earned another).
    pub retries: AtomicU64,
    /// Submissions rejected by admission backpressure (queue full).
    pub queue_rejected: AtomicU64,
    latency_us: [AtomicU64; BUCKETS],
    latency_sum_us: AtomicU64,
    shards: Vec<ShardMetrics>,
}

impl Metrics {
    /// A shard-less metrics block (single-pool coordinator path).
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Metrics with `shards` per-shard counter blocks (the sharded
    /// executor path; `new()` keeps a shard-less instance).
    pub fn with_shards(shards: usize) -> Metrics {
        Metrics {
            shards: (0..shards).map(|_| ShardMetrics::default()).collect(),
            ..Metrics::default()
        }
    }

    /// Count one submission.
    pub fn record_submit(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one completion: engine attribution, latency bucket, error
    /// tally.
    pub fn record_done(&self, engine: crate::coordinator::job::Engine, wall_ms: f64, ok: bool) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        if !ok {
            self.failed.fetch_add(1, Ordering::Relaxed);
        }
        match engine {
            crate::coordinator::job::Engine::SparseCpu => {
                self.sparse_jobs.fetch_add(1, Ordering::Relaxed)
            }
            crate::coordinator::job::Engine::DenseXla => {
                self.dense_jobs.fetch_add(1, Ordering::Relaxed)
            }
        };
        let us = (wall_ms * 1e3).max(0.0) as u64;
        // floor(log₂), clamped into the top bucket — out-of-range
        // samples saturate rather than vanish
        let bucket = (64 - us.max(1).leading_zeros() as usize - 1).min(BUCKETS - 1);
        self.latency_us[bucket].fetch_add(1, Ordering::Relaxed);
        self.latency_sum_us.fetch_add(us, Ordering::Relaxed);
    }

    // --- robustness counters --------------------------------------------

    /// Count one job shed at admission.
    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one job degraded to a stale-epoch read at admission.
    pub fn record_degraded(&self) {
        self.degraded.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one job cancelled at a pass boundary on `shard`.
    pub fn record_cancelled(&self, shard: usize) {
        self.cancelled.fetch_add(1, Ordering::Relaxed);
        if let Some(s) = self.shards.get(shard) {
            s.cancelled.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Count one job quarantined by the poison registry.
    pub fn record_quarantined(&self) {
        self.quarantined.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one panic-retry requeue.
    pub fn record_retry(&self) {
        self.retries.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one submission rejected by admission backpressure.
    pub fn record_queue_rejected(&self) {
        self.queue_rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one worker-body respawn on `shard`.
    pub fn record_respawn(&self, shard: usize) {
        if let Some(s) = self.shards.get(shard) {
            s.respawns.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Total worker-body respawns across shards.
    pub fn respawns(&self) -> u64 {
        self.shards.iter().map(|s| s.respawns.load(Ordering::Relaxed)).sum()
    }

    // --- per-shard serving counters -------------------------------------

    /// Per-shard counter blocks (empty unless built `with_shards`).
    pub fn shards(&self) -> &[ShardMetrics] {
        &self.shards
    }

    /// Count one job executed by `shard`.
    pub fn record_shard_done(&self, shard: usize) {
        if let Some(s) = self.shards.get(shard) {
            s.jobs.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Count one job `shard` stole from another shard's queue.
    pub fn record_steal(&self, shard: usize) {
        if let Some(s) = self.shards.get(shard) {
            s.stolen.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Count one soft-deadline miss on `shard`.
    pub fn record_deadline_miss(&self, shard: usize) {
        if let Some(s) = self.shards.get(shard) {
            s.deadline_miss.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Record `shard`'s current queue depth gauge.
    pub fn set_queue_depth(&self, shard: usize, depth: u64) {
        if let Some(s) = self.shards.get(shard) {
            s.queue_depth.store(depth, Ordering::Relaxed);
        }
    }

    /// Total soft-deadline misses across shards.
    pub fn deadline_misses(&self) -> u64 {
        self.shards.iter().map(|s| s.deadline_miss.load(Ordering::Relaxed)).sum()
    }

    /// Total cross-shard steals.
    pub fn steals(&self) -> u64 {
        self.shards.iter().map(|s| s.stolen.load(Ordering::Relaxed)).sum()
    }

    // --- summaries ------------------------------------------------------

    /// (completed, failed, mean latency ms).
    pub fn summary(&self) -> (u64, u64, f64) {
        let done = self.completed.load(Ordering::Relaxed);
        let failed = self.failed.load(Ordering::Relaxed);
        let mean_ms = if done == 0 {
            0.0
        } else {
            self.latency_sum_us.load(Ordering::Relaxed) as f64 / done as f64 / 1e3
        };
        (done, failed, mean_ms)
    }

    /// Latency histogram as (bucket_floor_us, count), non-empty buckets.
    pub fn latency_histogram(&self) -> Vec<(u64, u64)> {
        self.latency_us
            .iter()
            .enumerate()
            .filter_map(|(b, c)| {
                let count = c.load(Ordering::Relaxed);
                (count > 0).then_some((1u64 << b, count))
            })
            .collect()
    }

    /// Latency quantile `q` ∈ [0, 1] in **milliseconds**, resolved to
    /// the floor of the log₂ bucket holding the q-th sample (so the CLI
    /// never re-derives bucket math). `None` until a sample lands.
    pub fn quantile(&self, q: f64) -> Option<f64> {
        let counts: Vec<u64> = self.latency_us.iter().map(|c| c.load(Ordering::Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return None;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (b, &c) in counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                return Some((1u64 << b) as f64 / 1e3);
            }
        }
        Some((1u64 << (BUCKETS - 1)) as f64 / 1e3)
    }

    /// Render a one-line scrape (shard totals appended when present).
    pub fn render(&self) -> String {
        let (done, failed, mean) = self.summary();
        let mut line = format!(
            "submitted={} completed={} failed={} sparse={} dense={} mean_latency_ms={:.3}",
            self.submitted.load(Ordering::Relaxed),
            done,
            failed,
            self.sparse_jobs.load(Ordering::Relaxed),
            self.dense_jobs.load(Ordering::Relaxed),
            mean
        );
        if let (Some(p50), Some(p99)) = (self.quantile(0.50), self.quantile(0.99)) {
            line.push_str(&format!(" p50_ms={p50:.3} p99_ms={p99:.3}"));
        }
        if !self.shards.is_empty() {
            line.push_str(&format!(
                " shards={} stolen={} deadline_miss={}",
                self.shards.len(),
                self.steals(),
                self.deadline_misses()
            ));
        }
        // the robustness tallies only appear once any of them fires,
        // so fault-free scrapes render exactly as before
        let shed = self.shed.load(Ordering::Relaxed);
        let degraded = self.degraded.load(Ordering::Relaxed);
        let cancelled = self.cancelled.load(Ordering::Relaxed);
        let quarantined = self.quarantined.load(Ordering::Relaxed);
        let retries = self.retries.load(Ordering::Relaxed);
        let rejected = self.queue_rejected.load(Ordering::Relaxed);
        let respawns = self.respawns();
        if shed + degraded + cancelled + quarantined + retries + rejected + respawns > 0 {
            line.push_str(&format!(
                " shed={shed} degraded={degraded} cancelled={cancelled} \
                 quarantined={quarantined} retries={retries} rejected={rejected} \
                 respawns={respawns}"
            ));
        }
        line
    }

    /// One line per shard, for the CLI's verbose serving report.
    pub fn render_shards(&self) -> String {
        self.shards
            .iter()
            .enumerate()
            .map(|(i, s)| {
                format!(
                    "shard {i}: jobs={} stolen={} deadline_miss={} queue_depth={} \
                     cancelled={} respawns={}",
                    s.jobs.load(Ordering::Relaxed),
                    s.stolen.load(Ordering::Relaxed),
                    s.deadline_miss.load(Ordering::Relaxed),
                    s.queue_depth.load(Ordering::Relaxed),
                    s.cancelled.load(Ordering::Relaxed),
                    s.respawns.load(Ordering::Relaxed)
                )
            })
            .collect::<Vec<_>>()
            .join("\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::Engine;

    #[test]
    fn records_and_summarizes() {
        let m = Metrics::new();
        m.record_submit();
        m.record_submit();
        m.record_done(Engine::SparseCpu, 1.0, true);
        m.record_done(Engine::DenseXla, 3.0, false);
        let (done, failed, mean) = m.summary();
        assert_eq!(done, 2);
        assert_eq!(failed, 1);
        assert!((mean - 2.0).abs() < 0.01, "{mean}");
        assert_eq!(m.latency_histogram().iter().map(|&(_, c)| c).sum::<u64>(), 2);
        assert!(m.render().contains("completed=2"));
    }

    #[test]
    fn histogram_buckets_log2() {
        let m = Metrics::new();
        m.record_done(Engine::SparseCpu, 0.001, true); // 1us -> bucket 0
        m.record_done(Engine::SparseCpu, 1.0, true); // 1000us -> bucket 9
        let h = m.latency_histogram();
        assert_eq!(h.len(), 2);
        assert_eq!(h[0].0, 1);
        assert_eq!(h[1].0, 512);
    }

    #[test]
    fn out_of_range_samples_clamp_into_top_bucket() {
        let m = Metrics::new();
        // ~100 s ≫ the 2^23 us top bucket: must saturate, not vanish
        m.record_done(Engine::SparseCpu, 100_000.0, true);
        let h = m.latency_histogram();
        assert_eq!(h.len(), 1);
        assert_eq!(h[0], (1u64 << (BUCKETS - 1), 1));
        // and the quantile resolves to the top bucket floor
        assert_eq!(m.quantile(0.5), Some((1u64 << (BUCKETS - 1)) as f64 / 1e3));
    }

    #[test]
    fn quantile_resolves_bucket_floors() {
        let m = Metrics::new();
        assert_eq!(m.quantile(0.5), None);
        m.record_done(Engine::SparseCpu, 0.001, true); // bucket 0 (1us)
        m.record_done(Engine::SparseCpu, 0.001, true); // bucket 0
        m.record_done(Engine::SparseCpu, 1.0, true); // bucket 9 (512us)
        // p50: 2nd of 3 samples -> bucket 0 -> 1us = 0.001 ms
        assert_eq!(m.quantile(0.5), Some(0.001));
        // p99: 3rd sample -> bucket 9 -> 512us = 0.512 ms
        assert_eq!(m.quantile(0.99), Some(0.512));
        assert_eq!(m.quantile(0.0), Some(0.001));
        assert_eq!(m.quantile(1.0), Some(0.512));
    }

    #[test]
    fn concurrent_record_done_loses_nothing() {
        // 8 threads × 500 completions hammering the shared histogram:
        // every counter is Relaxed-atomic, so totals must be exact
        let m = std::sync::Arc::new(Metrics::new());
        let threads = 8u64;
        let per_thread = 500u64;
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let m = std::sync::Arc::clone(&m);
                std::thread::spawn(move || {
                    for i in 0..per_thread {
                        m.record_submit();
                        let engine =
                            if (t + i) % 2 == 0 { Engine::SparseCpu } else { Engine::DenseXla };
                        // spread latencies across several log₂ buckets
                        let wall_ms = 0.001 * (1 << (i % 12)) as f64;
                        m.record_done(engine, wall_ms, i % 10 == 0);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let total = threads * per_thread;
        assert_eq!(m.submitted.load(Ordering::Relaxed), total);
        let (done, failed, mean) = m.summary();
        assert_eq!(done, total);
        assert_eq!(failed, threads * per_thread.div_ceil(10));
        assert!(mean > 0.0);
        let sparse = m.sparse_jobs.load(Ordering::Relaxed);
        let dense = m.dense_jobs.load(Ordering::Relaxed);
        assert_eq!(sparse + dense, total);
        // histogram mass equals completions: no sample vanished
        let hist_total: u64 = m.latency_histogram().iter().map(|&(_, c)| c).sum();
        assert_eq!(hist_total, total);
    }

    #[test]
    fn quantiles_are_monotone_in_q() {
        let m = Metrics::new();
        // samples straddling many bucket boundaries, including repeats
        for us in [1u64, 2, 3, 8, 9, 64, 65, 1000, 1000, 65_000, 2_000_000] {
            m.record_done(Engine::SparseCpu, us as f64 / 1e3, true);
        }
        let qs = [0.0, 0.1, 0.25, 0.5, 0.75, 0.9, 0.95, 0.99, 1.0];
        let mut prev = 0.0f64;
        for q in qs {
            let v = m.quantile(q).unwrap();
            assert!(v >= prev, "quantile({q}) = {v} < quantile(prev) = {prev}");
            prev = v;
        }
        // extremes resolve to the floors of the min/max sample buckets
        assert_eq!(m.quantile(0.0), Some(0.001));
        assert_eq!(m.quantile(1.0), Some((1u64 << 20) as f64 / 1e3));
    }

    #[test]
    fn shard_gauges_consistent_under_races() {
        // each thread owns one shard id but all hammer the same Metrics
        // block; per-shard counters must not bleed into each other
        let shards = 4usize;
        let m = std::sync::Arc::new(Metrics::with_shards(shards));
        let per_shard = 300u64;
        let handles: Vec<_> = (0..shards)
            .map(|sh| {
                let m = std::sync::Arc::clone(&m);
                std::thread::spawn(move || {
                    for i in 0..per_shard {
                        m.record_shard_done(sh);
                        if i % 3 == 0 {
                            m.record_steal(sh);
                        }
                        if i % 7 == 0 {
                            m.record_deadline_miss(sh);
                        }
                        m.set_queue_depth(sh, sh as u64 * 100 + i);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        for (sh, s) in m.shards().iter().enumerate() {
            assert_eq!(s.jobs.load(Ordering::Relaxed), per_shard, "shard {sh} jobs");
            assert_eq!(
                s.stolen.load(Ordering::Relaxed),
                per_shard.div_ceil(3),
                "shard {sh} steals"
            );
            assert_eq!(
                s.deadline_miss.load(Ordering::Relaxed),
                per_shard.div_ceil(7),
                "shard {sh} misses"
            );
            // the gauge holds the owner's final store, not another
            // shard's value
            assert_eq!(s.queue_depth.load(Ordering::Relaxed), sh as u64 * 100 + per_shard - 1);
        }
        assert_eq!(m.steals(), shards as u64 * per_shard.div_ceil(3));
        assert_eq!(m.deadline_misses(), shards as u64 * per_shard.div_ceil(7));
    }

    #[test]
    fn robustness_counters_stay_exact_across_racing_shards() {
        // 8 shard threads each mixing deadline misses, sheds, cancels,
        // quarantines, retries and respawns against one Metrics block;
        // every tally must come out exact — the accounting behind the
        // chaos invariant (no outcome lost, none double-counted)
        let shards = 8usize;
        let m = std::sync::Arc::new(Metrics::with_shards(shards));
        let per_shard = 400u64;
        let handles: Vec<_> = (0..shards)
            .map(|sh| {
                let m = std::sync::Arc::clone(&m);
                std::thread::spawn(move || {
                    for i in 0..per_shard {
                        match i % 5 {
                            0 => m.record_shed(),
                            1 => m.record_degraded(),
                            2 => m.record_cancelled(sh),
                            3 => m.record_quarantined(),
                            _ => m.record_shard_done(sh),
                        }
                        if i % 3 == 0 {
                            m.record_deadline_miss(sh);
                        }
                        if i % 11 == 0 {
                            m.record_retry();
                            m.record_queue_rejected();
                        }
                        if i % 97 == 0 {
                            m.record_respawn(sh);
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let n = shards as u64;
        let per_bucket = per_shard / 5; // 400 divides evenly into 5 classes
        assert_eq!(m.shed.load(Ordering::Relaxed), n * per_bucket);
        assert_eq!(m.degraded.load(Ordering::Relaxed), n * per_bucket);
        assert_eq!(m.cancelled.load(Ordering::Relaxed), n * per_bucket);
        assert_eq!(m.quarantined.load(Ordering::Relaxed), n * per_bucket);
        assert_eq!(m.deadline_misses(), n * per_shard.div_ceil(3));
        assert_eq!(m.retries.load(Ordering::Relaxed), n * per_shard.div_ceil(11));
        assert_eq!(m.queue_rejected.load(Ordering::Relaxed), n * per_shard.div_ceil(11));
        assert_eq!(m.respawns(), n * per_shard.div_ceil(97));
        for (sh, s) in m.shards().iter().enumerate() {
            assert_eq!(s.cancelled.load(Ordering::Relaxed), per_bucket, "shard {sh} cancelled");
            assert_eq!(s.respawns.load(Ordering::Relaxed), per_shard.div_ceil(97), "shard {sh}");
        }
        let line = m.render();
        assert!(line.contains(&format!("shed={}", n * per_bucket)), "{line}");
        assert!(line.contains(&format!("respawns={}", n * per_shard.div_ceil(97))), "{line}");
        // fault-free metrics keep the legacy one-line shape
        assert!(!Metrics::with_shards(2).render().contains("shed="), "legacy shape changed");
    }

    #[test]
    fn shard_counters_roundtrip() {
        let m = Metrics::with_shards(2);
        assert_eq!(m.shards().len(), 2);
        m.record_shard_done(0);
        m.record_shard_done(1);
        m.record_shard_done(1);
        m.record_steal(1);
        m.record_deadline_miss(0);
        m.set_queue_depth(0, 7);
        assert_eq!(m.shards()[1].jobs.load(Ordering::Relaxed), 2);
        assert_eq!(m.steals(), 1);
        assert_eq!(m.deadline_misses(), 1);
        assert_eq!(m.shards()[0].queue_depth.load(Ordering::Relaxed), 7);
        // out-of-range shard ids are ignored, not panics
        m.record_shard_done(9);
        m.record_steal(9);
        m.record_deadline_miss(9);
        m.set_queue_depth(9, 1);
        let line = m.render();
        assert!(line.contains("shards=2"));
        assert!(line.contains("deadline_miss=1"));
        assert!(m.render_shards().contains("shard 1: jobs=2 stolen=1"));
        // shard-less metrics render without the shard suffix
        assert!(!Metrics::new().render().contains("shards="));
    }
}
