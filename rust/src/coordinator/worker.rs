//! Job execution: dispatch a routed request to the chosen engine.
//!
//! The sparse engine picks a pool [`Schedule`] **and a
//! [`SupportMode`]** per job: fixed overrides from
//! [`ServiceConfig`](super::service::ServiceConfig) when the operator
//! set them, otherwise per-job heuristics over the job's graph (see
//! [`choose_schedule`] and [`choose_support`]). Both choices are
//! recorded in the [`JobResult`] for provenance — the serving cost
//! model keys its per-label calibration on the support choice.

use super::job::{Engine, JobKind, JobOutput, JobRequest, JobResult};
use crate::algo::incremental::SupportMode;
use crate::algo::{decompose, kmax, triangle};
use crate::graph::Csr;
use crate::par::{ktruss_par_mode, Pool, Schedule};
use crate::runtime::DenseEngine;
use crate::util::Timer;

/// Pick a schedule from the graph's degree skew. The thresholds encode
/// the paper's load-imbalance finding: the more the max row dwarfs the
/// mean, the more a cost-aware schedule buys.
///
/// * tiny jobs → `Static` (spawn/binning overhead dominates),
/// * heavy skew (max/mean ≥ 8, the power-law hub regime) → `Stealing`
///   (estimation error is absorbed at runtime),
/// * moderate skew (≥ 3) → `WorkAware` (scan-binned chunks),
/// * near-uniform (road-network-like) → `Dynamic` (cheap and adequate).
pub fn choose_schedule(g: &Csr) -> Schedule {
    let n = g.n();
    if n == 0 || g.nnz() < 2048 {
        return Schedule::Static;
    }
    let mean = g.nnz() as f64 / n as f64;
    let max = (0..n).map(|i| g.row(i).len()).max().unwrap_or(0) as f64;
    let skew = if mean > 0.0 { max / mean } else { 0.0 };
    if skew >= 8.0 {
        Schedule::Stealing
    } else if skew >= 3.0 {
        Schedule::WorkAware
    } else {
        Schedule::Dynamic { chunk: 256 }
    }
}

/// Pick a support-maintenance mode for one job from its graph stats.
/// Cascades (many prune iterations with shrinking frontiers) are where
/// the incremental driver wins; dense low-k cores converge in one or
/// two rounds where a full recompute is already optimal:
///
/// * non-truss kinds → `Full` (their sparse paths drive the loop
///   internally; the label stays mode-free),
/// * tiny jobs → `Full` (frontier bookkeeping dominates),
/// * heavy degree skew (max/mean ≥ 8 — the hub regime whose fringes
///   peel over many rounds) → `Incremental`,
/// * everything else → `Auto` (per-round crossover decides).
pub fn choose_support(g: &Csr, kind: &JobKind) -> SupportMode {
    if !matches!(kind, JobKind::Ktruss { .. }) {
        return SupportMode::Full;
    }
    let n = g.n();
    if n == 0 || g.nnz() < 2048 {
        return SupportMode::Full;
    }
    let mean = g.nnz() as f64 / n as f64;
    let max = (0..n).map(|i| g.row(i).len()).max().unwrap_or(0) as f64;
    let skew = if mean > 0.0 { max / mean } else { 0.0 };
    if skew >= 8.0 {
        SupportMode::Incremental
    } else {
        SupportMode::Auto
    }
}

/// Stateless executor with handles to both engines.
pub struct Worker {
    /// The pool sparse jobs run on.
    pub pool: Pool,
    /// Fixed schedule override; `None` = per-job heuristic choice.
    pub schedule: Option<Schedule>,
    /// Fixed support-mode override; `None` = per-job heuristic choice.
    pub support: Option<SupportMode>,
    /// None when artifacts are unavailable (dense jobs then fall back to
    /// the sparse path with a provenance note).
    pub dense: Option<DenseEngine>,
}

impl Worker {
    /// A worker with the per-job schedule/support heuristics.
    pub fn new(pool: Pool, dense: Option<DenseEngine>) -> Worker {
        Worker { pool, schedule: None, support: None, dense }
    }

    /// A worker with an explicit schedule override (`None` keeps the
    /// heuristic); support mode stays heuristic.
    pub fn with_schedule(pool: Pool, dense: Option<DenseEngine>, schedule: Option<Schedule>) -> Worker {
        Worker { pool, schedule, support: None, dense }
    }

    /// A worker with explicit schedule and support-mode overrides
    /// (`None` keeps the respective heuristic).
    pub fn with_policy(
        pool: Pool,
        dense: Option<DenseEngine>,
        schedule: Option<Schedule>,
        support: Option<SupportMode>,
    ) -> Worker {
        Worker { pool, schedule, support, dense }
    }

    /// The schedule this worker runs `req` under.
    pub fn pick_schedule(&self, req: &JobRequest) -> Schedule {
        self.schedule.unwrap_or_else(|| choose_schedule(&req.graph))
    }

    /// The support mode this worker runs `req` under.
    pub fn pick_support(&self, req: &JobRequest) -> SupportMode {
        self.support
            .unwrap_or_else(|| choose_support(&req.graph, &req.kind))
    }

    /// Schedule and support mode for the sparse engine: `Some` only for
    /// job kinds whose sparse path actually runs on the pool (fixed-k
    /// truss). Kmax, decompose and triangle counting execute sequential
    /// algorithms, so no policy is picked (or paid for) there.
    fn sparse_policy(&self, req: &JobRequest) -> Option<(Schedule, SupportMode)> {
        match req.kind {
            JobKind::Ktruss { .. } => Some((self.pick_schedule(req), self.pick_support(req))),
            _ => None,
        }
    }

    /// Execute one request on `engine` (already routed).
    pub fn execute(&self, req: &JobRequest, engine: Engine) -> JobResult {
        let t = Timer::start();
        let (engine_used, policy, output) = match engine {
            Engine::DenseXla => match self.execute_dense(req) {
                Ok(out) => (Engine::DenseXla, None, Ok(out)),
                // dense failure (missing artifacts, size) falls back
                Err(_) => {
                    let p = self.sparse_policy(req);
                    let (s, m) = p.unwrap_or((Schedule::Static, SupportMode::Auto));
                    let out = self.execute_sparse(req, s, m);
                    (Engine::SparseCpu, p, out)
                }
            },
            Engine::SparseCpu => {
                let p = self.sparse_policy(req);
                let (s, m) = p.unwrap_or((Schedule::Static, SupportMode::Auto));
                let out = self.execute_sparse(req, s, m);
                (Engine::SparseCpu, p, out)
            }
        };
        JobResult {
            id: req.id,
            engine: engine_used,
            schedule: policy.map(|(s, _)| s),
            support: policy.map(|(_, m)| m),
            wall_ms: t.elapsed_ms(),
            output: output.map_err(|e| format!("{e:#}")),
        }
    }

    fn execute_sparse(
        &self,
        req: &JobRequest,
        schedule: Schedule,
        support: SupportMode,
    ) -> anyhow::Result<JobOutput> {
        Ok(match req.kind {
            JobKind::Ktruss { k, mode } => {
                let r = ktruss_par_mode(&req.graph, k, &self.pool, mode, schedule, support);
                JobOutput::Ktruss {
                    truss_edges: r.truss.nnz(),
                    iterations: r.iterations,
                    edges: r.truss.edges().collect(),
                }
            }
            JobKind::Kmax => {
                let r = kmax::kmax(&req.graph);
                JobOutput::Kmax { kmax: r.kmax, truss_edges: r.truss.nnz() }
            }
            JobKind::Decompose => {
                let d = decompose::decompose(&req.graph);
                JobOutput::Decompose { kmax: d.kmax, histogram: d.histogram() }
            }
            JobKind::Triangles => {
                JobOutput::Triangles { count: triangle::count_triangles(&req.graph) }
            }
        })
    }

    fn execute_dense(&self, req: &JobRequest) -> anyhow::Result<JobOutput> {
        let dense = self
            .dense
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("dense engine unavailable"))?;
        match req.kind {
            JobKind::Ktruss { k, mode: _ } => {
                let (truss, iterations) = dense.ktruss(&req.graph, k)?;
                Ok(JobOutput::Ktruss {
                    truss_edges: truss.nnz(),
                    iterations,
                    edges: truss.edges().collect(),
                })
            }
            _ => anyhow::bail!("dense engine only serves fixed-k truss"),
        }
    }
}

/// Convenience: run a ktruss job for tests without a full service.
pub fn run_inline(req: &JobRequest, engine: Engine) -> JobResult {
    let worker = Worker::new(Pool::new(2), None);
    worker.execute(req, engine)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::support::Mode;
    use crate::graph::builder::from_sorted_unique;
    use std::sync::Arc;

    fn diamond_req(kind: JobKind) -> JobRequest {
        let g = from_sorted_unique(4, &[(0, 1), (0, 2), (0, 3), (1, 2), (2, 3)]);
        JobRequest { id: 7, graph: Arc::new(g), kind }
    }

    #[test]
    fn sparse_ktruss_job() {
        let r = run_inline(
            &diamond_req(JobKind::Ktruss { k: 3, mode: Mode::Fine }),
            Engine::SparseCpu,
        );
        assert_eq!(r.id, 7);
        assert_eq!(r.engine, Engine::SparseCpu);
        // a tiny job must have been scheduled statically, full recompute
        assert_eq!(r.schedule, Some(Schedule::Static));
        assert_eq!(r.support, Some(SupportMode::Full));
        match r.output.unwrap() {
            JobOutput::Ktruss { truss_edges, .. } => assert_eq!(truss_edges, 5),
            other => panic!("wrong output {other:?}"),
        }
    }

    #[test]
    fn kmax_and_decompose_and_triangles() {
        match run_inline(&diamond_req(JobKind::Kmax), Engine::SparseCpu).output.unwrap() {
            JobOutput::Kmax { kmax, .. } => assert_eq!(kmax, 3),
            other => panic!("{other:?}"),
        }
        match run_inline(&diamond_req(JobKind::Decompose), Engine::SparseCpu).output.unwrap() {
            JobOutput::Decompose { kmax, histogram } => {
                assert_eq!(kmax, 3);
                assert_eq!(histogram.iter().map(|&(_, c)| c).sum::<usize>(), 5);
            }
            other => panic!("{other:?}"),
        }
        match run_inline(&diamond_req(JobKind::Triangles), Engine::SparseCpu).output.unwrap() {
            JobOutput::Triangles { count } => assert_eq!(count, 2),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn dense_request_without_artifacts_falls_back() {
        let r = run_inline(
            &diamond_req(JobKind::Ktruss { k: 3, mode: Mode::Coarse }),
            Engine::DenseXla,
        );
        // no dense engine in run_inline -> sparse fallback, still correct
        assert_eq!(r.engine, Engine::SparseCpu);
        assert!(r.schedule.is_some(), "fallback must record its schedule");
        match r.output.unwrap() {
            JobOutput::Ktruss { truss_edges, .. } => assert_eq!(truss_edges, 5),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn schedule_override_wins_over_heuristic() {
        let worker = Worker::with_schedule(Pool::new(2), None, Some(Schedule::Stealing));
        let req = diamond_req(JobKind::Ktruss { k: 3, mode: Mode::Fine });
        assert_eq!(worker.pick_schedule(&req), Schedule::Stealing);
        let r = worker.execute(&req, Engine::SparseCpu);
        assert_eq!(r.schedule, Some(Schedule::Stealing));
        match r.output.unwrap() {
            JobOutput::Ktruss { truss_edges, .. } => assert_eq!(truss_edges, 5),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn support_override_wins_and_is_recorded() {
        let worker = Worker::with_policy(
            Pool::new(2),
            None,
            Some(Schedule::WorkAware),
            Some(SupportMode::Incremental),
        );
        let req = diamond_req(JobKind::Ktruss { k: 3, mode: Mode::Fine });
        assert_eq!(worker.pick_support(&req), SupportMode::Incremental);
        let r = worker.execute(&req, Engine::SparseCpu);
        assert_eq!(r.support, Some(SupportMode::Incremental));
        assert_eq!(r.schedule, Some(Schedule::WorkAware));
        match r.output.unwrap() {
            JobOutput::Ktruss { truss_edges, .. } => assert_eq!(truss_edges, 5),
            other => panic!("{other:?}"),
        }
        // non-truss kinds record no support policy
        let r = worker.execute(&diamond_req(JobKind::Triangles), Engine::SparseCpu);
        assert_eq!(r.support, None);
        assert_eq!(r.schedule, None);
    }

    #[test]
    fn support_heuristic_tracks_shape() {
        let kt = JobKind::Ktruss { k: 3, mode: Mode::Fine };
        // tiny → full
        let tiny = from_sorted_unique(3, &[(0, 1), (1, 2)]);
        assert_eq!(choose_support(&tiny, &kt), SupportMode::Full);
        // hub-heavy → incremental (cascading fringe peels)
        let hub = crate::gen::rmat::rmat(
            4000,
            24_000,
            crate::gen::rmat::RmatParams::autonomous_system(),
            &mut crate::util::Rng::new(5),
        );
        assert!(matches!(
            choose_support(&hub, &kt),
            SupportMode::Incremental | SupportMode::Auto
        ));
        // near-uniform road lattice → auto (crossover decides per round)
        let road = crate::gen::grid::road(4000, 5600, 0.05, &mut crate::util::Rng::new(6));
        assert_eq!(choose_support(&road, &kt), SupportMode::Auto);
        // non-truss kinds never pick a mode
        assert_eq!(choose_support(&hub, &JobKind::Kmax), SupportMode::Full);
    }

    #[test]
    fn heuristic_tracks_skew() {
        // tiny → static
        let tiny = from_sorted_unique(3, &[(0, 1), (1, 2)]);
        assert_eq!(choose_schedule(&tiny), Schedule::Static);
        // hub-heavy rmat → a cost-aware schedule
        let hub = crate::gen::rmat::rmat(
            4000,
            24_000,
            crate::gen::rmat::RmatParams::autonomous_system(),
            &mut crate::util::Rng::new(5),
        );
        assert!(matches!(
            choose_schedule(&hub),
            Schedule::WorkAware | Schedule::Stealing
        ));
        // near-uniform road lattice → dynamic
        let road = crate::gen::grid::road(4000, 5600, 0.05, &mut crate::util::Rng::new(6));
        assert!(matches!(
            choose_schedule(&road),
            Schedule::Dynamic { .. } | Schedule::WorkAware
        ));
    }
}
