//! Job execution: dispatch a routed request to the chosen engine.
//!
//! The sparse engine runs every fixed-k truss job under one
//! [`ExecutionPlan`] — schedule × granularity × support mode ×
//! crossover, decided by [`crate::plan::Planner`]. The serving executor
//! computes the plan **once at submit time** and carries it through the
//! admission queue ([`Worker::execute_planned`] receives it); direct
//! callers without a precomputed plan get one from this worker's own
//! planner. The executed plan is recorded in the [`JobResult`] for
//! provenance — the serving cost model keys its per-label calibration
//! on the plan's support mode.

use super::job::{Engine, JobKind, JobOutcome, JobOutput, JobRequest, JobResult};
use crate::algo::{decompose, kmax, triangle};
use crate::par::{ktruss_par_plan_ctl, PassControl, Pool};
use crate::plan::{ExecutionPlan, PlanSpec, Planner};
use crate::runtime::DenseEngine;
use crate::util::Timer;

/// Stateless executor with handles to both engines.
pub struct Worker {
    /// The pool sparse jobs run on.
    pub pool: Pool,
    /// Planner for jobs that arrive without a precomputed plan (its
    /// spec carries the operator's pinned axes; its thread count is the
    /// pool's width).
    pub planner: Planner,
    /// None when artifacts are unavailable (dense jobs then fall back to
    /// the sparse path with a provenance note).
    pub dense: Option<DenseEngine>,
}

impl Worker {
    /// A worker whose planner chooses every axis per job.
    pub fn new(pool: Pool, dense: Option<DenseEngine>) -> Worker {
        Worker::with_spec(pool, dense, PlanSpec::auto())
    }

    /// A worker with operator-pinned plan axes (`PlanSpec::auto()` for
    /// fully per-job planning).
    pub fn with_spec(pool: Pool, dense: Option<DenseEngine>, spec: PlanSpec) -> Worker {
        let planner = Planner::new(pool.workers()).with_spec(spec);
        Worker { pool, planner, dense }
    }

    /// The plan this worker would run `req` under: `Some` only for job
    /// kinds whose sparse path actually runs on the pool (fixed-k
    /// truss, and mutation batches whose frontier passes it drives).
    /// Kmax, decompose and triangle counting execute sequential
    /// algorithms, so no plan is computed (or paid for) there.
    pub fn pick_plan(&self, req: &JobRequest) -> Option<ExecutionPlan> {
        match req.kind {
            JobKind::Ktruss { k, .. } => Some(self.planner.choose(&req.graph, k)),
            JobKind::Mutate { ref store, .. } => {
                Some(self.planner.choose(&req.graph, store.k()))
            }
            _ => None,
        }
    }

    /// Execute one request on `engine` (already routed), planning here.
    pub fn execute(&self, req: &JobRequest, engine: Engine) -> JobResult {
        self.execute_planned(req, engine, None)
    }

    /// Execute one request on `engine` under a precomputed plan. The
    /// serving executor passes the submit-time plan so the max-degree
    /// scan and candidate scoring run exactly once per job; `None`
    /// plans (direct callers, non-truss kinds) fall back to
    /// [`Worker::pick_plan`].
    pub fn execute_planned(
        &self,
        req: &JobRequest,
        engine: Engine,
        plan: Option<ExecutionPlan>,
    ) -> JobResult {
        self.execute_planned_ctl(req, engine, plan, PassControl::default())
    }

    /// [`execute_planned`](Worker::execute_planned) under a
    /// cooperative [`PassControl`]: pool-driven kinds (fixed-k truss,
    /// mutation batches) observe the token at their pass/stage
    /// boundaries and stop early, reporting
    /// [`JobOutcome::Cancelled`] with the partial work discarded (the
    /// `output` is an `Err`, the recorded passes are exactly the ones
    /// that executed). Sequential kinds (kmax, decompose, triangles)
    /// have no boundaries to observe — the serving executor enforces
    /// their deadlines before dispatch instead.
    pub fn execute_planned_ctl(
        &self,
        req: &JobRequest,
        engine: Engine,
        plan: Option<ExecutionPlan>,
        ctl: PassControl<'_>,
    ) -> JobResult {
        let t = Timer::start();
        let sparse_plan = |w: &Worker| plan.or_else(|| w.pick_plan(req));
        let (engine_used, used_plan, output) = match engine {
            Engine::DenseXla => match self.execute_dense(req) {
                Ok(out) => (Engine::DenseXla, None, Ok((out, Vec::new(), false))),
                // dense failure (missing artifacts, size) falls back
                Err(_) => {
                    let p = sparse_plan(self);
                    let out = self.execute_sparse(req, p, ctl);
                    (Engine::SparseCpu, p, out)
                }
            },
            Engine::SparseCpu => {
                let p = sparse_plan(self);
                let out = self.execute_sparse(req, p, ctl);
                (Engine::SparseCpu, p, out)
            }
        };
        let (output, passes, cancelled) = match output {
            Ok((out, passes, cancelled)) => (Ok(out), passes, cancelled),
            Err(e) => (Err(format!("{e:#}")), Vec::new(), false),
        };
        // a cancelled run's partial payload is not a usable answer —
        // surface the termination, keep the executed passes for the
        // span (their steps still sum to the measured total)
        let (outcome, output) = if cancelled {
            (JobOutcome::Cancelled, Err("cancelled at a pass boundary (deadline)".to_string()))
        } else {
            (JobOutcome::Done, output)
        };
        JobResult {
            id: req.id,
            engine: engine_used,
            plan: used_plan,
            schedule: used_plan.map(|p| p.schedule),
            support: used_plan.map(|p| p.support),
            wall_ms: t.elapsed_ms(),
            passes,
            outcome,
            output,
        }
    }

    fn execute_sparse(
        &self,
        req: &JobRequest,
        plan: Option<ExecutionPlan>,
        ctl: PassControl<'_>,
    ) -> anyhow::Result<(JobOutput, Vec<crate::obs::span::PassSpan>, bool)> {
        Ok(match req.kind {
            JobKind::Ktruss { k, mode } => {
                // truss jobs always carry a plan by construction; the
                // fallback pins the requested mode defensively
                let plan = plan.unwrap_or_else(|| {
                    ExecutionPlan::fixed(
                        crate::par::Schedule::Static,
                        mode.into(),
                        crate::algo::incremental::SupportMode::Auto,
                    )
                });
                let (r, cancelled) = ktruss_par_plan_ctl(&req.graph, k, &self.pool, &plan, ctl);
                let passes = crate::obs::span::passes_from_stats(&r.stats);
                (
                    JobOutput::Ktruss {
                        truss_edges: r.truss.nnz(),
                        iterations: r.iterations,
                        edges: r.truss.edges().collect(),
                    },
                    passes,
                    cancelled,
                )
            }
            JobKind::Kmax => {
                let r = kmax::kmax(&req.graph);
                (JobOutput::Kmax { kmax: r.kmax, truss_edges: r.truss.nnz() }, Vec::new(), false)
            }
            JobKind::Decompose => {
                let d = decompose::decompose(&req.graph);
                (
                    JobOutput::Decompose { kmax: d.kmax, histogram: d.histogram() },
                    Vec::new(),
                    false,
                )
            }
            JobKind::Triangles => (
                JobOutput::Triangles { count: triangle::count_triangles(&req.graph) },
                Vec::new(),
                false,
            ),
            JobKind::Mutate { ref store, ref batch } => {
                let applied = match plan {
                    Some(p) => store.apply_par_ctl(batch, &self.pool, &p, ctl),
                    None => Some(store.apply(batch)),
                };
                let Some((snap, out)) = applied else {
                    // cancelled at a stage boundary: the staged batch
                    // was discarded, nothing was published
                    return Ok((
                        JobOutput::Mutate {
                            epoch: store.epoch(),
                            inserted: 0,
                            deleted: 0,
                            rejected: 0,
                            recomputed: false,
                            truss_edges: 0,
                        },
                        Vec::new(),
                        true,
                    ));
                };
                // pass 0: the frontier decrement/increment sweep;
                // pass 1 (when taken): the re-convergence tail
                let mut passes = vec![crate::obs::span::PassSpan {
                    iter: 0,
                    incremental: true,
                    live_edges: snap.graph.nnz(),
                    removed: out.deleted,
                    steps: out.frontier_steps,
                    tasks: out.inserted + out.deleted,
                    wall_ms: 0.0,
                }];
                if out.recomputed {
                    passes.push(crate::obs::span::PassSpan {
                        iter: 1,
                        incremental: true,
                        live_edges: snap.graph.nnz(),
                        removed: 0,
                        steps: out.converge_steps,
                        tasks: 0,
                        wall_ms: 0.0,
                    });
                }
                (
                    JobOutput::Mutate {
                        epoch: snap.epoch,
                        inserted: out.inserted,
                        deleted: out.deleted,
                        rejected: out.rejected,
                        recomputed: out.recomputed,
                        truss_edges: out.truss_edges,
                    },
                    passes,
                    false,
                )
            }
        })
    }

    fn execute_dense(&self, req: &JobRequest) -> anyhow::Result<JobOutput> {
        let dense = self
            .dense
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("dense engine unavailable"))?;
        match req.kind {
            JobKind::Ktruss { k, mode: _ } => {
                let (truss, iterations) = dense.ktruss(&req.graph, k)?;
                Ok(JobOutput::Ktruss {
                    truss_edges: truss.nnz(),
                    iterations,
                    edges: truss.edges().collect(),
                })
            }
            _ => anyhow::bail!("dense engine only serves fixed-k truss"),
        }
    }
}

/// Convenience: run a ktruss job for tests without a full service.
pub fn run_inline(req: &JobRequest, engine: Engine) -> JobResult {
    let worker = Worker::new(Pool::new(2), None);
    worker.execute(req, engine)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::incremental::SupportMode;
    use crate::algo::support::{Granularity, Mode};
    use crate::graph::builder::from_sorted_unique;
    use crate::par::Schedule;
    use std::sync::Arc;

    fn diamond_req(kind: JobKind) -> JobRequest {
        let g = from_sorted_unique(4, &[(0, 1), (0, 2), (0, 3), (1, 2), (2, 3)]);
        JobRequest { id: 7, graph: Arc::new(g), kind }
    }

    #[test]
    fn sparse_ktruss_job() {
        let r = run_inline(
            &diamond_req(JobKind::Ktruss { k: 3, mode: Mode::Fine }),
            Engine::SparseCpu,
        );
        assert_eq!(r.id, 7);
        assert_eq!(r.engine, Engine::SparseCpu);
        // a tiny job must have been planned static/coarse/full
        let plan = r.plan.expect("truss jobs carry their plan");
        assert_eq!(plan.schedule, Schedule::Static);
        assert_eq!(plan.granularity, Granularity::Coarse);
        assert_eq!(plan.support, SupportMode::Full);
        // the flat provenance mirrors the plan
        assert_eq!(r.schedule, Some(Schedule::Static));
        assert_eq!(r.support, Some(SupportMode::Full));
        match r.output.unwrap() {
            JobOutput::Ktruss { truss_edges, .. } => assert_eq!(truss_edges, 5),
            other => panic!("wrong output {other:?}"),
        }
    }

    #[test]
    fn kmax_and_decompose_and_triangles() {
        match run_inline(&diamond_req(JobKind::Kmax), Engine::SparseCpu).output.unwrap() {
            JobOutput::Kmax { kmax, .. } => assert_eq!(kmax, 3),
            other => panic!("{other:?}"),
        }
        match run_inline(&diamond_req(JobKind::Decompose), Engine::SparseCpu).output.unwrap() {
            JobOutput::Decompose { kmax, histogram } => {
                assert_eq!(kmax, 3);
                assert_eq!(histogram.iter().map(|&(_, c)| c).sum::<usize>(), 5);
            }
            other => panic!("{other:?}"),
        }
        match run_inline(&diamond_req(JobKind::Triangles), Engine::SparseCpu).output.unwrap() {
            JobOutput::Triangles { count } => assert_eq!(count, 2),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn dense_request_without_artifacts_falls_back() {
        let r = run_inline(
            &diamond_req(JobKind::Ktruss { k: 3, mode: Mode::Coarse }),
            Engine::DenseXla,
        );
        // no dense engine in run_inline -> sparse fallback, still correct
        assert_eq!(r.engine, Engine::SparseCpu);
        assert!(r.plan.is_some(), "fallback must record its plan");
        match r.output.unwrap() {
            JobOutput::Ktruss { truss_edges, .. } => assert_eq!(truss_edges, 5),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn pinned_spec_wins_over_planner() {
        let spec: crate::plan::PlanSpec = "stealing/fine/incremental".parse().unwrap();
        let worker = Worker::with_spec(Pool::new(2), None, spec);
        let req = diamond_req(JobKind::Ktruss { k: 3, mode: Mode::Fine });
        let plan = worker.pick_plan(&req).unwrap();
        assert_eq!(plan.schedule, Schedule::Stealing);
        assert_eq!(plan.granularity, Granularity::Fine);
        assert_eq!(plan.support, SupportMode::Incremental);
        let r = worker.execute(&req, Engine::SparseCpu);
        assert_eq!(r.plan, Some(plan));
        assert_eq!(r.schedule, Some(Schedule::Stealing));
        assert_eq!(r.support, Some(SupportMode::Incremental));
        match r.output.unwrap() {
            JobOutput::Ktruss { truss_edges, .. } => assert_eq!(truss_edges, 5),
            other => panic!("{other:?}"),
        }
        // non-truss kinds record no plan
        let r = worker.execute(&diamond_req(JobKind::Triangles), Engine::SparseCpu);
        assert_eq!(r.plan, None);
        assert_eq!(r.schedule, None);
        assert_eq!(r.support, None);
    }

    #[test]
    fn precomputed_plan_is_used_verbatim() {
        // the executor's submit-time plan must not be re-derived
        let worker = Worker::new(Pool::new(2), None);
        let req = diamond_req(JobKind::Ktruss { k: 3, mode: Mode::Fine });
        let submitted = ExecutionPlan::fixed(
            Schedule::WorkAware,
            Granularity::Segment { len: 4 },
            SupportMode::Auto,
        );
        let r = worker.execute_planned(&req, Engine::SparseCpu, Some(submitted));
        assert_eq!(r.plan, Some(submitted));
        match r.output.unwrap() {
            JobOutput::Ktruss { truss_edges, .. } => assert_eq!(truss_edges, 5),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn cancelled_execution_reports_cancelled_outcome() {
        use crate::par::{CancelToken, PassControl};
        let worker = Worker::new(Pool::new(2), None);
        let g = crate::testkit::graphs::peel_chain(24);
        let req = JobRequest {
            id: 9,
            graph: Arc::new(g),
            kind: JobKind::Ktruss { k: 3, mode: Mode::Fine },
        };
        let tok = CancelToken::new();
        tok.cancel();
        let r = worker.execute_planned_ctl(
            &req,
            Engine::SparseCpu,
            None,
            PassControl { cancel: Some(&tok), on_pass: None },
        );
        assert_eq!(r.outcome, JobOutcome::Cancelled);
        assert!(r.output.is_err(), "a cancelled run must not report a usable payload");
        assert!(!r.passes.is_empty(), "the executed passes stay recorded");
        // the same request uncancelled completes normally
        let r = worker.execute(&req, Engine::SparseCpu);
        assert_eq!(r.outcome, JobOutcome::Done);
        assert!(r.output.is_ok());
    }

    #[test]
    fn planner_tracks_shape_through_the_worker() {
        // wide pool so the planner sees the same machine the shape
        // tests exercise; the hub fixture must not run coarse
        let worker = Worker::new(Pool::new(4), None);
        let hub = Arc::new(crate::testkit::graphs::star_with_fringe(1200));
        let req = JobRequest {
            id: 1,
            graph: hub,
            kind: JobKind::Ktruss { k: 3, mode: Mode::Fine },
        };
        let plan = worker.pick_plan(&req).unwrap();
        assert_ne!(plan.granularity, Granularity::Coarse, "{plan}");
        // every executed plan produces the correct truss
        let want = crate::algo::ktruss::ktruss(&req.graph, 3, Mode::Fine).truss.nnz();
        let r = worker.execute(&req, Engine::SparseCpu);
        match r.output.unwrap() {
            JobOutput::Ktruss { truss_edges, .. } => assert_eq!(truss_edges, want),
            other => panic!("{other:?}"),
        }
    }
}
