//! Job execution: dispatch a routed request to the chosen engine.
//!
//! The sparse engine picks a pool [`Schedule`] **per job**: a fixed
//! override from [`ServiceConfig`](super::service::ServiceConfig) when
//! the operator set one, otherwise a skew heuristic over the job's
//! graph (see [`choose_schedule`]). The chosen schedule is recorded in
//! the [`JobResult`] for provenance.

use super::job::{Engine, JobKind, JobOutput, JobRequest, JobResult};
use crate::algo::{decompose, kmax, triangle};
use crate::graph::Csr;
use crate::par::{ktruss_par, Pool, Schedule};
use crate::runtime::DenseEngine;
use crate::util::Timer;

/// Pick a schedule from the graph's degree skew. The thresholds encode
/// the paper's load-imbalance finding: the more the max row dwarfs the
/// mean, the more a cost-aware schedule buys.
///
/// * tiny jobs → `Static` (spawn/binning overhead dominates),
/// * heavy skew (max/mean ≥ 8, the power-law hub regime) → `Stealing`
///   (estimation error is absorbed at runtime),
/// * moderate skew (≥ 3) → `WorkAware` (scan-binned chunks),
/// * near-uniform (road-network-like) → `Dynamic` (cheap and adequate).
pub fn choose_schedule(g: &Csr) -> Schedule {
    let n = g.n();
    if n == 0 || g.nnz() < 2048 {
        return Schedule::Static;
    }
    let mean = g.nnz() as f64 / n as f64;
    let max = (0..n).map(|i| g.row(i).len()).max().unwrap_or(0) as f64;
    let skew = if mean > 0.0 { max / mean } else { 0.0 };
    if skew >= 8.0 {
        Schedule::Stealing
    } else if skew >= 3.0 {
        Schedule::WorkAware
    } else {
        Schedule::Dynamic { chunk: 256 }
    }
}

/// Stateless executor with handles to both engines.
pub struct Worker {
    /// The pool sparse jobs run on.
    pub pool: Pool,
    /// Fixed schedule override; `None` = per-job heuristic choice.
    pub schedule: Option<Schedule>,
    /// None when artifacts are unavailable (dense jobs then fall back to
    /// the sparse path with a provenance note).
    pub dense: Option<DenseEngine>,
}

impl Worker {
    /// A worker with the per-job schedule heuristic.
    pub fn new(pool: Pool, dense: Option<DenseEngine>) -> Worker {
        Worker { pool, schedule: None, dense }
    }

    /// A worker with an explicit schedule override (`None` keeps the
    /// heuristic).
    pub fn with_schedule(pool: Pool, dense: Option<DenseEngine>, schedule: Option<Schedule>) -> Worker {
        Worker { pool, schedule, dense }
    }

    /// The schedule this worker runs `req` under.
    pub fn pick_schedule(&self, req: &JobRequest) -> Schedule {
        self.schedule.unwrap_or_else(|| choose_schedule(&req.graph))
    }

    /// Schedule for the sparse engine: `Some` only for job kinds whose
    /// sparse path actually runs on the pool (fixed-k truss). Kmax,
    /// decompose and triangle counting execute sequential algorithms,
    /// so no schedule is picked (or paid for) there.
    fn sparse_schedule(&self, req: &JobRequest) -> Option<Schedule> {
        match req.kind {
            JobKind::Ktruss { .. } => Some(self.pick_schedule(req)),
            _ => None,
        }
    }

    /// Execute one request on `engine` (already routed).
    pub fn execute(&self, req: &JobRequest, engine: Engine) -> JobResult {
        let t = Timer::start();
        let (engine_used, schedule, output) = match engine {
            Engine::DenseXla => match self.execute_dense(req) {
                Ok(out) => (Engine::DenseXla, None, Ok(out)),
                // dense failure (missing artifacts, size) falls back
                Err(_) => {
                    let s = self.sparse_schedule(req);
                    let out = self.execute_sparse(req, s.unwrap_or(Schedule::Static));
                    (Engine::SparseCpu, s, out)
                }
            },
            Engine::SparseCpu => {
                let s = self.sparse_schedule(req);
                let out = self.execute_sparse(req, s.unwrap_or(Schedule::Static));
                (Engine::SparseCpu, s, out)
            }
        };
        JobResult {
            id: req.id,
            engine: engine_used,
            schedule,
            wall_ms: t.elapsed_ms(),
            output: output.map_err(|e| format!("{e:#}")),
        }
    }

    fn execute_sparse(&self, req: &JobRequest, schedule: Schedule) -> anyhow::Result<JobOutput> {
        Ok(match req.kind {
            JobKind::Ktruss { k, mode } => {
                let r = ktruss_par(&req.graph, k, &self.pool, mode, schedule);
                JobOutput::Ktruss {
                    truss_edges: r.truss.nnz(),
                    iterations: r.iterations,
                    edges: r.truss.edges().collect(),
                }
            }
            JobKind::Kmax => {
                let r = kmax::kmax(&req.graph);
                JobOutput::Kmax { kmax: r.kmax, truss_edges: r.truss.nnz() }
            }
            JobKind::Decompose => {
                let d = decompose::decompose(&req.graph);
                JobOutput::Decompose { kmax: d.kmax, histogram: d.histogram() }
            }
            JobKind::Triangles => {
                JobOutput::Triangles { count: triangle::count_triangles(&req.graph) }
            }
        })
    }

    fn execute_dense(&self, req: &JobRequest) -> anyhow::Result<JobOutput> {
        let dense = self
            .dense
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("dense engine unavailable"))?;
        match req.kind {
            JobKind::Ktruss { k, mode: _ } => {
                let (truss, iterations) = dense.ktruss(&req.graph, k)?;
                Ok(JobOutput::Ktruss {
                    truss_edges: truss.nnz(),
                    iterations,
                    edges: truss.edges().collect(),
                })
            }
            _ => anyhow::bail!("dense engine only serves fixed-k truss"),
        }
    }
}

/// Convenience: run a ktruss job for tests without a full service.
pub fn run_inline(req: &JobRequest, engine: Engine) -> JobResult {
    let worker = Worker::new(Pool::new(2), None);
    worker.execute(req, engine)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::support::Mode;
    use crate::graph::builder::from_sorted_unique;
    use std::sync::Arc;

    fn diamond_req(kind: JobKind) -> JobRequest {
        let g = from_sorted_unique(4, &[(0, 1), (0, 2), (0, 3), (1, 2), (2, 3)]);
        JobRequest { id: 7, graph: Arc::new(g), kind }
    }

    #[test]
    fn sparse_ktruss_job() {
        let r = run_inline(
            &diamond_req(JobKind::Ktruss { k: 3, mode: Mode::Fine }),
            Engine::SparseCpu,
        );
        assert_eq!(r.id, 7);
        assert_eq!(r.engine, Engine::SparseCpu);
        // a tiny job must have been scheduled statically
        assert_eq!(r.schedule, Some(Schedule::Static));
        match r.output.unwrap() {
            JobOutput::Ktruss { truss_edges, .. } => assert_eq!(truss_edges, 5),
            other => panic!("wrong output {other:?}"),
        }
    }

    #[test]
    fn kmax_and_decompose_and_triangles() {
        match run_inline(&diamond_req(JobKind::Kmax), Engine::SparseCpu).output.unwrap() {
            JobOutput::Kmax { kmax, .. } => assert_eq!(kmax, 3),
            other => panic!("{other:?}"),
        }
        match run_inline(&diamond_req(JobKind::Decompose), Engine::SparseCpu).output.unwrap() {
            JobOutput::Decompose { kmax, histogram } => {
                assert_eq!(kmax, 3);
                assert_eq!(histogram.iter().map(|&(_, c)| c).sum::<usize>(), 5);
            }
            other => panic!("{other:?}"),
        }
        match run_inline(&diamond_req(JobKind::Triangles), Engine::SparseCpu).output.unwrap() {
            JobOutput::Triangles { count } => assert_eq!(count, 2),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn dense_request_without_artifacts_falls_back() {
        let r = run_inline(
            &diamond_req(JobKind::Ktruss { k: 3, mode: Mode::Coarse }),
            Engine::DenseXla,
        );
        // no dense engine in run_inline -> sparse fallback, still correct
        assert_eq!(r.engine, Engine::SparseCpu);
        assert!(r.schedule.is_some(), "fallback must record its schedule");
        match r.output.unwrap() {
            JobOutput::Ktruss { truss_edges, .. } => assert_eq!(truss_edges, 5),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn schedule_override_wins_over_heuristic() {
        let worker = Worker::with_schedule(Pool::new(2), None, Some(Schedule::Stealing));
        let req = diamond_req(JobKind::Ktruss { k: 3, mode: Mode::Fine });
        assert_eq!(worker.pick_schedule(&req), Schedule::Stealing);
        let r = worker.execute(&req, Engine::SparseCpu);
        assert_eq!(r.schedule, Some(Schedule::Stealing));
        match r.output.unwrap() {
            JobOutput::Ktruss { truss_edges, .. } => assert_eq!(truss_edges, 5),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn heuristic_tracks_skew() {
        // tiny → static
        let tiny = from_sorted_unique(3, &[(0, 1), (1, 2)]);
        assert_eq!(choose_schedule(&tiny), Schedule::Static);
        // hub-heavy rmat → a cost-aware schedule
        let hub = crate::gen::rmat::rmat(
            4000,
            24_000,
            crate::gen::rmat::RmatParams::autonomous_system(),
            &mut crate::util::Rng::new(5),
        );
        assert!(matches!(
            choose_schedule(&hub),
            Schedule::WorkAware | Schedule::Stealing
        ));
        // near-uniform road lattice → dynamic
        let road = crate::gen::grid::road(4000, 5600, 0.05, &mut crate::util::Rng::new(6));
        assert!(matches!(
            choose_schedule(&road),
            Schedule::Dynamic { .. } | Schedule::WorkAware
        ));
    }
}
