//! Job execution: dispatch a routed request to the chosen engine.

use super::job::{Engine, JobKind, JobOutput, JobRequest, JobResult};
use crate::algo::{decompose, kmax, triangle};
use crate::par::{ktruss_par, Pool, Schedule};
use crate::runtime::DenseEngine;
use crate::util::Timer;

/// Stateless executor with handles to both engines.
pub struct Worker {
    pub pool: Pool,
    pub schedule: Schedule,
    /// None when artifacts are unavailable (dense jobs then fall back to
    /// the sparse path with a provenance note).
    pub dense: Option<DenseEngine>,
}

impl Worker {
    pub fn new(pool: Pool, dense: Option<DenseEngine>) -> Worker {
        Worker { pool, schedule: Schedule::Dynamic { chunk: 256 }, dense }
    }

    /// Execute one request on `engine` (already routed).
    pub fn execute(&self, req: &JobRequest, engine: Engine) -> JobResult {
        let t = Timer::start();
        let (engine_used, output) = match engine {
            Engine::DenseXla => match self.execute_dense(req) {
                Ok(out) => (Engine::DenseXla, Ok(out)),
                // dense failure (missing artifacts, size) falls back
                Err(_) => (Engine::SparseCpu, self.execute_sparse(req)),
            },
            Engine::SparseCpu => (Engine::SparseCpu, self.execute_sparse(req)),
        };
        JobResult {
            id: req.id,
            engine: engine_used,
            wall_ms: t.elapsed_ms(),
            output: output.map_err(|e| format!("{e:#}")),
        }
    }

    fn execute_sparse(&self, req: &JobRequest) -> anyhow::Result<JobOutput> {
        Ok(match req.kind {
            JobKind::Ktruss { k, mode } => {
                let r = ktruss_par(&req.graph, k, &self.pool, mode, self.schedule);
                JobOutput::Ktruss {
                    truss_edges: r.truss.nnz(),
                    iterations: r.iterations,
                    edges: r.truss.edges().collect(),
                }
            }
            JobKind::Kmax => {
                let r = kmax::kmax(&req.graph);
                JobOutput::Kmax { kmax: r.kmax, truss_edges: r.truss.nnz() }
            }
            JobKind::Decompose => {
                let d = decompose::decompose(&req.graph);
                JobOutput::Decompose { kmax: d.kmax, histogram: d.histogram() }
            }
            JobKind::Triangles => {
                JobOutput::Triangles { count: triangle::count_triangles(&req.graph) }
            }
        })
    }

    fn execute_dense(&self, req: &JobRequest) -> anyhow::Result<JobOutput> {
        let dense = self
            .dense
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("dense engine unavailable"))?;
        match req.kind {
            JobKind::Ktruss { k, mode: _ } => {
                let (truss, iterations) = dense.ktruss(&req.graph, k)?;
                Ok(JobOutput::Ktruss {
                    truss_edges: truss.nnz(),
                    iterations,
                    edges: truss.edges().collect(),
                })
            }
            _ => anyhow::bail!("dense engine only serves fixed-k truss"),
        }
    }
}

/// Convenience: run a ktruss job for tests without a full service.
pub fn run_inline(req: &JobRequest, engine: Engine) -> JobResult {
    let worker = Worker::new(Pool::new(2), None);
    worker.execute(req, engine)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::support::Mode;
    use crate::graph::builder::from_sorted_unique;
    use std::sync::Arc;

    fn diamond_req(kind: JobKind) -> JobRequest {
        let g = from_sorted_unique(4, &[(0, 1), (0, 2), (0, 3), (1, 2), (2, 3)]);
        JobRequest { id: 7, graph: Arc::new(g), kind }
    }

    #[test]
    fn sparse_ktruss_job() {
        let r = run_inline(
            &diamond_req(JobKind::Ktruss { k: 3, mode: Mode::Fine }),
            Engine::SparseCpu,
        );
        assert_eq!(r.id, 7);
        assert_eq!(r.engine, Engine::SparseCpu);
        match r.output.unwrap() {
            JobOutput::Ktruss { truss_edges, .. } => assert_eq!(truss_edges, 5),
            other => panic!("wrong output {other:?}"),
        }
    }

    #[test]
    fn kmax_and_decompose_and_triangles() {
        match run_inline(&diamond_req(JobKind::Kmax), Engine::SparseCpu).output.unwrap() {
            JobOutput::Kmax { kmax, .. } => assert_eq!(kmax, 3),
            other => panic!("{other:?}"),
        }
        match run_inline(&diamond_req(JobKind::Decompose), Engine::SparseCpu).output.unwrap() {
            JobOutput::Decompose { kmax, histogram } => {
                assert_eq!(kmax, 3);
                assert_eq!(histogram.iter().map(|&(_, c)| c).sum::<usize>(), 5);
            }
            other => panic!("{other:?}"),
        }
        match run_inline(&diamond_req(JobKind::Triangles), Engine::SparseCpu).output.unwrap() {
            JobOutput::Triangles { count } => assert_eq!(count, 2),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn dense_request_without_artifacts_falls_back() {
        let r = run_inline(
            &diamond_req(JobKind::Ktruss { k: 3, mode: Mode::Coarse }),
            Engine::DenseXla,
        );
        // no dense engine in run_inline -> sparse fallback, still correct
        assert_eq!(r.engine, Engine::SparseCpu);
        match r.output.unwrap() {
            JobOutput::Ktruss { truss_edges, .. } => assert_eq!(truss_edges, 5),
            other => panic!("{other:?}"),
        }
    }
}
