//! The serving coordinator — now a thin facade over the sharded
//! [`crate::serve::Executor`] (the L3 "request path" of the stack).
//!
//! Shape: callers `submit()` jobs and receive a ticket; the executor's
//! dispatcher drains the admission queue in batches, packs each batch
//! across shards by estimated cost, and shard workers route + execute
//! each job, delivering results through the ticket. The historical
//! single-pool API is preserved exactly (one shard by default); the
//! `shards` knob turns the same handle into the scale-out path.

use super::job::JobKind;
use super::metrics::Metrics;
use crate::graph::Csr;
use crate::par::Schedule;
use crate::serve::{Executor, ServeConfig};
use std::sync::Arc;
use std::time::Duration;

/// Ticket for a submitted job (the executor's ticket, unchanged:
/// `id`, blocking `wait()`, non-blocking `try_get()`).
pub use crate::serve::Ticket;

/// Configuration of the coordinator service.
#[derive(Clone, Copy, Debug)]
pub struct ServiceConfig {
    /// Worker pool width for sparse jobs (per shard).
    pub pool_workers: usize,
    /// Worker shards (1 = the historical single-pool dispatcher).
    pub shards: usize,
    /// Max jobs drained per batch.
    pub max_batch: usize,
    /// How long the dispatcher waits to fill a batch.
    pub batch_window: Duration,
    /// Try to construct the dense engine (requires artifacts).
    pub enable_dense: bool,
    /// Fixed pool schedule for sparse jobs; `None` lets the submit-time
    /// planner pick one per job (the schedule becomes a pinned axis of
    /// the executor's [`crate::plan::PlanSpec`]).
    pub schedule: Option<Schedule>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            pool_workers: 4,
            shards: 1,
            max_batch: 16,
            batch_window: Duration::from_millis(2),
            enable_dense: true,
            schedule: None,
        }
    }
}

/// The coordinator handle. Dropping it shuts the executor down.
pub struct Coordinator {
    exec: Executor,
    /// Latency and per-engine counters of the underlying executor.
    pub metrics: Arc<Metrics>,
}

impl Coordinator {
    /// Start the service.
    pub fn start(cfg: ServiceConfig) -> Coordinator {
        let exec = Executor::start(ServeConfig {
            shards: cfg.shards.max(1),
            workers_per_shard: cfg.pool_workers,
            max_batch: cfg.max_batch,
            batch_window: cfg.batch_window,
            enable_dense: cfg.enable_dense,
            plan: crate::plan::PlanSpec { schedule: cfg.schedule, ..Default::default() },
            ..Default::default()
        });
        let metrics = Arc::clone(&exec.metrics);
        Coordinator { exec, metrics }
    }

    /// Submit a job; returns a ticket to wait on.
    pub fn submit(&self, graph: Arc<Csr>, kind: JobKind) -> Ticket {
        self.exec.submit(graph, kind)
    }

    /// The backing sharded executor (priority/deadline submission).
    pub fn executor(&self) -> &Executor {
        &self.exec
    }

    /// Graceful shutdown (also triggered by Drop).
    pub fn shutdown(&self) {
        self.exec.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::support::Mode;
    use crate::coordinator::job::JobOutput;
    use crate::graph::builder::from_sorted_unique;

    fn cfg_no_dense() -> ServiceConfig {
        ServiceConfig { enable_dense: false, pool_workers: 2, ..Default::default() }
    }

    #[test]
    fn submit_and_wait_roundtrip() {
        let c = Coordinator::start(cfg_no_dense());
        let g = Arc::new(from_sorted_unique(4, &[(0, 1), (0, 2), (0, 3), (1, 2), (2, 3)]));
        let t = c.submit(Arc::clone(&g), JobKind::Ktruss { k: 3, mode: Mode::Fine });
        let r = t.wait();
        match r.output.unwrap() {
            JobOutput::Ktruss { truss_edges, .. } => assert_eq!(truss_edges, 5),
            other => panic!("{other:?}"),
        }
        c.shutdown();
    }

    #[test]
    fn batched_submissions_all_complete() {
        let c = Coordinator::start(cfg_no_dense());
        let g = Arc::new(crate::gen::erdos_renyi::gnm(100, 400, &mut crate::util::Rng::new(1)));
        let tickets: Vec<Ticket> = (0..10)
            .map(|i| {
                let kind = if i % 2 == 0 {
                    JobKind::Triangles
                } else {
                    JobKind::Ktruss { k: 3, mode: Mode::Coarse }
                };
                c.submit(Arc::clone(&g), kind)
            })
            .collect();
        for t in tickets {
            assert!(t.wait().output.is_ok());
        }
        let (done, failed, _) = c.metrics.summary();
        assert_eq!(done, 10);
        assert_eq!(failed, 0);
    }

    #[test]
    fn ids_are_unique_and_monotone() {
        let c = Coordinator::start(cfg_no_dense());
        let g = Arc::new(from_sorted_unique(3, &[(0, 1), (1, 2)]));
        let t1 = c.submit(Arc::clone(&g), JobKind::Triangles);
        let t2 = c.submit(Arc::clone(&g), JobKind::Triangles);
        assert!(t2.id > t1.id);
        t1.wait();
        t2.wait();
    }

    #[test]
    fn fixed_schedule_override_applies_to_every_job() {
        let c = Coordinator::start(ServiceConfig {
            schedule: Some(Schedule::WorkAware),
            ..cfg_no_dense()
        });
        let g = Arc::new(crate::gen::erdos_renyi::gnm(120, 500, &mut crate::util::Rng::new(3)));
        let want = crate::algo::ktruss::ktruss(&g, 3, Mode::Fine).truss.nnz();
        for _ in 0..4 {
            let t = c.submit(Arc::clone(&g), JobKind::Ktruss { k: 3, mode: Mode::Fine });
            let r = t.wait();
            assert_eq!(r.schedule, Some(Schedule::WorkAware));
            match r.output.unwrap() {
                JobOutput::Ktruss { truss_edges, .. } => assert_eq!(truss_edges, want),
                other => panic!("{other:?}"),
            }
        }
        c.shutdown();
    }

    #[test]
    fn multi_shard_facade_roundtrip() {
        let c = Coordinator::start(ServiceConfig { shards: 2, ..cfg_no_dense() });
        let g = Arc::new(crate::gen::erdos_renyi::gnm(80, 300, &mut crate::util::Rng::new(5)));
        let want = crate::algo::triangle::count_triangles(&g);
        let tickets: Vec<Ticket> =
            (0..8).map(|_| c.submit(Arc::clone(&g), JobKind::Triangles)).collect();
        for t in tickets {
            match t.wait().output.unwrap() {
                JobOutput::Triangles { count } => assert_eq!(count, want),
                other => panic!("{other:?}"),
            }
        }
        assert_eq!(c.metrics.shards().len(), 2);
        c.shutdown();
    }

    #[test]
    fn shutdown_is_idempotent() {
        let c = Coordinator::start(cfg_no_dense());
        c.shutdown();
        c.shutdown();
    }
}
