//! The serving coordinator: a submission queue, a batching loop, and
//! routed execution with metrics — the L3 "request path" of the stack.
//!
//! Shape: callers `submit()` jobs and receive a ticket; a dispatcher
//! thread drains the queue in batches (batching amortizes pool spin-up
//! and keeps dense-path executions back-to-back on the PJRT client),
//! routes each job, executes, and delivers results through the ticket.

use super::job::{JobId, JobKind, JobRequest, JobResult};
use super::metrics::Metrics;
use super::router::{route, RouterConfig};
use super::worker::Worker;
use crate::graph::Csr;
use crate::par::{Pool, Schedule};
use crate::runtime::DenseEngine;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Configuration of the coordinator service.
#[derive(Clone, Copy, Debug)]
pub struct ServiceConfig {
    /// Worker pool width for sparse jobs.
    pub pool_workers: usize,
    /// Max jobs drained per batch.
    pub max_batch: usize,
    /// How long the dispatcher waits to fill a batch.
    pub batch_window: Duration,
    /// Try to construct the dense engine (requires artifacts).
    pub enable_dense: bool,
    /// Fixed pool schedule for sparse jobs; `None` lets the worker pick
    /// one per job from the graph's degree skew
    /// (see [`super::worker::choose_schedule`]).
    pub schedule: Option<Schedule>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            pool_workers: 4,
            max_batch: 16,
            batch_window: Duration::from_millis(2),
            enable_dense: true,
            schedule: None,
        }
    }
}

/// Ticket for a submitted job.
pub struct Ticket {
    pub id: JobId,
    rx: Receiver<JobResult>,
}

impl Ticket {
    /// Block until the result arrives.
    pub fn wait(self) -> JobResult {
        self.rx.recv().expect("coordinator dropped without reply")
    }

    /// Non-blocking poll.
    pub fn try_get(&self) -> Option<JobResult> {
        self.rx.try_recv().ok()
    }
}

enum Msg {
    Job(JobRequest, Sender<JobResult>),
    Shutdown,
}

/// The coordinator handle. Dropping it shuts the dispatcher down.
pub struct Coordinator {
    tx: Sender<Msg>,
    next_id: AtomicU64,
    pub metrics: Arc<Metrics>,
    dispatcher: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Coordinator {
    /// Start the service.
    pub fn start(cfg: ServiceConfig) -> Coordinator {
        let (tx, rx) = channel::<Msg>();
        let metrics = Arc::new(Metrics::new());
        let m2 = Arc::clone(&metrics);
        let dispatcher = std::thread::Builder::new()
            .name("ktruss-coordinator".into())
            .spawn(move || dispatch_loop(rx, cfg, m2))
            .expect("spawn coordinator");
        Coordinator {
            tx,
            next_id: AtomicU64::new(1),
            metrics,
            dispatcher: Mutex::new(Some(dispatcher)),
        }
    }

    /// Submit a job; returns a ticket to wait on.
    pub fn submit(&self, graph: Arc<Csr>, kind: JobKind) -> Ticket {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (rtx, rrx) = channel();
        self.metrics.record_submit();
        self.tx
            .send(Msg::Job(JobRequest { id, graph, kind }, rtx))
            .expect("coordinator is down");
        Ticket { id, rx: rrx }
    }

    /// Graceful shutdown (also triggered by Drop).
    pub fn shutdown(&self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.dispatcher.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.dispatcher.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

fn dispatch_loop(rx: Receiver<Msg>, cfg: ServiceConfig, metrics: Arc<Metrics>) {
    let dense = if cfg.enable_dense { DenseEngine::new().ok() } else { None };
    let router_cfg = dense
        .as_ref()
        .map(|d| RouterConfig::new(d.max_n()))
        .unwrap_or_else(RouterConfig::disabled);
    let worker = Worker::with_schedule(Pool::new(cfg.pool_workers), dense, cfg.schedule);
    let mut batch: Vec<(JobRequest, Sender<JobResult>)> = Vec::new();
    'outer: loop {
        batch.clear();
        // block for the first job
        match rx.recv() {
            Ok(Msg::Job(j, t)) => batch.push((j, t)),
            Ok(Msg::Shutdown) | Err(_) => break 'outer,
        }
        // drain up to max_batch within the window
        let deadline = std::time::Instant::now() + cfg.batch_window;
        while batch.len() < cfg.max_batch {
            let now = std::time::Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Msg::Job(j, t)) => batch.push((j, t)),
                Ok(Msg::Shutdown) => {
                    process_batch(&worker, &router_cfg, &metrics, &mut batch);
                    break 'outer;
                }
                Err(_) => break,
            }
        }
        process_batch(&worker, &router_cfg, &metrics, &mut batch);
    }
}

fn process_batch(
    worker: &Worker,
    router_cfg: &RouterConfig,
    metrics: &Metrics,
    batch: &mut Vec<(JobRequest, Sender<JobResult>)>,
) {
    // route first, then execute dense jobs together (PJRT locality)
    let mut routed: Vec<(usize, crate::coordinator::job::Engine)> = batch
        .iter()
        .enumerate()
        .map(|(i, (req, _))| (i, route(router_cfg, req)))
        .collect();
    routed.sort_by_key(|&(_, e)| e as u8);
    for (idx, engine) in routed {
        let (req, reply) = &batch[idx];
        let result = worker.execute(req, engine);
        metrics.record_done(result.engine, result.wall_ms, result.output.is_ok());
        let _ = reply.send(result);
    }
    batch.clear();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::support::Mode;
    use crate::coordinator::job::JobOutput;
    use crate::graph::builder::from_sorted_unique;

    fn cfg_no_dense() -> ServiceConfig {
        ServiceConfig { enable_dense: false, pool_workers: 2, ..Default::default() }
    }

    #[test]
    fn submit_and_wait_roundtrip() {
        let c = Coordinator::start(cfg_no_dense());
        let g = Arc::new(from_sorted_unique(4, &[(0, 1), (0, 2), (0, 3), (1, 2), (2, 3)]));
        let t = c.submit(Arc::clone(&g), JobKind::Ktruss { k: 3, mode: Mode::Fine });
        let r = t.wait();
        match r.output.unwrap() {
            JobOutput::Ktruss { truss_edges, .. } => assert_eq!(truss_edges, 5),
            other => panic!("{other:?}"),
        }
        c.shutdown();
    }

    #[test]
    fn batched_submissions_all_complete() {
        let c = Coordinator::start(cfg_no_dense());
        let g = Arc::new(crate::gen::erdos_renyi::gnm(100, 400, &mut crate::util::Rng::new(1)));
        let tickets: Vec<Ticket> = (0..10)
            .map(|i| {
                let kind = if i % 2 == 0 {
                    JobKind::Triangles
                } else {
                    JobKind::Ktruss { k: 3, mode: Mode::Coarse }
                };
                c.submit(Arc::clone(&g), kind)
            })
            .collect();
        for t in tickets {
            assert!(t.wait().output.is_ok());
        }
        let (done, failed, _) = c.metrics.summary();
        assert_eq!(done, 10);
        assert_eq!(failed, 0);
    }

    #[test]
    fn ids_are_unique_and_monotone() {
        let c = Coordinator::start(cfg_no_dense());
        let g = Arc::new(from_sorted_unique(3, &[(0, 1), (1, 2)]));
        let t1 = c.submit(Arc::clone(&g), JobKind::Triangles);
        let t2 = c.submit(Arc::clone(&g), JobKind::Triangles);
        assert!(t2.id > t1.id);
        t1.wait();
        t2.wait();
    }

    #[test]
    fn fixed_schedule_override_applies_to_every_job() {
        let c = Coordinator::start(ServiceConfig {
            schedule: Some(Schedule::WorkAware),
            ..cfg_no_dense()
        });
        let g = Arc::new(crate::gen::erdos_renyi::gnm(120, 500, &mut crate::util::Rng::new(3)));
        let want = crate::algo::ktruss::ktruss(&g, 3, Mode::Fine).truss.nnz();
        for _ in 0..4 {
            let t = c.submit(Arc::clone(&g), JobKind::Ktruss { k: 3, mode: Mode::Fine });
            let r = t.wait();
            assert_eq!(r.schedule, Some(Schedule::WorkAware));
            match r.output.unwrap() {
                JobOutput::Ktruss { truss_edges, .. } => assert_eq!(truss_edges, want),
                other => panic!("{other:?}"),
            }
        }
        c.shutdown();
    }

    #[test]
    fn shutdown_is_idempotent() {
        let c = Coordinator::start(cfg_no_dense());
        c.shutdown();
        c.shutdown();
    }
}
