//! **L3 — serving vocabulary.** Job types, engine routing (sparse CPU
//! pool vs dense AOT/PJRT path), per-job workers, serving metrics, and
//! the [`Coordinator`] facade over the sharded [`crate::serve`]
//! executor. Load balancing at *job* granularity lives in
//! [`crate::serve`]; this module supplies the pieces it schedules —
//! what a job is, which engine it should run on, and the counters that
//! make the balance observable. *How* a sparse truss job executes is
//! one [`crate::plan::ExecutionPlan`], computed once at admission by
//! [`crate::plan::Planner`] and carried to [`worker::Worker`] through
//! the queue.

pub mod job;
pub mod metrics;
pub mod router;
pub mod service;
pub mod worker;

pub use job::{Engine, JobKind, JobOutcome, JobOutput, JobRequest, JobResult};
pub use metrics::{Metrics, ShardMetrics};
pub use router::{route, route_costed, RouterConfig};
pub use service::{Coordinator, ServiceConfig, Ticket};
pub use worker::Worker;
