//! L3 serving coordinator: job types, engine routing (sparse CPU pool
//! vs dense AOT/PJRT path), per-job workers, serving metrics, and the
//! [`Coordinator`] facade over the sharded [`crate::serve`] executor.

pub mod job;
pub mod metrics;
pub mod router;
pub mod service;
pub mod worker;

pub use job::{Engine, JobKind, JobOutput, JobRequest, JobResult};
pub use metrics::{Metrics, ShardMetrics};
pub use router::{route, route_costed, RouterConfig};
pub use service::{Coordinator, ServiceConfig, Ticket};
pub use worker::{choose_schedule, Worker};
