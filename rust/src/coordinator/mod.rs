//! **L3 — serving vocabulary.** Job types, engine routing (sparse CPU
//! pool vs dense AOT/PJRT path), per-job workers, serving metrics, and
//! the [`Coordinator`] facade over the sharded [`crate::serve`]
//! executor. Load balancing at *job* granularity lives in
//! [`crate::serve`]; this module supplies the pieces it schedules —
//! what a job is, which engine and pool schedule it should run under
//! ([`worker::Worker::pick_schedule`] chooses per-job from graph
//! skew), and the counters that make the balance observable.

pub mod job;
pub mod metrics;
pub mod router;
pub mod service;
pub mod worker;

pub use job::{Engine, JobKind, JobOutput, JobRequest, JobResult};
pub use metrics::{Metrics, ShardMetrics};
pub use router::{route, route_costed, RouterConfig};
pub use service::{Coordinator, ServiceConfig, Ticket};
pub use worker::{choose_schedule, Worker};
