//! L3 serving coordinator: job queue, batching dispatcher, engine
//! routing (sparse CPU pool vs dense AOT/PJRT path) and metrics.

pub mod job;
pub mod metrics;
pub mod router;
pub mod service;
pub mod worker;

pub use job::{Engine, JobKind, JobOutput, JobRequest, JobResult};
pub use service::{Coordinator, ServiceConfig, Ticket};
pub use worker::{choose_schedule, Worker};
