//! Engine routing: decide, per job, whether the dense AOT path or the
//! sparse CPU path executes it. The dense path is profitable only for
//! graphs that fit a compiled block (and is mandatory for none — it can
//! be disabled entirely when artifacts are absent, e.g. in unit tests).

use super::job::{Engine, JobKind, JobRequest};

/// Routing policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct RouterConfig {
    /// Largest dense block available (0 disables the dense path).
    pub dense_limit: usize,
    /// Route graphs at or below this vertex count to the dense engine
    /// (must be ≤ dense_limit).
    pub dense_threshold: usize,
}

impl RouterConfig {
    pub fn new(dense_limit: usize) -> RouterConfig {
        RouterConfig { dense_limit, dense_threshold: dense_limit }
    }

    pub fn disabled() -> RouterConfig {
        RouterConfig { dense_limit: 0, dense_threshold: 0 }
    }
}

/// Pick the engine for a request.
pub fn route(cfg: &RouterConfig, req: &JobRequest) -> Engine {
    let n = req.graph.n();
    let dense_ok = cfg.dense_limit > 0 && n <= cfg.dense_threshold.min(cfg.dense_limit);
    match req.kind {
        // only fixed-k truss has a dense AOT entry point; everything
        // else runs sparse
        JobKind::Ktruss { .. } if dense_ok => Engine::DenseXla,
        _ => Engine::SparseCpu,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::support::Mode;
    use crate::graph::builder::from_sorted_unique;
    use std::sync::Arc;

    fn req(n_vertices: usize, kind: JobKind) -> JobRequest {
        let edges: Vec<(u32, u32)> = (0..n_vertices as u32 - 1).map(|u| (u, u + 1)).collect();
        JobRequest { id: 0, graph: Arc::new(from_sorted_unique(n_vertices, &edges)), kind }
    }

    #[test]
    fn small_ktruss_goes_dense() {
        let cfg = RouterConfig::new(256);
        let r = req(100, JobKind::Ktruss { k: 3, mode: Mode::Fine });
        assert_eq!(route(&cfg, &r), Engine::DenseXla);
    }

    #[test]
    fn large_ktruss_goes_sparse() {
        let cfg = RouterConfig::new(256);
        let r = req(1000, JobKind::Ktruss { k: 3, mode: Mode::Fine });
        assert_eq!(route(&cfg, &r), Engine::SparseCpu);
    }

    #[test]
    fn non_ktruss_kinds_go_sparse() {
        let cfg = RouterConfig::new(256);
        for kind in [JobKind::Kmax, JobKind::Decompose, JobKind::Triangles] {
            assert_eq!(route(&cfg, &req(50, kind)), Engine::SparseCpu);
        }
    }

    #[test]
    fn disabled_dense_routes_everything_sparse() {
        let cfg = RouterConfig::disabled();
        let r = req(10, JobKind::Ktruss { k: 3, mode: Mode::Fine });
        assert_eq!(route(&cfg, &r), Engine::SparseCpu);
    }
}
