//! Engine routing: decide, per job, whether the dense AOT path or the
//! sparse CPU path executes it. The dense path is profitable only for
//! graphs that fit a compiled block (and is mandatory for none — it can
//! be disabled entirely when artifacts are absent, e.g. in unit tests).
//!
//! Routing takes two inputs: graph *shape* (vertex count vs the largest
//! compiled dense block) and, on the serving path, the cost model's
//! work estimate ([`route_costed`]) — a job can fit a dense block yet
//! carry enough merge work that the sparse pool's work-aware schedules
//! beat the O(n³)-ish dense formulation.

use super::job::{Engine, JobKind, JobRequest};
use anyhow::Result;

/// Routing policy knobs.
///
/// Invariant: `dense_threshold ≤ dense_limit`. The constructors uphold
/// it ([`RouterConfig::with_threshold`] rejects violations); `route`
/// additionally clamps defensively because the fields stay public.
#[derive(Clone, Copy, Debug)]
pub struct RouterConfig {
    /// Largest dense block available (0 disables the dense path).
    pub dense_limit: usize,
    /// Route graphs at or below this vertex count to the dense engine
    /// (must be ≤ dense_limit).
    pub dense_threshold: usize,
    /// Route to the dense engine only when the job's estimated work is
    /// at or below this many merge steps (`u64::MAX` = shape-only
    /// routing; see [`crate::serve::cost_model`]).
    pub dense_step_ceiling: u64,
}

impl RouterConfig {
    /// Shape-only routing: dense for graphs up to `dense_limit`
    /// vertices.
    pub fn new(dense_limit: usize) -> RouterConfig {
        RouterConfig { dense_limit, dense_threshold: dense_limit, dense_step_ceiling: u64::MAX }
    }

    /// Never route to the dense engine.
    pub fn disabled() -> RouterConfig {
        RouterConfig { dense_limit: 0, dense_threshold: 0, dense_step_ceiling: u64::MAX }
    }

    /// A config with an explicit threshold, rejecting the inconsistent
    /// `threshold > limit` case instead of silently clamping it.
    pub fn with_threshold(dense_limit: usize, dense_threshold: usize) -> Result<RouterConfig> {
        if dense_threshold > dense_limit {
            anyhow::bail!(
                "dense_threshold {dense_threshold} exceeds dense_limit {dense_limit} \
                 (graphs above the largest compiled block can never route dense)"
            );
        }
        Ok(RouterConfig { dense_limit, dense_threshold, dense_step_ceiling: u64::MAX })
    }

    /// Builder: cap the estimated work routed to the dense engine.
    pub fn with_step_ceiling(mut self, ceiling: u64) -> RouterConfig {
        self.dense_step_ceiling = ceiling;
        self
    }
}

/// Pick the engine for a request (shape-only: no cost estimate).
pub fn route(cfg: &RouterConfig, req: &JobRequest) -> Engine {
    route_costed(cfg, req, 0)
}

/// Pick the engine for a request whose estimated work is `est_steps`
/// (0 = unknown, shape-only routing).
pub fn route_costed(cfg: &RouterConfig, req: &JobRequest, est_steps: u64) -> Engine {
    debug_assert!(
        cfg.dense_threshold <= cfg.dense_limit,
        "inconsistent RouterConfig: threshold {} > limit {}",
        cfg.dense_threshold,
        cfg.dense_limit
    );
    let n = req.graph.n();
    let dense_ok = cfg.dense_limit > 0
        && n <= cfg.dense_threshold.min(cfg.dense_limit)
        && est_steps <= cfg.dense_step_ceiling;
    match req.kind {
        // only fixed-k truss has a dense AOT entry point; everything
        // else runs sparse
        JobKind::Ktruss { .. } if dense_ok => Engine::DenseXla,
        _ => Engine::SparseCpu,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::support::Mode;
    use crate::graph::builder::from_sorted_unique;
    use std::sync::Arc;

    fn req(n_vertices: usize, kind: JobKind) -> JobRequest {
        let edges: Vec<(u32, u32)> = (0..n_vertices as u32 - 1).map(|u| (u, u + 1)).collect();
        JobRequest { id: 0, graph: Arc::new(from_sorted_unique(n_vertices, &edges)), kind }
    }

    fn ktruss() -> JobKind {
        JobKind::Ktruss { k: 3, mode: Mode::Fine }
    }

    #[test]
    fn small_ktruss_goes_dense() {
        let cfg = RouterConfig::new(256);
        assert_eq!(route(&cfg, &req(100, ktruss())), Engine::DenseXla);
    }

    #[test]
    fn large_ktruss_goes_sparse() {
        let cfg = RouterConfig::new(256);
        assert_eq!(route(&cfg, &req(1000, ktruss())), Engine::SparseCpu);
    }

    #[test]
    fn threshold_boundary_is_inclusive() {
        let cfg = RouterConfig::with_threshold(256, 64).unwrap();
        assert_eq!(route(&cfg, &req(64, ktruss())), Engine::DenseXla);
        assert_eq!(route(&cfg, &req(65, ktruss())), Engine::SparseCpu);
    }

    #[test]
    fn non_ktruss_kinds_go_sparse() {
        let cfg = RouterConfig::new(256);
        for kind in [JobKind::Kmax, JobKind::Decompose, JobKind::Triangles] {
            assert_eq!(route(&cfg, &req(50, kind)), Engine::SparseCpu);
        }
    }

    #[test]
    fn disabled_dense_routes_everything_sparse() {
        let cfg = RouterConfig::disabled();
        assert_eq!(route(&cfg, &req(10, ktruss())), Engine::SparseCpu);
        // a zero threshold on a live limit likewise never routes dense
        let cfg = RouterConfig::with_threshold(256, 0).unwrap();
        assert_eq!(route(&cfg, &req(10, ktruss())), Engine::SparseCpu);
    }

    #[test]
    fn inconsistent_threshold_is_rejected_at_construction() {
        assert!(RouterConfig::with_threshold(100, 101).is_err());
        assert!(RouterConfig::with_threshold(100, 100).is_ok());
        assert!(RouterConfig::with_threshold(0, 0).is_ok());
    }

    #[test]
    fn step_ceiling_diverts_heavy_jobs_to_sparse() {
        let cfg = RouterConfig::new(256).with_step_ceiling(1000);
        let r = req(100, ktruss());
        assert_eq!(route_costed(&cfg, &r, 999), Engine::DenseXla);
        assert_eq!(route_costed(&cfg, &r, 1000), Engine::DenseXla);
        assert_eq!(route_costed(&cfg, &r, 1001), Engine::SparseCpu);
        // unknown cost (0) routes by shape alone
        assert_eq!(route_costed(&cfg, &r, 0), Engine::DenseXla);
    }
}
