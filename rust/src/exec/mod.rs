//! **Executing device backends.** The planner stamps every
//! [`ExecutionPlan`](crate::plan::ExecutionPlan) with a
//! [`PlanDevice`](crate::plan::PlanDevice); this layer is what makes
//! that axis *executable* instead of purely predictive. A `Cpu` plan
//! runs the worker-pool drivers in [`crate::par`] unchanged; a `Gpu`
//! plan dispatches to the lane-lockstep backend ([`lane`]), which
//! realizes the GPU execution shape the timing model in
//! [`crate::sim::gpu`] prices — 32-lane lockstep warps, merge-path
//! warp-chain assignment, persistent-block stealing — on the same
//! worker pool, with cycle-exact step accounting that the calibration
//! loop ([`crate::sim::calibrate`]) fits the model's constants
//! against.
//!
//! The backend boundary is deliberately *behind* the plan: callers go
//! through [`crate::par::ktruss_par_plan`], which inspects
//! `plan.device` and routes here, so the serving layer, CLI and tests
//! pick up device dispatch without knowing the backends exist.

pub mod lane;
