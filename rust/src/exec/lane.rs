//! Lane-lockstep execution backend: runs `PlanDevice::Gpu` plans for
//! real, in the execution shape the GPU timing model prices.
//!
//! The backend realizes, on the worker pool, the three structural
//! elements of the paper's GPU execution (and of
//! [`crate::sim::gpu`]'s model of it):
//!
//! * **Lockstep warps** — tasks of any [`Granularity`] (rows, slots,
//!   partner-row segments, hybrid bitmap probe chunks) are packed 32
//!   consecutive tasks to a warp ([`WARP_LANES`]), exactly the sim's
//!   warp-formation convention. Every lane advances under an explicit
//!   divergence mask and the warp's duration is the lane maximum —
//!   [`lockstep`] replays the mask trajectory from the exact per-lane
//!   step counts, so per-warp durations are cycle-exact against
//!   [`crate::sim::gpu::warp_durations`] on the same task list.
//! * **Merge-path warp-chain assignment** — warp chains are carved by
//!   [`balance::scan_bins`], the same exclusive-scan + upper-bound
//!   diagonal search (GraphBLAST's merge-path load-balanced search,
//!   arXiv:1908.01407) the pool's work-aware schedules use, fed with
//!   per-warp duration bounds aggregated from
//!   [`balance::estimate_costs`] (lane max per warp).
//! * **Persistent blocks** — one persistent block per pool worker;
//!   under [`Schedule::Stealing`] / [`Schedule::Dynamic`] the blocks
//!   repeatedly grab the next warp chain from a shared counter until
//!   the grid drains ("Dynamic Load Balancing Strategies for Graph
//!   Applications on GPUs", arXiv:1711.00231), mirroring the sim's
//!   earliest-finish dispatch.
//!
//! **Why whole-task lane execution is exact.** Eager K-truss support
//! updates are relaxed atomic fetch-adds on commutative counters that
//! are only read *after* the pass completes, so the interleaving of
//! steps between lanes is immaterial to the result: executing each
//! lane's task to completion and then replaying the warp's lockstep
//! schedule from the measured per-lane step counts produces the same
//! supports and the same per-round divergence masks as a true
//! step-interleaved execution — without paying a per-step barrier.
//! The replay advances every active lane by the minimum remaining
//! step count among active lanes per round, which is
//! accounting-identical to one-step-per-round lockstep (same total
//! duration, same idle-lane steps, rounds collapse runs of identical
//! masks).
//!
//! The incremental path runs the **fused** mark+decrement frontier
//! sweep (the PR 4 follow-up): one lane launch per round covers the
//! frontier scan and the triangle decrements, instead of a mark
//! kernel followed by a decrement kernel — see
//! [`LaneRunReport::fused_steps`] and
//! [`crate::algo::incremental::fused_mark_decrement_seq`] for the
//! accounting convention.
//!
//! Prune/compaction stays on the pool drivers
//! ([`crate::par::prune_par`], [`compact_preserving_par`]): row-local
//! memory-bound compaction has no divergence structure for lanes to
//! expose, and both backends share it unchanged, so supports stay
//! bit-identical by construction.

use crate::algo::bitmap::{self, eager_update_bitmap_atomic, HybridTasks};
use crate::algo::incremental::{self, InNbrs};
use crate::algo::ktruss::{IterationStat, KtrussResult};
use crate::algo::support::{
    eager_update_atomic, eager_update_segment_atomic, segment_tasks, Granularity, Mode,
};
use crate::graph::{Csr, ZCsr};
use crate::par::balance;
use crate::par::frontier::compact_preserving_par;
use crate::par::{prune_par, PassControl, Pool, Schedule};
use crate::plan::ExecutionPlan;
use std::sync::atomic::{AtomicU32, AtomicU64, AtomicUsize, Ordering};

/// Lanes per warp — fixed at the V100's warp width, matching
/// [`crate::sim::machine::GpuMachine::warp_size`] so measured warp
/// durations are directly comparable to the model's.
pub const WARP_LANES: usize = 32;

/// Whether `schedule` wants per-task cost estimates for its warp-chain
/// binning (same predicate the pool drivers use).
fn needs_costs(schedule: Schedule) -> bool {
    matches!(schedule, Schedule::WorkAware | Schedule::Stealing)
}

/// Measured execution record of one lane launch (one support or
/// frontier pass).
#[derive(Clone, Debug, Default)]
pub struct LaneReport {
    /// Tasks fed to the lanes.
    pub tasks: usize,
    /// Warps formed (`tasks / 32`, rounded up).
    pub warps: usize,
    /// Warp chains the assignment produced (one per block for
    /// static/work-aware, `blocks × 4` stealing chunks, fixed-size
    /// groups for dynamic).
    pub chains: usize,
    /// Exact merge steps executed across all lanes — equals the pool
    /// backend's step total for the same pass by construction.
    pub executed_steps: u64,
    /// Sum of warp durations (each the lane maximum): the step total
    /// *as the lockstep hardware pays it*.
    pub warp_steps: u64,
    /// Steps lanes spent masked off while a sibling lane still ran —
    /// `warp_steps × lanes − executed_steps`, the divergence waste the
    /// paper's fine granularities exist to shrink.
    pub idle_lane_steps: u64,
    /// Lockstep rounds replayed (mask-change epochs, not single
    /// steps): each round advances all active lanes together.
    pub lockstep_rounds: u64,
    /// Longest single warp (steps) — the sim's serial-tail input.
    pub longest_warp: u64,
    /// Warp-level makespan over the persistent blocks: the largest
    /// per-block sum of executed warp durations. This is the measured
    /// counterpart of the model's slot makespan.
    pub makespan_steps: u64,
    /// Per-warp measured durations, in warp order — feed these (as
    /// `f64`) to [`crate::sim::gpu::warp_durations`] built from the
    /// same task costs to check model/execution parity.
    pub warp_durations: Vec<u64>,
    /// Per-task measured steps, in task order.
    pub task_steps: Vec<u64>,
}

/// Accumulated lane-execution telemetry of one full k-truss run:
/// every support and frontier launch's [`LaneReport`], plus the
/// fused-vs-separate step accounting of the incremental path.
#[derive(Clone, Debug, Default)]
pub struct LaneRunReport {
    /// One report per full support pass, in execution order.
    pub support_passes: Vec<LaneReport>,
    /// One report per fused frontier sweep, in execution order.
    pub frontier_passes: Vec<LaneReport>,
    /// Steps of the fused mark+decrement sweeps: each round's frontier
    /// scan (one step per pre-prune live slot) plus its decrement
    /// enumerations, in a single launch.
    pub fused_steps: u64,
    /// What the same rounds would cost as separate mark-then-decrement
    /// launches: the scan, plus one re-read per marked task by the
    /// second kernel, plus the decrements. Always ≥ [`Self::fused_steps`],
    /// by exactly the marked-task count.
    pub separate_steps: u64,
}

impl LaneRunReport {
    /// Total measured warp makespan across every launch (steps) — the
    /// executed quantity the calibration loop fits the model against.
    pub fn makespan_steps(&self) -> u64 {
        self.support_passes
            .iter()
            .chain(self.frontier_passes.iter())
            .map(|r| r.makespan_steps)
            .sum()
    }

    /// Lane launches issued (support + frontier). The fused frontier
    /// sweep keeps this at one per round; a separate mark kernel would
    /// double the frontier launch count.
    pub fn launches(&self) -> usize {
        self.support_passes.len() + self.frontier_passes.len()
    }

    /// Total steps executed across every launch.
    pub fn executed_steps(&self) -> u64 {
        self.support_passes
            .iter()
            .chain(self.frontier_passes.iter())
            .map(|r| r.executed_steps)
            .sum()
    }

    /// Total idle-lane (divergence) steps across every launch.
    pub fn idle_lane_steps(&self) -> u64 {
        self.support_passes
            .iter()
            .chain(self.frontier_passes.iter())
            .map(|r| r.idle_lane_steps)
            .sum()
    }
}

/// Replay one warp's lockstep schedule from exact per-lane step
/// counts. Returns `(duration, rounds, idle_lane_steps)`:
///
/// * `duration` — steps until the last lane drains (= lane maximum,
///   the sim's warp-duration convention);
/// * `rounds` — mask-change epochs: each round advances every active
///   lane by the minimum remaining count among active lanes, which is
///   accounting-identical to single-step rounds (a run of identical
///   masks collapses into one round);
/// * `idle_lane_steps` — `duration × lanes − Σ lane_steps`: steps a
///   lane sat masked off while a sibling ran (zero-step lanes idle for
///   the whole duration — they are real lanes fed trivial tasks, e.g.
///   terminator slots of the fine granularity).
fn lockstep(lane_steps: &[u64]) -> (u64, u64, u64) {
    debug_assert!(lane_steps.len() <= WARP_LANES);
    let mut remaining = [0u64; WARP_LANES];
    let mut mask: u32 = 0;
    for (lane, &st) in lane_steps.iter().enumerate() {
        remaining[lane] = st;
        if st > 0 {
            mask |= 1 << lane;
        }
    }
    let mut duration = 0u64;
    let mut rounds = 0u64;
    while mask != 0 {
        rounds += 1;
        // smallest remaining among active lanes: the stretch until the
        // divergence mask next changes
        let mut chunk = u64::MAX;
        let mut m = mask;
        while m != 0 {
            let lane = m.trailing_zeros() as usize;
            chunk = chunk.min(remaining[lane]);
            m &= m - 1;
        }
        duration += chunk;
        let mut m = mask;
        while m != 0 {
            let lane = m.trailing_zeros() as usize;
            remaining[lane] -= chunk;
            if remaining[lane] == 0 {
                mask &= !(1 << lane);
            }
            m &= m - 1;
        }
    }
    let total: u64 = lane_steps.iter().sum();
    (duration, rounds, duration * lane_steps.len() as u64 - total)
}

/// Carve the warp index space into chains, one unit of block work
/// each. Returns `(chains, pulled)`: when `pulled` is true the blocks
/// grab chains from a shared counter (persistent-block dispatch);
/// otherwise chain `b` belongs to block `b` statically.
fn warp_chains(
    n_warps: usize,
    blocks: usize,
    warp_est: Option<&[u64]>,
    schedule: Schedule,
) -> (Vec<(usize, usize)>, bool) {
    let fallback: Vec<u64>;
    let est: &[u64] = match warp_est {
        Some(e) => e,
        None => {
            fallback = vec![1u64; n_warps];
            &fallback
        }
    };
    match schedule {
        Schedule::Static => (balance::even_chunks(n_warps, blocks), false),
        Schedule::Dynamic { chunk } => {
            // fixed-size chain of ⌈chunk/32⌉ warps pulled from the
            // shared counter — the task-chunk size expressed in warps
            let group = chunk.div_ceil(WARP_LANES).max(1);
            let mut chains = Vec::with_capacity(n_warps.div_ceil(group));
            let mut w = 0usize;
            while w < n_warps {
                chains.push((w, (w + group).min(n_warps)));
                w += group;
            }
            (chains, true)
        }
        // merge-path equal-work chains: one per block, assigned
        // statically
        Schedule::WorkAware => (balance::scan_bins(est, blocks), false),
        // over-decomposed merge-path chains pulled from the shared
        // counter (persistent-block stealing)
        Schedule::Stealing => (
            balance::scan_bins(est, blocks * balance::STEAL_CHUNKS_PER_WORKER),
            true,
        ),
    }
}

/// Execute one lane launch: `n_tasks` tasks packed into 32-lane
/// lockstep warps, warp chains formed per `schedule` (merge-path over
/// `costs` for the work-aware/stealing schedules), one persistent
/// block per pool worker. `exec(t)` runs task `t` and returns its
/// exact step count; it must be safe to call concurrently (the support
/// kernels' relaxed-atomic updates are).
///
/// Returns the cycle-exact [`LaneReport`] of the launch.
pub fn run_lane_pass(
    pool: &Pool,
    n_tasks: usize,
    costs: Option<&[u64]>,
    schedule: Schedule,
    exec: impl Fn(usize) -> u64 + Sync,
) -> LaneReport {
    if n_tasks == 0 {
        return LaneReport::default();
    }
    let n_warps = n_tasks.div_ceil(WARP_LANES);
    let blocks = pool.workers();
    // warp duration upper bounds (lane max of the per-task estimates):
    // the merge-path binner's input
    let warp_est: Option<Vec<u64>> = costs.map(|c| {
        assert_eq!(c.len(), n_tasks, "one cost estimate per task");
        c.chunks(WARP_LANES)
            .map(|ch| ch.iter().copied().max().unwrap_or(0).max(1))
            .collect()
    });
    let (chains, pulled) = warp_chains(n_warps, blocks, warp_est.as_deref(), schedule);
    let task_steps: Vec<AtomicU64> = (0..n_tasks).map(|_| AtomicU64::new(0)).collect();
    let warp_durs: Vec<AtomicU64> = (0..n_warps).map(|_| AtomicU64::new(0)).collect();
    // per-block outcome cells, each written exactly once when its
    // block drains (no contention, no padding needed)
    let block_wall: Vec<AtomicU64> = (0..blocks).map(|_| AtomicU64::new(0)).collect();
    let block_rounds: Vec<AtomicU64> = (0..blocks).map(|_| AtomicU64::new(0)).collect();
    let block_idle: Vec<AtomicU64> = (0..blocks).map(|_| AtomicU64::new(0)).collect();
    let next_chain = AtomicUsize::new(0);
    // one pool task per worker under Static: each worker becomes one
    // persistent block for the whole launch
    pool.parallel_for(blocks, Schedule::Static, |_w, b| {
        let mut wall = 0u64;
        let mut rounds = 0u64;
        let mut idle = 0u64;
        let mut lane_steps = [0u64; WARP_LANES];
        let mut run_chain = |ci: usize| {
            let (w_lo, w_hi) = chains[ci];
            for w in w_lo..w_hi {
                let t_lo = w * WARP_LANES;
                let t_hi = ((w + 1) * WARP_LANES).min(n_tasks);
                let lanes = t_hi - t_lo;
                for (lane, t) in (t_lo..t_hi).enumerate() {
                    let st = exec(t);
                    lane_steps[lane] = st;
                    task_steps[t].store(st, Ordering::Relaxed);
                }
                let (dur, rds, idl) = lockstep(&lane_steps[..lanes]);
                warp_durs[w].store(dur, Ordering::Relaxed);
                wall += dur;
                rounds += rds;
                idle += idl;
            }
        };
        if pulled {
            loop {
                let ci = next_chain.fetch_add(1, Ordering::Relaxed);
                if ci >= chains.len() {
                    break;
                }
                run_chain(ci);
            }
        } else if b < chains.len() {
            run_chain(b);
        }
        block_wall[b].store(wall, Ordering::Relaxed);
        block_rounds[b].store(rounds, Ordering::Relaxed);
        block_idle[b].store(idle, Ordering::Relaxed);
    });
    let task_steps: Vec<u64> = task_steps.into_iter().map(AtomicU64::into_inner).collect();
    let warp_durations: Vec<u64> = warp_durs.into_iter().map(AtomicU64::into_inner).collect();
    let executed_steps: u64 = task_steps.iter().sum();
    let warp_steps: u64 = warp_durations.iter().sum();
    let longest_warp = warp_durations.iter().copied().max().unwrap_or(0);
    LaneReport {
        tasks: n_tasks,
        warps: n_warps,
        chains: chains.len(),
        executed_steps,
        warp_steps,
        idle_lane_steps: block_idle.iter().map(|a| a.load(Ordering::Relaxed)).sum(),
        lockstep_rounds: block_rounds.iter().map(|a| a.load(Ordering::Relaxed)).sum(),
        longest_warp,
        makespan_steps: block_wall.iter().map(|a| a.load(Ordering::Relaxed)).max().unwrap_or(0),
        warp_durations,
        task_steps,
    }
}

/// One lane-executed **full support pass** at any granularity into an
/// existing (zeroed) atomic array. For `Hybrid`, `ht`/`pending` carry
/// the reusable [`HybridTasks`] across passes: the first pass builds
/// it, later passes re-encode only the rows in `pending`
/// ([`HybridTasks::refresh`], the frontier-driven invalidation of
/// ROADMAP item 5's follow-up) — identical task lists to a rebuild
/// because prune/compaction is row-local.
fn run_full_lane(
    z: &ZCsr,
    pool: &Pool,
    gran: Granularity,
    schedule: Schedule,
    s: &[AtomicU32],
    ht: &mut Option<HybridTasks>,
    pending: &mut Vec<u32>,
) -> LaneReport {
    let col = z.col();
    match gran {
        Granularity::Coarse => {
            let costs = needs_costs(schedule).then(|| balance::estimate_costs(z, Mode::Coarse));
            run_lane_pass(pool, z.n(), costs.as_deref(), schedule, |i| {
                let (start, end) = z.row_span(i);
                let mut row_steps = 0u64;
                for p in start..end {
                    let kappa = col[p];
                    if kappa == 0 {
                        break;
                    }
                    let (r0, _) = z.row_span(kappa as usize);
                    row_steps += eager_update_atomic(col, s, p, r0);
                }
                row_steps
            })
        }
        Granularity::Fine => {
            let costs = needs_costs(schedule).then(|| balance::estimate_costs(z, Mode::Fine));
            run_lane_pass(pool, z.slots(), costs.as_deref(), schedule, |p| {
                let kappa = col[p];
                if kappa == 0 {
                    return 0;
                }
                let (r0, _) = z.row_span(kappa as usize);
                eager_update_atomic(col, s, p, r0)
            })
        }
        Granularity::Segment { len } => {
            let tasks = segment_tasks(z, len);
            let costs = needs_costs(schedule)
                .then(|| tasks.iter().map(|t| t.estimated_steps()).collect::<Vec<u64>>());
            run_lane_pass(pool, tasks.len(), costs.as_deref(), schedule, |ti| {
                eager_update_segment_atomic(col, s, &tasks[ti])
            })
        }
        Granularity::Hybrid { len } => {
            match ht {
                Some(t) => t.refresh(z, len, pending),
                None => *ht = Some(bitmap::hybrid_tasks(z, len)),
            }
            pending.clear();
            let t = ht.as_ref().expect("hybrid task list just built");
            let n_merge = t.merge.len();
            let costs = needs_costs(schedule).then(|| t.estimated_steps());
            run_lane_pass(pool, t.len(), costs.as_deref(), schedule, |ti| {
                if ti < n_merge {
                    eager_update_segment_atomic(col, s, &t.merge[ti])
                } else {
                    let task = &t.probe[ti - n_merge];
                    let kappa = col[task.p as usize] as usize;
                    let bm = t.index.row(kappa).expect("probe task against unencoded row");
                    eager_update_bitmap_atomic(col, s, bm, task)
                }
            })
        }
    }
}

/// One lane-executed **frontier decrement launch** (the decrement half
/// of the fused sweep — the mark scan's steps are accounted by the
/// caller). Mirrors the pool's granularity handling: `Coarse` groups a
/// row's contiguous frontier tasks into one lane task, every other
/// granularity runs one lane task per dying edge.
#[allow(clippy::too_many_arguments)]
fn run_frontier_lane(
    z: &ZCsr,
    pool: &Pool,
    f: &incremental::Frontier,
    in_nbrs: &InNbrs,
    gran: Granularity,
    schedule: Schedule,
    s: &[AtomicU32],
    costs: Option<&[u64]>,
) -> LaneReport {
    if matches!(gran, Granularity::Coarse) {
        // group consecutive tasks by row (mark emits ascending slot
        // order, so a row's tasks are contiguous)
        let mut groups: Vec<(usize, usize)> = Vec::new();
        let mut start = 0usize;
        for i in 1..=f.tasks.len() {
            if i == f.tasks.len() || f.tasks[i].row != f.tasks[start].row {
                groups.push((start, i));
                start = i;
            }
        }
        let group_costs: Option<Vec<u64>> = if needs_costs(schedule) {
            let computed: Vec<u64>;
            let per_task: &[u64] = match costs {
                Some(c) => c,
                None => {
                    computed = incremental::frontier_costs(z, f, in_nbrs);
                    &computed
                }
            };
            assert_eq!(per_task.len(), f.tasks.len(), "one cost per frontier task");
            Some(
                groups
                    .iter()
                    .map(|&(lo, hi)| per_task[lo..hi].iter().sum::<u64>().max(1))
                    .collect(),
            )
        } else {
            None
        };
        run_lane_pass(pool, groups.len(), group_costs.as_deref(), schedule, |gi| {
            let (lo, hi) = groups[gi];
            let mut steps = 0u64;
            for t in &f.tasks[lo..hi] {
                steps += incremental::frontier_task_atomic(z, s, f, in_nbrs, *t);
            }
            steps
        })
    } else {
        let mut owned: Option<Vec<u64>> = None;
        let cost_slice: Option<&[u64]> = if needs_costs(schedule) {
            Some(match costs {
                Some(c) => c,
                None => owned.insert(incremental::frontier_costs(z, f, in_nbrs)).as_slice(),
            })
        } else {
            None
        };
        run_lane_pass(pool, f.tasks.len(), cost_slice, schedule, |ti| {
            incremental::frontier_task_atomic(z, s, f, in_nbrs, f.tasks[ti])
        })
    }
}

/// Lane-executed one-shot support pass at any granularity; returns the
/// plain support array and the launch's [`LaneReport`]. The lane
/// analogue of [`crate::par::compute_supports_gran`] — the parity
/// tests compare both outputs bit for bit and feed the report's
/// measured task steps through the sim's warp formation.
pub fn compute_supports_lane(
    z: &ZCsr,
    pool: &Pool,
    gran: Granularity,
    schedule: Schedule,
) -> (Vec<u32>, LaneReport) {
    let s: Vec<AtomicU32> = (0..z.slots()).map(|_| AtomicU32::new(0)).collect();
    let mut ht = None;
    let mut pending = Vec::new();
    let report = run_full_lane(z, pool, gran, schedule, &s, &mut ht, &mut pending);
    (s.into_iter().map(AtomicU32::into_inner).collect(), report)
}

/// Lane-backend k-truss under the plan's granularity/schedule/support
/// axes — the execution target of `PlanDevice::Gpu` plans
/// ([`crate::par::ktruss_par_plan`] routes here). Produces the exact
/// k-truss, bit-identical to the pool backend at every plan point.
pub fn ktruss_lane(g: &Csr, k: u32, pool: &Pool, plan: &ExecutionPlan) -> KtrussResult {
    ktruss_lane_ctl(g, k, pool, plan, PassControl::default()).0
}

/// [`ktruss_lane`] with pass-boundary control (the serving layer's
/// cancellable entry); returns `(result, cancelled)`.
pub fn ktruss_lane_ctl(
    g: &Csr,
    k: u32,
    pool: &Pool,
    plan: &ExecutionPlan,
    ctl: PassControl<'_>,
) -> (KtrussResult, bool) {
    let (result, _, cancelled) = ktruss_lane_report(g, k, pool, plan, ctl);
    (result, cancelled)
}

/// [`ktruss_lane_ctl`] returning the full [`LaneRunReport`] — the
/// entry the calibration loop and `bench lane` use to read measured
/// warp makespans, divergence waste and fused-sweep accounting.
///
/// The convergence loop mirrors the pool driver
/// ([`crate::par::ktruss_par_plan_ctl`]) decision for decision — same
/// frontier marks, same [`incremental::decide_incremental`] calls,
/// same prune/compaction — so iteration counts and per-iteration step
/// totals match the pool backend exactly; only the *execution* of each
/// support/decrement pass differs (lockstep warps instead of flat pool
/// tasks).
pub fn ktruss_lane_report(
    g: &Csr,
    k: u32,
    pool: &Pool,
    plan: &ExecutionPlan,
    ctl: PassControl<'_>,
) -> (KtrussResult, LaneRunReport, bool) {
    let gran = plan.granularity;
    let schedule = plan.schedule;
    let support = plan.support;
    let crossover = plan.crossover;
    // recorded mode follows the pool drivers: coarse records Coarse,
    // everything else (fine and its sub-divisions) records Fine
    let mode = match gran {
        Granularity::Coarse => Mode::Coarse,
        _ => Mode::Fine,
    };
    let hybrid_len = match gran {
        Granularity::Hybrid { len } => Some(len),
        _ => None,
    };
    let mut report = LaneRunReport::default();
    let mut z = ZCsr::from_csr(g);
    let s_atomic: Vec<AtomicU32> = (0..z.slots()).map(|_| AtomicU32::new(0)).collect();
    let mut s_plain = vec![0u32; z.slots()];
    let use_inc = support.allows_incremental();
    let mut iterations = 0usize;
    let mut stats = Vec::new();
    let mut live = z.live_edges();
    let mut cancelled = false;
    if live == 0 {
        return (
            KtrussResult { truss: z.to_csr(), iterations, stats, k, mode },
            report,
            false,
        );
    }
    let in_nbrs: Option<InNbrs> = if use_inc { Some(InNbrs::build(&z)) } else { None };
    // reusable hybrid task list + rows invalidated since the last full
    // hybrid pass (satellite: frontier-driven bitmap invalidation)
    let mut ht: Option<HybridTasks> = None;
    let mut pending_rows: Vec<u32> = Vec::new();
    let full_tasks = |live: usize, z: &ZCsr| match mode {
        Mode::Coarse => z.n(),
        Mode::Fine => live,
    };
    let mut pass_timer = crate::util::Timer::start();
    let lr = run_full_lane(&z, pool, gran, schedule, &s_atomic, &mut ht, &mut pending_rows);
    let mut pass_wall_ms = pass_timer.elapsed_ms();
    let mut pass_steps = lr.executed_steps;
    report.support_passes.push(lr);
    let mut pass_tasks = full_tasks(live, &z);
    let mut pass_incremental = false;
    let mut last_full_steps = pass_steps;
    loop {
        if live == 0 {
            break;
        }
        let f = incremental::mark_frontier_with(&z, k, |p| {
            s_atomic[p].load(Ordering::Relaxed)
        });
        iterations += 1;
        stats.push(IterationStat {
            live_edges: live,
            removed: f.len(),
            support_steps: pass_steps,
            incremental: pass_incremental,
            wall_ms: pass_wall_ms,
            tasks: pass_tasks,
        });
        if f.is_empty() {
            break;
        }
        if ctl.pass_boundary(iterations - 1) {
            cancelled = true;
            break;
        }
        // both branches below remove exactly this round's dying slots,
        // so the rows owning them are the ones whose bitmap encodings
        // go stale before the next full hybrid pass
        if hybrid_len.is_some() {
            let mut last = u32::MAX;
            for t in &f.tasks {
                if t.row != last {
                    pending_rows.push(t.row);
                    last = t.row;
                }
            }
        }
        let (go_incremental, frontier_cost_vec) = incremental::decide_incremental(
            &z,
            &f,
            in_nbrs.as_ref(),
            support,
            last_full_steps,
            crossover,
            needs_costs(schedule),
        );
        if go_incremental {
            let nbrs = in_nbrs.as_ref().expect("incremental mode builds the index");
            pass_tasks = f.len();
            pass_timer.restart();
            let lr = run_frontier_lane(
                &z,
                pool,
                &f,
                nbrs,
                gran,
                schedule,
                &s_atomic,
                frontier_cost_vec.as_deref(),
            );
            pass_wall_ms = pass_timer.elapsed_ms();
            let dec_steps = lr.executed_steps;
            report.frontier_passes.push(lr);
            // fused-sweep accounting: the mark scan (one step per
            // pre-prune live slot) rode the same launch; a separate
            // mark kernel would re-read each marked task in the
            // decrement launch and pay a second launch latency
            let live_total: u64 = f.live.iter().map(|&x| u64::from(x)).sum();
            report.fused_steps += live_total + dec_steps;
            report.separate_steps += live_total + f.len() as u64 + dec_steps;
            pass_steps = dec_steps;
            pass_incremental = true;
            live = compact_preserving_par(&mut z, &s_atomic, &f.dying, pool, schedule)
                .remaining;
        } else {
            for (d, a) in s_plain.iter_mut().zip(s_atomic.iter()) {
                *d = a.swap(0, Ordering::Relaxed);
            }
            live = prune_par(&mut z, &mut s_plain, k, pool, schedule).remaining;
            if live == 0 {
                pass_steps = 0;
                pass_incremental = false;
                pass_wall_ms = 0.0;
                pass_tasks = 0;
            } else {
                pass_timer.restart();
                let lr =
                    run_full_lane(&z, pool, gran, schedule, &s_atomic, &mut ht, &mut pending_rows);
                pass_wall_ms = pass_timer.elapsed_ms();
                pass_steps = lr.executed_steps;
                report.support_passes.push(lr);
                pass_tasks = full_tasks(live, &z);
                pass_incremental = false;
                last_full_steps = pass_steps;
            }
        }
    }
    (
        KtrussResult { truss: z.to_csr(), iterations, stats, k, mode },
        report,
        cancelled,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::incremental::SupportMode;
    use crate::algo::ktruss::ktruss_mode;
    use crate::algo::support::compute_supports_seq;
    use crate::par::pool::ALL_SCHEDULES;

    #[test]
    fn lockstep_matches_the_lane_max_convention() {
        // duration = lane max; idle = duration×lanes − total; rounds =
        // number of distinct nonzero step counts
        let (dur, rounds, idle) = lockstep(&[3, 1, 4, 1, 5]);
        assert_eq!(dur, 5);
        assert_eq!(idle, 5 * 5 - 14);
        assert_eq!(rounds, 4); // mask changes at 1, 3, 4, 5
        // zero-step lanes idle for the whole duration
        let (dur, rounds, idle) = lockstep(&[0, 7, 0]);
        assert_eq!((dur, rounds, idle), (7, 1, 14));
        // empty and all-zero warps cost nothing
        assert_eq!(lockstep(&[]), (0, 0, 0));
        assert_eq!(lockstep(&[0, 0]), (0, 0, 0));
        // uniform lanes never diverge: one round, zero idle
        let (dur, rounds, idle) = lockstep(&[6; 32]);
        assert_eq!((dur, rounds, idle), (6, 1, 0));
    }

    #[test]
    fn lane_pass_accounting_is_exact_under_every_schedule() {
        // synthetic task list: task t costs t % 7 steps
        let pool = Pool::new(4);
        let n = 1000;
        let step = |t: usize| (t % 7) as u64;
        let costs: Vec<u64> = (0..n).map(step).collect();
        let total: u64 = costs.iter().sum();
        for sched in ALL_SCHEDULES {
            let r = run_lane_pass(&pool, n, Some(&costs), sched, step);
            assert_eq!(r.executed_steps, total, "{sched:?}");
            assert_eq!(r.tasks, n);
            assert_eq!(r.warps, n.div_ceil(WARP_LANES));
            assert_eq!(r.task_steps, costs, "{sched:?}");
            // warp durations are the lane max of each consecutive chunk
            let want: Vec<u64> = costs
                .chunks(WARP_LANES)
                .map(|c| c.iter().copied().max().unwrap())
                .collect();
            assert_eq!(r.warp_durations, want, "{sched:?}");
            assert_eq!(r.warp_steps, want.iter().sum::<u64>());
            assert_eq!(r.longest_warp, 6);
            // every block's chain sum is ≤ the makespan, and the
            // makespan is ≤ the whole grid run serially
            assert!(r.makespan_steps >= r.warp_steps / pool.workers() as u64);
            assert!(r.makespan_steps <= r.warp_steps);
            assert_eq!(
                r.idle_lane_steps,
                r.warp_durations
                    .iter()
                    .zip(costs.chunks(WARP_LANES))
                    .map(|(&d, c)| d * c.len() as u64 - c.iter().sum::<u64>())
                    .sum::<u64>()
            );
        }
    }

    #[test]
    fn lane_supports_match_seq_at_every_granularity() {
        let g = crate::gen::rmat::rmat(
            250,
            1700,
            crate::gen::rmat::RmatParams::social(),
            &mut crate::util::Rng::new(7),
        );
        let z = ZCsr::from_csr(&g);
        let mut want = Vec::new();
        compute_supports_seq(&z, &mut want);
        let pool = Pool::new(4);
        for gran in [
            Granularity::Coarse,
            Granularity::Fine,
            Granularity::Segment { len: 8 },
            Granularity::Hybrid { len: 8 },
        ] {
            for sched in ALL_SCHEDULES {
                let (got, r) = compute_supports_lane(&z, &pool, gran, sched);
                assert_eq!(got, want, "{gran} {sched:?}");
                assert!(r.executed_steps > 0, "{gran} {sched:?}");
            }
        }
    }

    #[test]
    fn lane_ktruss_matches_seq_and_reports_passes() {
        let g = crate::testkit::graphs::peel_chain(16);
        let pool = Pool::new(3);
        for k in [3u32, 4] {
            let want = ktruss_mode(&g, k, Mode::Fine, SupportMode::Full);
            let plan = ExecutionPlan {
                schedule: Schedule::Stealing,
                granularity: Granularity::Fine,
                support: SupportMode::Auto,
                crossover: incremental::DEFAULT_CROSSOVER_FRAC,
                device: crate::plan::PlanDevice::Gpu,
            };
            let (got, rep, cancelled) =
                ktruss_lane_report(&g, k, &pool, &plan, PassControl::default());
            assert!(!cancelled);
            assert_eq!(got.truss, want.truss, "k={k}");
            assert_eq!(got.iterations, want.iterations, "k={k}");
            assert!(!rep.support_passes.is_empty());
            // any fused round strictly undercuts the separate launches
            if !rep.frontier_passes.is_empty() {
                assert!(rep.fused_steps < rep.separate_steps);
            }
        }
    }
}
