//! Workload selection shared by every bench binary and the CLI:
//! which replica graphs to run and at what scale.
//!
//! Environment knobs (recorded in every bench header):
//! * `KTRUSS_SUITE`  — `small` (6 graphs), `paper` (all 50; default for
//!   `cargo bench`), or a comma-separated list of graph names.
//! * `KTRUSS_SCALE`  — size multiplier for the replicas (default 0.15:
//!   this container is a single core; the scale is printed with every
//!   result and EXPERIMENTS.md records the scale each run used).

use crate::gen::suite::{by_name, GraphSpec, SUITE};
use crate::graph::Csr;
use anyhow::{bail, Result};

/// Resolved workload configuration.
#[derive(Clone, Debug)]
pub struct Workload {
    /// Replica specs to run, in suite order.
    pub specs: Vec<&'static GraphSpec>,
    /// Size multiplier for the generated replicas.
    pub scale: f64,
}

/// Default replica scale for bench runs on this container.
pub const DEFAULT_SCALE: f64 = 0.15;

impl Workload {
    /// Resolve from the environment.
    pub fn from_env() -> Result<Workload> {
        let scale = match std::env::var("KTRUSS_SCALE") {
            Ok(s) => s.parse::<f64>().map_err(|_| anyhow::anyhow!("bad KTRUSS_SCALE {s}"))?,
            Err(_) => DEFAULT_SCALE,
        };
        if !(0.001..=1.0).contains(&scale) {
            bail!("KTRUSS_SCALE must be in (0.001, 1.0], got {scale}");
        }
        let suite = std::env::var("KTRUSS_SUITE").unwrap_or_else(|_| "paper".to_string());
        let specs: Vec<&'static GraphSpec> = match suite.as_str() {
            "paper" | "full" => SUITE.iter().collect(),
            "small" => crate::gen::suite::small_suite(),
            list => {
                let mut out = Vec::new();
                for name in list.split(',') {
                    let name = name.trim();
                    match by_name(name) {
                        Some(s) => out.push(s),
                        None => bail!("unknown graph {name:?} in KTRUSS_SUITE"),
                    }
                }
                out
            }
        };
        Ok(Workload { specs, scale })
    }

    /// Load (or generate+cache) one replica at this workload's scale.
    pub fn load(&self, spec: &GraphSpec) -> Result<Csr> {
        crate::gen::suite::load(spec, self.scale)
    }

    /// Header line all benches print for provenance.
    pub fn banner(&self, bench: &str) -> String {
        format!(
            "# {bench}: {} graphs, scale {} (set KTRUSS_SUITE / KTRUSS_SCALE to change)",
            self.specs.len(),
            self.scale
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// One combined test: env vars are process-global and the test
    /// runner is multi-threaded, so all env manipulation lives in a
    /// single sequential test.
    #[test]
    fn env_parsing_cases() {
        // named list + explicit scale
        std::env::set_var("KTRUSS_SUITE", "ca-GrQc, roadNet-PA");
        std::env::set_var("KTRUSS_SCALE", "0.05");
        let w = Workload::from_env().unwrap();
        assert_eq!(w.specs.len(), 2);
        assert_eq!(w.scale, 0.05);
        assert!(w.banner("x").contains("2 graphs"));

        // bad scale
        std::env::set_var("KTRUSS_SCALE", "7.0");
        assert!(Workload::from_env().is_err());
        std::env::remove_var("KTRUSS_SCALE");

        // unknown graph
        std::env::set_var("KTRUSS_SUITE", "not-a-graph");
        assert!(Workload::from_env().is_err());

        // defaults
        std::env::remove_var("KTRUSS_SUITE");
        let w = Workload::from_env().unwrap();
        assert_eq!(w.specs.len(), 50);
        assert_eq!(w.scale, DEFAULT_SCALE);
    }
}
