//! Ablations for the design decisions DESIGN.md §7 calls out.
//!
//! 1. **Zero-terminated CSR vs bounds-carried rows** — the paper claims
//!    the terminator trick's overhead is "minor" (§III-D); we measure a
//!    bounds-carried variant of the kernel against it (real wallclock,
//!    single thread — this one is a genuine host measurement).
//! 2. **Static vs dynamic scheduling of coarse tasks** — how much of
//!    fine-grained's win a dynamic scheduler could recover (simulated
//!    48T makespans).
//! 3. **Ultra-fine tasks** (paper's future work §III-B) — split each
//!    fine task into ≤L-step segments with per-task overhead; simulated
//!    GPU kernel time vs plain fine.
//! 4. **Flat-index resolution** — binary search vs row-hint for
//!    recovering `i` from the flat slot index (real wallclock).

use crate::algo::support::Mode;
use crate::cost::trace::trace_supports;
use crate::graph::{Csr, ZCsr};
use crate::par::Schedule;
use crate::sim::machine::{CpuMachine, GpuMachine};
use crate::util::timer::bench_ms;
use crate::util::stats::mean;

/// Bounds-carried support kernel: identical eager updates, but walks
/// explicit `[start, end)` bounds on the canonical CSR instead of the
/// zero-terminated working form. Support indexed by CSR entry position.
pub fn support_bounds_carried(g: &Csr, s: &mut Vec<u32>) {
    s.clear();
    s.resize(g.nnz(), 0);
    let col = g.col_idx();
    let rp = g.row_ptr();
    for i in 0..g.n() {
        let (start, end) = (rp[i] as usize, rp[i + 1] as usize);
        for p in start..end {
            let kappa = col[p] as usize;
            let (mut q, mut r) = (p + 1, rp[kappa] as usize);
            let (q_end, r_end) = (end, rp[kappa + 1] as usize);
            while q < q_end && r < r_end {
                match col[q].cmp(&col[r]) {
                    std::cmp::Ordering::Less => q += 1,
                    std::cmp::Ordering::Greater => r += 1,
                    std::cmp::Ordering::Equal => {
                        s[p] += 1;
                        s[q] += 1;
                        s[r] += 1;
                        q += 1;
                        r += 1;
                    }
                }
            }
        }
    }
}

/// Ablation 1 result: mean ms per support pass for each representation.
#[derive(Clone, Debug)]
pub struct ZeroTermAblation {
    /// Mean ms per pass over the zero-terminated working form.
    pub zeroterm_ms: f64,
    /// Mean ms per pass over the bounds-carried canonical CSR.
    pub bounds_ms: f64,
}

impl ZeroTermAblation {
    /// overhead of zero-termination relative to bounds-carried
    pub fn overhead(&self) -> f64 {
        self.zeroterm_ms / self.bounds_ms - 1.0
    }
}

/// Measure ablation 1 on a graph (trials of the full support pass).
pub fn ablate_zeroterm(g: &Csr, trials: usize) -> ZeroTermAblation {
    let z = ZCsr::from_csr(g);
    let mut s = Vec::new();
    let zt = bench_ms(1, trials, || {
        crate::algo::support::compute_supports_seq(&z, &mut s);
    });
    let mut s2 = Vec::new();
    let bc = bench_ms(1, trials, || {
        support_bounds_carried(g, &mut s2);
    });
    ZeroTermAblation {
        zeroterm_ms: mean(&zt).unwrap(),
        bounds_ms: mean(&bc).unwrap(),
    }
}

/// Ablation 2 result: simulated 48T support-kernel times across the
/// full schedule axis (static | dynamic | workaware | stealing), both
/// granularities where the schedule can still matter.
#[derive(Clone, Debug)]
pub struct ScheduleAblation {
    /// Coarse tasks under the static schedule (the paper's baseline).
    pub coarse_static_s: f64,
    /// Coarse tasks under chunked dynamic self-scheduling.
    pub coarse_dynamic_s: f64,
    /// Fine tasks under the static schedule.
    pub fine_static_s: f64,
    /// Scan-binned equal-work chunks over coarse tasks — how much of
    /// fine-grained's win schedule-level balancing recovers.
    pub coarse_workaware_s: f64,
    /// Work stealing over coarse tasks.
    pub coarse_stealing_s: f64,
    /// Work-aware binning layered *under* fine tasks (both mechanisms).
    pub fine_workaware_s: f64,
}

/// Measure ablation 2 (first support pass of the K=3 run).
pub fn ablate_schedule(g: &Csr) -> ScheduleAblation {
    let z = ZCsr::from_csr(g);
    let mut s = Vec::new();
    let tr = trace_supports(&z, &mut s);
    let m = CpuMachine::skylake_8160(48);
    let pass = |mode: Mode, sched: Schedule| {
        crate::sim::cpu::support_pass_s(&m, &tr, z.row_ptr(), z.col(), mode.into(), sched)
    };
    ScheduleAblation {
        coarse_static_s: pass(Mode::Coarse, Schedule::Static),
        coarse_dynamic_s: pass(Mode::Coarse, Schedule::Dynamic { chunk: 16 }),
        fine_static_s: pass(Mode::Fine, Schedule::Static),
        coarse_workaware_s: pass(Mode::Coarse, Schedule::WorkAware),
        coarse_stealing_s: pass(Mode::Coarse, Schedule::Stealing),
        fine_workaware_s: pass(Mode::Fine, Schedule::WorkAware),
    }
}

/// Ablation 3 result: simulated GPU kernel times.
#[derive(Clone, Debug)]
pub struct UltraFineAblation {
    /// Plain fine-granularity kernel time.
    pub fine_s: f64,
    /// time with fine tasks split into ≤`segment`-step subtasks
    pub ultra_s: f64,
    /// Segment length of the split.
    pub segment: u32,
}

/// Measure ablation 3 (first support pass, GPU model).
pub fn ablate_ultrafine(g: &Csr, segment: u32) -> UltraFineAblation {
    let z = ZCsr::from_csr(g);
    let mut s = Vec::new();
    let tr = trace_supports(&z, &mut s);
    let m = GpuMachine::v100();
    let fine_s =
        crate::sim::gpu::support_kernel(&m, &tr, z.row_ptr(), z.col(), Mode::Fine).total_s();
    // split every fine task into ceil(c/segment) subtasks; each carries
    // the per-task overhead plus the bookkeeping the paper warns about
    // (locating the segment within the row costs ~an extra task setup)
    let ultra_overhead = m.fine_task_steps * 1.5;
    let mut ultra_tasks: Vec<f64> = Vec::with_capacity(tr.fine_steps.len());
    for &c in &tr.fine_steps {
        if c == 0 {
            ultra_tasks.push(ultra_overhead);
            continue;
        }
        let mut left = c;
        while left > 0 {
            let seg = left.min(segment);
            ultra_tasks.push(seg as f64 + ultra_overhead);
            left -= seg;
        }
    }
    let ultra_s = crate::sim::gpu::estimate_tasks(&m, &ultra_tasks, tr.total_steps as f64).total_s();
    UltraFineAblation { fine_s, ultra_s, segment }
}

/// Ablation 5 result: simulated coarse-kernel times under different
/// vertex orderings (the paper's cited future-work direction [9]:
/// reordering as a complementary load-balancing strategy).
#[derive(Clone, Debug)]
pub struct ReorderAblation {
    /// natural (generator) order
    pub natural_s: f64,
    /// degree-descending relabeling
    pub degree_sorted_s: f64,
    /// fine-grained on natural order, for reference
    pub fine_natural_s: f64,
}

/// Measure ablation 5 (first support pass, CPU 48T model, coarse).
pub fn ablate_reorder(g: &Csr) -> ReorderAblation {
    let m = CpuMachine::skylake_8160(48);
    let pass = |g: &Csr, mode: Mode| {
        let z = ZCsr::from_csr(g);
        let mut s = Vec::new();
        let tr = trace_supports(&z, &mut s);
        crate::sim::cpu::support_pass_s(
            &m,
            &tr,
            z.row_ptr(),
            z.col(),
            mode.into(),
            Schedule::Static,
        )
    };
    let sorted = crate::graph::builder::relabel_by_degree(g);
    ReorderAblation {
        natural_s: pass(g, Mode::Coarse),
        degree_sorted_s: pass(&sorted, Mode::Coarse),
        fine_natural_s: pass(g, Mode::Fine),
    }
}

/// Ablation 4 result: nanoseconds per flat-index resolution.
#[derive(Clone, Debug)]
pub struct FlatIndexAblation {
    /// ns per flat-slot→row resolve via plain binary search.
    pub binary_search_ns: f64,
    /// ns per resolve with the monotone row hint.
    pub hinted_ns: f64,
}

/// Measure ablation 4 (real wallclock over all slots).
pub fn ablate_flat_index(g: &Csr, trials: usize) -> FlatIndexAblation {
    let z = ZCsr::from_csr(g);
    let slots = z.slots();
    let bs = bench_ms(1, trials, || {
        let mut acc = 0usize;
        for p in 0..slots {
            acc = acc.wrapping_add(z.row_of(p));
        }
        std::hint::black_box(acc)
    });
    let hint = bench_ms(1, trials, || {
        let mut acc = 0usize;
        let mut h = 0usize;
        for p in 0..slots {
            h = z.row_of_hinted(p, h);
            acc = acc.wrapping_add(h);
        }
        std::hint::black_box(acc)
    });
    FlatIndexAblation {
        binary_search_ns: mean(&bs).unwrap() * 1e6 / slots as f64,
        hinted_ns: mean(&hint).unwrap() * 1e6 / slots as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::support::compute_supports_seq;

    fn graph() -> Csr {
        crate::gen::rmat::rmat(
            1000,
            8000,
            crate::gen::rmat::RmatParams::social(),
            &mut crate::util::Rng::new(3),
        )
    }

    #[test]
    fn bounds_carried_matches_zeroterm_supports() {
        let g = graph();
        let z = ZCsr::from_csr(&g);
        let mut s_zt = Vec::new();
        compute_supports_seq(&z, &mut s_zt);
        let mut s_bc = Vec::new();
        support_bounds_carried(&g, &mut s_bc);
        // project zero-terminated supports onto live-edge positions
        let mut zt_edges = Vec::with_capacity(g.nnz());
        for i in 0..z.n() {
            let (start, _) = z.row_span(i);
            for off in 0..z.row_live(i).len() {
                zt_edges.push(s_zt[start + off]);
            }
        }
        assert_eq!(zt_edges, s_bc);
    }

    #[test]
    fn zeroterm_overhead_is_minor() {
        // the paper's §III-D claim, in test form: within ±60% of the
        // bounds-carried kernel even on a noisy shared host
        let a = ablate_zeroterm(&graph(), 3);
        assert!(a.overhead().abs() < 0.6, "overhead {}", a.overhead());
    }

    #[test]
    fn dynamic_schedule_recovers_some_imbalance() {
        let g = crate::gen::rmat::rmat(
            3000,
            15_000,
            crate::gen::rmat::RmatParams::autonomous_system(),
            &mut crate::util::Rng::new(9),
        );
        let a = ablate_schedule(&g);
        assert!(a.coarse_dynamic_s <= a.coarse_static_s * 1.001);
        assert!(a.fine_static_s <= a.coarse_dynamic_s * 1.2);
    }

    #[test]
    fn workaware_and_stealing_bounded_by_static() {
        let g = crate::gen::rmat::rmat(
            3000,
            15_000,
            crate::gen::rmat::RmatParams::autonomous_system(),
            &mut crate::util::Rng::new(9),
        );
        let a = ablate_schedule(&g);
        // provable sandwich: workaware/stealing ≤ 2× the static
        // makespan (total/threads + max ≤ 2·static), and all positive
        for (label, s) in [
            ("coarse_workaware", a.coarse_workaware_s),
            ("coarse_stealing", a.coarse_stealing_s),
            ("fine_workaware", a.fine_workaware_s),
        ] {
            assert!(s > 0.0, "{label}");
        }
        assert!(a.coarse_workaware_s <= a.coarse_static_s * 2.0, "workaware blew past static");
        assert!(a.coarse_stealing_s <= a.coarse_static_s * 2.0, "stealing blew past static");
    }

    #[test]
    fn reorder_ablation_runs() {
        let g = crate::gen::rmat::rmat(
            2000,
            10_000,
            crate::gen::rmat::RmatParams::autonomous_system(),
            &mut crate::util::Rng::new(13),
        );
        let a = ablate_reorder(&g);
        assert!(a.natural_s > 0.0 && a.degree_sorted_s > 0.0 && a.fine_natural_s > 0.0);
        // fine-grained should beat coarse under either ordering on a
        // hub-heavy graph
        assert!(a.fine_natural_s < a.natural_s);
    }

    #[test]
    fn ultrafine_runs_and_reports() {
        let a = ablate_ultrafine(&graph(), 64);
        assert!(a.fine_s > 0.0 && a.ultra_s > 0.0);
    }

    #[test]
    fn flat_index_hint_not_slower() {
        let a = ablate_flat_index(&graph(), 3);
        assert!(a.hinted_ns > 0.0 && a.binary_search_ns > 0.0);
        // hint should win or tie on a sequential walk
        assert!(a.hinted_ns <= a.binary_search_ns * 1.5);
    }
}
