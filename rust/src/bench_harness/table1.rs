//! Table I regeneration: runtimes (ms) and ME/s for CPU-C/CPU-F (48
//! threads, simulated Skylake) and GPU-C/GPU-F (simulated V100), K=3,
//! over the whole replica suite — the same columns the paper prints.

use super::workload::Workload;
use crate::sim::{simulate_ktruss, table1_configs};
use crate::util::fmt::{count_k, mes, ms, speedup, Table};
use crate::util::stats::geomean;
use anyhow::Result;

/// One Table-I row.
#[derive(Clone, Debug)]
pub struct Row {
    /// Replica graph name.
    pub name: String,
    /// Vertices of the generated replica.
    pub vertices: usize,
    /// Edges of the generated replica.
    pub edges: usize,
    /// [CPU-C, CPU-F, GPU-C, GPU-F] total times, ms.
    pub time_ms: [f64; 4],
    /// [CPU-C, CPU-F, GPU-C, GPU-F] ME/s.
    pub me_s: [f64; 4],
}

/// Aggregated result of the Table-I run.
#[derive(Clone, Debug)]
pub struct Table1 {
    /// One row per replica graph.
    pub rows: Vec<Row>,
    /// The k the runs used.
    pub k: u32,
    /// Replica scale the table was generated at.
    pub scale: f64,
}

impl Table1 {
    /// Geomean speedups: (CPU fine/coarse, GPU fine/coarse, GPU-F/CPU-F).
    pub fn headline(&self) -> (f64, f64, f64) {
        let cpu: Vec<f64> = self.rows.iter().map(|r| r.time_ms[0] / r.time_ms[1]).collect();
        let gpu: Vec<f64> = self.rows.iter().map(|r| r.time_ms[2] / r.time_ms[3]).collect();
        let cross: Vec<f64> = self.rows.iter().map(|r| r.time_ms[1] / r.time_ms[3]).collect();
        (
            geomean(&cpu).unwrap_or(f64::NAN),
            geomean(&gpu).unwrap_or(f64::NAN),
            geomean(&cross).unwrap_or(f64::NAN),
        )
    }

    /// Render in the paper's column layout.
    pub fn render(&self) -> String {
        let mut t = Table::new(vec![
            "Input Graph",
            "Vertices",
            "Edges",
            "CPU-C ms",
            "CPU-F ms",
            "GPU-C ms",
            "GPU-F ms",
            "CPU-C ME/s",
            "CPU-F ME/s",
            "GPU-C ME/s",
            "GPU-F ME/s",
        ]);
        for r in &self.rows {
            t.row(vec![
                r.name.clone(),
                count_k(r.vertices),
                count_k(r.edges),
                ms(r.time_ms[0]),
                ms(r.time_ms[1]),
                ms(r.time_ms[2]),
                ms(r.time_ms[3]),
                mes(r.me_s[0]),
                mes(r.me_s[1]),
                mes(r.me_s[2]),
                mes(r.me_s[3]),
            ]);
        }
        let (cpu, gpu, cross) = self.headline();
        format!(
            "{}\ngeomean speedups (K={}): CPU fine/coarse {}   GPU fine/coarse {}   GPU-F/CPU-F {}\n(paper: CPU 1.48x, GPU 16.93x, GPU-F/CPU-F 1.92x at K=3, full-size SNAP graphs)\n",
            t.render(),
            self.k,
            speedup(cpu),
            speedup(gpu),
            speedup(cross),
        )
    }
}

/// Run Table I at `k` over the workload.
pub fn run(w: &Workload, k: u32, mut progress: impl FnMut(&str)) -> Result<Table1> {
    let configs = table1_configs();
    let mut rows = Vec::new();
    for spec in &w.specs {
        let g = w.load(spec)?;
        let res = simulate_ktruss(&g, k, &configs);
        progress(&format!("{}: {} edges, {} iterations", spec.name, g.nnz(), res[0].iterations));
        rows.push(Row {
            name: spec.name.to_string(),
            vertices: g.n(),
            edges: g.nnz(),
            time_ms: [res[0].time_ms(), res[1].time_ms(), res[2].time_ms(), res[3].time_ms()],
            me_s: [res[0].me_per_s, res[1].me_per_s, res[2].me_per_s, res[3].me_per_s],
        });
    }
    Ok(Table1 { rows, k, scale: w.scale })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::suite::by_name;

    #[test]
    fn table1_on_two_graphs() {
        let w = Workload {
            specs: vec![by_name("as20000102").unwrap(), by_name("p2p-Gnutella08").unwrap()],
            scale: 0.05,
        };
        let t = run(&w, 3, |_| {}).unwrap();
        assert_eq!(t.rows.len(), 2);
        let (cpu, gpu, _) = t.headline();
        assert!(cpu.is_finite() && gpu.is_finite());
        let rendered = t.render();
        assert!(rendered.contains("as20000102"));
        assert!(rendered.contains("geomean"));
    }
}
