//! Lockstep-lane backend study: the executing counterpart of the
//! `gpu-sched` model sweep.
//!
//! Three claims, each checked with exact step accounting where
//! possible so CI stays deterministic:
//!
//! * **Intra-warp balancing wins on hubs** — on the hub-divergence
//!   fixtures, the lane backend's warp makespan at fine/hybrid
//!   granularity beats the coarse (row-per-lane) decomposition, the
//!   executed analogue of the paper's granularity result. Step-exact,
//!   no wallclock involved.
//! * **The calibration loop closes** — one [`calibrate_lane`] pass fits
//!   step/launch/divergence constants; feeding the fitted machine and
//!   the backend's measured per-task steps through
//!   [`estimate_tasks_sched`] must predict the measured lane wall
//!   within [`CAL_BAND`]. Model-vs-executed ratios per regime feed a
//!   [`DriftTracker`] under `gpu/…` keys (rendered in the report).
//! * **The fused frontier sweep saves its re-reads** — the lane
//!   driver's fused mark+decrement accounting on the peel-chain
//!   fixture shows exactly `frontier-size` fewer steps than the
//!   mark-then-decrement pair of launches.

use crate::algo::support::Granularity;
use crate::exec::lane::{compute_supports_lane, ktruss_lane_report};
use crate::obs::drift::DriftTracker;
use crate::par::{ktruss_par_plan, PassControl, Pool, Schedule};
use crate::plan::{ExecutionPlan, Planner};
use crate::sim::calibrate::{calibrate_lane, lane_regime, LaneCalibration};
use crate::sim::gpu::estimate_tasks_sched;
use crate::util::fmt::Table;
use crate::util::Timer;
use anyhow::Result;

/// The calibration band: after one calibration pass, the measured lane
/// wall must sit within this factor of the fitted model's prediction
/// (either side).
pub const CAL_BAND: f64 = 1.5;

/// One granularity's lane execution on the hub fixture.
#[derive(Clone, Debug)]
pub struct HubRow {
    /// Granularity label.
    pub gran: String,
    /// Warp makespan (lockstep steps) of the support pass.
    pub makespan_steps: u64,
    /// Total executed lane steps.
    pub executed_steps: u64,
    /// Idle lane-steps under the divergence mask.
    pub idle_lane_steps: u64,
    /// Measured wall of the pass, ms.
    pub wall_ms: f64,
}

/// The full study report.
#[derive(Clone, Debug)]
pub struct LaneBenchReport {
    /// Pool workers the lane blocks ran on.
    pub workers: usize,
    /// Hub-fixture rows (coarse, fine, hybrid).
    pub hub: Vec<HubRow>,
    /// The fitted calibration constants.
    pub cal: LaneCalibration,
    /// Model-predicted wall of the band-check pass, ms.
    pub band_predicted_ms: f64,
    /// Measured wall of the band-check pass, ms.
    pub band_measured_ms: f64,
    /// Fused mark+decrement steps over the peel-chain run.
    pub fused_steps: u64,
    /// Separate mark-then-decrement steps over the same run.
    pub separate_steps: u64,
    /// Frontier tasks the fused path avoided re-reading.
    pub frontier_tasks: u64,
    /// Per-regime model-vs-executed drift lines (`gpu/…` keys).
    pub drift: String,
}

impl LaneBenchReport {
    /// measured / predicted of the band-check pass.
    pub fn band_ratio(&self) -> f64 {
        self.band_measured_ms / self.band_predicted_ms.max(1e-12)
    }

    /// Every invariant the CI smoke job relies on.
    pub fn verify(&self) -> Result<()> {
        let coarse = self
            .hub
            .iter()
            .find(|r| r.gran == "coarse")
            .ok_or_else(|| anyhow::anyhow!("missing coarse hub row"))?;
        for r in self.hub.iter().filter(|r| r.gran != "coarse") {
            if r.makespan_steps >= coarse.makespan_steps {
                anyhow::bail!(
                    "lane {} makespan {} steps does not beat coarse {} steps on the hub fixture",
                    r.gran,
                    r.makespan_steps,
                    coarse.makespan_steps
                );
            }
        }
        let ratio = self.band_ratio();
        if !(1.0 / CAL_BAND..=CAL_BAND).contains(&ratio) {
            anyhow::bail!(
                "calibrated model missed the band: measured {:.4} ms vs predicted {:.4} ms \
                 (ratio {:.3}, band {CAL_BAND}x)",
                self.band_measured_ms,
                self.band_predicted_ms,
                ratio
            );
        }
        if self.fused_steps + self.frontier_tasks != self.separate_steps {
            anyhow::bail!(
                "fused accounting broke: fused {} + frontier {} != separate {}",
                self.fused_steps,
                self.frontier_tasks,
                self.separate_steps
            );
        }
        if self.frontier_tasks > 0 && self.fused_steps >= self.separate_steps {
            anyhow::bail!(
                "fused sweep did not reduce steps: {} vs {}",
                self.fused_steps,
                self.separate_steps
            );
        }
        Ok(())
    }

    /// Render the study as tables plus greppable check lines.
    pub fn render(&self) -> String {
        let mut table =
            Table::new(vec!["hub pass", "makespan steps", "executed", "idle lanes", "wall ms"]);
        for r in &self.hub {
            table.row(vec![
                r.gran.clone(),
                r.makespan_steps.to_string(),
                r.executed_steps.to_string(),
                r.idle_lane_steps.to_string(),
                format!("{:.4}", r.wall_ms),
            ]);
        }
        let mut out = format!(
            "# lane backend study ({} workers, warp calibration: step {:.2} ns, \
             serial {:.2} ns, launch {:.2} us, occupancy {:.2} lanes/warp-step)\n",
            self.workers,
            self.cal.step_ns,
            self.cal.serial_step_ns,
            self.cal.launch_us,
            self.cal.divergence_ratio
        );
        out.push_str(&table.render());
        out.push_str(&format!(
            "model-vs-executed: predicted {:.4} ms, measured {:.4} ms, ratio {:.3} \
             (band {CAL_BAND}x): {}\n",
            self.band_predicted_ms,
            self.band_measured_ms,
            self.band_ratio(),
            if (1.0 / CAL_BAND..=CAL_BAND).contains(&self.band_ratio()) { "ok" } else { "MISS" }
        ));
        out.push_str(&format!(
            "fused-frontier: {} steps vs {} separate ({} re-reads saved): {}\n",
            self.fused_steps,
            self.separate_steps,
            self.frontier_tasks,
            if self.fused_steps + self.frontier_tasks == self.separate_steps { "ok" } else { "MISS" }
        ));
        let coarse_makespan =
            self.hub.iter().find(|r| r.gran == "coarse").map(|r| r.makespan_steps).unwrap_or(0);
        out.push_str(&format!(
            "lane-beats-coarse-on-hub: {}\n",
            if self
                .hub
                .iter()
                .filter(|r| r.gran != "coarse")
                .all(|r| r.makespan_steps < coarse_makespan)
            {
                "ok"
            } else {
                "MISS"
            }
        ));
        if !self.drift.is_empty() {
            out.push_str(&self.drift);
            out.push('\n');
        }
        out
    }
}

/// One timed lane support pass: returns the report of a cold pass and
/// the trial-averaged wall of the warm passes.
fn timed_pass(
    z: &crate::graph::ZCsr,
    pool: &Pool,
    gran: Granularity,
    schedule: Schedule,
) -> (crate::exec::lane::LaneReport, f64) {
    let (_, report) = compute_supports_lane(z, pool, gran, schedule);
    let trials = 3;
    let t = Timer::start();
    for _ in 0..trials {
        let (s, _) = compute_supports_lane(z, pool, gran, schedule);
        std::hint::black_box(&s);
    }
    (report, t.elapsed_ms() / trials as f64)
}

/// Run the study on `workers` pool workers.
pub fn run(workers: usize, progress: impl Fn(&str)) -> Result<LaneBenchReport> {
    let pool = Pool::new(workers.max(1));
    let hub_graph = crate::graph::ZCsr::from_csr(&crate::testkit::graphs::hub_divergence_comb(
        64, 256, 800,
    ));
    let drift = DriftTracker::new();

    progress("calibrating lane constants (balanced / hub / launch fixtures)");
    let cal = calibrate_lane(&pool);
    let machine = cal.fitted_machine(pool.workers());

    let mut hub = Vec::new();
    for (label, gran) in [
        ("coarse", Granularity::Coarse),
        ("fine", Granularity::Fine),
        ("hybrid", Granularity::Hybrid { len: 64 }),
    ] {
        let (report, wall_ms) = timed_pass(&hub_graph, &pool, gran, Schedule::Stealing);
        progress(&format!(
            "hub {label}: makespan {} steps, executed {}, wall {:.3} ms",
            report.makespan_steps, report.executed_steps, wall_ms
        ));
        // model-vs-executed per regime: the fitted machine prices the
        // measured per-task steps; the drift tracker accumulates the
        // ratio under the gpu/ regime key
        let costs: Vec<f64> = report.task_steps.iter().map(|&c| c as f64).collect();
        let predicted_ms = estimate_tasks_sched(
            &machine,
            &costs,
            report.executed_steps as f64,
            Schedule::Stealing,
        )
        .total_s()
            * 1e3;
        drift.observe(&lane_regime(Schedule::Stealing, gran), predicted_ms, wall_ms);
        hub.push(HubRow {
            gran: label.to_string(),
            makespan_steps: report.makespan_steps,
            executed_steps: report.executed_steps,
            idle_lane_steps: report.idle_lane_steps,
            wall_ms,
        });
    }

    // band check: a fine/stealing hub pass against the fitted model
    progress("band check: fine/stealing hub pass vs fitted model");
    let (report, band_measured_ms) =
        timed_pass(&hub_graph, &pool, Granularity::Fine, Schedule::Stealing);
    let costs: Vec<f64> = report.task_steps.iter().map(|&c| c as f64).collect();
    let band_predicted_ms =
        estimate_tasks_sched(&machine, &costs, report.executed_steps as f64, Schedule::Stealing)
            .total_s()
            * 1e3;
    drift.observe(
        &lane_regime(Schedule::Stealing, Granularity::Fine),
        band_predicted_ms,
        band_measured_ms,
    );

    // fused frontier sweep on the peel chain (the incremental regime)
    progress("fused frontier sweep on peel_chain(16)");
    let chain = crate::testkit::graphs::peel_chain(16);
    let plan = Planner::gpu()
        .with_spec(crate::plan::PlanSpec {
            schedule: Some(Schedule::Stealing),
            granularity: Some(Granularity::Fine),
            support: Some(crate::algo::incremental::SupportMode::Auto),
            crossover: None,
        })
        .choose(&chain, 4);
    let (result, lane_run, _) =
        ktruss_lane_report(&chain, 4, &pool, &plan, PassControl::default());
    let pool_result = ktruss_par_plan(
        &chain,
        4,
        &pool,
        &ExecutionPlan { device: crate::plan::PlanDevice::Cpu, ..plan },
    );
    if result.truss != pool_result.truss {
        anyhow::bail!("lane truss diverged from the pool truss on peel_chain(16)");
    }
    let frontier_tasks = lane_run.separate_steps - lane_run.fused_steps;

    Ok(LaneBenchReport {
        workers: pool.workers(),
        hub,
        cal,
        band_predicted_ms,
        band_measured_ms,
        fused_steps: lane_run.fused_steps,
        separate_steps: lane_run.separate_steps,
        frontier_tasks,
        drift: drift.render(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_study_holds_the_step_invariants() {
        // wallclock-free subset of verify(): hub makespans and the
        // fused accounting are exact, so they never flake
        let pool = Pool::new(2);
        let hub = crate::graph::ZCsr::from_csr(&crate::testkit::graphs::hub_divergence_comb(
            32, 128, 400,
        ));
        let (_, coarse) =
            compute_supports_lane(&hub, &pool, Granularity::Coarse, Schedule::Stealing);
        let (_, fine) = compute_supports_lane(&hub, &pool, Granularity::Fine, Schedule::Stealing);
        assert!(
            fine.makespan_steps < coarse.makespan_steps,
            "fine {} vs coarse {}",
            fine.makespan_steps,
            coarse.makespan_steps
        );

        let chain = crate::testkit::graphs::peel_chain(12);
        let plan = Planner::gpu().choose(&chain, 4);
        let (result, run, _) =
            ktruss_lane_report(&chain, 4, &pool, &plan, PassControl::default());
        let cpu = ktruss_par_plan(
            &chain,
            4,
            &pool,
            &ExecutionPlan { device: crate::plan::PlanDevice::Cpu, ..plan },
        );
        assert_eq!(result.truss, cpu.truss, "lane/pool truss parity");
        assert!(run.separate_steps >= run.fused_steps);
    }

    #[test]
    fn report_checks_render_greppably() {
        let report = LaneBenchReport {
            workers: 2,
            hub: vec![
                HubRow {
                    gran: "coarse".into(),
                    makespan_steps: 100,
                    executed_steps: 120,
                    idle_lane_steps: 300,
                    wall_ms: 0.5,
                },
                HubRow {
                    gran: "fine".into(),
                    makespan_steps: 40,
                    executed_steps: 120,
                    idle_lane_steps: 20,
                    wall_ms: 0.2,
                },
            ],
            cal: calibrate_stub(),
            band_predicted_ms: 1.0,
            band_measured_ms: 1.2,
            fused_steps: 90,
            separate_steps: 100,
            frontier_tasks: 10,
            drift: String::new(),
        };
        assert!(report.verify().is_ok());
        let text = report.render();
        assert!(text.contains("lane-beats-coarse-on-hub: ok"), "{text}");
        assert!(text.contains("fused-frontier"), "{text}");
        assert!(text.contains("model-vs-executed"), "{text}");
    }

    fn calibrate_stub() -> LaneCalibration {
        LaneCalibration {
            step_ns: 2.0,
            serial_step_ns: 4.0,
            launch_us: 5.0,
            divergence_ratio: 3.0,
            makespan_steps: 1000,
            wall_ms: 0.5,
        }
    }
}
