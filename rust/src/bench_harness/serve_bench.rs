//! Serving throughput workload: open-loop job arrivals over the
//! generator families, replayed against the sharded executor at
//! several shard counts (same *total* worker budget), reporting
//! throughput, p50/p99 serving latency, deadline-miss rate, and steal
//! counts per shard count.
//!
//! The job mix is deliberately skewed — a stream of small interactive
//! jobs with an occasional heavy batch job — because that is the regime
//! where sharding pays: a single-pool dispatcher serializes the stream
//! behind each heavy job (head-of-line blocking, the paper's coarse-
//! task pathology at job granularity), while ≥2 shards isolate the
//! heavy job on one shard and keep small jobs flowing through the
//! others.

use crate::algo::support::Mode;
use crate::coordinator::job::JobKind;
use crate::gen;
use crate::graph::Csr;
use crate::serve::{Executor, Priority, ServeConfig, SubmitOpts, Ticket};
use crate::util::{Rng, Timer};
use anyhow::Result;
use std::sync::Arc;
use std::time::Duration;

/// Workload knobs.
#[derive(Clone, Debug)]
pub struct ThroughputConfig {
    /// Jobs per shard-count run.
    pub jobs: usize,
    /// Open-loop inter-arrival gap in microseconds (arrivals do not
    /// wait for completions).
    pub arrival_us: u64,
    /// Total worker budget, split evenly across shards in each run.
    pub total_workers: usize,
    /// Shard counts to sweep (each run replays the identical job set).
    pub shard_counts: Vec<usize>,
    /// Soft deadline attached to high-priority jobs.
    pub deadline_ms: u64,
    /// Workload RNG seed (graphs and kinds are pre-generated once).
    pub seed: u64,
}

impl Default for ThroughputConfig {
    fn default() -> Self {
        ThroughputConfig {
            jobs: 120,
            arrival_us: 300,
            total_workers: 4,
            shard_counts: vec![1, 2, 4],
            deadline_ms: 50,
            seed: 42,
        }
    }
}

/// One pre-generated job of the workload.
struct JobSpec {
    graph: Arc<Csr>,
    kind: JobKind,
    priority: Priority,
    deadline: Option<Duration>,
}

/// Measured outcome of one shard-count run.
#[derive(Clone, Debug)]
pub struct ShardRun {
    /// Shard count of this run.
    pub shards: usize,
    /// Pool workers per shard.
    pub workers_per_shard: usize,
    /// Total wall time of the run, ms.
    pub wall_ms: f64,
    /// Completed jobs per second over the whole run.
    pub throughput_jps: f64,
    /// Serving latency (queueing + execution) p50, ms.
    pub p50_ms: f64,
    /// Serving latency (queueing + execution) p99, ms.
    pub p99_ms: f64,
    /// Soft-deadline misses / jobs that carried a deadline.
    pub miss_rate: f64,
    /// Jobs executed by a shard other than the one they were packed to.
    pub stolen: u64,
    /// Prometheus-style text exposition of the run's serving counters
    /// and plan-drift gauges, captured just before executor shutdown.
    pub exposition: String,
}

/// Full sweep report.
#[derive(Clone, Debug)]
pub struct ThroughputReport {
    /// Jobs submitted per shard-count run.
    pub jobs: usize,
    /// Open-loop inter-arrival gap, microseconds.
    pub arrival_us: u64,
    /// Total pool workers split across the shards.
    pub total_workers: usize,
    /// One entry per swept shard count.
    pub runs: Vec<ShardRun>,
}

impl ThroughputReport {
    /// Throughput of the best multi-shard run over the 1-shard run
    /// (`None` when the sweep lacks either side).
    pub fn sharding_speedup(&self) -> Option<f64> {
        let single = self.runs.iter().find(|r| r.shards == 1)?;
        let best = self
            .runs
            .iter()
            .filter(|r| r.shards > 1)
            .map(|r| r.throughput_jps)
            .fold(f64::NAN, f64::max);
        if best.is_nan() || single.throughput_jps <= 0.0 {
            return None;
        }
        Some(best / single.throughput_jps)
    }

    /// Render the sweep as an aligned plain-text table.
    pub fn render(&self) -> String {
        let mut out = format!(
            "# serve throughput: {} open-loop jobs, {} us inter-arrival, {} total workers\n\
             # skewed mix: ~87% small interactive jobs (25% high-priority w/ deadline), ~13% heavy batch jobs\n\
             {:>7} {:>12} {:>10} {:>10} {:>10} {:>10} {:>9} {:>7}\n",
            self.jobs,
            self.arrival_us,
            self.total_workers,
            "shards",
            "workers/sh",
            "wall_ms",
            "jobs/s",
            "p50_ms",
            "p99_ms",
            "miss%",
            "stolen"
        );
        for r in &self.runs {
            out.push_str(&format!(
                "{:>7} {:>12} {:>10.1} {:>10.1} {:>10.3} {:>10.3} {:>9.1} {:>7}\n",
                r.shards,
                r.workers_per_shard,
                r.wall_ms,
                r.throughput_jps,
                r.p50_ms,
                r.p99_ms,
                r.miss_rate * 100.0,
                r.stolen
            ));
        }
        if let Some(s) = self.sharding_speedup() {
            out.push_str(&format!(
                "# best multi-shard throughput vs single-pool dispatcher: {s:.2}x\n"
            ));
        }
        if let Some(r) = self.runs.last() {
            out.push_str(&format!("\n# metrics exposition ({} shard(s), last run):\n", r.shards));
            out.push_str(&r.exposition);
        }
        out
    }
}

/// Pre-generate the job set once so every shard count replays an
/// identical workload.
fn generate_jobs(cfg: &ThroughputConfig) -> Vec<JobSpec> {
    let mut rng = Rng::new(cfg.seed);
    let mut jobs = Vec::with_capacity(cfg.jobs);
    for i in 0..cfg.jobs {
        if i % 8 == 7 {
            // heavy batch job: large power-law graph, multi-round kind
            let n = rng.range(500, 1100);
            let m = (5 * n).min(n * (n - 1) / 2);
            let g = Arc::new(gen::rmat::rmat(
                n,
                m,
                gen::rmat::RmatParams::social(),
                &mut rng,
            ));
            let kind = if i % 16 == 15 { JobKind::Decompose } else { JobKind::Kmax };
            jobs.push(JobSpec { graph: g, kind, priority: Priority::Low, deadline: None });
        } else {
            // small interactive job
            let n = rng.range(40, 160);
            let m = (2 * n + rng.range(0, n)).min(n * (n - 1) / 2);
            let g = Arc::new(gen::erdos_renyi::gnm(n, m, &mut rng));
            let kind = match i % 3 {
                0 => JobKind::Triangles,
                1 => JobKind::Ktruss { k: 3, mode: Mode::Fine },
                _ => JobKind::Ktruss { k: 4, mode: Mode::Coarse },
            };
            let (priority, deadline) = if i % 4 == 0 {
                (Priority::High, Some(Duration::from_millis(cfg.deadline_ms)))
            } else {
                (Priority::Normal, None)
            };
            jobs.push(JobSpec { graph: g, kind, priority, deadline });
        }
    }
    jobs
}

/// Replay the workload once against `shards` shards.
fn run_one(cfg: &ThroughputConfig, jobs: &[JobSpec], shards: usize) -> Result<ShardRun> {
    let serve_cfg = ServeConfig {
        shards,
        enable_dense: false,
        batch_window: Duration::from_millis(1),
        ..Default::default()
    }
    .with_total_workers(cfg.total_workers);
    let workers_per_shard = serve_cfg.workers_per_shard;
    let ex = Executor::start(serve_cfg);
    let deadline_jobs = jobs.iter().filter(|j| j.deadline.is_some()).count() as u64;
    let t = Timer::start();
    let mut tickets: Vec<Ticket> = Vec::with_capacity(jobs.len());
    for j in jobs {
        tickets.push(ex.submit_with(
            Arc::clone(&j.graph),
            j.kind.clone(),
            SubmitOpts { priority: j.priority, deadline: j.deadline, degrade_store: None },
        ));
        if cfg.arrival_us > 0 {
            std::thread::sleep(Duration::from_micros(cfg.arrival_us));
        }
    }
    for ticket in tickets {
        let r = ticket.wait();
        if let Err(e) = &r.output {
            anyhow::bail!("job {} failed: {e}", r.id);
        }
    }
    let wall_ms = t.elapsed_ms();
    let p50_ms = ex.metrics.quantile(0.50).unwrap_or(0.0);
    let p99_ms = ex.metrics.quantile(0.99).unwrap_or(0.0);
    let misses = ex.metrics.deadline_misses();
    let stolen = ex.metrics.steals();
    let exposition = crate::obs::prom::render(&ex.metrics, Some(&ex.obs.drift));
    ex.shutdown();
    Ok(ShardRun {
        shards,
        workers_per_shard,
        wall_ms,
        throughput_jps: jobs.len() as f64 / (wall_ms / 1e3).max(1e-9),
        p50_ms,
        p99_ms,
        miss_rate: if deadline_jobs == 0 { 0.0 } else { misses as f64 / deadline_jobs as f64 },
        stolen,
        exposition,
    })
}

/// Run the full shard-count sweep.
pub fn run(cfg: &ThroughputConfig, progress: impl Fn(&str)) -> Result<ThroughputReport> {
    if cfg.jobs == 0 || cfg.shard_counts.is_empty() {
        anyhow::bail!("serve bench needs ≥1 job and ≥1 shard count");
    }
    let jobs = generate_jobs(cfg);
    let mut runs = Vec::new();
    for &shards in &cfg.shard_counts {
        let shards = shards.max(1);
        if shards > cfg.total_workers.max(1) {
            // a shard floor of 1 worker would exceed the budget and
            // falsely credit the extra parallelism to sharding
            progress(&format!(
                "skipping shards={shards}: exceeds the {}-worker budget",
                cfg.total_workers
            ));
            continue;
        }
        progress(&format!("shards={shards}: replaying {} jobs", jobs.len()));
        runs.push(run_one(cfg, &jobs, shards)?);
    }
    if runs.is_empty() {
        anyhow::bail!("every shard count exceeded the {}-worker budget", cfg.total_workers);
    }
    Ok(ThroughputReport {
        jobs: cfg.jobs,
        arrival_us: cfg.arrival_us,
        total_workers: cfg.total_workers,
        runs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_sweep_completes_and_renders() {
        let cfg = ThroughputConfig {
            jobs: 16,
            arrival_us: 50,
            total_workers: 2,
            shard_counts: vec![1, 2],
            deadline_ms: 40,
            seed: 7,
        };
        let report = run(&cfg, |_| {}).unwrap();
        assert_eq!(report.runs.len(), 2);
        for r in &report.runs {
            assert!(r.wall_ms > 0.0);
            assert!(r.throughput_jps > 0.0);
            assert!(r.p99_ms >= r.p50_ms);
            assert!((0.0..=1.0).contains(&r.miss_rate));
        }
        let text = report.render();
        assert!(text.contains("jobs/s"));
        assert!(text.contains("p99_ms"));
        assert!(text.contains("ktruss_jobs_submitted_total"));
        assert!(report.sharding_speedup().is_some());
        for r in &report.runs {
            assert!(r.exposition.contains("ktruss_jobs_completed_total"));
        }
    }

    #[test]
    fn degenerate_configs_are_rejected() {
        let no_jobs = ThroughputConfig { jobs: 0, ..Default::default() };
        assert!(run(&no_jobs, |_| {}).is_err());
        let no_shards = ThroughputConfig { shard_counts: Vec::new(), ..Default::default() };
        assert!(run(&no_shards, |_| {}).is_err());
    }
}
