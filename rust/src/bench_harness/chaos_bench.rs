//! Chaos / overload-recovery study for the fault-tolerant executor.
//!
//! Three runs over one pre-generated workload:
//!
//! 1. **reference** — fault-free, closed-loop (each job waits before the
//!    next submits): produces the ground-truth output signature for
//!    every job and the *unloaded* high-priority latency baseline.
//! 2. **chaos, no shedding** — a head-of-line wave of heavy doomed
//!    low-priority jobs bursts in first and occupies the shards, then
//!    the interactive stream arrives open-loop; seeded faults (exec
//!    panics, shard crashes, stalls) fire throughout.
//! 3. **chaos, shedding** — identical workload and fault seed, but
//!    admission control is on: the doomed heavies are shed at admission
//!    (predicted cost cannot meet their deadline), so shards stay free
//!    for the interactive stream.
//!
//! The report verifies the chaos invariants — every submitted job
//! reaches exactly one terminal outcome, no job is lost or duplicated,
//! every `done` result is bit-identical to the fault-free reference,
//! the injected faults actually fired, and shedding keeps the
//! high-priority p99 strictly below the unshed run — and stamps
//! `chaos-ok` into the rendered table only when all of them hold.

use crate::algo::support::Mode;
use crate::coordinator::job::{JobKind, JobOutcome, JobOutput};
use crate::gen;
use crate::graph::Csr;
use crate::serve::{Executor, FaultPlan, Priority, ServeConfig, SubmitOpts, Ticket};
use crate::util::{Rng, Timer};
use anyhow::Result;
use std::collections::{BTreeMap, HashMap, HashSet};
use std::sync::Arc;
use std::time::Duration;

/// Workload and fault-injection knobs.
#[derive(Clone, Debug)]
pub struct ChaosConfig {
    /// Interactive stream jobs (small, high/normal priority).
    pub jobs: usize,
    /// Heavy head-of-line jobs submitted as an initial burst: large
    /// decompositions at `Priority::Low` with a deadline they cannot
    /// meet — shed fodder under admission control, shard blockers
    /// without it.
    pub heavy: usize,
    /// Vertex count of each heavy job's graph.
    pub heavy_n: usize,
    /// Open-loop inter-arrival gap of the interactive stream, µs.
    pub arrival_us: u64,
    /// Total worker budget, split evenly across shards.
    pub total_workers: usize,
    /// Shard count for every run.
    pub shards: usize,
    /// Workload RNG seed (graphs and kinds are pre-generated once).
    pub seed: u64,
    /// Seeded fault plan driving both chaos runs.
    pub faults: FaultPlan,
    /// Retry budget per job shape before quarantine.
    pub retry_max: u32,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        ChaosConfig {
            jobs: 48,
            heavy: 6,
            heavy_n: 700,
            arrival_us: 400,
            total_workers: 2,
            shards: 2,
            seed: 42,
            faults: FaultPlan {
                seed: 42,
                exec_panic_every: 6,
                transient: true,
                shard_crash_every: 17,
                stall_every: 9,
                stall_ms: 2,
            },
            retry_max: 3,
        }
    }
}

/// One pre-generated job of the workload.
struct JobSpec {
    graph: Arc<Csr>,
    kind: JobKind,
    priority: Priority,
    deadline: Option<Duration>,
}

/// Measured outcome of one run.
#[derive(Clone, Debug)]
pub struct ChaosRun {
    /// Run label (`reference`, `chaos/no-shed`, `chaos/shed`).
    pub label: String,
    /// Jobs submitted (admitted + shed; rejects cannot occur — the
    /// admission queue is unbounded in this study).
    pub submitted: usize,
    /// Tickets that resolved to a terminal outcome (conservation
    /// requires `resolved == submitted`).
    pub resolved: usize,
    /// Terminal outcome counts, sorted by outcome name.
    pub outcomes: Vec<(String, usize)>,
    /// Total wall time of the run, ms.
    pub wall_ms: f64,
    /// Time from the last submission to full drain, ms (recovery time).
    pub drain_ms: f64,
    /// High-priority serving latency p50, ms.
    pub high_p50_ms: f64,
    /// High-priority serving latency p99, ms.
    pub high_p99_ms: f64,
    /// Jobs shed at admission.
    pub shed: u64,
    /// Panic retries.
    pub retries: u64,
    /// Quarantined jobs.
    pub quarantined: u64,
    /// Shard supervisor respawns.
    pub respawns: u64,
    /// Injected execution panics.
    pub exec_panics: u64,
    /// Injected shard-body crashes.
    pub shard_crashes: u64,
    /// Injected pass-boundary stalls.
    pub stalls: u64,
    /// `done` jobs compared against the fault-free reference.
    pub done_checked: usize,
    /// `done` jobs whose output differed from the reference (must be 0).
    pub mismatched: usize,
    /// Prometheus-style exposition captured before shutdown.
    pub exposition: String,
}

/// Full study report.
#[derive(Clone, Debug)]
pub struct ChaosReport {
    /// Interactive stream jobs per run.
    pub jobs: usize,
    /// Heavy head-of-line jobs per run.
    pub heavy: usize,
    /// Unloaded high-priority p99 from the closed-loop reference, ms.
    pub baseline_p99_ms: f64,
    /// The three runs: reference, chaos/no-shed, chaos/shed.
    pub runs: Vec<ChaosRun>,
}

impl ChaosReport {
    /// The chaos/no-shed run.
    fn noshed(&self) -> Option<&ChaosRun> {
        self.runs.iter().find(|r| r.label == "chaos/no-shed")
    }

    /// The chaos/shed run.
    fn shed(&self) -> Option<&ChaosRun> {
        self.runs.iter().find(|r| r.label == "chaos/shed")
    }

    /// Check every chaos invariant; `Err` names the first violation.
    pub fn verify(&self) -> Result<(), String> {
        for r in &self.runs {
            if r.resolved != r.submitted {
                return Err(format!(
                    "{}: {} submitted but {} resolved (jobs lost or duplicated)",
                    r.label, r.submitted, r.resolved
                ));
            }
            let counted: usize = r.outcomes.iter().map(|(_, c)| c).sum();
            if counted != r.submitted {
                return Err(format!(
                    "{}: outcome counts sum to {counted}, expected {}",
                    r.label, r.submitted
                ));
            }
            if r.mismatched != 0 {
                return Err(format!(
                    "{}: {} of {} done jobs diverged from the fault-free reference",
                    r.label, r.mismatched, r.done_checked
                ));
            }
        }
        let noshed = self.noshed().ok_or_else(|| "missing chaos/no-shed run".to_string())?;
        let shed = self.shed().ok_or_else(|| "missing chaos/shed run".to_string())?;
        for r in [noshed, shed] {
            if r.exec_panics + r.shard_crashes + r.stalls == 0 {
                return Err(format!("{}: no injected fault fired", r.label));
            }
        }
        if noshed.respawns + shed.respawns == 0 {
            return Err("no shard respawned across the chaos runs".to_string());
        }
        if shed.shed == 0 {
            return Err("chaos/shed run shed nothing under burst".to_string());
        }
        if shed.high_p99_ms >= noshed.high_p99_ms {
            return Err(format!(
                "shedding did not improve high-priority p99: {:.3}ms (shed) vs {:.3}ms (no-shed)",
                shed.high_p99_ms, noshed.high_p99_ms
            ));
        }
        Ok(())
    }

    /// Render the study as an aligned plain-text table with the
    /// invariant verdict and the shed run's metrics exposition.
    pub fn render(&self) -> String {
        let mut out = format!(
            "# chaos recovery: {} stream jobs + {} heavy head-of-line jobs, seeded faults\n\
             # unloaded high-priority p99 baseline: {:.3} ms\n\
             {:>14} {:>6} {:>9} {:>9} {:>9} {:>9} {:>6} {:>6} {:>5} {:>8} {:>7} {:>7} {:>7}\n",
            self.jobs,
            self.heavy,
            self.baseline_p99_ms,
            "run",
            "jobs",
            "wall_ms",
            "drain_ms",
            "hi_p50",
            "hi_p99",
            "shed",
            "retry",
            "quar",
            "respawns",
            "panics",
            "crashes",
            "stalls"
        );
        for r in &self.runs {
            out.push_str(&format!(
                "{:>14} {:>6} {:>9.1} {:>9.1} {:>9.3} {:>9.3} {:>6} {:>6} {:>5} {:>8} {:>7} {:>7} {:>7}\n",
                r.label,
                r.submitted,
                r.wall_ms,
                r.drain_ms,
                r.high_p50_ms,
                r.high_p99_ms,
                r.shed,
                r.retries,
                r.quarantined,
                r.respawns,
                r.exec_panics,
                r.shard_crashes,
                r.stalls
            ));
        }
        for r in &self.runs {
            let counts = r
                .outcomes
                .iter()
                .map(|(o, c)| format!("{c} {o}"))
                .collect::<Vec<_>>()
                .join(", ");
            out.push_str(&format!("# {}: {counts}\n", r.label));
        }
        if let (Some(ns), Some(s)) = (self.noshed(), self.shed()) {
            out.push_str(&format!(
                "# high-priority p99 vs unloaded baseline: {:.2}x without shedding, {:.2}x with\n",
                ns.high_p99_ms / self.baseline_p99_ms.max(1e-9),
                s.high_p99_ms / self.baseline_p99_ms.max(1e-9)
            ));
        }
        match self.verify() {
            Ok(()) => out.push_str(
                "# chaos-ok: every job reached one terminal outcome, done results match the \
                 fault-free reference, shedding beat no-shedding on high-priority p99\n",
            ),
            Err(e) => out.push_str(&format!("# chaos-FAILED: {e}\n")),
        }
        if let Some(r) = self.shed() {
            out.push_str("\n# metrics exposition (chaos/shed run):\n");
            out.push_str(&r.exposition);
        }
        out
    }
}

/// Deterministic signature of a job output: equal signatures ⇔
/// bit-identical results (iteration counts are excluded — they are
/// plan-dependent, the truss itself is not).
fn signature(out: &JobOutput) -> String {
    fn fold(vals: impl Iterator<Item = u64>) -> u64 {
        let mut state = 0xcbf2_9ce4_8422_2325u64;
        for v in vals {
            state = (state ^ v).wrapping_mul(0x0100_0000_01b3);
        }
        state
    }
    match out {
        JobOutput::Ktruss { truss_edges, edges, .. } => format!(
            "ktruss:{truss_edges}:{:016x}",
            fold(edges.iter().flat_map(|&(u, v)| [u64::from(u), u64::from(v)]))
        ),
        JobOutput::Kmax { kmax, truss_edges } => format!("kmax:{kmax}:{truss_edges}"),
        JobOutput::Decompose { kmax, histogram } => format!(
            "decompose:{kmax}:{:016x}",
            fold(histogram.iter().flat_map(|&(k, c)| [u64::from(k), c as u64]))
        ),
        JobOutput::Triangles { count } => format!("triangles:{count}"),
        JobOutput::Mutate { .. } => "mutate".to_string(),
    }
}

/// Pre-generate the workload once so every run replays identical jobs:
/// `heavy` doomed low-priority blockers first, then the interactive
/// stream (every other job high-priority — the p99 population).
fn generate_jobs(cfg: &ChaosConfig) -> Vec<JobSpec> {
    let mut rng = Rng::new(cfg.seed);
    let mut jobs = Vec::with_capacity(cfg.heavy + cfg.jobs);
    for _ in 0..cfg.heavy {
        let n = cfg.heavy_n.max(50);
        let m = (5 * n).min(n * (n - 1) / 2);
        let g = Arc::new(gen::rmat::rmat(n, m, gen::rmat::RmatParams::social(), &mut rng));
        jobs.push(JobSpec {
            graph: g,
            kind: JobKind::Decompose,
            priority: Priority::Low,
            // a deadline no decomposition of this size can meet: the
            // shed fodder for the admission-control run
            deadline: Some(Duration::from_micros(100)),
        });
    }
    for i in 0..cfg.jobs {
        let n = rng.range(40, 140);
        let m = (2 * n + rng.range(0, n)).min(n * (n - 1) / 2);
        let g = Arc::new(gen::erdos_renyi::gnm(n, m, &mut rng));
        let kind = match i % 3 {
            0 => JobKind::Triangles,
            1 => JobKind::Ktruss { k: 3, mode: Mode::Fine },
            _ => JobKind::Ktruss { k: 4, mode: Mode::Coarse },
        };
        let priority = if i % 2 == 0 { Priority::High } else { Priority::Normal };
        jobs.push(JobSpec { graph: g, kind, priority, deadline: None });
    }
    jobs
}

/// High-priority serving-latency quantiles (p50, p99) from the job
/// spans of one run.
fn high_quantiles(ex: &Executor, high_ids: &HashSet<u64>) -> (f64, f64) {
    let mut lat: Vec<f64> = ex
        .obs
        .spans
        .snapshot()
        .iter()
        .filter(|s| high_ids.contains(&s.id))
        .map(|s| s.serve_ms)
        .collect();
    if lat.is_empty() {
        return (0.0, 0.0);
    }
    lat.sort_by(f64::total_cmp);
    let pick = |q: f64| lat[((lat.len() - 1) as f64 * q).round() as usize];
    (pick(0.50), pick(0.99))
}

/// Replay the workload once. `closed_loop` waits each ticket before the
/// next submission (the unloaded reference); otherwise the heavies
/// burst in back-to-back and the stream follows open-loop. `reference`
/// carries the fault-free signatures to diff `done` outputs against
/// (`None` on the reference run itself, which records them instead).
fn run_one(
    cfg: &ChaosConfig,
    jobs: &[JobSpec],
    label: &str,
    shed: bool,
    faults: Option<FaultPlan>,
    closed_loop: bool,
    reference: Option<&HashMap<usize, String>>,
) -> Result<(ChaosRun, HashMap<usize, String>)> {
    let serve_cfg = ServeConfig {
        shards: cfg.shards,
        enable_dense: false,
        batch_window: Duration::from_millis(1),
        shed,
        faults,
        retry_max: cfg.retry_max,
        ..Default::default()
    }
    .with_total_workers(cfg.total_workers);
    let ex = Executor::start(serve_cfg);
    let t = Timer::start();
    let mut tickets: Vec<(usize, Ticket)> = Vec::with_capacity(jobs.len());
    let mut high_ids: HashSet<u64> = HashSet::new();
    let mut outcomes: BTreeMap<String, usize> = BTreeMap::new();
    let mut signatures: HashMap<usize, String> = HashMap::new();
    let mut done_checked = 0usize;
    let mut mismatched = 0usize;
    let mut resolved = 0usize;
    let mut settle = |idx: usize, r: crate::coordinator::job::JobResult| {
        resolved += 1;
        *outcomes.entry(r.outcome.to_string()).or_insert(0) += 1;
        if r.outcome == JobOutcome::Done {
            match &r.output {
                Ok(out) => {
                    let sig = signature(out);
                    if let Some(truth) = reference {
                        done_checked += 1;
                        if truth.get(&idx) != Some(&sig) {
                            mismatched += 1;
                        }
                    }
                    signatures.insert(idx, sig);
                }
                Err(e) => anyhow::bail!("{label}: done job {} carries an error: {e}", r.id),
            }
        }
        Ok(())
    };
    for (idx, j) in jobs.iter().enumerate() {
        let opts = SubmitOpts { priority: j.priority, deadline: j.deadline, degrade_store: None };
        let ticket = ex
            .try_submit_with(Arc::clone(&j.graph), j.kind.clone(), opts)
            .map_err(|e| anyhow::anyhow!("{label}: admission refused job {idx}: {e}"))?;
        if j.priority == Priority::High {
            high_ids.insert(ticket.id);
        }
        if closed_loop {
            settle(idx, ticket.wait())?;
        } else {
            tickets.push((idx, ticket));
            // burst the heavies, pace the stream
            if idx >= cfg.heavy && cfg.arrival_us > 0 {
                std::thread::sleep(Duration::from_micros(cfg.arrival_us));
            }
        }
    }
    let submit_ms = t.elapsed_ms();
    for (idx, ticket) in tickets {
        settle(idx, ticket.wait())?;
    }
    let wall_ms = t.elapsed_ms();
    let (high_p50_ms, high_p99_ms) = high_quantiles(&ex, &high_ids);
    let exposition = crate::obs::prom::render(&ex.metrics, Some(&ex.obs.drift));
    let m = &ex.metrics;
    let (shed_n, retries, quarantined, respawns) = (
        m.shed.load(std::sync::atomic::Ordering::Relaxed),
        m.retries.load(std::sync::atomic::Ordering::Relaxed),
        m.quarantined.load(std::sync::atomic::Ordering::Relaxed),
        m.respawns(),
    );
    let (exec_panics, shard_crashes, stalls) = match &ex.faults {
        Some(inj) => (
            inj.exec_panics.load(std::sync::atomic::Ordering::Relaxed),
            inj.shard_crashes.load(std::sync::atomic::Ordering::Relaxed),
            inj.stalls.load(std::sync::atomic::Ordering::Relaxed),
        ),
        None => (0, 0, 0),
    };
    ex.shutdown();
    Ok((
        ChaosRun {
            label: label.to_string(),
            submitted: jobs.len(),
            resolved,
            outcomes: outcomes.into_iter().collect(),
            wall_ms,
            drain_ms: (wall_ms - submit_ms).max(0.0),
            high_p50_ms,
            high_p99_ms,
            shed: shed_n,
            retries,
            quarantined,
            respawns,
            exec_panics,
            shard_crashes,
            stalls,
            done_checked,
            mismatched,
            exposition,
        },
        signatures,
    ))
}

/// Run the full study: fault-free closed-loop reference, then the two
/// chaos runs (shedding off / on) over the identical workload and seed.
pub fn run(cfg: &ChaosConfig, progress: impl Fn(&str)) -> Result<ChaosReport> {
    if cfg.jobs == 0 || cfg.heavy == 0 {
        anyhow::bail!("chaos bench needs ≥1 stream job and ≥1 heavy job");
    }
    if !cfg.faults.is_active() {
        anyhow::bail!("chaos bench needs an active fault plan");
    }
    let jobs = generate_jobs(cfg);
    progress(&format!(
        "reference: fault-free closed-loop replay of {} jobs",
        jobs.len()
    ));
    let (reference, truth) = run_one(cfg, &jobs, "reference", false, None, true, None)?;
    let baseline_p99_ms = reference.high_p99_ms;
    progress("chaos/no-shed: burst + faults, admission control off");
    let (noshed, _) =
        run_one(cfg, &jobs, "chaos/no-shed", false, Some(cfg.faults), false, Some(&truth))?;
    progress("chaos/shed: burst + faults, admission control on");
    let (shed, _) =
        run_one(cfg, &jobs, "chaos/shed", true, Some(cfg.faults), false, Some(&truth))?;
    Ok(ChaosReport {
        jobs: cfg.jobs,
        heavy: cfg.heavy,
        baseline_p99_ms,
        runs: vec![reference, noshed, shed],
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_chaos_study_upholds_every_invariant() {
        let cfg = ChaosConfig {
            jobs: 14,
            heavy: 2,
            heavy_n: 300,
            arrival_us: 200,
            total_workers: 2,
            shards: 2,
            seed: 9,
            faults: FaultPlan {
                seed: 9,
                exec_panic_every: 4,
                transient: true,
                shard_crash_every: 5,
                stall_every: 6,
                stall_ms: 1,
            },
            retry_max: 3,
        };
        let report = run(&cfg, |_| {}).unwrap();
        assert_eq!(report.runs.len(), 3);
        report.verify().unwrap();
        for r in &report.runs {
            assert_eq!(r.resolved, r.submitted);
            assert_eq!(r.mismatched, 0);
        }
        let reference = &report.runs[0];
        assert_eq!(reference.outcomes, vec![("done".to_string(), 16)]);
        let text = report.render();
        assert!(text.contains("chaos-ok"));
        assert!(text.contains("ktruss_jobs_shed_total"));
        assert!(text.contains("ktruss_shard_respawns_total"));
    }

    #[test]
    fn degenerate_configs_are_rejected() {
        let no_jobs = ChaosConfig { jobs: 0, ..Default::default() };
        assert!(run(&no_jobs, |_| {}).is_err());
        let no_faults =
            ChaosConfig { faults: FaultPlan::disabled(), ..Default::default() };
        assert!(run(&no_faults, |_| {}).is_err());
    }
}
