//! Streaming maintenance workload: replay the deterministic
//! [`churn_chain`](crate::testkit::graphs::churn_chain) mutation script
//! two ways and report the merge-step economics of maintenance.
//!
//! * **Differential churn run**: a sequential
//!   [`StreamState`](crate::algo::stream::StreamState) applies every
//!   batch; after each one the maintained truss is checked
//!   **bit-identical** against a from-scratch
//!   [`SupportMode::Full`](crate::algo::incremental::SupportMode::Full)
//!   recompute of the mutated graph, and both sides' merge steps are
//!   accumulated. The run fails unless maintenance is at least
//!   [`STEP_RATIO_FLOOR`]× cheaper — the paper's incremental-frontier
//!   argument restated for mutations.
//! * **Serve run**: the same script through a
//!   [`GraphStore`](crate::serve::GraphStore) on the sharded executor —
//!   `Mutate` jobs serialized ticket-by-ticket, one pinned-epoch read
//!   racing each batch — verifying planned spans, epoch sequencing, and
//!   pinned-read isolation under the open-loop mix.

use crate::algo::incremental::SupportMode;
use crate::algo::ktruss::ktruss_mode;
use crate::algo::stream::StreamState;
use crate::algo::support::Mode;
use crate::coordinator::job::{JobKind, JobOutput};
use crate::serve::{Executor, GraphStore, ServeConfig};
use crate::util::Timer;
use anyhow::{bail, Result};
use std::sync::Arc;

/// Minimum scratch-steps / maintained-steps ratio the churn run must
/// clear (the CI smoke gate).
pub const STEP_RATIO_FLOOR: f64 = 3.0;

/// Workload knobs.
#[derive(Clone, Debug)]
pub struct StreamConfig {
    /// Chain depth of the underlying `peel_chain` fixture (≥ 4).
    pub depth: usize,
    /// Churn batches to replay (alternating delete / re-insert).
    pub batches: usize,
    /// Truss order maintained by the store.
    pub k: u32,
    /// Executor shards for the serve run.
    pub shards: usize,
    /// Total worker budget for the serve run.
    pub total_workers: usize,
    /// Optional Chrome-trace path for the serve run's job spans.
    pub trace_out: Option<String>,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig {
            depth: 10,
            batches: 12,
            k: 4,
            shards: 1,
            total_workers: 3,
            trace_out: None,
        }
    }
}

/// Outcome of the sequential differential churn run.
#[derive(Clone, Copy, Debug)]
pub struct ChurnRun {
    /// Batches applied (every one verified against scratch).
    pub batches: usize,
    /// Batches that took the re-convergence slow path.
    pub recomputed: usize,
    /// Merge steps the maintenance path spent (frontier + converge).
    pub maintained_steps: u64,
    /// Merge steps the from-scratch recomputes spent.
    pub scratch_steps: u64,
    /// Wall time of the maintenance side, ms.
    pub wall_ms: f64,
}

impl ChurnRun {
    /// How many times cheaper maintenance was than recomputation.
    pub fn ratio(&self) -> f64 {
        self.scratch_steps as f64 / (self.maintained_steps as f64).max(1.0)
    }
}

/// Outcome of the executor-served run.
#[derive(Clone, Debug)]
pub struct ServeRun {
    /// Mutation batches served (strictly serialized).
    pub batches: usize,
    /// Pinned-epoch reads raced against the mutations.
    pub reads: usize,
    /// Epoch the store ended on (equals `batches`).
    pub final_epoch: u64,
    /// `Mutate` jobs that carried an execution plan.
    pub planned: usize,
    /// Job spans captured (written to `trace_out` when set).
    pub spans: usize,
    /// Where the trace landed, if requested.
    pub trace_path: Option<String>,
}

/// Full streaming report.
#[derive(Clone, Debug)]
pub struct StreamReport {
    /// The config the run used.
    pub depth: usize,
    /// Batches in the churn script.
    pub batches: usize,
    /// Maintained truss order.
    pub k: u32,
    /// The sequential differential run.
    pub churn: ChurnRun,
    /// The executor-served run.
    pub serve: ServeRun,
}

impl StreamReport {
    /// Render the report as plain text (the CI smoke greps
    /// `stream[churn-chain]` and the final `stream-ok` line).
    pub fn render(&self) -> String {
        let mut out = format!(
            "# stream: churn-chain depth {}, {} batches, k={}\n",
            self.depth, self.batches, self.k
        );
        out.push_str(&format!(
            "stream[churn-chain] maintained_steps={} scratch_steps={} ratio={:.2}x \
             (floor {STEP_RATIO_FLOOR:.1}x) recomputed={}/{} wall={:.2} ms\n",
            self.churn.maintained_steps,
            self.churn.scratch_steps,
            self.churn.ratio(),
            self.churn.recomputed,
            self.churn.batches,
            self.churn.wall_ms,
        ));
        out.push_str(&format!(
            "stream[serve] batches={} reads={} final_epoch={} planned={}/{} spans={}\n",
            self.serve.batches,
            self.serve.reads,
            self.serve.final_epoch,
            self.serve.planned,
            self.serve.batches,
            self.serve.spans,
        ));
        if let Some(p) = &self.serve.trace_path {
            out.push_str(&format!("trace: wrote {} job span(s) to {p}\n", self.serve.spans));
        }
        out.push_str("stream-ok\n");
        out
    }
}

/// Sequential differential run: maintain, verify against scratch after
/// every batch, account both sides' merge steps.
fn run_churn(cfg: &StreamConfig) -> Result<ChurnRun> {
    let (g, script) = crate::testkit::graphs::churn_chain(cfg.depth, cfg.batches);
    let mut st = StreamState::new(&g, cfg.k);
    let mut maintained: u64 = 0;
    let mut scratch_steps: u64 = 0;
    let mut recomputed = 0usize;
    let t = Timer::start();
    for (b, batch) in script.iter().enumerate() {
        let out = st.apply(batch);
        maintained += out.frontier_steps + out.converge_steps;
        recomputed += out.recomputed as usize;
        let scratch = ktruss_mode(st.graph(), cfg.k, Mode::Fine, SupportMode::Full);
        scratch_steps += scratch.total_support_steps();
        if st.truss() != &scratch.truss {
            bail!(
                "batch {b}: maintained truss ({} edges) diverged from scratch ({} edges)",
                st.truss().nnz(),
                scratch.truss.nnz()
            );
        }
    }
    let wall_ms = t.elapsed_ms();
    let run = ChurnRun {
        batches: script.len(),
        recomputed,
        maintained_steps: maintained,
        scratch_steps,
        wall_ms,
    };
    if run.ratio() < STEP_RATIO_FLOOR {
        bail!(
            "maintenance spent {} steps vs {} from scratch ({:.2}x < the {STEP_RATIO_FLOOR:.1}x \
             floor)",
            run.maintained_steps,
            run.scratch_steps,
            run.ratio()
        );
    }
    Ok(run)
}

/// Serve run: the same script through a [`GraphStore`] on the executor,
/// one pinned-epoch read racing each serialized mutation.
fn run_serve(cfg: &StreamConfig) -> Result<ServeRun> {
    let (g, script) = crate::testkit::graphs::churn_chain(cfg.depth, cfg.batches);
    let store = Arc::new(GraphStore::new(&g, cfg.k));
    let ex = Executor::start(
        ServeConfig { shards: cfg.shards.max(1), enable_dense: false, ..Default::default() }
            .with_total_workers(cfg.total_workers.max(2)),
    );
    let mut planned = 0usize;
    let mut reads = Vec::with_capacity(script.len());
    for (i, batch) in script.iter().enumerate() {
        let pinned = store.pin();
        // open-loop read against the pinned pre-batch epoch
        reads.push((
            pinned.clone(),
            ex.submit(pinned.graph.clone(), JobKind::Ktruss { k: cfg.k, mode: Mode::Fine }),
        ));
        let ticket = ex.submit(
            pinned.graph.clone(),
            JobKind::Mutate { store: store.clone(), batch: Arc::new(batch.clone()) },
        );
        // batches are order-dependent: wait this one out before the next
        let r = ticket.wait();
        planned += r.plan.is_some() as usize;
        match r.output.map_err(|e| anyhow::anyhow!("batch {i}: {e}"))? {
            JobOutput::Mutate { epoch, .. } if epoch == (i + 1) as u64 => {}
            JobOutput::Mutate { epoch, .. } => {
                bail!("batch {i}: published epoch {epoch}, expected {}", i + 1)
            }
            other => bail!("batch {i}: unexpected output {other:?}"),
        }
    }
    let n_reads = reads.len();
    for (pinned, ticket) in reads {
        let r = ticket.wait();
        match r.output.map_err(|e| anyhow::anyhow!("read @ epoch {}: {e}", pinned.epoch))? {
            JobOutput::Ktruss { truss_edges, .. } => {
                let want = ktruss_mode(&pinned.graph, cfg.k, Mode::Fine, SupportMode::Full);
                if truss_edges != want.truss.nnz() {
                    bail!(
                        "pinned read @ epoch {} saw {truss_edges} truss edges, expected {}",
                        pinned.epoch,
                        want.truss.nnz()
                    );
                }
            }
            other => bail!("unexpected read output {other:?}"),
        }
    }
    let spans = ex.obs.spans.snapshot();
    let mutate_spans = spans.iter().filter(|s| s.kind == "mutate").count();
    if mutate_spans != script.len() {
        bail!("expected {} mutate spans, saw {mutate_spans}", script.len());
    }
    let trace_path = match &cfg.trace_out {
        Some(path) => {
            crate::obs::export::write_trace(std::path::Path::new(path), &spans)?;
            Some(path.clone())
        }
        None => None,
    };
    let final_epoch = store.epoch();
    ex.shutdown();
    Ok(ServeRun {
        batches: script.len(),
        reads: n_reads,
        final_epoch,
        planned,
        spans: spans.len(),
        trace_path,
    })
}

/// Run both halves of the streaming workload.
pub fn run(cfg: &StreamConfig, progress: impl Fn(&str)) -> Result<StreamReport> {
    if cfg.depth < 4 {
        bail!("stream bench needs --depth >= 4 (peel_chain floor)");
    }
    if cfg.batches == 0 {
        bail!("stream bench needs >= 1 batch");
    }
    progress(&format!(
        "churn: {} batches over peel_chain({}) at k={}",
        cfg.batches, cfg.depth, cfg.k
    ));
    let churn = run_churn(cfg)?;
    progress(&format!(
        "churn done: {:.2}x fewer steps than scratch; serving the same script",
        churn.ratio()
    ));
    let serve = run_serve(cfg)?;
    if serve.planned != serve.batches {
        let missing = serve.batches - serve.planned;
        bail!("{missing} of {} mutate jobs arrived unplanned", serve.batches);
    }
    if serve.final_epoch != serve.batches as u64 {
        bail!("store ended on epoch {}, expected {}", serve.final_epoch, serve.batches);
    }
    Ok(StreamReport {
        depth: cfg.depth,
        batches: cfg.batches,
        k: cfg.k,
        churn,
        serve,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_stream_bench_completes_and_renders() {
        let cfg = StreamConfig {
            depth: 6,
            batches: 4,
            total_workers: 2,
            ..Default::default()
        };
        let report = run(&cfg, |_| {}).unwrap();
        assert_eq!(report.churn.batches, 4);
        assert_eq!(report.churn.recomputed, 4, "every churn batch reconverges");
        assert!(report.churn.ratio() >= STEP_RATIO_FLOOR);
        assert_eq!(report.serve.final_epoch, 4);
        assert_eq!(report.serve.planned, 4);
        let text = report.render();
        assert!(text.contains("stream[churn-chain]"));
        assert!(text.contains("stream-ok"));
    }

    #[test]
    fn degenerate_configs_are_rejected() {
        assert!(run(&StreamConfig { depth: 3, ..Default::default() }, |_| {}).is_err());
        assert!(run(&StreamConfig { batches: 0, ..Default::default() }, |_| {}).is_err());
    }
}
