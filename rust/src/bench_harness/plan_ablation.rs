//! Plan ablation: the auto planner against every fixed plan, across
//! the testkit fixture families.
//!
//! Two comparisons per fixture, with deliberately different evaluators:
//!
//! * **predicted** — the planner's own per-candidate scores
//!   ([`Planner::explain`]). The chosen plan is provably within
//!   `1 / `[`PLAN_SWITCH_MARGIN`] of the best-scored candidate, so the
//!   `auto ≤ 1.05 × best-fixed` bound checks the selection plumbing and
//!   its stickiness margin end to end.
//! * **simulated** — a full convergence-loop replay of the chosen plan
//!   and of the pre-planner `static/coarse/full` baseline through the
//!   calibrated CPU machine model ([`simulate_ktruss_mode`], exact
//!   traced task costs). On the skewed fixtures the auto plan must beat
//!   the static-coarse baseline **strictly** — this is the model-level
//!   claim the planner exists to exploit, evaluated by a richer model
//!   than the one that made the choice.
//!
//! The `plan-ablation` bench binary (and the CI smoke job behind it)
//! fails unless both properties hold.

use crate::algo::incremental::SupportMode;
use crate::algo::support::Granularity;
use crate::graph::Csr;
use crate::par::Schedule;
use crate::plan::{ExecutionPlan, Planner, PLAN_SWITCH_MARGIN};
use crate::sim::{simulate_ktruss_mode, SimConfig};
use crate::util::fmt::Table;
use anyhow::Result;

/// The CI bound: the auto plan's predicted cost may exceed the best
/// fixed candidate's by at most this factor (the stickiness margin
/// guarantees `1 / PLAN_SWITCH_MARGIN ≈ 1.031`, comfortably inside).
pub const AUTO_MARGIN: f64 = 1.05;

/// One fixture's measurements.
#[derive(Clone, Debug)]
pub struct FixtureResult {
    /// Fixture name.
    pub name: String,
    /// Whether this fixture is degree-skewed (the strict-win check
    /// applies only to skewed fixtures; on flat ones every plan ties).
    pub skewed: bool,
    /// The plan the auto planner chose.
    pub auto_plan: ExecutionPlan,
    /// Predicted cost of the chosen plan (planner's scoring), ms.
    pub auto_predicted_ms: f64,
    /// Best predicted cost over every fixed candidate, ms.
    pub best_fixed_ms: f64,
    /// Simulated end-to-end makespan of the chosen plan (full replay
    /// through the CPU machine model), ms.
    pub auto_sim_ms: f64,
    /// Simulated end-to-end makespan of the `static/coarse/full`
    /// baseline, ms.
    pub static_coarse_sim_ms: f64,
}

impl FixtureResult {
    /// predicted auto / predicted best-fixed.
    pub fn predicted_ratio(&self) -> f64 {
        self.auto_predicted_ms / self.best_fixed_ms.max(1e-12)
    }

    /// simulated static-coarse / simulated auto (the end-to-end win).
    pub fn sim_speedup(&self) -> f64 {
        self.static_coarse_sim_ms / self.auto_sim_ms.max(1e-12)
    }
}

/// The full sweep report.
#[derive(Clone, Debug)]
pub struct PlanAblationReport {
    /// CPU threads the planner and the simulated pool ran at.
    pub threads: usize,
    /// Truss threshold used throughout.
    pub k: u32,
    /// One entry per fixture.
    pub rows: Vec<FixtureResult>,
}

impl PlanAblationReport {
    /// Whether every fixture's auto plan is within [`AUTO_MARGIN`] of
    /// its best fixed candidate (predicted).
    pub fn auto_within_margin(&self) -> bool {
        self.rows.iter().all(|r| r.predicted_ratio() <= AUTO_MARGIN)
    }

    /// Whether the auto plan strictly beats the static-coarse baseline
    /// (simulated, end to end) on every skewed fixture.
    pub fn auto_beats_static_coarse(&self) -> bool {
        self.rows
            .iter()
            .filter(|r| r.skewed)
            .all(|r| r.auto_sim_ms < r.static_coarse_sim_ms)
    }

    /// Render the sweep as an aligned table plus the two check lines.
    pub fn render(&self) -> String {
        let mut table = Table::new(vec![
            "fixture",
            "auto plan",
            "pred ms",
            "best fixed ms",
            "ratio",
            "sim auto ms",
            "sim C-static ms",
            "speedup",
        ]);
        for r in &self.rows {
            table.row(vec![
                r.name.clone(),
                r.auto_plan.to_string(),
                format!("{:.4}", r.auto_predicted_ms),
                format!("{:.4}", r.best_fixed_ms),
                format!("{:.3}", r.predicted_ratio()),
                format!("{:.4}", r.auto_sim_ms),
                format!("{:.4}", r.static_coarse_sim_ms),
                format!("{:.2}x", r.sim_speedup()),
            ]);
        }
        let mut out = format!(
            "# plan ablation: auto vs fixed plans, CPU model at {} threads, k={}\n\
             # stickiness margin {:.2} -> predicted ratio bound {:.3}\n",
            self.threads,
            self.k,
            PLAN_SWITCH_MARGIN,
            1.0 / PLAN_SWITCH_MARGIN,
        );
        out.push_str(&table.render());
        out.push_str(&format!(
            "auto-within-{AUTO_MARGIN}x-of-best: {}\n",
            if self.auto_within_margin() { "yes" } else { "NO" }
        ));
        out.push_str(&format!(
            "auto-beats-static-coarse-on-skewed: {}\n",
            if self.auto_beats_static_coarse() { "yes" } else { "NO" }
        ));
        out
    }
}

/// Simulated end-to-end makespan (ms) of one plan: replay the full
/// convergence loop under the plan's support mode and price every
/// kernel launch on the CPU model at the plan's granularity/schedule.
fn sim_ms(g: &Csr, k: u32, plan: &ExecutionPlan, threads: usize) -> f64 {
    let cfg = SimConfig::cpu_gran(threads, plan.granularity, plan.schedule);
    simulate_ktruss_mode(g, k, &[cfg], plan.support)[0].seconds * 1e3
}

/// Run the sweep over the fixture families at `threads` model threads.
pub fn run(threads: usize, k: u32, progress: impl Fn(&str)) -> Result<PlanAblationReport> {
    let mut rng = crate::util::Rng::new(0x91A);
    let fixtures: Vec<(&str, bool, Csr)> = vec![
        (
            "hub-comb",
            true,
            crate::testkit::graphs::hub_divergence_comb(64, 256, 800),
        ),
        ("star-fringe", true, crate::testkit::graphs::star_with_fringe(1200)),
        (
            "rmat-as",
            true,
            crate::gen::rmat::rmat(
                3000,
                15_000,
                crate::gen::rmat::RmatParams::autonomous_system(),
                &mut rng,
            ),
        ),
        (
            "road-grid",
            false,
            crate::gen::grid::road(3000, 5800, 0.05, &mut rng),
        ),
    ];
    let planner = Planner::new(threads);
    let static_coarse =
        ExecutionPlan::fixed(Schedule::Static, Granularity::Coarse, SupportMode::Full);
    let mut rows = Vec::with_capacity(fixtures.len());
    for (name, skewed, g) in &fixtures {
        progress(&format!("{name}: planning and replaying (n={}, m={})", g.n(), g.nnz()));
        let ex = planner.explain(g, k);
        let auto_plan = ex.plan();
        let row = FixtureResult {
            name: name.to_string(),
            skewed: *skewed,
            auto_plan,
            auto_predicted_ms: ex.predicted_ms(),
            best_fixed_ms: ex.best_ms(),
            auto_sim_ms: sim_ms(g, k, &auto_plan, threads),
            static_coarse_sim_ms: sim_ms(g, k, &static_coarse, threads),
        };
        progress(&format!(
            "{name}: auto {} (ratio {:.3}, sim speedup {:.2}x)",
            row.auto_plan,
            row.predicted_ratio(),
            row.sim_speedup()
        ));
        rows.push(row);
    }
    Ok(PlanAblationReport { threads, k, rows })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_sweep_holds_both_invariants() {
        // smaller fixtures than the bench uses, same invariants: the
        // chosen plan stays within the margin by construction, and the
        // skewed fixture wins strictly end to end
        let threads = 48;
        let g = crate::testkit::graphs::hub_divergence_comb(32, 128, 400);
        let planner = Planner::new(threads);
        let ex = planner.explain(&g, 3);
        assert!(ex.predicted_ms() <= ex.best_ms() * AUTO_MARGIN);
        let auto_plan = ex.plan();
        let static_coarse = ExecutionPlan::fixed(
            Schedule::Static,
            Granularity::Coarse,
            SupportMode::Full,
        );
        let auto = sim_ms(&g, 3, &auto_plan, threads);
        let base = sim_ms(&g, 3, &static_coarse, threads);
        assert!(auto < base, "auto {auto} vs static-coarse {base}");
    }

    #[test]
    fn report_renders_checks() {
        let report = PlanAblationReport {
            threads: 8,
            k: 3,
            rows: vec![FixtureResult {
                name: "x".into(),
                skewed: true,
                auto_plan: ExecutionPlan::fixed(
                    Schedule::WorkAware,
                    Granularity::Fine,
                    SupportMode::Auto,
                ),
                auto_predicted_ms: 1.0,
                best_fixed_ms: 1.0,
                auto_sim_ms: 1.0,
                static_coarse_sim_ms: 2.0,
            }],
        };
        assert!(report.auto_within_margin());
        assert!(report.auto_beats_static_coarse());
        let text = report.render();
        assert!(text.contains("auto-within-"));
        assert!(text.contains("auto-beats-static-coarse-on-skewed: yes"));
    }
}
