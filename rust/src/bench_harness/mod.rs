//! Benchmark harness: regenerates every table and figure of the paper's
//! evaluation (Table I, Figs 2–4) plus the design ablations, over the
//! synthetic replica suite. Used by `cargo bench` binaries and the CLI.

pub mod ablations;
pub mod chaos_bench;
pub mod figs;
pub mod lane_bench;
pub mod plan_ablation;
pub mod report;
pub mod serve_bench;
pub mod stream_bench;
pub mod table1;
pub mod workload;

pub use workload::Workload;
