//! Bench output sink: every bench prints to stdout *and* persists to
//! `bench_results/` (text + CSV where applicable) so EXPERIMENTS.md can
//! reference stable files.

use anyhow::{Context, Result};
use std::path::PathBuf;

/// Directory bench outputs land in (`KTRUSS_BENCH_OUT` overrides).
pub fn out_dir() -> PathBuf {
    std::env::var_os("KTRUSS_BENCH_OUT")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("bench_results"))
}

/// Write a named report file and echo the path.
pub fn save(name: &str, contents: &str) -> Result<PathBuf> {
    let dir = out_dir();
    std::fs::create_dir_all(&dir).with_context(|| format!("mkdir {}", dir.display()))?;
    let path = dir.join(name);
    std::fs::write(&path, contents).with_context(|| format!("write {}", path.display()))?;
    Ok(path)
}

/// Standard bench epilogue: print and persist.
pub fn emit(name: &str, contents: &str) -> Result<()> {
    println!("{contents}");
    let path = save(name, contents)?;
    println!("[saved {}]", path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn save_roundtrip() {
        std::env::set_var("KTRUSS_BENCH_OUT", std::env::temp_dir().join("ktruss-bench-test"));
        let p = save("x.txt", "hello").unwrap();
        assert_eq!(std::fs::read_to_string(&p).unwrap(), "hello");
        std::fs::remove_file(p).unwrap();
        std::env::remove_var("KTRUSS_BENCH_OUT");
    }
}
