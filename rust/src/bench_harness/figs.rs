//! Figure regeneration:
//!
//! * **Fig 2** — speedup of fine over coarse on the CPU vs thread count
//!   {1,2,4,8,16,32,48}, K = K_max, one series per graph.
//! * **Fig 3** — ME/s on the CPU at 48 threads, coarse vs fine, for
//!   K=3 (top panel) and K=K_max (bottom panel).
//! * **Fig 4** — ME/s on the GPU, coarse vs fine, K=3 and K=K_max.
//!
//! Each `run_*` returns the plotted series as data; the bench binaries
//! print them as aligned tables (the "plot" of a text harness).

use super::workload::Workload;
use crate::algo::support::Mode;
use crate::graph::Csr;
use crate::par::Schedule;
use crate::sim::{simulate_kmax, simulate_ktruss, SimConfig, GPU_SCHEDULES};
use crate::util::fmt::{mes, speedup, Table};
use crate::util::stats::geomean;
use anyhow::Result;

/// The paper's Fig-2 thread axis.
pub const THREADS: [usize; 7] = [1, 2, 4, 8, 16, 32, 48];

/// The schedule-ablation axis the thread-scaling sweep runs — the one
/// canonical list from the pool, re-exported so it cannot drift.
pub use crate::par::ALL_SCHEDULES as SCHEDULES;

/// Short, stable label for a schedule (table column/row keys; chunk
/// size elided). Exhaustive match: a new `Schedule` variant fails to
/// compile here rather than silently missing from the sweep.
pub fn schedule_name(s: Schedule) -> &'static str {
    match s {
        Schedule::Static => "static",
        Schedule::Dynamic { .. } => "dynamic",
        Schedule::WorkAware => "workaware",
        Schedule::Stealing => "stealing",
    }
}

/// Fig 2: per-graph speedup series over the thread axis.
#[derive(Clone, Debug)]
pub struct Fig2 {
    /// (graph, kmax, speedups per THREADS entry).
    pub series: Vec<(String, u32, [f64; 7])>,
    /// Replica scale the series were generated at.
    pub scale: f64,
}

impl Fig2 {
    /// Render the figure as an aligned plain-text table.
    pub fn render(&self) -> String {
        let mut t = Table::new(vec![
            "graph", "kmax", "1t", "2t", "4t", "8t", "16t", "32t", "48t",
        ]);
        for (name, kmax, sp) in &self.series {
            let mut row = vec![name.clone(), kmax.to_string()];
            row.extend(sp.iter().map(|&x| speedup(x)));
            t.row(row);
        }
        format!(
            "{}\n(values are coarse_time/fine_time at K=Kmax; paper Fig 2 shows most graphs above 1.0,\n growing with threads, with road networks near parity)\n",
            t.render()
        )
    }
}

/// Run Fig 2.
pub fn run_fig2(w: &Workload, mut progress: impl FnMut(&str)) -> Result<Fig2> {
    let mut configs = Vec::new();
    for &t in &THREADS {
        configs.push(SimConfig::cpu(t, Mode::Coarse));
        configs.push(SimConfig::cpu(t, Mode::Fine));
    }
    let mut series = Vec::new();
    for spec in &w.specs {
        let g = w.load(spec)?;
        let (kmax, res) = simulate_kmax(&g, &configs);
        let mut sp = [0.0f64; 7];
        for (ti, _) in THREADS.iter().enumerate() {
            sp[ti] = res[2 * ti].seconds / res[2 * ti + 1].seconds;
        }
        progress(&format!("{}: kmax={kmax}", spec.name));
        series.push((spec.name.to_string(), kmax, sp));
    }
    Ok(Fig2 { series, scale: w.scale })
}

/// Schedule sweep companion to Fig 2: coarse-grained K=3 runtime under
/// every schedule across the thread axis, reported as speedup over
/// coarse-static at the same thread count. Shows how much of the
/// fine-grained win schedule-level load balancing recovers on its own.
#[derive(Clone, Debug)]
pub struct Fig2Schedules {
    /// (graph, schedule label, speedup-over-static per THREADS entry).
    pub series: Vec<(String, &'static str, [f64; 7])>,
    /// Replica scale the series were generated at.
    pub scale: f64,
}

impl Fig2Schedules {
    /// Render the sweep as an aligned plain-text table.
    pub fn render(&self) -> String {
        let mut t = Table::new(vec![
            "graph", "schedule", "1t", "2t", "4t", "8t", "16t", "32t", "48t",
        ]);
        for (name, sched, sp) in &self.series {
            let mut row = vec![name.clone(), sched.to_string()];
            row.extend(sp.iter().map(|&x| speedup(x)));
            t.row(row);
        }
        format!(
            "{}\n(values are coarse static_time/schedule_time at K=3; workaware/stealing recover\n part of the fine-grained win without changing the task granularity)\n",
            t.render()
        )
    }
}

/// Run the schedule sweep (one replay per graph drives all
/// threads × schedules configurations).
pub fn run_fig2_schedules(w: &Workload, mut progress: impl FnMut(&str)) -> Result<Fig2Schedules> {
    let mut configs = Vec::new();
    for &t in &THREADS {
        for &sch in &SCHEDULES {
            configs.push(SimConfig::cpu_sched(t, Mode::Coarse, sch));
        }
    }
    // baseline index found by kind, not position, so reordering the
    // shared schedule axis cannot silently renormalize the figure
    let base = SCHEDULES
        .iter()
        .position(|s| matches!(s, Schedule::Static))
        .expect("schedule axis must include Static");
    let mut series = Vec::new();
    for spec in &w.specs {
        let g = w.load(spec)?;
        let res = simulate_ktruss(&g, 3, &configs);
        for (si, &sch) in SCHEDULES.iter().enumerate() {
            let mut sp = [0.0f64; 7];
            for ti in 0..THREADS.len() {
                let static_s = res[ti * SCHEDULES.len() + base].seconds;
                sp[ti] = static_s / res[ti * SCHEDULES.len() + si].seconds;
            }
            series.push((spec.name.to_string(), schedule_name(sch), sp));
        }
        progress(spec.name);
    }
    Ok(Fig2Schedules { series, scale: w.scale })
}

/// GPU schedule × granularity sweep: the schedule-aware GPU machine
/// model across coarse/fine/segment under static/work-aware/stealing,
/// on the workloads where the distinction matters — a skewed power-law
/// RMAT (hub rows clustered at low vertex ids, so static contiguous
/// waves pile hot warps onto few schedulers) and the star hot-row graph
/// (one mega task: only a finer granularity, not a schedule, helps).
#[derive(Clone, Debug)]
pub struct GpuScheduleSweep {
    /// Segment length of the `Granularity::Segment` rows.
    pub seg_len: u32,
    /// (graph, granularity label, seconds per [`GPU_SCHEDULES`] entry).
    pub rows: Vec<(String, String, [f64; 3])>,
}

impl GpuScheduleSweep {
    /// Speedup of schedule `si` over static for row `row`.
    fn speedup_over_static(&self, row: usize, si: usize) -> f64 {
        let (_, _, secs) = &self.rows[row];
        secs[0] / secs[si]
    }

    /// Segment-over-coarse speedup (static schedule) for `graph`, if
    /// both rows exist.
    pub fn segment_vs_coarse(&self, graph: &str) -> Option<f64> {
        let sec = |gran: &str| {
            self.rows
                .iter()
                .find(|(g, gl, _)| g == graph && gl == gran)
                .map(|(_, _, s)| s[0])
        };
        Some(sec("coarse")? / sec(&format!("segment:{}", self.seg_len))?)
    }

    /// Render the sweep as an aligned table plus per-graph summaries.
    pub fn render(&self) -> String {
        let mut t = Table::new(vec![
            "graph",
            "granularity",
            "static ms",
            "workaware ms",
            "stealing ms",
            "workaware",
            "stealing",
        ]);
        for (ri, (graph, gran, secs)) in self.rows.iter().enumerate() {
            t.row(vec![
                graph.clone(),
                gran.clone(),
                format!("{:.3}", secs[0] * 1e3),
                format!("{:.3}", secs[1] * 1e3),
                format!("{:.3}", secs[2] * 1e3),
                speedup(self.speedup_over_static(ri, 1)),
                speedup(self.speedup_over_static(ri, 2)),
            ]);
        }
        let mut out = format!(
            "{}\n(schedule columns are speedup over static at the same granularity; the\n schedule fixes across-warp imbalance, the granularity fixes the intra-warp\n divergence/tail a schedule cannot touch)\n",
            t.render()
        );
        let graphs: Vec<&String> = {
            let mut seen = Vec::new();
            for (g, _, _) in &self.rows {
                if !seen.contains(&g) {
                    seen.push(g);
                }
            }
            seen
        };
        for g in graphs {
            if let Some(sp) = self.segment_vs_coarse(g) {
                out.push_str(&format!("segment/coarse on {g} (static): {}\n", speedup(sp)));
            }
        }
        out
    }
}

/// Run the GPU schedule sweep over explicit `(label, graph)` pairs.
/// Rows are keyed off each config's own `gran`/`schedule` fields (not
/// the grid's construction order), so a reordered or extended
/// [`crate::sim::gpu_schedule_grid`] cannot silently mislabel cells.
pub fn run_gpu_schedule_sweep_on(
    graphs: &[(String, Csr)],
    k: u32,
    seg_len: u32,
    mut progress: impl FnMut(&str),
) -> Result<GpuScheduleSweep> {
    let configs = crate::sim::gpu_schedule_grid(seg_len);
    let mut rows: Vec<(String, String, [f64; 3])> = Vec::new();
    for (name, g) in graphs {
        let res = simulate_ktruss(g, k, &configs);
        for (cfg, r) in configs.iter().zip(res.iter()) {
            let si = GPU_SCHEDULES
                .iter()
                .position(|&s| s == cfg.schedule)
                .expect("grid schedule must be on the GPU_SCHEDULES axis");
            let gran_label = cfg.gran.to_string();
            match rows
                .iter_mut()
                .find(|(n, gl, _)| n == name && *gl == gran_label)
            {
                Some((_, _, secs)) => secs[si] = r.seconds,
                None => {
                    let mut secs = [0.0f64; 3];
                    secs[si] = r.seconds;
                    rows.push((name.clone(), gran_label, secs));
                }
            }
        }
        progress(name.as_str());
    }
    Ok(GpuScheduleSweep { seg_len, rows })
}

/// Run the GPU schedule sweep on its standard adversarial trio: a
/// skewed AS-topology RMAT, the hub-divergence comb (clustered
/// divergent warps — where the schedule axis pays off hardest), and
/// the star hot-row graph (one mega task — where only granularity
/// helps).
pub fn run_gpu_schedule_sweep(
    seg_len: u32,
    progress: impl FnMut(&str),
) -> Result<GpuScheduleSweep> {
    let graphs = vec![
        (
            "rmat-skew".to_string(),
            crate::gen::rmat::rmat(
                20_000,
                120_000,
                crate::gen::rmat::RmatParams::autonomous_system(),
                &mut crate::util::Rng::new(0x6B5),
            ),
        ),
        (
            "hub-comb".to_string(),
            crate::testkit::graphs::hub_divergence_comb(600, 2400, 1500),
        ),
        (
            "star-hot".to_string(),
            crate::testkit::graphs::star_with_fringe(4000),
        ),
    ];
    run_gpu_schedule_sweep_on(&graphs, 3, seg_len, progress)
}

/// Fig 3/4 panel: per-graph coarse and fine ME/s for one device, one K
/// setting.
#[derive(Clone, Debug)]
pub struct MesPanel {
    /// Device label (`CPU 48 threads` / `GPU (V100)`).
    pub device: String,
    /// "3" or "kmax".
    pub k_setting: String,
    /// (graph, coarse ME/s, fine ME/s, k used).
    pub rows: Vec<(String, f64, f64, u32)>,
    /// Replica scale the panel was generated at.
    pub scale: f64,
}

impl MesPanel {
    /// Geometric-mean fine-over-coarse speedup across the panel.
    pub fn geomean_speedup(&self) -> f64 {
        let r: Vec<f64> = self.rows.iter().map(|(_, c, f, _)| f / c).collect();
        geomean(&r).unwrap_or(f64::NAN)
    }

    /// Render the panel as an aligned plain-text table.
    pub fn render(&self) -> String {
        let mut t = Table::new(vec!["graph", "k", "coarse ME/s", "fine ME/s", "speedup"]);
        for (name, c, f, k) in &self.rows {
            t.row(vec![
                name.clone(),
                k.to_string(),
                mes(*c),
                mes(*f),
                speedup(f / c),
            ]);
        }
        format!(
            "## {} K={}\n{}geomean fine/coarse speedup: {}\n",
            self.device,
            self.k_setting,
            t.render(),
            speedup(self.geomean_speedup())
        )
    }
}

/// Which device a panel simulates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PanelDevice {
    /// The paper's 48-thread Skylake node.
    Cpu48,
    /// The paper's Tesla V100.
    Gpu,
}

impl PanelDevice {
    fn configs(self) -> Vec<SimConfig> {
        match self {
            PanelDevice::Cpu48 => vec![
                SimConfig::cpu(48, Mode::Coarse),
                SimConfig::cpu(48, Mode::Fine),
            ],
            PanelDevice::Gpu => vec![SimConfig::gpu(Mode::Coarse), SimConfig::gpu(Mode::Fine)],
        }
    }

    fn name(self) -> &'static str {
        match self {
            PanelDevice::Cpu48 => "CPU 48 threads",
            PanelDevice::Gpu => "GPU (V100)",
        }
    }
}

/// Run one ME/s panel (Fig 3 = Cpu48, Fig 4 = Gpu; each at K=3 and
/// K=Kmax).
pub fn run_mes_panel(
    w: &Workload,
    device: PanelDevice,
    use_kmax: bool,
    mut progress: impl FnMut(&str),
) -> Result<MesPanel> {
    let configs = device.configs();
    let mut rows = Vec::new();
    for spec in &w.specs {
        let g = w.load(spec)?;
        let (k_used, res) = if use_kmax {
            let (kmax, res) = simulate_kmax(&g, &configs);
            (kmax, res)
        } else {
            (3, simulate_ktruss(&g, 3, &configs))
        };
        progress(&format!("{}: k={k_used}", spec.name));
        rows.push((spec.name.to_string(), res[0].me_per_s, res[1].me_per_s, k_used));
    }
    Ok(MesPanel {
        device: device.name().to_string(),
        k_setting: if use_kmax { "kmax".into() } else { "3".into() },
        rows,
        scale: w.scale,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::suite::by_name;

    fn tiny_workload() -> Workload {
        Workload { specs: vec![by_name("as20000102").unwrap()], scale: 0.05 }
    }

    #[test]
    fn fig2_produces_series() {
        let f = run_fig2(&tiny_workload(), |_| {}).unwrap();
        assert_eq!(f.series.len(), 1);
        let (_, kmax, sp) = &f.series[0];
        assert!(*kmax >= 3);
        assert!(sp.iter().all(|x| x.is_finite() && *x > 0.0));
        assert!(f.render().contains("48t"));
    }

    #[test]
    fn fig2_schedule_sweep_produces_all_series() {
        let f = run_fig2_schedules(&tiny_workload(), |_| {}).unwrap();
        // one series per schedule for the single graph
        assert_eq!(f.series.len(), SCHEDULES.len());
        for (name, sched, sp) in &f.series {
            assert_eq!(name, "as20000102");
            assert!(sp.iter().all(|x| x.is_finite() && *x > 0.0), "{sched}");
        }
        // the static series is identically 1.0 (it is its own baseline)
        let static_series = f.series.iter().find(|(_, s, _)| *s == "static").unwrap();
        assert!(static_series.2.iter().all(|&x| (x - 1.0).abs() < 1e-9));
        assert!(f.render().contains("workaware"));
    }

    #[test]
    fn mes_panels_cpu_and_gpu() {
        let w = tiny_workload();
        for dev in [PanelDevice::Cpu48, PanelDevice::Gpu] {
            for use_kmax in [false, true] {
                let p = run_mes_panel(&w, dev, use_kmax, |_| {}).unwrap();
                assert_eq!(p.rows.len(), 1);
                assert!(p.geomean_speedup().is_finite());
                assert!(p.render().contains("geomean"));
            }
        }
    }

    #[test]
    fn gpu_schedule_sweep_shapes() {
        let graphs = vec![
            (
                "rmat-small".to_string(),
                crate::gen::rmat::rmat(
                    2000,
                    12_000,
                    crate::gen::rmat::RmatParams::autonomous_system(),
                    &mut crate::util::Rng::new(5),
                ),
            ),
            ("star-small".to_string(), crate::testkit::graphs::star_with_fringe(600)),
        ];
        let sweep = run_gpu_schedule_sweep_on(&graphs, 3, 64, |_| {}).unwrap();
        // 2 graphs × 3 granularities
        assert_eq!(sweep.rows.len(), 6);
        for (g, gran, secs) in &sweep.rows {
            assert!(secs.iter().all(|s| s.is_finite() && *s > 0.0), "{g} {gran}");
        }
        // the hot-row claim: segment beats coarse on the star graph
        let sp = sweep.segment_vs_coarse("star-small").unwrap();
        assert!(sp > 1.0, "segment/coarse on star: {sp}");
        let rendered = sweep.render();
        assert!(rendered.contains("workaware"));
        assert!(rendered.contains("segment/coarse on star-small"));
    }

    #[test]
    fn gpu_speedup_exceeds_cpu_on_hub_graph() {
        // the paper's central claim, checked end-to-end at bench level
        let w = tiny_workload(); // as20000102: AS topology, hub-dominated
        let cpu = run_mes_panel(&w, PanelDevice::Cpu48, false, |_| {}).unwrap();
        let gpu = run_mes_panel(&w, PanelDevice::Gpu, false, |_| {}).unwrap();
        assert!(
            gpu.geomean_speedup() > cpu.geomean_speedup(),
            "gpu {} vs cpu {}",
            gpu.geomean_speedup(),
            cpu.geomean_speedup()
        );
    }
}
