//! Synthetic graph generators. The paper's inputs are SNAP graphs from
//! the GraphChallenge collection; this container has no network access,
//! so [`suite`] replicates every Table-I graph from a structural family
//! generator with matched vertex/edge counts (DESIGN.md §2).

pub mod barabasi_albert;
pub mod community;
pub mod erdos_renyi;
pub mod grid;
pub mod rmat;
pub mod suite;

pub use suite::{by_name, generate, load, GraphSpec, SUITE};
