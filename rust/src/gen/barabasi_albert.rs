//! Barabási–Albert preferential attachment with triadic closure — used
//! for collaboration-network replicas (ca-*): BA gives the power-law
//! hub structure and the triangle-closure step gives the high clustering
//! coefficient characteristic of co-authorship graphs (each paper is a
//! clique over its authors).

use crate::graph::builder;
use crate::graph::coo::EdgeList;
use crate::graph::csr::{Csr, Vid};
use crate::util::Rng;

/// Preferential-attachment generator.
///
/// * `n` vertices are added one at a time; each new vertex attaches to
///   `k ≈ m/n` targets sampled proportionally to current degree.
/// * With probability `closure`, an attachment instead closes a triangle
///   with a random neighbor of the previously chosen target (the
///   Holme–Kim triad step), raising clustering to ca-* levels.
/// * Generation overshoots/undershoots `m` slightly; the result is
///   trimmed or topped up with random preferential edges to hit `m`
///   exactly.
pub fn ba_closure(n: usize, m: usize, closure: f64, rng: &mut Rng) -> Csr {
    assert!(n >= 3);
    let k = (m as f64 / n as f64).ceil().max(1.0) as usize;
    // `targets` is the repeated-endpoint list: sampling uniformly from it
    // is sampling proportional to degree.
    let mut targets: Vec<Vid> = Vec::with_capacity(4 * m);
    let mut el = EdgeList::with_capacity(n, m + n);
    // seed clique on k+1 vertices
    let seed = (k + 1).min(n);
    for u in 0..seed {
        for v in (u + 1)..seed {
            el.push(u as Vid, v as Vid);
            targets.push(u as Vid);
            targets.push(v as Vid);
        }
    }
    let mut last_target: Vid = 0;
    for u in seed..n {
        let mut added = 0usize;
        let mut guard = 0usize;
        while added < k && guard < 50 * k {
            guard += 1;
            let t = if added > 0 && rng.chance(closure) {
                // triad step: neighbor of last target (approximate: any
                // endpoint sharing an edge with it from the target list)
                let start = rng.below(targets.len() as u64) as usize;
                let mut found = last_target;
                for off in 0..targets.len().min(64) {
                    let idx = (start + off) % targets.len();
                    if targets[idx] == last_target && idx + 1 < targets.len() {
                        found = targets[idx ^ 1];
                        break;
                    }
                }
                found
            } else {
                targets[rng.below(targets.len() as u64) as usize]
            };
            if t as usize == u {
                continue;
            }
            el.push(u as Vid, t);
            targets.push(u as Vid);
            targets.push(t);
            last_target = t;
            added += 1;
        }
    }
    el.normalize();
    // adjust to exactly m edges
    if el.edges.len() > m {
        // drop uniformly at random (deterministic under rng)
        rng.shuffle(&mut el.edges);
        el.edges.truncate(m);
        el.edges.sort_unstable();
    } else {
        let mut have: std::collections::HashSet<(Vid, Vid)> = el.edges.iter().copied().collect();
        let mut guard = 0usize;
        while el.edges.len() < m && guard < 100 * m {
            guard += 1;
            let a = targets[rng.below(targets.len() as u64) as usize];
            let b = targets[rng.below(targets.len() as u64) as usize];
            if a == b {
                continue;
            }
            let e = if a < b { (a, b) } else { (b, a) };
            if have.insert(e) {
                el.edges.push(e);
            }
        }
        el.edges.sort_unstable();
    }
    assert_eq!(el.edges.len(), m, "ba_closure could not hit m={m}");
    builder::from_sorted_unique(n, &el.edges)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{stats, validate};

    #[test]
    fn exact_counts_and_valid() {
        let mut rng = Rng::new(21);
        let g = ba_closure(500, 1500, 0.4, &mut rng);
        assert_eq!(g.n(), 500);
        assert_eq!(g.nnz(), 1500);
        assert!(validate::check(&g).is_ok());
    }

    #[test]
    fn has_hubs() {
        let mut rng = Rng::new(23);
        let g = ba_closure(1000, 3000, 0.3, &mut rng);
        let s = stats::stats(&g);
        // preferential attachment: max degree far above the mean
        assert!(s.max_sym_degree as f64 > 5.0 * s.mean_sym_degree);
    }

    #[test]
    fn closure_increases_triangles() {
        let tri = |g: &Csr| crate::algo::triangle::count_triangles(g);
        let lo = ba_closure(800, 2400, 0.0, &mut Rng::new(31));
        let hi = ba_closure(800, 2400, 0.8, &mut Rng::new(31));
        assert!(
            tri(&hi) > tri(&lo),
            "closure should add triangles: {} vs {}",
            tri(&hi),
            tri(&lo)
        );
    }
}
