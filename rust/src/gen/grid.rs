//! Road-network replica generator (roadNet-PA/TX/CA): a 2-D lattice with
//! random edge deletions and a sprinkle of diagonal shortcuts.
//!
//! Road networks are near-planar with tiny, near-uniform degree
//! (mean ≈ 2.8, max ≈ 12) and very low triangle density. That uniformity
//! is exactly why the paper sees *no* fine-vs-coarse speedup on the
//! roadNet graphs (Table I: ~1.0x) — reproducing that null effect needs
//! this family, not just power-law graphs.

use crate::graph::builder;
use crate::graph::coo::EdgeList;
use crate::graph::csr::{Csr, Vid};
use crate::util::Rng;

/// Generate an `n`-vertex, exactly-`m`-edge road-like network.
///
/// `diag_frac` of the retained edges (approximately) are diagonal
/// shortcuts, which create the sparse triangles real road networks have
/// (highway merges, grid diagonals).
pub fn road(n: usize, m: usize, diag_frac: f64, rng: &mut Rng) -> Csr {
    assert!(n >= 4);
    let side = (n as f64).sqrt().ceil() as usize;
    let vid = |r: usize, c: usize| -> Option<Vid> {
        let id = r * side + c;
        (r < side && c < side && id < n).then_some(id as Vid)
    };
    // candidate edges: lattice + diagonals
    let mut lattice: Vec<(Vid, Vid)> = Vec::with_capacity(2 * n);
    let mut diags: Vec<(Vid, Vid)> = Vec::with_capacity(2 * n);
    for r in 0..side {
        for c in 0..side {
            let Some(u) = vid(r, c) else { continue };
            if let Some(v) = vid(r, c + 1) {
                lattice.push((u, v));
            }
            if let Some(v) = vid(r + 1, c) {
                lattice.push((u, v));
            }
            if let Some(v) = vid(r + 1, c + 1) {
                diags.push((u, v));
            }
            if c > 0 {
                if let Some(v) = vid(r + 1, c - 1) {
                    diags.push((u.min(v), u.max(v)));
                }
            }
        }
    }
    let want_diag = ((m as f64) * diag_frac) as usize;
    let want_lat = m.saturating_sub(want_diag);
    assert!(
        want_lat <= lattice.len(),
        "road: m={m} too large for lattice of n={n} (max lattice {})",
        lattice.len()
    );
    rng.shuffle(&mut lattice);
    rng.shuffle(&mut diags);
    let mut el = EdgeList::with_capacity(n, m);
    for &(u, v) in lattice.iter().take(want_lat) {
        el.push(u, v);
    }
    for &(u, v) in diags.iter().take(want_diag.min(diags.len())) {
        el.push(u, v);
    }
    // top up from remaining lattice if diagonals ran short
    let mut extra = want_lat;
    while el.len() < m && extra < lattice.len() {
        let (u, v) = lattice[extra];
        el.push(u, v);
        extra += 1;
    }
    el.normalize();
    assert_eq!(el.edges.len(), m, "road: could not hit m={m}");
    builder::from_sorted_unique(n, &el.edges)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{stats, validate};

    #[test]
    fn exact_counts_and_valid() {
        let mut rng = Rng::new(3);
        let g = road(10_000, 14_000, 0.05, &mut rng);
        assert_eq!(g.n(), 10_000);
        assert_eq!(g.nnz(), 14_000);
        assert!(validate::check(&g).is_ok());
    }

    #[test]
    fn degree_is_near_uniform() {
        let mut rng = Rng::new(5);
        let g = road(5_000, 7_000, 0.05, &mut rng);
        let s = stats::stats(&g);
        assert!(s.max_sym_degree <= 8, "max degree {}", s.max_sym_degree);
        assert!(s.degree_cv < 0.6, "cv {}", s.degree_cv);
    }

    #[test]
    fn has_some_but_few_triangles() {
        let mut rng = Rng::new(7);
        let g = road(2_500, 3_500, 0.06, &mut rng);
        let t = crate::algo::triangle::count_triangles(&g);
        assert!(t > 0, "roads need a few triangles");
        // triangle-to-edge ratio stays tiny, unlike social graphs
        assert!((t as f64) < 0.2 * g.nnz() as f64, "t={t}");
    }
}
