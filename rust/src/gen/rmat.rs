//! R-MAT (recursive matrix) generator — the power-law family used for
//! the social / citation / AS-topology replicas (soc-*, cit-*, oregon*,
//! as*, email-*, loc-*). R-MAT with skewed quadrant probabilities
//! produces the heavy-tailed degree distributions that create the
//! coarse-grained load imbalance the paper targets.

use crate::graph::builder;
use crate::graph::csr::{Csr, Vid};
use crate::util::Rng;
use std::collections::HashSet;

/// Quadrant probabilities. Classic GraphChallenge/Graph500 skew is
/// (0.57, 0.19, 0.19, 0.05); AS-style hub-dominated graphs go higher.
#[derive(Clone, Copy, Debug)]
pub struct RmatParams {
    /// Top-left quadrant probability (hub–hub edges).
    pub a: f64,
    /// Top-right quadrant probability.
    pub b: f64,
    /// Bottom-left quadrant probability.
    pub c: f64,
    /// Per-coordinate random noise applied at each recursion level to
    /// avoid the lattice artifacts of pure R-MAT.
    pub noise: f64,
}

impl RmatParams {
    /// Graph500-style skew: strong power law (soc-*, cit-*, email-*).
    pub fn social() -> Self {
        RmatParams { a: 0.57, b: 0.19, c: 0.19, noise: 0.1 }
    }
    /// Very hub-heavy: AS / oregon / caida topologies.
    pub fn autonomous_system() -> Self {
        RmatParams { a: 0.70, b: 0.15, c: 0.10, noise: 0.05 }
    }
    /// Mild skew: amazon co-purchase style.
    pub fn copurchase() -> Self {
        RmatParams { a: 0.45, b: 0.22, c: 0.22, noise: 0.1 }
    }
}

/// Generate an undirected graph with exactly `m` distinct edges on `n`
/// vertices by R-MAT sampling (rejecting self-loops, duplicates and
/// out-of-range ids when `n` is not a power of two).
pub fn rmat(n: usize, m: usize, p: RmatParams, rng: &mut Rng) -> Csr {
    assert!(n >= 2);
    let scale = (usize::BITS - (n - 1).leading_zeros()) as usize; // ceil(log2 n)
    let mut seen: HashSet<(Vid, Vid)> = HashSet::with_capacity(m * 2);
    let mut edges: Vec<(Vid, Vid)> = Vec::with_capacity(m);
    let max_edges = n * (n - 1) / 2;
    assert!(m <= max_edges, "rmat: m={m} exceeds {max_edges}");
    let mut attempts = 0usize;
    let attempt_cap = m.saturating_mul(1000).max(1_000_000);
    while edges.len() < m {
        attempts += 1;
        assert!(
            attempts < attempt_cap,
            "rmat failed to reach m={m} unique edges (got {})",
            edges.len()
        );
        let (mut u, mut v) = (0usize, 0usize);
        for _ in 0..scale {
            // jitter quadrant probabilities per level
            let na = p.a * (1.0 + p.noise * (rng.next_f64() - 0.5));
            let nb = p.b * (1.0 + p.noise * (rng.next_f64() - 0.5));
            let nc = p.c * (1.0 + p.noise * (rng.next_f64() - 0.5));
            let nd = (1.0 - p.a - p.b - p.c) * (1.0 + p.noise * (rng.next_f64() - 0.5));
            let total = na + nb + nc + nd;
            let r = rng.next_f64() * total;
            let (bu, bv) = if r < na {
                (0, 0)
            } else if r < na + nb {
                (0, 1)
            } else if r < na + nb + nc {
                (1, 0)
            } else {
                (1, 1)
            };
            u = (u << 1) | bu;
            v = (v << 1) | bv;
        }
        if u >= n || v >= n || u == v {
            continue;
        }
        let e = if u < v { (u as Vid, v as Vid) } else { (v as Vid, u as Vid) };
        if seen.insert(e) {
            edges.push(e);
        }
    }
    edges.sort_unstable();
    builder::from_sorted_unique(n, &edges)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{stats, validate};

    #[test]
    fn exact_counts_and_valid() {
        let mut rng = Rng::new(42);
        let g = rmat(1000, 5000, RmatParams::social(), &mut rng);
        assert_eq!(g.n(), 1000);
        assert_eq!(g.nnz(), 5000);
        assert!(validate::check(&g).is_ok());
    }

    #[test]
    fn social_is_more_skewed_than_uniform() {
        let mut rng = Rng::new(11);
        let g = rmat(2000, 10_000, RmatParams::social(), &mut rng);
        let s = stats::stats(&g);
        let mut rng2 = Rng::new(11);
        let er = crate::gen::erdos_renyi::gnm(2000, 10_000, &mut rng2);
        let se = stats::stats(&er);
        assert!(
            s.degree_cv > 1.5 * se.degree_cv,
            "rmat cv {} vs er cv {}",
            s.degree_cv,
            se.degree_cv
        );
    }

    #[test]
    fn as_params_even_more_skewed() {
        let mut rng = Rng::new(13);
        let social = stats::stats(&rmat(2000, 8000, RmatParams::social(), &mut rng));
        let mut rng = Rng::new(13);
        let asys = stats::stats(&rmat(2000, 8000, RmatParams::autonomous_system(), &mut rng));
        assert!(asys.max_sym_degree > social.max_sym_degree);
    }

    #[test]
    fn non_power_of_two_n() {
        let mut rng = Rng::new(5);
        let g = rmat(777, 2000, RmatParams::copurchase(), &mut rng);
        assert_eq!(g.n(), 777);
        assert_eq!(g.nnz(), 2000);
    }
}
