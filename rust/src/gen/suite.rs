//! Synthetic replicas of the paper's 50-graph GraphChallenge/SNAP suite
//! (Table I). The container has no network access, so each SNAP input is
//! replaced by a generator from the matching structural family with the
//! same vertex and edge counts (DESIGN.md §2 documents the substitution).
//!
//! Replicas are deterministic: each graph's seed is derived from its
//! name, so every bench run sees the identical graph. A binary cache
//! under `artifacts/graphs/` avoids regenerating the large ones.

use super::barabasi_albert::ba_closure;
use super::community::communities;
use super::erdos_renyi::gnm;
use super::grid::road;
use super::rmat::{rmat, RmatParams};
use crate::graph::{io, Csr};
use crate::util::Rng;
use anyhow::Result;
use std::path::PathBuf;

/// Structural family a SNAP graph is replicated from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Family {
    /// Collaboration networks (ca-*): overlapping author cliques.
    Collab,
    /// Gnutella overlays (p2p-*): engineered, low clustering.
    P2p,
    /// Autonomous-system / BGP topologies (as*, oregon*, caida): extreme hubs.
    AutonomousSystem,
    /// Social / citation / email / location: power-law, triangle-rich.
    Social,
    /// Co-purchase (amazon*): mild skew, moderate clustering.
    Copurchase,
    /// Road networks: near-planar lattice, uniform tiny degree.
    Road,
}

/// One row of Table I: the graph we must replicate.
#[derive(Clone, Copy, Debug)]
pub struct GraphSpec {
    /// SNAP dataset name (Table I row key).
    pub name: &'static str,
    /// Vertex count of the original dataset.
    pub vertices: usize,
    /// Edge count of the original dataset.
    pub edges: usize,
    /// Generator family used to replicate the structure.
    pub family: Family,
}

use Family::*;

/// The paper's full Table I suite, ordered by edge count like the plots
/// ("graphs are ordered from least number of edges to greatest").
pub const SUITE: &[GraphSpec] = &[
    GraphSpec { name: "as20000102", vertices: 6_500, edges: 12_600, family: AutonomousSystem },
    GraphSpec { name: "ca-GrQc", vertices: 5_200, edges: 14_500, family: Collab },
    GraphSpec { name: "p2p-Gnutella08", vertices: 6_300, edges: 20_800, family: P2p },
    GraphSpec { name: "oregon1_010331", vertices: 10_700, edges: 22_000, family: AutonomousSystem },
    GraphSpec { name: "oregon1_010407", vertices: 10_700, edges: 22_000, family: AutonomousSystem },
    GraphSpec { name: "oregon1_010414", vertices: 10_800, edges: 22_500, family: AutonomousSystem },
    GraphSpec { name: "oregon1_010428", vertices: 10_900, edges: 22_500, family: AutonomousSystem },
    GraphSpec { name: "oregon1_010505", vertices: 10_900, edges: 22_600, family: AutonomousSystem },
    GraphSpec { name: "oregon1_010421", vertices: 10_900, edges: 22_700, family: AutonomousSystem },
    GraphSpec { name: "oregon1_010512", vertices: 11_000, edges: 22_700, family: AutonomousSystem },
    GraphSpec { name: "oregon1_010519", vertices: 11_000, edges: 22_700, family: AutonomousSystem },
    GraphSpec { name: "oregon1_010526", vertices: 11_200, edges: 23_400, family: AutonomousSystem },
    GraphSpec { name: "ca-HepTh", vertices: 9_900, edges: 26_000, family: Collab },
    GraphSpec { name: "p2p-Gnutella09", vertices: 8_100, edges: 26_000, family: P2p },
    GraphSpec { name: "oregon2_010407", vertices: 11_000, edges: 30_900, family: AutonomousSystem },
    GraphSpec { name: "oregon2_010505", vertices: 11_200, edges: 30_900, family: AutonomousSystem },
    GraphSpec { name: "oregon2_010331", vertices: 10_900, edges: 31_200, family: AutonomousSystem },
    GraphSpec { name: "oregon2_010512", vertices: 11_300, edges: 31_300, family: AutonomousSystem },
    GraphSpec { name: "oregon2_010428", vertices: 11_100, edges: 31_400, family: AutonomousSystem },
    GraphSpec { name: "p2p-Gnutella06", vertices: 8_700, edges: 31_500, family: P2p },
    GraphSpec { name: "oregon2_010421", vertices: 11_100, edges: 31_500, family: AutonomousSystem },
    GraphSpec { name: "oregon2_010414", vertices: 11_000, edges: 31_800, family: AutonomousSystem },
    GraphSpec { name: "p2p-Gnutella05", vertices: 8_800, edges: 31_800, family: P2p },
    GraphSpec { name: "oregon2_010519", vertices: 11_400, edges: 32_300, family: AutonomousSystem },
    GraphSpec { name: "oregon2_010526", vertices: 11_500, edges: 32_700, family: AutonomousSystem },
    GraphSpec { name: "p2p-Gnutella04", vertices: 10_900, edges: 40_000, family: P2p },
    GraphSpec { name: "as-caida20071105", vertices: 26_500, edges: 53_400, family: AutonomousSystem },
    GraphSpec { name: "p2p-Gnutella25", vertices: 22_700, edges: 54_700, family: P2p },
    GraphSpec { name: "p2p-Gnutella24", vertices: 26_500, edges: 65_400, family: P2p },
    GraphSpec { name: "p2p-Gnutella30", vertices: 36_700, edges: 88_300, family: P2p },
    GraphSpec { name: "ca-CondMat", vertices: 23_100, edges: 93_400, family: Collab },
    GraphSpec { name: "p2p-Gnutella31", vertices: 62_600, edges: 147_900, family: P2p },
    GraphSpec { name: "email-Enron", vertices: 36_700, edges: 183_800, family: Social },
    GraphSpec { name: "ca-AstroPh", vertices: 18_800, edges: 198_100, family: Collab },
    GraphSpec { name: "loc-brightkite_edges", vertices: 58_200, edges: 214_100, family: Social },
    GraphSpec { name: "cit-HepTh", vertices: 27_800, edges: 352_300, family: Social },
    GraphSpec { name: "email-EuAll", vertices: 265_000, edges: 364_500, family: Social },
    GraphSpec { name: "soc-Epinions1", vertices: 75_900, edges: 405_700, family: Social },
    GraphSpec { name: "cit-HepPh", vertices: 34_500, edges: 420_900, family: Social },
    GraphSpec { name: "soc-Slashdot0811", vertices: 77_400, edges: 469_200, family: Social },
    GraphSpec { name: "soc-Slashdot0902", vertices: 82_200, edges: 504_200, family: Social },
    GraphSpec { name: "amazon0302", vertices: 262_100, edges: 899_800, family: Copurchase },
    GraphSpec { name: "loc-gowalla_edges", vertices: 196_600, edges: 950_300, family: Social },
    GraphSpec { name: "roadNet-PA", vertices: 1_088_100, edges: 1_541_900, family: Road },
    GraphSpec { name: "roadNet-TX", vertices: 1_379_900, edges: 1_921_700, family: Road },
    GraphSpec { name: "amazon0312", vertices: 400_700, edges: 2_349_900, family: Copurchase },
    GraphSpec { name: "amazon0505", vertices: 410_200, edges: 2_439_400, family: Copurchase },
    GraphSpec { name: "amazon0601", vertices: 403_400, edges: 2_443_400, family: Copurchase },
    GraphSpec { name: "roadNet-CA", vertices: 1_965_200, edges: 2_766_600, family: Road },
    GraphSpec { name: "cit-Patents", vertices: 3_774_800, edges: 16_518_900, family: Social },
];

/// Find a spec by its SNAP name.
pub fn by_name(name: &str) -> Option<&'static GraphSpec> {
    SUITE.iter().find(|s| s.name == name)
}

/// FNV-1a over the name — the per-graph deterministic seed.
pub fn seed_of(name: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1_0000_01b3);
    }
    h
}

/// Scale a spec's sizes by `scale` (≤ 1.0 shrinks the suite for CI-speed
/// runs; the scale used is always recorded in bench output). Edge counts
/// are clamped to stay feasible for the family.
pub fn scaled(spec: &GraphSpec, scale: f64) -> (usize, usize) {
    let n = ((spec.vertices as f64 * scale) as usize).max(64);
    let mut m = ((spec.edges as f64 * scale) as usize).max(96);
    let max_edges = n * (n - 1) / 2;
    m = m.min(max_edges);
    (n, m)
}

/// Generate the replica for `spec` at `scale` (1.0 = paper size).
pub fn generate(spec: &GraphSpec, scale: f64) -> Csr {
    let (n, m) = scaled(spec, scale);
    let mut rng = Rng::new(seed_of(spec.name));
    match spec.family {
        Collab => communities(n, m, 35, &mut rng),
        P2p => gnm(n, m, &mut rng),
        AutonomousSystem => rmat(n, m, RmatParams::autonomous_system(), &mut rng),
        Social => rmat(n, m, RmatParams::social(), &mut rng),
        Copurchase => ba_closure(n, m, 0.35, &mut rng),
        Road => road(n, m, 0.05, &mut rng),
    }
}

/// Cache directory for generated replicas.
pub fn cache_dir() -> PathBuf {
    std::env::var_os("KTRUSS_GRAPH_CACHE")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts/graphs"))
}

/// Generate-or-load a replica through the binary cache.
pub fn load(spec: &GraphSpec, scale: f64) -> Result<Csr> {
    let path = cache_dir().join(format!("{}-s{:.3}.bin", spec.name, scale));
    if path.exists() {
        if let Ok(g) = io::read_binary_file(&path) {
            return Ok(g);
        }
    }
    let g = generate(spec, scale);
    io::write_binary_file(&g, &path)?;
    Ok(g)
}

/// A small, fast, family-diverse subset used by tests and quick runs.
pub fn small_suite() -> Vec<&'static GraphSpec> {
    ["ca-GrQc", "p2p-Gnutella08", "as20000102", "oregon1_010331", "email-Enron", "roadNet-PA"]
        .iter()
        .filter_map(|n| by_name(n))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::validate;

    #[test]
    fn suite_has_all_50_graphs() {
        assert_eq!(SUITE.len(), 50);
    }

    #[test]
    fn suite_sorted_by_edges() {
        for w in SUITE.windows(2) {
            assert!(w[0].edges <= w[1].edges, "{} > {}", w[0].name, w[1].name);
        }
    }

    #[test]
    fn seeds_differ_across_names() {
        assert_ne!(seed_of("oregon1_010331"), seed_of("oregon1_010407"));
    }

    #[test]
    fn small_scale_generation_valid_for_each_family() {
        for name in ["ca-GrQc", "p2p-Gnutella08", "as20000102", "amazon0302", "roadNet-PA", "soc-Epinions1"] {
            let spec = by_name(name).unwrap();
            let g = generate(spec, 0.05);
            assert!(validate::check(&g).is_ok(), "{name}");
            let (n, m) = scaled(spec, 0.05);
            assert_eq!(g.n(), n, "{name}");
            assert_eq!(g.nnz(), m, "{name}");
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let spec = by_name("ca-GrQc").unwrap();
        assert_eq!(generate(spec, 0.1), generate(spec, 0.1));
    }

    #[test]
    fn scaled_clamps_to_feasible() {
        let spec = GraphSpec { name: "x", vertices: 100, edges: 10_000, family: P2p };
        let (n, m) = scaled(&spec, 1.0);
        assert!(m <= n * (n - 1) / 2);
    }
}
