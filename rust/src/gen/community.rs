//! Overlapping-community (clique-cover) generator — the alternative
//! collaboration-style family: sample communities with a heavy-tailed
//! size distribution and clique each one, then add a background of
//! random edges. Produces very high triangle density per edge, like
//! co-authorship (every paper = a clique of its authors) and the dense
//! cores of email/social graphs.

use crate::graph::builder;
use crate::graph::csr::{Csr, Vid};
use crate::util::Rng;
use std::collections::HashSet;

/// Generate `n` vertices / exactly `m` edges from overlapping cliques.
///
/// * community sizes are `3 + Zipf(alpha=1.1)` capped at `max_comm`;
/// * members are drawn with mild preferential reuse so hubs emerge;
/// * cliques are added until the unique-edge count reaches `m`
///   (the final clique may be partially applied), then topped up with
///   random edges if needed.
pub fn communities(n: usize, m: usize, max_comm: usize, rng: &mut Rng) -> Csr {
    assert!(n >= 4);
    let max_edges = n * (n - 1) / 2;
    assert!(m <= max_edges, "communities: m={m} exceeds {max_edges}");
    let mut seen: HashSet<(Vid, Vid)> = HashSet::with_capacity(2 * m);
    let mut edges: Vec<(Vid, Vid)> = Vec::with_capacity(m);
    let push = |seen: &mut HashSet<(Vid, Vid)>,
                    edges: &mut Vec<(Vid, Vid)>,
                    a: Vid,
                    b: Vid|
     -> bool {
        if a == b {
            return false;
        }
        let e = if a < b { (a, b) } else { (b, a) };
        if seen.insert(e) {
            edges.push(e);
            true
        } else {
            false
        }
    };
    let mut guard = 0usize;
    'outer: while edges.len() < m {
        guard += 1;
        assert!(guard < 50 * m + 1000, "communities: stuck below m={m}");
        let size = (3 + rng.zipf_index(max_comm.saturating_sub(2).max(1), 1.1)).min(max_comm);
        // pick members: zipf over vertex ids gives preferential reuse
        let mut members: Vec<Vid> = Vec::with_capacity(size);
        for _ in 0..size {
            members.push(rng.zipf_index(n, 0.6) as Vid);
        }
        members.sort_unstable();
        members.dedup();
        for i in 0..members.len() {
            for j in (i + 1)..members.len() {
                push(&mut seen, &mut edges, members[i], members[j]);
                if edges.len() >= m {
                    break 'outer;
                }
            }
        }
    }
    // top-up (only hit if the guard loop exits exactly at m, so usually a
    // no-op; kept for safety with tiny n)
    while edges.len() < m {
        let a = rng.below(n as u64) as Vid;
        let b = rng.below(n as u64) as Vid;
        push(&mut seen, &mut edges, a, b);
    }
    edges.sort_unstable();
    builder::from_sorted_unique(n, &edges)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::validate;

    #[test]
    fn exact_counts_and_valid() {
        let mut rng = Rng::new(17);
        let g = communities(1000, 4000, 30, &mut rng);
        assert_eq!(g.n(), 1000);
        assert_eq!(g.nnz(), 4000);
        assert!(validate::check(&g).is_ok());
    }

    #[test]
    fn triangle_rich_vs_er() {
        let g = communities(1000, 4000, 25, &mut Rng::new(19));
        let er = crate::gen::erdos_renyi::gnm(1000, 4000, &mut Rng::new(19));
        let tg = crate::algo::triangle::count_triangles(&g);
        let te = crate::algo::triangle::count_triangles(&er);
        assert!(tg > 5 * te.max(1), "communities {tg} vs er {te}");
    }

    #[test]
    fn deterministic() {
        let a = communities(300, 900, 20, &mut Rng::new(23));
        let b = communities(300, 900, 20, &mut Rng::new(23));
        assert_eq!(a, b);
    }
}
