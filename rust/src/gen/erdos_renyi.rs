//! Erdős–Rényi G(n, m) generator — the low-clustering, near-uniform
//! family. Used for the p2p-Gnutella replicas: Gnutella overlays are
//! engineered topologies with low triangle density and mild degree
//! spread, which G(n, m) with a small degree perturbation captures.

use crate::graph::builder;
use crate::graph::csr::{Csr, Vid};
use crate::util::Rng;
use std::collections::HashSet;

/// Sample exactly `m` distinct undirected edges uniformly at random.
/// Panics if `m` exceeds the number of possible edges.
pub fn gnm(n: usize, m: usize, rng: &mut Rng) -> Csr {
    let max_edges = n * (n - 1) / 2;
    assert!(m <= max_edges, "G(n,m): m={m} exceeds {max_edges}");
    // Dense fallback when m is a large fraction of all pairs: sample by
    // rejection over a shuffled pair enumeration would be O(n^2); for the
    // suite's sparse graphs rejection sampling is the fast path.
    let mut seen: HashSet<(Vid, Vid)> = HashSet::with_capacity(m * 2);
    let mut edges: Vec<(Vid, Vid)> = Vec::with_capacity(m);
    while edges.len() < m {
        let u = rng.below(n as u64) as Vid;
        let v = rng.below(n as u64) as Vid;
        if u == v {
            continue;
        }
        let e = if u < v { (u, v) } else { (v, u) };
        if seen.insert(e) {
            edges.push(e);
        }
    }
    edges.sort_unstable();
    builder::from_sorted_unique(n, &edges)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::validate;

    #[test]
    fn exact_edge_count() {
        let mut rng = Rng::new(1);
        let g = gnm(100, 300, &mut rng);
        assert_eq!(g.n(), 100);
        assert_eq!(g.nnz(), 300);
        assert!(validate::check(&g).is_ok());
    }

    #[test]
    fn deterministic() {
        let a = gnm(50, 100, &mut Rng::new(7));
        let b = gnm(50, 100, &mut Rng::new(7));
        assert_eq!(a, b);
    }

    #[test]
    fn near_complete_graph() {
        let mut rng = Rng::new(3);
        let g = gnm(10, 45, &mut rng); // complete K10
        assert_eq!(g.nnz(), 45);
    }

    #[test]
    #[should_panic(expected = "exceeds")]
    fn too_many_edges_panics() {
        gnm(4, 7, &mut Rng::new(1));
    }
}
