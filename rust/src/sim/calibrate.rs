//! One-shot calibration of the merge-step cost against real wallclock:
//! run the (single-thread) support kernel on a mid-size generated graph,
//! divide measured nanoseconds by traced steps. This pins the absolute
//! scale of the CPU model to this host; all *relative* results are
//! independent of it.

use crate::algo::support::{
    compute_supports_seq, compute_supports_segmented_seq, segment_tasks,
};
use crate::cost::trace::trace_supports;
use crate::graph::ZCsr;
use crate::util::timer::Timer;

/// Calibration output.
#[derive(Clone, Copy, Debug)]
pub struct Calibration {
    /// Measured nanoseconds per merge step (single thread).
    pub step_ns: f64,
    /// Steps in the calibration workload.
    pub steps: u64,
    /// Wall time of the measured pass, ms.
    pub wall_ms: f64,
}

/// The standard calibration workload (social-graph replica, mid-size).
fn calibration_graph() -> crate::graph::Csr {
    crate::gen::rmat::rmat(
        20_000,
        150_000,
        crate::gen::rmat::RmatParams::social(),
        &mut crate::util::Rng::new(0xCA11B),
    )
}

/// Measure step cost on a standard calibration graph.
pub fn calibrate_step_ns() -> Calibration {
    let g = calibration_graph();
    let z = ZCsr::from_csr(&g);
    let mut s = Vec::new();
    let tr = trace_supports(&z, &mut s);
    // warm-up, then measure the untraced kernel (what production runs)
    compute_supports_seq(&z, &mut s);
    let trials = 5;
    let t = Timer::start();
    for _ in 0..trials {
        compute_supports_seq(&z, &mut s);
        std::hint::black_box(&s);
    }
    let wall_ms = t.elapsed_ms() / trials as f64;
    let step_ns = wall_ms * 1e6 / tr.total_steps as f64;
    Calibration { step_ns, steps: tr.total_steps, wall_ms }
}

/// Calibration of the segment split's per-task overhead (the
/// machine-model constant behind
/// [`crate::sim::machine::CpuMachine::segment_task_ns`]).
#[derive(Clone, Copy, Debug)]
pub struct SegmentCalibration {
    /// Segment length the measurement ran with.
    pub seg_len: u32,
    /// Segment tasks in the calibration pass.
    pub tasks: usize,
    /// Measured extra nanoseconds per segment task over the plain
    /// sequential pass (≥ 0; task setup + in-tail lower-bound search).
    pub per_task_ns: f64,
    /// Wall time of one segmented pass, ms.
    pub wall_ms: f64,
}

/// Measure the segment split's per-task overhead: time the segmented
/// sequential pass against the plain one on the calibration graph and
/// attribute the difference to the task count. Noise can push the
/// difference below zero on a shared host; it is clamped at 0.
pub fn calibrate_segment_overhead(seg_len: u32) -> SegmentCalibration {
    let g = calibration_graph();
    let z = ZCsr::from_csr(&g);
    let tasks = segment_tasks(&z, seg_len).len();
    let mut s = Vec::new();
    // warm-ups
    compute_supports_seq(&z, &mut s);
    compute_supports_segmented_seq(&z, seg_len, &mut s);
    let trials = 3;
    let t = Timer::start();
    for _ in 0..trials {
        compute_supports_seq(&z, &mut s);
        std::hint::black_box(&s);
    }
    let plain_ms = t.elapsed_ms() / trials as f64;
    let t = Timer::start();
    for _ in 0..trials {
        compute_supports_segmented_seq(&z, seg_len, &mut s);
        std::hint::black_box(&s);
    }
    let wall_ms = t.elapsed_ms() / trials as f64;
    let per_task_ns = ((wall_ms - plain_ms) * 1e6 / tasks.max(1) as f64).max(0.0);
    SegmentCalibration { seg_len, tasks, per_task_ns, wall_ms }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_yields_sane_step_cost() {
        let c = calibrate_step_ns();
        // a compare-advance merge step lands in low single-digit ns on
        // anything newer than ~2010; allow wide slack for CI noise
        assert!(
            (0.1..100.0).contains(&c.step_ns),
            "step_ns {} wall {}ms steps {}",
            c.step_ns,
            c.wall_ms,
            c.steps
        );
        assert!(c.steps > 100_000);
    }

    #[test]
    fn segment_calibration_yields_sane_overhead() {
        let c = calibrate_segment_overhead(64);
        assert_eq!(c.seg_len, 64);
        assert!(c.tasks > 10_000, "tasks {}", c.tasks);
        assert!(c.per_task_ns.is_finite() && c.per_task_ns >= 0.0);
        // even with the segmented pass's bookkeeping the overhead of a
        // single task stays far below a microsecond
        assert!(c.per_task_ns < 1000.0, "per_task_ns {}", c.per_task_ns);
        assert!(c.wall_ms > 0.0);
    }
}
