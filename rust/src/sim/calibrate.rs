//! One-shot calibration of the merge-step cost against real wallclock:
//! run the (single-thread) support kernel on a mid-size generated graph,
//! divide measured nanoseconds by traced steps. This pins the absolute
//! scale of the CPU model to this host; all *relative* results are
//! independent of it.

use crate::algo::support::compute_supports_seq;
use crate::cost::trace::trace_supports;
use crate::graph::ZCsr;
use crate::util::timer::Timer;

/// Calibration output.
#[derive(Clone, Copy, Debug)]
pub struct Calibration {
    /// Measured nanoseconds per merge step (single thread).
    pub step_ns: f64,
    /// Steps in the calibration workload.
    pub steps: u64,
    /// Wall time of the measured pass, ms.
    pub wall_ms: f64,
}

/// Measure step cost on a standard calibration graph.
pub fn calibrate_step_ns() -> Calibration {
    let g = crate::gen::rmat::rmat(
        20_000,
        150_000,
        crate::gen::rmat::RmatParams::social(),
        &mut crate::util::Rng::new(0xCA11B),
    );
    let z = ZCsr::from_csr(&g);
    let mut s = Vec::new();
    let tr = trace_supports(&z, &mut s);
    // warm-up, then measure the untraced kernel (what production runs)
    compute_supports_seq(&z, &mut s);
    let trials = 5;
    let t = Timer::start();
    for _ in 0..trials {
        compute_supports_seq(&z, &mut s);
        std::hint::black_box(&s);
    }
    let wall_ms = t.elapsed_ms() / trials as f64;
    let step_ns = wall_ms * 1e6 / tr.total_steps as f64;
    Calibration { step_ns, steps: tr.total_steps, wall_ms }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_yields_sane_step_cost() {
        let c = calibrate_step_ns();
        // a compare-advance merge step lands in low single-digit ns on
        // anything newer than ~2010; allow wide slack for CI noise
        assert!(
            (0.1..100.0).contains(&c.step_ns),
            "step_ns {} wall {}ms steps {}",
            c.step_ns,
            c.wall_ms,
            c.steps
        );
        assert!(c.steps > 100_000);
    }
}
