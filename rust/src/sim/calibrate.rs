//! One-shot calibration of the merge-step cost against real wallclock:
//! run the (single-thread) support kernel on a mid-size generated graph,
//! divide measured nanoseconds by traced steps. This pins the absolute
//! scale of the CPU model to this host; all *relative* results are
//! independent of it.

use crate::algo::support::{
    compute_supports_seq, compute_supports_segmented_seq, segment_tasks, Granularity,
};
use crate::cost::trace::trace_supports;
use crate::exec::lane::{compute_supports_lane, WARP_LANES};
use crate::graph::ZCsr;
use crate::par::{Pool, Schedule};
use crate::sim::machine::GpuMachine;
use crate::util::timer::Timer;

/// Calibration output.
#[derive(Clone, Copy, Debug)]
pub struct Calibration {
    /// Measured nanoseconds per merge step (single thread).
    pub step_ns: f64,
    /// Steps in the calibration workload.
    pub steps: u64,
    /// Wall time of the measured pass, ms.
    pub wall_ms: f64,
}

/// The standard calibration workload (social-graph replica, mid-size).
fn calibration_graph() -> crate::graph::Csr {
    crate::gen::rmat::rmat(
        20_000,
        150_000,
        crate::gen::rmat::RmatParams::social(),
        &mut crate::util::Rng::new(0xCA11B),
    )
}

/// Measure step cost on a standard calibration graph.
pub fn calibrate_step_ns() -> Calibration {
    let g = calibration_graph();
    let z = ZCsr::from_csr(&g);
    let mut s = Vec::new();
    let tr = trace_supports(&z, &mut s);
    // warm-up, then measure the untraced kernel (what production runs)
    compute_supports_seq(&z, &mut s);
    let trials = 5;
    let t = Timer::start();
    for _ in 0..trials {
        compute_supports_seq(&z, &mut s);
        std::hint::black_box(&s);
    }
    let wall_ms = t.elapsed_ms() / trials as f64;
    let step_ns = wall_ms * 1e6 / tr.total_steps as f64;
    Calibration { step_ns, steps: tr.total_steps, wall_ms }
}

/// Calibration of the segment split's per-task overhead (the
/// machine-model constant behind
/// [`crate::sim::machine::CpuMachine::segment_task_ns`]).
#[derive(Clone, Copy, Debug)]
pub struct SegmentCalibration {
    /// Segment length the measurement ran with.
    pub seg_len: u32,
    /// Segment tasks in the calibration pass.
    pub tasks: usize,
    /// Measured extra nanoseconds per segment task over the plain
    /// sequential pass (≥ 0; task setup + in-tail lower-bound search).
    pub per_task_ns: f64,
    /// Wall time of one segmented pass, ms.
    pub wall_ms: f64,
}

/// Measure the segment split's per-task overhead: time the segmented
/// sequential pass against the plain one on the calibration graph and
/// attribute the difference to the task count. Noise can push the
/// difference below zero on a shared host; it is clamped at 0.
pub fn calibrate_segment_overhead(seg_len: u32) -> SegmentCalibration {
    let g = calibration_graph();
    let z = ZCsr::from_csr(&g);
    let tasks = segment_tasks(&z, seg_len).len();
    let mut s = Vec::new();
    // warm-ups
    compute_supports_seq(&z, &mut s);
    compute_supports_segmented_seq(&z, seg_len, &mut s);
    let trials = 3;
    let t = Timer::start();
    for _ in 0..trials {
        compute_supports_seq(&z, &mut s);
        std::hint::black_box(&s);
    }
    let plain_ms = t.elapsed_ms() / trials as f64;
    let t = Timer::start();
    for _ in 0..trials {
        compute_supports_segmented_seq(&z, seg_len, &mut s);
        std::hint::black_box(&s);
    }
    let wall_ms = t.elapsed_ms() / trials as f64;
    let per_task_ns = ((wall_ms - plain_ms) * 1e6 / tasks.max(1) as f64).max(0.0);
    SegmentCalibration { seg_len, tasks, per_task_ns, wall_ms }
}

/// Calibration of the lockstep-lane backend ([`crate::exec::lane`])
/// against measured warp walls: the constants that make the GPU
/// machine model's estimates comparable to what the lane execution
/// actually measures on this host.
///
/// Three fixtures fit three constants. A balanced social-replica pass
/// fits the *occupied* step cost (every lane busy, the lockstep
/// makespan tracks the wall). A hub-divergence pass fits the *serial*
/// step cost: the host realization pays every executed lane step while
/// the lockstep accounting charges only the warp max, so divergent
/// warps cost more per accounted step. A near-triangle-free pass whose
/// step count is ~0 isolates the per-pass launch overhead.
#[derive(Clone, Copy, Debug)]
pub struct LaneCalibration {
    /// Nanoseconds per lockstep makespan step on the balanced fixture.
    pub step_ns: f64,
    /// Nanoseconds per lockstep makespan step on the divergent hub
    /// fixture (≥ `step_ns` up to noise — divergence inflates it).
    pub serial_step_ns: f64,
    /// Per-pass fixed overhead, microseconds (fit from a pass with a
    /// near-zero step count).
    pub launch_us: f64,
    /// Lane occupancy on the hub fixture: executed lane steps per warp
    /// (lane-max) step. 1 = fully divergent warps,
    /// [`WARP_LANES`] = perfectly converged.
    pub divergence_ratio: f64,
    /// Lockstep makespan of the balanced fixture's measured pass.
    pub makespan_steps: u64,
    /// Wall time of one balanced-fixture pass, ms.
    pub wall_ms: f64,
}

impl LaneCalibration {
    /// A [`GpuMachine`] whose constants reproduce this host's measured
    /// lane walls: one "SM" per pool worker, 1 GHz clock so cycles read
    /// as nanoseconds, fitted occupied/serial step costs and launch
    /// overhead, remaining task constants inherited from the V100
    /// profile. Feeding [`crate::sim::gpu::estimate_tasks_sched`] this
    /// machine predicts lane-executed pass walls directly.
    pub fn fitted_machine(&self, workers: usize) -> GpuMachine {
        let v = GpuMachine::v100();
        GpuMachine {
            sms: workers.max(1),
            schedulers_per_sm: 1,
            clock_ghz: 1.0,
            warp_size: WARP_LANES,
            step_cycles: self.step_ns,
            serial_step_cycles: self.serial_step_ns,
            coarse_task_steps: v.coarse_task_steps,
            fine_task_steps: v.fine_task_steps,
            launch_us: self.launch_us,
            prune_slot_steps: v.prune_slot_steps,
            mem_bw_gbs: v.mem_bw_gbs,
        }
    }
}

/// The drift-regime key for a lane-executed pass — device-first, the
/// same grammar [`crate::obs::span::JobSpan::plan_string`] renders, so
/// calibration observations land in the `gpu/…` bands of
/// [`crate::obs::drift::DriftTracker`] instead of polluting the CPU
/// regimes.
pub fn lane_regime(schedule: Schedule, gran: Granularity) -> String {
    format!("gpu/{schedule}/{gran}/full")
}

/// The divergent calibration fixture: a comb of hub rows whose warps
/// mix one long lane with many short ones.
fn lane_hub_graph() -> crate::graph::Csr {
    crate::testkit::graphs::hub_divergence_comb(64, 256, 800)
}

/// Measure the lane backend's step/launch/divergence constants on
/// `pool`. One calibration pass makes
/// [`LaneCalibration::fitted_machine`] predictions land within a small
/// factor of measured lane walls (the `bench lane` harness asserts the
/// band).
pub fn calibrate_lane(pool: &Pool) -> LaneCalibration {
    let trials = 3;
    // balanced fixture → occupied step cost
    let z = ZCsr::from_csr(&calibration_graph());
    let (_, report) = compute_supports_lane(&z, pool, Granularity::Fine, Schedule::Stealing);
    let t = Timer::start();
    for _ in 0..trials {
        let (s, _) = compute_supports_lane(&z, pool, Granularity::Fine, Schedule::Stealing);
        std::hint::black_box(&s);
    }
    let wall_ms = t.elapsed_ms() / trials as f64;
    let makespan_steps = report.makespan_steps;
    let step_ns = wall_ms * 1e6 / makespan_steps.max(1) as f64;

    // hub fixture → serial (divergence-inflated) step cost + occupancy
    let hub = ZCsr::from_csr(&lane_hub_graph());
    let (_, hub_report) = compute_supports_lane(&hub, pool, Granularity::Coarse, Schedule::Static);
    let t = Timer::start();
    for _ in 0..trials {
        let (s, _) = compute_supports_lane(&hub, pool, Granularity::Coarse, Schedule::Static);
        std::hint::black_box(&s);
    }
    let hub_wall_ms = t.elapsed_ms() / trials as f64;
    let serial_step_ns = hub_wall_ms * 1e6 / hub_report.makespan_steps.max(1) as f64;
    let divergence_ratio =
        hub_report.executed_steps as f64 / hub_report.warp_steps.max(1) as f64;

    // near-zero-step fixture → launch overhead (a path has no
    // triangles: every task runs its setup and finds nothing)
    let path = ZCsr::from_csr(&crate::testkit::graphs::path(4096));
    let _ = compute_supports_lane(&path, pool, Granularity::Fine, Schedule::Static);
    let t = Timer::start();
    for _ in 0..trials {
        let (s, _) = compute_supports_lane(&path, pool, Granularity::Fine, Schedule::Static);
        std::hint::black_box(&s);
    }
    let launch_us = (t.elapsed_ms() / trials as f64) * 1e3;

    LaneCalibration {
        step_ns,
        serial_step_ns,
        launch_us,
        divergence_ratio,
        makespan_steps,
        wall_ms,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_yields_sane_step_cost() {
        let c = calibrate_step_ns();
        // a compare-advance merge step lands in low single-digit ns on
        // anything newer than ~2010; allow wide slack for CI noise
        assert!(
            (0.1..100.0).contains(&c.step_ns),
            "step_ns {} wall {}ms steps {}",
            c.step_ns,
            c.wall_ms,
            c.steps
        );
        assert!(c.steps > 100_000);
    }

    #[test]
    fn segment_calibration_yields_sane_overhead() {
        let c = calibrate_segment_overhead(64);
        assert_eq!(c.seg_len, 64);
        assert!(c.tasks > 10_000, "tasks {}", c.tasks);
        assert!(c.per_task_ns.is_finite() && c.per_task_ns >= 0.0);
        // even with the segmented pass's bookkeeping the overhead of a
        // single task stays far below a microsecond
        assert!(c.per_task_ns < 1000.0, "per_task_ns {}", c.per_task_ns);
        assert!(c.wall_ms > 0.0);
    }

    #[test]
    fn lane_calibration_fits_finite_constants() {
        let pool = Pool::new(2);
        let c = calibrate_lane(&pool);
        assert!(c.step_ns.is_finite() && c.step_ns > 0.0, "step_ns {}", c.step_ns);
        assert!(
            c.serial_step_ns.is_finite() && c.serial_step_ns > 0.0,
            "serial_step_ns {}",
            c.serial_step_ns
        );
        assert!(c.launch_us.is_finite() && c.launch_us >= 0.0);
        // occupancy is bounded by the warp width on any fixture
        assert!(
            c.divergence_ratio >= 1.0 && c.divergence_ratio <= WARP_LANES as f64,
            "divergence_ratio {}",
            c.divergence_ratio
        );
        assert!(c.makespan_steps > 0 && c.wall_ms > 0.0);
        let m = c.fitted_machine(pool.workers());
        assert_eq!(m.sms, 2);
        assert_eq!(m.warp_size, WARP_LANES);
        // 1 GHz clock: a fitted step's seconds read back as step_ns
        assert!((m.occupied_step_s() * 1e9 - c.step_ns).abs() < 1e-9);
    }

    #[test]
    fn lane_regime_keys_are_device_first() {
        let key = lane_regime(Schedule::Stealing, Granularity::Fine);
        assert_eq!(key, "gpu/stealing/fine/full");
    }
}
