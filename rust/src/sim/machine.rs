//! Machine models for the paper's two testbeds. This container has one
//! CPU core and no GPU, so per-task cost traces (exact, measured) are
//! combined with these calibrated throughput/overhead constants to
//! produce timing estimates (DESIGN.md §2 documents the substitution).
//!
//! The constants are *not* fitted per-graph: they are set once from
//! first-principles hardware numbers (clocks, SM counts, bandwidths)
//! plus a single calibration of merge-step cost, and then every graph,
//! K setting and granularity flows through the same model. What the
//! reproduction must get right is the *relative* behaviour — who wins,
//! by roughly what factor, and where the crossovers are.

/// CPU model: dual-socket Intel Xeon Platinum 8160 (2×24 cores, 96
/// hyperthreads; the paper ran 1–48 threads).
#[derive(Clone, Copy, Debug)]
pub struct CpuMachine {
    /// Worker threads used by the run.
    pub threads: usize,
    /// Nanoseconds per merge step (single thread, sustained). Set by
    /// `calibrate` on the container and consistent with ~3 cycles/step
    /// at 2.1 GHz for a branchy compare-advance loop.
    pub step_ns: f64,
    /// Fixed per-coarse-task (row) overhead: loop setup, row-pointer
    /// loads.
    pub coarse_task_ns: f64,
    /// Per-live-entry overhead inside a coarse task (row-span lookup of
    /// the partner row).
    pub entry_ns: f64,
    /// Fixed per-fine-task (slot) overhead: flat-index → row resolve +
    /// partner row lookup. Higher than `entry_ns` because the row of the
    /// slot must be recovered (binary search with hint).
    pub fine_task_ns: f64,
    /// Fork/join cost of one parallel region (OpenMP barrier at 48T).
    pub fork_join_us: f64,
    /// Prune cost per slot (compaction walk, bandwidth-bound).
    pub prune_slot_ns: f64,
    /// Aggregate memory bandwidth in GB/s (caps streaming phases).
    pub mem_bw_gbs: f64,
}

impl CpuMachine {
    /// The paper's CPU node at a given thread count.
    pub fn skylake_8160(threads: usize) -> CpuMachine {
        CpuMachine {
            threads: threads.max(1),
            step_ns: 1.4,
            coarse_task_ns: 18.0,
            entry_ns: 4.0,
            fine_task_ns: 9.0,
            fork_join_us: 3.0,
            prune_slot_ns: 0.8,
            mem_bw_gbs: 200.0,
        }
    }

    /// Replace the merge-step cost with a calibrated value (measured on
    /// the host by [`crate::sim::calibrate`]).
    pub fn with_step_ns(mut self, step_ns: f64) -> CpuMachine {
        self.step_ns = step_ns;
        self
    }

    /// Fixed per-segment-task overhead: a fine task's resolve plus the
    /// in-tail lower-bound search that locates the segment's merge
    /// window (the bookkeeping cost the paper warns about for the
    /// ultra-fine split; ~1.5× a fine task, consistent with what
    /// [`crate::sim::calibrate::calibrate_segment_overhead`] measures).
    pub fn segment_task_ns(&self) -> f64 {
        self.fine_task_ns * 1.5
    }

    /// Fixed per-bitmap-task overhead: a fine task's resolve plus the
    /// partner bitmap header load — but **no** in-tail locate search
    /// (the chunk bounds are precomputed in the task), so it stays at
    /// the fine-task cost. The probes themselves are word-indexed
    /// AND + popcount at one step each ([`crate::algo::bitmap`]),
    /// charged at the ordinary `step_ns` rate.
    pub fn bitmap_task_ns(&self) -> f64 {
        self.fine_task_ns
    }
}

/// GPU model: NVIDIA Tesla V100 (Volta) — 80 SMs, 4 warp schedulers
/// each, 1.38 GHz, ~900 GB/s HBM2.
#[derive(Clone, Copy, Debug)]
pub struct GpuMachine {
    /// Streaming multiprocessors.
    pub sms: usize,
    /// Warp schedulers per SM.
    pub schedulers_per_sm: usize,
    /// Core clock, GHz.
    pub clock_ghz: f64,
    /// Lanes per warp.
    pub warp_size: usize,
    /// Cycles one merge step costs a *fully occupied* warp scheduler
    /// (memory latency hidden by other resident warps).
    pub step_cycles: f64,
    /// Cycles per merge step when a warp runs alone (tail of a skewed
    /// kernel: latency no longer hidden). This is what serializes the
    /// mega-row coarse tasks on AS-topology graphs.
    pub serial_step_cycles: f64,
    /// Per-task overhead, in steps: index math + row lookups
    /// (coarse task = one row; fine task = one slot).
    pub coarse_task_steps: f64,
    /// Per-fine-task overhead, in steps.
    pub fine_task_steps: f64,
    /// Kernel launch + sync latency per kernel, microseconds.
    pub launch_us: f64,
    /// Prune cost per slot in steps.
    pub prune_slot_steps: f64,
    /// HBM bandwidth GB/s.
    pub mem_bw_gbs: f64,
}

impl GpuMachine {
    /// The paper's Tesla V100.
    pub fn v100() -> GpuMachine {
        GpuMachine {
            sms: 80,
            schedulers_per_sm: 4,
            clock_ghz: 1.38,
            warp_size: 32,
            step_cycles: 6.0,
            serial_step_cycles: 15.0,
            coarse_task_steps: 4.0,
            fine_task_steps: 6.0,
            launch_us: 8.0,
            prune_slot_steps: 0.5,
            mem_bw_gbs: 850.0,
        }
    }

    /// Peak merge-step throughput (steps/second) with full occupancy.
    pub fn peak_steps_per_s(&self) -> f64 {
        self.sms as f64 * self.schedulers_per_sm as f64 * self.clock_ghz * 1e9 / self.step_cycles
    }

    /// Seconds per step for a lone warp (divergence/tail regime).
    pub fn serial_step_s(&self) -> f64 {
        self.serial_step_cycles / (self.clock_ghz * 1e9)
    }

    /// Concurrent warp-execution slots: one warp in flight per warp
    /// scheduler (80 SMs × 4 = 320 on the V100). The schedule-aware
    /// kernel model treats these as the processors of a warp-level
    /// makespan problem.
    pub fn warp_slots(&self) -> usize {
        self.sms * self.schedulers_per_sm
    }

    /// Seconds one warp-step costs a fully occupied scheduler.
    pub fn occupied_step_s(&self) -> f64 {
        self.step_cycles / (self.clock_ghz * 1e9)
    }

    /// Per-segment-task overhead in steps: fine-task resolve plus the
    /// segment-locate search (see [`CpuMachine::segment_task_ns`] for
    /// the same 1.5× rationale on the CPU side).
    pub fn segment_task_steps(&self) -> f64 {
        self.fine_task_steps * 1.5
    }

    /// Per-bitmap-task overhead in steps: fine-task resolve plus the
    /// bitmap header load, no locate search (see
    /// [`CpuMachine::bitmap_task_ns`] for the same rationale). The
    /// probes are uniform one-step word tests — exactly the cost shape
    /// the lockstep warp model rewards, since warp duration is the lane
    /// maximum and uniform lanes waste nothing.
    pub fn bitmap_task_steps(&self) -> f64 {
        self.fine_task_steps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn v100_peak_is_order_10e10() {
        let g = GpuMachine::v100();
        let peak = g.peak_steps_per_s();
        assert!((1e10..1e12).contains(&peak), "{peak}");
    }

    #[test]
    fn serial_step_slower_than_occupied() {
        let g = GpuMachine::v100();
        let occupied_step = g.step_cycles / (g.clock_ghz * 1e9);
        assert!(g.serial_step_s() > occupied_step);
    }

    #[test]
    fn cpu_threads_clamped() {
        assert_eq!(CpuMachine::skylake_8160(0).threads, 1);
    }
}
