//! GPU SIMT timing model (V100-class).
//!
//! The flat `RangePolicy` grid maps 32 consecutive tasks to a warp.
//! Lanes execute in lockstep, so a warp's duration is the *maximum*
//! task cost among its lanes — intra-warp divergence is where coarse
//! tasks burn the GPU (one mega-row makes 31 lanes idle). The kernel's
//! duration combines:
//!
//! * **throughput term** — total warp-steps over the device's peak
//!   scheduler throughput (valid while occupancy is high);
//! * **tail/serial term** — the longest single warp at the degraded
//!   lone-warp step cost (latency no longer hidden). This is what
//!   serializes hub rows on the AS-topology graphs and reproduces the
//!   paper's catastrophic GPU-C results on `as20000102`/`oregon*`;
//! * **bandwidth term** — streamed bytes over HBM bandwidth;
//! * **launch latency** per kernel, which dominates tiny graphs and
//!   many-iteration K_max runs, exactly as in Table I.

use super::machine::GpuMachine;
use crate::algo::support::Mode;
use crate::cost::trace::SupportTrace;

/// Kernel-time estimate decomposed into the model's terms (seconds).
#[derive(Clone, Copy, Debug, Default)]
pub struct KernelEstimate {
    pub throughput_s: f64,
    pub tail_s: f64,
    pub bandwidth_s: f64,
    pub launch_s: f64,
}

impl KernelEstimate {
    /// Total kernel wall time: overlapping terms take the max, the
    /// launch latency is additive.
    pub fn total_s(&self) -> f64 {
        self.throughput_s.max(self.tail_s).max(self.bandwidth_s) + self.launch_s
    }
}

/// Per-task costs in *steps* for the support kernel.
fn task_steps(m: &GpuMachine, trace: &SupportTrace, row_ptr: &[u32], mode: Mode) -> Vec<f64> {
    match mode {
        Mode::Coarse => (0..row_ptr.len() - 1)
            .map(|i| trace.row_steps(row_ptr, i) as f64 + m.coarse_task_steps)
            .collect(),
        Mode::Fine => trace
            .fine_steps
            .iter()
            .map(|&st| st as f64 + m.fine_task_steps)
            .collect(),
    }
}

/// Estimate one support kernel.
pub fn support_kernel(
    m: &GpuMachine,
    trace: &SupportTrace,
    row_ptr: &[u32],
    mode: Mode,
) -> KernelEstimate {
    let costs = task_steps(m, trace, row_ptr, mode);
    estimate_kernel(m, &costs, trace.total_steps as f64)
}

/// Estimate one prune kernel (flat over slots, ~uniform small tasks).
pub fn prune_kernel(m: &GpuMachine, slots: usize) -> KernelEstimate {
    let costs = vec![m.prune_slot_steps; slots];
    estimate_kernel(m, &costs, slots as f64 * m.prune_slot_steps)
}

/// Public entry for synthetic task lists (used by the ultra-fine
/// ablation, which builds its own task decomposition).
pub fn estimate_tasks(m: &GpuMachine, task_costs: &[f64], total_steps: f64) -> KernelEstimate {
    estimate_kernel(m, task_costs, total_steps)
}

/// Core model: warp-max aggregation + three-way bound.
fn estimate_kernel(m: &GpuMachine, task_costs: &[f64], total_steps: f64) -> KernelEstimate {
    if task_costs.is_empty() {
        return KernelEstimate { launch_s: m.launch_us / 1e6, ..Default::default() };
    }
    let mut total_warp_steps = 0.0f64;
    let mut longest_warp = 0.0f64;
    for w in task_costs.chunks(m.warp_size) {
        let wmax = w.iter().cloned().fold(0.0f64, f64::max);
        total_warp_steps += wmax;
        longest_warp = longest_warp.max(wmax);
    }
    let throughput_s = total_warp_steps / m.peak_steps_per_s();
    let tail_s = longest_warp * m.serial_step_s();
    // bytes: 8B of column data per merge step + 16B of pointers per task
    let bytes = total_steps * 8.0 + task_costs.len() as f64 * 16.0;
    let bandwidth_s = bytes / (m.mem_bw_gbs * 1e9);
    KernelEstimate { throughput_s, tail_s, bandwidth_s, launch_s: m.launch_us / 1e6 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::trace::trace_supports;
    use crate::graph::ZCsr;

    fn trace_of(g: &crate::graph::Csr) -> (ZCsr, SupportTrace) {
        let z = ZCsr::from_csr(g);
        let mut s = Vec::new();
        let t = trace_supports(&z, &mut s);
        (z, t)
    }

    #[test]
    fn fine_crushes_coarse_on_hub_graph() {
        // AS-style topology: mega-hub rows serialize the coarse kernel
        let g = crate::gen::rmat::rmat(
            6500,
            12_600,
            crate::gen::rmat::RmatParams::autonomous_system(),
            &mut crate::util::Rng::new(1),
        );
        let (z, tr) = trace_of(&g);
        let m = GpuMachine::v100();
        let coarse = support_kernel(&m, &tr, z.row_ptr(), Mode::Coarse).total_s();
        let fine = support_kernel(&m, &tr, z.row_ptr(), Mode::Fine).total_s();
        assert!(
            coarse > 3.0 * fine,
            "expected big GPU win for fine: coarse {coarse} fine {fine}"
        );
    }

    #[test]
    fn road_graph_parity() {
        let g = crate::gen::grid::road(30_000, 42_000, 0.05, &mut crate::util::Rng::new(2));
        let (z, tr) = trace_of(&g);
        let m = GpuMachine::v100();
        let coarse = support_kernel(&m, &tr, z.row_ptr(), Mode::Coarse).total_s();
        let fine = support_kernel(&m, &tr, z.row_ptr(), Mode::Fine).total_s();
        let ratio = coarse / fine;
        assert!((0.4..2.5).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn tail_term_dominates_for_single_giant_task() {
        let m = GpuMachine::v100();
        let mut costs = vec![1.0; 32 * 100];
        costs[0] = 1_000_000.0;
        let est = estimate_kernel(&m, &costs, 1_003_200.0);
        assert!(est.tail_s > est.throughput_s);
        assert!(est.total_s() >= est.tail_s);
    }

    #[test]
    fn launch_latency_floors_empty_kernels() {
        let m = GpuMachine::v100();
        let est = estimate_kernel(&m, &[], 0.0);
        assert!((est.total_s() - 8e-6).abs() < 1e-9);
    }

    #[test]
    fn prune_kernel_scales() {
        let m = GpuMachine::v100();
        assert!(prune_kernel(&m, 10_000_000).total_s() > prune_kernel(&m, 10_000).total_s());
    }
}
