//! GPU SIMT timing model (V100-class), schedule- and granularity-aware.
//!
//! Tasks (rows for coarse, slots for fine, partner-row segments for the
//! segment split) are packed into warps of 32 lanes executing in
//! lockstep, so a warp's duration is the *maximum* task cost among its
//! lanes — intra-warp divergence is where coarse tasks burn the GPU
//! (one mega-row makes 31 lanes idle). Warp formation is fixed — 32
//! consecutive tasks per warp, duration = lane maximum — because
//! lockstep lanes cannot be fed fewer tasks without idling; what the
//! [`Schedule`] governs is the warp→scheduler *assignment*, the exact
//! CPU makespan model shifted one level up (warps are the tasks,
//! warp-scheduler slots are the workers):
//!
//! * [`Schedule::Static`] — the flat grid is issued in contiguous
//!   equal-*count* waves per scheduler (what the paper's Kokkos
//!   `RangePolicy` compiles to): a clustered hot region of the
//!   iteration space serializes on a few schedulers. Mirrors the CPU
//!   model's static contiguous-block makespan.
//! * [`Schedule::WorkAware`] — scan-binned equal-*work* warp chunks:
//!   each scheduler receives a contiguous chain of warps of
//!   approximately equal total work, via the same
//!   [`balance::scan_bins`] the real pool runs over the per-task costs
//!   (aggregated to warp durations). The binner's isolate-the-giant
//!   property puts a hot warp alone on its scheduler.
//! * [`Schedule::Stealing`] — persistent blocks with a global work
//!   counter ("Dynamic Load Balancing Strategies for Graph Applications
//!   on GPUs", arXiv:1711.00231): each persistent warp grabs the next
//!   32-task chunk when it drains, i.e. earliest-finish greedy
//!   dispatch, so no scheduler idles behind a hot wave.
//!   [`Schedule::Dynamic`] is modeled the same way.
//!
//! The kernel's duration combines:
//!
//! * **throughput/makespan term** — the warp-level makespan over the
//!   device's warp-scheduler slots at the occupied step rate (reduces
//!   to total-warp-steps over peak throughput when warps are balanced);
//! * **tail/serial term** — the longest single warp at the degraded
//!   lone-warp step cost (latency no longer hidden). This is what
//!   serializes hub rows on the AS-topology graphs and reproduces the
//!   paper's catastrophic GPU-C results on `as20000102`/`oregon*`. No
//!   *schedule* can shrink it — only a finer granularity splits the
//!   giant task, which is exactly the paper's argument;
//! * **bandwidth term** — streamed bytes over HBM bandwidth;
//! * **launch latency** per kernel, which dominates tiny graphs and
//!   many-iteration K_max runs, exactly as in Table I.
//!
//! Per-task base costs come from [`balance::Costs::from_trace_rows`] —
//! the same derivation the CPU model uses — so the two machine models
//! read one shared view of the traced work and cannot drift.

use super::machine::GpuMachine;
use crate::algo::support::{Granularity, Mode};
use crate::cost::trace::SupportTrace;
use crate::par::{balance, Schedule};
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Kernel-time estimate decomposed into the model's terms (seconds).
#[derive(Clone, Copy, Debug, Default)]
pub struct KernelEstimate {
    /// Warp-level makespan over the scheduler slots (occupied rate).
    pub throughput_s: f64,
    /// Longest single warp at the degraded lone-warp rate.
    pub tail_s: f64,
    /// Streamed bytes over HBM bandwidth.
    pub bandwidth_s: f64,
    /// Kernel launch + sync latency.
    pub launch_s: f64,
}

impl KernelEstimate {
    /// Total kernel wall time: overlapping terms take the max, the
    /// launch latency is additive.
    pub fn total_s(&self) -> f64 {
        self.throughput_s.max(self.tail_s).max(self.bandwidth_s) + self.launch_s
    }
}

/// Per-task costs in *steps* for the support kernel: shared base steps
/// from [`balance::Costs::from_trace_rows`] plus this model's per-task
/// overhead for the granularity. `col` is the pass-time column array —
/// only the hybrid split reads it, to mirror the bitmap representation
/// selection ([`balance::hybrid_trace_pieces`]).
fn task_steps(
    m: &GpuMachine,
    trace: &SupportTrace,
    row_ptr: &[u32],
    col: &[u32],
    gran: Granularity,
) -> Vec<f64> {
    // hybrid splits into two differently-priced task kinds: merge
    // segments at the segment overhead, bitmap probe chunks at the
    // cheaper no-locate probe overhead (and uniform one-step probes are
    // exactly what the lockstep warp model rewards)
    if let Granularity::Hybrid { len } = gran {
        let (merge, probe) =
            balance::hybrid_trace_pieces(&trace.fine_steps, row_ptr, col, &trace.live_per_row, len);
        return merge
            .iter()
            .map(|&st| st as f64 + m.segment_task_steps())
            .chain(probe.iter().map(|&st| st as f64 + m.bitmap_task_steps()))
            .collect();
    }
    let base = balance::Costs::from_trace_rows(&trace.fine_steps, row_ptr, gran);
    let overhead = match gran {
        Granularity::Coarse => m.coarse_task_steps,
        Granularity::Fine => m.fine_task_steps,
        Granularity::Segment { .. } => m.segment_task_steps(),
        Granularity::Hybrid { .. } => unreachable!("handled above"),
    };
    base.per_task.iter().map(|&c| c as f64 + overhead).collect()
}

/// Estimate one support kernel under the default static schedule
/// (back-compatible entry for the coarse/fine pair). `col` is the
/// pass-time column array (0 = terminator); only hybrid reads it.
pub fn support_kernel(
    m: &GpuMachine,
    trace: &SupportTrace,
    row_ptr: &[u32],
    col: &[u32],
    mode: Mode,
) -> KernelEstimate {
    support_kernel_sched(m, trace, row_ptr, col, mode.into(), Schedule::Static)
}

/// Estimate one support kernel at any granularity under any schedule.
/// `col` is the pass-time column array (0 = terminator); only the
/// hybrid split reads it.
pub fn support_kernel_sched(
    m: &GpuMachine,
    trace: &SupportTrace,
    row_ptr: &[u32],
    col: &[u32],
    gran: Granularity,
    schedule: Schedule,
) -> KernelEstimate {
    let costs = task_steps(m, trace, row_ptr, col, gran);
    estimate_kernel(m, &costs, trace.total_steps as f64, schedule)
}

/// Estimate one prune kernel (flat over slots, ~uniform small tasks —
/// the schedule cannot matter, so the static path is used).
pub fn prune_kernel(m: &GpuMachine, slots: usize) -> KernelEstimate {
    let costs = vec![m.prune_slot_steps; slots];
    estimate_kernel(m, &costs, slots as f64 * m.prune_slot_steps, Schedule::Static)
}

/// Estimate one **incremental frontier kernel**
/// ([`crate::algo::incremental`]): the launch covers only the
/// pruned-edge frontier. Per-task base steps come from the shared
/// [`balance::Costs::from_frontier`] derivation (same as the CPU
/// model), warp formation and schedule handling are identical to the
/// full support kernel — a frontier skewed onto one hub edge still
/// pays the serial-tail term, which only a finer granularity splits.
pub fn frontier_kernel(
    m: &GpuMachine,
    task_steps: &[u32],
    task_rows: &[u32],
    gran: Granularity,
    schedule: Schedule,
) -> KernelEstimate {
    let base = balance::Costs::from_frontier(task_steps, task_rows, gran);
    let overhead = match gran {
        Granularity::Coarse => m.coarse_task_steps,
        Granularity::Fine => m.fine_task_steps,
        Granularity::Segment { .. } => m.segment_task_steps(),
        // frontier decrements are merge-walks regardless of the support
        // pass's representation: charge the segment overhead
        Granularity::Hybrid { .. } => m.segment_task_steps(),
    };
    let costs: Vec<f64> = base.per_task.iter().map(|&c| c as f64 + overhead).collect();
    let total_steps: f64 = task_steps.iter().map(|&x| x as f64).sum();
    estimate_kernel(m, &costs, total_steps, schedule)
}

/// Public entry for synthetic task lists (used by the ultra-fine
/// ablation and the schedule shape tests, which build their own task
/// decompositions).
pub fn estimate_tasks(m: &GpuMachine, task_costs: &[f64], total_steps: f64) -> KernelEstimate {
    estimate_kernel(m, task_costs, total_steps, Schedule::Static)
}

/// [`estimate_tasks`] with an explicit warp/dispatch schedule.
pub fn estimate_tasks_sched(
    m: &GpuMachine,
    task_costs: &[f64],
    total_steps: f64,
    schedule: Schedule,
) -> KernelEstimate {
    estimate_kernel(m, task_costs, total_steps, schedule)
}

/// Per-warp durations (steps): 32 consecutive tasks per warp, duration
/// = lane maximum (lockstep). Identical for every schedule — lockstep
/// lanes cannot be fed fewer tasks without idling, so only a finer
/// *granularity* (not a schedule) can shrink a warp.
///
/// Public because the executing lane backend
/// ([`crate::exec::lane`]) replays exactly this warp-formation
/// convention (consecutive chunks of `warp_size` tasks, duration =
/// lane max), and the parity tests feed the backend's measured
/// per-task steps through this function to assert the model and the
/// execution agree warp by warp.
pub fn warp_durations(m: &GpuMachine, task_costs: &[f64]) -> Vec<f64> {
    task_costs
        .chunks(m.warp_size)
        .map(|chunk| chunk.iter().cloned().fold(0.0f64, f64::max))
        .collect()
}

/// Makespan (steps) of the warp durations over the device's scheduler
/// slots — the CPU makespan model one level up. Static issues
/// contiguous equal-count waves per slot; `WorkAware` scan-bins the
/// warp durations into one equal-work contiguous chain per slot;
/// `Stealing`/`Dynamic` dispatch earliest-finish (persistent blocks on
/// a global counter).
fn slot_makespan_steps(warps: &[f64], slots: usize, schedule: Schedule) -> f64 {
    if warps.is_empty() {
        return 0.0;
    }
    let slots = slots.max(1);
    match schedule {
        Schedule::Static => {
            let n = warps.len();
            let mut worst = 0.0f64;
            for s in 0..slots {
                let lo = n * s / slots;
                let hi = n * (s + 1) / slots;
                let sum: f64 = warps[lo..hi].iter().sum();
                worst = worst.max(sum);
            }
            worst
        }
        Schedule::WorkAware => {
            // fixed-point costs (≥ 1 each) keep the binner integral,
            // exactly as the CPU model's WorkAware branch does
            let fixed: Vec<u64> = warps.iter().map(|&c| (c * 16.0).round() as u64 + 1).collect();
            let bins = balance::scan_bins(&fixed, slots);
            bins.iter()
                .map(|&(lo, hi)| warps[lo..hi].iter().sum::<f64>())
                .fold(0.0, f64::max)
        }
        Schedule::Dynamic { .. } | Schedule::Stealing => {
            // earliest-finish greedy over slot clocks (1/16-step
            // fixed point keeps the heap ordered, as in sim::cpu)
            let mut heap: BinaryHeap<Reverse<u64>> = (0..slots).map(|_| Reverse(0u64)).collect();
            let mut makespan = 0u64;
            for &c in warps {
                let Reverse(t) = heap.pop().unwrap();
                let done = t + (c * 16.0).round() as u64;
                makespan = makespan.max(done);
                heap.push(Reverse(done));
            }
            makespan as f64 / 16.0
        }
    }
}

/// Core model: warp formation + slot makespan + tail/bandwidth bounds.
fn estimate_kernel(
    m: &GpuMachine,
    task_costs: &[f64],
    total_steps: f64,
    schedule: Schedule,
) -> KernelEstimate {
    if task_costs.is_empty() {
        return KernelEstimate { launch_s: m.launch_us / 1e6, ..Default::default() };
    }
    let warps = warp_durations(m, task_costs);
    let longest_warp = warps.iter().cloned().fold(0.0f64, f64::max);
    let makespan = slot_makespan_steps(&warps, m.warp_slots(), schedule);
    let throughput_s = makespan * m.occupied_step_s();
    let tail_s = longest_warp * m.serial_step_s();
    // bytes: 8B of column data per merge step + 16B of pointers per task
    let bytes = total_steps * 8.0 + task_costs.len() as f64 * 16.0;
    let bandwidth_s = bytes / (m.mem_bw_gbs * 1e9);
    KernelEstimate { throughput_s, tail_s, bandwidth_s, launch_s: m.launch_us / 1e6 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::trace::trace_supports;
    use crate::graph::ZCsr;

    fn trace_of(g: &crate::graph::Csr) -> (ZCsr, SupportTrace) {
        let z = ZCsr::from_csr(g);
        let mut s = Vec::new();
        let t = trace_supports(&z, &mut s);
        (z, t)
    }

    #[test]
    fn fine_crushes_coarse_on_hub_graph() {
        // AS-style topology: mega-hub rows serialize the coarse kernel
        let g = crate::gen::rmat::rmat(
            6500,
            12_600,
            crate::gen::rmat::RmatParams::autonomous_system(),
            &mut crate::util::Rng::new(1),
        );
        let (z, tr) = trace_of(&g);
        let m = GpuMachine::v100();
        let coarse = support_kernel(&m, &tr, z.row_ptr(), z.col(), Mode::Coarse).total_s();
        let fine = support_kernel(&m, &tr, z.row_ptr(), z.col(), Mode::Fine).total_s();
        assert!(
            coarse > 3.0 * fine,
            "expected big GPU win for fine: coarse {coarse} fine {fine}"
        );
    }

    #[test]
    fn road_graph_parity() {
        let g = crate::gen::grid::road(30_000, 42_000, 0.05, &mut crate::util::Rng::new(2));
        let (z, tr) = trace_of(&g);
        let m = GpuMachine::v100();
        let coarse = support_kernel(&m, &tr, z.row_ptr(), z.col(), Mode::Coarse).total_s();
        let fine = support_kernel(&m, &tr, z.row_ptr(), z.col(), Mode::Fine).total_s();
        let ratio = coarse / fine;
        assert!((0.4..2.5).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn tail_term_dominates_for_single_giant_task() {
        let m = GpuMachine::v100();
        let mut costs = vec![1.0; 32 * 100];
        costs[0] = 1_000_000.0;
        let est = estimate_tasks(&m, &costs, 1_003_200.0);
        assert!(est.tail_s > est.throughput_s);
        assert!(est.total_s() >= est.tail_s);
    }

    #[test]
    fn launch_latency_floors_empty_kernels() {
        let m = GpuMachine::v100();
        let est = estimate_tasks(&m, &[], 0.0);
        assert!((est.total_s() - 8e-6).abs() < 1e-9);
    }

    #[test]
    fn prune_kernel_scales() {
        let m = GpuMachine::v100();
        assert!(prune_kernel(&m, 10_000_000).total_s() > prune_kernel(&m, 10_000).total_s());
    }

    #[test]
    fn workaware_and_stealing_beat_static_on_clustered_hot_region() {
        // 1000 warps over 320 slots, heavy tasks clustered at the front
        // (hub rows sit at low vertex ids in power-law orderings): the
        // static contiguous waves pile several hot warps onto the same
        // schedulers, dynamic dispatch spreads them
        let m = GpuMachine::v100();
        let n = 32 * 1000;
        let costs: Vec<f64> = (0..n).map(|i| if i < 3200 { 100.0 } else { 1.0 }).collect();
        let total: f64 = costs.iter().sum();
        let stat = estimate_tasks_sched(&m, &costs, total, Schedule::Static);
        let wa = estimate_tasks_sched(&m, &costs, total, Schedule::WorkAware);
        let steal = estimate_tasks_sched(&m, &costs, total, Schedule::Stealing);
        assert!(
            wa.throughput_s < 0.6 * stat.throughput_s,
            "workaware {} vs static {}",
            wa.throughput_s,
            stat.throughput_s
        );
        assert!(
            steal.throughput_s < 0.6 * stat.throughput_s,
            "stealing {} vs static {}",
            steal.throughput_s,
            stat.throughput_s
        );
        // the tail term is granularity physics, not schedule physics:
        // the longest warp stays within a small factor across schedules
        assert!(wa.tail_s <= stat.tail_s * 1.01 + 1e-12);
        assert!((steal.tail_s - stat.tail_s).abs() < 1e-12);
    }

    #[test]
    fn schedules_tie_when_warps_fit_the_slots() {
        // fewer warps than schedulers: every warp runs concurrently, no
        // schedule can help (or hurt)
        let m = GpuMachine::v100();
        let costs: Vec<f64> = (0..32 * 100).map(|i| 1.0 + (i % 13) as f64).collect();
        let total: f64 = costs.iter().sum();
        let stat = estimate_tasks_sched(&m, &costs, total, Schedule::Static);
        let steal = estimate_tasks_sched(&m, &costs, total, Schedule::Stealing);
        assert!((stat.throughput_s - steal.throughput_s).abs() < 1e-9);
    }

    #[test]
    fn workaware_not_worse_than_static_on_star_hot_row() {
        // the satellite acceptance check: on the star hot-row graph the
        // work-aware GPU model's predicted support-kernel time must not
        // exceed static's, at every granularity
        let g = crate::testkit::graphs::star_with_fringe(1200);
        let (z, tr) = trace_of(&g);
        let m = GpuMachine::v100();
        for gran in [
            Granularity::Coarse,
            Granularity::Fine,
            Granularity::Segment { len: 64 },
        ] {
            let stat =
                support_kernel_sched(&m, &tr, z.row_ptr(), z.col(), gran, Schedule::Static)
                    .total_s();
            let wa =
                support_kernel_sched(&m, &tr, z.row_ptr(), z.col(), gran, Schedule::WorkAware)
                    .total_s();
            assert!(wa <= stat * 1.001, "{gran}: workaware {wa} vs static {stat}");
        }
    }

    #[test]
    fn segment_granularity_beats_coarse_on_hot_row_graph() {
        // hub row + triangle fringe: the hot coarse task dominates the
        // tail term; the segment split decomposes it
        let g = crate::testkit::graphs::star_with_fringe(1500);
        let (z, tr) = trace_of(&g);
        let m = GpuMachine::v100();
        for sched in [Schedule::Static, Schedule::WorkAware] {
            let coarse =
                support_kernel_sched(&m, &tr, z.row_ptr(), z.col(), Granularity::Coarse, sched)
                    .total_s();
            let seg = support_kernel_sched(
                &m,
                &tr,
                z.row_ptr(),
                z.col(),
                Granularity::Segment { len: 64 },
                sched,
            )
            .total_s();
            assert!(seg < coarse, "{sched:?}: segment {seg} vs coarse {coarse}");
        }
    }

    #[test]
    fn hybrid_probe_pricing_not_worse_than_segment_on_hub_graph() {
        // the hub row is bitmap-encoded, so slots probing it become
        // uniform chunks at the cheaper probe overhead with ≤ the merge
        // step count — the replay estimate must not charge them as
        // segment merges (the pre-PR behaviour)
        let g = crate::testkit::graphs::hub_divergence_comb(64, 256, 800);
        let (z, tr) = trace_of(&g);
        let m = GpuMachine::v100();
        let seg = support_kernel_sched(
            &m,
            &tr,
            z.row_ptr(),
            z.col(),
            Granularity::Segment { len: 32 },
            Schedule::Static,
        );
        let hyb = support_kernel_sched(
            &m,
            &tr,
            z.row_ptr(),
            z.col(),
            Granularity::Hybrid { len: 32 },
            Schedule::Static,
        );
        assert!(
            hyb.total_s() <= seg.total_s() * 1.001,
            "hybrid {} vs segment {}",
            hyb.total_s(),
            seg.total_s()
        );
        // and the per-task sum is strictly cheaper: fewer steps per
        // probed slot plus the smaller per-task overhead
        let seg_sum: f64 =
            task_steps(&m, &tr, z.row_ptr(), z.col(), Granularity::Segment { len: 32 })
                .iter()
                .sum();
        let hyb_sum: f64 =
            task_steps(&m, &tr, z.row_ptr(), z.col(), Granularity::Hybrid { len: 32 })
                .iter()
                .sum();
        assert!(hyb_sum < seg_sum, "hybrid work {hyb_sum} vs segment work {seg_sum}");
    }

    #[test]
    fn segment_splits_bound_warp_divergence() {
        // a single giant fine task: segment-splitting caps the longest
        // warp at ~len steps, so the tail term collapses
        let m = GpuMachine::v100();
        let row_ptr = vec![0u32, 2, 3];
        let fine_steps = vec![100_000u32, 0, 0];
        // col only matters to the hybrid split; a minimal valid layout
        // (one live entry pointing at row 1, then terminators) suffices
        let col = vec![1u32, 0, 0];
        let tr = SupportTrace {
            fine_steps,
            live_per_row: vec![1, 0],
            total_steps: 100_000,
        };
        let fine =
            support_kernel_sched(&m, &tr, &row_ptr, &col, Granularity::Fine, Schedule::Static);
        let seg = support_kernel_sched(
            &m,
            &tr,
            &row_ptr,
            &col,
            Granularity::Segment { len: 64 },
            Schedule::Static,
        );
        assert!(seg.tail_s < fine.tail_s / 100.0, "seg {} fine {}", seg.tail_s, fine.tail_s);
    }
}
