//! Device timing simulators for the paper's testbeds (48-thread Skylake
//! node, Tesla V100). Exact per-task work traces from [`crate::cost`]
//! are scheduled under calibrated machine models to produce the timing
//! estimates the benchmark harness reports. See DESIGN.md §2 for why
//! this substitution preserves the paper's phenomena.

pub mod calibrate;
pub mod cpu;
pub mod gpu;
pub mod machine;
pub mod run;

pub use machine::{CpuMachine, GpuMachine};
pub use run::{
    gpu_schedule_grid, simulate_kmax, simulate_ktruss, simulate_ktruss_mode, table1_configs,
    Device, SimConfig, SimResult, GPU_SCHEDULES,
};
