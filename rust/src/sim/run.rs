//! End-to-end simulated K-truss timing: replay the convergence loop
//! once, estimate every device/granularity configuration from the same
//! per-iteration traces.

use super::cpu;
use super::gpu;
use super::machine::{CpuMachine, GpuMachine};
use crate::algo::incremental::SupportMode;
use crate::algo::support::{Granularity, Mode};
use crate::cost::replay::{
    replay_kmax, replay_ktruss, replay_ktruss_mode, FrontierIterObservation, IterObservation,
    PassObservation,
};
use crate::graph::Csr;
use crate::par::Schedule;
use crate::util::timer::me_per_s;

/// A simulated execution target.
#[derive(Clone, Copy, Debug)]
pub enum Device {
    /// The calibrated multicore CPU model.
    Cpu(CpuMachine),
    /// The calibrated V100 model.
    Gpu(GpuMachine),
}

impl Device {
    /// Short device label (`cpu48t`, `gpu`).
    pub fn name(&self) -> String {
        match self {
            Device::Cpu(m) => format!("cpu{}t", m.threads),
            Device::Gpu(_) => "gpu".to_string(),
        }
    }
}

/// One configuration to estimate.
#[derive(Clone, Debug)]
pub struct SimConfig {
    /// Human-readable row key (`CPU-C-48t`, `GPU-F-workaware`, …).
    pub label: String,
    /// Machine model the configuration runs on.
    pub device: Device,
    /// Task granularity of the support pass.
    pub gran: Granularity,
    /// Warp/thread schedule of the support pass.
    pub schedule: Schedule,
}

impl SimConfig {
    /// CPU configuration at the paper's default static schedule.
    pub fn cpu(threads: usize, mode: Mode) -> SimConfig {
        SimConfig::cpu_gran(threads, mode.into(), Schedule::Static)
    }

    /// GPU configuration at the paper's default static schedule.
    pub fn gpu(mode: Mode) -> SimConfig {
        SimConfig::gpu_gran(mode.into(), Schedule::Static)
    }

    /// CPU configuration with an explicit schedule (the schedule
    /// ablation axis: static | dynamic | workaware | stealing).
    pub fn cpu_sched(threads: usize, mode: Mode, schedule: Schedule) -> SimConfig {
        SimConfig {
            label: format!("CPU-{}-{}t-{}", Granularity::from(mode).short(), threads, schedule),
            device: Device::Cpu(CpuMachine::skylake_8160(threads)),
            gran: mode.into(),
            schedule,
        }
    }

    /// CPU configuration at any point of the schedule × granularity
    /// grid. Static-schedule labels stay schedule-suffix-free so the
    /// Table-I row keys (`CPU-C-48t`) are stable.
    pub fn cpu_gran(threads: usize, gran: Granularity, schedule: Schedule) -> SimConfig {
        let label = match schedule {
            Schedule::Static => format!("CPU-{}-{}t", gran.short(), threads),
            _ => format!("CPU-{}-{}t-{}", gran.short(), threads, schedule),
        };
        SimConfig {
            label,
            device: Device::Cpu(CpuMachine::skylake_8160(threads)),
            gran,
            schedule,
        }
    }

    /// GPU configuration at any point of the schedule × granularity
    /// grid (`GPU-C`, `GPU-F-workaware`, `GPU-S64-stealing`, …).
    pub fn gpu_gran(gran: Granularity, schedule: Schedule) -> SimConfig {
        let label = match schedule {
            Schedule::Static => format!("GPU-{}", gran.short()),
            _ => format!("GPU-{}-{}", gran.short(), schedule),
        };
        SimConfig { label, device: Device::Gpu(GpuMachine::v100()), gran, schedule }
    }
}

/// The GPU schedule axis the sweeps report (dynamic is modeled
/// identically to stealing on the GPU, so it is elided).
pub const GPU_SCHEDULES: [Schedule; 3] =
    [Schedule::Static, Schedule::WorkAware, Schedule::Stealing];

/// The full GPU schedule × granularity grid: coarse/fine/segment under
/// static/work-aware/stealing (9 configurations, static first per
/// granularity so speedup baselines are adjacent).
pub fn gpu_schedule_grid(seg_len: u32) -> Vec<SimConfig> {
    let mut out = Vec::new();
    for gran in [
        Granularity::Coarse,
        Granularity::Fine,
        Granularity::Segment { len: seg_len },
    ] {
        for sched in GPU_SCHEDULES {
            out.push(SimConfig::gpu_gran(gran, sched));
        }
    }
    out
}

/// Simulated timing of one full K-truss run under one configuration.
#[derive(Clone, Debug)]
pub struct SimResult {
    /// The configuration's label.
    pub label: String,
    /// Total wall time (all iterations, support + prune kernels).
    pub seconds: f64,
    /// Convergence iterations.
    pub iterations: usize,
    /// Millions of edges (of the input graph) per second.
    pub me_per_s: f64,
}

impl SimResult {
    /// Total wall time in milliseconds.
    pub fn time_ms(&self) -> f64 {
        self.seconds * 1e3
    }
}

/// Accumulate one iteration's kernel estimates into `totals`.
fn accumulate(configs: &[SimConfig], totals: &mut [f64], o: &IterObservation) {
    for (cfg, acc) in configs.iter().zip(totals.iter_mut()) {
        let t = match &cfg.device {
            Device::Cpu(m) => {
                cpu::support_pass_s(m, o.trace, o.row_ptr, o.col, cfg.gran, cfg.schedule)
                    + cpu::prune_pass_s(m, o.slots)
            }
            Device::Gpu(m) => {
                gpu::support_kernel_sched(m, o.trace, o.row_ptr, o.col, cfg.gran, cfg.schedule)
                    .total_s()
                    + gpu::prune_kernel(m, o.slots).total_s()
            }
        };
        *acc += t;
    }
}

/// Simulate a fixed-k K-truss under every configuration. One replay of
/// the actual algorithm drives all estimates.
pub fn simulate_ktruss(g: &Csr, k: u32, configs: &[SimConfig]) -> Vec<SimResult> {
    let mut totals = vec![0.0f64; configs.len()];
    let (iterations, _) = replay_ktruss(g, k, |o| accumulate(configs, &mut totals, o));
    finish(g, configs, totals, iterations)
}

/// Accumulate one frontier-pass iteration into `totals`: a
/// frontier-sized kernel launch (plus the compaction pass) under every
/// configured device/granularity/schedule.
fn accumulate_frontier(configs: &[SimConfig], totals: &mut [f64], o: &FrontierIterObservation) {
    for (cfg, acc) in configs.iter().zip(totals.iter_mut()) {
        let t = match &cfg.device {
            Device::Cpu(m) => {
                cpu::frontier_pass_s(m, o.task_steps, o.task_rows, cfg.gran, cfg.schedule)
                    + cpu::prune_pass_s(m, o.slots)
            }
            Device::Gpu(m) => {
                gpu::frontier_kernel(m, o.task_steps, o.task_rows, cfg.gran, cfg.schedule)
                    .total_s()
                    + gpu::prune_kernel(m, o.slots).total_s()
            }
        };
        *acc += t;
    }
}

/// Simulate a fixed-k K-truss under every configuration with an
/// explicit support-maintenance mode: the replay makes the same
/// per-round full-vs-frontier decisions as the real driver
/// ([`crate::cost::replay::replay_ktruss_mode`]), so incremental
/// iterations are priced as frontier-sized kernel launches.
/// `SupportMode::Full` is identical to [`simulate_ktruss`].
pub fn simulate_ktruss_mode(
    g: &Csr,
    k: u32,
    configs: &[SimConfig],
    support: SupportMode,
) -> Vec<SimResult> {
    let mut totals = vec![0.0f64; configs.len()];
    let (iterations, _) = replay_ktruss_mode(g, k, support, |o| match o {
        PassObservation::Full(full) => accumulate(configs, &mut totals, full),
        PassObservation::Frontier(front) => accumulate_frontier(configs, &mut totals, front),
    });
    finish(g, configs, totals, iterations)
}

/// Simulate the K_max discovery run (total time across all k levels —
/// the paper's K=K_max experiment). Returns (kmax, results).
pub fn simulate_kmax(g: &Csr, configs: &[SimConfig]) -> (u32, Vec<SimResult>) {
    let mut totals = vec![0.0f64; configs.len()];
    let (kmax, iterations) = replay_kmax(g, |_, o| accumulate(configs, &mut totals, o));
    (kmax, finish(g, configs, totals, iterations))
}

fn finish(g: &Csr, configs: &[SimConfig], totals: Vec<f64>, iterations: usize) -> Vec<SimResult> {
    configs
        .iter()
        .zip(totals)
        .map(|(cfg, seconds)| SimResult {
            label: cfg.label.clone(),
            seconds,
            iterations,
            me_per_s: me_per_s(g.nnz(), seconds * 1e3),
        })
        .collect()
}

/// The paper's Table-I configuration set: CPU 48T coarse/fine + GPU
/// coarse/fine.
pub fn table1_configs() -> Vec<SimConfig> {
    vec![
        SimConfig::cpu(48, Mode::Coarse),
        SimConfig::cpu(48, Mode::Fine),
        SimConfig::gpu(Mode::Coarse),
        SimConfig::gpu(Mode::Fine),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hub_graph() -> Csr {
        crate::gen::rmat::rmat(
            3000,
            15_000,
            crate::gen::rmat::RmatParams::autonomous_system(),
            &mut crate::util::Rng::new(7),
        )
    }

    #[test]
    fn table1_shape_on_hub_graph() {
        let g = hub_graph();
        let res = simulate_ktruss(&g, 3, &table1_configs());
        assert_eq!(res.len(), 4);
        let by = |l: &str| res.iter().find(|r| r.label.contains(l)).unwrap().seconds;
        let (cpu_c, cpu_f) = (by("CPU-C"), by("CPU-F"));
        let (gpu_c, gpu_f) = (by("GPU-C"), by("GPU-F"));
        // the paper's headline shape
        assert!(cpu_f < cpu_c, "CPU fine should win: {cpu_f} vs {cpu_c}");
        assert!(gpu_f < gpu_c, "GPU fine should win: {gpu_f} vs {gpu_c}");
        let gpu_speedup = gpu_c / gpu_f;
        let cpu_speedup = cpu_c / cpu_f;
        assert!(
            gpu_speedup > cpu_speedup,
            "GPU gain ({gpu_speedup}) must exceed CPU gain ({cpu_speedup})"
        );
    }

    #[test]
    fn all_results_positive_and_iterations_agree() {
        let g = hub_graph();
        let res = simulate_ktruss(&g, 3, &table1_configs());
        let iters = res[0].iterations;
        for r in &res {
            assert!(r.seconds > 0.0);
            assert!(r.me_per_s > 0.0);
            assert_eq!(r.iterations, iters);
        }
    }

    #[test]
    fn kmax_sim_runs() {
        let g = crate::gen::community::communities(300, 1500, 15, &mut crate::util::Rng::new(2));
        let (kmax, res) = simulate_kmax(&g, &table1_configs());
        assert!(kmax >= 3);
        assert!(res.iter().all(|r| r.seconds > 0.0));
        // kmax run does at least as many iterations as fixed k=3
        let k3 = simulate_ktruss(&g, 3, &table1_configs());
        assert!(res[0].iterations >= k3[0].iterations);
    }

    #[test]
    fn gpu_schedule_grid_shapes() {
        let g = hub_graph();
        let cfgs = gpu_schedule_grid(64);
        assert_eq!(cfgs.len(), 9);
        let res = simulate_ktruss(&g, 3, &cfgs);
        assert_eq!(res.len(), 9);
        // per granularity (chunks of 3: static, workaware, stealing):
        // the work-aware schedules stay within the provable sandwich of
        // the static makespan and never blow past it
        for chunk in res.chunks(3) {
            let stat = chunk[0].seconds;
            for r in &chunk[1..] {
                assert!(r.seconds > 0.0, "{}", r.label);
                assert!(
                    r.seconds <= stat * 2.0 + 1e-9,
                    "{}: {} vs static {}",
                    r.label,
                    r.seconds,
                    stat
                );
            }
        }
        // finer granularity beats coarse on the hub graph at every
        // schedule (the schedule alone cannot split the mega-row)
        for si in 0..3 {
            let coarse = res[si].seconds;
            let fine = res[3 + si].seconds;
            let seg = res[6 + si].seconds;
            assert!(fine < coarse, "{}: fine {fine} vs coarse {coarse}", res[si].label);
            assert!(seg < coarse, "{}: segment {seg} vs coarse {coarse}", res[si].label);
        }
        // labels carry the grid coordinates
        assert!(res[0].label == "GPU-C");
        assert!(res[4].label.contains("workaware"), "{}", res[4].label);
        assert!(res[6].label.contains("S64"), "{}", res[6].label);
    }

    #[test]
    fn incremental_sim_mode_shapes() {
        let g = hub_graph();
        let cfgs = table1_configs();
        // Full mode reproduces the classic replay exactly
        let full = simulate_ktruss(&g, 4, &cfgs);
        let full2 = simulate_ktruss_mode(&g, 4, &cfgs, SupportMode::Full);
        for (a, b) in full.iter().zip(full2.iter()) {
            assert!((a.seconds - b.seconds).abs() < 1e-12, "{}", a.label);
            assert_eq!(a.iterations, b.iterations);
        }
        // the incremental driver converges in the same iteration count
        let inc = simulate_ktruss_mode(&g, 4, &cfgs, SupportMode::Incremental);
        assert_eq!(inc[0].iterations, full[0].iterations);
        assert!(inc.iter().all(|r| r.seconds > 0.0));
        // when the real driver's step reduction is substantial, the
        // priced estimates must reflect it (gate on the measured steps
        // so the assertion cannot flake on a shallow cascade)
        let d_full =
            crate::algo::ktruss::ktruss_mode(&g, 4, Mode::Fine, SupportMode::Full);
        let d_inc =
            crate::algo::ktruss::ktruss_mode(&g, 4, Mode::Fine, SupportMode::Incremental);
        if d_inc.total_support_steps() * 3 <= d_full.total_support_steps() {
            let f_cpu = full.iter().find(|r| r.label == "CPU-F-48t").unwrap();
            let i_cpu = inc.iter().find(|r| r.label == "CPU-F-48t").unwrap();
            assert!(
                i_cpu.seconds < f_cpu.seconds,
                "incremental {} vs full {}",
                i_cpu.seconds,
                f_cpu.seconds
            );
        }
        let auto = simulate_ktruss_mode(&g, 4, &cfgs, SupportMode::Auto);
        assert_eq!(auto[0].iterations, full[0].iterations);
        assert!(auto.iter().all(|r| r.seconds > 0.0));
    }

    #[test]
    fn thread_sweep_speedup_profile() {
        // fig-2 style: fine/coarse ratio per thread count is finite and
        // positive everywhere
        let g = hub_graph();
        for t in [1usize, 8, 48] {
            let cfgs = vec![SimConfig::cpu(t, Mode::Coarse), SimConfig::cpu(t, Mode::Fine)];
            let res = simulate_ktruss(&g, 3, &cfgs);
            let ratio = res[0].seconds / res[1].seconds;
            assert!(ratio.is_finite() && ratio > 0.0);
        }
    }
}
