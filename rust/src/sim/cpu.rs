//! CPU multicore timing model.
//!
//! A support kernel is a parallel-for over tasks (rows for coarse,
//! slots for fine). Given the exact per-task costs from the trace, the
//! model computes the makespan under the chosen schedule:
//!
//! * `Static` — contiguous equal-count chunks, one per thread (what
//!   Kokkos' RangePolicy does on the OpenMP backend, and what the paper
//!   ran). Makespan = max chunk cost.
//! * `Dynamic {chunk}` — workers pull fixed-size chunks from a queue;
//!   simulated with an earliest-finish-time heap. Used by the
//!   scheduling ablation.
//!
//! The kernel time is `max(makespan, bandwidth bound) + fork/join`.

use super::machine::CpuMachine;
use crate::algo::support::Granularity;
use crate::cost::trace::SupportTrace;
use crate::par::{balance, Schedule};
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Per-task cost in nanoseconds for the support kernel: shared base
/// steps from [`balance::Costs::from_trace_rows`] (the same derivation
/// the GPU model reads, so the two models cannot drift) plus this
/// model's per-task overheads. `col` is the pass-time column array —
/// only the hybrid split reads it, to mirror the bitmap representation
/// selection ([`balance::hybrid_trace_pieces`]).
fn task_costs_ns(
    m: &CpuMachine,
    trace: &SupportTrace,
    row_ptr: &[u32],
    col: &[u32],
    gran: Granularity,
) -> Vec<f64> {
    // hybrid splits into two differently-priced task kinds: merge
    // segments at the segment overhead, bitmap probe chunks at the
    // cheaper branch-free probe overhead
    if let Granularity::Hybrid { len } = gran {
        let (merge, probe) =
            balance::hybrid_trace_pieces(&trace.fine_steps, row_ptr, col, &trace.live_per_row, len);
        return merge
            .iter()
            .map(|&st| m.segment_task_ns() + st as f64 * m.step_ns)
            .chain(probe.iter().map(|&st| m.bitmap_task_ns() + st as f64 * m.step_ns))
            .collect();
    }
    let base = balance::Costs::from_trace_rows(&trace.fine_steps, row_ptr, gran);
    match gran {
        Granularity::Coarse => base
            .per_task
            .iter()
            .enumerate()
            .map(|(i, &steps)| {
                let live = trace.live_per_row[i] as f64;
                m.coarse_task_ns + live * m.entry_ns + steps as f64 * m.step_ns
            })
            .collect(),
        Granularity::Hybrid { .. } => unreachable!("handled above"),
        Granularity::Fine => base
            .per_task
            .iter()
            .map(|&st| m.fine_task_ns + st as f64 * m.step_ns)
            .collect(),
        Granularity::Segment { .. } => base
            .per_task
            .iter()
            .map(|&st| m.segment_task_ns() + st as f64 * m.step_ns)
            .collect(),
    }
}

/// Makespan (ns) of `costs` under `schedule` on `threads` workers.
pub fn makespan_ns(costs: &[f64], threads: usize, schedule: Schedule) -> f64 {
    if costs.is_empty() {
        return 0.0;
    }
    let threads = threads.max(1);
    match schedule {
        Schedule::Static => {
            let n = costs.len();
            let mut worst = 0.0f64;
            for w in 0..threads {
                let lo = n * w / threads;
                let hi = n * (w + 1) / threads;
                let sum: f64 = costs[lo..hi].iter().sum();
                worst = worst.max(sum);
            }
            worst
        }
        Schedule::Dynamic { chunk } => {
            let chunk = chunk.max(1);
            // earliest-finish-time heap over worker clocks
            let mut heap: BinaryHeap<Reverse<u64>> = (0..threads).map(|_| Reverse(0u64)).collect();
            // fixed-point ns to keep the heap ordered (f64 is not Ord)
            let mut makespan = 0u64;
            for c in costs.chunks(chunk) {
                let cost: f64 = c.iter().sum();
                let Reverse(t) = heap.pop().unwrap();
                let done = t + (cost * 16.0) as u64; // 1/16 ns resolution
                makespan = makespan.max(done);
                heap.push(Reverse(done));
            }
            makespan as f64 / 16.0
        }
        Schedule::WorkAware => {
            // scan-binned equal-work contiguous chunks, one per thread.
            // Same binner the pool runs, but fed the *exact* traced
            // costs rather than the pool's static upper-bound estimates
            // — i.e. an idealized (best-case) work-aware makespan.
            let fixed: Vec<u64> = costs.iter().map(|&c| (c * 16.0).round() as u64 + 1).collect();
            let bins = crate::par::balance::scan_bins(&fixed, threads);
            bins.iter()
                .map(|&(lo, hi)| costs[lo..hi].iter().sum::<f64>())
                .fold(0.0, f64::max)
        }
        Schedule::Stealing => {
            // idealized stealing ≈ per-task self-scheduling: greedy
            // earliest-finish assignment (what the deques converge to
            // once steal granularity is fine enough)
            let mut heap: BinaryHeap<Reverse<u64>> = (0..threads).map(|_| Reverse(0u64)).collect();
            let mut makespan = 0u64;
            for &c in costs {
                let Reverse(t) = heap.pop().unwrap();
                let done = t + (c * 16.0).round() as u64;
                makespan = makespan.max(done);
                heap.push(Reverse(done));
            }
            makespan as f64 / 16.0
        }
    }
}

/// Seconds for one support pass at any granularity under `schedule`.
/// `col` is the pass-time column array (0 = terminator) the hybrid
/// split reads to decide which partner rows are bitmap-encoded.
pub fn support_pass_s(
    m: &CpuMachine,
    trace: &SupportTrace,
    row_ptr: &[u32],
    col: &[u32],
    gran: Granularity,
    schedule: Schedule,
) -> f64 {
    let costs = task_costs_ns(m, trace, row_ptr, col, gran);
    let compute_ns = makespan_ns(&costs, m.threads, schedule);
    // streaming bound: every step touches ~8B of column data, every task
    // ~24B of pointers/support
    let bytes = trace.total_steps as f64 * 8.0 + costs.len() as f64 * 24.0;
    let bw_ns = bytes / m.mem_bw_gbs; // GB/s == B/ns
    compute_ns.max(bw_ns) / 1e9 + m.fork_join_us / 1e6
}

/// Seconds for one prune pass (parallel compaction over rows; near
/// perfectly balanced, bandwidth-bound).
pub fn prune_pass_s(m: &CpuMachine, slots: usize) -> f64 {
    let per_thread = slots as f64 / m.threads as f64 * m.prune_slot_ns;
    let bw_ns = slots as f64 * 8.0 / m.mem_bw_gbs;
    per_thread.max(bw_ns) / 1e9 + m.fork_join_us / 1e6
}

/// Seconds for one **incremental frontier pass**
/// ([`crate::algo::incremental`]): the task set is the pruned-edge
/// frontier (exact per-task steps from the replay tracer), regrouped to
/// `gran` through the shared [`balance::Costs::from_frontier`]
/// derivation and scheduled like any other pass — a frontier-sized
/// kernel launch instead of a whole-graph one.
pub fn frontier_pass_s(
    m: &CpuMachine,
    task_steps: &[u32],
    task_rows: &[u32],
    gran: Granularity,
    schedule: Schedule,
) -> f64 {
    let base = balance::Costs::from_frontier(task_steps, task_rows, gran);
    let overhead = match gran {
        Granularity::Coarse => m.coarse_task_ns,
        Granularity::Fine => m.fine_task_ns,
        Granularity::Segment { .. } => m.segment_task_ns(),
        // frontier decrements are merge-walks regardless of the support
        // pass's representation: charge the segment overhead
        Granularity::Hybrid { .. } => m.segment_task_ns(),
    };
    let costs: Vec<f64> = base
        .per_task
        .iter()
        .map(|&st| overhead + st as f64 * m.step_ns)
        .collect();
    let compute_ns = makespan_ns(&costs, m.threads, schedule);
    let total_steps: f64 = task_steps.iter().map(|&x| x as f64).sum();
    // same streaming model as the full pass: ~8B of column data per
    // step, ~24B of pointers/support per task
    let bytes = total_steps * 8.0 + costs.len() as f64 * 24.0;
    let bw_ns = bytes / m.mem_bw_gbs;
    compute_ns.max(bw_ns) / 1e9 + m.fork_join_us / 1e6
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::trace::trace_supports;
    use crate::graph::ZCsr;

    fn trace_of(g: &crate::graph::Csr) -> (ZCsr, SupportTrace) {
        let z = ZCsr::from_csr(g);
        let mut s = Vec::new();
        let t = trace_supports(&z, &mut s);
        (z, t)
    }

    #[test]
    fn makespan_static_vs_dynamic_on_skewed_costs() {
        // one huge task at the front, many small
        let mut costs = vec![1000.0];
        costs.extend(std::iter::repeat(1.0).take(999));
        let static_ms = makespan_ns(&costs, 4, Schedule::Static);
        let dyn_ms = makespan_ns(&costs, 4, Schedule::Dynamic { chunk: 8 });
        // static: first chunk gets the big task plus 249 small
        assert!(static_ms >= 1000.0);
        // dynamic: big chunk runs alone while others share the rest
        assert!(dyn_ms <= static_ms + 1.0);
        // both bounded below by critical path and above by total
        let total: f64 = costs.iter().sum();
        assert!(dyn_ms >= 1000.0 && dyn_ms <= total);
    }

    #[test]
    fn makespan_single_thread_is_total() {
        let costs = vec![3.0, 5.0, 2.0];
        assert!((makespan_ns(&costs, 1, Schedule::Static) - 10.0).abs() < 1e-9);
        assert!((makespan_ns(&costs, 1, Schedule::Dynamic { chunk: 2 }) - 10.0).abs() < 0.2);
        assert!((makespan_ns(&costs, 1, Schedule::WorkAware) - 10.0).abs() < 1e-9);
        assert!((makespan_ns(&costs, 1, Schedule::Stealing) - 10.0).abs() < 0.2);
    }

    #[test]
    fn workaware_and_stealing_bounded_on_skewed_costs() {
        // one huge task among many small: the imbalance the schedules fix
        let mut costs = vec![1000.0];
        costs.extend(std::iter::repeat(1.0).take(999));
        let total: f64 = costs.iter().sum();
        let static_ms = makespan_ns(&costs, 8, Schedule::Static);
        for sched in [Schedule::WorkAware, Schedule::Stealing] {
            let m = makespan_ns(&costs, 8, sched);
            // sandwich: critical path ≤ m ≤ total, and never beyond
            // 2× static (provable: ≤ total/threads + max ≤ 2·static)
            assert!(m >= 1000.0 - 1.0, "{sched:?}: {m}");
            assert!(m <= total + 1.0, "{sched:?}: {m}");
            assert!(m <= 2.0 * static_ms + 1.0, "{sched:?}: {m} vs static {static_ms}");
        }
    }

    #[test]
    fn more_threads_never_slower() {
        let g = crate::gen::rmat::rmat(
            500,
            4000,
            crate::gen::rmat::RmatParams::social(),
            &mut crate::util::Rng::new(2),
        );
        let (z, tr) = trace_of(&g);
        for gran in [
            Granularity::Coarse,
            Granularity::Fine,
            Granularity::Segment { len: 64 },
        ] {
            let mut prev = f64::INFINITY;
            for t in [1usize, 2, 4, 8, 16, 48] {
                let m = CpuMachine::skylake_8160(t);
                let s = support_pass_s(&m, &tr, z.row_ptr(), z.col(), gran, Schedule::Static);
                assert!(s <= prev * 1.001, "gran={gran} t={t}: {s} > {prev}");
                prev = s;
            }
        }
    }

    #[test]
    fn fine_beats_coarse_on_skewed_graph_at_48t() {
        // hub-heavy graph → coarse static badly imbalanced
        let g = crate::gen::rmat::rmat(
            3000,
            20_000,
            crate::gen::rmat::RmatParams::autonomous_system(),
            &mut crate::util::Rng::new(4),
        );
        let (z, tr) = trace_of(&g);
        let m = CpuMachine::skylake_8160(48);
        let coarse =
            support_pass_s(&m, &tr, z.row_ptr(), z.col(), Granularity::Coarse, Schedule::Static);
        let fine =
            support_pass_s(&m, &tr, z.row_ptr(), z.col(), Granularity::Fine, Schedule::Static);
        assert!(fine < coarse, "fine {fine} vs coarse {coarse}");
    }

    #[test]
    fn road_graph_near_parity() {
        let g = crate::gen::grid::road(20_000, 28_000, 0.05, &mut crate::util::Rng::new(6));
        let (z, tr) = trace_of(&g);
        let m = CpuMachine::skylake_8160(48);
        let coarse =
            support_pass_s(&m, &tr, z.row_ptr(), z.col(), Granularity::Coarse, Schedule::Static);
        let fine =
            support_pass_s(&m, &tr, z.row_ptr(), z.col(), Granularity::Fine, Schedule::Static);
        let ratio = coarse / fine;
        assert!((0.5..2.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn hybrid_prices_probe_chunks_below_segment_merges() {
        // hub-heavy fixture: the hub row is bitmap-encoded, so slots
        // probing it become cheap uniform chunks instead of merge
        // segments — the replay price must reflect that, not charge
        // hybrid as if it were segment (the pre-PR behaviour)
        let g = crate::testkit::graphs::hub_divergence_comb(64, 256, 800);
        let (z, tr) = trace_of(&g);
        let m = CpuMachine::skylake_8160(1); // 1T: makespan = Σ costs, no bw tie
        let seg = support_pass_s(
            &m,
            &tr,
            z.row_ptr(),
            z.col(),
            Granularity::Segment { len: 32 },
            Schedule::Static,
        );
        let hyb = support_pass_s(
            &m,
            &tr,
            z.row_ptr(),
            z.col(),
            Granularity::Hybrid { len: 32 },
            Schedule::Static,
        );
        assert!(hyb < seg, "hybrid {hyb} should undercut segment {seg}");
    }

    #[test]
    fn prune_scales_with_slots() {
        let m = CpuMachine::skylake_8160(48);
        assert!(prune_pass_s(&m, 2_000_000) > prune_pass_s(&m, 1_000));
    }
}
