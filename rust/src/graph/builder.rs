//! Conversion from edge lists to the strictly upper-triangular CSR form
//! the Eager algorithms operate on, mirroring the paper's preprocessing
//! ("graphs were made upper-triangular before being used as inputs").

use super::coo::EdgeList;
use super::csr::{Csr, Vid};

/// Build an upper-triangular CSR from an (arbitrary-orientation)
/// undirected edge list. Self-loops are dropped, duplicates collapsed,
/// each edge stored once as `(min, max)`.
pub fn from_edge_list(mut el: EdgeList) -> Csr {
    el.normalize();
    from_sorted_unique(el.n, &el.edges)
}

/// Build from edges already normalized (u < v, sorted, unique).
pub fn from_sorted_unique(n: usize, edges: &[(Vid, Vid)]) -> Csr {
    let mut row_ptr = vec![0u32; n + 1];
    for &(u, _) in edges {
        row_ptr[u as usize + 1] += 1;
    }
    for i in 0..n {
        row_ptr[i + 1] += row_ptr[i];
    }
    let col_idx: Vec<Vid> = edges.iter().map(|&(_, v)| v).collect();
    Csr::from_parts(n, row_ptr, col_idx)
}

/// Relabel vertices by *degree-descending* order and rebuild. The paper's
/// inputs come pre-triangularized from GraphChallenge (which orders by
/// the natural SNAP ids); we expose relabeling as an ablation knob since
/// vertex order shifts the upper-triangular skew the paper discusses.
pub fn relabel_by_degree(g: &Csr) -> Csr {
    let deg = g.symmetric_degrees();
    let mut order: Vec<Vid> = (0..g.n() as Vid).collect();
    // Stable ordering: degree desc, id asc — deterministic.
    order.sort_by(|&a, &b| {
        deg[b as usize]
            .cmp(&deg[a as usize])
            .then(a.cmp(&b))
    });
    let mut new_id = vec![0 as Vid; g.n()];
    for (rank, &old) in order.iter().enumerate() {
        new_id[old as usize] = rank as Vid;
    }
    let mut el = EdgeList::with_capacity(g.n(), g.nnz());
    for (u, v) in g.edges() {
        el.push(new_id[u as usize], new_id[v as usize]);
    }
    from_edge_list(el)
}

/// Apply an arbitrary permutation `perm` (new_id[old_id]) and rebuild.
pub fn relabel(g: &Csr, perm: &[Vid]) -> Csr {
    assert_eq!(perm.len(), g.n());
    let mut el = EdgeList::with_capacity(g.n(), g.nnz());
    for (u, v) in g.edges() {
        el.push(perm[u as usize], perm[v as usize]);
    }
    from_edge_list(el)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_upper_triangular() {
        let mut el = EdgeList::new(4);
        // deliberately reversed orientations + duplicate
        el.push(1, 0);
        el.push(2, 0);
        el.push(0, 2);
        el.push(3, 2);
        el.push(2, 1);
        let g = from_edge_list(el);
        assert_eq!(g.nnz(), 4);
        assert_eq!(g.row(0), &[1, 2]);
        assert_eq!(g.row(1), &[2]);
        assert_eq!(g.row(2), &[3]);
        assert!(crate::graph::validate::check(&g).is_ok());
    }

    #[test]
    fn relabel_preserves_edge_count_and_structure() {
        let mut el = EdgeList::new(5);
        for (u, v) in [(0, 1), (0, 2), (1, 2), (2, 3), (3, 4)] {
            el.push(u, v);
        }
        let g = from_edge_list(el);
        let r = relabel_by_degree(&g);
        assert_eq!(r.nnz(), g.nnz());
        assert_eq!(r.n(), g.n());
        // vertex 2 has max degree (3) -> becomes id 0
        assert_eq!(r.degree(0), 3);
        assert!(crate::graph::validate::check(&r).is_ok());
    }

    #[test]
    fn relabel_identity_roundtrip() {
        let mut el = EdgeList::new(4);
        for (u, v) in [(0, 1), (1, 2), (2, 3)] {
            el.push(u, v);
        }
        let g = from_edge_list(el);
        let id: Vec<Vid> = (0..4).collect();
        assert_eq!(relabel(&g, &id), g);
    }
}
