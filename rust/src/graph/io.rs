//! Graph I/O: the SNAP/GraphChallenge TSV edge-list format the paper's
//! inputs ship in, plus a compact binary cache format so generated
//! replica graphs are built once and reloaded by benches.

use super::builder;
use super::coo::EdgeList;
use super::csr::{Csr, Vid};
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

/// Parse a SNAP-style edge list: one `u<TAB>v` (or whitespace) pair per
/// line, `#` comments ignored. Vertex ids are compacted to `0..n`.
pub fn read_edge_list<R: Read>(r: R) -> Result<Csr> {
    let reader = BufReader::new(r);
    let mut raw: Vec<(u64, u64)> = Vec::new();
    let mut max_id = 0u64;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line.context("read line")?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let (Some(a), Some(b)) = (it.next(), it.next()) else {
            bail!("line {}: expected two vertex ids, got {t:?}", lineno + 1);
        };
        let u: u64 = a.parse().with_context(|| format!("line {}: bad id {a:?}", lineno + 1))?;
        let v: u64 = b.parse().with_context(|| format!("line {}: bad id {b:?}", lineno + 1))?;
        max_id = max_id.max(u).max(v);
        raw.push((u, v));
    }
    // Compact ids: many SNAP graphs have sparse id spaces.
    let mut present = vec![false; (max_id + 1) as usize];
    for &(u, v) in &raw {
        present[u as usize] = true;
        present[v as usize] = true;
    }
    let mut remap = vec![0 as Vid; (max_id + 1) as usize];
    let mut n = 0usize;
    for (id, &p) in present.iter().enumerate() {
        if p {
            remap[id] = n as Vid;
            n += 1;
        }
    }
    let mut el = EdgeList::with_capacity(n, raw.len());
    for (u, v) in raw {
        el.push(remap[u as usize], remap[v as usize]);
    }
    Ok(builder::from_edge_list(el))
}

/// Read an edge-list file from disk.
pub fn read_edge_list_file(path: impl AsRef<Path>) -> Result<Csr> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("open {}", path.as_ref().display()))?;
    read_edge_list(f)
}

/// Write the upper-triangular edges as a TSV edge list.
pub fn write_edge_list<W: Write>(g: &Csr, w: W) -> Result<()> {
    let mut w = BufWriter::new(w);
    writeln!(w, "# ktruss upper-triangular edge list: n={} m={}", g.n(), g.nnz())?;
    for (u, v) in g.edges() {
        writeln!(w, "{u}\t{v}")?;
    }
    w.flush()?;
    Ok(())
}

const BIN_MAGIC: &[u8; 8] = b"KTRUSSG1";

/// Write the compact binary cache format (little-endian u32s).
pub fn write_binary<W: Write>(g: &Csr, w: W) -> Result<()> {
    let mut w = BufWriter::new(w);
    w.write_all(BIN_MAGIC)?;
    w.write_all(&(g.n() as u64).to_le_bytes())?;
    w.write_all(&(g.nnz() as u64).to_le_bytes())?;
    for &x in g.row_ptr() {
        w.write_all(&x.to_le_bytes())?;
    }
    for &x in g.col_idx() {
        w.write_all(&x.to_le_bytes())?;
    }
    w.flush()?;
    Ok(())
}

/// Read the binary cache format.
pub fn read_binary<R: Read>(r: R) -> Result<Csr> {
    let mut r = BufReader::new(r);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic).context("read magic")?;
    if &magic != BIN_MAGIC {
        bail!("not a ktruss binary graph (bad magic)");
    }
    let mut buf8 = [0u8; 8];
    r.read_exact(&mut buf8)?;
    let n = u64::from_le_bytes(buf8) as usize;
    r.read_exact(&mut buf8)?;
    let m = u64::from_le_bytes(buf8) as usize;
    let mut read_u32s = |count: usize| -> Result<Vec<u32>> {
        let mut bytes = vec![0u8; count * 4];
        r.read_exact(&mut bytes)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    };
    let row_ptr = read_u32s(n + 1)?;
    let col_idx = read_u32s(m)?;
    if row_ptr.last().copied().unwrap_or(1) as usize != m {
        bail!("corrupt binary graph: row_ptr end != nnz");
    }
    Ok(Csr::from_parts(n, row_ptr, col_idx))
}

/// Write binary cache to a path (creating parent dirs).
pub fn write_binary_file(g: &Csr, path: impl AsRef<Path>) -> Result<()> {
    if let Some(parent) = path.as_ref().parent() {
        std::fs::create_dir_all(parent)?;
    }
    write_binary(g, std::fs::File::create(path.as_ref())?)
}

/// Read binary cache from a path.
pub fn read_binary_file(path: impl AsRef<Path>) -> Result<Csr> {
    read_binary(std::fs::File::open(path.as_ref())?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::from_sorted_unique;

    #[test]
    fn parse_edge_list_with_comments_and_dups() {
        let text = "# SNAP header\n1 2\n2\t1\n2 3\n5 5\n3 5\n";
        let g = read_edge_list(text.as_bytes()).unwrap();
        // ids {1,2,3,5} compact to {0,1,2,3}; self-loop dropped, dup removed
        assert_eq!(g.n(), 4);
        assert_eq!(g.nnz(), 3);
        assert!(g.has_edge(0, 1) && g.has_edge(1, 2) && g.has_edge(2, 3));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(read_edge_list("1 two\n".as_bytes()).is_err());
        assert!(read_edge_list("1\n".as_bytes()).is_err());
    }

    #[test]
    fn tsv_roundtrip() {
        let g = from_sorted_unique(5, &[(0, 1), (0, 4), (1, 2), (2, 3), (3, 4)]);
        let mut buf = Vec::new();
        write_edge_list(&g, &mut buf).unwrap();
        let g2 = read_edge_list(buf.as_slice()).unwrap();
        assert_eq!(g, g2);
    }

    #[test]
    fn binary_roundtrip() {
        let g = from_sorted_unique(6, &[(0, 2), (0, 5), (1, 3), (2, 4), (3, 5), (4, 5)]);
        let mut buf = Vec::new();
        write_binary(&g, &mut buf).unwrap();
        let g2 = read_binary(buf.as_slice()).unwrap();
        assert_eq!(g, g2);
    }

    #[test]
    fn binary_rejects_bad_magic() {
        assert!(read_binary(&b"NOTMAGIC\0\0\0\0"[..]).is_err());
    }
}
