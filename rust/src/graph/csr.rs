//! Canonical compressed-sparse-row (CSR) storage for the strictly
//! upper-triangular adjacency matrix of an undirected, unweighted graph.
//!
//! Invariants (checked by [`crate::graph::validate`]):
//! * `row_ptr.len() == n + 1`, monotone non-decreasing,
//!   `row_ptr[0] == 0`, `row_ptr[n] == col_idx.len()`.
//! * every stored entry is strictly upper-triangular: `col > row`.
//! * each row's column indices are sorted ascending with no duplicates.
//!
//! Because the matrix is *strictly* upper-triangular, every stored column
//! index is ≥ 1 — the value `0` never appears, which is what lets the
//! zero-terminated working representation ([`crate::graph::ZCsr`]) use `0`
//! as both row terminator and pruning tombstone (paper §III-D).

/// Vertex / column index type. `u32` covers every GraphChallenge graph in
/// the paper (largest: cit-Patents, 3.77M vertices, 16.5M edges).
pub type Vid = u32;

/// Strictly upper-triangular CSR adjacency matrix.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Csr {
    /// Number of vertices (rows/cols of the square matrix).
    n: usize,
    /// Row start offsets into `col_idx`; length `n + 1`.
    row_ptr: Vec<u32>,
    /// Column indices, sorted ascending within each row.
    col_idx: Vec<Vid>,
}

impl Csr {
    /// Construct from raw parts, asserting structural invariants in debug
    /// builds. Use [`crate::graph::builder`] to build from edge lists.
    pub fn from_parts(n: usize, row_ptr: Vec<u32>, col_idx: Vec<Vid>) -> Csr {
        debug_assert_eq!(row_ptr.len(), n + 1);
        debug_assert_eq!(*row_ptr.last().unwrap() as usize, col_idx.len());
        let g = Csr { n, row_ptr, col_idx };
        debug_assert!(crate::graph::validate::check(&g).is_ok());
        g
    }

    /// The empty graph on `n` vertices.
    pub fn empty(n: usize) -> Csr {
        Csr { n, row_ptr: vec![0; n + 1], col_idx: Vec::new() }
    }

    /// Number of vertices.
    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of stored entries == number of undirected edges.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.col_idx.len()
    }

    /// Row offsets; length `n + 1`.
    #[inline]
    pub fn row_ptr(&self) -> &[u32] {
        &self.row_ptr
    }

    /// Column indices, row-major, sorted within each row.
    #[inline]
    pub fn col_idx(&self) -> &[Vid] {
        &self.col_idx
    }

    /// The sorted out-neighborhood (upper-triangular part) of vertex `i`:
    /// the paper's `a₁₂ᵀ` for row partition `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[Vid] {
        &self.col_idx[self.row_ptr[i] as usize..self.row_ptr[i + 1] as usize]
    }

    /// Out-degree (upper-triangular) of vertex `i`.
    #[inline]
    pub fn degree(&self, i: usize) -> usize {
        (self.row_ptr[i + 1] - self.row_ptr[i]) as usize
    }

    /// True if edge `(u, v)` (with `u < v`) is present, via binary search.
    pub fn has_edge(&self, u: Vid, v: Vid) -> bool {
        let (u, v) = if u < v { (u, v) } else { (v, u) };
        if u == v {
            return false;
        }
        self.row(u as usize).binary_search(&v).is_ok()
    }

    /// Iterate all edges as `(u, v)` with `u < v`, row-major order.
    pub fn edges(&self) -> impl Iterator<Item = (Vid, Vid)> + '_ {
        (0..self.n).flat_map(move |u| self.row(u).iter().map(move |&v| (u as Vid, v)))
    }

    /// Full (symmetric) degree of every vertex — in-degree + out-degree of
    /// the triangular form. Used by generators and stats.
    pub fn symmetric_degrees(&self) -> Vec<u32> {
        let mut deg = vec![0u32; self.n];
        for (u, v) in self.edges() {
            deg[u as usize] += 1;
            deg[v as usize] += 1;
        }
        deg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 4-cycle plus chord 0-2: triangle {0,1,2} and {0,2,3}.
    pub fn diamond() -> Csr {
        // edges: (0,1) (0,2) (0,3) (1,2) (2,3)
        Csr::from_parts(4, vec![0, 3, 4, 5, 5], vec![1, 2, 3, 2, 3])
    }

    #[test]
    fn basic_accessors() {
        let g = diamond();
        assert_eq!(g.n(), 4);
        assert_eq!(g.nnz(), 5);
        assert_eq!(g.row(0), &[1, 2, 3]);
        assert_eq!(g.row(1), &[2]);
        assert_eq!(g.row(3), &[] as &[Vid]);
        assert_eq!(g.degree(0), 3);
        assert_eq!(g.degree(3), 0);
    }

    #[test]
    fn has_edge_symmetric_lookup() {
        let g = diamond();
        assert!(g.has_edge(0, 1));
        assert!(g.has_edge(1, 0)); // normalized internally
        assert!(g.has_edge(2, 3));
        assert!(!g.has_edge(1, 3));
        assert!(!g.has_edge(2, 2));
    }

    #[test]
    fn edges_iterator_row_major() {
        let g = diamond();
        let es: Vec<(Vid, Vid)> = g.edges().collect();
        assert_eq!(es, vec![(0, 1), (0, 2), (0, 3), (1, 2), (2, 3)]);
    }

    #[test]
    fn symmetric_degrees_sum_to_2m() {
        let g = diamond();
        let deg = g.symmetric_degrees();
        assert_eq!(deg, vec![3, 2, 3, 2]);
        assert_eq!(deg.iter().sum::<u32>() as usize, 2 * g.nnz());
    }

    #[test]
    fn empty_graph() {
        let g = Csr::empty(5);
        assert_eq!(g.n(), 5);
        assert_eq!(g.nnz(), 0);
        assert_eq!(g.edges().count(), 0);
    }
}
