//! Graph substrate: strictly upper-triangular CSR adjacency matrices,
//! the zero-terminated working form used by the Eager K-truss kernels
//! (paper §III-D), builders, I/O and validation.

pub mod builder;
pub mod coo;
pub mod csr;
pub mod io;
pub mod stats;
pub mod validate;
pub mod zeroterm;

pub use coo::EdgeList;
pub use csr::{Csr, Vid};
pub use zeroterm::ZCsr;
