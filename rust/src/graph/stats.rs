//! Graph statistics used for (a) checking that synthetic SNAP replicas
//! land in the right structural family and (b) the imbalance analysis
//! that motivates the paper (§III-A): the distribution of per-row work
//! is what coarse-grained parallelism is exposed to.

use super::csr::Csr;
use crate::util::stats::{cv, Summary};

/// Degree / structure profile of a graph.
#[derive(Debug, Clone)]
pub struct GraphStats {
    /// Vertices.
    pub n: usize,
    /// Undirected edges.
    pub edges: usize,
    /// Largest symmetric degree.
    pub max_sym_degree: u32,
    /// Mean symmetric degree.
    pub mean_sym_degree: f64,
    /// Coefficient of variation of the symmetric degree distribution —
    /// the skew proxy (power-law graphs ≫ 1, roadNet ≈ 0.2).
    pub degree_cv: f64,
    /// Max out-degree of the upper-triangular form (the largest coarse
    /// task's neighborhood length).
    pub max_tri_degree: u32,
    /// Summary of the upper-triangular row lengths (coarse task sizes,
    /// first-order proxy; exact work is measured by `cost::trace`).
    pub row_len: Summary,
}

/// Compute the profile.
pub fn stats(g: &Csr) -> GraphStats {
    let sym = g.symmetric_degrees();
    let sym_f: Vec<f64> = sym.iter().map(|&d| d as f64).collect();
    let rows: Vec<f64> = (0..g.n()).map(|i| g.degree(i) as f64).collect();
    GraphStats {
        n: g.n(),
        edges: g.nnz(),
        max_sym_degree: sym.iter().copied().max().unwrap_or(0),
        mean_sym_degree: if g.n() == 0 { 0.0 } else { 2.0 * g.nnz() as f64 / g.n() as f64 },
        degree_cv: cv(&sym_f).unwrap_or(0.0),
        max_tri_degree: (0..g.n()).map(|i| g.degree(i) as u32).max().unwrap_or(0),
        row_len: Summary::of(&rows).unwrap_or(Summary {
            n: 0,
            min: 0.0,
            max: 0.0,
            mean: 0.0,
            stddev: 0.0,
            p50: 0.0,
            p95: 0.0,
            p99: 0.0,
        }),
    }
}

/// Histogram of symmetric degrees in log₂ buckets: `counts[b]` counts
/// vertices with degree in `[2^b, 2^(b+1))` (bucket 0 holds degree 0–1).
pub fn degree_histogram(g: &Csr) -> Vec<usize> {
    let sym = g.symmetric_degrees();
    let max_b = sym
        .iter()
        .map(|&d| 64 - u64::from(d.max(1)).leading_zeros() as usize)
        .max()
        .unwrap_or(1);
    let mut counts = vec![0usize; max_b];
    for &d in &sym {
        let b = (64 - u64::from(d.max(1)).leading_zeros() as usize) - 1;
        counts[b] += 1;
    }
    counts
}

impl std::fmt::Display for GraphStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} m={} deg(mean={:.2} max={} cv={:.2}) row(max={} p99={:.0})",
            self.n,
            self.edges,
            self.mean_sym_degree,
            self.max_sym_degree,
            self.degree_cv,
            self.max_tri_degree,
            self.row_len.p99
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::from_sorted_unique;

    #[test]
    fn star_graph_is_skewed() {
        // star: vertex 0 connected to all others
        let edges: Vec<(u32, u32)> = (1..100).map(|v| (0u32, v)).collect();
        let g = from_sorted_unique(100, &edges);
        let s = stats(&g);
        assert_eq!(s.max_sym_degree, 99);
        assert!(s.degree_cv > 3.0, "cv={}", s.degree_cv);
    }

    #[test]
    fn path_graph_is_uniform() {
        let edges: Vec<(u32, u32)> = (0..99).map(|v| (v, v + 1)).collect();
        let g = from_sorted_unique(100, &edges);
        let s = stats(&g);
        assert_eq!(s.max_sym_degree, 2);
        assert!(s.degree_cv < 0.2, "cv={}", s.degree_cv);
    }

    #[test]
    fn histogram_buckets() {
        let edges: Vec<(u32, u32)> = (1..9).map(|v| (0u32, v)).collect();
        let g = from_sorted_unique(9, &edges);
        let h = degree_histogram(&g);
        // 8 leaves with degree 1 (bucket 0), hub with degree 8 (bucket 3)
        assert_eq!(h[0], 8);
        assert_eq!(*h.last().unwrap(), 1);
    }
}
