//! Zero-terminated CSR — the paper's working representation (§III-D).
//!
//! Each row of the strictly upper-triangular CSR is given one extra slot
//! holding `0`. Because every real column index is ≥ 1 (strict upper
//! triangularity), `0` doubles as:
//!
//! * the **row terminator**: intersection loops walk "the rest of the
//!   row" without carrying an explicit bound, and
//! * the **pruning tombstone**: `pruneEdges` compacts surviving entries
//!   to the front of the row and zero-fills the tail, so a subsequent
//!   pass terminates early exactly where the live row ends.
//!
//! The support array `S` is stored parallel to `col`, one counter per
//! slot (terminator slots are dead weight — the cost the paper calls
//! "minor", and which the `ablations` bench quantifies).

use super::csr::{Csr, Vid};

/// Zero-terminated upper-triangular CSR.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ZCsr {
    n: usize,
    /// Row spans over `col`; length `n + 1`. Row `i` occupies
    /// `row_ptr[i] .. row_ptr[i+1]`, and `col[row_ptr[i+1] - 1]` is the
    /// terminator slot (always part of the row).
    row_ptr: Vec<u32>,
    /// Column indices (≥ 1) followed by zero fill; one extra slot per row.
    col: Vec<Vid>,
    /// Edge count of the *original* graph (the ME/s denominator — the
    /// paper normalizes by input edges, not surviving edges).
    initial_edges: usize,
}

impl ZCsr {
    /// Build the zero-terminated working copy from a canonical CSR.
    pub fn from_csr(g: &Csr) -> ZCsr {
        let n = g.n();
        let mut row_ptr = Vec::with_capacity(n + 1);
        let mut col = Vec::with_capacity(g.nnz() + n);
        row_ptr.push(0u32);
        for i in 0..n {
            col.extend_from_slice(g.row(i));
            col.push(0); // terminator
            row_ptr.push(col.len() as u32);
        }
        ZCsr { n, row_ptr, col, initial_edges: g.nnz() }
    }

    /// Vertex count.
    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Total slots in `col` (live + tombstones + terminators).
    #[inline]
    pub fn slots(&self) -> usize {
        self.col.len()
    }

    /// Edge count of the original input graph.
    #[inline]
    pub fn initial_edges(&self) -> usize {
        self.initial_edges
    }

    /// Row spans over `col`; length `n + 1`.
    #[inline]
    pub fn row_ptr(&self) -> &[u32] {
        &self.row_ptr
    }

    /// The zero-terminated column array.
    #[inline]
    pub fn col(&self) -> &[Vid] {
        &self.col
    }

    /// Mutable column array (prune compaction writes through this).
    #[inline]
    pub fn col_mut(&mut self) -> &mut [Vid] {
        &mut self.col
    }

    /// The full row span including terminator/tombstone slots.
    #[inline]
    pub fn row_span(&self, i: usize) -> (usize, usize) {
        (self.row_ptr[i] as usize, self.row_ptr[i + 1] as usize)
    }

    /// Slice of the full row (live entries, then zero fill).
    #[inline]
    pub fn row_raw(&self, i: usize) -> &[Vid] {
        &self.col[self.row_ptr[i] as usize..self.row_ptr[i + 1] as usize]
    }

    /// Live entries of row `i` (leading nonzeros — rows are kept
    /// compacted by `prune`).
    pub fn row_live(&self, i: usize) -> &[Vid] {
        let raw = self.row_raw(i);
        let end = raw.iter().position(|&c| c == 0).unwrap_or(raw.len());
        &raw[..end]
    }

    /// Number of live (nonzero) entries across all rows.
    pub fn live_edges(&self) -> usize {
        self.col.iter().filter(|&&c| c != 0).count()
    }

    /// Map a flat slot index to its row via binary search on `row_ptr`
    /// — how the fine-grained flat `RangePolicy` recovers `i` from the
    /// 1-D task index (paper Listing 1). `O(log n)`.
    #[inline]
    pub fn row_of(&self, p: usize) -> usize {
        debug_assert!(p < self.col.len());
        // partition_point returns the first row whose start is > p; -1.
        self.row_ptr.partition_point(|&s| s as usize <= p) - 1
    }

    /// `row_of` with a monotone hint (the previous task's row). Falls
    /// back to binary search on a miss. The §Perf pass measures this
    /// against plain binary search.
    #[inline]
    pub fn row_of_hinted(&self, p: usize, hint: usize) -> usize {
        if hint < self.n {
            let (s, e) = self.row_span(hint);
            if p >= s && p < e {
                return hint;
            }
            if p >= e && hint + 1 < self.n {
                let (s2, e2) = self.row_span(hint + 1);
                if p >= s2 && p < e2 {
                    return hint + 1;
                }
            }
        }
        self.row_of(p)
    }

    /// Extract the live entries back into a canonical CSR (for
    /// validation and for reporting the surviving truss subgraph).
    pub fn to_csr(&self) -> Csr {
        let mut row_ptr = Vec::with_capacity(self.n + 1);
        let mut col_idx = Vec::with_capacity(self.live_edges());
        row_ptr.push(0u32);
        for i in 0..self.n {
            col_idx.extend_from_slice(self.row_live(i));
            row_ptr.push(col_idx.len() as u32);
        }
        Csr::from_parts(self.n, row_ptr, col_idx)
    }

    /// Reset the working copy back to the given canonical CSR contents
    /// (reusing allocations). Row *capacities* are rebuilt to match `g`.
    pub fn reset_from(&mut self, g: &Csr) {
        assert_eq!(g.n(), self.n);
        self.col.clear();
        self.row_ptr.clear();
        self.row_ptr.push(0);
        for i in 0..self.n {
            self.col.extend_from_slice(g.row(i));
            self.col.push(0);
            self.row_ptr.push(self.col.len() as u32);
        }
        self.initial_edges = g.nnz();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::from_sorted_unique;

    fn diamond() -> Csr {
        from_sorted_unique(4, &[(0, 1), (0, 2), (0, 3), (1, 2), (2, 3)])
    }

    #[test]
    fn terminators_present() {
        let z = ZCsr::from_csr(&diamond());
        assert_eq!(z.slots(), 5 + 4);
        assert_eq!(z.row_raw(0), &[1, 2, 3, 0]);
        assert_eq!(z.row_raw(1), &[2, 0]);
        assert_eq!(z.row_raw(3), &[0]);
        assert_eq!(z.live_edges(), 5);
        assert_eq!(z.initial_edges(), 5);
    }

    #[test]
    fn row_live_stops_at_terminator() {
        let mut z = ZCsr::from_csr(&diamond());
        assert_eq!(z.row_live(0), &[1, 2, 3]);
        // tombstone the middle entry by compaction semantics: [1,3,0,0]
        let (s, _) = z.row_span(0);
        z.col_mut()[s + 1] = 3;
        z.col_mut()[s + 2] = 0;
        assert_eq!(z.row_live(0), &[1, 3]);
        assert_eq!(z.live_edges(), 4);
    }

    #[test]
    fn row_of_flat_index() {
        let z = ZCsr::from_csr(&diamond());
        // layout: row0 [1,2,3,0] row1 [2,0] row2 [3,0] row3 [0]
        assert_eq!(z.row_of(0), 0);
        assert_eq!(z.row_of(3), 0); // terminator slot still belongs to row 0
        assert_eq!(z.row_of(4), 1);
        assert_eq!(z.row_of(5), 1);
        assert_eq!(z.row_of(6), 2);
        assert_eq!(z.row_of(8), 3);
    }

    #[test]
    fn row_of_hinted_agrees_with_search() {
        let z = ZCsr::from_csr(&diamond());
        let mut hint = 0usize;
        for p in 0..z.slots() {
            let r = z.row_of_hinted(p, hint);
            assert_eq!(r, z.row_of(p), "p={p}");
            hint = r;
        }
        // wildly wrong hints must still be correct
        for p in 0..z.slots() {
            assert_eq!(z.row_of_hinted(p, 3), z.row_of(p));
        }
    }

    #[test]
    fn to_csr_roundtrip() {
        let g = diamond();
        let z = ZCsr::from_csr(&g);
        assert_eq!(z.to_csr(), g);
    }

    #[test]
    fn reset_from_restores() {
        let g = diamond();
        let mut z = ZCsr::from_csr(&g);
        let (s, _) = z.row_span(0);
        z.col_mut()[s] = 0; // kill the whole row 0 prefix
        assert_ne!(z.to_csr(), g);
        z.reset_from(&g);
        assert_eq!(z.to_csr(), g);
    }
}
