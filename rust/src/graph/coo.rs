//! Edge-list (COO) intermediate representation used by generators and
//! file loaders before conversion to upper-triangular CSR.

use super::csr::Vid;

/// A mutable undirected edge list. Stores edges in arbitrary orientation;
/// normalization (u<v, dedup, self-loop removal) happens in the builder.
#[derive(Clone, Debug, Default)]
pub struct EdgeList {
    /// Vertex count (edge endpoints must stay below it).
    pub n: usize,
    /// The edges, arbitrary orientation, duplicates allowed.
    pub edges: Vec<(Vid, Vid)>,
}

impl EdgeList {
    /// An empty edge list over `n` vertices.
    pub fn new(n: usize) -> EdgeList {
        EdgeList { n, edges: Vec::new() }
    }

    /// An empty edge list with room for `m` edges.
    pub fn with_capacity(n: usize, m: usize) -> EdgeList {
        EdgeList { n, edges: Vec::with_capacity(m) }
    }

    /// Add an undirected edge; self-loops are silently dropped, duplicate
    /// edges are kept (deduped at build time).
    #[inline]
    pub fn push(&mut self, u: Vid, v: Vid) {
        debug_assert!((u as usize) < self.n && (v as usize) < self.n);
        if u != v {
            self.edges.push((u, v));
        }
    }

    /// Edges pushed so far (duplicates included).
    pub fn len(&self) -> usize {
        self.edges.len()
    }

    /// Whether no edges were pushed.
    pub fn is_empty(&self) -> bool {
        self.edges.is_empty()
    }

    /// Normalize in place: orient u<v, sort, dedup. Returns the number of
    /// duplicates removed (useful for generator diagnostics).
    pub fn normalize(&mut self) -> usize {
        for e in self.edges.iter_mut() {
            if e.0 > e.1 {
                *e = (e.1, e.0);
            }
        }
        self.edges.sort_unstable();
        let before = self.edges.len();
        self.edges.dedup();
        before - self.edges.len()
    }

    /// Grow the vertex count (ids already pushed must stay valid).
    pub fn grow_to(&mut self, n: usize) {
        debug_assert!(n >= self.n);
        self.n = n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_drops_self_loops() {
        let mut el = EdgeList::new(3);
        el.push(0, 0);
        el.push(0, 1);
        assert_eq!(el.len(), 1);
    }

    #[test]
    fn normalize_orients_sorts_dedups() {
        let mut el = EdgeList::new(4);
        el.push(2, 1);
        el.push(1, 2);
        el.push(3, 0);
        el.push(0, 3);
        el.push(0, 1);
        let dups = el.normalize();
        assert_eq!(dups, 2);
        assert_eq!(el.edges, vec![(0, 1), (0, 3), (1, 2)]);
    }
}
