//! Structural validation of graph representations. Every invariant the
//! algorithms rely on is checked here; generators, loaders and the
//! property tests all call through [`check`].

use super::csr::Csr;
use super::zeroterm::ZCsr;

/// Violations of the CSR invariants.
///
/// (`Display`/`Error` are hand-implemented — the offline crate set has
/// no `thiserror`.)
#[derive(Debug, PartialEq, Eq)]
pub enum GraphError {
    /// `row_ptr` has the wrong length for the vertex count.
    RowPtrLen {
        /// Observed length.
        got: usize,
        /// Required length (`n + 1`).
        want: usize,
    },
    /// `row_ptr` decreases at `row`.
    RowPtrMonotone {
        /// First offending row.
        row: usize,
    },
    /// `row_ptr[0]` is not 0 (holds the offending value).
    RowPtrStart(u32),
    /// `row_ptr[n]` does not match the entry count.
    RowPtrEnd {
        /// Observed final offset.
        got: usize,
        /// Required final offset (the entry count).
        want: usize,
    },
    /// An entry at or below the diagonal.
    NotUpperTriangular {
        /// Offending row.
        row: usize,
        /// Offending column value.
        col: u32,
    },
    /// A column index ≥ n.
    ColOutOfRange {
        /// Offending row.
        row: usize,
        /// Offending column value.
        col: u32,
        /// Vertex count bound.
        n: usize,
    },
    /// Row entries not strictly ascending.
    RowNotSorted {
        /// Offending row.
        row: usize,
        /// Position within the row.
        pos: usize,
    },
    /// The same column stored twice in one row.
    DuplicateCol {
        /// Offending row.
        row: usize,
        /// Duplicated column value.
        col: u32,
    },
    /// A zero-terminated row without its trailing `0` slot.
    MissingTerminator {
        /// Offending row.
        row: usize,
    },
    /// A live entry after a tombstone (violates prune compaction).
    EntryAfterTombstone {
        /// Offending row.
        row: usize,
        /// Position of the stray live entry.
        pos: usize,
    },
}

impl std::fmt::Display for GraphError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            GraphError::RowPtrLen { got, want } => {
                write!(f, "row_ptr length {got} != n+1 ({want})")
            }
            GraphError::RowPtrMonotone { row } => write!(f, "row_ptr not monotone at row {row}"),
            GraphError::RowPtrStart(v) => write!(f, "row_ptr[{v}] does not start at 0"),
            GraphError::RowPtrEnd { got, want } => {
                write!(f, "row_ptr end {got} != col_idx len {want}")
            }
            GraphError::NotUpperTriangular { row, col } => {
                write!(f, "entry ({row},{col}) not strictly upper-triangular")
            }
            GraphError::ColOutOfRange { row, col, n } => {
                write!(f, "column {col} out of range in row {row} (n={n})")
            }
            GraphError::RowNotSorted { row, pos } => {
                write!(f, "row {row} not sorted ascending at position {pos}")
            }
            GraphError::DuplicateCol { row, col } => {
                write!(f, "duplicate column {col} in row {row}")
            }
            GraphError::MissingTerminator { row } => {
                write!(f, "zero-terminated row {row} missing terminator")
            }
            GraphError::EntryAfterTombstone { row, pos } => {
                write!(f, "zero-terminated row {row} has live entry after tombstone at {pos}")
            }
        }
    }
}

impl std::error::Error for GraphError {}

/// Check all invariants of a canonical upper-triangular CSR.
pub fn check(g: &Csr) -> Result<(), GraphError> {
    let n = g.n();
    let rp = g.row_ptr();
    if rp.len() != n + 1 {
        return Err(GraphError::RowPtrLen { got: rp.len(), want: n + 1 });
    }
    if rp[0] != 0 {
        return Err(GraphError::RowPtrStart(rp[0]));
    }
    for i in 0..n {
        if rp[i + 1] < rp[i] {
            return Err(GraphError::RowPtrMonotone { row: i });
        }
    }
    if rp[n] as usize != g.col_idx().len() {
        return Err(GraphError::RowPtrEnd { got: rp[n] as usize, want: g.col_idx().len() });
    }
    for i in 0..n {
        let row = g.row(i);
        for (pos, &c) in row.iter().enumerate() {
            if c as usize <= i {
                return Err(GraphError::NotUpperTriangular { row: i, col: c });
            }
            if c as usize >= n {
                return Err(GraphError::ColOutOfRange { row: i, col: c, n });
            }
            if pos > 0 {
                if row[pos - 1] > c {
                    return Err(GraphError::RowNotSorted { row: i, pos });
                }
                if row[pos - 1] == c {
                    return Err(GraphError::DuplicateCol { row: i, col: c });
                }
            }
        }
    }
    Ok(())
}

/// Check the zero-terminated working form: every row ends with a
/// terminator slot, live entries are a sorted strictly-upper-triangular
/// prefix, and no live entry follows a tombstone (compaction invariant).
pub fn check_zcsr(z: &ZCsr) -> Result<(), GraphError> {
    let n = z.n();
    for i in 0..n {
        let raw = z.row_raw(i);
        match raw.last() {
            Some(0) => {}
            _ => return Err(GraphError::MissingTerminator { row: i }),
        }
        let mut seen_zero = false;
        let mut prev: u32 = 0;
        for (pos, &c) in raw.iter().enumerate() {
            if c == 0 {
                seen_zero = true;
                continue;
            }
            if seen_zero {
                return Err(GraphError::EntryAfterTombstone { row: i, pos });
            }
            if c as usize <= i {
                return Err(GraphError::NotUpperTriangular { row: i, col: c });
            }
            if c as usize >= n {
                return Err(GraphError::ColOutOfRange { row: i, col: c, n });
            }
            if prev != 0 {
                if prev > c {
                    return Err(GraphError::RowNotSorted { row: i, pos });
                }
                if prev == c {
                    return Err(GraphError::DuplicateCol { row: i, col: c });
                }
            }
            prev = c;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::from_sorted_unique;

    #[test]
    fn valid_graph_passes() {
        let g = from_sorted_unique(4, &[(0, 1), (0, 2), (1, 2), (2, 3)]);
        assert!(check(&g).is_ok());
        assert!(check_zcsr(&ZCsr::from_csr(&g)).is_ok());
    }

    #[test]
    fn zcsr_detects_entry_after_tombstone() {
        let g = from_sorted_unique(3, &[(0, 1), (0, 2)]);
        let mut z = ZCsr::from_csr(&g);
        let (s, _) = z.row_span(0);
        z.col_mut()[s] = 0; // tombstone before live entry [0,2,0]
        assert_eq!(
            check_zcsr(&z),
            Err(GraphError::EntryAfterTombstone { row: 0, pos: 1 })
        );
    }

    #[test]
    fn zcsr_detects_lower_triangular_entry() {
        let g = from_sorted_unique(3, &[(1, 2)]);
        let mut z = ZCsr::from_csr(&g);
        let (s, _) = z.row_span(1);
        z.col_mut()[s] = 1; // (1,1) self reference
        assert!(matches!(
            check_zcsr(&z),
            Err(GraphError::NotUpperTriangular { row: 1, col: 1 })
        ));
    }
}
