//! Structural validation of graph representations. Every invariant the
//! algorithms rely on is checked here; generators, loaders and the
//! property tests all call through [`check`].

use super::csr::Csr;
use super::zeroterm::ZCsr;
use thiserror::Error;

/// Violations of the CSR invariants.
#[derive(Debug, Error, PartialEq, Eq)]
pub enum GraphError {
    #[error("row_ptr length {got} != n+1 ({want})")]
    RowPtrLen { got: usize, want: usize },
    #[error("row_ptr not monotone at row {row}")]
    RowPtrMonotone { row: usize },
    #[error("row_ptr[{0}] does not start at 0")]
    RowPtrStart(u32),
    #[error("row_ptr end {got} != col_idx len {want}")]
    RowPtrEnd { got: usize, want: usize },
    #[error("entry ({row},{col}) not strictly upper-triangular")]
    NotUpperTriangular { row: usize, col: u32 },
    #[error("column {col} out of range in row {row} (n={n})")]
    ColOutOfRange { row: usize, col: u32, n: usize },
    #[error("row {row} not sorted ascending at position {pos}")]
    RowNotSorted { row: usize, pos: usize },
    #[error("duplicate column {col} in row {row}")]
    DuplicateCol { row: usize, col: u32 },
    #[error("zero-terminated row {row} missing terminator")]
    MissingTerminator { row: usize },
    #[error("zero-terminated row {row} has live entry after tombstone at {pos}")]
    EntryAfterTombstone { row: usize, pos: usize },
}

/// Check all invariants of a canonical upper-triangular CSR.
pub fn check(g: &Csr) -> Result<(), GraphError> {
    let n = g.n();
    let rp = g.row_ptr();
    if rp.len() != n + 1 {
        return Err(GraphError::RowPtrLen { got: rp.len(), want: n + 1 });
    }
    if rp[0] != 0 {
        return Err(GraphError::RowPtrStart(rp[0]));
    }
    for i in 0..n {
        if rp[i + 1] < rp[i] {
            return Err(GraphError::RowPtrMonotone { row: i });
        }
    }
    if rp[n] as usize != g.col_idx().len() {
        return Err(GraphError::RowPtrEnd { got: rp[n] as usize, want: g.col_idx().len() });
    }
    for i in 0..n {
        let row = g.row(i);
        for (pos, &c) in row.iter().enumerate() {
            if c as usize <= i {
                return Err(GraphError::NotUpperTriangular { row: i, col: c });
            }
            if c as usize >= n {
                return Err(GraphError::ColOutOfRange { row: i, col: c, n });
            }
            if pos > 0 {
                if row[pos - 1] > c {
                    return Err(GraphError::RowNotSorted { row: i, pos });
                }
                if row[pos - 1] == c {
                    return Err(GraphError::DuplicateCol { row: i, col: c });
                }
            }
        }
    }
    Ok(())
}

/// Check the zero-terminated working form: every row ends with a
/// terminator slot, live entries are a sorted strictly-upper-triangular
/// prefix, and no live entry follows a tombstone (compaction invariant).
pub fn check_zcsr(z: &ZCsr) -> Result<(), GraphError> {
    let n = z.n();
    for i in 0..n {
        let raw = z.row_raw(i);
        match raw.last() {
            Some(0) => {}
            _ => return Err(GraphError::MissingTerminator { row: i }),
        }
        let mut seen_zero = false;
        let mut prev: u32 = 0;
        for (pos, &c) in raw.iter().enumerate() {
            if c == 0 {
                seen_zero = true;
                continue;
            }
            if seen_zero {
                return Err(GraphError::EntryAfterTombstone { row: i, pos });
            }
            if c as usize <= i {
                return Err(GraphError::NotUpperTriangular { row: i, col: c });
            }
            if c as usize >= n {
                return Err(GraphError::ColOutOfRange { row: i, col: c, n });
            }
            if prev != 0 {
                if prev > c {
                    return Err(GraphError::RowNotSorted { row: i, pos });
                }
                if prev == c {
                    return Err(GraphError::DuplicateCol { row: i, col: c });
                }
            }
            prev = c;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::from_sorted_unique;

    #[test]
    fn valid_graph_passes() {
        let g = from_sorted_unique(4, &[(0, 1), (0, 2), (1, 2), (2, 3)]);
        assert!(check(&g).is_ok());
        assert!(check_zcsr(&ZCsr::from_csr(&g)).is_ok());
    }

    #[test]
    fn zcsr_detects_entry_after_tombstone() {
        let g = from_sorted_unique(3, &[(0, 1), (0, 2)]);
        let mut z = ZCsr::from_csr(&g);
        let (s, _) = z.row_span(0);
        z.col_mut()[s] = 0; // tombstone before live entry [0,2,0]
        assert_eq!(
            check_zcsr(&z),
            Err(GraphError::EntryAfterTombstone { row: 0, pos: 1 })
        );
    }

    #[test]
    fn zcsr_detects_lower_triangular_entry() {
        let g = from_sorted_unique(3, &[(1, 2)]);
        let mut z = ZCsr::from_csr(&g);
        let (s, _) = z.row_span(1);
        z.col_mut()[s] = 1; // (1,1) self reference
        assert!(matches!(
            check_zcsr(&z),
            Err(GraphError::NotUpperTriangular { row: 1, col: 1 })
        ));
    }
}
