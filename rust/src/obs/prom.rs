//! Prometheus-style text exposition of the serving counters and drift
//! gauges.
//!
//! Renders [`Metrics`](crate::coordinator::metrics::Metrics) (job
//! counters, the log₂ latency histogram as a cumulative
//! `_bucket{le=...}` series, per-shard gauges) plus
//! [`DriftTracker`](crate::obs::drift::DriftTracker) regime gauges in
//! the text format any Prometheus-compatible scraper parses. There is
//! no HTTP listener — the exposition is printed by the `metrics` CLI
//! snapshot and inside `bench serve` output, and tests parse it as
//! plain text.

use crate::coordinator::metrics::Metrics;
use crate::obs::drift::DriftTracker;
use std::sync::atomic::Ordering;

fn counter(out: &mut String, name: &str, help: &str, value: u64) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} counter\n{name} {value}\n"));
}

/// Render `metrics` (and, when given, `drift`) as Prometheus text
/// exposition.
pub fn render(metrics: &Metrics, drift: Option<&DriftTracker>) -> String {
    let mut out = String::new();
    counter(
        &mut out,
        "ktruss_jobs_submitted_total",
        "Jobs admitted",
        metrics.submitted.load(Ordering::Relaxed),
    );
    counter(
        &mut out,
        "ktruss_jobs_completed_total",
        "Jobs completed (ok or failed)",
        metrics.completed.load(Ordering::Relaxed),
    );
    counter(
        &mut out,
        "ktruss_jobs_failed_total",
        "Jobs completed with an error",
        metrics.failed.load(Ordering::Relaxed),
    );
    counter(
        &mut out,
        "ktruss_jobs_sparse_total",
        "Jobs the sparse CPU engine executed",
        metrics.sparse_jobs.load(Ordering::Relaxed),
    );
    counter(
        &mut out,
        "ktruss_jobs_dense_total",
        "Jobs the dense XLA engine executed",
        metrics.dense_jobs.load(Ordering::Relaxed),
    );
    // robustness counters: always emitted (a zero is a signal too —
    // the chaos smoke asserts their presence on fault-free runs)
    counter(
        &mut out,
        "ktruss_jobs_shed_total",
        "Jobs shed at admission (planned cost blew the deadline)",
        metrics.shed.load(Ordering::Relaxed),
    );
    counter(
        &mut out,
        "ktruss_jobs_degraded_total",
        "Jobs answered from a stale epoch at admission",
        metrics.degraded.load(Ordering::Relaxed),
    );
    counter(
        &mut out,
        "ktruss_jobs_cancelled_total",
        "Jobs cancelled at a pass boundary (deadline enforcement)",
        metrics.cancelled.load(Ordering::Relaxed),
    );
    counter(
        &mut out,
        "ktruss_jobs_quarantined_total",
        "Jobs refused by the poison-job registry",
        metrics.quarantined.load(Ordering::Relaxed),
    );
    counter(
        &mut out,
        "ktruss_job_retries_total",
        "Panic-retry requeues",
        metrics.retries.load(Ordering::Relaxed),
    );
    counter(
        &mut out,
        "ktruss_queue_rejected_total",
        "Submissions rejected by admission backpressure",
        metrics.queue_rejected.load(Ordering::Relaxed),
    );
    counter(
        &mut out,
        "ktruss_shard_respawns_total",
        "Worker-body respawns after a panic, all shards",
        metrics.respawns(),
    );

    // latency histogram: the log₂ buckets as a cumulative le-series
    out.push_str("# HELP ktruss_job_latency_us Job serve latency histogram (microseconds)\n");
    out.push_str("# TYPE ktruss_job_latency_us histogram\n");
    let mut cum = 0u64;
    for (floor_us, count) in metrics.latency_histogram() {
        cum += count;
        // a sample in the log₂ bucket with floor f lies in [f, 2f)
        out.push_str(&format!("ktruss_job_latency_us_bucket{{le=\"{}\"}} {cum}\n", floor_us * 2));
    }
    out.push_str(&format!("ktruss_job_latency_us_bucket{{le=\"+Inf\"}} {cum}\n"));
    out.push_str(&format!("ktruss_job_latency_us_count {cum}\n"));

    if !metrics.shards().is_empty() {
        out.push_str("# HELP ktruss_shard_jobs_total Jobs executed per shard\n");
        out.push_str("# TYPE ktruss_shard_jobs_total counter\n");
        for (i, s) in metrics.shards().iter().enumerate() {
            out.push_str(&format!(
                "ktruss_shard_jobs_total{{shard=\"{i}\"}} {}\n",
                s.jobs.load(Ordering::Relaxed)
            ));
        }
        out.push_str("# HELP ktruss_shard_stolen_total Jobs stolen from other shards\n");
        out.push_str("# TYPE ktruss_shard_stolen_total counter\n");
        for (i, s) in metrics.shards().iter().enumerate() {
            out.push_str(&format!(
                "ktruss_shard_stolen_total{{shard=\"{i}\"}} {}\n",
                s.stolen.load(Ordering::Relaxed)
            ));
        }
        out.push_str("# HELP ktruss_shard_deadline_miss_total Soft-deadline misses per shard\n");
        out.push_str("# TYPE ktruss_shard_deadline_miss_total counter\n");
        for (i, s) in metrics.shards().iter().enumerate() {
            out.push_str(&format!(
                "ktruss_shard_deadline_miss_total{{shard=\"{i}\"}} {}\n",
                s.deadline_miss.load(Ordering::Relaxed)
            ));
        }
        out.push_str("# HELP ktruss_shard_queue_depth Queued jobs per shard (gauge)\n");
        out.push_str("# TYPE ktruss_shard_queue_depth gauge\n");
        for (i, s) in metrics.shards().iter().enumerate() {
            out.push_str(&format!(
                "ktruss_shard_queue_depth{{shard=\"{i}\"}} {}\n",
                s.queue_depth.load(Ordering::Relaxed)
            ));
        }
    }

    if let Some(drift) = drift {
        let snap = drift.snapshot();
        if !snap.is_empty() {
            out.push_str(
                "# HELP ktruss_plan_drift_ratio EWMA of actual/predicted wall per plan regime\n",
            );
            out.push_str("# TYPE ktruss_plan_drift_ratio gauge\n");
            for r in &snap {
                out.push_str(&format!(
                    "ktruss_plan_drift_ratio{{plan=\"{}\"}} {:.6}\n",
                    r.plan, r.ratio
                ));
            }
            out.push_str(
                "# HELP ktruss_plan_drift_predicted_ms EWMA of predicted wall per plan regime\n",
            );
            out.push_str("# TYPE ktruss_plan_drift_predicted_ms gauge\n");
            for r in &snap {
                out.push_str(&format!(
                    "ktruss_plan_drift_predicted_ms{{plan=\"{}\"}} {:.6}\n",
                    r.plan, r.predicted_ms
                ));
            }
            out.push_str(
                "# HELP ktruss_plan_drift_actual_ms EWMA of measured wall per plan regime\n",
            );
            out.push_str("# TYPE ktruss_plan_drift_actual_ms gauge\n");
            for r in &snap {
                out.push_str(&format!(
                    "ktruss_plan_drift_actual_ms{{plan=\"{}\"}} {:.6}\n",
                    r.plan, r.actual_ms
                ));
            }
            out.push_str(
                "# HELP ktruss_plan_drift_samples_total Drift observations per plan regime\n",
            );
            out.push_str("# TYPE ktruss_plan_drift_samples_total counter\n");
            for r in &snap {
                out.push_str(&format!(
                    "ktruss_plan_drift_samples_total{{plan=\"{}\"}} {}\n",
                    r.plan, r.samples
                ));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::Engine;

    #[test]
    fn exposition_carries_counters_and_cumulative_buckets() {
        let m = Metrics::with_shards(2);
        m.record_submit();
        m.record_submit();
        m.record_done(Engine::SparseCpu, 0.001, true); // bucket floor 1us
        m.record_done(Engine::SparseCpu, 1.0, false); // bucket floor 512us
        m.record_shard_done(0);
        m.record_steal(1);
        m.set_queue_depth(1, 3);
        let text = render(&m, None);
        assert!(text.contains("ktruss_jobs_submitted_total 2"), "{text}");
        assert!(text.contains("ktruss_jobs_completed_total 2"), "{text}");
        assert!(text.contains("ktruss_jobs_failed_total 1"), "{text}");
        // cumulative: the 512us-floor bucket (le=1024) holds both samples
        assert!(text.contains("ktruss_job_latency_us_bucket{le=\"2\"} 1"), "{text}");
        assert!(text.contains("ktruss_job_latency_us_bucket{le=\"1024\"} 2"), "{text}");
        assert!(text.contains("ktruss_job_latency_us_bucket{le=\"+Inf\"} 2"), "{text}");
        assert!(text.contains("ktruss_job_latency_us_count 2"), "{text}");
        assert!(text.contains("ktruss_shard_jobs_total{shard=\"0\"} 1"), "{text}");
        assert!(text.contains("ktruss_shard_stolen_total{shard=\"1\"} 1"), "{text}");
        assert!(text.contains("ktruss_shard_queue_depth{shard=\"1\"} 3"), "{text}");
    }

    #[test]
    fn robustness_counters_are_always_exposed() {
        // fault-free metrics still expose every robustness series at 0
        let m = Metrics::with_shards(1);
        let text = render(&m, None);
        for series in [
            "ktruss_jobs_shed_total 0",
            "ktruss_jobs_degraded_total 0",
            "ktruss_jobs_cancelled_total 0",
            "ktruss_jobs_quarantined_total 0",
            "ktruss_job_retries_total 0",
            "ktruss_queue_rejected_total 0",
            "ktruss_shard_respawns_total 0",
        ] {
            assert!(text.contains(series), "missing {series}: {text}");
        }
        m.record_shed();
        m.record_degraded();
        m.record_cancelled(0);
        m.record_quarantined();
        m.record_retry();
        m.record_queue_rejected();
        m.record_respawn(0);
        let text = render(&m, None);
        assert!(text.contains("ktruss_jobs_shed_total 1"), "{text}");
        assert!(text.contains("ktruss_jobs_cancelled_total 1"), "{text}");
        assert!(text.contains("ktruss_shard_respawns_total 1"), "{text}");
    }

    #[test]
    fn bucket_series_is_monotone_nondecreasing() {
        let m = Metrics::new();
        for i in 0..50 {
            m.record_done(Engine::SparseCpu, 0.001 * (1 << (i % 8)) as f64, true);
        }
        let text = render(&m, None);
        let mut last = 0u64;
        for line in text.lines().filter(|l| l.starts_with("ktruss_job_latency_us_bucket")) {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= last, "bucket series must be cumulative: {text}");
            last = v;
        }
        assert_eq!(last, 50);
    }

    #[test]
    fn drift_gauges_are_exposed() {
        let m = Metrics::new();
        let d = DriftTracker::new();
        d.observe("static/fine/full", 1.0, 2.0);
        let text = render(&m, Some(&d));
        assert!(
            text.contains("ktruss_plan_drift_ratio{plan=\"static/fine/full\"} 2.000000"),
            "{text}"
        );
        assert!(
            text.contains("ktruss_plan_drift_samples_total{plan=\"static/fine/full\"} 1"),
            "{text}"
        );
        // an empty tracker adds no drift series
        let empty = render(&m, Some(&DriftTracker::new()));
        assert!(!empty.contains("ktruss_plan_drift_ratio"), "{empty}");
    }
}
