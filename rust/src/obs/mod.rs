//! Observability: plan-vs-actual telemetry across all three layers.
//!
//! The kernels already measure exact per-pass merge/probe steps
//! (L1), the drivers return them per iteration (L2), and the serving
//! executor knows what the planner predicted at admission (L3) — this
//! module joins the three into one telemetry stream:
//!
//! * [`span`]: a thread-safe recorder producing a job → pass span
//!   tree; each pass span carries the executed plan axes, task count,
//!   exact measured steps, and wall time, and each job span adds the
//!   queue-wait / execution / deadline segments the executor measures.
//! * [`drift`]: per-plan-regime EWMAs of predicted/actual wall-time
//!   ratios — the calibration cross-check that makes cost-model
//!   miscalibration visible instead of silent.
//! * [`export`]: Chrome trace-event JSON and JSONL span dumps
//!   (`run --trace-out`, `serve --trace-out`).
//! * [`prom`]: Prometheus-style text exposition of the serving
//!   counters plus the drift gauges (`metrics` CLI snapshot,
//!   `bench serve`).

pub mod drift;
pub mod export;
pub mod prom;
pub mod span;

/// The executor-shared observability hub: one span recorder plus one
/// drift tracker, cloned into every shard via `Arc`.
#[derive(Default)]
pub struct ObsHub {
    /// Completed job spans, in completion order.
    pub spans: span::SpanRecorder,
    /// Per-plan-regime predicted/actual drift EWMAs.
    pub drift: drift::DriftTracker,
}

impl ObsHub {
    /// A fresh hub (empty recorder, empty tracker).
    pub fn new() -> ObsHub {
        ObsHub::default()
    }
}
