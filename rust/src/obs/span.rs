//! Span recording: the job → pass span tree.
//!
//! A [`JobSpan`] captures one served job end to end — the plan the
//! planner chose (device/schedule/granularity/support axes), the cost model's
//! predicted wall time, the measured queue-wait / execution / serve
//! segments, and one [`PassSpan`] per convergence iteration carrying
//! the *exact* merge/probe step count the kernels measured (the same
//! counters `cost::trace` replays). The recorder is a thread-safe
//! bounded log shared by every executor shard (newest [`SPAN_CAP`]
//! spans retained); `obs::export` turns a snapshot into Chrome trace
//! JSON or JSONL.

use std::collections::VecDeque;
use std::sync::Mutex;
use std::time::Instant;

/// One support/frontier pass of a truss convergence loop — the leaf of
/// the span tree. Built from
/// [`IterationStat`](crate::algo::ktruss::IterationStat), so `steps`
/// is the kernel's exact measured merge/probe count, not an estimate.
#[derive(Clone, Debug, Default)]
pub struct PassSpan {
    /// Convergence iteration index (0-based).
    pub iter: usize,
    /// Whether this pass ran the incremental frontier kernel (`true`)
    /// or a full support recompute (`false`).
    pub incremental: bool,
    /// Live edges at the start of the iteration.
    pub live_edges: usize,
    /// Edges removed by the prune that followed this pass.
    pub removed: usize,
    /// Exact measured merge/probe steps the pass executed.
    pub steps: u64,
    /// Tasks offered to the worker pool for this pass (0 = sequential
    /// or warm-inherited).
    pub tasks: usize,
    /// Measured wall time of the pass, in milliseconds.
    pub wall_ms: f64,
}

/// One served job: the root of the span tree.
#[derive(Clone, Debug)]
pub struct JobSpan {
    /// Job id (monotonic per executor).
    pub id: u64,
    /// Job kind label (`ktruss`, `kmax`, `decompose`, `triangles`).
    pub kind: String,
    /// Vertices of the job's graph.
    pub n: usize,
    /// Edges of the job's graph.
    pub m: usize,
    /// Shard that executed the job.
    pub shard: usize,
    /// Executed schedule axis of the plan (`-` when unplanned).
    pub schedule: String,
    /// Executed granularity axis of the plan (`-` when unplanned).
    pub granularity: String,
    /// Executed support-mode axis of the plan (`-` when unplanned).
    pub support: String,
    /// Executed device axis of the plan (`cpu` for the pool drivers,
    /// `gpu` for the lane backend; `-` when unplanned).
    pub device: String,
    /// The cost model's static step estimate at admission.
    pub est_steps: u64,
    /// Sum of the pass spans' exact measured steps.
    pub total_steps: u64,
    /// The cost model's predicted wall time at admission, in ms.
    pub predicted_ms: f64,
    /// The planner's scored per-pass prediction (`Planner::choose_scored`),
    /// in ms; `None` when the plan was pinned or the kind is unplanned.
    pub planned_pass_ms: Option<f64>,
    /// Admission-to-dequeue wait, in ms.
    pub queue_ms: f64,
    /// Execution wall time, in ms.
    pub exec_ms: f64,
    /// End-to-end admission-to-completion latency, in ms.
    pub serve_ms: f64,
    /// Soft deadline budget relative to admission, in ms (`None` =
    /// best-effort).
    pub deadline_ms: Option<f64>,
    /// Whether the job completed past its soft deadline.
    pub deadline_missed: bool,
    /// Execution start, µs since the recorder's epoch (trace timeline).
    pub start_us: u64,
    /// Whether the job completed without error.
    pub ok: bool,
    /// Terminal disposition (`done`, `shed`, `degraded`, `cancelled`,
    /// `quarantined`) — the string form of
    /// [`JobOutcome`](crate::coordinator::job::JobOutcome).
    pub outcome: String,
    /// Per-iteration pass spans (empty for non-truss kinds).
    pub passes: Vec<PassSpan>,
}

impl JobSpan {
    /// The executed plan as one `device/schedule/granularity/support`
    /// string (`-/-/-/-` when unplanned). The device axis leads so
    /// drift regimes keyed off this string separate lane-backend walls
    /// from pool walls.
    pub fn plan_string(&self) -> String {
        format!("{}/{}/{}/{}", self.device, self.schedule, self.granularity, self.support)
    }
}

/// Retention cap: the recorder keeps the most recent this-many job
/// spans, so a long-lived server's trace memory stays bounded while
/// any realistic `--trace-out` window is fully covered.
pub const SPAN_CAP: usize = 65_536;

/// Thread-safe span log shared across executor shards; keeps the
/// newest [`SPAN_CAP`] spans.
pub struct SpanRecorder {
    epoch: Instant,
    spans: Mutex<VecDeque<JobSpan>>,
}

impl Default for SpanRecorder {
    fn default() -> Self {
        SpanRecorder::new()
    }
}

impl SpanRecorder {
    /// An empty recorder; its construction instant is the trace epoch.
    pub fn new() -> SpanRecorder {
        SpanRecorder { epoch: Instant::now(), spans: Mutex::new(VecDeque::new()) }
    }

    /// Microseconds since the recorder's epoch (span timestamps share
    /// one timeline regardless of which shard records them).
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Append one completed job span, evicting the oldest once the
    /// log holds [`SPAN_CAP`] spans.
    pub fn record(&self, span: JobSpan) {
        let mut spans = self.spans.lock().unwrap();
        if spans.len() >= SPAN_CAP {
            spans.pop_front();
        }
        spans.push_back(span);
    }

    /// Copy of every retained span, in completion order.
    pub fn snapshot(&self) -> Vec<JobSpan> {
        self.spans.lock().unwrap().iter().cloned().collect()
    }

    /// Spans recorded so far.
    pub fn len(&self) -> usize {
        self.spans.lock().unwrap().len()
    }

    /// Whether no spans have been recorded.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Build the pass spans for a completed truss run from its driver
/// statistics (one span per convergence iteration; `steps` is exact).
pub fn passes_from_stats(stats: &[crate::algo::ktruss::IterationStat]) -> Vec<PassSpan> {
    stats
        .iter()
        .enumerate()
        .map(|(i, st)| PassSpan {
            iter: i,
            incremental: st.incremental,
            live_edges: st.live_edges,
            removed: st.removed,
            steps: st.support_steps,
            tasks: st.tasks,
            wall_ms: st.wall_ms,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(id: u64, steps: &[u64]) -> JobSpan {
        JobSpan {
            id,
            kind: "ktruss".into(),
            n: 10,
            m: 20,
            shard: 0,
            schedule: "static".into(),
            granularity: "fine".into(),
            support: "full".into(),
            device: "cpu".into(),
            est_steps: 100,
            total_steps: steps.iter().sum(),
            predicted_ms: 1.0,
            planned_pass_ms: Some(0.5),
            queue_ms: 0.1,
            exec_ms: 0.8,
            serve_ms: 0.9,
            deadline_ms: None,
            deadline_missed: false,
            start_us: 42,
            ok: true,
            outcome: "done".into(),
            passes: steps
                .iter()
                .enumerate()
                .map(|(i, &s)| PassSpan { iter: i, steps: s, ..PassSpan::default() })
                .collect(),
        }
    }

    #[test]
    fn recorder_appends_and_snapshots() {
        let rec = SpanRecorder::new();
        assert!(rec.is_empty());
        rec.record(span(1, &[3, 4]));
        rec.record(span(2, &[5]));
        let snap = rec.snapshot();
        assert_eq!(rec.len(), 2);
        assert_eq!(snap[0].id, 1);
        assert_eq!(snap[0].total_steps, 7);
        assert_eq!(snap[1].passes.len(), 1);
        assert_eq!(snap[0].plan_string(), "cpu/static/fine/full");
    }

    #[test]
    fn recorder_evicts_oldest_past_cap() {
        let rec = SpanRecorder::new();
        for id in 0..(SPAN_CAP as u64 + 3) {
            rec.record(span(id, &[1]));
        }
        assert_eq!(rec.len(), SPAN_CAP);
        let snap = rec.snapshot();
        assert_eq!(snap.first().unwrap().id, 3);
        assert_eq!(snap.last().unwrap().id, SPAN_CAP as u64 + 2);
    }

    #[test]
    fn pass_spans_mirror_iteration_stats() {
        let stats = vec![
            crate::algo::ktruss::IterationStat {
                live_edges: 9,
                removed: 2,
                support_steps: 30,
                incremental: false,
                wall_ms: 0.5,
                tasks: 9,
            },
            crate::algo::ktruss::IterationStat {
                live_edges: 7,
                removed: 0,
                support_steps: 4,
                incremental: true,
                wall_ms: 0.1,
                tasks: 2,
            },
        ];
        let passes = passes_from_stats(&stats);
        assert_eq!(passes.len(), 2);
        assert_eq!(passes[0].iter, 0);
        assert_eq!(passes[0].steps, 30);
        assert!(!passes[0].incremental);
        assert_eq!(passes[1].iter, 1);
        assert!(passes[1].incremental);
        assert_eq!(passes[1].tasks, 2);
        assert_eq!(passes.iter().map(|p| p.steps).sum::<u64>(), 34);
    }

    #[test]
    fn now_us_is_monotonic() {
        let rec = SpanRecorder::new();
        let a = rec.now_us();
        let b = rec.now_us();
        assert!(b >= a);
    }
}
