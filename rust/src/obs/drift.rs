//! Drift accounting: predicted-vs-actual calibration cross-check.
//!
//! At job completion the executor joins the admission-time prediction
//! (the cost model's per-label estimate, plus the planner's scored
//! per-pass prediction when one exists) against the measured wall time,
//! keyed by the executed plan axes
//! (`device/schedule/granularity/support`). Each key holds EWMAs of
//! predicted ms, actual ms, and the actual/predicted ratio, so a regime
//! the model consistently mis-prices shows up as a ratio far from 1.
//! The device axis leads the key so lane-backend
//! ([`crate::exec::lane`]) walls accumulate in their own `gpu/…` bands
//! instead of polluting the `cpu/…` EWMAs the pool drivers calibrate
//! against.

use crate::cost::persist::TraceRecord;
use crate::serve::cost_model::CostModel;
use std::collections::HashMap;
use std::sync::Mutex;

/// EWMA smoothing factor for drift observations (matches the cost
/// model's per-label smoothing so the two converge at the same rate).
const EWMA_ALPHA: f64 = 0.2;

#[derive(Clone, Copy)]
struct DriftStat {
    predicted_ms: f64,
    actual_ms: f64,
    ratio: f64,
    samples: u64,
}

impl DriftStat {
    fn new() -> DriftStat {
        DriftStat { predicted_ms: 0.0, actual_ms: 0.0, ratio: 1.0, samples: 0 }
    }

    fn fold(&mut self, predicted: f64, actual: f64) {
        let ratio = actual / predicted;
        if self.samples == 0 {
            self.predicted_ms = predicted;
            self.actual_ms = actual;
            self.ratio = ratio;
        } else {
            self.predicted_ms = EWMA_ALPHA * predicted + (1.0 - EWMA_ALPHA) * self.predicted_ms;
            self.actual_ms = EWMA_ALPHA * actual + (1.0 - EWMA_ALPHA) * self.actual_ms;
            self.ratio = EWMA_ALPHA * ratio + (1.0 - EWMA_ALPHA) * self.ratio;
        }
        self.samples += 1;
    }
}

/// One plan regime's drift snapshot.
#[derive(Clone, Debug, PartialEq)]
pub struct DriftReport {
    /// The plan axes key (`device/schedule/granularity/support`).
    pub plan: String,
    /// EWMA of predicted wall time, ms.
    pub predicted_ms: f64,
    /// EWMA of measured wall time, ms.
    pub actual_ms: f64,
    /// EWMA of the per-job actual/predicted ratio (1.0 = calibrated,
    /// above 1 = the model is optimistic, below 1 = pessimistic).
    pub ratio: f64,
    /// Observations folded into this regime.
    pub samples: u64,
}

/// Thread-safe per-plan-regime drift tracker shared by executor shards.
pub struct DriftTracker {
    state: Mutex<HashMap<String, DriftStat>>,
}

impl Default for DriftTracker {
    fn default() -> Self {
        DriftTracker::new()
    }
}

impl DriftTracker {
    /// An empty tracker.
    pub fn new() -> DriftTracker {
        DriftTracker { state: Mutex::new(HashMap::new()) }
    }

    /// Fold one completed job: `predicted_ms` is the admission-time
    /// estimate, `actual_ms` the measured execution wall. Degenerate
    /// observations (non-positive or non-finite on either side) are
    /// dropped rather than poisoning the ratio.
    pub fn observe(&self, plan: &str, predicted_ms: f64, actual_ms: f64) {
        if predicted_ms <= 0.0
            || !predicted_ms.is_finite()
            || actual_ms < 0.0
            || !actual_ms.is_finite()
        {
            return;
        }
        self.state
            .lock()
            .unwrap()
            .entry(plan.to_string())
            .or_insert_with(DriftStat::new)
            .fold(predicted_ms, actual_ms);
    }

    /// Seed drift baselines from persisted calibration records carrying
    /// plan provenance (see [`TraceRecord`]): the record's wall time is
    /// the actual; the prediction is what `model` — itself seeded from
    /// the same records — estimates for the record's label and steps.
    /// Provenance-less legacy records (axes `-`) are skipped.
    pub fn seed(&self, records: &[TraceRecord], model: &CostModel) {
        for r in records {
            if !r.has_provenance() {
                continue;
            }
            let plan =
                format!("{}/{}/{}/{}", r.device, r.schedule, r.granularity, r.support);
            self.observe(&plan, model.predict_ms_for(&r.kind, r.est_steps), r.wall_ms);
        }
    }

    /// Every regime's drift report, sorted by plan key.
    pub fn snapshot(&self) -> Vec<DriftReport> {
        let st = self.state.lock().unwrap();
        let mut out: Vec<DriftReport> = st
            .iter()
            .map(|(plan, s)| DriftReport {
                plan: plan.clone(),
                predicted_ms: s.predicted_ms,
                actual_ms: s.actual_ms,
                ratio: s.ratio,
                samples: s.samples,
            })
            .collect();
        out.sort_by(|a, b| a.plan.cmp(&b.plan));
        out
    }

    /// Regimes with at least `min_samples` observations whose ratio
    /// EWMA sits outside `[1/band, band]` — the miscalibrated set.
    pub fn flagged(&self, band: f64, min_samples: u64) -> Vec<DriftReport> {
        let band = band.max(1.0);
        self.snapshot()
            .into_iter()
            .filter(|r| r.samples >= min_samples && (r.ratio > band || r.ratio < 1.0 / band))
            .collect()
    }

    /// One line per regime, machine-greppable
    /// (`drift[plan] predicted_ms=… actual_ms=… ratio=… n=…`).
    pub fn render(&self) -> String {
        self.snapshot()
            .iter()
            .map(|r| {
                format!(
                    "drift[{}] predicted_ms={:.3} actual_ms={:.3} ratio={:.3} n={}",
                    r.plan, r.predicted_ms, r.actual_ms, r.ratio, r.samples
                )
            })
            .collect::<Vec<_>>()
            .join("\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn observe_tracks_ratio_per_regime() {
        let d = DriftTracker::new();
        d.observe("static/fine/full", 1.0, 2.0);
        d.observe("dynamic/coarse/full", 4.0, 1.0);
        let snap = d.snapshot();
        assert_eq!(snap.len(), 2);
        // sorted by plan key
        assert_eq!(snap[0].plan, "dynamic/coarse/full");
        assert!((snap[0].ratio - 0.25).abs() < 1e-12);
        assert_eq!(snap[1].plan, "static/fine/full");
        assert!((snap[1].ratio - 2.0).abs() < 1e-12);
        assert_eq!(snap[1].samples, 1);
    }

    #[test]
    fn ewma_pulls_toward_new_observations() {
        let d = DriftTracker::new();
        d.observe("p", 1.0, 1.0);
        d.observe("p", 1.0, 3.0);
        let r = &d.snapshot()[0];
        assert_eq!(r.samples, 2);
        assert!(r.ratio > 1.0 && r.ratio < 3.0, "{}", r.ratio);
        assert!((r.ratio - (0.2 * 3.0 + 0.8 * 1.0)).abs() < 1e-12);
    }

    #[test]
    fn degenerate_observations_are_dropped() {
        let d = DriftTracker::new();
        d.observe("p", 0.0, 1.0);
        d.observe("p", -1.0, 1.0);
        d.observe("p", f64::NAN, 1.0);
        d.observe("p", 1.0, f64::NAN);
        d.observe("p", 1.0, -0.5);
        assert!(d.snapshot().is_empty());
    }

    #[test]
    fn flagged_respects_band_and_min_samples() {
        let d = DriftTracker::new();
        for _ in 0..5 {
            d.observe("calibrated", 1.0, 1.05);
            d.observe("optimistic", 1.0, 10.0);
        }
        d.observe("thin", 1.0, 10.0); // 1 sample only
        let flagged = d.flagged(2.0, 3);
        assert_eq!(flagged.len(), 1);
        assert_eq!(flagged[0].plan, "optimistic");
        // a pessimistic regime (ratio < 1/band) is flagged too
        for _ in 0..5 {
            d.observe("pessimistic", 10.0, 1.0);
        }
        let plans: Vec<String> = d.flagged(2.0, 3).into_iter().map(|r| r.plan).collect();
        assert_eq!(plans, vec!["optimistic".to_string(), "pessimistic".to_string()]);
    }

    #[test]
    fn render_is_greppable() {
        let d = DriftTracker::new();
        d.observe("static/fine/full", 2.0, 1.0);
        let line = d.render();
        assert!(line.contains("drift[static/fine/full]"), "{line}");
        assert!(line.contains("ratio=0.500"), "{line}");
        assert!(line.contains("n=1"), "{line}");
    }

    #[test]
    fn seed_skips_legacy_records() {
        let model = CostModel::new();
        let legacy = TraceRecord::unplanned("ktruss+full".into(), 10, 20, 1000, 0.5);
        let planned = TraceRecord {
            schedule: "static".into(),
            granularity: "fine".into(),
            support: "full".into(),
            ..legacy.clone()
        };
        let executed = TraceRecord { device: "gpu".into(), ..planned.clone() };
        let d = DriftTracker::new();
        d.seed(&[legacy, planned, executed], &model);
        let snap = d.snapshot();
        assert_eq!(snap.len(), 2);
        // pre-device records seed a distinct `-` device band; lane-executed
        // records land in their own gpu band.
        assert_eq!(snap[0].plan, "-/static/fine/full");
        assert_eq!(snap[1].plan, "gpu/static/fine/full");
        assert_eq!(snap[0].samples, 1);
    }
}
