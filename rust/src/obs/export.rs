//! Span export: Chrome trace-event JSON and JSONL dumps.
//!
//! Hand-rolled serialization (the offline crate set has no serde). Two
//! formats off one [`JobSpan`] snapshot:
//!
//! * **Chrome trace** ([`chrome_trace`]): an array of complete (`"X"`)
//!   trace events loadable in `chrome://tracing` / Perfetto — one
//!   queue-wait event and one execution event per job (pid = 0, tid =
//!   shard), plus one nested event per pass span carrying its exact
//!   measured steps in `args`.
//! * **JSONL** ([`jsonl`]): one self-contained JSON object per job
//!   span, line-oriented for `jq`/awk post-processing.
//!
//! [`write_trace`] picks by extension: `.jsonl` → JSONL, anything else
//! → Chrome trace JSON.

use crate::obs::span::JobSpan;
use anyhow::{Context, Result};
use std::path::Path;

/// Escape a string for embedding in a JSON string literal.
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render one finite f64 for JSON (JSON has no NaN/Inf; degenerate
/// values serialize as 0).
fn num(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.6}")
    } else {
        "0".to_string()
    }
}

fn event(
    name: &str,
    cat: &str,
    ts_us: f64,
    dur_us: f64,
    tid: usize,
    args: &[(&str, String)],
) -> String {
    let args_json = args
        .iter()
        .map(|(k, v)| format!("\"{}\":{}", esc(k), v))
        .collect::<Vec<_>>()
        .join(",");
    format!(
        "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\"pid\":0,\"tid\":{},\"args\":{{{}}}}}",
        esc(name),
        esc(cat),
        num(ts_us),
        num(dur_us.max(0.0)),
        tid,
        args_json
    )
}

/// Render spans as a Chrome trace-event JSON document
/// (`{"traceEvents": [...]}`).
pub fn chrome_trace(spans: &[JobSpan]) -> String {
    let mut events = Vec::new();
    for s in spans {
        let start = s.start_us as f64;
        let queue_start = start - s.queue_ms * 1e3;
        events.push(event(
            &format!("queue job {}", s.id),
            "queue",
            queue_start,
            s.queue_ms * 1e3,
            s.shard,
            &[("job", s.id.to_string())],
        ));
        events.push(event(
            &format!("{} job {}", s.kind, s.id),
            "job",
            start,
            s.exec_ms * 1e3,
            s.shard,
            &[
                ("job", s.id.to_string()),
                ("kind", format!("\"{}\"", esc(&s.kind))),
                ("plan", format!("\"{}\"", esc(&s.plan_string()))),
                ("n", s.n.to_string()),
                ("m", s.m.to_string()),
                ("est_steps", s.est_steps.to_string()),
                ("total_steps", s.total_steps.to_string()),
                ("predicted_ms", num(s.predicted_ms)),
                (
                    "planned_pass_ms",
                    s.planned_pass_ms.map(num).unwrap_or_else(|| "null".to_string()),
                ),
                ("serve_ms", num(s.serve_ms)),
                ("deadline_missed", s.deadline_missed.to_string()),
                ("ok", s.ok.to_string()),
                ("outcome", format!("\"{}\"", esc(&s.outcome))),
            ],
        ));
        // nest each pass inside the job's execution window,
        // apportioned by measured pass wall time (falling back to an
        // even split when the passes carry no timing)
        let total_wall: f64 = s.passes.iter().map(|p| p.wall_ms).sum();
        let mut cursor = start;
        for p in &s.passes {
            let dur_us = if total_wall > 0.0 {
                p.wall_ms * 1e3
            } else {
                s.exec_ms * 1e3 / s.passes.len() as f64
            };
            events.push(event(
                &format!("pass {} job {}", p.iter, s.id),
                "pass",
                cursor,
                dur_us,
                s.shard,
                &[
                    ("job", s.id.to_string()),
                    ("iter", p.iter.to_string()),
                    ("steps", p.steps.to_string()),
                    ("tasks", p.tasks.to_string()),
                    ("live_edges", p.live_edges.to_string()),
                    ("removed", p.removed.to_string()),
                    ("incremental", p.incremental.to_string()),
                ],
            ));
            cursor += dur_us;
        }
    }
    format!("{{\"traceEvents\":[\n{}\n]}}\n", events.join(",\n"))
}

/// Render one job span as a self-contained JSON object.
pub fn span_json(s: &JobSpan) -> String {
    let passes = s
        .passes
        .iter()
        .map(|p| {
            format!(
                "{{\"iter\":{},\"incremental\":{},\"live_edges\":{},\"removed\":{},\"steps\":{},\"tasks\":{},\"wall_ms\":{}}}",
                p.iter, p.incremental, p.live_edges, p.removed, p.steps, p.tasks, num(p.wall_ms)
            )
        })
        .collect::<Vec<_>>()
        .join(",");
    format!(
        "{{\"id\":{},\"kind\":\"{}\",\"n\":{},\"m\":{},\"shard\":{},\"plan\":\"{}\",\"est_steps\":{},\"total_steps\":{},\"predicted_ms\":{},\"planned_pass_ms\":{},\"queue_ms\":{},\"exec_ms\":{},\"serve_ms\":{},\"deadline_ms\":{},\"deadline_missed\":{},\"start_us\":{},\"ok\":{},\"outcome\":\"{}\",\"passes\":[{}]}}",
        s.id,
        esc(&s.kind),
        s.n,
        s.m,
        s.shard,
        esc(&s.plan_string()),
        s.est_steps,
        s.total_steps,
        num(s.predicted_ms),
        s.planned_pass_ms.map(num).unwrap_or_else(|| "null".to_string()),
        num(s.queue_ms),
        num(s.exec_ms),
        num(s.serve_ms),
        s.deadline_ms.map(num).unwrap_or_else(|| "null".to_string()),
        s.deadline_missed,
        s.start_us,
        s.ok,
        esc(&s.outcome),
        passes
    )
}

/// Render spans as JSONL (one [`span_json`] object per line).
pub fn jsonl(spans: &[JobSpan]) -> String {
    let mut out = String::new();
    for s in spans {
        out.push_str(&span_json(s));
        out.push('\n');
    }
    out
}

/// Write spans to `path`: `.jsonl` extension → JSONL, anything else →
/// Chrome trace-event JSON.
pub fn write_trace(path: &Path, spans: &[JobSpan]) -> Result<()> {
    let body = if path.extension().and_then(|e| e.to_str()) == Some("jsonl") {
        jsonl(spans)
    } else {
        chrome_trace(spans)
    };
    std::fs::write(path, body).with_context(|| format!("write trace file {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::span::PassSpan;

    fn span() -> JobSpan {
        JobSpan {
            id: 7,
            kind: "ktruss".into(),
            n: 10,
            m: 20,
            shard: 1,
            schedule: "static".into(),
            granularity: "fine".into(),
            support: "full".into(),
            device: "cpu".into(),
            est_steps: 100,
            total_steps: 34,
            predicted_ms: 1.5,
            planned_pass_ms: None,
            queue_ms: 0.2,
            exec_ms: 0.8,
            serve_ms: 1.0,
            deadline_ms: Some(5.0),
            deadline_missed: false,
            start_us: 1000,
            ok: true,
            outcome: "done".into(),
            passes: vec![
                PassSpan { iter: 0, steps: 30, wall_ms: 0.6, ..PassSpan::default() },
                PassSpan {
                    iter: 1,
                    steps: 4,
                    wall_ms: 0.2,
                    incremental: true,
                    ..PassSpan::default()
                },
            ],
        }
    }

    #[test]
    fn chrome_trace_has_job_and_pass_events() {
        let doc = chrome_trace(&[span()]);
        assert!(doc.starts_with("{\"traceEvents\":["), "{doc}");
        assert!(doc.contains("\"ktruss job 7\""), "{doc}");
        assert!(doc.contains("\"queue job 7\""), "{doc}");
        assert!(doc.contains("\"pass 0 job 7\""), "{doc}");
        assert!(doc.contains("\"pass 1 job 7\""), "{doc}");
        assert!(doc.contains("\"total_steps\":34"), "{doc}");
        assert!(doc.contains("\"steps\":30"), "{doc}");
        assert!(doc.contains("\"plan\":\"cpu/static/fine/full\""), "{doc}");
        assert!(doc.contains("\"planned_pass_ms\":null"), "{doc}");
        assert!(doc.contains("\"outcome\":\"done\""), "{doc}");
    }

    #[test]
    fn jsonl_is_one_object_per_line() {
        let text = jsonl(&[span(), span()]);
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for l in lines {
            assert!(l.starts_with('{') && l.ends_with('}'), "{l}");
            assert!(l.contains("\"total_steps\":34"), "{l}");
            assert!(l.contains("\"deadline_ms\":5.000000"), "{l}");
            assert!(l.contains("\"outcome\":\"done\""), "{l}");
        }
    }

    #[test]
    fn escaping_handles_quotes_and_controls() {
        assert_eq!(esc("a\"b\\c"), "a\\\"b\\\\c");
        assert_eq!(esc("x\ny"), "x\\ny");
        assert_eq!(esc("\u{1}"), "\\u0001");
        assert_eq!(num(f64::NAN), "0");
    }

    #[test]
    fn write_trace_picks_format_by_extension() {
        let dir = std::env::temp_dir();
        let chrome = dir.join("ktruss-obs-export-test.json");
        let lines = dir.join("ktruss-obs-export-test.jsonl");
        write_trace(&chrome, &[span()]).unwrap();
        write_trace(&lines, &[span()]).unwrap();
        let chrome_text = std::fs::read_to_string(&chrome).unwrap();
        let lines_text = std::fs::read_to_string(&lines).unwrap();
        assert!(chrome_text.contains("traceEvents"));
        assert!(!lines_text.contains("traceEvents"));
        assert!(lines_text.trim().starts_with('{'));
        std::fs::remove_file(&chrome).unwrap();
        std::fs::remove_file(&lines).unwrap();
    }
}
