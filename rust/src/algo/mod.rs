//! **L1 — kernels.** The Eager K-truss algorithm family (paper
//! Algorithms 1–3): support computation across the full granularity
//! ladder — [`support::Mode::Coarse`] (one task per row),
//! [`support::Mode::Fine`] (one task per nonzero), the ultra-fine
//! [`support::Granularity::Segment`] split (one task per ≤ L-entry
//! partner-row segment), and the per-row hybrid
//! [`support::Granularity::Hybrid`] representation ([`bitmap`]: bitmap
//! hub rows probed by tail-side chunks, merge segments elsewhere) —
//! plus pruning, the convergence driver, K_max
//! search, full truss decomposition, and the independent naive oracle.
//! This layer owns load balancing at *merge-step* granularity: how the
//! pass's work is cut into tasks; [`crate::par`] decides how tasks map
//! to workers, [`crate::serve`] how jobs map to shards.
//!
//! The convergence driver maintains supports across iterations in one
//! of three [`SupportMode`]s: full recompute, incremental
//! frontier-driven decrement ([`incremental`]), or a per-iteration
//! auto crossover (the default).

pub mod bitmap;
pub mod decompose;
pub mod incremental;
pub mod kmax;
pub mod ktruss;
pub mod prune;
pub mod reference;
pub mod stream;
pub mod support;
pub mod triangle;

pub use incremental::SupportMode;
pub use ktruss::{ktruss, KtrussResult};
pub use support::Mode;
