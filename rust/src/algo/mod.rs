//! The Eager K-truss algorithm family (paper Algorithms 1–3):
//! support computation in coarse and fine granularity, pruning,
//! the convergence driver, K_max search, full truss decomposition,
//! and the independent naive oracle.

pub mod decompose;
pub mod kmax;
pub mod ktruss;
pub mod prune;
pub mod reference;
pub mod support;
pub mod triangle;

pub use ktruss::{ktruss, KtrussResult};
pub use support::Mode;
