//! Naive reference K-truss — a deliberately independent implementation
//! (hash-set adjacency, no zero-terminated CSR, no eager updates) used
//! as the correctness oracle, mirroring the paper's verification against
//! the Low et al. reference code.

use crate::graph::{Csr, Vid};
use std::collections::{HashMap, HashSet};

/// Compute the k-truss edge set by repeated full recount + removal.
/// O(iterations · m · d_max) — fine for oracle-scale graphs only.
pub fn ktruss_naive(g: &Csr, k: u32) -> Vec<(Vid, Vid)> {
    let threshold = k.saturating_sub(2);
    // symmetric adjacency sets
    let mut adj: HashMap<Vid, HashSet<Vid>> = HashMap::new();
    for (u, v) in g.edges() {
        adj.entry(u).or_default().insert(v);
        adj.entry(v).or_default().insert(u);
    }
    let mut edges: HashSet<(Vid, Vid)> = g.edges().collect();
    loop {
        let mut to_remove: Vec<(Vid, Vid)> = Vec::new();
        for &(u, v) in &edges {
            let (nu, nv) = (&adj[&u], &adj[&v]);
            let common = if nu.len() <= nv.len() {
                nu.iter().filter(|w| nv.contains(w)).count()
            } else {
                nv.iter().filter(|w| nu.contains(w)).count()
            };
            if (common as u32) < threshold {
                to_remove.push((u, v));
            }
        }
        if to_remove.is_empty() {
            break;
        }
        for (u, v) in to_remove {
            edges.remove(&(u, v));
            adj.get_mut(&u).map(|s| s.remove(&v));
            adj.get_mut(&v).map(|s| s.remove(&u));
        }
    }
    let mut out: Vec<(Vid, Vid)> = edges.into_iter().collect();
    out.sort_unstable();
    out
}

/// Naive K_max: linear scan upward.
pub fn kmax_naive(g: &Csr) -> u32 {
    if g.nnz() == 0 {
        return 0;
    }
    let mut k = 2u32;
    loop {
        if ktruss_naive(g, k + 1).is_empty() {
            return k;
        }
        k += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::ktruss::{ktruss, Mode};
    use crate::graph::builder::from_sorted_unique;

    #[test]
    fn matches_eager_on_small_known_graph() {
        let g = from_sorted_unique(
            6,
            &[(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3), (3, 4), (4, 5)],
        );
        for k in [3u32, 4, 5] {
            let naive = ktruss_naive(&g, k);
            let eager: Vec<(Vid, Vid)> = ktruss(&g, k, Mode::Fine).truss.edges().collect();
            assert_eq!(naive, eager, "k={k}");
        }
    }

    #[test]
    fn matches_eager_on_random_graphs() {
        for seed in 0..5u64 {
            let g = crate::gen::rmat::rmat(
                120,
                700,
                crate::gen::rmat::RmatParams::social(),
                &mut crate::util::Rng::new(seed),
            );
            for k in [3u32, 4, 6] {
                let naive = ktruss_naive(&g, k);
                let eager: Vec<(Vid, Vid)> = ktruss(&g, k, Mode::Coarse).truss.edges().collect();
                assert_eq!(naive, eager, "seed={seed} k={k}");
            }
        }
    }

    #[test]
    fn kmax_agrees() {
        let g = crate::gen::community::communities(100, 500, 12, &mut crate::util::Rng::new(4));
        assert_eq!(kmax_naive(&g), crate::algo::kmax::kmax(&g).kmax);
    }
}
